"""Quickstart: the paper's technique in 30 lines.

Takes a weight tensor, packs it into link flits, measures bit transitions,
applies '1'-bit-count descending ordering, and shows the BT reduction -
Table I of the paper in miniature.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (pack, bt_per_flit, descending_order,
                        expected_bt_stream, measure_stream, wire_transform)
from repro.quant import quantize_fixed8

# a "trained-like" weight tensor: concentrated near zero
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (20000,)) * 0.1
w = w * (jax.random.uniform(jax.random.fold_in(key, 1), w.shape) ** 2)

print("== float-32 over a 256-bit link (8 values/flit) ==")
base = pack(w.astype(jnp.float32), lanes=8)
ordered = pack(descending_order(w.astype(jnp.float32)).values, lanes=8)
b, o = float(bt_per_flit(base)), float(bt_per_flit(ordered))
print(f"baseline {b:.2f} BT/flit -> ordered {o:.2f} BT/flit "
      f"({(1 - o / b) * 100:.1f}% reduction)")

print("\n== fixed-8 over a 64-bit link ==")
q = quantize_fixed8(w).values
base = pack(q, lanes=8)
ordered = pack(descending_order(q).values, lanes=8)
b, o = float(bt_per_flit(base)), float(bt_per_flit(ordered))
print(f"baseline {b:.2f} BT/flit -> ordered {o:.2f} BT/flit "
      f"({(1 - o / b) * 100:.1f}% reduction)")

print("\n== the O1/O2 wire transforms (paired input|weight flits) ==")
x = jax.random.normal(jax.random.fold_in(key, 2), w.shape).astype(jnp.float32)
for name in ("O0", "O1", "O2"):
    t = wire_transform(name, window=512)
    stream = t.apply(x, w.astype(jnp.float32), lanes=16)
    m = measure_stream(stream)
    print(f"{name}: {m['bt_per_flit']:8.2f} BT/flit over {m['num_flits']} flits"
          f"  (expected-BT model: {m['expected_bt']:.0f})")
