"""Run a LeNet inference's operand traffic through the cycle-level NoC
under O0/O1/O2 and report per-configuration link BT - the paper's Fig. 12
pipeline end to end (train -> quantize -> packetize -> order -> simulate),
driven by the declarative sweep engine: all three orderings are packetized
once and drained in a single batched, compile-cached simulation.
``--results`` also drains the PE->MC result phase; ``--affinity nearest``
serves each PE from its hop-minimizing MC instead of round-robin; ``--o3``
adds the beyond-paper O3/O3a min-Hamming ordering lanes.

    PYTHONPATH=src python examples/noc_inference.py [--noc 8x8_mc4] [--f32]
"""
import argparse

import jax

from repro.data import glyph_batch
from repro.models import LeNet, init_params
from repro.noc import (AFFINITIES, PAPER_NOCS, PLACEMENTS, SweepGrid,
                       mc_placement, mesh_by_name, run_sweep)
from repro.noc.power import link_power_mw, ordering_overhead_mw
from repro.optim import AdamW, cosine
from repro.train import make_train_step, init_state

ap = argparse.ArgumentParser()
ap.add_argument("--noc", default="4x4_mc2",
                help=f"one of {sorted(PAPER_NOCS)} or any RxC_mcN spec")
ap.add_argument("--f32", action="store_true", help="float-32 (default fixed-8)")
ap.add_argument("--placement", default="edge", choices=sorted(PLACEMENTS),
                help="MC placement strategy (default: the paper's edge spread)")
ap.add_argument("--full", action="store_true",
                help="packetize the full inference (streamed chunked path) "
                     "instead of subsampling to --max-packets")
ap.add_argument("--affinity", default="roundrobin", choices=sorted(AFFINITIES),
                help="packet->MC assignment (nearest = hop-minimizing MC "
                     "per PE instead of the round-robin deal)")
ap.add_argument("--results", action="store_true",
                help="also drain the PE->MC result phase and report its "
                     "per-direction BT and drain cycles")
ap.add_argument("--o3", action="store_true",
                help="add the beyond-paper O3/O3a min-Hamming orderings "
                     "to the transform axis")
ap.add_argument("--train-steps", type=int, default=60)
ap.add_argument("--max-packets", type=int, default=30)
args = ap.parse_args()

model = LeNet()
params = init_params(model.specs(), jax.random.PRNGKey(0))
opt = AdamW(cosine(2e-3, args.train_steps, warmup=5), weight_decay=0.0)
step = jax.jit(make_train_step(lambda p, b: model.loss(p, *b), opt))
state = init_state(params, opt)
print(f"training LeNet on glyphs for {args.train_steps} steps...")
for i in range(args.train_steps):
    state, m = step(state, glyph_batch(jax.random.PRNGKey(i), 32))
print(f"final loss {float(m['loss']):.3f}")

x, _ = glyph_batch(jax.random.PRNGKey(99), 1)
layers = model.layer_traffic(state.params, x[0])
cfg = mesh_by_name(args.noc)
mc_nodes = mc_placement(cfg.rows, cfg.cols, cfg.num_mcs, args.placement)

print(f"\nNoC {args.noc}: {cfg.rows}x{cfg.cols}, {cfg.num_mcs} MCs "
      f"({args.placement} placement at routers {list(mc_nodes)}), "
      f"{cfg.num_inter_router_links} inter-router links")
grid = SweepGrid(
    meshes=(args.noc,), placements=(args.placement,),
    affinity=(args.affinity,),
    transforms=("O0", "O1", "O2", "O3", "O3a") if args.o3
    else ("O0", "O1", "O2"),
    tiebreaks=("pattern",),
    precisions=("float32" if args.f32 else "fixed8",), models=("lenet",),
    max_packets_per_layer=None if args.full else args.max_packets,
    result_phase=args.results, chunk=2048)
report = run_sweep(grid, lambda _name: layers)
print(f"packet->MC affinity: {args.affinity} "
      f"(mean {report.rows[0]['mean_hops']:.2f} hops per packet)")
for row in report.rows:
    red = "" if row["transform"] == grid.baseline else \
        f"  ({row['reduction_pct']:+.1f}% vs O0," \
        f" {row['adjusted_reduction_pct']:+.1f}% after recovery index)"
    tpc = row["total_bt"] / row["cycles"]
    pw = link_power_mw(tpc)
    res = "" if row["result_bt"] is None else \
        f" + result phase {row['result_bt']} BT / {row['result_cycles']} cyc"
    print(f"{row['transform']}: {row['total_bt']:10d} BT over "
          f"{row['cycles']} cycles -> link power {pw:7.2f} mW{red}{res}")
print(f"sweep engine: {report.stats['cycles_per_sec']:.0f} simulated "
      f"cycles/s across {report.stats['cells']} cells")
print(f"ordering-unit overhead: O1 {ordering_overhead_mw(cfg.num_mcs):.2f} mW, "
      f"O2 {ordering_overhead_mw(cfg.num_mcs, separated=True):.2f} mW")
