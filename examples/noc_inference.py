"""Run a LeNet inference's operand traffic through the cycle-level NoC
under O0/O1/O2 and report per-configuration link BT - the paper's Fig. 12
pipeline end to end (train -> quantize -> packetize -> order -> simulate).

    PYTHONPATH=src python examples/noc_inference.py [--noc 8x8_mc4] [--f32]
"""
import argparse

import jax

from repro.core.wire import by_name
from repro.data import glyph_batch
from repro.models import LeNet, init_params
from repro.noc import PAPER_NOCS, build_traffic, simulate
from repro.noc.power import link_power_mw, ordering_overhead_mw
from repro.optim import AdamW, cosine
from repro.quant import quantize_fixed8
from repro.train import make_train_step, init_state

ap = argparse.ArgumentParser()
ap.add_argument("--noc", default="4x4_mc2", choices=sorted(PAPER_NOCS))
ap.add_argument("--f32", action="store_true", help="float-32 (default fixed-8)")
ap.add_argument("--train-steps", type=int, default=60)
ap.add_argument("--max-packets", type=int, default=30)
args = ap.parse_args()

model = LeNet()
params = init_params(model.specs(), jax.random.PRNGKey(0))
opt = AdamW(cosine(2e-3, args.train_steps, warmup=5), weight_decay=0.0)
step = jax.jit(make_train_step(lambda p, b: model.loss(p, *b), opt))
state = init_state(params, opt)
print(f"training LeNet on glyphs for {args.train_steps} steps...")
for i in range(args.train_steps):
    state, m = step(state, glyph_batch(jax.random.PRNGKey(i), 32))
print(f"final loss {float(m['loss']):.3f}")

x, _ = glyph_batch(jax.random.PRNGKey(99), 1)
layers = model.layer_traffic(state.params, x[0])
cfg = PAPER_NOCS[args.noc]
quant = None if args.f32 else (lambda t: quantize_fixed8(t).values)

print(f"\nNoC {args.noc}: {cfg.rows}x{cfg.cols}, {cfg.num_mcs} MCs, "
      f"{cfg.num_inter_router_links} inter-router links")
base_bt = None
for name in ("O0", "O1", "O2"):
    tr = build_traffic(layers, cfg, by_name(name, tiebreak="pattern"),
                       quantizer=quant, max_packets_per_layer=args.max_packets)
    res = simulate(cfg, tr, chunk=2048)
    red = "" if base_bt is None else \
        f"  ({(1 - res.total_bt / base_bt) * 100:+.1f}% vs O0)"
    base_bt = base_bt or res.total_bt
    tpc = res.total_bt / res.cycles
    pw = link_power_mw(tpc)
    print(f"{name}: {res.total_bt:10d} BT over {res.cycles} cycles "
          f"-> link power {pw:7.2f} mW{red}")
print(f"ordering-unit overhead: O1 {ordering_overhead_mw(cfg.num_mcs):.2f} mW, "
      f"O2 {ordering_overhead_mw(cfg.num_mcs, separated=True):.2f} mW")
