"""End-to-end LM training driver example: trains the xlstm-125m FULL config
(~71M backbone) for a few hundred steps on CPU with checkpoint/restart and
gradient-wire BT telemetry. This is a thin veneer over repro.launch.train,
which the production launcher uses on real meshes.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import subprocess
import sys

steps = "200"
for i, a in enumerate(sys.argv):
    if a == "--steps":
        steps = sys.argv[i + 1]

subprocess.run([
    sys.executable, "-m", "repro.launch.train",
    "--arch", "xlstm-125m",
    "--steps", steps, "--seq", "128", "--batch", "8",
    "--ckpt", "/tmp/repro_xlstm_ckpt", "--ckpt-every", "50",
    "--wire-telemetry",
], check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
