"""Batched serving example: prefill + KV-cache decode on a reduced config.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro.configs import get
from repro.models.spec import init_params
from repro.serve import Engine, GenerationConfig

arch = get("h2o-danube-3-4b")          # SWA arch: ring KV cache path
model = arch.build_reduced()
params = init_params(model.specs(), jax.random.PRNGKey(0))
engine = Engine(model, params, context=64)

prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                             model.cfg.vocab)
out = engine.generate(prompts, GenerationConfig(max_new_tokens=24,
                                                temperature=0.7))
print("generated token ids:")
print(out)
