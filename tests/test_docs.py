"""Docs must not rot: every relative markdown link in the repo's top-level
docs (README/DESIGN/ROADMAP/PAPER/CHANGES and docs/) must resolve to a
file or directory that exists, and the README quickstart must reference
real entry points. External (http/mailto) links are not fetched - CI has
no network guarantee - but their syntax is validated."""
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOCS = ["README.md", "DESIGN.md", "ROADMAP.md", "PAPER.md", "CHANGES.md"]
DOCS += [os.path.join("docs", f) for f in
         (os.listdir(os.path.join(REPO, "docs"))
          if os.path.isdir(os.path.join(REPO, "docs")) else [])
         if f.endswith(".md")]

# [text](target) - excluding images' leading ! is irrelevant for existence
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = re.compile(r"^[a-z][a-z0-9+.-]*:")  # http:, https:, mailto:, ...


def _links(md_path):
    with open(os.path.join(REPO, md_path)) as f:
        text = f.read()
    # drop fenced code blocks: shell snippets legitimately contain ")" etc.
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return _LINK.findall(text)


@pytest.mark.parametrize("doc", [d for d in DOCS
                                 if os.path.exists(os.path.join(REPO, d))])
def test_relative_links_resolve(doc):
    base = os.path.dirname(os.path.join(REPO, doc))
    missing = []
    for target in _links(doc):
        if _EXTERNAL.match(target) or target.startswith("#"):
            continue
        path = os.path.normpath(os.path.join(base, target.split("#")[0]))
        if not os.path.exists(path):
            missing.append(target)
    assert not missing, f"{doc} links to missing files: {missing}"


def test_front_door_docs_exist():
    for doc in ("README.md", "DESIGN.md", "ROADMAP.md",
                os.path.join("docs", "bench_schema.md")):
        assert os.path.exists(os.path.join(REPO, doc)), doc


def test_readme_references_real_entry_points():
    """The quickstart commands name files that must exist, and the docs the
    README points at must be linked (so the link-resolution test covers
    them)."""
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for ref in ("examples/noc_inference.py", "benchmarks.run",
                "docs/bench_schema.md", "DESIGN.md", "ROADMAP.md",
                "BENCH_noc.json", "python -m pytest"):
        assert ref in readme, f"README no longer mentions {ref}"
    assert os.path.exists(os.path.join(REPO, "examples", "noc_inference.py"))
    assert os.path.exists(os.path.join(REPO, "benchmarks", "run.py"))


def test_bench_schema_covers_recorded_suites():
    """Every suite key currently recorded in BENCH_noc.json must appear in
    docs/bench_schema.md - the schema doc cannot silently lag the file."""
    import json
    bench_path = os.path.join(REPO, "BENCH_noc.json")
    if not os.path.exists(bench_path):
        pytest.skip("no BENCH_noc.json recorded yet")
    with open(bench_path) as f:
        bench = json.load(f)
    with open(os.path.join(REPO, "docs", "bench_schema.md")) as f:
        schema = f.read()
    missing = [k for k in bench.get("suites", {}) if k not in schema]
    missing += [k for k in bench if k != "suites" and k not in schema]
    assert not missing, f"bench_schema.md does not document: {missing}"
