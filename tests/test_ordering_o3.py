"""O3 min-Hamming ordering correctness suite.

The chain kernel (``repro.kernels.min_hamming``) and its ordering wrappers
are pinned four ways: every output is a valid permutation that inverts
bit-exactly, the chain never costs more than the zeros-to-tail identity
order, the kernel matches a brute-force optimal-Hamming-path oracle on
exhaustive families of <= 6-value windows, and the flit deal preserves the
result-phase slicing contract (non-zeros confined to the leading flits).
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ordering
from repro.core.bits import unsigned_view
from repro.core.wire import by_name, measure
from repro.kernels.min_hamming import chain_cost, min_hamming_chain

rng = np.random.default_rng(11)


def _popc(x):
    return bin(int(x)).count("1")


def _path_cost(vals, order):
    return sum(_popc(int(vals[order[i]]) ^ int(vals[order[i + 1]]))
               for i in range(len(order) - 1))


def _optimal_cost(vals):
    return min(_path_cost(vals, p)
               for p in itertools.permutations(range(len(vals))))


# --- kernel-level properties ------------------------------------------------

def test_chain_is_valid_permutation():
    vals = rng.integers(0, 256, size=(40, 13)).astype(np.uint8)
    vals[rng.random(vals.shape) < 0.3] = 0
    res = min_hamming_chain(jnp.asarray(vals))
    perm = np.asarray(res.perm)
    for r in range(vals.shape[0]):
        assert sorted(perm[r].tolist()) == list(range(13))


def test_chain_zeros_to_tail():
    """Padding zeros chain last, in original order - the slicing contract."""
    vals = rng.integers(0, 200, size=(30, 9)).astype(np.uint32)
    vals[rng.random(vals.shape) < 0.4] = 0
    res = min_hamming_chain(jnp.asarray(vals))
    perm = np.asarray(res.perm)
    z = np.asarray(res.nonzeros)
    for r in range(vals.shape[0]):
        seq = vals[r][perm[r]]
        assert np.all(seq[z[r]:] == 0)
        assert np.all(seq[:z[r]] != 0)
        # tail zeros keep their original relative order (stable partition)
        tail = perm[r][z[r]:]
        assert np.all(np.diff(tail) > 0) or tail.size <= 1


def test_chain_cost_le_identity():
    """The chain never costs more than not reordering: cost <= the
    zeros-to-tail identity order (== plain identity on zero-free windows)."""
    clean = rng.integers(1, 256, size=(50, 11)).astype(np.uint8)
    res = min_hamming_chain(jnp.asarray(clean))
    for r in range(50):
        assert int(np.asarray(res.cost)[r]) <= _path_cost(
            clean[r], list(range(11)))

    dirty = clean.copy()
    dirty[rng.random(dirty.shape) < 0.35] = 0
    res = min_hamming_chain(jnp.asarray(dirty))
    for r in range(50):
        part = [i for i in range(11) if dirty[r][i] != 0] + \
            [i for i in range(11) if dirty[r][i] == 0]
        assert int(np.asarray(res.cost)[r]) <= _path_cost(dirty[r], part)


def test_chain_cost_column_matches_chain_cost_fn():
    vals = rng.integers(0, 256, size=(20, 8)).astype(np.uint8)
    res = min_hamming_chain(jnp.asarray(vals))
    assert np.array_equal(np.asarray(chain_cost(jnp.asarray(vals), res.perm)),
                          np.asarray(res.cost))


# Exhaustive families of <= 6-value windows; multi-start greedy at the
# default beam must equal the brute-force optimal path on every one.
_ORACLE_FAMILIES = {
    "w2": [t for t in itertools.product(range(1, 16), repeat=2)],
    "w3": [t for t in itertools.product(range(1, 8), repeat=3)],
    "w4": [t for t in itertools.product((1, 2, 3, 5), repeat=4)],
    "w5": [t for t in itertools.product((1, 2, 3, 5), repeat=5)],
    "w6": [t for t in itertools.product((1, 2, 3), repeat=6)],
}


@pytest.mark.parametrize("family", sorted(_ORACLE_FAMILIES))
def test_chain_matches_bruteforce_oracle(family):
    fam = _ORACLE_FAMILIES[family]
    arr = np.asarray(fam, np.uint32)
    res = min_hamming_chain(jnp.asarray(arr))
    cost = np.asarray(res.cost)
    mismatches = [(fam[i], int(cost[i]), _optimal_cost(fam[i]))
                  for i in range(len(fam))
                  if int(cost[i]) != _optimal_cost(fam[i])]
    assert not mismatches, mismatches[:5]


# --- ordering-level properties ---------------------------------------------

def test_min_hamming_perm_windows():
    """Flat chain perm: valid within each window, offsets like
    descending_perm, inverse recovers the stream bit-exactly."""
    vals = jnp.asarray(rng.integers(0, 2 ** 30, size=48).astype(np.uint32))
    perm = ordering.min_hamming_perm(vals, window=16)
    p = np.asarray(perm)
    for wstart in range(0, 48, 16):
        win = p[wstart:wstart + 16]
        assert sorted(win.tolist()) == list(range(wstart, wstart + 16))
    chained = ordering.apply_permutation(vals, perm)
    inv = ordering.inverse_permutation(perm)
    assert np.array_equal(np.asarray(chained)[np.asarray(inv)],
                          np.asarray(vals))


def test_min_hamming_order_inverse_roundtrip():
    """The dealt O3 ordering round-trips bit-identically, including
    streams that need window padding and flit (Wp) padding."""
    for n, w, lanes in [(37, 16, 4), (30, 10, 4), (12, None, 8), (5, 7, 3)]:
        vals = jnp.asarray(rng.integers(0, 255, size=n).astype(np.uint8))
        o = ordering.min_hamming_order(vals, window=w, lanes=lanes)
        inv = ordering.inverse_permutation(o.perm)
        back = np.asarray(o.values)[np.asarray(inv)]
        padded_len = back.shape[0]
        wreal = w if (w is not None and w < n) else n
        nw = -(-n // wreal)
        wp = padded_len // nw
        orig = np.zeros(nw * wreal, back.dtype)
        orig[:n] = np.asarray(unsigned_view(vals))
        assert np.array_equal(back.reshape(nw, wp)[:, :wreal].reshape(-1),
                              orig), (n, w, lanes)
        assert np.all(back.reshape(nw, wp)[:, wreal:] == 0)


def test_min_hamming_deal_confines_nonzeros():
    """The column-major deal keeps non-zeros in the first ceil(z / lanes)
    flits of each window - the contract the result packetizer slices by."""
    lanes, w = 4, 16
    vals = rng.integers(0, 250, size=64).astype(np.uint8)
    vals[rng.random(64) < 0.5] = 0
    o = ordering.min_hamming_order(jnp.asarray(vals), window=w, lanes=lanes)
    for win in np.asarray(o.values).reshape(-1, w):
        z = int((win != 0).sum())
        fr = max(-(-z // lanes), 1)
        nz_flits = np.nonzero(win.reshape(-1, lanes).any(axis=1))[0]
        assert nz_flits.size == 0 or nz_flits.max() < fr


def test_separated_and_affiliated_variants():
    ins = jnp.asarray(rng.integers(0, 256, size=32).astype(np.uint8))
    wts = jnp.asarray(rng.integers(0, 256, size=32).astype(np.uint8))
    sep = ordering.separated_min_hamming_order(ins, wts, window=16, lanes=4)
    aff = ordering.affiliated_min_hamming_order(ins, wts, window=16, lanes=4)
    # affiliated: ONE shared perm keeps pairs matched (the zero-overhead
    # claim); separated chains are independent
    assert np.array_equal(np.asarray(aff.input_perm),
                          np.asarray(aff.weight_perm))
    for po in (sep, aff):
        assert sorted(np.asarray(po.inputs).tolist()) == \
            sorted(np.asarray(unsigned_view(ins)).tolist())
        assert sorted(np.asarray(po.weights).tolist()) == \
            sorted(np.asarray(unsigned_view(wts)).tolist())
    with pytest.raises(ValueError, match="lane count"):
        ordering.min_hamming_order(ins, window=16)
    with pytest.raises(ValueError, match="equal length"):
        ordering.affiliated_min_hamming_order(ins[:8], wts, lanes=4)


# --- transform-level: O3/O3a against the paper orderings --------------------

def test_o3_transform_beats_o0_and_charges_index():
    vals = jnp.asarray(rng.integers(0, 256, size=256).astype(np.uint8))
    bt = {name: measure(by_name(name, window=64).apply_single(vals, 8))
          ["total_bt"] for name in ("O0", "O1", "O2", "O3")}
    assert bt["O3"] <= bt["O0"]
    assert bt["O3"] <= bt["O2"]
    for name, (pb, sb) in {"O0": (0, 0), "O1": (0, 6), "O2": (6, 6),
                           "O3": (6, 6), "O3a": (0, 6), "desc": (6, 6)}.items():
        tr = by_name(name, window=64)
        assert tr.overhead_bits_per_value(64, paired=True) == pb, name
        assert tr.overhead_bits_per_value(64, paired=False) == sb, name


def test_o3_paired_apply_preserves_multisets():
    ins = jnp.asarray(rng.integers(0, 256, size=128).astype(np.uint8))
    wts = jnp.asarray(rng.integers(0, 256, size=128).astype(np.uint8))
    for name in ("O3", "O3a"):
        st = by_name(name, window=32).apply(ins, wts, 8)
        words = np.asarray(st.words)
        assert sorted(words[:, :4].reshape(-1).tolist()) == \
            sorted(np.asarray(unsigned_view(ins)).tolist())
        assert sorted(words[:, 4:].reshape(-1).tolist()) == \
            sorted(np.asarray(unsigned_view(wts)).tolist())


def test_o3_kernel_validation_errors():
    vals = jnp.zeros((2, 4), jnp.uint8)
    with pytest.raises(ValueError, match="beam"):
        min_hamming_chain(vals, beam=0)
    with pytest.raises(ValueError, match="starts"):
        min_hamming_chain(vals, starts=0)
    with pytest.raises(ValueError, match="shape"):
        min_hamming_chain((jnp.zeros((2, 4), jnp.uint8),
                           jnp.zeros((2, 5), jnp.uint8)))
