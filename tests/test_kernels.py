"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, exact equality
(bit ops have no tolerance). Kernels run in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import popcount, bt_boundaries, sort_windows_desc
from repro.kernels.ref import (popcount_ref, bt_boundaries_ref,
                               sort_windows_desc_ref)


@pytest.mark.parametrize("shape", [(7,), (128,), (1000,), (33, 9), (3, 4, 5)])
@pytest.mark.parametrize("dtype", ["float32", "int8", "uint32", "bfloat16"])
def test_popcount_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(hash((shape, dtype)) % (2**31))
    if dtype == "float32":
        x = jax.random.normal(key, shape, jnp.float32)
    elif dtype == "bfloat16":
        x = jax.random.normal(key, shape, jnp.float32).astype(jnp.bfloat16)
    elif dtype == "int8":
        x = jax.random.randint(key, shape, -128, 128, jnp.int32).astype(jnp.int8)
    else:
        x = jax.random.randint(key, shape, 0, 2**31 - 1, jnp.int32).astype(jnp.uint32)
    a, b = popcount(x), popcount_ref(x)
    assert a.shape == b.shape
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("nf,lanes", [(2, 4), (9, 16), (64, 16), (33, 7), (1, 8)])
def test_bt_boundaries_matches_ref(nf, lanes):
    key = jax.random.PRNGKey(nf * 131 + lanes)
    w = jax.random.randint(key, (nf, lanes), 0, 2**31 - 1,
                           jnp.int32).astype(jnp.uint32)
    a, b = bt_boundaries(w), bt_boundaries_ref(w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("rows,w", [(1, 128), (8, 128), (5, 256), (16, 512), (3, 1024)])
def test_bitonic_sort_keys_match_ref(rows, w):
    key = jax.random.PRNGKey(rows * 7 + w)
    keys = jax.random.randint(key, (rows, w), 0, 33, jnp.int32)
    vals = jax.random.randint(jax.random.fold_in(key, 1), (rows, w), 0,
                              2**31 - 1, jnp.int32).astype(jnp.uint32)
    sk, sv = sort_windows_desc(keys, vals)
    rk, rv = sort_windows_desc_ref(keys, vals)
    # keys must match exactly (same multiset sorted descending)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
    # bitonic is unstable: compare (key, value) pairs as multisets per row
    for i in range(rows):
        pa = sorted(zip(np.asarray(sk[i]).tolist(), np.asarray(sv[i]).tolist()))
        pb = sorted(zip(np.asarray(keys[i]).tolist(), np.asarray(vals[i]).tolist()))
        assert pa == pb


@pytest.mark.parametrize("w", [128, 256])
def test_bitonic_two_payloads(w):
    """Affiliated ordering: weights and inputs ride the same swaps."""
    key = jax.random.PRNGKey(w)
    keys = jax.random.randint(key, (4, w), 0, 9, jnp.int32)
    wgt = jax.random.normal(jax.random.fold_in(key, 1), (4, w), jnp.float32)
    inp = jax.random.normal(jax.random.fold_in(key, 2), (4, w), jnp.float32)
    sk, sw, si = sort_windows_desc(keys, wgt, inp)
    # pairing invariant: the (weight, input) pairs survive as a multiset
    for i in range(4):
        pa = sorted(zip(np.asarray(sw[i]).tolist(), np.asarray(si[i]).tolist()))
        pb = sorted(zip(np.asarray(wgt[i]).tolist(), np.asarray(inp[i]).tolist()))
        assert pa == pb
    assert bool(jnp.all(sk[:, :-1] >= sk[:, 1:]))


def test_bitonic_rejects_bad_window():
    with pytest.raises(ValueError):
        sort_windows_desc(jnp.zeros((2, 100), jnp.int32))


def test_popcount_kernel_float_padding_safe():
    """Padding lanes must not pollute results at ragged sizes."""
    x = jnp.full((129,), -1.0, jnp.float32)   # 0xBF800000: 8 ones
    out = popcount(x)
    assert out.shape == (129,)
    assert bool(jnp.all(out == 8))


@pytest.mark.parametrize("rows,w", [(2, 128), (8, 256), (3, 512)])
def test_fused_order_unit_matches_ref(rows, w):
    """The fused popcount+sort kernel vs the two-stage oracle: key sequences
    must match exactly; (value -> position) pairs as multisets (bitonic is
    unstable)."""
    from repro.kernels import order_unit
    from repro.kernels.ref import order_unit_ref
    key = jax.random.PRNGKey(rows * w)
    vals = jax.random.randint(key, (rows, w), 0, 2**31 - 1,
                              jnp.int32).astype(jnp.uint32)
    out, perm = order_unit(vals)
    ref_out, ref_perm = order_unit_ref(vals)
    from repro.core.bits import popcount as pc
    np.testing.assert_array_equal(np.asarray(pc(out)), np.asarray(pc(ref_out)))
    for i in range(rows):
        assert sorted(np.asarray(out[i]).tolist()) == \
            sorted(np.asarray(vals[i]).tolist())
        # perm is a valid permutation reproducing the output
        p = np.asarray(perm[i])
        assert sorted(p.tolist()) == list(range(w))
        np.testing.assert_array_equal(np.asarray(vals[i])[p],
                                      np.asarray(out[i]))
