"""Fused-step overhaul regression suite.

Pins the fused-state router step (packed sideband lane, closed-form
routing, receiver-side pushes, optional conservation ledger, hardware
popcount recorder) bit-for-bit against the frozen PR-3 unfused step
(``repro.noc._reference.simulate_unfused``) on the full 36-cell pinned
reference grid, and the drain scheduler's variant retirement/compaction
against the plain batched drain.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bits import popcount, popcount_hw
from repro.core.wire import by_name
from repro.data import glyph_batch
from repro.models import LeNet, init_params
from repro.noc import (PAPER_NOCS, LayerTraffic, NocConfig, SweepGrid,
                       Traffic, build_traffic, build_traffic_batch,
                       make_noc, mesh_by_name, run_sweep, simulate,
                       simulate_batch)
from repro.noc.sim import (META_PAYLOAD, META_TAIL, SIDE_META_SHIFT,
                           SIDE_VC_SHIFT, _DEST_MASK, fuse_traffic,
                           pack_sideband)
from repro.noc._reference import simulate_unfused
from repro.noc.sweep import _deal_order, drain_estimate
from repro.noc.topology import mean_hop_counts
from repro.quant import quantize_fixed8

CHUNK = 128

# The pinned 36-cell equivalence grid (matches benchmarks/fig12.PINNED):
# 3 paper meshes x 2 precisions x 2 tiebreaks x 3 orderings.
PINNED_MESHES = tuple(PAPER_NOCS)
PINNED_PRECISIONS = ("float32", "fixed8")
PINNED_TIEBREAKS = ("stable", "pattern")
PINNED_ORDERINGS = ("O0", "O1", "O2")
MAX_PACKETS = 8


@pytest.fixture(scope="module")
def pinned_layers():
    model = LeNet()
    params = init_params(model.specs(), jax.random.PRNGKey(1))
    x, _ = glyph_batch(jax.random.PRNGKey(7), 1)
    return model.layer_traffic(params, x[0])


def _quant(name):
    return None if name == "float32" else (lambda t: quantize_fixed8(t).values)


def test_popcount_hw_matches_swar_oracle():
    """The lax.population_count recorder path equals the SWAR circuit on
    random words (incl. all-ones/zero edge patterns) for every wire dtype
    the simulator carries."""
    key = jax.random.PRNGKey(0)
    words = jax.random.randint(key, (512,), 0, 2**31 - 1, jnp.int32)
    words = jax.lax.bitcast_convert_type(words, jnp.uint32)
    words = jnp.concatenate([words, jnp.array([0, 0xFFFFFFFF, 1 << 31],
                                              jnp.uint32)])
    assert np.array_equal(np.asarray(popcount_hw(words)),
                          np.asarray(popcount(words)))
    f32 = jax.random.normal(key, (257,), jnp.float32)
    assert np.array_equal(np.asarray(popcount_hw(f32)),
                          np.asarray(popcount(f32)))


def test_sideband_roundtrip():
    """dest/META/VC survive the packed sideband word exactly."""
    dest = jnp.arange(512, dtype=jnp.int32)
    meta = jnp.tile(jnp.array([0, META_PAYLOAD, META_PAYLOAD | META_TAIL,
                               META_TAIL], jnp.int32), 128)
    vc = jnp.tile(jnp.arange(32, dtype=jnp.int32), 16)
    side = pack_sideband(dest, meta, vc).astype(jnp.int32)
    assert np.array_equal(np.asarray(side & _DEST_MASK), np.asarray(dest))
    assert np.array_equal(np.asarray((side >> SIDE_META_SHIFT) & 3),
                          np.asarray(meta))
    assert np.array_equal(np.asarray(side >> SIDE_VC_SHIFT), np.asarray(vc))


def test_fuse_traffic_layout(pinned_layers):
    cfg = PAPER_NOCS["4x4_mc2"]
    tr = build_traffic(pinned_layers[:1], cfg, by_name("O1"),
                       max_packets_per_layer=4)
    wire = fuse_traffic(tr, track_pkt=True)
    l = cfg.lanes
    assert wire.wire.shape == tr.words.shape[:-1] + (l + 2,)
    assert np.array_equal(np.asarray(wire.wire[..., :l]),
                          np.asarray(tr.words))
    assert np.array_equal(
        np.asarray(wire.wire[..., l + 1]).astype(np.int32),
        np.asarray(tr.pkt))


@pytest.mark.parametrize("mesh", PINNED_MESHES)
def test_fused_step_bit_identical_to_unfused(pinned_layers, mesh):
    """All 36 pinned reference cells: total_bt, link_bt, AND the exact
    drain_cycle of the fused step equal the frozen PR-3 unfused step."""
    cfg = mesh_by_name(mesh)
    for prec in PINNED_PRECISIONS:
        for tb in PINNED_TIEBREAKS:
            for o in PINNED_ORDERINGS:
                tr = build_traffic(pinned_layers, cfg,
                                   by_name(o, tiebreak=tb),
                                   quantizer=_quant(prec),
                                   max_packets_per_layer=MAX_PACKETS)
                ref = simulate_unfused(cfg, tr, chunk=CHUNK)
                new = simulate(cfg, tr, chunk=CHUNK)
                cell = (mesh, prec, tb, o)
                assert new.total_bt == ref.total_bt, cell
                assert new.drain_cycle == ref.drain_cycle, cell
                assert new.ejected == ref.ejected == new.injected, cell
                assert np.array_equal(new.link_bt, ref.link_bt), cell
                assert np.array_equal(new.inj_bt, ref.inj_bt), cell


def test_fused_step_unusual_geometry(pinned_layers):
    """Parity also holds off the paper grid: non-square mesh, 3 VCs,
    narrow flits, headers excluded from the recorder."""
    cfg = NocConfig(rows=3, cols=5, mc_nodes=(0, 14), num_vcs=3, lanes=8)
    tr = build_traffic(pinned_layers, cfg, by_name("O1"),
                       max_packets_per_layer=6)
    ref = simulate_unfused(cfg, tr, chunk=64, count_headers=False)
    new = simulate(cfg, tr, chunk=64, count_headers=False)
    assert new.total_bt == ref.total_bt
    assert new.drain_cycle == ref.drain_cycle
    assert np.array_equal(new.link_bt, ref.link_bt)


def test_sideband_capacity_guard():
    with pytest.raises(ValueError, match="sideband"):
        simulate(make_noc(32, 32, 4),
                 build_traffic([LayerTraffic(jnp.ones((2, 4)),
                                             jnp.ones((2, 4)))],
                               make_noc(32, 32, 4), by_name("O0")))


def _hetero_batch(cfg, lane_packets, k=6, seed=0):
    """Batched Traffic whose lanes carry *different* packet counts (and so
    different drain times) - the retirement scheduler's target case."""
    from repro.noc.traffic import stack_traffics
    key = jax.random.PRNGKey(seed)
    singles = []
    for i, n in enumerate(lane_packets):
        ki = jax.random.fold_in(key, i)
        layer = LayerTraffic(
            jax.random.normal(ki, (n, k)),
            jax.random.normal(jax.random.fold_in(ki, 1), (n, k)) * 0.4)
        singles.append(build_traffic([layer], cfg, by_name("O0")))
    return stack_traffics(singles)


def _assert_same_results(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert x.total_bt == y.total_bt
        assert x.drain_cycle == y.drain_cycle
        assert x.ejected == y.ejected
        assert np.array_equal(x.link_bt, y.link_bt)
        assert np.array_equal(x.inj_bt, y.inj_bt)


def test_retirement_matches_plain_drain():
    """Heterogeneous lanes (incl. an empty one) drained with retirement +
    compaction give exactly the plain batched drain's per-variant results;
    the small chunk forces several retire/compact events."""
    cfg = NocConfig(rows=3, cols=3, mc_nodes=(0,), lanes=4)
    batch = _hetero_batch(cfg, [40, 1, 0, 13, 26, 2])
    fast = simulate_batch(cfg, batch, chunk=32, retire=True,
                          check_conservation=True)
    plain = simulate_batch(cfg, batch, chunk=32, retire=False,
                           check_conservation=True)
    _assert_same_results(fast, plain)
    # exact per-lane drain cycles really do differ across lanes
    drains = [r.drain_cycle for r in fast]
    assert len(set(drains)) > 2


def test_retirement_matches_single_drains():
    """Each retired lane's result equals its standalone simulate()."""
    cfg = NocConfig(rows=3, cols=3, mc_nodes=(0, 8), lanes=4)
    batch = _hetero_batch(cfg, [21, 3, 9], seed=3)
    fast = simulate_batch(cfg, batch, chunk=16, retire=True)
    for i in range(3):
        single = simulate(cfg, batch.variant(i), chunk=16)
        assert fast[i].total_bt == single.total_bt
        assert fast[i].drain_cycle == single.drain_cycle
        assert np.array_equal(fast[i].link_bt, single.link_bt)


def test_mc_nodes_lane_axis_matches_separate_calls(pinned_layers):
    """Per-lane mc_nodes (how the sweep engine merges MC placements into
    one drain) is bit-identical to per-placement simulate_batch calls."""
    base = make_noc(4, 4, 2, lanes=8)
    inter = make_noc(4, 4, 2, "interleaved", lanes=8)
    variants = [(by_name(o), None) for o in ("O0", "O1")]
    batch = build_traffic_batch(pinned_layers, base, variants,
                                max_packets_per_layer=6)
    merged = Traffic(*(
        (jnp.concatenate([x, x]) if getattr(x, "ndim", 0) else x)
        for x in batch))
    mc = np.stack([np.asarray(base.mc_nodes, np.int32)] * 2
                  + [np.asarray(inter.mc_nodes, np.int32)] * 2)
    got = simulate_batch(base, merged, chunk=CHUNK, mc_nodes=mc)
    want = (simulate_batch(base, batch, chunk=CHUNK)
            + simulate_batch(inter, batch, chunk=CHUNK))
    _assert_same_results(got, want)


def test_merged_placement_sweep_matches_split_sweeps(pinned_layers):
    """run_sweep's drain-aware merged-placement batching returns the same
    rows as running each placement in its own grid."""
    kw = dict(meshes=("4x4_mc2",), transforms=("O0", "O1"),
              precisions=("fixed8",), models=("lenet",),
              max_packets_per_layer=6, chunk=CHUNK)
    merged = run_sweep(SweepGrid(placements=("edge", "interleaved"), **kw),
                       lambda _n: pinned_layers)
    keep = ("mesh", "placement", "model", "precision", "transform",
            "total_bt", "cycles", "flits")
    split_rows = []
    for pl in ("edge", "interleaved"):
        split_rows += run_sweep(SweepGrid(placements=(pl,), **kw),
                                lambda _n: pinned_layers).rows
    assert [{k: r[k] for k in keep} for r in merged.rows] == \
        [{k: r[k] for k in keep} for r in split_rows]


def test_drain_estimate_orders_congested_placements():
    """The injection bound ties across placements; the link-congestion
    proxy must rank boundary MCs above interleaved MCs (matching the
    measured 16x16 DarkNet drains: edge 181k vs interleaved 82k)."""
    lengths = np.full(16, 82_000)
    edge = make_noc(16, 16, 16, "edge")
    inter = make_noc(16, 16, 16, "interleaved")
    assert drain_estimate(edge, lengths) > drain_estimate(inter, lengths)
    assert drain_estimate(inter, lengths) >= lengths.max()
    hops = mean_hop_counts(edge)
    assert hops.shape == (16,) and (hops > 0).all()


def test_deal_order_balances_devices():
    ests = np.array([10.0, 10.0, 10.0, 1.0, 1.0, 1.0])
    order = _deal_order(ests, 2)
    # each contiguous half (device shard) gets one mix of slow+fast lanes
    assert sorted(order.tolist()) == list(range(6))
    halves = [set(order[:3]), set(order[3:])]
    for h in halves:
        assert h & {0, 1, 2} and h & {3, 4, 5}
    # identity when there is nothing to balance
    assert np.array_equal(_deal_order(ests, 1), np.arange(6))
    assert np.array_equal(_deal_order(np.ones(4), 2), np.arange(4))


def test_num_packets_metadata(pinned_layers):
    cfg = PAPER_NOCS["4x4_mc2"]
    tr = build_traffic(pinned_layers, cfg, by_name("O0"),
                       max_packets_per_layer=5)
    assert tr.num_packets == int(np.asarray(tr.pkt).max()) + 1
    # hand-built Traffic falls back to the legacy host pull
    legacy = tr._replace(num_packets=-1)
    from repro.noc.sim import _npkt
    assert _npkt(legacy) == _npkt(tr)
