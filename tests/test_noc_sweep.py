"""Sweep-engine regression suite: the vectorized packetizer and the
retrace-free simulator are pinned bit-for-bit to the seed implementation
(``repro.noc._reference``), packet conservation is enforced, and the
declarative SweepGrid engine is exercised end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.wire import by_name
from repro.data import glyph_batch
from repro.models import LeNet, init_params
from repro.noc import (NocConfig, LayerTraffic, SweepGrid, Traffic,
                       build_traffic, build_traffic_batch, make_noc,
                       mc_placement, mesh_by_name, recovery_overhead_bits,
                       run_sweep, simulate, simulate_batch)
from repro.noc._reference import build_traffic_reference, simulate_reference
from repro.quant import quantize_fixed8

CHUNK = 256


@pytest.fixture(scope="module")
def lenet_layers():
    """The pinned equivalence workload: two LeNet layers (conv + linear)
    of one deterministic random-init inference."""
    model = LeNet()
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    x, _ = glyph_batch(jax.random.PRNGKey(7), 1)
    layers = model.layer_traffic(params, x[0])
    return [layers[0], layers[-1]]


@pytest.fixture(scope="module")
def pinned_cfg():
    return NocConfig(rows=4, cols=4, mc_nodes=(0, 15), num_vcs=3, lanes=8)


def _assert_traffic_equal(a, b):
    for name in a._fields:
        xa, xb = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert xa.dtype == xb.dtype, name
        assert xa.shape == xb.shape, name
        assert np.array_equal(xa, xb), f"Traffic.{name} diverged"


@pytest.mark.parametrize("ordering", ["O0", "O1", "O2"])
def test_packetizer_bit_identical_to_seed_loop(lenet_layers, pinned_cfg,
                                               ordering):
    """The vectorized packetizer reproduces the seed's per-neuron loop
    exactly: words/dest/meta/vc/pkt/length all bit-identical."""
    for quantizer in (None, lambda t: quantize_fixed8(t).values):
        tr = by_name(ordering, tiebreak="pattern")
        new = build_traffic(lenet_layers, pinned_cfg, tr, quantizer=quantizer,
                            max_packets_per_layer=6)
        ref = build_traffic_reference(lenet_layers, pinned_cfg, tr,
                                      quantizer=quantizer,
                                      max_packets_per_layer=6)
        _assert_traffic_equal(new, ref)


def test_simulate_identical_to_seed_driver(lenet_layers, pinned_cfg):
    """Same traffic, same chunking: total_bt, cycles, and the per-link BT
    maps must match the pre-refactor (closure-captured) simulator."""
    traffic = build_traffic(lenet_layers, pinned_cfg, by_name("O1"),
                            max_packets_per_layer=6)
    ref = simulate_reference(pinned_cfg, traffic, chunk=CHUNK)
    new = simulate(pinned_cfg, traffic, chunk=CHUNK, check_conservation=True)
    assert new.total_bt == ref.total_bt
    assert new.cycles == ref.cycles
    assert new.ejected == ref.ejected == new.injected
    assert np.array_equal(new.link_bt, ref.link_bt)
    assert np.array_equal(new.inj_bt, ref.inj_bt)
    assert 0 < new.drain_cycle <= new.cycles


def test_simulate_batch_matches_single_runs(lenet_layers, pinned_cfg):
    """A batched O0/O1/O2 drain returns exactly what three single simulate
    calls return (shared-shape variants, one compiled program)."""
    variants = [(by_name(o), None) for o in ("O0", "O1", "O2")]
    batch = build_traffic_batch(lenet_layers, pinned_cfg, variants,
                                max_packets_per_layer=6)
    batch_res = simulate_batch(pinned_cfg, batch, chunk=CHUNK,
                               check_conservation=True)
    for (transform, _), got in zip(variants, batch_res):
        single = simulate(
            pinned_cfg,
            build_traffic(lenet_layers, pinned_cfg, transform,
                          max_packets_per_layer=6),
            chunk=CHUNK)
        assert got.total_bt == single.total_bt
        assert got.drain_cycle == single.drain_cycle
        assert got.ejected == single.ejected
        assert np.array_equal(got.link_bt, single.link_bt)


def test_conservation_detects_duplicate_packet_ids(lenet_layers, pinned_cfg):
    """The pkt field is now actually checked: collapsing all packet ids to
    zero means id 0 is 'injected' many times - the debug path must raise."""
    traffic = build_traffic(lenet_layers, pinned_cfg, by_name("O0"),
                            max_packets_per_layer=6)
    bad = traffic._replace(pkt=jnp.zeros_like(traffic.pkt))
    with pytest.raises(RuntimeError, match="conservation"):
        simulate(pinned_cfg, bad, chunk=CHUNK, check_conservation=True)


def test_padded_streams_leave_results_untouched(lenet_layers, pinned_cfg):
    """MC-stream / stream-length padding (how the sweep engine lets every
    MC placement of one mesh size share an executable) must not perturb the
    flit timeline: empty streams never inject."""
    from repro.noc.traffic import (assemble_traffic, ordered_payloads,
                                   pad_traffic_length)
    payloads = ordered_payloads(lenet_layers, pinned_cfg.lanes,
                                [(by_name("O1"), None)],
                                max_packets_per_layer=6)
    plain = assemble_traffic(payloads, pinned_cfg)
    padded = pad_traffic_length(
        assemble_traffic(payloads, pinned_cfg, num_streams=4),
        int(plain.words.shape[-2]) + 7)
    assert padded.length.shape == (1, 4)
    a = simulate(pinned_cfg, plain.variant(0), chunk=CHUNK)
    b = simulate(pinned_cfg, padded.variant(0), chunk=CHUNK)
    assert a.total_bt == b.total_bt
    assert a.drain_cycle == b.drain_cycle
    assert np.array_equal(a.link_bt, b.link_bt)


def test_mesh_by_name_and_make_noc():
    cfg = mesh_by_name("2x2_mc1")
    assert (cfg.rows, cfg.cols, cfg.num_mcs) == (2, 2, 1)
    assert mesh_by_name("8x8_mc4").num_inter_router_links == 112
    with pytest.raises(KeyError):
        mesh_by_name("not-a-mesh")
    with pytest.raises(ValueError):
        make_noc(2, 2, 9)   # more MCs than boundary routers


def test_recovery_overhead_bits():
    layers = [LayerTraffic(jnp.zeros((10, 16)), jnp.zeros((10, 16)))]
    assert recovery_overhead_bits(layers, by_name("O0")) == 0
    assert recovery_overhead_bits(layers, by_name("O1")) == 0
    # O2/O3: 4 index bits per value for a 16-value window, 10 packets x 16
    assert recovery_overhead_bits(layers, by_name("O2")) == 10 * 16 * 4
    assert recovery_overhead_bits(layers, by_name("O3")) == 10 * 16 * 4
    assert recovery_overhead_bits(layers, by_name("O2"),
                                  max_packets_per_layer=5) == 5 * 16 * 4
    # single-stream accounting: any non-identity reorder owes the index
    assert recovery_overhead_bits(layers, by_name("O0"), paired=False) == 0
    assert recovery_overhead_bits(layers, by_name("O1"),
                                  paired=False) == 10 * 16 * 4
    assert recovery_overhead_bits(layers, by_name("O3a")) == 0
    assert recovery_overhead_bits(layers, by_name("O3a"),
                                  paired=False) == 10 * 16 * 4


def test_sweep_grid_end_to_end(tmp_path):
    """Declarative grid -> rows + JSON artifact; baseline anchoring and the
    honest O2 recovery-index charge."""
    key = jax.random.PRNGKey(3)
    layers = [LayerTraffic(
        jax.random.normal(key, (12, 16)),
        jax.random.normal(jax.random.fold_in(key, 1), (12, 16)) * 0.3)]
    grid = SweepGrid(meshes=("2x2_mc1",), transforms=("O0", "O2"),
                     tiebreaks=("pattern",), precisions=("fixed8",),
                     models=("toy",), max_packets_per_layer=None, chunk=CHUNK)
    out = tmp_path / "sweep.json"
    report = run_sweep(grid, lambda name: layers, out_path=str(out),
                       check_conservation=True)
    assert len(report.rows) == 2
    base = report.row(transform="O0")
    o2 = report.row(transform="O2")
    assert base["reduction_pct"] == 0.0 and base["overhead_bits"] == 0
    assert o2["overhead_bits"] == 12 * 16 * 4
    # honest reduction is strictly worse than the raw link number for O2
    assert o2["adjusted_reduction_pct"] < o2["reduction_pct"]
    assert o2["adjusted_bt"] == o2["total_bt"] + o2["overhead_bits"] // 2
    assert report.stats["cells"] == 2
    assert report.stats["cycles_per_sec"] is not None
    import json
    blob = json.loads(out.read_text())
    assert set(blob) == {"grid", "rows", "stats"}
    assert blob["grid"]["meshes"] == ["2x2_mc1"]


def test_ordered_payload_cache_hit_bit_identical(lenet_layers):
    """Cache-hit path through run_sweep == cold path: a two-mesh grid (the
    second mesh reuses the first's cached orderings, as do every placement
    x affinity lane) produces rows identical to per-mesh cold sweeps."""
    kw = dict(transforms=("O0", "O2"), tiebreaks=("pattern",),
              precisions=("fixed8",), placements=("edge", "interleaved"),
              affinity=("roundrobin", "nearest"),
              max_packets_per_layer=6, chunk=CHUNK)
    fn = lambda _name: lenet_layers  # noqa: E731
    warm = run_sweep(SweepGrid(meshes=("4x4_mc2", "4x4_mc4"), **kw), fn)
    cold = (run_sweep(SweepGrid(meshes=("4x4_mc2",), **kw), fn).rows
            + run_sweep(SweepGrid(meshes=("4x4_mc4",), **kw), fn).rows)
    skip = {"result_bt", "result_cycles", "result_flits"}
    for w, c in zip(warm.rows, cold):
        for k in w:
            if k not in skip:
                assert w[k] == c[k], (w["mesh"], w["transform"], k)


def test_ordered_payload_cache_distinct_keys(lenet_layers):
    """Negative: distinct precisions/tiebreaks never share a cache entry,
    and every entry equals the uncached ordering pass bit for bit."""
    from repro.noc.sweep import _QUANTIZERS, cached_ordered_payloads
    from repro.noc.traffic import ordered_payloads

    layers = [lenet_layers[-1]]
    axes = [("float32", "stable", "O2"), ("float32", "pattern", "O2"),
            ("fixed8", "stable", "O2"), ("fixed8", "stable", "O1")]
    variants = [(by_name(tr, tiebreak=tb), _QUANTIZERS[prec])
                for prec, tb, tr in axes]
    cache = {}
    stacks = cached_ordered_payloads(cache, "lenet", layers, 8, variants,
                                     axes, max_packets_per_layer=4)
    # 4 distinct (transform, precision) combos -> 4 distinct entries, even
    # though every variant shares the O-family and most share "O2".
    assert len(cache) == 4
    want = ordered_payloads(layers, 8, variants, max_packets_per_layer=4)
    for got, exp in zip(stacks, want):
        np.testing.assert_array_equal(got, exp)
    # A repeat call is pure cache hits: no new entries, identical bits.
    again = cached_ordered_payloads(cache, "lenet", layers, 8, variants,
                                    axes, max_packets_per_layer=4)
    assert len(cache) == 4
    for got, exp in zip(again, want):
        np.testing.assert_array_equal(got, exp)


def test_sweep_grid_validation():
    with pytest.raises(ValueError, match="baseline"):
        SweepGrid(transforms=("O1",), baseline="O0")
    with pytest.raises(ValueError, match="precisions"):
        SweepGrid(precisions=("int4",))
    with pytest.raises(ValueError, match="placements"):
        SweepGrid(placements=("diagonal",))
    with pytest.raises(ValueError, match="placement"):
        SweepGrid(placements=())
    with pytest.raises(ValueError, match="compression"):
        SweepGrid(compression=("zip",), precisions=("fixed8",))
    with pytest.raises(ValueError, match="compression"):
        SweepGrid(compression=(), precisions=("fixed8",))
    # MSR reads int8 payloads: float32 anywhere in the precision axis is
    # rejected up front, not at packetization time.
    with pytest.raises(ValueError, match="int8"):
        SweepGrid(compression=("msr",))
    with pytest.raises(ValueError, match="int8"):
        SweepGrid(compression=("none", "msr"),
                  precisions=("fixed8", "float32"))
    SweepGrid(compression=("none", "msr"), precisions=("fixed8",))


def test_mc_placement_strategies():
    """Placement geometry: edge is the paper's boundary spread, corner is
    diagonal-corners-first, interleaved walks the whole mesh; all are
    deterministic, distinct-node, PE-preserving."""
    # On square meshes the n=2 edge spread lands on opposite corners, so
    # edge and corner coincide - the symmetry the sweep parity test uses.
    assert mc_placement(4, 4, 2, "edge") == mc_placement(4, 4, 2, "corner")
    assert mc_placement(2, 2, 2, "edge") == mc_placement(2, 2, 2, "corner")
    assert mc_placement(4, 4, 4, "corner") == (0, 15, 3, 12)
    assert mc_placement(4, 4, 2, "interleaved") == (0, 8)
    assert mc_placement(16, 16, 8, "edge") == \
        tuple(make_noc(16, 16, 8).mc_nodes)
    for strategy in ("edge", "corner", "interleaved"):
        nodes = mc_placement(5, 3, 4, strategy)
        assert len(set(nodes)) == 4
        assert all(0 <= n < 15 for n in nodes)
    with pytest.raises(KeyError):
        mc_placement(4, 4, 2, "diagonal")
    with pytest.raises(ValueError):
        mc_placement(2, 2, 4, "interleaved")   # no PE routers left
    with pytest.raises(ValueError):
        mc_placement(4, 4, 13, "corner")       # beyond the boundary


def test_placement_axis_parity(lenet_layers):
    """The MC-placement axis: symmetric placements (edge/corner resolve to
    the same opposite-corner node set on 4x4/MC2) give identical rows;
    interleaved genuinely moves the MCs, keeps the flit volume, and still
    conserves every packet."""
    grid = SweepGrid(meshes=("4x4_mc2",),
                     placements=("edge", "corner", "interleaved"),
                     transforms=("O0", "O1"), tiebreaks=("pattern",),
                     precisions=("fixed8",), models=("lenet",),
                     max_packets_per_layer=6, chunk=CHUNK)
    report = run_sweep(grid, lambda _n: lenet_layers,
                       check_conservation=True)
    assert report.stats["cells"] == 6
    for tr in ("O0", "O1"):
        edge = report.row(placement="edge", transform=tr)
        corner = report.row(placement="corner", transform=tr)
        inter = report.row(placement="interleaved", transform=tr)
        assert edge["total_bt"] == corner["total_bt"]
        assert edge["cycles"] == corner["cycles"]
        assert inter["flits"] == edge["flits"]
        assert inter["total_bt"] != edge["total_bt"]


def test_conservation_on_16x16_mesh(lenet_layers):
    """The scale axis: a 16x16/MC8 mesh drains with every packet ejected
    exactly once (positive), and the ledger still catches corrupted packet
    ids at that scale (negative)."""
    cfg = make_noc(16, 16, 8, lanes=8)
    traffic = build_traffic(lenet_layers, cfg, by_name("O1"),
                            max_packets_per_layer=8)
    res = simulate(cfg, traffic, chunk=CHUNK, check_conservation=True)
    assert res.ejected == res.injected > 0
    bad = traffic._replace(pkt=jnp.zeros_like(traffic.pkt))
    with pytest.raises(RuntimeError, match="conservation"):
        simulate(cfg, bad, chunk=CHUNK, check_conservation=True)


def test_streamed_sweep_matches_oneshot_sweep(lenet_layers):
    """max_packets_per_layer=None routes through the streamed packetizer;
    its rows must equal the one-shot sweep run at an unreached budget."""
    kw = dict(meshes=("4x4_mc2",), transforms=("O0", "O2"),
              precisions=("fixed8",), models=("lenet",), chunk=CHUNK)
    layers = [LayerTraffic(l.inputs[:10], l.weights[:10])
              for l in lenet_layers]
    streamed = run_sweep(
        SweepGrid(max_packets_per_layer=None, stream_chunk_packets=3, **kw),
        lambda _n: layers)
    oneshot = run_sweep(SweepGrid(max_packets_per_layer=10 ** 9, **kw),
                        lambda _n: layers)
    assert streamed.stats["streamed"] is True
    assert oneshot.stats["streamed"] is False
    keep = ("mesh", "placement", "model", "precision", "transform",
            "total_bt", "cycles", "flits", "overhead_bits")
    assert [{k: r[k] for k in keep} for r in streamed.rows] == \
        [{k: r[k] for k in keep} for r in oneshot.rows]


# Pinned by running the engine at PR-3 time; any schema or numeric drift in
# the sweep output is a deliberate, reviewed change, not an accident. The
# workload is fully deterministic (threefry PRNG, integer BT counters).
# PR 5 extended every row with the affinity knob and the (optional) result
# phase: "affinity"/"mean_hops" are always present, the "result_*" columns
# are None unless SweepGrid.result_phase is on. PR 6 added the honest
# single-stream result accounting ("result_overhead_bits"/
# "result_adjusted_bt"/"result_adjusted_reduction_pct", also None when the
# phase is off). PR 10 added the compression axis: "compression"/
# "compression_overhead_bits"/"result_compression_overhead_bits" are always
# present and pinned to ("none", 0, None-when-phase-off) on default grids.
# The PR-3 numerics are untouched - default grids must keep producing
# exactly these rows.
GOLDEN_GRID = dict(meshes=("2x2_mc1",), placements=("edge", "interleaved"),
                   transforms=("O0", "O1"), tiebreaks=("pattern",),
                   precisions=("fixed8",), models=("toy",),
                   max_packets_per_layer=None, chunk=256)
GOLDEN_ROWS = [
    {"mesh": "2x2_mc1", "placement": "edge", "affinity": "roundrobin",
     "transform": "O0", "total_bt": 4499, "cycles": 30, "flits": 27,
     "result_bt": None, "result_cycles": None},
    {"mesh": "2x2_mc1", "placement": "edge", "affinity": "roundrobin",
     "transform": "O1", "total_bt": 4687, "cycles": 30, "flits": 27,
     "result_bt": None, "result_cycles": None},
    {"mesh": "2x2_mc1", "placement": "interleaved", "affinity": "roundrobin",
     "transform": "O0", "total_bt": 4499, "cycles": 30, "flits": 27,
     "result_bt": None, "result_cycles": None},
    {"mesh": "2x2_mc1", "placement": "interleaved", "affinity": "roundrobin",
     "transform": "O1", "total_bt": 4687, "cycles": 30, "flits": 27,
     "result_bt": None, "result_cycles": None},
]


def test_sweep_golden_rows():
    key = jax.random.PRNGKey(5)
    layers = [LayerTraffic(
        jax.random.normal(key, (9, 12)),
        jax.random.normal(jax.random.fold_in(key, 1), (9, 12)) * 0.5)]
    report = run_sweep(SweepGrid(**GOLDEN_GRID), lambda _n: layers)
    schema = {"mesh", "placement", "affinity", "model", "precision",
              "transform", "tiebreak", "compression", "total_bt",
              "adjusted_bt", "overhead_bits", "compression_overhead_bits",
              "cycles", "flits", "bt_per_flit", "mean_hops",
              "reduction_pct", "adjusted_reduction_pct", "result_bt",
              "result_cycles", "result_flits", "result_overhead_bits",
              "result_compression_overhead_bits",
              "result_adjusted_bt", "result_adjusted_reduction_pct"}
    assert all(set(r) == schema for r in report.rows)
    # Default grids ride the pinned uncompressed path: the axis columns are
    # present but inert.
    assert all(r["compression"] == "none" for r in report.rows)
    assert all(r["compression_overhead_bits"] == 0 for r in report.rows)
    assert all(r["result_compression_overhead_bits"] is None
               for r in report.rows)
    got = [{k: r[k] for k in ("mesh", "placement", "affinity", "transform",
                              "total_bt", "cycles", "flits", "result_bt",
                              "result_cycles")} for r in report.rows]
    assert got == GOLDEN_ROWS


# PR 10: the same golden workload swept with compression=("none", "msr").
# The k=12 packets of this workload fit the same flit count compressed or
# not (2 payload flits either way at 16 lanes), so cycles/flits match the
# uncompressed pins while the 5-bit code lanes move total_bt and the
# escape metadata shows up in compression_overhead_bits - a deliberate
# pin of the "geometry may not shrink, accounting still must charge" edge.
GOLDEN_MSR_ROWS = [
    {"mesh": "2x2_mc1", "placement": "edge", "transform": "O0",
     "total_bt": 3764, "cycles": 30, "flits": 27,
     "compression_overhead_bits": 1105},
    {"mesh": "2x2_mc1", "placement": "edge", "transform": "O1",
     "total_bt": 3748, "cycles": 30, "flits": 27,
     "compression_overhead_bits": 1105},
    {"mesh": "2x2_mc1", "placement": "interleaved", "transform": "O0",
     "total_bt": 3764, "cycles": 30, "flits": 27,
     "compression_overhead_bits": 1105},
    {"mesh": "2x2_mc1", "placement": "interleaved", "transform": "O1",
     "total_bt": 3748, "cycles": 30, "flits": 27,
     "compression_overhead_bits": 1105},
]


def _golden_layers():
    key = jax.random.PRNGKey(5)
    return [LayerTraffic(
        jax.random.normal(key, (9, 12)),
        jax.random.normal(jax.random.fold_in(key, 1), (9, 12)) * 0.5)]


def test_sweep_golden_rows_msr():
    """The compression axis on the golden workload: none rows stay pinned
    bit-for-bit to the PR-3 numbers, msr rows match their own pins, and the
    conservation ledger holds on the compressed drain (positive arm)."""
    layers = _golden_layers()
    report = run_sweep(SweepGrid(compression=("none", "msr"), **GOLDEN_GRID),
                       lambda _n: layers, check_conservation=True)
    assert len(report.rows) == 2 * len(GOLDEN_ROWS)
    none_got = [{k: r[k] for k in ("mesh", "placement", "affinity",
                                   "transform", "total_bt", "cycles",
                                   "flits", "result_bt", "result_cycles")}
                for r in report.rows if r["compression"] == "none"]
    assert none_got == GOLDEN_ROWS
    msr_got = [{k: r[k] for k in ("mesh", "placement", "transform",
                                  "total_bt", "cycles",
                                  "flits", "compression_overhead_bits")}
               for r in report.rows if r["compression"] == "msr"]
    assert msr_got == GOLDEN_MSR_ROWS
    for r in report.rows:
        if r["compression"] != "msr":
            continue
        # the escape bits are charged at half a transition each, exactly
        # like the recovery index
        assert r["adjusted_bt"] == (r["total_bt"] + r["overhead_bits"] // 2
                                    + r["compression_overhead_bits"] // 2)


def test_conservation_detects_corruption_under_msr(lenet_layers, pinned_cfg):
    """Negative arm: the packet-conservation ledger still catches corrupted
    packet ids when the payload lanes carry MSR codes (fewer flits, same
    per-packet accounting)."""
    traffic = build_traffic(lenet_layers, pinned_cfg, by_name("O1"),
                            quantizer=lambda t: quantize_fixed8(t).values,
                            max_packets_per_layer=6, compression="msr")
    res = simulate(pinned_cfg, traffic, chunk=CHUNK, check_conservation=True)
    assert res.ejected == res.injected > 0
    bad = traffic._replace(pkt=jnp.zeros_like(traffic.pkt))
    with pytest.raises(RuntimeError, match="conservation"):
        simulate(pinned_cfg, bad, chunk=CHUNK, check_conservation=True)


# The fig12 pinned reference grid (PAPER_NOCS x 2 precisions x 2 tiebreaks,
# benchmarks/fig12.PINNED: random-init LeNet seed 1, glyph seed 7,
# max_packets=8, chunk=128), extended with the PR-6 O3 lane: 48 cells. The
# 36 O0/O1/O2 cells are bit-identical to PR-5 - the sweep engine on this
# exact config is equivalence-pinned against the seed driver by
# benchmarks/fig12.reference_compare, so any drift here is a real numeric
# change, not noise. O3 cells are tiebreak-independent (the chain ignores
# the popcount tiebreak) and must beat O2 on raw AND adjusted BT.
# (mesh, precision, tiebreak, transform) -> (total_bt, cycles, flits)
REFERENCE_GOLDEN = {
    ('4x4_mc2', 'float32', 'stable', 'O0'): (919679, 422, 832),
    ('4x4_mc2', 'float32', 'stable', 'O1'): (905763, 422, 832),
    ('4x4_mc2', 'float32', 'stable', 'O2'): (897483, 422, 832),
    ('4x4_mc2', 'float32', 'stable', 'O3'): (544169, 422, 832),
    ('4x4_mc2', 'float32', 'pattern', 'O0'): (919679, 422, 832),
    ('4x4_mc2', 'float32', 'pattern', 'O1'): (910023, 422, 832),
    ('4x4_mc2', 'float32', 'pattern', 'O2'): (904063, 422, 832),
    ('4x4_mc2', 'float32', 'pattern', 'O3'): (544169, 422, 832),
    ('4x4_mc2', 'fixed8', 'stable', 'O0'): (254514, 422, 832),
    ('4x4_mc2', 'fixed8', 'stable', 'O1'): (198800, 422, 832),
    ('4x4_mc2', 'fixed8', 'stable', 'O2'): (182389, 422, 832),
    ('4x4_mc2', 'fixed8', 'stable', 'O3'): (49438, 422, 832),
    ('4x4_mc2', 'fixed8', 'pattern', 'O0'): (254514, 422, 832),
    ('4x4_mc2', 'fixed8', 'pattern', 'O1'): (194442, 422, 832),
    ('4x4_mc2', 'fixed8', 'pattern', 'O2'): (174521, 422, 832),
    ('4x4_mc2', 'fixed8', 'pattern', 'O3'): (49438, 422, 832),
    ('8x8_mc4', 'float32', 'stable', 'O0'): (1545642, 219, 832),
    ('8x8_mc4', 'float32', 'stable', 'O1'): (1524068, 219, 832),
    ('8x8_mc4', 'float32', 'stable', 'O2'): (1511093, 219, 832),
    ('8x8_mc4', 'float32', 'stable', 'O3'): (908462, 219, 832),
    ('8x8_mc4', 'float32', 'pattern', 'O0'): (1545642, 219, 832),
    ('8x8_mc4', 'float32', 'pattern', 'O1'): (1531356, 219, 832),
    ('8x8_mc4', 'float32', 'pattern', 'O2'): (1523261, 219, 832),
    ('8x8_mc4', 'float32', 'pattern', 'O3'): (908462, 219, 832),
    ('8x8_mc4', 'fixed8', 'stable', 'O0'): (429567, 219, 832),
    ('8x8_mc4', 'fixed8', 'stable', 'O1'): (335531, 219, 832),
    ('8x8_mc4', 'fixed8', 'stable', 'O2'): (308273, 219, 832),
    ('8x8_mc4', 'fixed8', 'stable', 'O3'): (81477, 219, 832),
    ('8x8_mc4', 'fixed8', 'pattern', 'O0'): (429567, 219, 832),
    ('8x8_mc4', 'fixed8', 'pattern', 'O1'): (327806, 219, 832),
    ('8x8_mc4', 'fixed8', 'pattern', 'O2'): (294771, 219, 832),
    ('8x8_mc4', 'fixed8', 'pattern', 'O3'): (81477, 219, 832),
    ('8x8_mc8', 'float32', 'stable', 'O0'): (1189749, 137, 832),
    ('8x8_mc8', 'float32', 'stable', 'O1'): (1175949, 137, 832),
    ('8x8_mc8', 'float32', 'stable', 'O2'): (1168986, 137, 832),
    ('8x8_mc8', 'float32', 'stable', 'O3'): (717055, 137, 832),
    ('8x8_mc8', 'float32', 'pattern', 'O0'): (1189749, 137, 832),
    ('8x8_mc8', 'float32', 'pattern', 'O1'): (1180762, 137, 832),
    ('8x8_mc8', 'float32', 'pattern', 'O2'): (1176960, 137, 832),
    ('8x8_mc8', 'float32', 'pattern', 'O3'): (717055, 137, 832),
    ('8x8_mc8', 'fixed8', 'stable', 'O0'): (331382, 137, 832),
    ('8x8_mc8', 'fixed8', 'stable', 'O1'): (264011, 137, 832),
    ('8x8_mc8', 'fixed8', 'stable', 'O2'): (245286, 137, 832),
    ('8x8_mc8', 'fixed8', 'stable', 'O3'): (73837, 137, 832),
    ('8x8_mc8', 'fixed8', 'pattern', 'O0'): (331382, 137, 832),
    ('8x8_mc8', 'fixed8', 'pattern', 'O1'): (258230, 137, 832),
    ('8x8_mc8', 'fixed8', 'pattern', 'O2'): (235562, 137, 832),
    ('8x8_mc8', 'fixed8', 'pattern', 'O3'): (73837, 137, 832),
}


@pytest.mark.slow
def test_reference_grid_golden_with_o3():
    """The 48-cell pinned reference grid: O0/O1/O2 bit-identical to PR-5,
    O3 rows pinned at PR-6, tiebreak="pattern" AND "stable" both covered.
    Also asserts the PR-6 acceptance ordering: O3 beats O2's adjusted
    reduction on fixed8 and stays >= O2 on float32, on every mesh."""
    from benchmarks._trained import random_params
    from repro.data import glyph_batch

    model, params = random_params("lenet", seed=1)
    x, _ = glyph_batch(jax.random.PRNGKey(7), 1)
    layers = model.layer_traffic(params, x[0])
    grid = SweepGrid(meshes=("4x4_mc2", "8x8_mc4", "8x8_mc8"),
                     transforms=("O0", "O1", "O2", "O3"),
                     tiebreaks=("stable", "pattern"),
                     precisions=("float32", "fixed8"),
                     models=("lenet",), max_packets_per_layer=8, chunk=128)
    report = run_sweep(grid, lambda _n: layers)
    assert len(report.rows) == len(REFERENCE_GOLDEN) == 48
    got = {(r["mesh"], r["precision"], r["tiebreak"], r["transform"]):
           (r["total_bt"], r["cycles"], r["flits"]) for r in report.rows}
    assert got == REFERENCE_GOLDEN
    for r in report.rows:
        if r["transform"] != "O3":
            continue
        o2 = report.row(mesh=r["mesh"], precision=r["precision"],
                        tiebreak=r["tiebreak"], transform="O2")
        gap = r["adjusted_reduction_pct"] - o2["adjusted_reduction_pct"]
        if r["precision"] == "fixed8":
            assert gap > 0, (r["mesh"], r["tiebreak"], gap)
        else:
            assert gap >= 0, (r["mesh"], r["tiebreak"], gap)
