"""Flaky-skip audit: every skip in this suite must be real and explained.

A silently-skipping test is a hole in the safety net: a bare ``skip``, an
``xfail``, or a ``skipif`` on a constant condition passes CI forever
without testing anything. This meta-test walks every test module's AST and
enforces the repo's skip policy:

* ``pytest.importorskip`` always carries a non-empty ``reason`` naming the
  missing dependency (the hypothesis gates must actually say "hypothesis");
* ``pytest.mark.skipif`` always carries a non-empty ``reason``, and its
  condition is a real runtime probe (it references some observable - device
  counts, module state - never a bare ``True``/``False``/number literal);
* device-gated skips really gate on device count (the condition mentions a
  device probe, so a stale reason cannot outlive its check);
* inline ``pytest.skip(...)`` calls carry a non-empty message;
* bare ``@pytest.mark.skip`` and every flavor of ``xfail`` are banned
  outright - a test that cannot pass deterministically is deleted or
  fixed, not parked.
"""
import ast
import os

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def _test_modules():
    for name in sorted(os.listdir(TESTS_DIR)):
        if name.startswith("test_") and name.endswith(".py"):
            path = os.path.join(TESTS_DIR, name)
            with open(path) as f:
                src = f.read()
            yield name, src, ast.parse(src)


def _dotted(node):
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    if isinstance(node, ast.Name):
        return node.id
    return None


def _string_value(node):
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.BinOp):     # implicit/explicit concatenation
        left, right = _string_value(node.left), _string_value(node.right)
        if left is not None and right is not None:
            return left + right
    if isinstance(node, ast.JoinedStr):
        return "".join(_string_value(v) or "<expr>" for v in node.values)
    return None


def _calls(tree):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield _dotted(node.func), node


def test_importorskip_reasons_are_real():
    seen = 0
    for name, src, tree in _test_modules():
        for fn, call in _calls(tree):
            if fn != "pytest.importorskip":
                continue
            seen += 1
            module = _string_value(call.args[0]) if call.args else None
            assert module, f"{name}: importorskip needs a literal module name"
            reason = None
            for kw in call.keywords:
                if kw.arg == "reason":
                    reason = _string_value(kw.value)
            assert reason and reason.strip(), \
                f"{name}: importorskip({module!r}) must say why it may skip"
            # The reason must name the dependency it gates on, so a reader
            # of the skip summary knows what to install.
            assert module in reason, \
                f"{name}: importorskip reason {reason!r} does not name " \
                f"the gated module {module!r}"
    assert seen >= 6     # the hypothesis suites all gate this way


def test_inline_skips_have_messages():
    for name, src, tree in _test_modules():
        for fn, call in _calls(tree):
            if fn != "pytest.skip":
                continue
            msg = _string_value(call.args[0]) if call.args else None
            assert msg and msg.strip(), \
                f"{name}:{call.lineno}: pytest.skip() without a message"


def test_skipif_conditions_are_probes_with_reasons():
    seen = 0
    for name, src, tree in _test_modules():
        for fn, call in _calls(tree):
            if fn != "pytest.mark.skipif":
                continue
            seen += 1
            where = f"{name}:{call.lineno}"
            assert call.args, f"{where}: skipif without a condition"
            cond = call.args[0]
            assert not isinstance(cond, ast.Constant), \
                f"{where}: skipif on a constant never re-evaluates - " \
                f"delete the test or probe something real"
            reason = None
            for kw in call.keywords:
                if kw.arg == "reason":
                    reason = _string_value(kw.value)
            assert reason and reason.strip(), \
                f"{where}: skipif must carry a reason"
            cond_src = ast.get_source_segment(src, cond) or ""
            if "device" in reason:
                # A device-gated reason must be backed by a device-count
                # probe, not a stale explanation of some other condition.
                assert "device" in cond_src, \
                    f"{where}: reason claims a device gate but the " \
                    f"condition {cond_src!r} never counts devices"
    assert seen >= 2     # the multi-device suites gate this way


def test_no_bare_skip_or_xfail_markers():
    for name, src, tree in _test_modules():
        for node in ast.walk(tree):
            target = None
            if isinstance(node, ast.Attribute):
                target = _dotted(node)
            if target in ("pytest.mark.skip", "pytest.mark.xfail"):
                raise AssertionError(
                    f"{name}:{node.lineno}: {target} is banned - use "
                    f"skipif/importorskip with a reason, or fix the test")


def test_device_gated_skips_count_devices():
    """Every skipif whose condition touches jax devices uses a count
    comparison (the gate cannot rot into an always-True tautology)."""
    for name, src, tree in _test_modules():
        for fn, call in _calls(tree):
            if fn != "pytest.mark.skipif" or not call.args:
                continue
            cond_src = ast.get_source_segment(src, call.args[0]) or ""
            if "device" not in cond_src:
                continue
            assert ("device_count()" in cond_src
                    or "devices())" in cond_src), \
                f"{name}:{call.lineno}: device gate {cond_src!r} should " \
                f"compare a live device count"
