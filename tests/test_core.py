"""Unit tests: bit primitives, flit packing, BT metrics, orderings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bits, pack, pack_paired, unpack, bt_stream,
                        bt_per_flit, bt_between, expected_bt_pair,
                        pairing_objective, descending_order, affiliated_order,
                        separated_order, descending_perm, inverse_permutation,
                        index_overhead_bits)
from repro.core.bits import popcount, transitions
from repro.core.ordering import pad_to_window


def test_popcount_known_values():
    x = jnp.array([0, 1, 3, 255, 2**31], dtype=jnp.uint32)
    assert popcount(x).tolist() == [0, 1, 2, 8, 1]


def test_popcount_float_bitpattern():
    # -0.0 is 0x80000000 -> one '1' bit; 1.0 is 0x3F800000 -> 7 ones
    x = jnp.array([-0.0, 1.0, 0.0], dtype=jnp.float32)
    assert popcount(x).tolist() == [1, 7, 0]


def test_popcount_int8():
    x = jnp.array([-1, 0, 1, -128], dtype=jnp.int8)  # 0xFF, 0, 1, 0x80
    assert popcount(x).tolist() == [8, 0, 1, 1]


def test_transitions_symmetric_zero_on_equal():
    a = jnp.array([7, 9], dtype=jnp.uint32)
    assert transitions(a, a).tolist() == [0, 0]
    b = jnp.array([0, 0xFFFFFFFF], dtype=jnp.uint32)
    assert transitions(b, jnp.zeros_like(b)).tolist() == [0, 32]


def test_pack_pads_with_zeros_and_roundtrips():
    v = jnp.arange(10, dtype=jnp.float32)
    s = pack(v, lanes=8)
    assert s.words.shape == (2, 8)
    assert s.flit_bits == 256
    back = unpack(s, 10, jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(v))


def test_pack_paired_layout():
    i = jnp.arange(8, dtype=jnp.float32)
    w = jnp.arange(8, 16, dtype=jnp.float32)
    s = pack_paired(i, w, lanes=16)
    assert s.words.shape == (1, 16)
    left = unpack(type(s)(s.words[:, :8], 8, 32), 8, jnp.float32)
    right = unpack(type(s)(s.words[:, 8:], 8, 32), 8, jnp.float32)
    np.testing.assert_array_equal(np.asarray(left), np.asarray(i))
    np.testing.assert_array_equal(np.asarray(right), np.asarray(w))


def test_bt_stream_matches_manual():
    w = jnp.array([[0b1010, 0b1100], [0b0101, 0b1100], [0b0101, 0b0011]],
                  dtype=jnp.uint32)
    # boundary 0->1: 1010^0101=1111 (4) + 1100^1100=0 -> 4
    # boundary 1->2: 0 + 1100^0011=1111 (4) -> 4
    s = pack(jax.lax.bitcast_convert_type(w.reshape(-1), jnp.float32), 2)
    assert int(bt_stream(s)) == 8
    assert float(bt_per_flit(s)) == 4.0


def test_expected_bt_pair_eq2():
    # Eq. (2) at b=32: E = x + y - xy/16
    assert float(expected_bt_pair(jnp.array(16), jnp.array(16), 32)) == 16.0
    assert float(expected_bt_pair(jnp.array(32), jnp.array(32), 32)) == 0.0
    assert float(expected_bt_pair(jnp.array(0), jnp.array(32), 32)) == 32.0


def test_descending_order_sorts_by_popcount():
    v = jnp.array([0x0F, 0x01, 0xFF, 0x00], dtype=jnp.uint32)
    o = descending_order(v)
    assert popcount(o.values).tolist() == [8, 4, 1, 0]
    # multiset preserved
    assert sorted(np.asarray(o.values).tolist()) == sorted(np.asarray(v).tolist())


def test_windowed_ordering_stays_in_window():
    v = jnp.arange(16, dtype=jnp.uint32)
    o = descending_order(v, window=4)
    p = np.asarray(o.perm)
    for wstart in range(0, 16, 4):
        assert set(p[wstart:wstart + 4]) == set(range(wstart, wstart + 4))


def test_interleave_fill_realizes_paper_order():
    # two flits of 4 lanes: sorted s1..s8 -> lane j of flit f = s[2j+f]
    v = jnp.array([1, 3, 7, 15, 31, 63, 127, 255], dtype=jnp.uint32)
    o = descending_order(v, fill="interleave", lanes=4)
    c = popcount(o.values).reshape(2, 4)
    # per-lane: flit0 >= flit1, and flit0 lanes descending
    assert bool(jnp.all(c[0] >= c[1]))
    assert bool(jnp.all(c[0][:-1] >= c[0][1:]))


def test_affiliated_keeps_pairs():
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (64,), jnp.float32)
    i = jax.random.normal(jax.random.fold_in(k, 1), (64,), jnp.float32)
    po = affiliated_order(i, w, window=16)
    # pairing intact: the same permutation applied to both
    np.testing.assert_array_equal(np.asarray(po.input_perm),
                                  np.asarray(po.weight_perm))
    np.testing.assert_array_equal(np.asarray(po.inputs),
                                  np.asarray(i)[np.asarray(po.input_perm)])


def test_separated_orders_both_streams():
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (64,), jnp.float32)
    i = jax.random.normal(jax.random.fold_in(k, 1), (64,), jnp.float32)
    po = separated_order(i, w)
    cw = popcount(po.weights)
    ci = popcount(po.inputs)
    assert bool(jnp.all(cw[:-1] >= cw[1:]))
    assert bool(jnp.all(ci[:-1] >= ci[1:]))


def test_inverse_permutation():
    p = descending_perm(jnp.array([3, 1, 7, 0], dtype=jnp.uint32))
    inv = inverse_permutation(p)
    np.testing.assert_array_equal(np.asarray(p)[np.asarray(inv)],
                                  np.arange(4))


def test_index_overhead_bits():
    assert index_overhead_bits(16) == 4
    assert index_overhead_bits(17) == 5
    assert index_overhead_bits(1) == 1


def test_pad_to_window():
    v = jnp.arange(10, dtype=jnp.uint32)
    assert pad_to_window(v, 8).shape == (16,)
    assert pad_to_window(v, None).shape == (10,)
    assert int(pad_to_window(v, 8)[10:].sum()) == 0
