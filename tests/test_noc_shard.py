"""Device-sharded sweep drain: `simulate_batch(devices=...)` splits the
variants axis across devices with shard_map and must be bit-identical to
the single-device vmapped drain (variant lanes never communicate).

Multi-device cases need forced host devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_noc_shard.py
On a 1-device host they skip; the fallback and helper tests always run.
"""
import jax
import numpy as np
import pytest

from repro.core.wire import by_name
from repro.dist.sharding import batch_shardings
from repro.noc import NocConfig, SweepGrid, build_traffic_batch, run_sweep, \
    simulate_batch
from repro.noc.traffic import LayerTraffic

multi_device = pytest.mark.skipif(
    jax.local_device_count() < 2,
    reason="needs >1 device (set --xla_force_host_platform_device_count)")


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(0)
    layers = [LayerTraffic(
        jax.random.normal(key, (14, 6)),
        jax.random.normal(jax.random.fold_in(key, 1), (14, 6)) * 0.4)]
    cfg = NocConfig(4, 4, (0, 15), lanes=8)
    variants = [(by_name(o), None) for o in ("O0", "O1", "O2")]
    return layers, cfg, build_traffic_batch(layers, cfg, variants)


@multi_device
def test_sharded_drain_bit_identical(workload):
    """B=3 over N devices (padded with empty rows to a device multiple):
    every per-variant result matches the unsharded drain exactly."""
    _, cfg, batch = workload
    plain = simulate_batch(cfg, batch, chunk=128, check_conservation=True)
    shard = simulate_batch(cfg, batch, chunk=128, check_conservation=True,
                           devices=jax.local_devices())
    assert len(plain) == len(shard) == 3
    for p, s in zip(plain, shard):
        assert s.total_bt == p.total_bt
        assert s.drain_cycle == p.drain_cycle
        assert s.cycles == p.cycles
        assert s.ejected == p.ejected == s.injected
        assert np.array_equal(s.link_bt, p.link_bt)
        assert np.array_equal(s.inj_bt, p.inj_bt)


@multi_device
def test_sharded_sweep_matches_unsharded(workload):
    """run_sweep(devices='auto') on a multi-device host returns the same
    rows as the explicit single-device sweep."""
    layers, _, _ = workload
    grid = SweepGrid(meshes=("4x4_mc2",), transforms=("O0", "O2"),
                     precisions=("fixed8",), models=("toy",),
                     max_packets_per_layer=None, chunk=128)
    auto = run_sweep(grid, lambda _n: layers)
    single = run_sweep(grid, lambda _n: layers, devices=None)
    assert auto.stats["devices"] == jax.local_device_count()
    assert single.stats["devices"] == 1
    strip = lambda rows: [{k: v for k, v in r.items()} for r in rows]
    assert strip(auto.rows) == strip(single.rows)


@multi_device
def test_mesh_spec_devices_bit_identical(workload):
    """devices= also accepts a 1-D jax.sharding.Mesh directly (the
    dist.sharding mesh-spec contract); results match the device-list path
    exactly, and a 2-D mesh is rejected."""
    from jax.sharding import Mesh

    _, cfg, batch = workload
    mesh = Mesh(np.asarray(jax.local_devices()), ("lanes",))
    shard = simulate_batch(cfg, batch, chunk=128, devices=mesh)
    plain = simulate_batch(cfg, batch, chunk=128,
                           devices=jax.local_devices())
    for s, p in zip(shard, plain):
        assert s.total_bt == p.total_bt
        assert s.drain_cycle == p.drain_cycle
        assert np.array_equal(s.link_bt, p.link_bt)

    ndev = jax.local_device_count()
    bad = Mesh(np.asarray(jax.local_devices()).reshape(ndev // 2, 2),
               ("a", "b"))
    with pytest.raises(ValueError, match="1-D device mesh"):
        simulate_batch(cfg, batch, chunk=128, devices=bad)


def test_single_device_fallback(workload):
    """devices=None and a 1-device list take the plain vmapped runner."""
    _, cfg, batch = workload
    a = simulate_batch(cfg, batch, chunk=128)
    b = simulate_batch(cfg, batch, chunk=128,
                       devices=jax.local_devices()[:1])
    for x, y in zip(a, b):
        assert x.total_bt == y.total_bt and x.drain_cycle == y.drain_cycle


def test_batch_shardings_leading_axis():
    """The dist.sharding helper shards divisible leading dims over the axis
    and replicates scalars / non-divisible leading dims (the same fallback
    contract as logical_to_pspec)."""
    from jax.sharding import Mesh, PartitionSpec as P

    ndev = jax.local_device_count()
    mesh = Mesh(np.asarray(jax.local_devices()), ("variants",))
    tree = {"a": np.zeros((2 * ndev, 3)), "s": np.zeros(()),
            "odd": np.zeros((2 * ndev + 1, 2))}
    shardings = batch_shardings(mesh, tree, "variants")
    assert shardings["a"].spec == P("variants")
    assert shardings["s"].spec == P()
    if ndev > 1:
        assert shardings["odd"].spec == P()
