"""Fault-tolerance integration: crash -> restore -> elastic continuation.

Simulates the 1000-node operational story at CI scale: a training job
checkpoints, dies, restarts from the newest intact checkpoint, and
continues with a DIFFERENT gradient-accumulation factor (what an elastic
re-mesh does when the data axis shrinks but the global batch must hold) -
while the deterministic pipeline regenerates exactly the shards it needs.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenStream
from repro.models import LM, LMConfig, init_params
from repro.optim import AdamW, constant
from repro.train import make_train_step, init_state, checkpoint
from repro.train.elastic import microbatches_for


@pytest.fixture(scope="module")
def setup():
    cfg = LMConfig("ft", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_ff=128, vocab=256)
    model = LM(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    stream = TokenStream(vocab=256, seq_len=32, global_batch=8)

    def loss_fn(p, b):
        toks, tgt, mask = b
        return model.loss(p, toks, tgt, mask)

    return model, params, stream, loss_fn


def test_crash_restore_elastic_continuation(tmp_path, setup):
    model, params, stream, loss_fn = setup
    opt = AdamW(constant(1e-3))
    ckpt = str(tmp_path)

    # phase 1: run 6 steps, checkpoint every 3, then "crash"
    step = jax.jit(make_train_step(loss_fn, opt))
    state = init_state(params, opt)
    for i in range(6):
        state, m = step(state, stream.batch(i))
        if (i + 1) % 3 == 0:
            checkpoint.save(ckpt, i + 1, state)
    loss_before_crash = float(m["loss"])

    # phase 2: fresh process state; restore newest intact checkpoint
    restored = checkpoint.restore(ckpt, init_state(params, opt))
    assert restored is not None
    start, state2 = restored
    assert start == 6
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # phase 3: elastic continuation - half the data axis, so double the
    # microbatches to hold the global batch (elastic.microbatches_for)
    mb = microbatches_for(global_batch=8, per_device_batch=4, data_axis=1)
    assert mb == 2
    step2 = jax.jit(make_train_step(loss_fn, opt, microbatches=mb))
    for i in range(start, start + 4):
        state2, m2 = step2(state2, stream.batch(i))
    assert jnp.isfinite(m2["loss"])
    assert float(m2["loss"]) < loss_before_crash + 0.5  # no divergence


def test_restore_skips_future_torn_step(tmp_path, setup):
    model, params, stream, loss_fn = setup
    opt = AdamW(constant(1e-3))
    state = init_state(params, opt)
    checkpoint.save(str(tmp_path), 10, state)
    # torn newer checkpoint: directory exists, npz missing
    os.makedirs(os.path.join(str(tmp_path), "step_000000020"))
    with open(os.path.join(str(tmp_path), "step_000000020", "manifest.json"),
              "w") as f:
        f.write('{"step": 20, "num_hosts": 1, "keys": [], "shapes": {}, "dtypes": {}}')
    got = checkpoint.restore(str(tmp_path), state)
    assert got is not None and got[0] == 10


@pytest.mark.skipif(
    len(jax.devices()) < 2 or 8 % len(jax.devices()) != 0,
    reason="elastic re-mesh onto a real data-parallel mesh needs >= 2 "
           "devices that divide the global batch of 8")
def test_restore_then_continue_on_data_parallel_mesh(tmp_path, setup):
    """The multi-device leg of the restart story: a checkpoint written by a
    single-host job restores onto a (data, model) mesh and keeps training
    with the batch sharded over the data axis."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    model, params, stream, loss_fn = setup
    opt = AdamW(constant(1e-3))
    checkpoint.save(str(tmp_path), 1, init_state(params, opt))
    restored = checkpoint.restore(str(tmp_path), init_state(params, opt))
    assert restored is not None
    start, state = restored

    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    step = jax.jit(make_train_step(loss_fn, opt))
    with mesh:
        batch = jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("data"))),
            stream.batch(start))
        state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))


def test_shard_regeneration_covers_full_batch(setup):
    """Straggler mitigation invariant: the union of shard batches equals the
    single-host batch, so any host can recompute any shard."""
    _, _, stream, _ = setup
    full = stream.batch(3)
    parts = [stream.batch(3, shard=s, num_shards=4) for s in range(4)]
    glued = jnp.concatenate([p[0] for p in parts], axis=0)
    np.testing.assert_array_equal(np.asarray(glued), np.asarray(full[0]))
