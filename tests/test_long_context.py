"""Long-context smoke paths: the mechanisms long_500k relies on, exercised
at CI scale - SWA ring caches that wrap many times, recurrent state that
carries across thousands of positions, and prefill->decode agreement at
positions far beyond the window."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models.spec import init_params


@pytest.mark.parametrize("name", ["h2o-danube-3-4b", "recurrentgemma-9b",
                                  "xlstm-125m", "mixtral-8x7b"])
def test_decode_far_past_window(name):
    """Decode 3x the window/context depth: states stay finite and the ring
    cache wraps correctly (slot = pos % window)."""
    arch = get(name)
    model = arch.build_reduced()
    cfg = model.cfg
    window = cfg.window or 16
    b, s0 = 1, 8
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s0), 0, cfg.vocab)
    logits, cache = model.prefill(params, toks, context=window)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1)
    for i in range(3 * window):          # wraps the ring several times
        pos = jnp.full((b,), s0 + i, jnp.int32)
        logits, cache = step(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(tok.max()) < cfg.vocab


def test_swa_decode_matches_forward_beyond_window():
    """After the ring wraps, cached decode must still agree with a full
    forward pass (the window masks identically either way)."""
    arch = get("h2o-danube-3-4b")
    model = arch.build_reduced()
    cfg = model.cfg
    w = cfg.window                      # 16 in the reduced config
    b, s = 1, 3 * w                     # context far beyond the window
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0, cfg.vocab)
    _, cache = model.prefill(params, toks[:, :s], context=w)
    step_logits, _ = model.decode_step(params, toks[:, s], cache,
                                       jnp.full((b,), s, jnp.int32))
    full, _ = model.forward(params, toks)
    assert int(jnp.argmax(step_logits)) == int(jnp.argmax(full[:, s]))


def test_recurrent_state_is_context_length_independent():
    """The property that makes long_500k feasible: cache/state byte size for
    a recurrent arch does not grow with requested context."""
    arch = get("xlstm-125m")
    model = arch.build_reduced()

    def nbytes(ctx):
        cache = jax.eval_shape(lambda: model.init_cache(1, ctx))
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

    assert nbytes(1 << 10) == nbytes(1 << 19)

    swa = get("h2o-danube-3-4b").build_reduced()
    def nbytes_swa(ctx):
        cache = jax.eval_shape(lambda: swa.init_cache(1, ctx))
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    # bounded by the window once context exceeds it
    assert nbytes_swa(1 << 10) == nbytes_swa(1 << 19)


def test_vlm_prefill_decode_consistency():
    """internvl2: patch-embedding prefix flows through prefill; decode
    continues from the cache and stays in-vocab."""
    arch = get("internvl2-1b")
    model = arch.build_reduced()
    cfg = model.cfg
    b, s = 2, 8
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    pe = jax.random.normal(jax.random.PRNGKey(4),
                           (b, cfg.vlm_prefix, cfg.d_model)).astype(jnp.bfloat16)
    logits, cache = model.prefill(params, toks, context=64, patch_embeds=pe)
    assert logits.shape == (b, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1)
    pos = jnp.full((b,), cfg.vlm_prefix + s, jnp.int32)
    logits2, _ = model.decode_step(params, tok, cache, pos)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(jnp.argmax(logits2, -1).max()) < cfg.vocab
