"""End-to-end behaviour tests: train -> serve -> NoC evaluation, the full
pipeline the paper describes, at CI scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core.wire import by_name
from repro.data import TokenStream, glyph_batch
from repro.models import LeNet, init_params
from repro.models.spec import abstract_params
from repro.noc import PAPER_NOCS, NocConfig, simulate, build_traffic
from repro.optim import AdamW, cosine
from repro.quant import quantize_fixed8
from repro.serve import Engine, GenerationConfig
from repro.train import make_train_step, init_state


def test_train_then_serve_lm():
    arch = get("xlstm-125m")
    model = arch.build_reduced()
    cfg = model.cfg
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=8)
    opt = AdamW(cosine(3e-3, 30, warmup=3))
    def loss_fn(p, b):
        toks, tgt, mask = b
        return model.loss(p, toks, tgt, mask)
    step = jax.jit(make_train_step(loss_fn, opt))
    st = init_state(params, opt)
    l0 = lN = None
    for i in range(30):
        st, m = step(st, stream.batch(i))
        l0 = l0 if l0 is not None else float(m["loss"])
        lN = float(m["loss"])
    assert lN < l0

    engine = Engine(model, st.params, context=64)
    prompts = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, cfg.vocab)
    out = engine.generate(prompts, GenerationConfig(max_new_tokens=4))
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab


def test_lenet_inference_traffic_through_noc_all_orderings():
    """The paper's full pipeline: train LeNet (briefly), push one inference's
    operand traffic through the 4x4 NoC under O0/O1/O2, check that the
    orderings reduce payload BT for fixed-8."""
    model = LeNet()
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    opt = AdamW(cosine(2e-3, 20, warmup=2), weight_decay=0.0)
    def loss_fn(p, b):
        x, y = b
        return model.loss(p, x, y)
    step = jax.jit(make_train_step(loss_fn, opt))
    st = init_state(params, opt)
    for i in range(20):
        st, _ = step(st, glyph_batch(jax.random.PRNGKey(i), 32))

    x, _ = glyph_batch(jax.random.PRNGKey(77), 1)
    layers = model.layer_traffic(st.params, x[0])
    cfg = PAPER_NOCS["4x4_mc2"]
    q = lambda t: quantize_fixed8(t).values
    bt = {}
    for name in ("O0", "O1", "O2"):
        tr = build_traffic(layers, cfg, by_name(name), quantizer=q,
                           max_packets_per_layer=12)
        res = simulate(cfg, tr, chunk=1024, count_headers=False)
        assert res.ejected == res.injected
        bt[name] = res.total_bt
    assert bt["O2"] < bt["O0"]
    assert bt["O1"] < bt["O0"] * 1.02   # O1 never meaningfully worse


def test_dryrun_cell_builder_abstract_only():
    """build_cell must work purely with ShapeDtypeStructs (no allocation of
    full-scale params) - guard against accidental materialization."""
    # initialize the backend BEFORE importing dryrun so its XLA_FLAGS line
    # (512 host devices) cannot take effect inside the test process
    n = len(jax.devices())
    from repro.launch.dryrun import build_cell
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    fn, args, shardings = build_cell("xlstm-125m", "decode_32k", mesh)
    leaves = jax.tree.leaves(args)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """ENTRY %main (p: f32[2]) -> f32[2] {
  %ag = bf16[8,1024]{1,0} all-gather(bf16[8,64]{1,0} %x), dimensions={1}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs = bf16[4,64]{1,0} reduce-scatter(bf16[4,1024]{1,0} %z), dimensions={1}
  %cp = u32[16]{0} collective-permute(u32[16]{0} %w)
  %nothing = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 1024 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 4 * 1024 * 2   # big operand counted
    assert out["collective-permute"] == 16 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


class _ScriptedModel:
    """Deterministic Engine stand-in: step ``t``'s logits put all mass on
    ``script[:, t]``, so greedy decode emits the script verbatim. The KV
    cache degenerates to the step counter."""

    vocab = 16

    def __init__(self, script):
        self.script = jnp.asarray(script, jnp.int32)

    def prefill(self, params, prompts, context):
        return self._logits(jnp.int32(0)), jnp.int32(0)

    def decode_step(self, params, tok, cache, pos):
        step = cache + 1
        return self._logits(step), step

    def _logits(self, step):
        b, t = self.script.shape
        idx = self.script[:, jnp.minimum(step, t - 1)]
        lg = jnp.full((b, self.vocab), -1e9, jnp.float32)
        return lg.at[jnp.arange(b), idx].set(0.0)


def _per_step_reference_generate(engine, prompts, gen, key=None):
    """The seed Engine.generate loop verbatim: one blocking
    ``bool(jnp.all(done))`` host sync per decode step."""
    b, s = prompts.shape
    logits, cache = engine.model.prefill(engine.params, prompts,
                                         engine.context)
    if key is None:
        key = jax.random.PRNGKey(0)
    out = []
    tok = engine._sample(logits, gen, key)
    done = jnp.zeros((b,), bool)
    for i in range(gen.max_new_tokens):
        out.append(tok)
        done = done | (tok == gen.eos_id)
        if bool(jnp.all(done)):
            break
        pos = jnp.full((b,), s + i, jnp.int32)
        logits, cache = engine._decode(engine.params, tok, cache, pos)
        key = jax.random.fold_in(key, i)
        tok = engine._sample(logits, gen, key)
        tok = jnp.where(done, gen.eos_id, tok)
    return jnp.stack(out, axis=1)


def test_engine_generate_matches_per_step_reference():
    """The block-synced decode loop (host done-check every ``sync_every``
    steps plus a final trim) must reproduce the per-step early-exit loop
    bit-for-bit - same tokens, same early-exit length - while issuing
    strictly fewer blocking syncs and still cutting the decode short."""
    eos = 7
    script = [[4, 2, eos, 1, 1, 1, 1, 1, 1, 1, 1, 1],
              [5, 3, 6, 2, eos, 1, 1, 1, 1, 1, 1, 1]]
    prompts = jnp.zeros((2, 3), jnp.int32)
    gen = GenerationConfig(max_new_tokens=12, eos_id=eos, sync_every=4)

    engine = Engine(_ScriptedModel(script), params={}, context=32)
    inner = engine._decode
    ref = _per_step_reference_generate(engine, prompts, gen)
    assert ref.shape == (2, 5)          # rows finish at steps 2 and 4

    calls = []
    engine._decode = lambda *a: calls.append(0) or inner(*a)
    out = engine.generate(prompts, gen)
    assert out.shape == ref.shape
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # EOS early exit survives the block sync: well short of max_new_tokens
    assert len(calls) < gen.max_new_tokens - 1

    # no-EOS path: full length, still bit-identical
    gen_full = GenerationConfig(max_new_tokens=12, eos_id=-1, sync_every=4)
    ref_full = _per_step_reference_generate(engine, prompts, gen_full)
    out_full = engine.generate(prompts, gen_full)
    assert out_full.shape == (2, 12)
    np.testing.assert_array_equal(np.asarray(out_full), np.asarray(ref_full))


def test_serve_offered_load_loop():
    """The arrival-driven serving loop: every request served, latency
    summary well-formed, deterministic arrival replay from the seed."""
    from repro.launch.serve import serve_offered_load

    engine = Engine(_ScriptedModel([[4, 2, 3, 1]]), params={}, context=32)
    prompts = jnp.zeros((6, 3), jnp.int32)
    gen = GenerationConfig(max_new_tokens=4)
    outs, stats = serve_offered_load(engine, prompts, gen, load=200.0,
                                     arrival="poisson", seed=3, pace=False)
    assert len(outs) == 6
    assert all(o.shape == (1, 4) for o in outs)
    assert stats["count"] == 6 and stats["truncated"] == 0
    assert stats["p50"] is not None and stats["p99"] >= stats["p50"]
    assert stats["throughput_rps"] > 0
    from repro.noc.online import ArrivalProcess
    a1 = ArrivalProcess("poisson", 200.0, 3).times(6)
    a2 = ArrivalProcess("poisson", 200.0, 3).times(6)
    np.testing.assert_array_equal(a1, a2)


def test_collective_bytes_while_trip_count():
    """Collectives inside a scan body count trip_count times (XLA prints
    the body once; traffic happens every iteration)."""
    from repro.launch.dryrun import collective_bytes
    hlo = """%cond.1 (c: (s32[])) -> pred[] {
  %bound = s32[] constant(40)
  %it = s32[] get-tuple-element(%c), index=0
  ROOT %cmp = pred[] compare(%it, %bound), direction=LT
}
%body.1 (b: (s32[])) -> (s32[]) {
  %ag2 = bf16[64]{0} all-gather(bf16[4]{0} %x), dimensions={0}
}
ENTRY %main (p: f32[2]) -> f32[2] {
  %w = (s32[]) while((s32[]) %init), condition=%cond.1, body=%body.1
  %ar = f32[8]{0} all-reduce(f32[8]{0} %y), to_apply=%add
}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 2 * 40
    assert out["counts"]["all-gather"] == 40
    assert out["all-reduce"] == 8 * 4
