"""Property test (hypothesis): variant retirement + lane compaction in
``simulate_batch`` is bit-identical to the plain (uncompacted) batched
drain for ARBITRARY per-variant drain times - arbitrary lane counts,
arbitrary per-lane packet counts (including empty lanes), and arbitrary
chunk sizes (chunk=1 forces a retire decision every cycle).

Kept separate from tests/test_noc_step.py so importorskip can stay
module-granular (mirrors tests/test_noc_stream_properties.py).
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.wire import by_name  # noqa: E402
from repro.noc import (LayerTraffic, NocConfig, build_traffic,  # noqa: E402
                       simulate_batch)
from repro.noc.traffic import stack_traffics  # noqa: E402

CFG = NocConfig(rows=3, cols=3, mc_nodes=(0, 4), lanes=4)


def _batch(lane_packets, seed):
    key = jax.random.PRNGKey(seed)
    singles = []
    for i, n in enumerate(lane_packets):
        ki = jax.random.fold_in(key, i)
        layer = LayerTraffic(
            jax.random.normal(ki, (n, 5)),
            jax.random.normal(jax.random.fold_in(ki, 1), (n, 5)) * 0.5)
        singles.append(build_traffic([layer], CFG, by_name("O0")))
    return stack_traffics(singles)


@settings(max_examples=12, deadline=None)
@given(
    lane_packets=st.lists(st.integers(min_value=0, max_value=24),
                          min_size=2, max_size=7).filter(lambda x: sum(x)),
    chunk=st.sampled_from([1, 3, 16, 64]),
    seed=st.integers(min_value=0, max_value=5),
)
def test_retirement_compaction_matches_plain_drain(lane_packets, chunk, seed):
    batch = _batch(lane_packets, seed)
    fast = simulate_batch(CFG, batch, chunk=chunk, retire=True,
                          check_conservation=True)
    plain = simulate_batch(CFG, batch, chunk=chunk, retire=False,
                           check_conservation=True)
    assert len(fast) == len(plain) == len(lane_packets)
    for f, p in zip(fast, plain):
        assert f.total_bt == p.total_bt
        assert f.drain_cycle == p.drain_cycle
        assert f.ejected == p.ejected
        assert np.array_equal(f.link_bt, p.link_bt)
        assert np.array_equal(f.inj_bt, p.inj_bt)
