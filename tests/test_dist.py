"""repro.dist beyond the seed pins: ragged round-trips, real-gradient wire
reports, static-layout stream reports, bucket invariants, and the
multi-device sharding path (skipped on single-device hosts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenStream
from repro.dist.ordered_collectives import (gradient_wire_report,
                                            order_gradient_bucket,
                                            restore_gradient_bucket)
from repro.dist.overlap import bucketed, unbucket
from repro.dist.sharding import DEFAULT_RULES, spec_shardings
from repro.dist.static_reorder import reorder_lm_params, stream_bt_report
from repro.models import LM, LMConfig, init_params
from repro.models.spec import ParamSpec
from repro.optim import AdamW, cosine
from repro.train import init_state, make_train_step


@pytest.fixture(scope="module")
def trained_grads():
    """A briefly trained tiny LM and its real gradients (not synthetic)."""
    cfg = LMConfig("t", n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                   vocab=256)
    model = LM(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    stream = TokenStream(vocab=256, seq_len=32, global_batch=8)
    opt = AdamW(cosine(3e-3, 10, warmup=2))

    def loss_fn(p, b):
        toks, tgt, mask = b
        return model.loss(p, toks, tgt, mask)

    step = jax.jit(make_train_step(loss_fn, opt))
    st = init_state(params, opt)
    for i in range(10):
        st, _ = step(st, stream.batch(i))
    grads = jax.grad(loss_fn)(st.params, stream.batch(10))
    return st.params, grads


# ---------------------------------------------------------------------------
# ordered collectives
# ---------------------------------------------------------------------------

def test_ordered_bucket_roundtrip_ragged_pytree():
    """Every leaf of a ragged tree (no length divides the window) must come
    back bit-identical, across dtypes."""
    key = jax.random.PRNGKey(0)
    tree = {
        "a": jax.random.normal(key, (37, 5), jnp.float32),
        "b": {
            "c": jax.random.normal(jax.random.fold_in(key, 1), (13,),
                                   jnp.float32).astype(jnp.bfloat16),
            "d": jax.random.normal(jax.random.fold_in(key, 2), (3, 7, 2),
                                   jnp.float32),
        },
    }
    weights = jax.tree.map(
        lambda g: jax.random.normal(jax.random.fold_in(key, g.size),
                                    g.shape, jnp.float32).astype(g.dtype),
        tree)
    for g, w in zip(jax.tree.leaves(tree), jax.tree.leaves(weights)):
        bucket = order_gradient_bucket(g.reshape(-1), w.reshape(-1), window=64)
        assert bucket.values.shape[0] % 64 == 0          # padded to packets
        back = restore_gradient_bucket(bucket, g.size)
        assert back.dtype == g.dtype
        np.testing.assert_array_equal(np.asarray(back),
                                      np.asarray(g.reshape(-1)))


def test_ordered_bucket_window_none_full_sort():
    g = jax.random.normal(jax.random.PRNGKey(3), (100,), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (100,), jnp.float32)
    bucket = order_gradient_bucket(g, w, window=None)
    back = restore_gradient_bucket(bucket, 100)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(g))


def test_gradient_wire_report_reduces_bt_on_real_gradients(trained_grads):
    """O1 (weight-keyed, zero overhead) and O2 (self-keyed bound) must both
    beat the baseline phit stream on real gradients, with O2 >= O1."""
    params, grads = trained_grads
    rep = gradient_wire_report(grads, params, window=256, lanes=16)
    rep = {k: float(v) for k, v in rep.items()}
    assert rep["bt_baseline"] > 0
    assert rep["reduction_o1"] > 0
    assert rep["reduction_o2"] > 0
    assert rep["reduction_o2"] >= rep["reduction_o1"]
    assert rep["o2_index_bits"] == 8                      # log2(256)


def test_gradient_wire_report_is_jittable(trained_grads):
    params, grads = trained_grads
    rep = jax.jit(lambda g, p: gradient_wire_report(g, p, window=256))(
        grads, params)
    eager = gradient_wire_report(grads, params, window=256)
    assert float(rep["bt_baseline"]) == float(eager["bt_baseline"])


# ---------------------------------------------------------------------------
# static reorder stream report
# ---------------------------------------------------------------------------

def _scale_units(mlp, key):
    """Give hidden units a wide shuffled scale spread (what dead/saturated
    units in trained nets look like) so popcounts carry real structure."""
    f = mlp["wu"].shape[-1]
    s = 2.0 ** -jax.random.permutation(
        key, jnp.arange(f) % 12).astype(jnp.float32)
    out = dict(mlp)
    for name, sc in (("wu", s), ("wg", s), ("wd", s[:, None])):
        if name in mlp:
            out[name] = (mlp[name].astype(jnp.float32) * sc).astype(
                mlp[name].dtype)
    return out


def test_stream_bt_report_monotone():
    """Identity layout -> exactly zero reduction; popcount-descending layout
    on scale-structured weights -> strictly positive reduction."""
    cfg = LMConfig("t", n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                   vocab=256)
    m = LM(cfg)
    params = init_params(m.specs(), jax.random.PRNGKey(0))
    blocks = {}
    for i, (name, blk) in enumerate(sorted(params["blocks"].items())):
        blk = dict(blk)
        blk["mlp"] = _scale_units(blk["mlp"], jax.random.PRNGKey(100 + i))
        blocks[name] = blk
    params = dict(params, blocks=blocks)

    rep0 = stream_bt_report(params, params)
    assert float(rep0["reduction"]) == 0.0
    rep = stream_bt_report(params, reorder_lm_params(params))
    assert float(rep["bt_per_flit_after"]) < float(rep["bt_per_flit_before"])
    assert float(rep["reduction"]) > 0


# ---------------------------------------------------------------------------
# overlap bucketing invariants
# ---------------------------------------------------------------------------

def test_bucketed_respects_cap_except_oversized_singletons():
    tree = {"a": jnp.zeros((100, 100), jnp.float32),    # 40 kB > cap
            "b": jnp.zeros((1000,), jnp.float32),       # 4 kB
            "c": jnp.zeros((1000,), jnp.float32),
            "d": jnp.zeros((1000,), jnp.float32)}
    cap = 10_000
    buckets = bucketed(tree, max_bytes=cap)
    for b in buckets:
        nbytes = sum(x.size * jnp.dtype(x.dtype).itemsize for x in b)
        assert nbytes <= cap or len(b) == 1
    back = unbucket(buckets, tree)
    assert jax.tree.structure(back) == jax.tree.structure(tree)


def test_bucketed_rejects_nonpositive_cap():
    with pytest.raises(ValueError):
        bucketed({"a": jnp.zeros((4,))}, max_bytes=0)


def test_unbucket_rejects_leaf_mismatch():
    tree = {"a": jnp.zeros((4,)), "b": jnp.zeros((4,))}
    buckets = bucketed(tree, max_bytes=1 << 20)
    with pytest.raises(ValueError):
        unbucket(buckets[:0], tree)


# ---------------------------------------------------------------------------
# multi-device sharding (genuinely needs >= 2 devices; skipped on CI CPUs)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices for a non-trivial data axis")
def test_spec_shardings_distributes_batch_axis():
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    specs = {"w": ParamSpec((8 * n, 16), ("batch", "embed"))}
    sh = spec_shardings(specs, DEFAULT_RULES, mesh)
    arr = jax.jit(lambda: jnp.zeros((8 * n, 16), jnp.bfloat16),
                  out_shardings=sh["w"])()
    assert len(arr.sharding.device_set) == n
