"""Sharding rules: divisibility fallback, duplicate-axis guard, cache rules.

These run on a 1x1 host mesh plus rule-level checks against a fake mesh
shape - no 512-device requirement (that is the dry-run's job).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.sharding import (DEFAULT_RULES, logical_to_pspec,
                                 spec_shardings, data_axis_size)
from repro.models.spec import ParamSpec
from repro.configs import get, SHAPES
from repro.configs.common import cache_shardings


class FakeAxes(dict):
    pass


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """A Mesh over however many real devices exist is not constructible at
    16x16 here; tests that only read .shape/.axis_names use this stand-in."""
    class M:
        axis_names = axes
        def __init__(self):
            self.shape = dict(zip(axes, shape))
    return M()


def test_divisibility_fallback():
    mesh = fake_mesh()
    # 14 heads do not divide 16 -> replicated
    ps = logical_to_pspec(("embed", "heads", "head_dim"), (896, 14, 64),
                          DEFAULT_RULES, mesh)
    assert ps == P(None, None, None)
    # 48 heads divide -> sharded
    ps = logical_to_pspec(("embed", "heads", "head_dim"), (6144, 48, 128),
                          DEFAULT_RULES, mesh)
    assert ps == P(None, "model", None)


def test_duplicate_axis_guard():
    mesh = fake_mesh()
    rules = dict(DEFAULT_RULES, head_dim="model")
    ps = logical_to_pspec(("embed", "heads", "head_dim"), (5120, 32, 128),
                          rules, mesh)
    # heads grabs 'model' first; head_dim must NOT reuse it
    assert ps == P(None, "model", None)


def test_pod_expansion():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    ps = logical_to_pspec(("batch", "seq"), (256, 4096), DEFAULT_RULES, mesh)
    assert ps == P(("pod", "data"), None)
    assert data_axis_size(mesh) == 32


def test_batch_one_replicates():
    mesh = fake_mesh()
    ps = logical_to_pspec(("batch", "seq"), (1, 524288), DEFAULT_RULES, mesh)
    assert ps == P(None, None)


def test_kimi_rules_fsdp():
    arch = get("kimi-k2-1t-a32b")
    mesh = fake_mesh()
    # expert tensor: (layers, experts, embed, mlp)
    ps = logical_to_pspec(("layers", "experts", "embed", "mlp"),
                          (61, 384, 7168, 2048), arch.rules, mesh)
    assert ps == P(None, "model", "data", None)


def test_cache_shardings_structure():
    arch = get("h2o-danube-3-4b")
    model = arch.build_reduced()
    cache_abs = jax.eval_shape(lambda: model.init_cache(8, 64))
    mesh = fake_mesh()
    # should not raise, and KV leaves get batch on 'data'
    shards = cache_shardings(cache_abs, _RealishMesh(mesh))
    leaves = jax.tree.leaves(shards)
    assert len(leaves) == len(jax.tree.leaves(cache_abs))


class _RealishMesh:
    """cache_shardings only uses mesh for NamedSharding construction; wrap
    the 1-device host mesh but keep fake shape lookups for divisibility."""
    def __new__(cls, fake):
        n = len(jax.devices())
        real = jax.make_mesh((n, 1), ("data", "model"))
        return real


def test_spec_shardings_on_host_mesh():
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    specs = {"w": ParamSpec((32, 64), ("embed", "mlp"))}
    sh = spec_shardings(specs, DEFAULT_RULES, mesh)
    arr = jax.jit(lambda: jnp.zeros((32, 64), jnp.bfloat16),
                  out_shardings=sh["w"])()
    assert arr.shape == (32, 64)


@pytest.mark.parametrize("name", ["minicpm-2b", "kimi-k2-1t-a32b",
                                  "whisper-medium"])
def test_input_specs_shapes(name):
    arch = get(name)
    for shape_name, cell in SHAPES.items():
        ok, _ = arch.supports(shape_name)
        if not ok:
            continue
        ins = arch.input_specs(shape_name)
        if cell.mode == "decode":
            assert ins["token"].shape == (cell.global_batch,)
        elif arch.kind == "encdec":
            assert ins["frames"].shape[0] == cell.global_batch
        else:
            assert ins["tokens"].shape == (cell.global_batch, cell.seq_len)


def test_overlap_bucketing_roundtrip():
    """Gradient buckets must partition the tree and reassemble exactly."""
    import jax.numpy as jnp
    from repro.dist.overlap import bucketed, unbucket, xla_overlap_flags
    tree = {"a": jnp.ones((100, 100)), "b": {"c": jnp.zeros((50,)),
                                             "d": jnp.ones((200, 10))}}
    buckets = bucketed(tree, max_bytes=20000)
    assert sum(len(b) for b in buckets) == 3
    assert len(buckets) >= 2          # 40kB tensor alone exceeds the cap
    back = unbucket(buckets, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert bool(jnp.all(x == y))
    assert "--xla" in xla_overlap_flags()
