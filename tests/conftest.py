"""Make ``python -m pytest`` work without the PYTHONPATH=src incantation.

The tier-1 command (PYTHONPATH=src python -m pytest -x -q) keeps working:
prepending an already-importable path is a no-op.
"""
import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
