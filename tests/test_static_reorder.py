"""Static popcount-ordered weight layouts: exact function preservation.

The paper's Fig. 5 order-invariance argument, applied to stored layouts:
permuting MLP hidden units (up/gate columns + down rows) must leave model
outputs bit-identical.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM, LMConfig, init_params
from repro.dist.static_reorder import (reorder_lm_params, reorder_mlp,
                                       mlp_unit_permutation, stream_bt_report)
from repro.core.bits import popcount


def test_lm_outputs_bit_identical_gated_and_ungated():
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    for gated in (True, False):
        cfg = LMConfig("t", n_layers=4, d_model=64, n_heads=4, n_kv=2,
                       d_ff=128, vocab=256, gated_mlp=gated)
        m = LM(cfg)
        params = init_params(m.specs(), jax.random.PRNGKey(int(gated)))
        l0, _ = m.forward(params, toks)
        l1, _ = m.forward(reorder_lm_params(params), toks)
        assert bool(jnp.all(l0 == l1))


def test_permutation_sorts_column_popcounts():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    perm = mlp_unit_permutation(w)
    counts = jnp.sum(popcount(w), axis=0)[perm]
    assert bool(jnp.all(counts[:-1] >= counts[1:]))


def test_reorder_mlp_is_permutation():
    p = {"wu": jax.random.normal(jax.random.PRNGKey(0), (16, 32)),
         "wg": jax.random.normal(jax.random.PRNGKey(1), (16, 32)),
         "wd": jax.random.normal(jax.random.PRNGKey(2), (32, 16))}
    new, perm = reorder_mlp(p)
    assert sorted(np.asarray(perm).tolist()) == list(range(32))
    np.testing.assert_array_equal(np.asarray(new["wu"]),
                                  np.asarray(p["wu"])[:, np.asarray(perm)])
    np.testing.assert_array_equal(np.asarray(new["wd"]),
                                  np.asarray(p["wd"])[np.asarray(perm), :])


def test_stream_report_keys():
    cfg = LMConfig("t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_ff=128, vocab=256)
    m = LM(cfg)
    params = init_params(m.specs(), jax.random.PRNGKey(0))
    rep = stream_bt_report(params, reorder_lm_params(params))
    assert set(rep) == {"bt_per_flit_before", "bt_per_flit_after", "reduction"}
    assert rep["bt_per_flit_before"] > 0
