"""Property test (hypothesis): streamed packetization is bit-identical to
the one-shot path for *arbitrary* layer geometries and chunk sizes -
chunk=1, chunk > total, ragged final chunks, multi-layer mixes. The
deterministic parity suite is tests/test_noc_stream.py; this module only
holds the hypothesis half so importorskip can stay module-granular."""
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.core.wire import by_name
from repro.noc import NocConfig, build_traffic_batch, build_traffic_streamed
from repro.quant import quantize_fixed8

from test_noc_stream import _assert_traffic_equal, _layers

settings.register_profile("noc_stream", max_examples=25, deadline=None)
settings.load_profile("noc_stream")


@given(data=st.data(),
       chunk=st.integers(min_value=1, max_value=50),
       seed=st.integers(min_value=0, max_value=2**16))
def test_property_streamed_equals_oneshot(data, chunk, seed):
    """P: chunking is invisible - for any layer list and any chunk size the
    streamed Traffic equals the one-shot Traffic bit for bit."""
    sizes = data.draw(st.lists(
        st.tuples(st.integers(1, 40), st.integers(1, 24)),
        min_size=1, max_size=3))
    layers = _layers(sizes, seed=seed)
    cfg = NocConfig(2, 2, (0, 3), lanes=8)
    variants = [(by_name("O2", tiebreak="pattern"), None),
                (by_name("O1", tiebreak="stable"),
                 lambda t: quantize_fixed8(t).values)]
    ref = build_traffic_batch(layers, cfg, variants)
    got = build_traffic_streamed(layers, cfg, variants, chunk_packets=chunk)
    _assert_traffic_equal(ref, got)
