"""Property tests (hypothesis) for the fault-injection drain.

Three invariants of ``simulate_faulty`` for ARBITRARY small meshes,
packet counts, fault rates, and protection schemes:

* **Conservation under faults** - every packet ends in exactly one of
  delivered / dropped / retry-exhausted / unsent, and the ledger's
  breakdown sums to the injected count (the ISSUE-9 acceptance identity);
* **Null-model equivalence** - rate 0 + protect none + no hard faults
  reproduces ``simulate``'s total_bt and drain_cycle exactly;
* **Seeded replay** - the same (model, traffic) pair drains identically,
  ledger and per-packet status included.

Kept separate from tests/test_noc_faults.py so importorskip can stay
module-granular (mirrors tests/test_noc_online_properties.py).
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.wire import by_name  # noqa: E402
from repro.noc import (FaultModel, LayerTraffic, NocConfig,  # noqa: E402
                       build_traffic_batch, make_noc, simulate,
                       simulate_faulty, STATUS_DELIVERED, STATUS_DROPPED,
                       STATUS_RETRY_EXHAUSTED, STATUS_UNSENT)

CHUNK = 64

_MESHES = [
    NocConfig(rows=3, cols=3, mc_nodes=(0, 4), lanes=4),
    NocConfig(rows=3, cols=4, mc_nodes=(0, 11), num_vcs=3, lanes=4),
    make_noc(4, 4, num_mcs=4, lanes=4),
]

_STATUSES = {STATUS_DELIVERED, STATUS_DROPPED, STATUS_RETRY_EXHAUSTED,
             STATUS_UNSENT}


def _traffic(cfg, seed, npkts):
    key = jax.random.PRNGKey(seed)
    layer = LayerTraffic(
        jax.random.normal(key, (npkts, 6)),
        jax.random.normal(jax.random.fold_in(key, 1), (npkts, 6)) * 0.5)
    return build_traffic_batch([layer], cfg, [(by_name("O0"), None)]
                               ).variant(0)


@settings(max_examples=10, deadline=None)
@given(
    mesh=st.integers(min_value=0, max_value=len(_MESHES) - 1),
    npkts=st.integers(min_value=3, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    rate=st.sampled_from([0.0, 1e-3, 2e-2, 1e-1]),
    protect=st.sampled_from(["none", "parity", "crc8"]),
    retries=st.integers(min_value=0, max_value=3),
)
def test_conservation_under_faults(mesh, npkts, seed, rate, protect,
                                   retries):
    cfg = _MESHES[mesh]
    traffic = _traffic(cfg, seed % 7, npkts)
    model = FaultModel(rate=rate, seed=seed, protect=protect,
                       max_retries=retries)
    fd = simulate_faulty(cfg, traffic, model, chunk=CHUNK)
    led = fd.ledger
    assert led["conservation_ok"]
    assert (led["delivered"] + led["dropped"] + led["retry_exhausted"]
            + led["unsent"] == led["injected_packets"] == npkts)
    assert set(np.unique(fd.status)) <= _STATUSES
    # Retries only happen for detected corruption, within budget.
    assert int(fd.retries.max(initial=0)) <= retries
    if protect == "none":
        # Nothing is ever detected, so nothing retries or exhausts.
        assert led["retry_exhausted"] == 0 and led["total_retries"] == 0
    assert led["transmitted_flits"] >= int(np.asarray(traffic.length).sum())


@settings(max_examples=10, deadline=None)
@given(
    mesh=st.integers(min_value=0, max_value=len(_MESHES) - 1),
    npkts=st.integers(min_value=3, max_value=16),
    seed=st.integers(min_value=0, max_value=10),
)
def test_null_model_matches_simulate(mesh, npkts, seed):
    cfg = _MESHES[mesh]
    traffic = _traffic(cfg, seed, npkts)
    clean = simulate(cfg, traffic, chunk=CHUNK)
    fd = simulate_faulty(cfg, traffic, FaultModel(seed=seed), chunk=CHUNK)
    assert fd.sim.total_bt == clean.total_bt
    assert fd.sim.drain_cycle == clean.drain_cycle
    np.testing.assert_array_equal(np.asarray(fd.sim.link_bt),
                                  np.asarray(clean.link_bt))


@settings(max_examples=8, deadline=None)
@given(
    mesh=st.integers(min_value=0, max_value=len(_MESHES) - 1),
    npkts=st.integers(min_value=3, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    rate=st.sampled_from([1e-2, 1e-1]),
)
def test_seeded_replay(mesh, npkts, seed, rate):
    cfg = _MESHES[mesh]
    traffic = _traffic(cfg, seed % 5, npkts)
    model = FaultModel(rate=rate, seed=seed, protect="crc8")
    a = simulate_faulty(cfg, traffic, model, chunk=CHUNK)
    b = simulate_faulty(cfg, traffic, model, chunk=CHUNK)
    assert a.ledger == b.ledger
    assert a.sim.total_bt == b.sim.total_bt
    np.testing.assert_array_equal(a.status, b.status)
    np.testing.assert_array_equal(np.asarray(a.sim.link_bt),
                                  np.asarray(b.sim.link_bt))
