"""Result-phase (PE->MC) and packet->MC-affinity regression suite.

Pins the bidirectional-sweep contract added in PR 5: result traffic
conserves every packet (positive and negative), the affinity knob is
bit-identical to round-robin when disabled, `nearest` strictly lowers the
static mean hop count while conserving flit volume, and `run_sweep`
surfaces both axes as row columns.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.wire import by_name
from repro.noc import (LayerTraffic, NocConfig, SweepGrid, affinity_mc_table,
                       build_result_traffic, build_traffic_batch,
                       layer_results, make_noc, packet_mean_hops, run_sweep,
                       simulate, simulate_batch)
from repro.quant import quantize_fixed8

CHUNK = 256


@pytest.fixture(scope="module")
def layers():
    """Deterministic two-layer workload (uneven packet counts and operand
    widths exercise ragged result windows)."""
    key = jax.random.PRNGKey(2)
    return [
        LayerTraffic(jax.random.normal(key, (37, 20)),
                     jax.random.normal(jax.random.fold_in(key, 1),
                                       (37, 20)) * 0.3),
        LayerTraffic(jax.random.normal(jax.random.fold_in(key, 2), (11, 9)),
                     jax.random.normal(jax.random.fold_in(key, 3), (11, 9))),
    ]


@pytest.fixture(scope="module")
def cfg():
    return NocConfig(rows=4, cols=4, mc_nodes=(0, 15), num_vcs=3, lanes=8)


VARIANTS = [(by_name(o), q) for o in ("O0", "O1", "O2")
            for q in (None, lambda t: quantize_fixed8(t).values)]


def test_result_conservation_positive(layers, cfg):
    """Every result packet injected at a PE ejects exactly once at its MC,
    under every ordering/precision variant (batched and single drains)."""
    rt = build_result_traffic(layers, cfg, VARIANTS, result_window=6)
    assert rt.num_packets > 0
    pe_rows = np.broadcast_to(np.asarray(cfg.pe_nodes, np.int32),
                              (len(VARIANTS), len(cfg.pe_nodes))).copy()
    batch = simulate_batch(cfg, rt, chunk=CHUNK, check_conservation=True,
                           mc_nodes=pe_rows)
    single = simulate(cfg, rt.variant(0), chunk=CHUNK,
                      check_conservation=True,
                      mc_nodes=np.asarray(cfg.pe_nodes))
    assert single.total_bt == batch[0].total_bt
    assert single.drain_cycle == batch[0].drain_cycle
    for r in batch:
        assert r.ejected == r.injected > 0


def test_result_conservation_negative(layers, cfg):
    """Corrupted result packet ids must trip the ledger: collapsing them to
    zero makes id 0 look multiply injected."""
    rt = build_result_traffic(layers, cfg, [(by_name("O0"), None)],
                              result_window=6).variant(0)
    bad = rt._replace(pkt=jnp.zeros_like(rt.pkt))
    with pytest.raises(RuntimeError, match="conservation"):
        simulate(cfg, bad, chunk=CHUNK, check_conservation=True,
                 mc_nodes=np.asarray(cfg.pe_nodes))


def test_result_value_volume(layers, cfg):
    """The result phase carries exactly one value per request packet: total
    payload capacity across result packets covers every neuron, and header
    word 2 of each packet states its payload flit count."""
    rt = build_result_traffic(layers, cfg, [(by_name("O0"), None)],
                              result_window=6)
    n_neurons = sum(int(l.inputs.shape[0]) for l in layers)
    meta = np.asarray(rt.meta[0])
    length = np.asarray(rt.length[0])
    valid = np.arange(meta.shape[1])[None, :] < length[:, None]
    headers = valid & (meta == 0)
    payloads = valid & ((meta & 1) > 0)
    assert headers.sum() == rt.num_packets
    # every payload flit carries <= lanes values, at least one is real
    assert payloads.sum() * cfg.lanes >= n_neurons
    assert payloads.sum() <= rt.num_packets * (6 // cfg.lanes + 1)


def test_result_dest_follows_affinity(layers, cfg):
    """Under `nearest` affinity every result packet ejects at the MC its
    source PE is affined to - request and result phases traverse the same
    MC<->PE pairs in opposite directions."""
    tbl = affinity_mc_table(cfg)
    rt = build_result_traffic(layers, cfg, [(by_name("O0"), None)],
                              mc_table=tbl)
    dest = np.asarray(rt.dest[0])
    length = np.asarray(rt.length[0])
    mcs = np.asarray(cfg.mc_nodes)
    for s in range(len(cfg.pe_nodes)):
        if length[s]:
            want = mcs[tbl[s]]
            assert np.all(dest[s, :length[s]] == want)


def test_affinity_disabled_is_bit_identical(layers, cfg):
    """The affinity knob off (`roundrobin`) must be a no-op: Traffic equals
    the table-free build field by field."""
    m = cfg.num_mcs
    rr = build_traffic_batch(layers, cfg, VARIANTS[:2])
    via_table = build_traffic_batch(layers, cfg, VARIANTS[:2],
                                    mc_table=np.arange(m))
    for name in ("words", "dest", "meta", "vc", "pkt", "length"):
        assert np.array_equal(np.asarray(getattr(rr, name)),
                              np.asarray(getattr(via_table, name))), name


def test_affinity_lowers_hops_conserves_volume(layers):
    """`nearest` strictly lowers the static mean hop count on an 8x8/MC4
    mesh and redistributes - never creates or drops - flits."""
    cfg = make_noc(8, 8, 4, lanes=8)
    tbl = affinity_mc_table(cfg)
    n = sum(int(l.inputs.shape[0]) for l in layers)
    assert packet_mean_hops(cfg, n, tbl) < packet_mean_hops(cfg, n)
    rr = build_traffic_batch(layers, cfg, VARIANTS[:1])
    aff = build_traffic_batch(layers, cfg, VARIANTS[:1], mc_table=tbl)
    assert int(np.asarray(rr.length).sum()) == int(np.asarray(aff.length).sum())
    res = simulate_batch(cfg, aff, chunk=CHUNK, check_conservation=True)
    assert res[0].ejected == res[0].injected > 0


def test_affinity_streamed_matches_oneshot(layers, cfg):
    """The affinity schedule stays elementwise in the global packet id, so
    chunked streaming under a mc_table must be bit-identical to the
    one-shot build - including ragged final chunks."""
    from repro.noc.traffic import build_traffic_streamed
    tbl = affinity_mc_table(cfg)
    oneshot = build_traffic_batch(layers, cfg, VARIANTS[:2], mc_table=tbl)
    for chunk in (1, 5, 4096):
        streamed = build_traffic_streamed(layers, cfg, VARIANTS[:2],
                                          chunk_packets=chunk, mc_table=tbl)
        for name in ("words", "dest", "meta", "vc", "pkt", "length"):
            assert np.array_equal(np.asarray(getattr(oneshot, name)),
                                  np.asarray(getattr(streamed, name))), \
                (name, chunk)


def test_affinity_table_shape_and_range():
    cfg = make_noc(8, 8, 8)
    tbl = affinity_mc_table(cfg)
    assert tbl.shape == (len(cfg.pe_nodes),)
    assert tbl.min() >= 0 and tbl.max() < cfg.num_mcs
    # deterministic
    assert np.array_equal(tbl, affinity_mc_table(cfg))


def test_sweep_affinity_and_result_rows(layers):
    """End-to-end: both new axes through run_sweep. Round-robin rows match
    a grid without the axis bit for bit; nearest rows lower mean_hops; the
    result phase populates per-direction columns for every cell."""
    kw = dict(meshes=("4x4_mc2",), transforms=("O0", "O1"),
              precisions=("fixed8",), models=("toy",),
              max_packets_per_layer=20, chunk=CHUNK)
    plain = run_sweep(SweepGrid(**kw), lambda _n: layers)
    both = run_sweep(SweepGrid(affinity=("roundrobin", "nearest"),
                               result_phase=True, result_window=6, **kw),
                     lambda _n: layers, check_conservation=True)
    assert both.stats["cells"] == 2 * plain.stats["cells"]
    assert both.stats["result_phase"] is True
    for row in plain.rows:
        rr = both.row(affinity="roundrobin", transform=row["transform"])
        near = both.row(affinity="nearest", transform=row["transform"])
        for k in ("total_bt", "cycles", "flits", "adjusted_bt"):
            assert rr[k] == row[k], k
        assert near["mean_hops"] < rr["mean_hops"]
        assert near["flits"] == rr["flits"]
        for r in (rr, near):
            assert r["result_bt"] > 0
            assert 0 < r["result_cycles"]
            assert r["result_flits"] > 0
    # request-phase columns of the result-enabled sweep stay untouched
    for row in plain.rows:
        assert row["result_bt"] is None and row["result_cycles"] is None


def test_sweep_grid_affinity_validation():
    with pytest.raises(ValueError, match="affinity"):
        SweepGrid(affinity=("diagonal",))
    with pytest.raises(ValueError, match="affinity"):
        SweepGrid(affinity=())
