"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (the FULL
configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get
from repro.models.spec import init_params
from repro.optim import AdamW, constant
from repro.train import make_train_step, init_state

ARCH_NAMES = sorted(ARCHS)


def _batch_for(arch, model, b=2, s=16):
    cfg = model.cfg
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    tgt = jnp.roll(toks, -1, axis=1)
    mask = jnp.ones((b, s), jnp.float32)
    if arch.kind == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model),
                                   jnp.float32).astype(jnp.bfloat16)
        return {"frames": frames, "tokens": toks, "targets": tgt, "mask": mask}
    out = {"tokens": toks, "targets": tgt, "mask": mask}
    if getattr(cfg, "vlm_prefix", 0):
        out["patch_embeds"] = jnp.ones((b, cfg.vlm_prefix, cfg.d_model),
                                       jnp.bfloat16)
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_forward_and_train_step(name):
    arch = get(name)
    model = arch.build_reduced()
    cfg = model.cfg
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    batch = _batch_for(arch, model)

    if arch.kind == "encdec":
        logits = model.forward(params, batch["frames"], batch["tokens"])
        assert logits.shape == (2, 16, cfg.padded_vocab)
        def loss_fn(p, b):
            return model.loss(p, b["frames"], b["tokens"], b["targets"], b["mask"])
    else:
        logits, _ = model.forward(params, batch["tokens"],
                                  batch.get("patch_embeds"))
        prefix = getattr(cfg, "vlm_prefix", 0)
        assert logits.shape == (2, 16 + prefix, cfg.padded_vocab)
        def loss_fn(p, b):
            return model.loss(p, b["tokens"], b["targets"], b["mask"],
                              b.get("patch_embeds"))
    assert not bool(jnp.any(jnp.isnan(logits)))

    opt = AdamW(constant(1e-3), state_dtype=arch.optimizer_state)
    step = jax.jit(make_train_step(loss_fn, opt))
    state = init_state(params, opt)
    state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    # params actually changed
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(state.params), jax.tree.leaves(params)))
    assert moved


@pytest.mark.parametrize("name", [n for n in ARCH_NAMES
                                  if ARCHS[n].kind == "lm"])
def test_reduced_decode_matches_vocab(name):
    arch = get(name)
    model = arch.build_reduced()
    cfg = model.cfg
    if getattr(cfg, "vlm_prefix", 0):
        pytest.skip("decode exercised without vision prefix elsewhere")
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits, cache = model.prefill(params, toks, context=32)
    assert logits.shape == (b, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1)
    # padded-vocab logits are masked: argmax stays within the true vocab
    assert int(tok.max()) < cfg.vocab
    logits2, cache = model.decode_step(params, tok, cache,
                                       jnp.full((b,), s, jnp.int32))
    assert logits2.shape == (b, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits2)))


def test_encdec_decode_step():
    arch = get("whisper-medium")
    model = arch.build_reduced()
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(2), (2, 12, model.cfg.d_model))
    mem = model.encode(params, frames)
    # both cache flavors: precomputed cross k/v (production) and legacy
    lg_pre = lg_legacy = None
    for params_arg in (params, None):
        cache = model.init_cache(2, 16, mem, params_arg)
        lg, cache = model.decode_step(params, jnp.zeros((2,), jnp.int32),
                                      cache, jnp.zeros((2,), jnp.int32))
        assert lg.shape == (2, model.cfg.padded_vocab)
        assert not bool(jnp.any(jnp.isnan(lg)))
        if params_arg is not None:
            lg_pre = lg
        else:
            lg_legacy = lg
    # same math either way (bf16 rounding of the cached k/v only)
    assert float(jnp.max(jnp.abs(lg_pre - lg_legacy))) < 0.25
    assert int(jnp.argmax(lg_pre)) == int(jnp.argmax(lg_legacy))


def test_swa_prefill_ring_cache_consistency():
    """Decoding right after an SWA prefill must attend the same window a
    full forward sees: compare next-token logits against a one-longer
    forward pass."""
    arch = get("h2o-danube-3-4b")
    model = arch.build_reduced()
    cfg = model.cfg
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    b, s = 1, 24                      # window is 16 in the reduced config
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s + 1), 0, cfg.vocab)
    logits, cache = model.prefill(params, toks[:, :s], context=s)
    step_logits, _ = model.decode_step(params, toks[:, s], cache,
                                       jnp.full((b,), s, jnp.int32))
    full, _ = model.forward(params, toks)
    ref = full[:, s]
    # bf16 matmuls; compare top-1 and correlation rather than exact values
    assert int(jnp.argmax(step_logits)) == int(jnp.argmax(ref))


def test_long_context_shape_policy():
    sub_q = {n: get(n).supports("long_500k")[0] for n in ARCH_NAMES}
    assert sub_q["h2o-danube-3-4b"] and sub_q["mixtral-8x7b"]
    assert sub_q["recurrentgemma-9b"] and sub_q["xlstm-125m"]
    assert not sub_q["minicpm-2b"] and not sub_q["kimi-k2-1t-a32b"]
    assert not sub_q["whisper-medium"]


def test_assigned_full_configs_match_table():
    """The exact assigned hyperparameters (guards against config drift)."""
    c = get("kimi-k2-1t-a32b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv) == (61, 7168, 64, 8)
    assert (c.n_experts, c.top_k, c.vocab) == (384, 8, 163840)
    c = get("recurrentgemma-9b").config
    assert (c.n_layers, c.d_model, c.pattern) == (38, 4096, ("rec", "rec", "attn"))
    c = get("mixtral-8x7b").config
    assert (c.n_experts, c.top_k, c.window) == (8, 2, 4096)
    c = get("xlstm-125m").config
    assert (c.d_ff, c.pattern) == (0, ("mlstm", "slstm"))
    c = get("whisper-medium").config
    assert (c.n_layers, c.d_model, c.vocab) == (24, 1024, 51865)
