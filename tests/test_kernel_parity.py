"""Kernel-vs-reference parity beyond the seed sweeps: every Pallas path
(popcount, bt_count, bitonic_sort, chain-select, and the router step -
interpret mode on CPU) against the repro.kernels.ref / numpy oracles
across wire dtypes (fp32, bf16, int8) and odd, padding-exercising shapes.
Pins the kernel semantics the compiled Mosaic path inherits on TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bits import popcount as popcount_bits, unsigned_view
from repro.core.wire import by_name
from repro.data import glyph_batch
from repro.kernels import bt_boundaries, popcount, sort_windows_desc
from repro.kernels.min_hamming import (chain_select_pallas,
                                       min_hamming_chain,
                                       min_hamming_chain_reference)
from repro.kernels.ref import (bt_boundaries_ref, popcount_ref,
                               sort_windows_desc_ref)
from repro.models import LeNet, init_params
from repro.noc import PAPER_NOCS, mesh_by_name
from repro.noc._reference import simulate_unfused
from repro.noc.sim import simulate, simulate_batch
from repro.noc.traffic import build_traffic, stack_traffics
from repro.quant import quantize_fixed8

WIRE_DTYPES = ["float32", "bfloat16", "int8"]


def _rand(key, shape, dtype):
    if dtype == "float32":
        return jax.random.normal(key, shape, jnp.float32)
    if dtype == "bfloat16":
        return jax.random.normal(key, shape, jnp.float32).astype(jnp.bfloat16)
    if dtype == "int8":
        return jax.random.randint(key, shape, -128, 128,
                                  jnp.int32).astype(jnp.int8)
    raise ValueError(dtype)


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
@pytest.mark.parametrize("shape", [(1,), (127,), (129,), (8, 128 + 1),
                                   (2, 3, 5, 7)])
def test_popcount_parity_odd_shapes(shape, dtype):
    """Sizes straddling the (8, 128) tile contract: padding must never leak
    into results, for every wire dtype."""
    x = _rand(jax.random.PRNGKey(sum(shape) * 31 + len(dtype)), shape, dtype)
    np.testing.assert_array_equal(np.asarray(popcount(x)),
                                  np.asarray(popcount_ref(x)))


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
def test_popcount_parity_extremes(dtype):
    """All-zeros and all-ones bit patterns, the popcount range endpoints."""
    zeros = jnp.zeros((9,), jnp.int32).astype(jnp.dtype(dtype))
    assert bool(jnp.all(popcount(zeros) == 0))
    width = jnp.dtype(unsigned_view(zeros).dtype).itemsize * 8
    all_ones = jnp.zeros((9,), unsigned_view(zeros).dtype) - 1
    assert bool(jnp.all(popcount(all_ones) == width))


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
@pytest.mark.parametrize("nf,lanes", [(2, 1), (3, 16), (17, 16), (9, 130)])
def test_bt_boundaries_parity_wire_dtypes(nf, lanes, dtype):
    """The BT recorder on flit streams of every wire dtype, including lane
    counts off the 128 tile and single-lane links."""
    w = _rand(jax.random.PRNGKey(nf * 1000 + lanes), (nf, lanes), dtype)
    a, b = bt_boundaries(w), bt_boundaries_ref(unsigned_view(w))
    assert a.shape == (nf - 1,)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bt_boundaries_single_flit_stream():
    """A one-flit stream has no boundaries - the empty-output path."""
    w = jnp.ones((1, 16), jnp.uint32)
    assert bt_boundaries(w).shape == (0,)


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
@pytest.mark.parametrize("rows,w", [(1, 128), (3, 128), (7, 256), (9, 512)])
def test_bitonic_sort_payload_dtypes(rows, w, dtype):
    """Windowed descending sort with payloads in each wire dtype, at row
    counts that force the ROW_TILE padding path. Keys are real popcounts
    (heavy ties); bitonic nets are unstable, so parity is: exact key
    sequences + per-row (key, payload-bits) multisets."""
    key = jax.random.PRNGKey(rows * w + len(dtype))
    payload = _rand(key, (rows, w), dtype)
    keys = popcount_bits(payload)
    sk, sp = sort_windows_desc(keys, payload)
    rk, rp = sort_windows_desc_ref(keys, payload)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
    assert sp.dtype == payload.dtype
    assert bool(jnp.all(sk[:, :-1] >= sk[:, 1:]))
    sp_bits = np.asarray(unsigned_view(sp))
    in_bits = np.asarray(unsigned_view(payload))
    ks = np.asarray(keys)
    for i in range(rows):
        got = sorted(zip(np.asarray(sk[i]).tolist(), sp_bits[i].tolist()))
        want = sorted(zip(ks[i].tolist(), in_bits[i].tolist()))
        assert got == want


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
def test_bitonic_sort_matches_descending_perm_semantics(dtype):
    """The kernel's (key-sorted) output stream must produce the same BT as
    the pure-jnp ordering path - the property later perf work relies on
    when swapping one for the other."""
    from repro.core.ordering import descending_order
    vals = _rand(jax.random.PRNGKey(5), (4, 128), dtype)
    keys = popcount_bits(vals)
    sk, sv = sort_windows_desc(keys, vals)
    ordered = descending_order(vals.reshape(-1), window=128)
    np.testing.assert_array_equal(
        np.asarray(popcount_bits(sv)).reshape(-1),
        np.asarray(popcount_bits(ordered.values)))


# ---------------------------------------------------------------------------
# Router-step Pallas kernel (interpret mode on CPU)
# ---------------------------------------------------------------------------

# The pinned 36-cell equivalence grid (matches benchmarks/fig12.PINNED):
# 3 paper meshes x 2 precisions x 2 tiebreaks x 3 orderings, 8 packets.
PINNED_MESHES = tuple(PAPER_NOCS)
PINNED_CELLS = [(prec, tb, o)
                for prec in ("float32", "fixed8")
                for tb in ("stable", "pattern")
                for o in ("O0", "O1", "O2")]
MAX_PACKETS = 8
CHUNK = 128


@pytest.fixture(scope="module")
def pinned_layers():
    model = LeNet()
    params = init_params(model.specs(), jax.random.PRNGKey(1))
    x, _ = glyph_batch(jax.random.PRNGKey(7), 1)
    return model.layer_traffic(params, x[0])


def _quant(name):
    return None if name == "float32" else (lambda t: quantize_fixed8(t).values)


def _pinned_traffics(layers, cfg):
    return [build_traffic(layers, cfg, by_name(o, tiebreak=tb),
                          quantizer=_quant(prec),
                          max_packets_per_layer=MAX_PACKETS)
            for prec, tb, o in PINNED_CELLS]


@pytest.mark.parametrize("mesh", PINNED_MESHES)
def test_router_kernel_matches_fused_36_cells(pinned_layers, mesh):
    """Interpret-mode Pallas == fused step on every pinned cell: total_bt,
    link_bt, and the exact drain_cycle. One batched drain per mesh covers
    the 12 cells AND the vmap-over-pallas_call batching rule."""
    cfg = mesh_by_name(mesh)
    batch = stack_traffics(_pinned_traffics(pinned_layers, cfg))
    fused = simulate_batch(cfg, batch, chunk=CHUNK, backend="fused")
    pallas = simulate_batch(cfg, batch, chunk=CHUNK, backend="pallas")
    for cell, f, p in zip(PINNED_CELLS, fused, pallas):
        assert p.total_bt == f.total_bt, (mesh, cell)
        assert p.drain_cycle == f.drain_cycle, (mesh, cell)
        assert p.ejected == f.ejected == p.injected, (mesh, cell)
        assert np.array_equal(p.link_bt, f.link_bt), (mesh, cell)
        assert np.array_equal(p.inj_bt, f.inj_bt), (mesh, cell)


def test_router_kernel_matches_frozen_reference(pinned_layers):
    """Single-sim Pallas drains == the frozen PR-3 reference step on the
    4x4 mesh's 12 pinned cells (fused == reference on all 36 is pinned in
    tests/test_noc_step.py, closing the three-way equality)."""
    cfg = mesh_by_name(PINNED_MESHES[0])
    for cell, tr in zip(PINNED_CELLS, _pinned_traffics(pinned_layers, cfg)):
        ref = simulate_unfused(cfg, tr, chunk=CHUNK)
        new = simulate(cfg, tr, chunk=CHUNK, backend="pallas")
        assert new.total_bt == ref.total_bt, cell
        assert new.drain_cycle == ref.drain_cycle, cell
        assert np.array_equal(new.link_bt, ref.link_bt), cell


def test_router_backend_validation():
    cfg = mesh_by_name("2x2_mc1")
    tr = build_traffic([], cfg, by_name("O0"))
    with pytest.raises(ValueError, match="backend"):
        simulate(cfg, tr, backend="mosaic2000")


# ---------------------------------------------------------------------------
# Batched O3 chain and its Pallas select body
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,w,zfrac", [(1, 1, 0.0), (3, 5, 0.3), (5, 17, 0.5),
                                       (4, 40, 0.2), (8, 2, 0.9)])
def test_batched_chain_matches_reference_oracle(r, w, zfrac):
    """The batched beam-select chain (argmin select, one scan over all
    windows) == the per-window numpy oracle, perm/cost/nonzeros exact."""
    rng = np.random.default_rng(r * 100 + w)
    u = rng.integers(0, 2**32, (r, w), dtype=np.uint32)
    u[rng.random((r, w)) < zfrac] = 0
    res = min_hamming_chain(u)
    perm, cost, z = min_hamming_chain_reference(u)
    np.testing.assert_array_equal(np.asarray(res.perm), perm)
    np.testing.assert_array_equal(np.asarray(res.cost), cost)
    np.testing.assert_array_equal(np.asarray(res.nonzeros), z)


def test_batched_chain_affiliated_matches_reference_oracle():
    """Two-plane (affiliated O3a) chains on the summed pair distance."""
    rng = np.random.default_rng(9)
    a = rng.integers(0, 2**32, (4, 9), dtype=np.uint32)
    b = rng.integers(0, 2**32, (4, 9), dtype=np.uint32)
    a[rng.random((4, 9)) < 0.3] = 0
    res = min_hamming_chain([a, b])
    perm, cost, z = min_hamming_chain_reference([a, b])
    np.testing.assert_array_equal(np.asarray(res.perm), perm)
    np.testing.assert_array_equal(np.asarray(res.cost), cost)
    np.testing.assert_array_equal(np.asarray(res.nonzeros), z)


@pytest.mark.parametrize("r,w,planes", [(1, 4, 1), (3, 17, 1), (5, 130, 2),
                                        (8, 128, 1), (2, 300, 2)])
def test_chain_select_pallas_matches_key_argsort(r, w, planes):
    """The Pallas distance+select body == stable argsort of the chain's
    selection key (keys embed the lane index, so they are pairwise
    distinct and stability is vacuous - any correct sort agrees)."""
    rng = np.random.default_rng(r * 1000 + w + planes)
    xors = [rng.integers(0, 2**32, (r, w), dtype=np.uint32)
            for _ in range(planes)]
    penalty = rng.choice(
        np.array([0, 1 << 28, 1 << 30], np.int32), (r, w)).astype(np.int32)
    dvec, order = chain_select_pallas(
        [jnp.asarray(x) for x in xors], jnp.asarray(penalty))
    want_d = sum(np.unpackbits(x.view(np.uint8), axis=-1)
                 .reshape(r, w, 32).sum(-1).astype(np.int32) for x in xors)
    np.testing.assert_array_equal(np.asarray(dvec), want_d)
    key = want_d * np.int32(w) + np.arange(w, dtype=np.int32) + penalty
    np.testing.assert_array_equal(np.asarray(order),
                                  np.argsort(key, axis=-1, kind="stable"))
