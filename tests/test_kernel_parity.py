"""Kernel-vs-reference parity beyond the seed sweeps: every Pallas path
(popcount, bt_count, bitonic_sort - interpret mode on CPU) against the
repro.kernels.ref oracles across wire dtypes (fp32, bf16, int8) and odd,
padding-exercising shapes. Pins the kernel semantics before later perf
work swaps interpret mode for compiled Mosaic on TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bits import popcount as popcount_bits, unsigned_view
from repro.kernels import bt_boundaries, popcount, sort_windows_desc
from repro.kernels.ref import (bt_boundaries_ref, popcount_ref,
                               sort_windows_desc_ref)

WIRE_DTYPES = ["float32", "bfloat16", "int8"]


def _rand(key, shape, dtype):
    if dtype == "float32":
        return jax.random.normal(key, shape, jnp.float32)
    if dtype == "bfloat16":
        return jax.random.normal(key, shape, jnp.float32).astype(jnp.bfloat16)
    if dtype == "int8":
        return jax.random.randint(key, shape, -128, 128,
                                  jnp.int32).astype(jnp.int8)
    raise ValueError(dtype)


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
@pytest.mark.parametrize("shape", [(1,), (127,), (129,), (8, 128 + 1),
                                   (2, 3, 5, 7)])
def test_popcount_parity_odd_shapes(shape, dtype):
    """Sizes straddling the (8, 128) tile contract: padding must never leak
    into results, for every wire dtype."""
    x = _rand(jax.random.PRNGKey(sum(shape) * 31 + len(dtype)), shape, dtype)
    np.testing.assert_array_equal(np.asarray(popcount(x)),
                                  np.asarray(popcount_ref(x)))


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
def test_popcount_parity_extremes(dtype):
    """All-zeros and all-ones bit patterns, the popcount range endpoints."""
    zeros = jnp.zeros((9,), jnp.int32).astype(jnp.dtype(dtype))
    assert bool(jnp.all(popcount(zeros) == 0))
    width = jnp.dtype(unsigned_view(zeros).dtype).itemsize * 8
    all_ones = jnp.zeros((9,), unsigned_view(zeros).dtype) - 1
    assert bool(jnp.all(popcount(all_ones) == width))


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
@pytest.mark.parametrize("nf,lanes", [(2, 1), (3, 16), (17, 16), (9, 130)])
def test_bt_boundaries_parity_wire_dtypes(nf, lanes, dtype):
    """The BT recorder on flit streams of every wire dtype, including lane
    counts off the 128 tile and single-lane links."""
    w = _rand(jax.random.PRNGKey(nf * 1000 + lanes), (nf, lanes), dtype)
    a, b = bt_boundaries(w), bt_boundaries_ref(unsigned_view(w))
    assert a.shape == (nf - 1,)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bt_boundaries_single_flit_stream():
    """A one-flit stream has no boundaries - the empty-output path."""
    w = jnp.ones((1, 16), jnp.uint32)
    assert bt_boundaries(w).shape == (0,)


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
@pytest.mark.parametrize("rows,w", [(1, 128), (3, 128), (7, 256), (9, 512)])
def test_bitonic_sort_payload_dtypes(rows, w, dtype):
    """Windowed descending sort with payloads in each wire dtype, at row
    counts that force the ROW_TILE padding path. Keys are real popcounts
    (heavy ties); bitonic nets are unstable, so parity is: exact key
    sequences + per-row (key, payload-bits) multisets."""
    key = jax.random.PRNGKey(rows * w + len(dtype))
    payload = _rand(key, (rows, w), dtype)
    keys = popcount_bits(payload)
    sk, sp = sort_windows_desc(keys, payload)
    rk, rp = sort_windows_desc_ref(keys, payload)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
    assert sp.dtype == payload.dtype
    assert bool(jnp.all(sk[:, :-1] >= sk[:, 1:]))
    sp_bits = np.asarray(unsigned_view(sp))
    in_bits = np.asarray(unsigned_view(payload))
    ks = np.asarray(keys)
    for i in range(rows):
        got = sorted(zip(np.asarray(sk[i]).tolist(), sp_bits[i].tolist()))
        want = sorted(zip(ks[i].tolist(), in_bits[i].tolist()))
        assert got == want


@pytest.mark.parametrize("dtype", WIRE_DTYPES)
def test_bitonic_sort_matches_descending_perm_semantics(dtype):
    """The kernel's (key-sorted) output stream must produce the same BT as
    the pure-jnp ordering path - the property later perf work relies on
    when swapping one for the other."""
    from repro.core.ordering import descending_order
    vals = _rand(jax.random.PRNGKey(5), (4, 128), dtype)
    keys = popcount_bits(vals)
    sk, sv = sort_windows_desc(keys, vals)
    ordered = descending_order(vals.reshape(-1), window=128)
    np.testing.assert_array_equal(
        np.asarray(popcount_bits(sv)).reshape(-1),
        np.asarray(popcount_bits(ordered.values)))
