"""Fault-injection NoC: the bit-identity pin and the protection contract.

Pins the ISSUE-9 guarantees of ``repro.noc.faults``:

* **Zero-fault bit-identity** - a null ``FaultModel`` drains through
  ``simulate_faulty`` with total_bt/link_bt/drain_cycle identical to both
  ``simulate`` and the frozen seed driver (``noc._reference``) on the
  pinned 36-cell grid;
* **Protection correctness** - the syndrome-mask codes equal the bitwise
  CRC-8 reference; ``protect_wire`` stamps codes a clean ejection always
  verifies; CRC-8 detects the injected flips of the pinned schedule while
  ``protect=none`` ships them silently;
* **Hard faults** - a dead mid-mesh link detour-delivers everything, a
  dead router's packets are reported dropped (never silently lost), and
  the conservation ledger closes either way;
* **Seeded replay** - one (rate, seed) pair replays the identical drain;
* the drain watchdog (``DrainTimeout`` diagnostics), the explicit
  ``backend="pallas"`` + ``check_conservation`` contradiction, and the
  serving-layer deadline/admission degradation knobs.
"""
import jax
import numpy as np
import pytest

from repro.core.wire import (by_name, crc8_reference,
                             protection_syndrome_masks)
from repro.noc import (ArrivalProcess, DrainTimeout, FaultModel,
                       LayerTraffic, build_result_traffic,
                       build_traffic_batch, make_noc, protect_wire,
                       simulate, simulate_faulty, simulate_online,
                       STATUS_DELIVERED, STATUS_DROPPED)
from repro.noc._reference import simulate_reference

CHUNK = 256


@pytest.fixture(scope="module")
def layers():
    key = jax.random.PRNGKey(3)
    return [
        LayerTraffic(jax.random.normal(key, (24, 12)),
                     jax.random.normal(jax.random.fold_in(key, 1),
                                       (24, 12)) * 0.4),
        LayerTraffic(jax.random.normal(jax.random.fold_in(key, 2), (10, 8)),
                     jax.random.normal(jax.random.fold_in(key, 3), (10, 8))),
    ]


@pytest.fixture(scope="module")
def cfg36():
    """The pinned 36-cell grid of the acceptance criteria."""
    return make_noc(6, 6, num_mcs=4, lanes=8)


@pytest.fixture(scope="module")
def traffic36(layers, cfg36):
    return build_traffic_batch(layers, cfg36, [(by_name("O1"), None)],
                               max_packets_per_layer=8).variant(0)


def test_zero_fault_bit_identity(cfg36, traffic36):
    clean = simulate(cfg36, traffic36, chunk=CHUNK)
    ref = simulate_reference(cfg36, traffic36, chunk=CHUNK)
    fd = simulate_faulty(cfg36, traffic36, FaultModel(), chunk=CHUNK)
    # The frozen seed driver predates drain_cycle (None) - BT recorders
    # are the shared contract; the fused path adds the drain-cycle pin.
    for other in (clean, ref):
        assert fd.sim.total_bt == other.total_bt
        np.testing.assert_array_equal(np.asarray(fd.sim.link_bt),
                                      np.asarray(other.link_bt))
        np.testing.assert_array_equal(np.asarray(fd.sim.inj_bt),
                                      np.asarray(other.inj_bt))
    assert fd.sim.drain_cycle == clean.drain_cycle
    led = fd.ledger
    assert led["conservation_ok"]
    assert led["delivered"] == led["injected_packets"]
    assert led["dropped"] == led["retry_exhausted"] == led["unsent"] == 0
    assert led["protection_overhead_bits"] == 0
    assert led["transmission_rounds"] == 1
    assert np.all(fd.status == STATUS_DELIVERED)


def test_null_model_predicate():
    assert FaultModel().is_null
    assert not FaultModel(rate=1e-3).is_null
    assert not FaultModel(protect="crc8").is_null
    assert not FaultModel(dead_links=((0, 1),)).is_null
    with pytest.raises(ValueError):
        FaultModel(rate=1.5)
    with pytest.raises(ValueError):
        FaultModel(protect="hamming")


def test_syndrome_masks_match_crc8_reference():
    lanes = 8
    masks = protection_syndrome_masks("crc8", lanes)
    rng = np.random.default_rng(0)
    for _ in range(16):
        payload = rng.integers(0, 2**32, size=lanes, dtype=np.uint64
                               ).astype(np.uint32)
        code = 0
        for j in range(masks.shape[0]):
            bits = np.bitwise_count(payload & masks[j]).sum() & 1
            code |= int(bits) << j
        msg = b"".join(int(w).to_bytes(4, "little") for w in payload)
        assert code == crc8_reference(msg)


def test_protect_wire_stamps_verifiable_codes(cfg36, traffic36):
    from repro.noc import fuse_traffic
    lanes = cfg36.lanes
    fused = fuse_traffic(traffic36, False)
    wire = protect_wire(fused, "crc8", lanes)
    raw = np.asarray(fused.wire)
    stamped = np.asarray(wire.wire)
    np.testing.assert_array_equal(stamped[..., :lanes], raw[..., :lanes])
    # Codes live in sideband bits 16+; dest/meta/vc bits stay untouched.
    np.testing.assert_array_equal(stamped[..., lanes] & 0xFFFF,
                                  raw[..., lanes] & 0xFFFF)
    lengths = np.asarray(traffic36.length)
    for s in range(stamped.shape[0]):
        for f in range(min(int(lengths[s]), 4)):
            msg = b"".join(int(w).to_bytes(4, "little")
                           for w in stamped[s, f, :lanes])
            assert int(stamped[s, f, lanes]) >> 16 == crc8_reference(msg)


def test_seeded_replay_determinism(cfg36, traffic36):
    model = FaultModel(rate=5e-2, seed=11, protect="crc8")
    a = simulate_faulty(cfg36, traffic36, model, chunk=CHUNK)
    b = simulate_faulty(cfg36, traffic36, model, chunk=CHUNK)
    assert a.sim.total_bt == b.sim.total_bt
    assert a.sim.drain_cycle == b.sim.drain_cycle
    assert a.ledger == b.ledger
    np.testing.assert_array_equal(a.status, b.status)
    np.testing.assert_array_equal(a.retries, b.retries)

    c = simulate_faulty(cfg36, traffic36,
                        FaultModel(rate=5e-2, seed=12, protect="crc8"),
                        chunk=CHUNK)
    assert (c.ledger["flip_events"] != a.ledger["flip_events"]
            or c.sim.total_bt != a.sim.total_bt)


def test_crc8_detects_flips_none_ships_them(cfg36, traffic36):
    protected = simulate_faulty(
        cfg36, traffic36, FaultModel(rate=5e-2, seed=7, protect="crc8"),
        chunk=CHUNK)
    led = protected.ledger
    assert led["flip_events"] > 0
    assert led["detected_bad_flits"] > 0
    assert led["silent_corrupt"] == 0
    assert led["retried_packets"] > 0
    assert led["transmission_rounds"] > 1
    assert led["conservation_ok"]
    # Protection bits are charged on every transmitted flit, retries
    # included.
    assert led["protection_overhead_bits"] == 8 * led["transmitted_flits"]

    bare = simulate_faulty(cfg36, traffic36,
                           FaultModel(rate=5e-2, seed=7), chunk=CHUNK)
    bled = bare.ledger
    assert bled["flip_events"] > 0
    assert bled["detected_bad_flits"] == 0
    assert bled["silent_corrupt"] > 0
    assert bled["transmission_rounds"] == 1
    assert bled["delivered"] == bled["injected_packets"]


def test_dead_link_detours_and_delivers(cfg36, traffic36):
    model = FaultModel(dead_links=((cfg36.cols + 1, 1),))
    fd = simulate_faulty(cfg36, traffic36, model, chunk=CHUNK)
    led = fd.ledger
    assert led["conservation_ok"]
    assert led["dropped"] == 0
    assert led["delivered"] == led["injected_packets"]


def test_dead_router_drops_with_reason(cfg36, traffic36):
    dead = cfg36.cols + 1                 # a PE, not an MC
    assert dead not in cfg36.mc_nodes
    fd = simulate_faulty(cfg36, traffic36,
                         FaultModel(dead_routers=(dead,)), chunk=CHUNK)
    led = fd.ledger
    assert led["conservation_ok"]
    from repro.noc.faults import _packet_endpoints
    _, pdst = _packet_endpoints(traffic36, np.asarray(cfg36.mc_nodes),
                                int(traffic36.num_packets))
    to_dead = pdst == dead
    assert led["dropped"] == int(to_dead.sum()) > 0
    assert np.all(fd.status[to_dead] == STATUS_DROPPED)
    assert led["delivered"] == led["injected_packets"] - led["dropped"]


def test_drain_timeout_diagnostic(cfg36, traffic36):
    with pytest.raises(DrainTimeout) as ei:
        simulate(cfg36, traffic36, max_cycles=8, chunk=8,
                 check_conservation=True)
    e = ei.value
    assert e.cycle >= 8 and e.ejected < e.total
    assert e.occupancy or e.pending
    assert e.undelivered is not None and len(e.undelivered) > 0


def test_pallas_backend_rejects_conservation(cfg36, traffic36):
    with pytest.raises(ValueError, match="pallas"):
        simulate(cfg36, traffic36, backend="pallas",
                 check_conservation=True, chunk=CHUNK)


@pytest.fixture(scope="module")
def serving_setup():
    cfg = make_noc(4, 4, num_mcs=2, lanes=4)
    key = jax.random.PRNGKey(5)
    layer = LayerTraffic(jax.random.normal(key, (12, 6)),
                         jax.random.normal(jax.random.fold_in(key, 1),
                                           (12, 6)))
    variants = [(by_name("O0"), None)]
    req = build_traffic_batch([layer], cfg, variants,
                              max_packets_per_layer=6).variant(0)
    res = build_result_traffic([layer], cfg, variants,
                               max_packets_per_layer=6,
                               result_window=4).variant(0)
    return cfg, req, res


def test_online_deadline_slo(serving_setup):
    cfg, req, res = serving_setup
    kw = dict(arrivals=ArrivalProcess("uniform", 4.0, 0),
              num_inferences=4, chunk=64, check_conservation=True,
              record_bt=False)
    relaxed = simulate_online(cfg, req, res, deadline=10**6, **kw)
    assert relaxed.slo_attainment == 1.0
    assert relaxed.num_failed == relaxed.num_shed == 0
    tight = simulate_online(cfg, req, res, deadline=1, **kw)
    assert tight.slo_attainment == 0.0
    assert tight.goodput is None or tight.goodput == 0.0
    with pytest.raises(ValueError):
        simulate_online(cfg, req, res, deadline=0, **kw)


def test_online_admission_sheds_under_overload(serving_setup):
    cfg, req, res = serving_setup
    onl = simulate_online(cfg, req, res,
                          arrivals=ArrivalProcess("backtoback"),
                          num_inferences=6, chunk=64,
                          admit_queue_depth=1, deadline=10**6,
                          check_conservation=True, record_bt=False)
    assert onl.num_shed > 0
    assert onl.num_shed + int((onl.completions >= 0).sum()) <= 6
    # Shed inferences never complete and never count toward SLO.
    shed = np.asarray(onl.shed)
    assert np.all(onl.completions[shed] < 0)
    assert onl.slo_attainment <= (6 - onl.num_shed) / 6


def test_online_fault_ledger_closes(serving_setup):
    cfg, req, res = serving_setup
    onl = simulate_online(cfg, req, res,
                          arrivals=ArrivalProcess("uniform", 4.0, 0),
                          num_inferences=4, chunk=64,
                          faults=FaultModel(rate=1e-3, protect="crc8"),
                          deadline=10**6,
                          check_conservation=True, record_bt=False)
    for phase in ("request", "result"):
        assert onl.fault_ledger[phase]["conservation_ok"]
    assert onl.slo_attainment is not None
