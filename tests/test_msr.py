"""MSR codec oracle: the jitted 8b->5b kernels are pinned bit-for-bit to
the numpy reference, the round trip is exact on every representable byte,
the compressed flit geometry never exceeds the uncompressed one, and the
escape-metadata accounting matches the closed form on every
(window, outlier-count) combination."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import msr
from repro.core.flits import num_flits, pack, pack_paired
from repro.core.msr import (CODE_BITS, ESCAPE_BITS, MSR_RUN, compress,
                            compress_reference, compressed_bytes,
                            compressed_paired_payload_flits,
                            compressed_payload_flits, decompress,
                            decompress_reference, escape_bits, msr_pack,
                            msr_pack_paired, msr_pack_paired_reference,
                            msr_pack_reference, msr_overhead_bits,
                            msr_stream_overhead_bits, outlier_mask,
                            unpack_codes_reference)

ALL_INT8 = np.arange(-128, 128, dtype=np.int8)
ALL_UINT8 = np.arange(256, dtype=np.uint8)


def test_constants_are_the_papers_msr4():
    # 4-bit sign run -> 5-bit codes with 3 explicit escape top bits.
    assert MSR_RUN == 4
    assert CODE_BITS == 5
    assert ESCAPE_BITS == 3
    assert CODE_BITS + ESCAPE_BITS == 8


@pytest.mark.parametrize("window", [1, 3, 5, 16, 64, 256])
def test_roundtrip_exhaustive_all_256_int8(window):
    """decompress(compress(x)) == x for every int8 value, at several
    window sizes (including ones that force ragged final windows)."""
    got = decompress(compress(ALL_INT8, window))
    np.testing.assert_array_equal(np.asarray(got), ALL_INT8)
    ref = decompress_reference(compress_reference(ALL_INT8, window))
    np.testing.assert_array_equal(ref, ALL_INT8)


@pytest.mark.parametrize("window", [1, 7, 32, 256])
def test_roundtrip_exhaustive_all_256_uint8(window):
    got = decompress(compress(ALL_UINT8, window))
    np.testing.assert_array_equal(np.asarray(got), ALL_UINT8)
    ref = decompress_reference(compress_reference(ALL_UINT8, window))
    np.testing.assert_array_equal(ref, ALL_UINT8)


def test_jitted_kernel_matches_numpy_reference_bit_for_bit():
    """Every field of the compressed form agrees between the jitted codec
    and the numpy oracle, on every byte value at once."""
    for values in (ALL_INT8, ALL_UINT8):
        for window in (1, 6, 16, 200):
            a = compress(values, window)
            b = compress_reference(values, window)
            np.testing.assert_array_equal(np.asarray(a.codes), b.codes)
            np.testing.assert_array_equal(np.asarray(a.outlier), b.outlier)
            np.testing.assert_array_equal(np.asarray(a.top), b.top)
            assert (a.window, a.count, a.shape, a.dtype) == \
                (b.window, b.count, b.shape, b.dtype)
            assert a.overhead_bits() == b.overhead_bits()
            np.testing.assert_array_equal(np.asarray(decompress(a)),
                                          decompress_reference(b))


def test_worked_examples_from_the_paper():
    """The two SNIPPETS worked examples: 13 -> 01101, -10 -> 10110 (both
    inliers; the top four bits are a sign run)."""
    c = compress_reference(np.array([13, -10], np.int8), 2)
    assert not c.outlier.any()
    assert c.codes.reshape(-1).tolist() == [0b01101, 0b10110]


def test_outlier_mask_is_the_range_predicate():
    """A value escapes iff it falls outside the 5-bit two's-complement
    range [-16, 15] - as bytes, outside {0..15, 240..255}."""
    v = ALL_INT8
    np.testing.assert_array_equal(outlier_mask(v), (v < -16) | (v > 15))
    u = ALL_UINT8
    np.testing.assert_array_equal(outlier_mask(u),
                                  (u > 15) & (u < 240))
    # shape-preserving
    assert outlier_mask(v.reshape(16, 16)).shape == (16, 16)


def test_mixed_inlier_outlier_windows_roundtrip():
    """Windows that mix inliers and outliers in every slot pattern of a
    4-value window still round-trip exactly."""
    inl, out = np.int8(7), np.int8(-100)
    for pattern in range(16):
        vals = np.array([out if (pattern >> i) & 1 else inl
                         for i in range(4)], np.int8)
        c = compress(vals, 4)
        assert int(np.asarray(c.outlier).sum()) == bin(pattern).count("1")
        np.testing.assert_array_equal(np.asarray(decompress(c)), vals)


def test_padding_is_inlier_and_count_restores_shape():
    """Ragged final windows zero-pad; zero is an inlier, so padding never
    inflates the escape budget, and the original shape returns exactly."""
    vals = np.array([[100, -3], [5, -128]], np.int8)
    c = compress(vals, 3)
    assert c.codes.shape == (2, 3)          # 4 values in 2 windows of 3
    assert c.count == 4 and c.shape == (2, 2)
    assert int(np.asarray(c.outlier).sum()) == 2    # 100 and -128 only
    got = np.asarray(decompress(c))
    assert got.shape == (2, 2) and got.dtype == np.int8
    np.testing.assert_array_equal(got, vals)


def test_codec_rejects_wide_dtypes():
    with pytest.raises(TypeError, match="int8"):
        compress(np.arange(4, dtype=np.int32), 4)
    with pytest.raises(TypeError, match="int8"):
        compress_reference(np.arange(4, dtype=np.float32), 4)
    with pytest.raises(ValueError, match="window"):
        compress(ALL_INT8, 0)


# --- escape-metadata accounting ---------------------------------------------

@pytest.mark.parametrize("window", [1, 2, 3, 4, 7, 8, 16, 64, 100])
def test_escape_budget_matches_closed_form(window):
    """For EVERY outlier count a window can hold, the measured escape bits
    equal the closed form: a ceil(log2(window+1)) count field plus a
    (ceil(log2(window)) position + 3 top bits) record per outlier."""
    count_bits = max(1, window.bit_length())
    pos_bits = max(1, (window - 1).bit_length())
    for n_out in range(window + 1):
        want = count_bits + n_out * (pos_bits + ESCAPE_BITS)
        assert msr_overhead_bits(window, n_out) == want
        # and the codec measures exactly that on a real window
        vals = np.array([-100] * n_out + [1] * (window - n_out), np.int8)
        assert compress_reference(vals, window).overhead_bits() == want
        assert escape_bits(vals, window) == want


def test_stream_overhead_is_per_window_sum():
    assert msr_stream_overhead_bits(16, 10, 7) == \
        10 * msr_overhead_bits(16, 0) + 7 * (4 + ESCAPE_BITS)
    with pytest.raises(ValueError, match="window"):
        msr_stream_overhead_bits(0, 1, 0)


def test_escape_bits_2d_charges_one_window_per_row():
    """Operand matrices charge one window per packet row (rows are padded
    to the wire window; padding zeros are inliers)."""
    vals = np.array([[100, 1, 1], [1, 1, 1]], np.int8)
    assert escape_bits(vals, 4) == msr_stream_overhead_bits(4, 2, 1)
    with pytest.raises(ValueError, match="fit"):
        escape_bits(vals, 2)


# --- compressed flit geometry ----------------------------------------------

@pytest.mark.parametrize("lanes", [2, 4, 8, 16])
def test_compressed_never_exceeds_uncompressed_flits(lanes):
    """The 5-bit payload stream never needs more flits than the 8-bit one,
    for every value count up to several hundred."""
    for n in range(1, 400):
        assert compressed_payload_flits(n, lanes) <= num_flits(n, lanes)
        assert compressed_paired_payload_flits(n, lanes) <= \
            num_flits(n, lanes // 2)
    # vectorized form agrees with the scalar form
    ns = np.arange(1, 400)
    np.testing.assert_array_equal(
        compressed_payload_flits(ns, lanes),
        [compressed_payload_flits(int(n), lanes) for n in ns])


@pytest.mark.parametrize("lanes", [2, 4, 8, 16])
@pytest.mark.parametrize("n", [1, 5, 16, 63, 64, 257])
def test_pack_geometry_matches_helpers(lanes, n):
    """msr_pack/_paired produce exactly the flit counts the closed-form
    helpers promise, and the numpy references match the jitted packers."""
    rng = np.random.default_rng(n * 31 + lanes)
    vals = rng.integers(-128, 128, n).astype(np.int8)
    wgts = rng.integers(-128, 128, n).astype(np.int8)

    fs = msr_pack(vals, lanes)
    assert fs.words.shape == (compressed_payload_flits(n, lanes), lanes)
    assert fs.value_bits == 8
    np.testing.assert_array_equal(np.asarray(fs.words),
                                  msr_pack_reference(vals, lanes))

    ps = msr_pack_paired(vals, wgts, lanes)
    assert ps.words.shape == (compressed_paired_payload_flits(n, lanes),
                              lanes)
    np.testing.assert_array_equal(
        np.asarray(ps.words), msr_pack_paired_reference(vals, wgts, lanes))

    # wire-level recovery: the dense code stream still holds every code
    slots = num_flits(n, lanes) * lanes
    codes = unpack_codes_reference(np.asarray(fs.words).reshape(-1), slots)
    np.testing.assert_array_equal(
        codes[:n], vals.view(np.uint8) & ((1 << CODE_BITS) - 1))


def test_compressed_bytes_formula():
    assert compressed_bytes(0) == 0
    assert compressed_bytes(8) == 5       # 40 bits
    assert compressed_bytes(3) == 2       # 15 bits -> 2 bytes
    assert compressed_bytes(400) == 250


@pytest.mark.parametrize("lanes", [4, 8, 16])
def test_pack_vs_uncompressed_pack_shrinks_large_packets(lanes):
    """On a full-size packet the compressed payload is strictly smaller
    than flits.pack's (the tentpole's reason to exist)."""
    rng = np.random.default_rng(0)
    vals = rng.integers(-16, 16, 40 * lanes).astype(np.int8)
    assert msr_pack(vals, lanes).words.shape[0] < \
        pack(jnp.asarray(vals), lanes).words.shape[0]
    wgts = rng.integers(-16, 16, 40 * lanes).astype(np.int8)
    assert msr_pack_paired(vals, wgts, lanes).words.shape[0] < \
        pack_paired(jnp.asarray(vals), jnp.asarray(wgts), lanes).words.shape[0]


def test_pack_paired_validation():
    vals = np.zeros(4, np.int8)
    with pytest.raises(ValueError, match="even"):
        msr_pack_paired(vals, vals, 3)
    with pytest.raises(ValueError, match="element count"):
        msr_pack_paired(vals, np.zeros(5, np.int8), 4)


def test_module_reexports():
    from repro.core import (MsrCompressed, msr_compress, msr_decompress,
                            msr_overhead_bits as mob)
    c = msr_compress(np.array([1, -1], np.int8), 2)
    assert isinstance(c, MsrCompressed)
    np.testing.assert_array_equal(np.asarray(msr_decompress(c)),
                                  np.array([1, -1], np.int8))
    assert mob(2, 0) == msr.msr_overhead_bits(2, 0)
