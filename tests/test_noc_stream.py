"""Streamed packetization parity: the chunked path
(``ordered_payloads_streamed`` / ``build_traffic_streamed``) must be
bit-identical to the one-shot packetizer for every chunk size - including
chunk=1, chunk > total, and ragged final chunks - and must scale to a full
unsubsampled DarkNet layer. The one-shot path is itself pinned to the seed
loop (tests/test_noc_sweep.py), so these tests transitively pin the
streamed path to the seed as well."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.wire import by_name
from repro.noc import NocConfig, build_traffic_batch, build_traffic_streamed
from repro.noc.traffic import (LayerTraffic, assemble_traffic,
                               ordered_payloads, ordered_payloads_streamed,
                               payload_shapes, stream_lengths)
from repro.quant import quantize_fixed8

VARIANTS = [(by_name(o, tiebreak=tb), q)
            for o in ("O0", "O1", "O2")
            for tb in ("pattern",)
            for q in (None, lambda t: quantize_fixed8(t).values)]


def _layers(sizes, seed=0):
    key = jax.random.PRNGKey(seed)
    out = []
    for i, (n, k) in enumerate(sizes):
        ki = jax.random.fold_in(key, 2 * i)
        kw = jax.random.fold_in(key, 2 * i + 1)
        out.append(LayerTraffic(jax.random.normal(ki, (n, k)),
                                jax.random.normal(kw, (n, k)) * 0.3))
    return out


def _assert_traffic_equal(a, b):
    for name in a._fields:
        xa, xb = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert xa.dtype == xb.dtype, name
        assert xa.shape == xb.shape, name
        assert np.array_equal(xa, xb), f"Traffic.{name} diverged"


@pytest.fixture(scope="module")
def two_layers():
    return _layers([(13, 7), (6, 11)])


@pytest.fixture(scope="module")
def pinned_cfg():
    return NocConfig(rows=4, cols=4, mc_nodes=(0, 15), num_vcs=3, lanes=8)


# chunk=1, tiny, ragged-final, exact divisor, chunk > total packets
@pytest.mark.parametrize("chunk", [1, 3, 6, 13, 1000])
def test_streamed_traffic_bit_identical(two_layers, pinned_cfg, chunk):
    ref = build_traffic_batch(two_layers, pinned_cfg, VARIANTS)
    got = build_traffic_streamed(two_layers, pinned_cfg, VARIANTS,
                                 chunk_packets=chunk)
    _assert_traffic_equal(ref, got)


def test_streamed_payload_chunks_reassemble(two_layers, pinned_cfg):
    """The generator's (layer, start, words) chunks tile the one-shot
    payload arrays exactly, and the shape probe agrees with both."""
    lanes = pinned_cfg.lanes
    ref = ordered_payloads(two_layers, lanes, VARIANTS)
    assert payload_shapes(two_layers, lanes, VARIANTS) == \
        [(w.shape[1], w.shape[2]) for w in ref]
    acc = [np.full_like(w, 0xFF) for w in ref]
    seen = [np.zeros(w.shape[1], bool) for w in ref]
    for li, start, words in ordered_payloads_streamed(
            two_layers, lanes, VARIANTS, chunk_packets=4):
        c = words.shape[1]
        assert not seen[li][start:start + c].any(), "overlapping chunks"
        seen[li][start:start + c] = True
        acc[li][:, start:start + c] = words
    assert all(s.all() for s in seen), "missing packets"
    for a, w in zip(acc, ref):
        assert np.array_equal(a, w)


def test_streamed_respects_subsampling_and_padding(two_layers, pinned_cfg):
    """max_packets_per_layer and num_streams thread through the streamed
    path exactly as through the one-shot path."""
    ref = assemble_traffic(
        ordered_payloads(two_layers, pinned_cfg.lanes, VARIANTS,
                         max_packets_per_layer=5),
        pinned_cfg, num_streams=4)
    got = build_traffic_streamed(two_layers, pinned_cfg, VARIANTS,
                                 chunk_packets=2, num_streams=4,
                                 max_packets_per_layer=5)
    _assert_traffic_equal(ref, got)


def test_zero_packet_layer(pinned_cfg):
    """A 0-packet layer must flow through probe, streamed, and one-shot
    paths alike (the one-shot path emits a (B, 0, F, L) array for it)."""
    layers = _layers([(5, 7)]) + [
        LayerTraffic(jnp.zeros((0, 7)), jnp.zeros((0, 7)))]
    assert payload_shapes(layers, pinned_cfg.lanes, VARIANTS)[1][0] == 0
    ref = build_traffic_batch(layers, pinned_cfg, VARIANTS)
    got = build_traffic_streamed(layers, pinned_cfg, VARIANTS,
                                 chunk_packets=3)
    _assert_traffic_equal(ref, got)


def test_streamed_validation():
    layers = _layers([(4, 3)])
    cfg = NocConfig(2, 2, (0,), lanes=8)
    with pytest.raises(ValueError, match="chunk_packets"):
        list(ordered_payloads_streamed(layers, 8, VARIANTS[:1],
                                       chunk_packets=0))
    with pytest.raises(ValueError, match="variant"):
        build_traffic_streamed(layers, cfg, [])


def test_stream_lengths_match_assembled_lengths(two_layers, pinned_cfg):
    shapes = payload_shapes(two_layers, pinned_cfg.lanes, VARIANTS)
    traffic = build_traffic_streamed(two_layers, pinned_cfg, VARIANTS,
                                     chunk_packets=3)
    assert np.array_equal(stream_lengths(shapes, pinned_cfg.num_mcs),
                          np.asarray(traffic.length[0]))


def test_full_darknet_layer_smoke():
    """A full, unsubsampled DarkNet conv layer (61504 packets - the layer
    the seed's `_subsample` existed to avoid) streams through in bounded
    chunks and lands bit-identical to the one-shot packetizer."""
    from repro.models import DarkNetLike, init_params

    model = DarkNetLike()
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), model.input_shape)
    layer = model.layer_traffic(params, x)[0]
    n = int(layer.inputs.shape[0])
    assert n == 62 * 62 * 16          # full layer, nothing subsampled

    cfg = NocConfig(4, 4, (0, 15), lanes=16)
    variants = [(by_name("O1", tiebreak="pattern"),
                 lambda t: quantize_fixed8(t).values)]
    ref = assemble_traffic(ordered_payloads([layer], cfg.lanes, variants),
                           cfg)
    got = build_traffic_streamed([layer], cfg, variants, chunk_packets=4096)
    _assert_traffic_equal(ref, got)
    assert int(np.asarray(got.length).sum()) == n * (4 + 1)


# The hypothesis property test (arbitrary geometries x chunk sizes) lives in
# tests/test_noc_stream_properties.py: importorskip is module-granular, and
# the deterministic parity tests above must run even where hypothesis is not
# installed.
