"""Property tests (hypothesis) for the MSR compression knob.

Three contracts, each over arbitrary inputs rather than pinned examples:
the codec round-trip is the identity for any shape/dtype/window; streamed
packetization equals the one-shot path bit for bit under compression="msr"
for any chunk size (chunk=1 and ragged finals included); and
compression="none" through run_sweep is field-by-field identical to a grid
that never heard of the axis. The deterministic halves live in
tests/test_msr.py and tests/test_noc_sweep.py; this module holds only the
hypothesis half so importorskip can stay module-granular."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.core import msr
from repro.noc import NocConfig, SweepGrid, run_sweep
from repro.noc.traffic import build_traffic_batch, build_traffic_streamed
from repro.core.wire import by_name
from repro.quant import quantize_fixed8

from test_noc_stream import _assert_traffic_equal, _layers

settings.register_profile("msr", max_examples=25, deadline=None)
settings.load_profile("msr")

_FIXED8 = lambda t: quantize_fixed8(t).values  # noqa: E731


@given(data=st.data(),
       window=st.integers(min_value=1, max_value=300),
       dtype=st.sampled_from([np.int8, np.uint8]))
def test_property_roundtrip_identity(data, window, dtype):
    """P: decompress(compress(x, w)) == x bit-for-bit for any byte array,
    any shape (flat, 2-D, or empty-ish), any window size."""
    shape = data.draw(st.sampled_from(["flat", "matrix"]))
    if shape == "flat":
        n = data.draw(st.integers(0, 600))
        dims = (n,)
    else:
        dims = (data.draw(st.integers(1, 24)), data.draw(st.integers(1, 24)))
    raw = data.draw(st.binary(min_size=int(np.prod(dims)),
                              max_size=int(np.prod(dims))))
    vals = np.frombuffer(raw, np.uint8).astype(dtype).reshape(dims)

    comp = msr.compress(vals, window)
    got = np.asarray(msr.decompress(comp))
    assert got.dtype == vals.dtype and got.shape == vals.shape
    np.testing.assert_array_equal(got, vals)

    ref = msr.compress_reference(vals, window)
    np.testing.assert_array_equal(decoded := msr.decompress_reference(ref),
                                  vals)
    assert decoded.dtype == vals.dtype
    # jitted kernel == numpy oracle, field by field
    np.testing.assert_array_equal(np.asarray(comp.codes), ref.codes)
    np.testing.assert_array_equal(np.asarray(comp.outlier), ref.outlier)
    np.testing.assert_array_equal(np.asarray(comp.top), ref.top)
    assert comp.overhead_bits() == ref.overhead_bits()


@given(n=st.integers(0, 500),
       lanes=st.sampled_from([2, 4, 8, 16, 32]))
def test_property_compressed_flits_never_exceed(n, lanes):
    """P: compressed payload flit counts never exceed the uncompressed
    ceil(n/lanes) geometry, single or paired."""
    from repro.core.flits import num_flits
    assert msr.compressed_payload_flits(n, lanes) <= num_flits(n, lanes)
    assert msr.compressed_paired_payload_flits(n, lanes) <= \
        num_flits(n, lanes // 2)


@given(data=st.data(),
       chunk=st.integers(min_value=1, max_value=50),
       seed=st.integers(min_value=0, max_value=2**16))
def test_property_streamed_equals_oneshot_under_msr(data, chunk, seed):
    """P: chunking stays invisible when the payload lanes carry MSR codes -
    for any layer list and any chunk size (1, ragged, > total) the streamed
    Traffic equals the one-shot Traffic bit for bit."""
    sizes = data.draw(st.lists(
        st.tuples(st.integers(1, 40), st.integers(1, 24)),
        min_size=1, max_size=3))
    layers = _layers(sizes, seed=seed)
    cfg = NocConfig(2, 2, (0, 3), lanes=8)
    variants = [(by_name("O2", tiebreak="pattern"), _FIXED8),
                (by_name("O1", tiebreak="stable"), _FIXED8)]
    ref = build_traffic_batch(layers, cfg, variants, compression="msr")
    got = build_traffic_streamed(layers, cfg, variants, chunk_packets=chunk,
                                 compression="msr")
    _assert_traffic_equal(ref, got)


@given(seed=st.integers(min_value=0, max_value=2**16),
       n=st.integers(min_value=2, max_value=14),
       k=st.integers(min_value=2, max_value=20))
def test_property_none_rows_identical_to_axisless_grid(seed, n, k):
    """P: compression="none" is the PR-9 path - run_sweep rows agree field
    by field with a grid that does not name the axis, for any workload."""
    layers = _layers([(n, k)], seed=seed)
    kw = dict(meshes=("2x2_mc1",), transforms=("O0", "O2"),
              tiebreaks=("pattern",), precisions=("fixed8",),
              models=("toy",), max_packets_per_layer=8, chunk=64)
    with_axis = run_sweep(SweepGrid(compression=("none",), **kw),
                          lambda _m: layers)
    without = run_sweep(SweepGrid(**kw), lambda _m: layers)
    assert len(with_axis.rows) == len(without.rows)
    for a, b in zip(with_axis.rows, without.rows):
        assert set(a) == set(b)
        for key in a:
            assert a[key] == b[key], key
