"""Property-based tests (hypothesis) for the system's invariants.

The paper's central claims, stated as properties:
  P1  descending interleave maximizes F = sum(x_i * y_i) over lane pairings
      (Sec. III-B optimality proof).
  P2  any reordering leaves the value multiset intact (Sec. III-B:
      "maintains strict numerical equivalence").
  P3  expected BT (Eq. 3) never increases under descending ordering.
  P4  affiliated ordering leaves convolution/linear outputs bit-identical
      (Fig. 5 order invariance).
  P5  measured BT of a stream equals the sum of per-boundary XOR popcounts
      (Fig. 8 recorder definition).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.core import (pack, bt_stream, expected_bt_stream, pairing_objective,
                        descending_order, affiliated_order)
from repro.core.bits import popcount, transitions
from repro.quant import quantize_fixed8, dequantize_fixed8

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")

u32 = st.integers(min_value=0, max_value=2**32 - 1)


@st.composite
def word_streams(draw, min_len=8, max_len=64):
    n = draw(st.integers(min_len, max_len))
    return np.array(draw(st.lists(u32, min_size=n, max_size=n)), np.uint32)


@given(word_streams())
def test_p2_multiset_preserved(vals):
    v = jnp.asarray(vals)
    o = descending_order(v)
    assert sorted(np.asarray(o.values).tolist()) == sorted(vals.tolist())


@given(word_streams(min_len=8, max_len=8), st.integers(0, 7))
def test_p1_interleave_maximizes_F_vs_random(vals, seed):
    """F(descending interleave) >= F(any arrangement) for the lane pairing
    between one flit PAIR - the exact scope of the Sec. III-B proof.
    (The multi-flit chain version is only a statistical claim: hypothesis
    found counterexamples when windows of 3+ flits share values, the same
    endpoint effect documented for P3.)"""
    lanes = 4
    v = jnp.asarray(vals[:2 * lanes])
    opt = descending_order(v, fill="interleave", lanes=lanes)
    c_opt = popcount(opt.values).reshape(2, lanes).astype(jnp.float32)
    f_opt = float(pairing_objective(c_opt[0], c_opt[1]))
    rng = np.random.default_rng(seed)
    c_rnd = popcount(v[rng.permutation(2 * lanes)]) \
        .reshape(2, lanes).astype(jnp.float32)
    f_rnd = float(pairing_objective(c_rnd[0], c_rnd[1]))
    assert f_opt >= f_rnd - 1e-3


@given(word_streams(min_len=16, max_len=16), st.integers(0, 5))
def test_p3_two_flit_expected_bt_never_increases(vals, seed):
    """The exact form the paper proves (Sec. III-B): for one flit PAIR,
    the descending interleave minimizes expected BT over arrangements.
    (For long streams with endpoints this is a statistical claim, not a
    per-instance one - hypothesis found counterexamples, documented in
    EXPERIMENTS.md; the guaranteed property is the two-flit case.)"""
    lanes = 8
    v = jnp.asarray(vals[:16])
    opt = descending_order(v, fill="interleave", lanes=lanes)
    e_opt = expected_bt_stream(pack(opt.values, lanes))
    rng = np.random.default_rng(seed)
    e_rnd = expected_bt_stream(pack(v[jnp.asarray(rng.permutation(16))], lanes))
    assert float(e_opt) <= float(e_rnd) + 1e-3


@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
def test_p4_affiliated_ordering_is_output_invariant(seed, k):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k,), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (k,), jnp.float32)
    po = affiliated_order(x, w, window=None)
    # fp32 dot products are order-sensitive in the last ulps; compare with
    # a tolerance scaled to the magnitudes involved (the paper's Fig. 5
    # argument is exact over reals / integer accumulators).
    a = float(jnp.vdot(x, w))
    b = float(jnp.vdot(po.inputs, po.weights))
    assert abs(a - b) <= 1e-4 * max(1.0, float(jnp.sum(jnp.abs(x * w))))


@given(word_streams(min_len=4, max_len=32))
def test_p5_bt_recorder_definition(vals):
    n = (len(vals) // 4) * 4
    if n < 8:
        return
    v = jnp.asarray(vals[:n])
    s = pack(v, 4)
    manual = sum(int(jnp.sum(transitions(s.words[i], s.words[i + 1])))
                 for i in range(s.words.shape[0] - 1))
    assert int(bt_stream(s)) == manual


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=64))
def test_fixed8_quantization_bounds(xs):
    x = jnp.asarray(xs, jnp.float32)
    q = quantize_fixed8(x)
    back = dequantize_fixed8(q)
    amax = float(jnp.max(jnp.abs(x)))
    if amax == 0.0:
        assert float(jnp.max(jnp.abs(back))) == 0.0
        return
    scale = 2.0 ** -float(q.frac_bits)
    # round-to-nearest within half an LSB, except clamp at the int8 edge
    err = np.asarray(jnp.abs(back - x))
    clamped = np.asarray(jnp.abs(x) >= 127 * scale)
    assert np.all(err[~clamped] <= scale * 0.5 + 1e-7)


@given(word_streams(min_len=8, max_len=64), st.sampled_from([4, 8, 16]))
def test_descending_order_monotone_counts(vals, window):
    n = (len(vals) // window) * window
    if n == 0:
        return
    v = jnp.asarray(vals[:n])
    o = descending_order(v, window=window)
    c = np.asarray(popcount(o.values)).reshape(-1, window)
    assert np.all(c[:, :-1] >= c[:, 1:])
