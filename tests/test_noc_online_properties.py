"""Property tests (hypothesis) for the closed-loop serving model.

Three invariants of ``simulate_online`` for ARBITRARY compute latencies,
arrival gaps, and mesh sizes:

* **Phase-symmetric conservation under overlap** - every request packet
  and every result packet of every inference ejects exactly once, however
  the phases interleave in the mesh (the packet-id ledger is asserted
  directly, and ``check_conservation=True`` must not raise);
* **Latency lower bound** - no inference completes faster than its
  congestion-free floor: a request stream of ``L`` flits needs ``L``
  injection cycles, results cannot release before the slowest gated PE,
  and each phase needs at least one traversal cycle;
* **Deterministic replay** - one (seed, load, kind) triple replays the
  identical arrival schedule, completions, and BT totals.

Kept separate from tests/test_noc_online.py so importorskip can stay
module-granular (mirrors tests/test_noc_step_properties.py).
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.wire import by_name  # noqa: E402
from repro.noc import (ArrivalProcess, LayerTraffic, NocConfig,  # noqa: E402
                       build_result_traffic, build_traffic_batch, make_noc,
                       simulate_online)

CHUNK = 64

_MESHES = [
    NocConfig(rows=3, cols=3, mc_nodes=(0, 4), lanes=4),
    NocConfig(rows=3, cols=4, mc_nodes=(0, 11), num_vcs=3, lanes=4),
    make_noc(4, 4, num_mcs=4, lanes=4),
]


def _phases(cfg, seed, npkts):
    key = jax.random.PRNGKey(seed)
    layer = LayerTraffic(
        jax.random.normal(key, (npkts, 6)),
        jax.random.normal(jax.random.fold_in(key, 1), (npkts, 6)) * 0.5)
    variants = [(by_name("O0"), None)]
    req = build_traffic_batch([layer], cfg, variants).variant(0)
    res = build_result_traffic([layer], cfg, variants,
                               result_window=4).variant(0)
    return req, res


@settings(max_examples=10, deadline=None)
@given(
    mesh=st.integers(min_value=0, max_value=len(_MESHES) - 1),
    npkts=st.integers(min_value=3, max_value=24),
    k=st.integers(min_value=1, max_value=4),
    latency=st.integers(min_value=0, max_value=300),
    gap=st.integers(min_value=0, max_value=400),
    seed=st.integers(min_value=0, max_value=3),
)
def test_conservation_under_overlap(mesh, npkts, k, latency, gap, seed):
    """Every request and result packet of every inference ejects exactly
    once, for arbitrary latencies, arrival gaps, and meshes."""
    cfg = _MESHES[mesh]
    req, res = _phases(cfg, seed, npkts)
    arrivals = np.arange(k, dtype=np.int64) * gap
    onl = simulate_online(cfg, req, res, arrivals=arrivals,
                          compute_latency=latency, chunk=CHUNK,
                          check_conservation=True)
    assert onl.truncated == 0
    # the ledgers themselves: every concatenated packet ejected once
    assert onl.request_eject_time.shape == (k * req.num_packets,)
    assert (onl.request_eject_time >= 0).all()
    assert onl.result_eject_time.shape == (k * res.num_packets,)
    assert (onl.result_eject_time >= 0).all()
    # gated totals cover every inference's flits
    total_req = k * int(np.asarray(req.length).sum())
    total_res = k * int(np.asarray(res.length).sum())
    assert onl.sched_request.ejected == total_req
    assert onl.sched_result.ejected == total_res


@settings(max_examples=10, deadline=None)
@given(
    mesh=st.integers(min_value=0, max_value=len(_MESHES) - 1),
    npkts=st.integers(min_value=3, max_value=24),
    k=st.integers(min_value=1, max_value=3),
    latency=st.integers(min_value=0, max_value=200),
    load=st.floats(min_value=0.5, max_value=20.0),
    seed=st.integers(min_value=0, max_value=3),
)
def test_latency_lower_bound(mesh, npkts, k, latency, load, seed):
    """No inference beats its congestion-free floor: the longest request
    stream must fully inject (one flit per cycle per NI), the slowest
    gated PE delays every result behind request delivery + compute
    latency, and each phase needs one traversal cycle beyond injection."""
    cfg = _MESHES[mesh]
    req, res = _phases(cfg, seed, npkts)
    onl = simulate_online(cfg, req, res,
                          arrivals=ArrivalProcess("poisson", load, seed),
                          num_inferences=k, compute_latency=latency,
                          chunk=CHUNK)
    req_len = np.asarray(req.length, np.int64)
    res_len = np.asarray(res.length, np.int64)
    floor = int(req_len.max()) + int(res_len.max()) + (latency if
                                                       res_len.any() else 0)
    assert (onl.latencies >= max(floor, 1)).all()
    # completions respect the release gates: no result ejects before the
    # earliest release cycle of a stream that actually carries packets
    live = res_len > 0
    if live.any():
        first_release = int(onl.release[live].min())
        assert int(onl.result_eject_time.min()) > first_release


@settings(max_examples=8, deadline=None)
@given(
    kind=st.sampled_from(["uniform", "poisson", "backtoback"]),
    load=st.floats(min_value=0.5, max_value=10.0),
    k=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=6),
)
def test_deterministic_replay(kind, load, k, seed):
    """One (kind, load, seed) triple replays the identical arrival
    schedule, completions, and BT totals - the closed loop is a pure
    function of its inputs."""
    cfg = _MESHES[0]
    req, res = _phases(cfg, seed=1, npkts=9)
    ap = ArrivalProcess(kind, load, seed)
    a = simulate_online(cfg, req, res, arrivals=ap, num_inferences=k,
                        compute_latency=17, chunk=CHUNK)
    b = simulate_online(cfg, req, res, arrivals=ap, num_inferences=k,
                        compute_latency=17, chunk=CHUNK)
    np.testing.assert_array_equal(a.arrivals, b.arrivals)
    np.testing.assert_array_equal(a.completions, b.completions)
    np.testing.assert_array_equal(a.latencies, b.latencies)
    assert a.request.total_bt == b.request.total_bt
    assert a.result.total_bt == b.result.total_bt
    assert a.request_drain_cycle == b.request_drain_cycle
    assert a.result_drain_cycle == b.result_drain_cycle
