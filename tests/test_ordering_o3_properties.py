"""Property tests (hypothesis) for the O3 min-Hamming ordering.

Three properties over arbitrary geometries: the batched chain kernel equals
the per-window numpy reference loop bit for bit (any window/lane/dtype
combo), streamed packetization under O3 equals the one-shot path, and the
O3 result phase conserves every packet (positive) while the ledger still
catches corruption (negative). Deterministic O3 coverage lives in
tests/test_ordering_o3.py; this module holds only the hypothesis half so
importorskip can stay module-granular."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.core.wire import by_name
from repro.kernels.min_hamming import (min_hamming_chain,
                                       min_hamming_chain_reference)
from repro.noc import NocConfig, build_traffic_batch, build_traffic_streamed
from repro.noc.traffic import LayerTraffic, build_result_traffic
from repro.noc.sim import simulate
from repro.quant import quantize_fixed8

settings.register_profile("ordering_o3", max_examples=25, deadline=None)
settings.load_profile("ordering_o3")

_DTYPES = {"uint8": np.uint8, "uint16": np.uint16, "uint32": np.uint32,
           "float32": np.float32}


@given(data=st.data(),
       rows=st.integers(1, 8), width=st.integers(0, 14),
       beam=st.integers(1, 4), starts=st.integers(1, 10),
       dtype=st.sampled_from(sorted(_DTYPES)),
       seed=st.integers(0, 2 ** 16))
def test_property_kernel_equals_reference(data, rows, width, beam, starts,
                                          dtype, seed):
    """P: the vmapped/scanned kernel is bit-identical to the python
    per-window mirror for any (rows, width, beam, starts, dtype)."""
    rng = np.random.default_rng(seed)
    if dtype == "float32":
        vals = (rng.normal(size=(rows, width)) *
                rng.choice([0, 1], size=(rows, width), p=[0.3, 0.7])
                ).astype(np.float32)
    else:
        dt = _DTYPES[dtype]
        hi = min(np.iinfo(dt).max, 1 << 16)
        vals = rng.integers(0, hi, size=(rows, width)).astype(dt)
        vals[rng.random((rows, width)) < 0.3] = 0
    res = min_hamming_chain(jnp.asarray(vals), beam=beam, starts=starts)
    rp, rc, rz = min_hamming_chain_reference(vals, beam=beam, starts=starts)
    assert np.array_equal(np.asarray(res.perm), rp)
    assert np.array_equal(np.asarray(res.cost), rc)
    assert np.array_equal(np.asarray(res.nonzeros), rz)


def _layers(sizes, seed=0):
    key = jax.random.PRNGKey(seed)
    out = []
    for i, (n, k) in enumerate(sizes):
        ki = jax.random.fold_in(key, 2 * i)
        kw = jax.random.fold_in(key, 2 * i + 1)
        out.append(LayerTraffic(jax.random.normal(ki, (n, k)),
                                jax.random.normal(kw, (n, k)) * 0.3))
    return out


def _assert_traffic_equal(a, b):
    for name in a._fields:
        xa, xb = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert xa.dtype == xb.dtype, name
        assert xa.shape == xb.shape, name
        assert np.array_equal(xa, xb), f"Traffic.{name} diverged"


@given(data=st.data(),
       chunk=st.integers(min_value=1, max_value=50),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_property_streamed_equals_oneshot_o3(data, chunk, seed):
    """P: chunking is invisible under O3 - the perm is per-window, so the
    streamed Traffic equals the one-shot Traffic bit for bit for any layer
    list and chunk size."""
    sizes = data.draw(st.lists(
        st.tuples(st.integers(1, 30), st.integers(1, 20)),
        min_size=1, max_size=3))
    layers = _layers(sizes, seed=seed)
    cfg = NocConfig(2, 2, (0, 3), lanes=8)
    variants = [(by_name("O3"), None),
                (by_name("O3a"), lambda t: quantize_fixed8(t).values)]
    ref = build_traffic_batch(layers, cfg, variants)
    got = build_traffic_streamed(layers, cfg, variants, chunk_packets=chunk)
    _assert_traffic_equal(ref, got)


@given(n=st.integers(1, 40), k=st.integers(1, 16),
       window=st.integers(1, 20), seed=st.integers(0, 2 ** 16))
def test_property_o3_result_phase_conserves(n, k, window, seed):
    """P: the O3-ordered result phase drains with every packet ejected
    exactly once (positive), and a corrupted packet-id tensor still trips
    the conservation ledger (negative)."""
    layers = _layers([(n, k)], seed=seed)
    cfg = NocConfig(2, 2, (0,), lanes=4)
    traffic = build_result_traffic(
        layers, cfg, [(by_name("O3"), None)], result_window=window)
    t = traffic.variant(0)
    pe = np.asarray(cfg.pe_nodes)
    res = simulate(cfg, t, mc_nodes=pe, chunk=64, check_conservation=True)
    assert res.ejected == res.injected > 0
    bad = t._replace(pkt=jnp.zeros_like(t.pkt))
    if int(t.num_packets) > 1:
        with pytest.raises(RuntimeError, match="conservation"):
            simulate(cfg, bad, mc_nodes=pe, chunk=64,
                     check_conservation=True)
