"""Closed-loop (online) serving model: the offline-equivalence oracle.

Pins the gating contract of ``repro.noc.online``:

* zero compute latency + a single inference reduces the closed loop to the
  offline phases **bit-identically** - reported request/result BT totals
  and per-link recorders equal ``simulate``'s, and the gated schedule
  drain itself (all gates open from cycle 0) matches the offline drain
  byte for byte;
* nonzero compute latency shifts the gated result drain later but never
  changes a reported BT total (BT is data-determined, timing is
  schedule-determined);
* the latency-percentile extraction matches the numpy reference on
  hand-built ledgers, including ties, single samples, and
  in-flight-at-cutoff entries (truncation reported, never dropped).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.wire import by_name
from repro.noc import (ArrivalProcess, LayerTraffic, NocConfig,
                       build_result_traffic, build_traffic,
                       build_traffic_batch, latency_percentiles, percentile,
                       simulate, simulate_online)

CHUNK = 256


@pytest.fixture(scope="module")
def layers():
    """Deterministic two-layer workload, matching the result-phase suite."""
    key = jax.random.PRNGKey(2)
    return [
        LayerTraffic(jax.random.normal(key, (37, 20)),
                     jax.random.normal(jax.random.fold_in(key, 1),
                                       (37, 20)) * 0.3),
        LayerTraffic(jax.random.normal(jax.random.fold_in(key, 2), (11, 9)),
                     jax.random.normal(jax.random.fold_in(key, 3), (11, 9))),
    ]


@pytest.fixture(scope="module")
def cfg():
    return NocConfig(rows=4, cols=4, mc_nodes=(0, 15), num_vcs=3, lanes=8)


@pytest.fixture(scope="module")
def phase_traffic(layers, cfg):
    """One inference's request + result traffic (O1, full precision)."""
    variants = [(by_name("O1"), None)]
    req = build_traffic_batch(layers, cfg, variants,
                              max_packets_per_layer=10).variant(0)
    res = build_result_traffic(layers, cfg, variants,
                               max_packets_per_layer=10,
                               result_window=6).variant(0)
    return req, res


def test_zero_latency_single_inference_is_offline_bit_identical(
        cfg, phase_traffic):
    """The oracle: one inference, zero compute latency -> the closed loop's
    request and result phases equal the offline ``simulate`` drains
    bit for bit (BT totals, per-link recorders, drain cycles), and the
    gated request schedule - every gate open from cycle 0 - IS the offline
    drain."""
    req, res = phase_traffic
    off_req = simulate(cfg, req, chunk=CHUNK, check_conservation=True)
    off_res = simulate(cfg, res, chunk=CHUNK, check_conservation=True,
                       mc_nodes=np.asarray(cfg.pe_nodes))

    onl = simulate_online(cfg, req, res, arrivals=[0], compute_latency=0,
                          chunk=CHUNK, check_conservation=True)

    # reported (canonical) phase BT: bit-identical to offline
    assert onl.request.total_bt == off_req.total_bt
    np.testing.assert_array_equal(onl.request.link_bt, off_req.link_bt)
    assert onl.request.drain_cycle == off_req.drain_cycle
    assert onl.result.total_bt == off_res.total_bt
    np.testing.assert_array_equal(onl.result.link_bt, off_res.link_bt)
    assert onl.result.drain_cycle == off_res.drain_cycle

    # the gated schedule itself reduces to the offline drain at gate-open-0
    assert onl.sched_request.total_bt == off_req.total_bt
    np.testing.assert_array_equal(onl.sched_request.link_bt,
                                  off_req.link_bt)
    assert onl.request_drain_cycle == off_req.drain_cycle
    assert onl.sched_request.ejected == off_req.injected

    # timing ledger sanity: one inference, completion after both phases
    assert onl.truncated == 0
    assert onl.completions.shape == (1,)
    assert int(onl.completions[0]) == onl.result_drain_cycle
    assert int(onl.latencies[0]) == int(onl.completions[0])


def test_nonzero_latency_shifts_timing_never_bt(cfg, phase_traffic):
    """The negative arm of the oracle: compute latency delays the gated
    result drain (timing is schedule-determined) but every reported BT
    total and recorder is unchanged (BT is data-determined)."""
    req, res = phase_traffic
    base = simulate_online(cfg, req, res, arrivals=[0], compute_latency=0,
                           chunk=CHUNK)
    slow = simulate_online(cfg, req, res, arrivals=[0],
                           compute_latency=100, chunk=CHUNK)

    assert slow.result_drain_cycle > base.result_drain_cycle
    assert int(slow.latencies[0]) > int(base.latencies[0])
    # request network never waits on compute
    assert slow.request_drain_cycle == base.request_drain_cycle

    assert slow.request.total_bt == base.request.total_bt
    assert slow.result.total_bt == base.result.total_bt
    np.testing.assert_array_equal(slow.request.link_bt,
                                  base.request.link_bt)
    np.testing.assert_array_equal(slow.result.link_bt, base.result.link_bt)


def test_overlapped_inferences_conserve_and_complete(cfg, phase_traffic):
    """Back-to-back inferences through the mesh: every packet of every
    inference ejects exactly once (conservation enforced on the
    concatenated gated drains) and per-inference completions respect
    arrival order under queueing."""
    req, res = phase_traffic
    onl = simulate_online(cfg, req, res,
                          arrivals=ArrivalProcess("poisson", 6.0, seed=5),
                          num_inferences=4, compute_latency=20,
                          chunk=CHUNK, check_conservation=True)
    assert onl.truncated == 0
    assert onl.completed == 4
    assert (onl.latencies > 0).all()
    assert (onl.completions > onl.arrivals).all()
    # ledgers cover every packet of every inference
    assert (onl.request_eject_time >= 0).all()
    assert (onl.result_eject_time >= 0).all()
    assert onl.throughput > 0


def test_backtoback_saturation_queues(cfg, phase_traffic):
    """The saturation probe: simultaneous arrivals serialize on the mesh,
    so per-inference latency grows with k and throughput beats a sparse
    uniform schedule's."""
    req, res = phase_traffic
    sat = simulate_online(cfg, req, res,
                          arrivals=ArrivalProcess("backtoback"),
                          num_inferences=4, chunk=CHUNK)
    lat = sat.latencies
    assert (np.diff(lat) > 0).all()          # queueing delay accumulates
    sparse = simulate_online(cfg, req, res,
                             arrivals=ArrivalProcess("uniform", 2.0),
                             num_inferences=4, chunk=CHUNK)
    assert sat.throughput > sparse.throughput
    # offered load only stretches the schedule - BT totals are identical
    assert sat.request.total_bt == sparse.request.total_bt
    assert sat.result.total_bt == sparse.result.total_bt


def test_arrival_process_contract():
    """Determinism, spacing, and validation of the arrival processes."""
    a = ArrivalProcess("poisson", 4.0, seed=11).times(16)
    b = ArrivalProcess("poisson", 4.0, seed=11).times(16)
    np.testing.assert_array_equal(a, b)                    # seeded replay
    assert a[0] == 0 and (np.diff(a) >= 0).all()
    c = ArrivalProcess("poisson", 4.0, seed=12).times(16)
    assert not np.array_equal(a, c)

    u = ArrivalProcess("uniform", 2.5).times(5)
    np.testing.assert_array_equal(u, np.floor(np.arange(5) * 400.0))
    np.testing.assert_array_equal(
        ArrivalProcess("backtoback").times(3), np.zeros(3, np.int64))

    with pytest.raises(ValueError, match="kind"):
        ArrivalProcess("burst", 1.0)
    with pytest.raises(ValueError, match="load"):
        ArrivalProcess("uniform", 0.0)
    with pytest.raises(ValueError, match="n >= 1"):
        ArrivalProcess("uniform", 1.0).times(0)


def test_simulate_online_validates_arrivals(cfg, phase_traffic):
    req, res = phase_traffic
    with pytest.raises(ValueError, match="num_inferences"):
        simulate_online(cfg, req, res, arrivals=ArrivalProcess("uniform"))
    with pytest.raises(ValueError, match="non-decreasing"):
        simulate_online(cfg, req, res, arrivals=[5, 3], chunk=CHUNK)
    with pytest.raises(ValueError, match="disagrees"):
        simulate_online(cfg, req, res, arrivals=[0, 4], num_inferences=3,
                        chunk=CHUNK)
    with pytest.raises(ValueError, match="compute_latency"):
        simulate_online(cfg, req, res, arrivals=[0], compute_latency=-1,
                        chunk=CHUNK)


# --- latency percentile extraction ---------------------------------------


@pytest.mark.parametrize("values", [
    [3.0],                                   # single sample
    [1.0, 2.0, 3.0, 4.0],
    [5.0, 5.0, 5.0, 5.0],                    # all ties
    [2.0, 9.0, 9.0, 9.0, 1.0, 4.0],          # tie block mid-distribution
    list(range(100)),
    [7.5, -2.0, 3.25, 7.5, 100.0],
])
@pytest.mark.parametrize("q", [0.0, 25.0, 50.0, 90.0, 99.0, 100.0])
def test_percentile_matches_numpy(values, q):
    assert percentile(values, q) == pytest.approx(
        float(np.percentile(np.asarray(values, np.float64), q)), abs=1e-12)


def test_percentile_validation():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50.0)
    with pytest.raises(ValueError, match="0, 100"):
        percentile([1.0], 101.0)
    with pytest.raises(ValueError, match="0, 100"):
        percentile([1.0], -0.5)


def test_latency_percentiles_reports_truncation():
    """In-flight-at-cutoff entries (-1) are excluded from the percentiles
    but surfaced as ``truncated`` - never silently dropped."""
    lat = np.asarray([10, -1, 30, 20, -1, 40], np.int64)
    out = latency_percentiles(lat)
    assert out["count"] == 4 and out["truncated"] == 2
    done = np.asarray([10, 30, 20, 40], np.float64)
    assert out["p50"] == pytest.approx(float(np.percentile(done, 50)))
    assert out["p99"] == pytest.approx(float(np.percentile(done, 99)))
    assert out["mean"] == pytest.approx(25.0)
    assert out["max"] == 40

    clean = latency_percentiles(np.asarray([10, 30, 20, 40], np.int64))
    assert clean["truncated"] == 0
    assert clean["p50"] == out["p50"]        # truncation never biases

    single = latency_percentiles(np.asarray([17], np.int64))
    assert single["p50"] == single["p99"] == 17.0

    none_done = latency_percentiles(np.asarray([-1, -1], np.int64))
    assert none_done["count"] == 0 and none_done["truncated"] == 2
    assert none_done["p50"] is None and none_done["mean"] is None

    with pytest.raises(ValueError, match="1-D"):
        latency_percentiles(np.zeros((2, 2), np.int64))
