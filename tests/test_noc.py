"""NoC simulator invariants: conservation, routing, BT recording."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack, bt_stream
from repro.core.wire import by_name
from repro.noc import (NocConfig, PAPER_NOCS, simulate, build_traffic,
                       LayerTraffic)
from repro.noc.sim import Traffic, META_PAYLOAD, META_TAIL
from repro.noc.topology import xy_route, neighbor_table, PORT_LOCAL
from repro.noc import power


def tiny_cfg(**kw):
    return NocConfig(rows=3, cols=3, mc_nodes=(0,), **kw)


def one_packet_traffic(cfg, dest, n_flits=4, seed=0):
    key = jax.random.PRNGKey(seed)
    words = jax.random.randint(key, (1, n_flits, cfg.lanes), 0, 2**31 - 1,
                               jnp.int32).astype(jnp.uint32)
    meta = jnp.full((1, n_flits), META_PAYLOAD, jnp.int32)
    meta = meta.at[0, -1].set(META_PAYLOAD | META_TAIL)
    return Traffic(words=words,
                   dest=jnp.full((1, n_flits), dest, jnp.int32),
                   meta=meta,
                   vc=jnp.zeros((1, n_flits), jnp.int32),
                   pkt=jnp.zeros((1, n_flits), jnp.int32),
                   length=jnp.array([n_flits], jnp.int32))


def test_all_flits_delivered():
    cfg = tiny_cfg()
    tr = one_packet_traffic(cfg, dest=8)
    res = simulate(cfg, tr, chunk=64)
    assert res.ejected == res.injected == 4


def test_xy_route_path_links_touched():
    """A single packet 0 -> 8 on a 3x3 mesh must traverse exactly the X-Y
    path 0 -> 1 -> 2 -> 5 -> 8 (east twice, then south twice)."""
    cfg = tiny_cfg()
    tr = one_packet_traffic(cfg, dest=8, n_flits=2)
    res = simulate(cfg, tr, chunk=64)
    touched = {(r, p) for r in range(9) for p in range(5)
               if res.link_flits[r, p] > 0}
    # PORT_E=1, PORT_S=2, ejection at 8 (PORT_LOCAL=4)
    assert touched == {(0, 1), (1, 1), (2, 2), (5, 2), (8, 4)}


def test_flit_conservation_many_packets():
    cfg = NocConfig(rows=4, cols=4, mc_nodes=(0, 15))
    key = jax.random.PRNGKey(1)
    inp = jax.random.normal(key, (30, 20), jnp.float32)
    wgt = jax.random.normal(jax.random.fold_in(key, 1), (30, 20), jnp.float32)
    tr = build_traffic([LayerTraffic(inp, wgt)], cfg, by_name("O0"))
    res = simulate(cfg, tr, chunk=256)
    assert res.ejected == res.injected
    # every payload flit ejects exactly once: ejection counts = injected
    assert int(res.link_flits[:, PORT_LOCAL].sum()) == res.injected


def test_single_link_bt_matches_recorder_math():
    """With one MC, one VC, and a single destination, the injection NI link
    carries the stream sequentially: its BT must equal core.bt on the same
    word sequence (prepended with the all-zero idle state)."""
    cfg = tiny_cfg(num_vcs=1)
    tr = one_packet_traffic(cfg, dest=8, n_flits=6, seed=3)
    res = simulate(cfg, tr, chunk=64)
    words = np.asarray(tr.words[0])
    stream = jnp.concatenate([jnp.zeros((1, cfg.lanes), jnp.uint32),
                              jnp.asarray(words)])
    expected = int(bt_stream(pack(
        jax.lax.bitcast_convert_type(stream.reshape(-1), jnp.float32),
        cfg.lanes)))
    assert int(res.inj_bt.sum()) == expected


def test_ordering_reduces_bt_on_noc_fixed8():
    """End-to-end paper claim on the smallest NoC: O2 < O0 total BT for
    quantized trained-like weights."""
    from repro.quant import quantize_fixed8
    cfg = PAPER_NOCS["4x4_mc2"]
    key = jax.random.PRNGKey(0)
    inp = jax.random.normal(key, (16, 150), jnp.float32)
    wgt = jax.random.normal(jax.random.fold_in(key, 1), (16, 150)) * 0.2
    wgt = wgt * (jax.random.uniform(jax.random.fold_in(key, 2), wgt.shape) ** 2)
    q = lambda x: quantize_fixed8(x).values
    bt = {}
    for name in ("O0", "O2"):
        tr = build_traffic([LayerTraffic(inp, wgt)], cfg, by_name(name), quantizer=q)
        bt[name] = simulate(cfg, tr, chunk=512, count_headers=False).total_bt
    assert bt["O2"] < bt["O0"]


def test_paper_noc_configs():
    assert PAPER_NOCS["4x4_mc2"].num_mcs == 2
    assert PAPER_NOCS["8x8_mc4"].num_mcs == 4
    assert PAPER_NOCS["8x8_mc8"].num_mcs == 8
    # paper: 112 bidirectional inter-router links on 8x8
    assert PAPER_NOCS["8x8_mc4"].num_inter_router_links == 112


def test_power_model_reproduces_paper_example():
    # 0.173 pJ * 64 bits * 112 links * 125 MHz = 155.008 mW (Sec. V-C)
    assert abs(power.paper_example() - 155.008) < 1e-6
    assert abs(power.paper_example(power.HW.e_bit_banerjee_pj) - 476.672) < 1e-6


def test_net_power_accounting():
    out = power.net_power_saving_mw(64, 0.4085, 112, 4, separated=True)
    assert out["net_saving_mw"] > 0
    assert out["ordering_units_mw"] == pytest.approx(2.213 * 4 * 2)
