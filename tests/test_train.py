"""Training substrate: convergence, microbatching, checkpointing, elastic,
int8 optimizer states, ordered gradient collectives."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenStream, glyph_batch
from repro.models import LM, LMConfig, LeNet, init_params
from repro.optim import AdamW, wsd, cosine, constant
from repro.train import make_train_step, init_state, checkpoint
from repro.train.elastic import choose_mesh, microbatches_for
from repro.dist.ordered_collectives import (order_gradient_bucket,
                                            restore_gradient_bucket,
                                            gradient_wire_report)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = LMConfig("t", n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                   vocab=256)
    model = LM(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    return model, params


def _loss_fn(model):
    def f(p, batch):
        toks, tgt, mask = batch
        return model.loss(p, toks, tgt, mask)
    return f


def test_loss_decreases(tiny_lm):
    model, params = tiny_lm
    stream = TokenStream(vocab=256, seq_len=32, global_batch=8)
    opt = AdamW(wsd(3e-3, 100, warmup=5))
    step = jax.jit(make_train_step(_loss_fn(model), opt))
    state = init_state(params, opt)
    first = last = None
    for i in range(25):
        state, m = step(state, stream.batch(i))
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5


def test_microbatch_equals_full_batch_grad_direction(tiny_lm):
    model, params = tiny_lm
    stream = TokenStream(vocab=256, seq_len=32, global_batch=8)
    opt = AdamW(constant(1e-3))
    s1 = jax.jit(make_train_step(_loss_fn(model), opt))
    s4 = jax.jit(make_train_step(_loss_fn(model), opt, microbatches=4))
    st1, m1 = s1(init_state(params, opt), stream.batch(0))
    st4, m4 = s4(init_state(params, opt), stream.batch(0))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-2
    # resulting params nearly identical (fp32 accumulation differences only)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_int8_optimizer_tracks_fp32(tiny_lm):
    model, params = tiny_lm
    stream = TokenStream(vocab=256, seq_len=32, global_batch=8)
    losses = {}
    for sd in ("fp32", "int8"):
        opt = AdamW(wsd(3e-3, 100, warmup=5), state_dtype=sd)
        step = jax.jit(make_train_step(_loss_fn(model), opt))
        st = init_state(params, opt)
        for i in range(15):
            st, m = step(st, stream.batch(i))
        losses[sd] = float(m["loss"])
    assert abs(losses["int8"] - losses["fp32"]) < 0.25


def test_checkpoint_roundtrip_and_torn_write(tmp_path, tiny_lm):
    model, params = tiny_lm
    opt = AdamW(constant(1e-3))
    state = init_state(params, opt)
    d = str(tmp_path)
    checkpoint.save(d, 3, state)
    checkpoint.save(d, 9, state)
    os.makedirs(os.path.join(d, "step_000000012"))
    with open(os.path.join(d, "step_000000012", "manifest.json"), "w") as f:
        f.write("{torn!")
    got = checkpoint.restore(d, state)
    assert got is not None and got[0] == 9
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got[1])):
        assert bool(jnp.all(a == b))
    assert checkpoint.latest_step(d) == 12 or checkpoint.latest_step(d) == 9


def test_checkpoint_keep_policy(tmp_path, tiny_lm):
    model, params = tiny_lm
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        checkpoint.save(d, s, {"w": jnp.ones((2,))}, keep=2)
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 2 and kept[-1].endswith("000000004")


def test_elastic_mesh_choices():
    assert choose_mesh(512, 16, pods=2) == ((2, 16, 16), ("pod", "data", "model"))
    assert choose_mesh(256, 16) == ((16, 16), ("data", "model"))
    # losing 32 devices -> data axis shrinks, TP intact
    assert choose_mesh(480, 16) == ((30, 16), ("data", "model"))
    with pytest.raises(ValueError):
        choose_mesh(8, 16)
    assert microbatches_for(256, 2, 8) == 16


def test_data_pipeline_shard_determinism():
    stream = TokenStream(vocab=1000, seq_len=16, global_batch=8)
    a = stream.batch(5, shard=2, num_shards=4)
    b = stream.batch(5, shard=2, num_shards=4)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    c = stream.batch(5, shard=3, num_shards=4)
    assert not np.array_equal(np.asarray(a[0]), np.asarray(c[0]))


def test_glyph_task_trainable():
    model = LeNet()
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    opt = AdamW(cosine(2e-3, 60, warmup=5), weight_decay=0.0)
    def loss_fn(p, batch):
        x, y = batch
        return model.loss(p, x, y)
    step = jax.jit(make_train_step(loss_fn, opt))
    st = init_state(params, opt)
    losses = []
    for i in range(60):
        batch = glyph_batch(jax.random.PRNGKey(100 + i), 32)
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.8 * losses[0]
    x, y = glyph_batch(jax.random.PRNGKey(999), 256)
    acc = float(jnp.mean(jnp.argmax(model.forward(st.params, x), -1) == y))
    assert acc > 0.5   # 10-class task, random = 0.1


def test_ordered_bucket_roundtrip():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (1000,), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (1000,), jnp.float32)
    b = order_gradient_bucket(g, w, window=256)
    back = restore_gradient_bucket(b, 1000)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(g))


def test_gradient_wire_report_keys(tiny_lm):
    model, params = tiny_lm
    key = jax.random.PRNGKey(0)
    grads = jax.tree.map(
        lambda p: jax.random.normal(key, p.shape, jnp.float32).astype(p.dtype),
        params)
    rep = gradient_wire_report(grads, params, window=256, lanes=16)
    assert set(rep) >= {"bt_baseline", "reduction_o1", "reduction_o2"}
    assert float(rep["bt_baseline"]) > 0
