"""LR schedules. WSD (warmup-stable-decay) is the MiniCPM schedule the
assigned minicpm-2b config calls for; cosine is the default elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["wsd", "cosine", "constant"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, warmup: int = 100, min_ratio: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos).astype(jnp.float32)
    return fn


def wsd(lr: float, total_steps: int, warmup: int = 100,
        decay_frac: float = 0.1, min_ratio: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM): linear warmup, long stable plateau,
    sharp exponential-style decay over the final ``decay_frac`` of steps."""
    decay_start = int(total_steps * (1 - decay_frac))

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        t = jnp.clip((step - decay_start) / max(total_steps - decay_start, 1), 0.0, 1.0)
        decay = lr * (min_ratio ** t)
        stable = jnp.asarray(lr, jnp.float32)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < decay_start, stable, decay))
        return out.astype(jnp.float32)
    return fn
