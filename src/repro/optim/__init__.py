from .adamw import AdamW, AdamWState
from .schedules import wsd, cosine, constant
from .clip import clip_by_global_norm

__all__ = ["AdamW", "AdamWState", "wsd", "cosine", "constant",
           "clip_by_global_norm"]
