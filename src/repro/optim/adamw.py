"""Functional AdamW with optional block-wise int8 second/first moments.

The int8 state path (``state_dtype='int8'``) is the distributed-optimization
trick that lets kimi-k2 (1T params) fit v5e HBM: m and v are stored as int8
with one fp32 scale per 256-element block (bnb-style), dequantized on the
fly inside the update. States shard exactly like their parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "AdamWState"]

_BLOCK = 256


class AdamWState(NamedTuple):
    step: jax.Array
    m: object     # pytree matching params (fp32 or Q8 pair)
    v: object


class Q8(NamedTuple):
    """Block-quantized moment tensor.

    Blocks run along the LAST axis of the parameter: q has shape
    param.shape[:-1] + (ceil(last/256), 256) and scale drops the final 256.
    This keeps every leading axis identical to the parameter's, so the
    quantized state inherits the parameter's sharding verbatim - a flat
    (-1, 256) layout forces GSPMD to reshard (all-gather) terabytes on the
    1T-param config (EXPERIMENTS.md SSPerf, kimi iteration 2).
    """

    q: jax.Array        # uint8 codes
    scale: jax.Array    # fp32 per-block absmax scales


def _dynamic_table(signed: bool) -> jnp.ndarray:
    """bnb-style dynamic 8-bit code: log-spaced magnitudes so that values
    many orders below the block max still quantize to nonzero - linear
    absmax codes zero them out, which blows up 1/sqrt(v) in Adam."""
    if signed:
        pos = jnp.logspace(-6.0, 0.0, 127)
        return jnp.concatenate([-pos[::-1], jnp.zeros((1,)), pos]).astype(jnp.float32)
    pos = jnp.logspace(-7.0, 0.0, 255)
    return jnp.concatenate([jnp.zeros((1,)), pos]).astype(jnp.float32)


_TABLE_SIGNED = _dynamic_table(True)       # 255 entries
_TABLE_UNSIGNED = _dynamic_table(False)    # 256 entries


def _q8_shape(shape):
    last = shape[-1] if shape else 1
    nb = -(-last // _BLOCK)
    lead = tuple(shape[:-1]) if shape else ()
    return lead + (nb, _BLOCK), lead + (nb,)


def _q8_encode(x: jax.Array, signed: bool) -> Q8:
    table = _TABLE_SIGNED if signed else _TABLE_UNSIGNED
    qshape, sshape = _q8_shape(x.shape)
    last = x.shape[-1] if x.ndim else 1
    pad = qshape[-2] * _BLOCK - last
    xb = x.reshape(x.shape or (1,))
    if pad:
        xb = jnp.pad(xb, [(0, 0)] * (xb.ndim - 1) + [(0, pad)])
    blocks = xb.reshape(qshape)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1), 1e-12)
    y = blocks / scale[..., None]
    # nearest-entry code via midpoint boundaries
    mids = (table[1:] + table[:-1]) * 0.5
    q = jnp.searchsorted(mids, y).astype(jnp.uint8)
    return Q8(q, scale.astype(jnp.float32))


def _q8_decode(s: Q8, shape, signed: bool) -> jax.Array:
    table = _TABLE_SIGNED if signed else _TABLE_UNSIGNED
    vals = table[s.q.astype(jnp.int32)] * s.scale[..., None]
    lead = shape[:-1] if shape else ()
    last = shape[-1] if shape else 1
    flat_last = vals.reshape(lead + (-1,))[..., :last]
    return flat_last.reshape(shape)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr_fn: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "fp32"      # "fp32" | "int8"

    def init(self, params) -> AdamWState:
        if self.state_dtype == "int8":
            def zero_m(p):
                qs, ss = _q8_shape(p.shape)
                # code 127 = 0.0 in the signed table
                return Q8(jnp.full(qs, 127, jnp.uint8),
                          jnp.full(ss, 1e-12, jnp.float32))
            def zero_v(p):
                qs, ss = _q8_shape(p.shape)
                # code 0 = 0.0 in the unsigned table
                return Q8(jnp.zeros(qs, jnp.uint8),
                          jnp.full(ss, 1e-12, jnp.float32))
            m = jax.tree.map(zero_m, params)
            v = jax.tree.map(zero_v, params)
        else:
            zero = lambda p: jnp.zeros(p.shape, jnp.float32)
            m = jax.tree.map(zero, params)
            v = jax.tree.map(zero, params)
        return AdamWState(jnp.zeros((), jnp.int32), m, v)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr_fn(step)
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        q8 = self.state_dtype == "int8"

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            mf = _q8_decode(m, p.shape, signed=True) if q8 else m
            vf = _q8_decode(v, p.shape, signed=False) if q8 else v
            mf = b1 * mf + (1 - b1) * g
            vf = b2 * vf + (1 - b2) * jnp.square(g)
            mhat = mf / c1
            vhat = vf / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decay matrices only, standard practice
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            if q8:
                return new_p, _q8_encode(mf, signed=True), _q8_encode(vf, signed=False)
            return new_p, mf, vf

        leaves_p, tdef = jax.tree.flatten(params)
        leaves_g = tdef.flatten_up_to(grads)
        is_q8 = lambda x: isinstance(x, Q8)
        leaves_m = jax.tree.flatten(state.m, is_leaf=is_q8)[0]
        leaves_v = jax.tree.flatten(state.v, is_leaf=is_q8)[0]
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(leaves_p, leaves_g, leaves_m, leaves_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v)
