"""phi3-medium-14b [dense]: 40L d5120 40H (GQA kv=10) ff17920 vocab=100352.

RoPE + SwiGLU + GQA [arXiv:2404.14219]. kv=10 not divisible by 16 ->
kv replicated, q-heads 40 also not divisible -> head_dim (128) carries TP
for attention; mlp/vocab shard over model.
"""
from .common import lm_arch

ARCH = lm_arch(
    "phi3-medium-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv=10, d_ff=17920, vocab=100352,
    tied_embeddings=False,
    rules_overrides={"head_dim": "model"},
)
