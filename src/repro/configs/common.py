"""Architecture definitions: config + shapes + input specs + shardings.

Each assigned architecture is one ArchDef. ``input_specs(shape)`` returns
ShapeDtypeStruct stand-ins for every input of the function the dry-run
lowers (weak-type-correct, shardable, no allocation). Modality frontends
are stubs: VLM archs get precomputed patch embeddings, audio archs get
precomputed frame embeddings, exactly as assigned.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import (DEFAULT_RULES, Rules, logical_to_pspec,
                                 spec_shardings)
from repro.models import LM, LMConfig, EncDec, EncDecConfig

__all__ = ["SHAPES", "ShapeCell", "ArchDef", "lm_arch"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

_I32 = jnp.int32
_BF16 = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# Cache logical-axes tables, by structure key pattern (see cache_shardings).
# Decode caches shard the SEQUENCE axis over 'model' (vLLM-page style):
# scatter updates stay local and the per-step score reduction is tiny,
# vs. head-dim sharding which forced a full cache rematerialization per
# step (EXPERIMENTS.md SSPerf "decode cache layout": 384x on starcoder2).
_CACHE_RULES: Rules = {
    "batch": "data", "seq": "model", "kv_heads": None, "head_dim": None,
    "state": "model", "heads": "model", "layers": None, "embed": "model",
}


def _cache_axes_for(path: str, rank: int) -> Tuple[Optional[str], ...]:
    """Logical axes of one cache leaf, from its pytree path + rank."""
    if "memory" in path:
        return ("batch", "seq", "embed")
    if "attn" in path or "self" in path or "cross" in path:  # KVCache k/v
        base = ("batch", "seq", "kv_heads", "head_dim")
        return ("layers",) + base if rank == 5 else base
    if "conv" in path:                               # (.., B, width, D)
        base = ("batch", None, "state")
        return ("layers",) + base if rank == 4 else base
    if rank >= 3 and ("mlstm" in path or "slstm" in path):
        # mlstm c (L,B,H,hd,hd) / n (L,B,H,hd) / m (L,B,H); slstm (L,B,D)
        names = ("layers", "batch", "heads", "head_dim", "head_dim")
        return names[:rank] if "mlstm" in path else ("layers", "batch", "state")[:rank]
    if rank == 2:                                    # rec h (B, D)
        return ("batch", "state")
    if rank == 3:                                    # rec h stacked (L, B, D)
        return ("layers", "batch", "state")
    return tuple([None] * rank)


def cache_shardings(cache_abstract, mesh: Mesh):
    """NamedSharding pytree for a decode cache (from jax.eval_shape)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abstract)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        axes = _cache_axes_for(pstr, len(leaf.shape))
        out.append(NamedSharding(
            mesh, logical_to_pspec(axes, leaf.shape, _CACHE_RULES, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass(frozen=True)
class ArchDef:
    name: str
    kind: str                               # "lm" | "encdec"
    config: object                          # LMConfig | EncDecConfig
    rules: Rules
    reduced_config: object                  # small same-family config
    optimizer_state: str = "fp32"           # "int8" for the 1T arch
    notes: str = ""

    def build(self):
        return LM(self.config) if self.kind == "lm" else EncDec(self.config)

    def build_reduced(self):
        return (LM(self.reduced_config) if self.kind == "lm"
                else EncDec(self.reduced_config))

    # -- shape support -------------------------------------------------
    def supports(self, shape_name: str) -> Tuple[bool, str]:
        cell = SHAPES[shape_name]
        if cell.name == "long_500k":
            sub_q = self.config.sub_quadratic
            if not sub_q:
                return False, ("full-attention KV at 500k context is "
                               "unbounded; skipped per assignment policy")
        return True, ""

    # -- abstract inputs -------------------------------------------------
    def input_specs(self, shape_name: str) -> Dict[str, object]:
        """ShapeDtypeStructs for the non-(params/state) inputs of the cell."""
        cell = SHAPES[shape_name]
        cfg = self.config
        b, s = cell.global_batch, cell.seq_len
        if self.kind == "encdec":
            s_dec = max(s // 4, 8)
            if cell.mode == "train":
                return {"frames": _sds((b, s, cfg.d_model), _BF16),
                        "tokens": _sds((b, s_dec), _I32),
                        "targets": _sds((b, s_dec), _I32),
                        "mask": _sds((b, s_dec), jnp.float32)}
            if cell.mode == "prefill":
                return {"frames": _sds((b, s, cfg.d_model), _BF16),
                        "tokens": _sds((b, s_dec), _I32)}
            return {"token": _sds((b,), _I32), "pos": _sds((b,), _I32)}
        # LM family
        if cell.mode == "train":
            specs = {"tokens": _sds((b, s), _I32),
                     "targets": _sds((b, s), _I32),
                     "mask": _sds((b, s), jnp.float32)}
        elif cell.mode == "prefill":
            specs = {"tokens": _sds((b, s), _I32)}
        else:
            specs = {"token": _sds((b,), _I32), "pos": _sds((b,), _I32)}
        if getattr(cfg, "vlm_prefix", 0) and cell.mode != "decode":
            specs["patch_embeds"] = _sds((b, cfg.vlm_prefix, cfg.d_model), _BF16)
        return specs

    def input_shardings(self, specs: Dict[str, object], mesh: Mesh):
        """Batch-sharded inputs (falls back to replication for batch=1).

        Token-like inputs carry a logical 'seq' second axis so a per-arch
        rule can turn on sequence parallelism (None under default rules).
        """
        out = {}
        for k, v in specs.items():
            axes = ("batch",) + tuple([None] * (len(v.shape) - 1))
            if k in ("tokens", "targets", "mask", "frames") and len(v.shape) >= 2:
                axes = ("batch", "seq") + tuple([None] * (len(v.shape) - 2))
            out[k] = NamedSharding(
                mesh, logical_to_pspec(axes, v.shape, self.rules, mesh))
        return out

    def param_shardings(self, mesh: Mesh):
        return spec_shardings(self.build().specs(), self.rules, mesh)


def lm_arch(name: str, *, reduced_overrides: Optional[dict] = None,
            rules_overrides: Optional[dict] = None,
            optimizer_state: str = "fp32", notes: str = "",
            **cfg_kw) -> ArchDef:
    cfg = LMConfig(name=name, **cfg_kw)
    red_kw = dict(cfg_kw)
    pattern = cfg_kw.get("pattern", ("attn",))
    red_kw.update({
        "n_layers": max(2 * len(pattern), 2),
        "d_model": 128,
        "n_heads": 4, "n_kv": min(cfg_kw.get("n_kv", 4), 4),
        "d_ff": 256 if cfg_kw.get("d_ff", 0) else 0,
        "vocab": 512,
    })
    if cfg_kw.get("n_experts"):
        red_kw["n_experts"] = 4
        red_kw["top_k"] = min(cfg_kw.get("top_k", 2), 2)
    if cfg_kw.get("window"):
        red_kw["window"] = 16
    if cfg_kw.get("vlm_prefix"):
        red_kw["vlm_prefix"] = 8
    if cfg_kw.get("kv_chunk"):
        red_kw["kv_chunk"] = 0
    if cfg_kw.get("head_dim"):
        red_kw["head_dim"] = 32
    red_kw.update(reduced_overrides or {})
    rules = dict(DEFAULT_RULES)
    rules.update(rules_overrides or {})
    return ArchDef(name=name, kind="lm", config=cfg, rules=rules,
                   reduced_config=LMConfig(name=name + "-reduced", **red_kw),
                   optimizer_state=optimizer_state, notes=notes)
