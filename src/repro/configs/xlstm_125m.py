"""xlstm-125m [ssm]: 12L d768 4H ff0 vocab 50304 - alternating
sLSTM/mLSTM blocks [arXiv:2405.04517]. d_ff=0: blocks carry their own
projections, no separate MLP. Recurrent state is O(1) in context ->
long_500k runs.
"""
from .common import lm_arch

ARCH = lm_arch(
    "xlstm-125m",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    pattern=("mlstm", "slstm"), tied_embeddings=True,
    reduced_overrides={"n_layers": 4},
)
