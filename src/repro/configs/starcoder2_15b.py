"""starcoder2-15b [dense]: 40L d6144 48H (GQA kv=4) ff24576 vocab=49152.

GQA + RoPE, ungated (GELU) MLP [arXiv:2402.19173; hf]. 48 heads / 16 = 3.
"""
from .common import lm_arch

ARCH = lm_arch(
    "starcoder2-15b",
    n_layers=40, d_model=6144, n_heads=48, n_kv=4, d_ff=24576, vocab=49152,
    gated_mlp=False, tied_embeddings=False,
)
