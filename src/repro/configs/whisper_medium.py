"""whisper-medium [audio]: enc-dec, 24+24L d1024 16H (kv=16) ff4096 vocab=51865.

Conv frontend is a STUB: input_specs() supplies precomputed frame
embeddings (B, S, 1024) [arXiv:2212.04356]. GELU MLP, learned positions,
full (not causal) encoder attention, causal decoder with cross-attention.
"""
import dataclasses
from repro.models import EncDecConfig
from repro.dist.sharding import DEFAULT_RULES
from .common import ArchDef

_CFG = EncDecConfig("whisper-medium", n_layers=24, d_model=1024, n_heads=16,
                    n_kv=16, d_ff=4096, vocab=51865)
_REDUCED = EncDecConfig("whisper-medium-reduced", n_layers=2, d_model=128,
                        n_heads=4, n_kv=4, d_ff=256, vocab=512)

ARCH = ArchDef(name="whisper-medium", kind="encdec", config=_CFG,
               rules=dict(DEFAULT_RULES), reduced_config=_REDUCED,
               notes="enc-dec; audio frontend stubbed as frame embeddings")
