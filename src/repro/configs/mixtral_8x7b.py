"""mixtral-8x7b [moe]: 32L d4096 32H (GQA kv=8) ff14336, 8 experts top-2,
SWA window 4096 [arXiv:2401.04088; hf].

8 experts < 16-way model axis -> experts replicate and TP shards the expert
ff dim instead (14336/16 = 896), the standard small-expert-count layout.
"""
from .common import lm_arch

ARCH = lm_arch(
    "mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, window=4096, tied_embeddings=False,
    rules_overrides={"experts": None},
)
