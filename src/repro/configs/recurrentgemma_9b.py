"""recurrentgemma-9b [hybrid]: 38L d4096 16H (MQA kv=1) ff12288 vocab 256000.

RG-LRU + local attention at 1:2 ratio [arXiv:2402.19427]: pattern
(rec, rec, attn) x 12 groups + 2 tail recurrent blocks = 38. Local window
2048 + O(1) recurrent state -> long_500k runs. The RG-LRU time axis is NOT
order-invariant (DESIGN.md SSArch-applicability).
"""
from .common import lm_arch

ARCH = lm_arch(
    "recurrentgemma-9b",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288, vocab=256000,
    pattern=("rec", "rec", "attn"), window=2048, tied_embeddings=True,
    reduced_overrides={"n_layers": 8},
)
