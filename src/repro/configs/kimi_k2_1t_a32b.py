"""kimi-k2-1t-a32b [moe]: 61L d7168 64H (GQA kv=8) expert-ff 2048,
384 experts top-8, vocab 163840 - trillion-parameter MoE (paper-table)
[arXiv:2501.kimi2].

The memory plan that fits 16 GB/chip v5e at 512 chips (EXPERIMENTS.md
SS Dry-run): experts shard over 'model' (384/16 = 24 per group), every other
large dim FSDP-shards over 'data' via the 'embed'->data rule (ZeRO-3 style,
gathered per scanned layer), and optimizer moments are block-wise int8
(repro.optim.adamw). bf16 params ~2.06 TB -> ~4 GB/chip resident.
"""
from .common import lm_arch

ARCH = lm_arch(
    "kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv=8, d_ff=2048, vocab=163840,
    n_experts=384, top_k=8, tied_embeddings=False, capacity_factor=1.0,
    rules_overrides={"embed": "data", "mlp": None, "kv_heads": None,
                     "head_dim": None},
    optimizer_state="int8",
    notes="1T MoE; EP over model axis, FSDP over data axis, int8 Adam moments",
)
