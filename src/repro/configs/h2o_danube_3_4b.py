"""h2o-danube-3-4b [dense]: 24L d3840 32H (GQA kv=8) ff10240 vocab=32000.

llama+mistral mix with sliding-window attention [arXiv:2401.16818].
SWA (window 4096) bounds the KV cache -> long_500k runs for this arch.
"""
from .common import lm_arch

ARCH = lm_arch(
    "h2o-danube-3-4b",
    n_layers=24, d_model=3840, n_heads=32, n_kv=8, d_ff=10240, vocab=32000,
    window=4096, tied_embeddings=False,
)
