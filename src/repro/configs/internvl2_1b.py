"""internvl2-1b [vlm]: 24L d896 14H (GQA kv=2) ff4864 vocab=151655.

InternViT + InternLM2 backbone [arXiv:2404.16821; hf]. The ViT frontend is
a STUB: input_specs() supplies 256 precomputed patch embeddings which the
backbone projects and prepends. 14 heads/d896 are too narrow for 16-way TP
-> only mlp (4864) and vocab shard; everything else replicates (a 1B model
needs no more).
"""
from .common import lm_arch

ARCH = lm_arch(
    "internvl2-1b",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, d_ff=4864, vocab=151655,
    vlm_prefix=256, tied_embeddings=True,
)
