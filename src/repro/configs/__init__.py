"""Architecture registry: the 10 assigned archs + the paper's own DNNs.

``get(name)`` -> ArchDef; ``ARCHS`` lists every selectable --arch id.
"""
from .common import ArchDef, SHAPES, ShapeCell, cache_shardings

from .minicpm_2b import ARCH as minicpm_2b
from .phi3_medium_14b import ARCH as phi3_medium_14b
from .starcoder2_15b import ARCH as starcoder2_15b
from .h2o_danube_3_4b import ARCH as h2o_danube_3_4b
from .internvl2_1b import ARCH as internvl2_1b
from .whisper_medium import ARCH as whisper_medium
from .kimi_k2_1t_a32b import ARCH as kimi_k2_1t_a32b
from .mixtral_8x7b import ARCH as mixtral_8x7b
from .recurrentgemma_9b import ARCH as recurrentgemma_9b
from .xlstm_125m import ARCH as xlstm_125m

ARCHS = {a.name: a for a in [
    minicpm_2b, phi3_medium_14b, starcoder2_15b, h2o_danube_3_4b,
    internvl2_1b, whisper_medium, kimi_k2_1t_a32b, mixtral_8x7b,
    recurrentgemma_9b, xlstm_125m,
]}


def get(name: str) -> ArchDef:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ArchDef", "SHAPES", "ShapeCell", "ARCHS", "get", "cache_shardings"]
