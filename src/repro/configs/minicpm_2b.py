"""minicpm-2b [dense]: 40L d2304 36H (kv=36, full MHA) ff5760 vocab=122753.

WSD schedule, llama-like, tied embeddings [arXiv:2404.06395; hf]. 36 heads
do not divide the 16-way model axis, so TP lands on mlp/vocab and the heads
stay replicated (the sharding rules' divisibility fallback).
"""
from .common import lm_arch

ARCH = lm_arch(
    "minicpm-2b",
    n_layers=40, d_model=2304, n_heads=36, n_kv=36, d_ff=5760, vocab=122753,
    tied_embeddings=True,
    notes="WSD schedule (repro.optim.schedules.wsd); llama-like dense",
)
