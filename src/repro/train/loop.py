"""Train-step factory: loss -> grads -> clip -> AdamW, with optional
microbatch gradient accumulation and gradient-wire BT telemetry.

``make_train_step`` returns a pure function suitable for jax.jit/pjit -
the dry-run lowers exactly this function at full scale, so everything the
production step does (optimizer included) is in the compiled HLO and hence
in the roofline numbers.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamW, clip_by_global_norm
from repro.dist.ordered_collectives import gradient_wire_report

__all__ = ["TrainState", "make_train_step", "init_state"]


class TrainState(NamedTuple):
    params: object
    opt: object


def init_state(params, optimizer: AdamW) -> TrainState:
    return TrainState(params, optimizer.init(params))


def make_train_step(loss_fn: Callable, optimizer: AdamW, *,
                    max_grad_norm: float = 1.0,
                    microbatches: int = 1,
                    wire_telemetry: bool = False):
    """loss_fn(params, batch) -> scalar. Returns step(state, batch) ->
    (state, metrics). ``microbatches`` > 1 splits the batch on axis 0 and
    accumulates grads in fp32 (activation-memory lever for the hillclimb).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def accumulate(params, batch):
        def micro(i, carry):
            acc, loss_acc = carry
            mb = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // microbatches),
                    x.shape[0] // microbatches, 0), batch)
            loss, g = grads_of(params, mb)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            return acc, loss_acc + loss
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, loss_sum = jax.lax.fori_loop(
            0, microbatches, micro, (zero, jnp.zeros((), jnp.float32)))
        g = jax.tree.map(lambda a: a / microbatches, acc)
        return loss_sum / microbatches, g

    def step(state: TrainState, batch):
        if microbatches > 1:
            loss, grads = accumulate(state.params, batch)
        else:
            loss, grads = grads_of(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": optimizer.lr_fn(state.opt.step + 1)}
        if wire_telemetry:
            metrics["wire"] = gradient_wire_report(grads, state.params)
        return TrainState(new_params, new_opt), metrics

    return step
