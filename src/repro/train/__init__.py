from .loop import TrainState, make_train_step, init_state
from . import checkpoint, elastic

__all__ = ["TrainState", "make_train_step", "init_state", "checkpoint", "elastic"]
