"""Fault-tolerant checkpointing: atomic, manifest-driven, shard-aware.

Layout of one checkpoint:
    <dir>/step_000123.tmp/...      (written first)
    <dir>/step_000123/             (atomic rename once complete)
        manifest.json              step, leaf paths, shapes/dtypes, host count
        host0000.npz               flat leaf arrays owned by this host

Restore picks the newest directory whose manifest is complete and whose
arrays all load - a torn write (killed mid-save) is skipped, which is the
crash-consistency property the multi-node story needs. On multi-host
deployments each host writes its own npz of locally-addressable shards;
in this single-host container there is one file, same format.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         host_id: int = 0, num_hosts: int = 1) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, f"host{host_id:04d}.npz"), **flat)
    manifest = {
        "step": step,
        "num_hosts": num_hosts,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if _STEP_RE.match(d))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def _try_load(path: str) -> Optional[dict]:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = {}
        for host in range(manifest["num_hosts"]):
            with np.load(os.path.join(path, f"host{host:04d}.npz")) as z:
                for k in z.files:
                    arr = z[k]
                    if arr.dtype.kind == "V":
                        # numpy stores ml_dtypes (bfloat16 etc.) as raw void;
                        # reinterpret via the dtype recorded in the manifest.
                        arr = arr.view(jax.numpy.dtype(manifest["dtypes"][k]))
                    data[k] = arr
        if sorted(data.keys()) != manifest["keys"]:
            return None
        return {"step": manifest["step"], "data": data}
    except Exception:
        return None


def restore(ckpt_dir: str, tree_like) -> Optional[Tuple[int, Any]]:
    """Load the newest intact checkpoint into the structure of ``tree_like``.

    Returns (step, tree) or None. Corrupt/torn checkpoints are skipped in
    favor of the next-newest intact one (crash consistency).
    """
    if not os.path.isdir(ckpt_dir):
        return None
    candidates = sorted((d for d in os.listdir(ckpt_dir) if _STEP_RE.match(d)),
                        reverse=True)
    for cand in candidates:
        loaded = _try_load(os.path.join(ckpt_dir, cand))
        if loaded is None:
            continue
        flat_ref = _flatten(tree_like)
        if sorted(flat_ref.keys()) != sorted(loaded["data"].keys()):
            continue
        leaves_ref, treedef = jax.tree_util.tree_flatten(tree_like)
        paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
        new_leaves = []
        for (path, ref) in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = loaded["data"][key]
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            new_leaves.append(jax.numpy.asarray(arr))
        return loaded["step"], jax.tree_util.tree_unflatten(treedef, new_leaves)
    return None
