"""Elastic scaling + straggler policy.

The contract at 1000+ node scale:

  * **Checkpoint/restart** - repro.train.checkpoint gives crash-consistent
    restore; the launcher restores the newest intact step on every (re)start.
  * **Elastic re-mesh** - ``choose_mesh`` picks a (data, model) factorization
    for whatever device count survives, holding the model axis fixed (TP
    degree is a property of the weights' layout) and flexing the data axis.
    Because the data pipeline is addressable by (step, shard), a re-meshed
    job recomputes shard assignments with no data loss.
  * **Straggler mitigation** - deterministic shard regeneration means a
    slow/failed host's shard can be re-issued to any spare host; combined
    with grad-accumulation the global batch stays constant when the data
    axis shrinks (``microbatches_for`` below).
"""
from __future__ import annotations

from typing import Optional, Tuple

__all__ = ["choose_mesh", "microbatches_for"]


def choose_mesh(n_devices: int, model_parallel: int = 16,
                pods: Optional[int] = None) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (data, model) mesh that fits ``n_devices``.

    Keeps the model axis fixed and uses the largest data axis such that
    data * model <= n_devices (dropped devices idle until replaced - the
    standard elastic policy when TP groups must stay intact).
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"need at least one full TP group ({model_parallel} devices), "
            f"got {n_devices}")
    data = n_devices // model_parallel
    if pods is not None and pods > 1:
        if data % pods:
            data = (data // pods) * pods
        return (pods, data // pods, model_parallel), ("pod", "data", "model")
    return (data, model_parallel), ("data", "model")


def microbatches_for(global_batch: int, per_device_batch: int,
                     data_axis: int) -> int:
    """Grad-accumulation factor keeping the global batch constant when the
    data axis shrinks (elastic downscale)."""
    per_step = per_device_batch * data_axis
    if global_batch % per_step:
        raise ValueError(
            f"global batch {global_batch} not divisible by "
            f"data_axis*per_device = {per_step}")
    return global_batch // per_step
