"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM (mLSTM/sLSTM).

Training paths use lax.associative_scan where the recurrence is linear
(RG-LRU) and lax.scan otherwise (mLSTM/sLSTM exponential gating). Decode
paths are single-step state updates - these archs are the sub-quadratic
ones that make the ``long_500k`` shape feasible (state is O(1) in context).

DESIGN.md notes: the time axis of these recurrences is NOT order-invariant,
so the paper's transmission ordering applies only to their weight streams
and channel dimensions, never to the sequence axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .spec import ParamSpec

_F32 = jnp.float32

__all__ = [
    "rglru_specs", "rglru_scan", "rglru_step", "RGLRUState",
    "conv1d_specs", "causal_conv1d", "causal_conv1d_step",
    "mlstm_specs", "mlstm_scan", "mlstm_step", "MLSTMState",
    "slstm_specs", "slstm_scan", "slstm_step", "SLSTMState",
]


# ---------------------------------------------------------------------------
# RG-LRU: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
# ---------------------------------------------------------------------------

class RGLRUState(NamedTuple):
    h: jax.Array      # (B, D)


def rglru_specs(d: int) -> dict:
    return {
        "wa": ParamSpec((d, d), ("embed", "state")),
        "ba": ParamSpec((d,), ("state",), init="zeros", dtype=jnp.float32),
        "wx": ParamSpec((d, d), ("embed", "state")),
        "bx": ParamSpec((d,), ("state",), init="zeros", dtype=jnp.float32),
        # log-space decay parameter Lambda; init so a ~ 0.9..0.999
        "lam": ParamSpec((d,), ("state",), init="ones", dtype=jnp.float32),
    }


_C = 8.0  # Griffin's fixed exponent scale


def _rglru_gates(params, x):
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x.astype(_F32),
                                  params["wa"].astype(_F32)) + params["ba"])
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x.astype(_F32),
                                  params["wx"].astype(_F32)) + params["bx"])
    # a_t = a^(c*r_t) with log a = log sigmoid(Lambda) < 0 (Griffin Eq. 4)
    log_a = _C * r * (-jax.nn.softplus(-params["lam"]))
    a = jnp.exp(log_a)
    gated_x = i * x.astype(_F32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-9)) * gated_x
    return a, b


def rglru_scan(params, x: jax.Array) -> jax.Array:
    """x (B, S, D) -> (B, S, D); parallel associative scan over time."""
    a, b = _rglru_gates(params, x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    return b_s.astype(x.dtype)


def rglru_step(params, x: jax.Array, state: RGLRUState):
    """x (B, D) one step."""
    a, b = _rglru_gates(params, x)
    h = a * state.h + b
    return h.astype(x.dtype), RGLRUState(h)


# ---------------------------------------------------------------------------
# Causal temporal conv (width w), used in Griffin and xLSTM blocks
# ---------------------------------------------------------------------------

def conv1d_specs(d: int, width: int = 4) -> dict:
    return {
        "w": ParamSpec((width, d), (None, "state"), dtype=jnp.bfloat16),
        "b": ParamSpec((d,), ("state",), init="zeros", dtype=jnp.bfloat16),
    }


def causal_conv1d(params, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over time: x (B, S, D)."""
    w = params["w"]
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    return out + params["b"]


def causal_conv1d_step(params, x: jax.Array, history: jax.Array):
    """x (B, D), history (B, width-1, D) -> (out (B, D), new history)."""
    w = params["w"]
    width = w.shape[0]
    window = jnp.concatenate([history, x[:, None, :]], axis=1)  # (B, width, D)
    out = jnp.einsum("bwd,wd->bd", window, w) + params["b"]
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# mLSTM: matrix memory C_t = f C_{t-1} + i v k^T (exponential gating)
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: jax.Array     # (B, H, hd, hd)  memory matrix (value x key)
    n: jax.Array     # (B, H, hd)      normalizer
    m: jax.Array     # (B, H)          gate stabilizer (log space)


def mlstm_specs(d: int, n_heads: int) -> dict:
    hd = d // n_heads
    return {
        "wq": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wi": ParamSpec((d, n_heads), ("embed", "heads"), dtype=jnp.float32),
        "wf": ParamSpec((d, n_heads), ("embed", "heads"), dtype=jnp.float32),
        "wo_gate": ParamSpec((d, d), ("embed", "state")),
    }


def _mlstm_qkv(params, x):
    q = jnp.einsum("...d,dnh->...nh", x, params["wq"])
    k = jnp.einsum("...d,dnh->...nh", x, params["wk"])
    v = jnp.einsum("...d,dnh->...nh", x, params["wv"])
    i_pre = jnp.einsum("...d,dn->...n", x.astype(_F32), params["wi"])
    f_pre = jnp.einsum("...d,dn->...n", x.astype(_F32), params["wf"])
    return q, k, v, i_pre, f_pre


def _mlstm_cell(state: MLSTMState, q, k, v, i_pre, f_pre, hd):
    """One stabilized mLSTM step; all (B, H, ...) fp32."""
    log_f = -jax.nn.softplus(-f_pre)                  # log sigmoid(f)
    m_new = jnp.maximum(log_f + state.m, i_pre)
    f_st = jnp.exp(log_f + state.m - m_new)
    i_st = jnp.exp(i_pre - m_new)
    kn = k * (hd ** -0.5)
    c_new = f_st[..., None, None] * state.c + i_st[..., None, None] * (
        v[..., :, None] * kn[..., None, :])
    n_new = f_st[..., None] * state.n + i_st[..., None] * kn
    num = jnp.einsum("bhvk,bhk->bhv", c_new, q)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return h, MLSTMState(c_new, n_new, m_new)


def mlstm_init_state(b: int, h: int, hd: int) -> MLSTMState:
    return MLSTMState(jnp.zeros((b, h, hd, hd), _F32),
                      jnp.zeros((b, h, hd), _F32),
                      jnp.full((b, h), -1e30, _F32))


def mlstm_scan(params, x: jax.Array, n_heads: int) -> jax.Array:
    """x (B, S, D) -> (B, S, D) via scan over time."""
    b, s, d = x.shape
    hd = d // n_heads
    q, k, v, i_pre, f_pre = _mlstm_qkv(params, x)

    def body(state, xs):
        qt, kt, vt, it, ft = xs
        h, state = _mlstm_cell(state, qt.astype(_F32), kt.astype(_F32),
                               vt.astype(_F32), it, ft, hd)
        return state, h

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    _, hs = jax.lax.scan(body, mlstm_init_state(b, n_heads, hd), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x.astype(_F32),
                                  params["wo_gate"].astype(_F32)))
    return (o * h).astype(x.dtype)


def mlstm_step(params, x: jax.Array, state: MLSTMState, n_heads: int):
    """x (B, D) one decode step."""
    b, d = x.shape
    hd = d // n_heads
    q, k, v, i_pre, f_pre = _mlstm_qkv(params, x)
    h, state = _mlstm_cell(state, q.astype(_F32), k.astype(_F32),
                           v.astype(_F32), i_pre, f_pre, hd)
    o = jax.nn.sigmoid(jnp.einsum("bd,de->be", x.astype(_F32),
                                  params["wo_gate"].astype(_F32)))
    return (o * h.reshape(b, d)).astype(x.dtype), state


# ---------------------------------------------------------------------------
# sLSTM: scalar memory with exponential gating + recurrent h feedback
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array     # (B, D)
    n: jax.Array     # (B, D)
    m: jax.Array     # (B, D)
    h: jax.Array     # (B, D)


def slstm_specs(d: int, n_heads: int) -> dict:
    hd = d // n_heads
    # recurrent R matrices are head-wise block diagonal: (H, hd, hd)
    return {
        "wz": ParamSpec((d, d), ("embed", "state")),
        "wi": ParamSpec((d, d), ("embed", "state"), dtype=jnp.float32),
        "wf": ParamSpec((d, d), ("embed", "state"), dtype=jnp.float32),
        "wo": ParamSpec((d, d), ("embed", "state")),
        "rz": ParamSpec((n_heads, hd, hd), ("heads", None, None)),
        "ri": ParamSpec((n_heads, hd, hd), ("heads", None, None), dtype=jnp.float32),
        "rf": ParamSpec((n_heads, hd, hd), ("heads", None, None), dtype=jnp.float32),
        "ro": ParamSpec((n_heads, hd, hd), ("heads", None, None)),
    }


def _headwise(r, h, n_heads):
    b, d = h.shape
    hd = d // n_heads
    hh = h.reshape(b, n_heads, hd)
    return jnp.einsum("bnh,nhk->bnk", hh, r.astype(h.dtype)).reshape(b, d)


def slstm_cell(params, x, state: SLSTMState, n_heads: int):
    """x (B, D) fp32 preactivations; returns (h, new state)."""
    xf = x.astype(_F32)
    hprev = state.h
    z_pre = xf @ params["wz"].astype(_F32) + _headwise(params["rz"], hprev, n_heads).astype(_F32)
    i_pre = xf @ params["wi"] + _headwise(params["ri"], hprev, n_heads)
    f_pre = xf @ params["wf"] + _headwise(params["rf"], hprev, n_heads)
    o_pre = xf @ params["wo"].astype(_F32) + _headwise(params["ro"], hprev, n_heads).astype(_F32)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + state.m, i_pre)
    i_st = jnp.exp(i_pre - m_new)
    f_st = jnp.exp(log_f + state.m - m_new)
    c_new = f_st * state.c + i_st * z
    n_new = f_st * state.n + i_st
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return h_new, SLSTMState(c_new, n_new, m_new, h_new)


def slstm_init_state(b: int, d: int) -> SLSTMState:
    z = jnp.zeros((b, d), _F32)
    return SLSTMState(z, z, jnp.full((b, d), -1e30, _F32), z)


def slstm_scan(params, x: jax.Array, n_heads: int) -> jax.Array:
    b, s, d = x.shape

    def body(state, xt):
        h, state = slstm_cell(params, xt, state, n_heads)
        return state, h

    _, hs = jax.lax.scan(body, slstm_init_state(b, d), x.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2).astype(x.dtype)


def slstm_step(params, x: jax.Array, state: SLSTMState, n_heads: int):
    h, state = slstm_cell(params, x, state, n_heads)
    return h.astype(x.dtype), state
