"""LeNet and a DarkNet-like CNN - the paper's evaluated DNN workloads.

These run forward *and* training on CPU (the paper uses trained LeNet
weights), and expose ``layer_traffic()`` which turns one inference into the
(input, weight) operand streams the NoC injects (Sec. V-B). Float32
end-to-end; the fixed-8 path quantizes at the memory controller
(repro.quant).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .spec import ParamSpec
from repro.noc.traffic import LayerTraffic, conv_layer_traffic, linear_layer_traffic

_F32 = jnp.float32

__all__ = ["LeNet", "DarkNetLike"]


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x[None] if x.ndim == 3 else x, w, (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y + b
    return y[0] if x.ndim == 3 else y


def _pool(x, k=2):
    nd = x.ndim
    if nd == 3:
        x = x[None]
    y = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, k, k, 1), (1, k, k, 1), "VALID")
    return y[0] if nd == 3 else y


class LeNet:
    """Classic LeNet-5: 32x32x1 -> conv6@5 -> pool -> conv16@5 -> pool
    -> fc120 -> fc84 -> fc10. ~61.7k parameters."""

    input_shape = (32, 32, 1)
    n_classes = 10

    def specs(self) -> dict:
        return {
            "c1w": ParamSpec((5, 5, 1, 6), (None, None, "conv_in", "conv_out"), dtype=_F32),
            "c1b": ParamSpec((6,), ("conv_out",), init="zeros", dtype=_F32),
            "c2w": ParamSpec((5, 5, 6, 16), (None, None, "conv_in", "conv_out"), dtype=_F32),
            "c2b": ParamSpec((16,), ("conv_out",), init="zeros", dtype=_F32),
            "f1w": ParamSpec((400, 120), ("mlp", "embed"), dtype=_F32),
            "f1b": ParamSpec((120,), ("embed",), init="zeros", dtype=_F32),
            "f2w": ParamSpec((120, 84), ("mlp", "embed"), dtype=_F32),
            "f2b": ParamSpec((84,), ("embed",), init="zeros", dtype=_F32),
            "f3w": ParamSpec((84, 10), ("mlp", "embed"), dtype=_F32),
            "f3b": ParamSpec((10,), ("embed",), init="zeros", dtype=_F32),
        }

    def activations(self, params, x: jax.Array) -> List[jax.Array]:
        """Per-layer INPUT activations for one image (H, W, C)."""
        acts = [x]
        h = jnp.tanh(_conv(x, params["c1w"], params["c1b"]))
        h = _pool(h)
        acts.append(h)
        h = jnp.tanh(_conv(h, params["c2w"], params["c2b"]))
        h = _pool(h)
        h = h.reshape(-1)
        acts.append(h)
        h = jnp.tanh(h @ params["f1w"] + params["f1b"])
        acts.append(h)
        h = jnp.tanh(h @ params["f2w"] + params["f2b"])
        acts.append(h)
        return acts

    def forward(self, params, x: jax.Array) -> jax.Array:
        """Batched forward: x (B, 32, 32, 1) -> logits (B, 10)."""
        h = jnp.tanh(_conv(x, params["c1w"], params["c1b"]))
        h = _pool(h)
        h = jnp.tanh(_conv(h, params["c2w"], params["c2b"]))
        h = _pool(h)
        h = h.reshape(h.shape[0], -1)
        h = jnp.tanh(h @ params["f1w"] + params["f1b"])
        h = jnp.tanh(h @ params["f2w"] + params["f2b"])
        return h @ params["f3w"] + params["f3b"]

    def loss(self, params, x, y):
        logits = self.forward(params, x)
        return -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), y[:, None], axis=1))

    def layer_traffic(self, params, x: jax.Array) -> List[LayerTraffic]:
        """The operand streams one inference injects into the NoC."""
        a = self.activations(params, x)
        return [
            conv_layer_traffic(a[0], params["c1w"]),
            conv_layer_traffic(a[1], params["c2w"]),
            linear_layer_traffic(a[2], params["f1w"].T),
            linear_layer_traffic(a[3], params["f2w"].T),
            linear_layer_traffic(a[4], params["f3w"].T),
        ]

    def weight_stream(self, params) -> jax.Array:
        """All weights as one flat stream, kernels zero-padded to flit-lane
        multiples (paper Sec. V-A protocol for the no-NoC study)."""
        ker1 = params["c1w"].transpose(3, 0, 1, 2).reshape(6, 25)
        ker2 = params["c2w"].transpose(3, 2, 0, 1).reshape(96, 25)
        parts = [
            jnp.pad(ker1, ((0, 0), (0, 7))).reshape(-1),
            jnp.pad(ker2, ((0, 0), (0, 7))).reshape(-1),
            params["f1w"].T.reshape(-1),
            params["f2w"].T.reshape(-1),
            jnp.pad(params["f3w"].T, ((0, 0), (0, 4))).reshape(-1),
        ]
        return jnp.concatenate(parts)


@dataclasses.dataclass(frozen=True)
class DarkNetLike:
    """DarkNet-reference-style CNN on 64x64x3 (paper Sec. V-B: input reduced
    to 64x64x3 'to speed up the simulation'). 3x3 convs doubling channels
    with maxpools, then a linear classifier head."""

    channels: Tuple[int, ...] = (16, 32, 64, 128)
    n_classes: int = 10
    input_shape = (64, 64, 3)

    def specs(self) -> dict:
        s = {}
        cin = 3
        for i, cout in enumerate(self.channels):
            s[f"c{i}w"] = ParamSpec((3, 3, cin, cout),
                                    (None, None, "conv_in", "conv_out"), dtype=_F32)
            s[f"c{i}b"] = ParamSpec((cout,), ("conv_out",), init="zeros", dtype=_F32)
            cin = cout
        # after len(channels) VALID convs + pools on 64x64: spatial ~2x2
        self_dim = self._head_dim()
        s["fw"] = ParamSpec((self_dim, self.n_classes), ("mlp", "embed"), dtype=_F32)
        s["fb"] = ParamSpec((self.n_classes,), ("embed",), init="zeros", dtype=_F32)
        return s

    def _head_dim(self) -> int:
        hw = 64
        for _ in self.channels:
            hw = (hw - 2) // 2
        return hw * hw * self.channels[-1]

    def activations(self, params, x: jax.Array) -> List[jax.Array]:
        acts = [x]
        h = x
        for i in range(len(self.channels)):
            h = jax.nn.leaky_relu(_conv(h, params[f"c{i}w"], params[f"c{i}b"]), 0.1)
            h = _pool(h)
            if i < len(self.channels) - 1:
                acts.append(h)
        acts.append(h.reshape(-1))
        return acts

    def forward(self, params, x: jax.Array) -> jax.Array:
        h = x
        for i in range(len(self.channels)):
            h = jax.nn.leaky_relu(_conv(h, params[f"c{i}w"], params[f"c{i}b"]), 0.1)
            h = _pool(h)
        h = h.reshape(h.shape[0], -1)
        return h @ params["fw"] + params["fb"]

    def loss(self, params, x, y):
        logits = self.forward(params, x)
        return -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), y[:, None], axis=1))

    def layer_traffic(self, params, x: jax.Array) -> List[LayerTraffic]:
        a = self.activations(params, x)
        out = []
        for i in range(len(self.channels)):
            out.append(conv_layer_traffic(a[i], params[f"c{i}w"]))
        out.append(linear_layer_traffic(a[-1], params["fw"].T))
        return out
