"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, D) directly (what the two
conv layers + sinusoidal embedding of real Whisper would produce). The
transformer backbone is complete: bidirectional encoder, causal decoder
with cross-attention, cached decode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .spec import ParamSpec

_F32 = jnp.float32

__all__ = ["EncDecConfig", "EncDec"]


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_layers: int            # per stack (24 enc + 24 dec for whisper-medium)
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    remat: bool = True
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // 256) * 256

    def attn_cfg(self, causal: bool) -> L.AttnConfig:
        # Whisper uses learned absolute positions, not RoPE.
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv, self.hd,
                            use_rope=False, causal=causal)

    @property
    def sub_quadratic(self) -> bool:
        return False

    def cache_len(self, context: int) -> int:
        return context


def _enc_block_specs(cfg: EncDecConfig) -> dict:
    return {"ln1": L.rms_norm_spec(cfg.d_model),
            "attn": L.attention_specs(cfg.attn_cfg(False)),
            "ln2": L.rms_norm_spec(cfg.d_model),
            "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, gated=False)}


def _dec_block_specs(cfg: EncDecConfig) -> dict:
    return {"ln1": L.rms_norm_spec(cfg.d_model),
            "self_attn": L.attention_specs(cfg.attn_cfg(True)),
            "ln_x": L.rms_norm_spec(cfg.d_model),
            "cross_attn": L.attention_specs(cfg.attn_cfg(False)),
            "ln2": L.rms_norm_spec(cfg.d_model),
            "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, gated=False)}


class EncDec:
    def __init__(self, cfg: EncDecConfig):
        self.cfg = cfg

    def specs(self) -> dict:
        cfg = self.cfg

        def stack(s: ParamSpec) -> ParamSpec:
            return ParamSpec((cfg.n_layers,) + s.shape, ("layers",) + s.axes,
                             s.dtype, s.init, s.scale)

        leaf = lambda x: isinstance(x, ParamSpec)
        return {
            "embed": ParamSpec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                               init="embed", scale=0.02),
            "pos_dec": ParamSpec((8192, cfg.d_model), (None, "embed"),
                                 init="embed", scale=0.01),
            "enc": jax.tree.map(stack, _enc_block_specs(cfg), is_leaf=leaf),
            "dec": jax.tree.map(stack, _dec_block_specs(cfg), is_leaf=leaf),
            "ln_enc": L.rms_norm_spec(cfg.d_model),
            "ln_f": L.rms_norm_spec(cfg.d_model),
        }

    # -- encoder --------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames (B, S_enc, D) stubbed frame embeddings -> memory."""
        cfg = self.cfg
        acfg = cfg.attn_cfg(False)

        def body(x, p):
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            x = x + L.attention(p["attn"], h, acfg)
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, gated=False)
            return x, ()

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, frames.astype(jnp.bfloat16), params["enc"])
        return L.rms_norm(x, params["ln_enc"], cfg.norm_eps)

    # -- decoder --------------------------------------------------------
    def _dec_embed(self, params, tokens, pos0=0):
        b, s = tokens.shape
        pos = pos0 + jnp.arange(s)
        return (params["embed"][tokens] + params["pos_dec"][pos][None]
                ).astype(jnp.bfloat16)

    def decode_train(self, params, tokens, memory):
        """Teacher-forced decoding: tokens (B, S_dec), memory (B, S_enc, D)."""
        cfg = self.cfg
        self_cfg, cross_cfg = cfg.attn_cfg(True), cfg.attn_cfg(False)
        b, s_enc, _ = memory.shape
        mem_pos = jnp.broadcast_to(jnp.arange(s_enc), (b, s_enc))
        # cross k/v recomputed per layer from memory inside the scan
        x = self._dec_embed(params, tokens)

        def body(x, p):
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            x = x + L.attention(p["self_attn"], h, self_cfg)
            h = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
            mk = jnp.einsum("bsd,dnh->bsnh", memory, p["cross_attn"]["wk"])
            mv = jnp.einsum("bsd,dnh->bsnh", memory, p["cross_attn"]["wv"])
            x = x + L.attention(p["cross_attn"], h, cross_cfg,
                                kv_override=(mk, mv, mem_pos))
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, gated=False)
            return x, ()

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["dec"])
        return self._logits(params, x)

    def _logits(self, params, x):
        cfg = self.cfg
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                            preferred_element_type=_F32)
        if cfg.padded_vocab != cfg.vocab:
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
            logits = jnp.where(pad_mask, -1e30, logits)
        return logits

    def forward(self, params, frames, tokens):
        memory = self.encode(params, frames)
        return self.decode_train(params, tokens, memory)

    def loss(self, params, frames, tokens, targets, mask):
        logits = self.forward(params, frames, tokens)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)

    # -- cached decode ---------------------------------------------------
    def init_cache(self, b: int, context: int, memory: jax.Array,
                   params=None):
        """Self-attn KV cache (per layer) + cross-attention k/v.

        Cross k/v are PRECOMPUTED per layer from the encoder memory at
        cache-init time (pass ``params``): recomputing two (B, S_enc, D)
        projections per layer per token made decode collective/memory-bound
        in the roofline table (EXPERIMENTS.md whisper decode diagnosis).
        Legacy path (params=None) stores the raw memory instead.
        """
        cfg = self.cfg
        kv, hd, nl = cfg.n_kv, cfg.hd, cfg.n_layers
        self_k = jnp.zeros((nl, b, context, kv, hd), jnp.bfloat16)
        cache = {"self": L.KVCache(self_k, jnp.zeros_like(self_k))}
        if params is not None:
            mk = jnp.einsum("bsd,ldnh->lbsnh", memory,
                            params["dec"]["cross_attn"]["wk"])
            mv = jnp.einsum("bsd,ldnh->lbsnh", memory,
                            params["dec"]["cross_attn"]["wv"])
            cache["cross"] = L.KVCache(mk.astype(jnp.bfloat16),
                                       mv.astype(jnp.bfloat16))
        else:
            cache["memory"] = memory
        return cache

    def decode_step(self, params, token, cache, pos):
        """token (B,), pos (B,). Cross-attends cached (or recomputed) k/v."""
        cfg = self.cfg
        self_cfg, cross_cfg = cfg.attn_cfg(True), cfg.attn_cfg(False)
        precomputed = "cross" in cache
        if precomputed:
            b, s_enc = cache["cross"].k.shape[1], cache["cross"].k.shape[2]
        else:
            b, s_enc, _ = cache["memory"].shape
        mem_pos = jnp.broadcast_to(jnp.arange(s_enc), (b, s_enc))
        x = (params["embed"][token[:, None]]
             + params["pos_dec"][pos][:, None]).astype(jnp.bfloat16)

        def body(carry, xs):
            x = carry
            p, kc, vc, mk, mv = xs
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            a, new_kv = L.attention_decode(p["self_attn"], h, self_cfg,
                                           L.KVCache(kc, vc), pos)
            x = x + a
            h = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
            if not precomputed:
                mk = jnp.einsum("bsd,dnh->bsnh", cache["memory"],
                                p["cross_attn"]["wk"])
                mv = jnp.einsum("bsd,dnh->bsnh", cache["memory"],
                                p["cross_attn"]["wv"])
            x = x + L.attention(p["cross_attn"], h, cross_cfg,
                                kv_override=(mk, mv, mem_pos))
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h, gated=False)
            return x, (new_kv.k, new_kv.v)

        if precomputed:
            xs = (params["dec"], cache["self"].k, cache["self"].v,
                  cache["cross"].k, cache["cross"].v)
        else:
            dummy = (jnp.zeros((cfg.n_layers,)),) * 2
            xs = (params["dec"], cache["self"].k, cache["self"].v) + dummy
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        logits = self._logits(params, x)[:, 0]
        new_cache = dict(cache)
        new_cache["self"] = L.KVCache(nk, nv)
        return logits, new_cache
