"""Transformer building blocks: RMSNorm, RoPE, GQA attention, MLPs, MoE.

Pure-functional JAX. Every block comes as a (specs(), apply()) pair; specs()
returns the ParamSpec pytree (shapes + logical sharding axes) and apply()
consumes the materialized (or abstract) params.

Numerics follow large-model practice: bf16 weights/activations, fp32
softmax/norm statistics and fp32 logits, accumulation in fp32 via
``preferred_element_type``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .spec import ParamSpec

__all__ = [
    "rms_norm_spec", "rms_norm",
    "rope",
    "AttnConfig", "attention_specs", "attention", "attention_decode",
    "KVCache",
    "mlp_specs", "mlp", "MoEConfig", "moe_specs", "moe",
]

_F32 = jnp.float32


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rms_norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), dtype=jnp.bfloat16, init="ones")


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(_F32)), axis=-1, keepdims=True)
    y = x.astype(_F32) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(_F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x (..., S, n, hd), positions (..., S) -> same shape, rotated pairs."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=_F32) / half)
    ang = positions[..., None].astype(_F32) * freq          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(_F32), x[..., half:].astype(_F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (optional sliding window), train + cached decode paths
# ---------------------------------------------------------------------------

class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    window: int = 0           # 0 = full causal; >0 = sliding-window attention
    kv_chunk: int = 0         # >0: blockwise scores over key chunks (memory opt)
    use_rope: bool = True
    causal: bool = True


class KVCache(NamedTuple):
    k: jax.Array    # (B, S_cache, n_kv, hd)
    v: jax.Array


def attention_specs(cfg: AttnConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }


def _qkv(params, x, cfg: AttnConfig, positions):
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_pos, k_pos, cfg: AttnConfig):
    """(..., Sq, Sk) additive mask in fp32."""
    dif = q_pos[..., :, None] - k_pos[..., None, :]
    ok = dif >= 0 if cfg.causal else jnp.ones_like(dif, bool)
    if cfg.window > 0:
        ok &= dif < cfg.window
    return jnp.where(ok, 0.0, -1e30).astype(_F32)


def _sdpa(q, k, v, mask, cfg: AttnConfig):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask (B|1, Sq, Sk) additive."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, sq, kvh, g, hd)
    scale = hd ** -0.5
    if cfg.kv_chunk and k.shape[1] > cfg.kv_chunk:
        return _sdpa_chunked(q, k, v, mask, scale, cfg)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=_F32) * scale
    scores = scores + mask[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(q, k, v, mask, scale, cfg: AttnConfig):
    """Blockwise (flash-style) attention over key chunks via lax.scan.

    Never materializes the (Sq, Sk) score tensor; this is the memory-term
    optimization used in the perf hillclimb for long prefill. Online softmax
    with running (max, sum, acc) per query.
    """
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    c = cfg.kv_chunk
    n_chunks = sk // c
    assert sk % c == 0, "kv_chunk must divide key length"
    kc = k.reshape(b, n_chunks, c, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, c, kvh, hd).transpose(1, 0, 2, 3, 4)
    mc = mask.reshape(mask.shape[0], sq, n_chunks, c).transpose(2, 0, 1, 3)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, mb = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", q, kb,
                       preferred_element_type=_F32) * scale
        s = s + mb[:, None, None, :, :]
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(q.dtype), vb,
            preferred_element_type=_F32)
        return (m_new, l_new, acc), ()

    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, _F32)
    l0 = jnp.zeros((b, kvh, g, sq), _F32)
    a0 = jnp.zeros((b, kvh, g, sq, hd), _F32)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, mc))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, kvh * g, hd).astype(q.dtype)


def attention(params, x: jax.Array, cfg: AttnConfig,
              positions: Optional[jax.Array] = None,
              kv_override: Optional[tuple] = None) -> jax.Array:
    """Training/prefill path: full-sequence self-attention.

    kv_override: (k, v, k_positions) for cross-attention (enc-dec).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(params, x, cfg, positions)
    if kv_override is not None:
        k, v, k_pos = kv_override
    else:
        k_pos = positions
    mask = _mask(positions, k_pos, cfg)
    out = _sdpa(q, k, v, mask, cfg)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"])


def attention_decode(params, x: jax.Array, cfg: AttnConfig, cache: KVCache,
                     pos: jax.Array):
    """One-token decode: x (B, 1, D), pos (B,) absolute position.

    Returns (out (B, 1, D), new cache). For SWA archs the cache length is
    min(window, context) and acts as a ring buffer indexed by pos % len.
    """
    b = x.shape[0]
    s_cache = cache.k.shape[1]
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    if cfg.use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    slot = (pos % s_cache)[:, None]
    bidx = jnp.arange(b)[:, None]
    new_k = cache.k.at[bidx, slot].set(k)
    new_v = cache.v.at[bidx, slot].set(v)
    # positions currently held by each cache slot (ring semantics)
    slots = jnp.arange(s_cache)[None, :]
    wraps = (pos[:, None] // s_cache)
    slot_pos = slots + wraps * s_cache
    slot_pos = jnp.where(slots > slot, slot_pos - s_cache, slot_pos)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    k_pos = jnp.where(valid, slot_pos, -1)
    dif = pos[:, None, None] - k_pos[:, None, :]
    ok = (dif >= 0) & valid[:, None, :]
    if cfg.window > 0:
        ok &= dif < cfg.window
    mask = jnp.where(ok, 0.0, -1e30).astype(_F32)
    out = _sdpa(q, new_k, new_v, mask, cfg._replace(kv_chunk=0))
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, KVCache(new_k, new_v)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(d: int, f: int, gated: bool = True) -> dict:
    s = {
        "wu": ParamSpec((d, f), ("embed", "mlp")),
        "wd": ParamSpec((f, d), ("mlp", "embed")),
    }
    if gated:
        s["wg"] = ParamSpec((d, f), ("embed", "mlp"))
    return s


def mlp(params, x: jax.Array, gated: bool = True) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, params["wu"])
    if gated:
        gate = jnp.einsum("bsd,df->bsf", x, params["wg"])
        h = jax.nn.silu(gate.astype(_F32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(_F32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["wd"])


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity dispatch, EP-shardable)
# ---------------------------------------------------------------------------

class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def moe_specs(cfg: MoEConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", None), dtype=jnp.float32),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wu": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wd": ParamSpec((e, f, d), ("experts", "mlp", "embed")),
    }


def moe(params, x: jax.Array, cfg: MoEConfig, groups: int = 1,
        shard: Optional[tuple] = None):
    """Top-k routed MoE with per-expert capacity buffers.

    x (B, S, D) -> (y (B, S, D), aux_loss scalar). Tokens over capacity are
    dropped (contribute zero), standard GShard semantics.

    ``groups`` partitions the token axis into independent dispatch groups,
    each with its own capacity budget (cap/groups). Setting groups to the
    data-parallel degree makes routing *shard-local*: the cumsum/scatter
    stay inside one data shard, GSPMD keeps the capacity buffers sharded
    on the token-group axis, and expert compute scales with the data axis
    instead of replicating (EXPERIMENTS.md SSPerf documents the before/
    after on kimi-k2 and mixtral). groups=1 is the naive global dispatch.

    ``shard = (group_axis, expert_axis)`` pins the dispatch/capacity
    tensors with explicit sharding constraints - propagation alone leaves
    the scatter replicated (kimi iteration 3's refutation); either axis
    may be None.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = groups
    if t % g:
        raise ValueError(f"token count {t} not divisible by groups {g}")
    tl = t // g
    cap = max(int(cfg.capacity_factor * tl * k / e), 1)
    xt = x.reshape(g, tl, d)

    constrain_buffers = True
    if shard is not None and g > 1:
        from jax.sharding import PartitionSpec as _P
        ga, ea = shard
        if ea == "tokens-only":
            ea, constrain_buffers = None, False
        wsc = jax.lax.with_sharding_constraint
        xt = wsc(xt, _P(ga, None, None))
    else:
        wsc = None

    logits = jnp.einsum("gtd,de->gte", xt.astype(_F32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (G, TL, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, choice) within its group-local expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # (G, TL, k, E)
    flat = onehot.reshape(g, tl * k, e)
    pie = jnp.cumsum(flat, axis=1) * flat                    # 1-based positions
    pos = jnp.max(pie.reshape(g, tl, k, e), axis=-1) - 1     # (G, TL, k)
    keep = (pos >= 0) & (pos < cap)
    eidx = jnp.where(keep, gate_idx, e)                       # e = drop bucket
    pidx = jnp.where(keep, pos, 0)

    # dispatch: scatter tokens into (G, E+1, cap, D); drop bucket absorbs
    buf = jnp.zeros((g, e + 1, cap, d), x.dtype)
    gi = jnp.broadcast_to(jnp.arange(g)[:, None], (g, tl * k)).reshape(-1)
    tok_rep = jnp.broadcast_to(jnp.arange(tl)[:, None], (tl, k)).reshape(-1)
    src = xt[:, tok_rep].reshape(-1, d)                       # (G*TL*k, D)
    buf = buf.at[gi, eidx.reshape(-1), pidx.reshape(-1)].set(src)
    buf = buf[:, :e]
    if wsc is not None and constrain_buffers:
        buf = wsc(buf, _P(ga, ea, None, None))

    # expert FFN, batched over (group, expert) - shardable on both axes
    gate = jnp.einsum("gecd,edf->gecf", buf, params["wg"])
    up = jnp.einsum("gecd,edf->gecf", buf, params["wu"])
    h = jax.nn.silu(gate.astype(_F32)).astype(x.dtype) * up
    out = jnp.einsum("gecf,efd->gecd", h, params["wd"])      # (G, E, cap, D)
    if wsc is not None and constrain_buffers:
        out = wsc(out, _P(ga, ea, None, None))

    # combine: gather each token's expert outputs, weight by gates
    zero = jnp.zeros((g, 1, cap, d), out.dtype)
    out_pad = jnp.concatenate([out, zero], axis=1)
    gathered = out_pad[gi, eidx.reshape(-1), pidx.reshape(-1)]
    gathered = gathered.reshape(g, tl, k, d)
    if wsc is not None:
        gathered = wsc(gathered, _P(ga, None, None, None))
    y = jnp.einsum("gtkd,gtk->gtd", gathered.astype(_F32),
                   gate_vals * keep.astype(_F32))
    y = y.astype(x.dtype).reshape(b, s, d)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e, dtype=_F32),
                       axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * router_prob) * e
    return y, aux
