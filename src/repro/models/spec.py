"""Parameter specs: shapes + logical sharding axes, materialization-free.

Every model module describes its parameters as a pytree of ParamSpec before
any array exists. This single source of truth serves three consumers:

  * ``init_params``     - materialize real arrays (smoke tests, training)
  * ``abstract_params`` - ShapeDtypeStructs for the multi-pod dry-run
  * ``axes_tree``       - logical axes, mapped to mesh axes by sharding rules

Logical axis vocabulary (mapped per-arch in repro/dist/sharding.py):
    batch seq embed mlp heads kv_heads head_dim vocab experts layers
    conv_in conv_out state
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ParamSpec", "init_params", "abstract_params", "axes_tree",
           "is_spec", "param_count", "param_bytes"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim (None = replicated dim)
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"                     # normal | zeros | ones | embed
    scale: float = 1.0                       # stddev multiplier for normal

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init in ("normal", "embed"):
        # fan-in scaled normal: last axis is the output dim by convention of
        # this codebase (x @ w with w (in, out)); embed scales by 1.0.
        if spec.init == "embed" or len(spec.shape) < 2:
            std = spec.scale
        else:
            fan_in = 1
            for d in spec.shape[:-1]:
                fan_in *= d
            std = spec.scale / max(fan_in, 1) ** 0.5
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init}")


def init_params(specs, key: jax.Array):
    """Materialize a spec pytree into arrays, splitting the key per leaf."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(specs):
    """ShapeDtypeStruct pytree - used by .lower() without allocating."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec)


def axes_tree(specs):
    """Logical-axes pytree, same structure as the params."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total


def param_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    total = 0
    for s in leaves:
        n = jnp.dtype(s.dtype).itemsize
        for d in s.shape:
            n *= d
        total += n
    return total
