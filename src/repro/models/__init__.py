from .spec import (ParamSpec, init_params, abstract_params, axes_tree,
                   param_count, param_bytes)
from .transformer import LM, LMConfig
from .encdec import EncDec, EncDecConfig
from .convnets import LeNet, DarkNetLike

__all__ = ["ParamSpec", "init_params", "abstract_params", "axes_tree",
           "param_count", "param_bytes", "LM", "LMConfig",
           "EncDec", "EncDecConfig", "LeNet", "DarkNetLike"]
