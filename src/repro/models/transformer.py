"""Decoder LM with composable block patterns.

One model class covers the whole assigned-architecture pool:
  dense GQA        pattern ("attn",)                 minicpm/phi3/starcoder2/danube
  MoE              pattern ("attn",) + moe config    mixtral/kimi-k2
  Griffin hybrid   pattern ("rec", "rec", "attn")    recurrentgemma
  xLSTM            pattern ("mlstm", "slstm")        xlstm
  VLM backbone     dense + prefix embeddings          internvl2

Layers are grouped by pattern cycle and scanned (lax.scan over stacked
group params) so the compiled HLO is O(pattern) not O(n_layers) - essential
for dry-run compile times at 40-61 layers. A remainder of n_layers %
len(pattern) is applied unscanned as a tail.

Three execution modes share the same block code: "train" (no cache),
"prefill" (returns cache), "decode" (single token, consumes cache).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import recurrent as R
from .spec import ParamSpec

_F32 = jnp.float32

__all__ = ["LMConfig", "LM"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    pattern: Tuple[str, ...] = ("attn",)
    rope_theta: float = 10000.0
    window: int = 0                        # sliding-window size; 0 = full attn
    n_experts: int = 0                     # >0 -> MoE MLP in attn blocks
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_groups: int = 1                    # shard-local dispatch groups (perf)
    moe_shard: Optional[Tuple[Optional[str], Optional[str]]] = None
    # ^ (group_axis, expert_axis) explicit constraints for the dispatch path
    tp_bf16_boundary: bool = False
    # ^ pin block outputs to bf16 via an optimization barrier so TP
    #   partial-sum all-reduces run in bf16, not the fused-f32 XLA picks
    gated_mlp: bool = True
    tied_embeddings: bool = True
    vlm_prefix: int = 0                    # vision stub: prepended patch embeds
    kv_chunk: int = 0                      # blockwise attention chunk (0 = off)
    remat: bool = True
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        cleanly over a 16-way model axis with 128-lane tiles. Logits in the
        pad region are masked to -1e30 (never sampled, never targeted)."""
        return -(-self.vocab // 256) * 256

    @property
    def attn_cfg(self) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv, self.hd,
                            self.rope_theta, self.window, self.kv_chunk)

    @property
    def moe_cfg(self) -> Optional[L.MoEConfig]:
        if self.n_experts == 0:
            return None
        return L.MoEConfig(self.d_model, self.d_ff, self.n_experts,
                           self.top_k, self.capacity_factor)

    @property
    def sub_quadratic(self) -> bool:
        """True when context memory is bounded (SWA or recurrent blocks)."""
        recurrent = any(k != "attn" for k in self.pattern)
        return recurrent or self.window > 0

    def cache_len(self, context: int) -> int:
        """KV entries needed per attention block for a given context."""
        return min(context, self.window) if self.window > 0 else context


# ---------------------------------------------------------------------------
# Per-block specs / apply / cache
# ---------------------------------------------------------------------------

def _block_specs(kind: str, cfg: LMConfig) -> dict:
    d = cfg.d_model
    if kind == "attn":
        s = {"ln1": L.rms_norm_spec(d), "attn": L.attention_specs(cfg.attn_cfg),
             "ln2": L.rms_norm_spec(d)}
        if cfg.moe_cfg is not None:
            s["moe"] = L.moe_specs(cfg.moe_cfg)
        else:
            s["mlp"] = L.mlp_specs(d, cfg.d_ff, cfg.gated_mlp)
        return s
    if kind == "rec":
        s = {"ln1": L.rms_norm_spec(d),
             "in_main": ParamSpec((d, d), ("embed", "state")),
             "in_gate": ParamSpec((d, d), ("embed", "state")),
             "conv": R.conv1d_specs(d),
             "rglru": R.rglru_specs(d),
             "out": ParamSpec((d, d), ("state", "embed")),
             "ln2": L.rms_norm_spec(d)}
        if cfg.d_ff > 0:
            s["mlp"] = L.mlp_specs(d, cfg.d_ff, cfg.gated_mlp)
        return s
    if kind == "mlstm":
        return {"ln1": L.rms_norm_spec(d), "cell": R.mlstm_specs(d, cfg.n_heads)}
    if kind == "slstm":
        return {"ln1": L.rms_norm_spec(d), "cell": R.slstm_specs(d, cfg.n_heads)}
    raise ValueError(f"unknown block kind {kind!r}")


def _block_cache(kind: str, cfg: LMConfig, b: int, context: int):
    d, hd, kv = cfg.d_model, cfg.hd, cfg.n_kv
    if kind == "attn":
        c = cfg.cache_len(context)
        return L.KVCache(jnp.zeros((b, c, kv, hd), jnp.bfloat16),
                         jnp.zeros((b, c, kv, hd), jnp.bfloat16))
    if kind == "rec":
        return {"h": jnp.zeros((b, d), _F32),
                "conv": jnp.zeros((b, 3, d), jnp.bfloat16)}
    if kind == "mlstm":
        return R.mlstm_init_state(b, cfg.n_heads, d // cfg.n_heads)
    if kind == "slstm":
        return R.slstm_init_state(b, d)
    raise ValueError(kind)


def _apply_mlp(params, cfg: LMConfig, x):
    if cfg.moe_cfg is not None and "moe" in params:
        groups = cfg.moe_groups
        # decode steps carry few tokens; fall back to global dispatch
        if x.shape[0] * x.shape[1] % max(groups, 1):
            groups = 1
        y, aux = L.moe(params["moe"], x, cfg.moe_cfg, groups=groups,
                       shard=cfg.moe_shard)
        return y, aux
    return L.mlp(params["mlp"], x, cfg.gated_mlp), 0.0


def _block_apply(kind: str, cfg: LMConfig, params, x, mode: str, cache, pos):
    """x (B, S, D) [S=1 in decode]; returns (x, new_cache, aux_loss)."""
    aux = 0.0
    if kind == "attn":
        h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
        if mode == "train":
            a = L.attention(params["attn"], h, cfg.attn_cfg)
            new_cache = cache
        elif mode == "prefill":
            a, new_cache = _attention_prefill(params["attn"], h, cfg, cache)
        else:
            a, new_cache = L.attention_decode(params["attn"], h, cfg.attn_cfg,
                                              cache, pos)
        if cfg.tp_bf16_boundary:
            a = jax.lax.optimization_barrier(a.astype(jnp.bfloat16))
        x = x + a
        h = L.rms_norm(x, params["ln2"], cfg.norm_eps)
        m, aux = _apply_mlp(params, cfg, h)
        if cfg.tp_bf16_boundary:
            m = jax.lax.optimization_barrier(m.astype(jnp.bfloat16))
        return x + m, new_cache, aux

    if kind == "rec":
        h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
        main = jnp.einsum("bsd,de->bse", h, params["in_main"])
        gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", h, params["in_gate"])
                           .astype(_F32)).astype(x.dtype)
        if mode == "decode":
            c_out, conv_hist = R.causal_conv1d_step(
                params["conv"], main[:, 0], cache["conv"])
            r_out, rst = R.rglru_step(params["rglru"], c_out,
                                      R.RGLRUState(cache["h"]))
            y = r_out[:, None, :]
            new_cache = {"h": rst.h, "conv": conv_hist}
        else:
            c_out = R.causal_conv1d(params["conv"], main)
            y = R.rglru_scan(params["rglru"], c_out)
            if mode == "prefill":
                new_cache = {"h": y[:, -1].astype(_F32),
                             "conv": main[:, -3:]}
            else:
                new_cache = cache
        y = y * gate
        x = x + jnp.einsum("bse,ed->bsd", y, params["out"])
        if "mlp" in params:
            h2 = L.rms_norm(x, params["ln2"], cfg.norm_eps)
            m, aux = _apply_mlp(params, cfg, h2)
            x = x + m
        return x, new_cache, aux

    if kind in ("mlstm", "slstm"):
        h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
        cell = params["cell"]
        if kind == "mlstm":
            if mode == "decode":
                y, st = R.mlstm_step(cell, h[:, 0], cache, cfg.n_heads)
                y = y[:, None, :]
                new_cache = st
            else:
                y = R.mlstm_scan(cell, h, cfg.n_heads)
                new_cache = _mlstm_final_state(cell, h, cfg, cache) \
                    if mode == "prefill" else cache
        else:
            if mode == "decode":
                y, st = R.slstm_step(cell, h[:, 0], cache, cfg.n_heads)
                y = y[:, None, :]
                new_cache = st
            else:
                y = R.slstm_scan(cell, h, cfg.n_heads)
                new_cache = _slstm_final_state(cell, h, cfg, cache) \
                    if mode == "prefill" else cache
        return x + y, new_cache, aux

    raise ValueError(kind)


def _attention_prefill(params, h, cfg: LMConfig, cache: L.KVCache):
    """Full-sequence attention that also fills the (ring) KV cache."""
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = L._qkv(params, h, cfg.attn_cfg, positions)
    mask = L._mask(positions, positions, cfg.attn_cfg)
    out = L._sdpa(q, k, v, mask, cfg.attn_cfg)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    c = cache.k.shape[1]
    keep = min(s, c)
    p_keep = jnp.arange(s - keep, s)
    slots = p_keep % c
    nk = cache.k.at[:, slots].set(k[:, p_keep].astype(cache.k.dtype))
    nv = cache.v.at[:, slots].set(v[:, p_keep].astype(cache.v.dtype))
    return y, L.KVCache(nk, nv)


def _mlstm_final_state(cell, h, cfg, cache):
    b, s, d = h.shape
    q, k, v, i_pre, f_pre = R._mlstm_qkv(cell, h)
    hd = d // cfg.n_heads

    def body(state, xs):
        qt, kt, vt, it, ft = xs
        _, state = R._mlstm_cell(state, qt.astype(_F32), kt.astype(_F32),
                                 vt.astype(_F32), it, ft, hd)
        return state, ()

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    st, _ = jax.lax.scan(body, R.mlstm_init_state(b, cfg.n_heads, hd), xs)
    return st


def _slstm_final_state(cell, h, cfg, cache):
    b, s, d = h.shape

    def body(state, xt):
        _, state = R.slstm_cell(cell, xt, state, cfg.n_heads)
        return state, ()

    st, _ = jax.lax.scan(body, R.slstm_init_state(b, d), h.transpose(1, 0, 2))
    return st


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

class LM:
    """Functional decoder LM; all methods are jit/pjit-compatible."""

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg
        p = len(cfg.pattern)
        self.n_groups = cfg.n_layers // p
        self.tail = tuple(cfg.pattern[:cfg.n_layers % p])

    # -- specs ---------------------------------------------------------
    def specs(self) -> dict:
        cfg = self.cfg
        group = {f"b{i}_{k}": _block_specs(k, cfg)
                 for i, k in enumerate(cfg.pattern)}
        # stack group specs along a leading "layers" axis
        def stack(s: ParamSpec) -> ParamSpec:
            return ParamSpec((self.n_groups,) + s.shape, ("layers",) + s.axes,
                             s.dtype, s.init, s.scale)
        specs = {
            "embed": ParamSpec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                               init="embed", scale=0.02),
            "blocks": jax.tree.map(stack, group,
                                   is_leaf=lambda x: isinstance(x, ParamSpec)),
            "ln_f": L.rms_norm_spec(cfg.d_model),
        }
        if self.tail:
            specs["tail"] = {f"t{i}_{k}": _block_specs(k, cfg)
                             for i, k in enumerate(self.tail)}
        if not cfg.tied_embeddings:
            specs["unembed"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                         ("embed", "vocab"), scale=0.02)
        if cfg.vlm_prefix:
            # projection for stubbed vision patch embeddings
            specs["vis_proj"] = ParamSpec((cfg.d_model, cfg.d_model),
                                          ("embed", "state"))
        return specs

    # -- caches --------------------------------------------------------
    def init_cache(self, b: int, context: int):
        cfg = self.cfg
        def per_group(kind):
            one = _block_cache(kind, cfg, b, context)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n_groups,) + x.shape), one)
        cache = {f"b{i}_{k}": per_group(k) for i, k in enumerate(cfg.pattern)}
        if self.tail:
            cache["tail"] = {f"t{i}_{k}": _block_cache(k, cfg, b, context)
                             for i, k in enumerate(self.tail)}
        return cache

    # -- forward -------------------------------------------------------
    def _embed(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens].astype(jnp.bfloat16)
        if cfg.vlm_prefix:
            if patch_embeds is None:
                raise ValueError("VLM arch needs patch_embeds")
            pe = jnp.einsum("bpd,de->bpe", patch_embeds.astype(jnp.bfloat16),
                            params["vis_proj"])
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def _blocks(self, params, x, mode, cache, pos):
        cfg = self.cfg
        names = [f"b{i}_{k}" for i, k in enumerate(cfg.pattern)]
        kinds = list(cfg.pattern)
        aux_total = 0.0

        def group_body(carry, xs):
            h, aux = carry
            gp, gc = xs
            new_gc = {}
            for name, kind in zip(names, kinds):
                h, nc, a = _block_apply(kind, cfg, gp[name], h, mode,
                                        gc[name] if gc else None, pos)
                new_gc[name] = nc
                aux = aux + a
            return (h, aux), new_gc

        body = group_body
        if cfg.remat and mode == "train":
            body = jax.checkpoint(group_body, prevent_cse=False)

        group_params = {n: params["blocks"][n] for n in names}
        group_cache = None if mode == "train" else \
            {n: cache[n] for n in names}
        aux0 = jnp.zeros((), _F32)
        if mode == "train":
            (x, aux_total), _ = jax.lax.scan(
                lambda c, gp: (body(c, (gp, None))[0], ()),
                (x, aux0), group_params)
            new_cache = cache
        else:
            (x, aux_total), new_group_cache = jax.lax.scan(
                body, (x, aux0), (group_params, group_cache))
            new_cache = dict(new_group_cache)

        if self.tail:
            tail_cache = {} if mode == "train" else dict(cache["tail"])
            new_tail = {}
            for i, kind in enumerate(self.tail):
                name = f"t{i}_{kind}"
                x, nc, a = _block_apply(kind, cfg, params["tail"][name], x,
                                        mode, tail_cache.get(name), pos)
                new_tail[name] = nc
                aux_total = aux_total + a
            if mode != "train":
                new_cache["tail"] = new_tail
        return x, new_cache, aux_total

    def _logits(self, params, x):
        cfg = self.cfg
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.tied_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                                preferred_element_type=_F32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"],
                                preferred_element_type=_F32)
        if cfg.padded_vocab != cfg.vocab:
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
            logits = jnp.where(pad_mask, -1e30, logits)
        return logits

    def forward(self, params, tokens: jax.Array,
                patch_embeds: Optional[jax.Array] = None) -> jax.Array:
        """Train-mode forward: tokens (B, S) -> logits (B, S[, +prefix], V)."""
        x = self._embed(params, tokens, patch_embeds)
        x, _, aux = self._blocks(params, x, "train", None, None)
        return self._logits(params, x), aux

    def loss(self, params, tokens, targets, mask,
             patch_embeds: Optional[jax.Array] = None):
        """Mean masked cross-entropy (fp32), plus MoE aux loss."""
        logits, aux = self.forward(params, tokens, patch_embeds)
        if self.cfg.vlm_prefix:
            logits = logits[:, self.cfg.vlm_prefix:]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + 0.01 * aux

    def prefill(self, params, tokens, context: int,
                patch_embeds: Optional[jax.Array] = None):
        """Run the prompt, return (last-position logits, cache)."""
        b = tokens.shape[0]
        cache = self.init_cache(b, context)
        x = self._embed(params, tokens, patch_embeds)
        x, cache, _ = self._blocks(params, x, "prefill", cache, None)
        return self._logits(params, x[:, -1:])[:, 0], cache

    def decode_step(self, params, token: jax.Array, cache, pos: jax.Array):
        """token (B,), pos (B,) -> (logits (B, V), new cache)."""
        x = params["embed"][token[:, None]].astype(jnp.bfloat16)
        x, cache, _ = self._blocks(params, x, "decode", cache, pos)
        return self._logits(params, x)[:, 0], cache
