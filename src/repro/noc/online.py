"""Closed-loop (online) serving model: overlapped phases under load.

The offline simulator drains one inference's request (MC->PE) and result
(PE->MC) traffic as two independent one-shot phases and leaves the overlap
question open (the old DESIGN.md "Result phase" caveat). This module closes
the loop:

* **Per-PE gating.** A PE may start injecting inference k's results only
  after the request phase delivered *that PE's* last request packet of
  inference k, plus a per-PE compute latency - the compute/communication
  interaction the offline model elides.
* **Back-to-back inferences.** An arrival process (:class:`ArrivalProcess`,
  the offered-load axis) releases inference k's request flits at
  ``arrival[k]``; inference k+1's distribution overlaps inference k's
  results in the mesh.
* **Per-packet timestamps.** The gated drains run with the simulator's
  timestamp ledgers (``noc.sim`` ``timestamps=True``): each packet's
  NI-injection and ejection cycles are harvested into per-inference
  completion times and latency percentiles.

The gating contract (DESIGN.md "Closed-loop serving"):

* **Timing is schedule-determined.** Drain dynamics (routing, credits,
  arbitration) never read payload *values*, so the gated schedule's
  timing - and therefore every latency/throughput figure here - is
  identical across ordering transforms and precisions of one workload.
  One gated drain per offered-load point prices the whole transform axis.
* **BT is data-determined.** The per-phase BT the closed loop *reports*
  (``OnlineResult.request`` / ``.result``) is the canonical per-inference
  phase drain - bit-identical to the offline ``simulate`` phases at any
  compute latency, which is what keeps serving-row BT comparable with
  every offline figure in the repo. Request and result traffic ride
  disjoint virtual networks (the standard request/reply protocol-deadlock
  separation), so the per-phase recorders are well defined. The gated
  drains' own recorders stay available (``sched_request``/``sched_result``:
  they include inter-inference seam transitions and stall/resume
  interleavings) but are a different measurement, not the reported one.

Mechanically the gate is a thin wrapper over the fused step: a stream's
*effective length* at cycle c is the number of flits its release schedule
has unlocked, and the step's own ``ptr < length`` injection guard does the
rest - the router pipeline, recorders, and ledgers are the proven offline
step, byte for byte. With every gate open from cycle 0 the wrapped step is
the offline step (the oracle test pins this bit-for-bit).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .sim import (META_TAIL, SimResult, Traffic, Wire, _conservation_error,
                  _make_step, _mc_array, _mesh_key, _result, fuse_traffic,
                  make_state)
from .topology import NocConfig
from .traffic import concat_inferences

__all__ = ["ArrivalProcess", "OnlineResult", "simulate_online",
           "percentile", "latency_percentiles", "ARRIVAL_KINDS"]

ARRIVAL_KINDS = ("uniform", "poisson", "backtoback")


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Deterministic offered-load arrival process (cycles are the clock).

    kind: ``uniform`` spaces arrivals ``1000 / load`` cycles apart,
        ``poisson`` draws exponential gaps from a PCG64 stream seeded by
        ``seed`` (bit-reproducible: the same seed replays the same
        process), ``backtoback`` releases everything at cycle 0 - the
        saturation probe.
    load: offered load in inferences per 1000 cycles (ignored by
        ``backtoback``).
    """

    kind: str = "uniform"
    load: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(f"kind must be one of {ARRIVAL_KINDS}, "
                             f"got {self.kind!r}")
        if self.kind != "backtoback" and not self.load > 0:
            raise ValueError(f"offered load must be > 0, got {self.load!r}")

    def times(self, n: int) -> np.ndarray:
        """Arrival cycles of inferences ``0..n-1`` (non-decreasing int64;
        the first arrival is cycle 0)."""
        if n < 1:
            raise ValueError(f"need n >= 1 inferences, got {n}")
        if self.kind == "backtoback":
            return np.zeros(n, np.int64)
        mean_gap = 1000.0 / self.load
        if self.kind == "uniform":
            return np.floor(np.arange(n) * mean_gap).astype(np.int64)
        rng = np.random.Generator(np.random.PCG64(self.seed))
        gaps = rng.exponential(mean_gap, size=n - 1) if n > 1 else []
        return np.concatenate(
            [[0], np.floor(np.cumsum(gaps))]).astype(np.int64)


class _GatedWire(NamedTuple):
    """Fused wire plus its release schedule.

    inc:     (M, K) int32 - flits gate k unlocks on stream m
    release: (M, K) int32 - cycle gate k opens on stream m (non-decreasing
             along K; gates whose flits must stay locked forever use
             a sentinel far beyond max_cycles)
    """

    wire: jax.Array
    length: jax.Array
    inc: jax.Array
    release: jax.Array


def _make_online_step(mesh_key, count_headers: bool):
    """The gated step: effective stream length = flits released by now."""
    base = _make_step(mesh_key, count_headers, track=True, timestamps=True)

    def step(state, gwire: _GatedWire, mc_nodes):
        eff = jnp.sum(
            jnp.where(gwire.release <= state.cycle, gwire.inc, 0),
            axis=-1).astype(jnp.int32)
        return base(state, Wire(gwire.wire, eff), mc_nodes)

    return step


@functools.lru_cache(maxsize=None)
def _online_runner(mesh_key, count_headers: bool, chunk: int):
    """Compiled ``chunk``-cycle gated driver, cached like ``_chunk_runner``
    (one executable per (state, wire, schedule) shape signature)."""
    step = _make_online_step(mesh_key, count_headers)

    def run(state, gwire: _GatedWire, mc_nodes):
        def body(s, _):
            return step(s, gwire, mc_nodes), ()
        out, _ = jax.lax.scan(body, state, None, length=chunk)
        return out, out.ejected

    return jax.jit(run, donate_argnums=0)


def _drain_gated(cfg: NocConfig, traffic: Traffic, mc_nodes: np.ndarray,
                 release: np.ndarray, inc: np.ndarray, *,
                 count_headers: bool, chunk: int, max_cycles: int,
                 allow_truncation: bool):
    """Drain ``traffic`` under a release schedule; harvest the ledgers.

    Returns ``(sim_result, inj_time, eject_time, eject_pkt, drained)`` with
    the ledgers as host arrays over the real packet ids. The gated step's
    own ``drained_at`` is meaningless mid-gate (its completion test sees
    only released flits), so ``drain_cycle`` is rebuilt from the ejection
    ledger: the cycle after the last tail ejected.
    """
    m = int(traffic.length.shape[0])
    npkt = int(traffic.num_packets)
    if npkt <= 0:
        raise ValueError("gated drains need Traffic with num_packets set")
    state = make_state(cfg, m, npkt=npkt, timestamps=True)
    wire = fuse_traffic(traffic, track_pkt=True)
    gwire = _GatedWire(wire.wire, wire.length,
                       jnp.asarray(inc, jnp.int32),
                       jnp.asarray(release, jnp.int32))
    run = _online_runner(_mesh_key(cfg), count_headers, chunk)
    nodes = jnp.asarray(mc_nodes, jnp.int32)
    total = int(np.sum(np.asarray(traffic.length)))
    drained = total == 0
    while total:
        state, ej = run(state, gwire, nodes)
        if int(ej) == total:
            drained = True
            break
        if int(state.cycle) >= max_cycles:
            break
    if not drained and not allow_truncation:
        raise RuntimeError(
            f"closed-loop drain incomplete: {int(state.ejected)}/{total} "
            f"flits ejected after {int(state.cycle)} cycles")
    inj_t = np.asarray(state.inj_time)[:npkt]
    ej_t = np.asarray(state.eject_time)[:npkt]
    drain_cycle = int(ej_t.max()) + 1 if (ej_t >= 0).any() else 0
    res = _result(cfg, (np.asarray(state.link_bt),
                        np.asarray(state.link_flits),
                        np.asarray(state.inj_bt), state.ejected, state.cycle,
                        np.int32(drain_cycle)), total)
    return res, inj_t, ej_t, np.asarray(state.eject_pkt), drained


def _packet_dest(traffic: Traffic) -> np.ndarray:
    """Destination router per packet id of an unbatched Traffic (-1 for
    ids that never appear - there are none for packetizer-built traffic)."""
    npkt = int(traffic.num_packets)
    dest = np.asarray(traffic.dest)
    meta = np.asarray(traffic.meta)
    pkt = np.asarray(traffic.pkt)
    valid = (np.arange(dest.shape[1])[None, :]
             < np.asarray(traffic.length)[:, None])
    tails = valid & ((meta & META_TAIL) > 0)
    out = np.full(npkt, -1, np.int64)
    out[pkt[tails]] = dest[tails]
    return out


@dataclasses.dataclass
class OnlineResult:
    """One closed-loop run: per-inference timing plus per-phase BT.

    completions[k] is the cycle after inference k's last result tail
    ejected, or -1 while still in flight at the cutoff (truncated runs
    only); latencies[k] = completions[k] - arrivals[k] (or -1). The
    ``request``/``result`` SimResults are the canonical per-inference
    phase drains (the BT contract - ``None`` under ``record_bt=False``);
    ``sched_request``/``sched_result`` are the gated schedule drains whose
    timing every latency figure comes from.
    """

    arrivals: np.ndarray            # (K,) int64 arrival cycles
    completions: np.ndarray         # (K,) int64; -1 = in flight at cutoff
    latencies: np.ndarray           # (K,) int64; -1 = in flight at cutoff
    truncated: int                  # inferences still in flight at cutoff
    request_drain_cycle: int        # gated request-network drain
    result_drain_cycle: int         # gated result-network drain
    delivery: np.ndarray            # (K, NR) per-router request delivery
    release: np.ndarray             # (P, K) result-injection release cycles
    compute_latency: np.ndarray     # (P,) per-PE-stream compute cycles
    sched_request: SimResult
    sched_result: Optional[SimResult]
    request: Optional[SimResult]    # canonical request phase (BT contract)
    result: Optional[SimResult]
    request_inj_time: np.ndarray    # (K * NP_req,) per-packet ledgers
    request_eject_time: np.ndarray
    result_inj_time: np.ndarray
    result_eject_time: np.ndarray

    @property
    def completed(self) -> int:
        return int((self.completions >= 0).sum())

    @property
    def throughput(self) -> Optional[float]:
        """Completed inferences per 1000 cycles over the busy span."""
        done = self.completions[self.completions >= 0]
        if not done.size:
            return None
        span = int(done.max()) - int(self.arrivals.min())
        return float(done.size) * 1000.0 / max(span, 1)


def simulate_online(cfg: NocConfig, request: Traffic, result: Traffic, *,
                    arrivals: Union[ArrivalProcess, Sequence[int]],
                    num_inferences: Optional[int] = None,
                    compute_latency: Union[int, Sequence[int]] = 0,
                    count_headers: bool = True, chunk: int = 2048,
                    max_cycles: int = 2_000_000,
                    check_conservation: bool = False,
                    allow_truncation: bool = False,
                    record_bt: bool = True) -> OnlineResult:
    """Closed-loop drain of ``num_inferences`` back-to-back inferences.

    request / result: ONE inference's unbatched phase traffics (e.g.
        ``build_traffic(...)`` and ``build_result_traffic(...).variant(i)``)
        with ``num_packets`` metadata. The result traffic's streams follow
        the ``build_result_traffic`` convention: stream i injects at
        ``cfg.pe_nodes[i]`` (padding streams beyond the PE count must be
        empty).
    arrivals: an :class:`ArrivalProcess` (needs ``num_inferences``) or an
        explicit non-decreasing sequence of arrival cycles, one per
        inference.
    compute_latency: cycles between a PE receiving its last request packet
        of an inference and releasing that inference's results - a scalar,
        or one value per PE stream.
    allow_truncation: return partial results when ``max_cycles`` hits with
        inferences in flight (their completions/latencies are -1 and
        ``truncated`` counts them) instead of raising - the saturation
        probe's contract. Phase BT and conservation are only meaningful on
        fully drained runs, so ``record_bt``/``check_conservation`` apply
        as usual only when everything drained.
    record_bt: also run the canonical per-inference phase drains and attach
        them as ``request``/``result`` (the reported-BT contract). Skip in
        load sweeps that join BT from an offline sweep instead.
    """
    if isinstance(arrivals, ArrivalProcess):
        if num_inferences is None:
            raise ValueError("ArrivalProcess arrivals need num_inferences")
        arr = arrivals.times(num_inferences)
    else:
        arr = np.asarray(arrivals, np.int64)
        if arr.ndim != 1 or not arr.size:
            raise ValueError("arrivals must be a non-empty 1-D sequence")
        if num_inferences is not None and num_inferences != arr.size:
            raise ValueError(f"num_inferences={num_inferences} disagrees "
                             f"with {arr.size} explicit arrivals")
    if (np.diff(arr) < 0).any() or arr[0] < 0:
        raise ValueError("arrival cycles must be non-negative and "
                         "non-decreasing")
    k = int(arr.size)

    m_req = int(request.length.shape[0])
    req_nodes = np.asarray(_mc_array(cfg, request, m_req, batched=False))
    m_res = int(result.length.shape[0])
    pes = np.asarray(cfg.pe_nodes, np.int64)
    if m_res < pes.size:
        raise ValueError(f"result traffic has {m_res} streams, config has "
                         f"{pes.size} PEs")
    if m_res > pes.size and np.asarray(result.length)[pes.size:].any():
        raise ValueError("result streams beyond the PE count must be empty "
                         "padding")
    res_nodes = np.concatenate(
        [pes, np.zeros(m_res - pes.size, np.int64)]).astype(np.int32)
    lat = np.broadcast_to(
        np.asarray(compute_latency, np.int64), (m_res,)).copy()
    if (lat < 0).any():
        raise ValueError("compute_latency must be >= 0")

    npkt_req = int(request.num_packets)
    npkt_res = int(result.num_packets)

    # --- request network: every inference's distribution traffic, gated
    # by the arrival process (all MC streams of inference k open together).
    req_cat = concat_inferences(request, k)
    req_len1 = np.asarray(request.length, np.int64)
    req_rel = np.broadcast_to(arr[None, :], (m_req, k))
    req_inc = np.broadcast_to(req_len1[:, None], (m_req, k))
    sched_req, req_it, req_et, req_ep, req_drained = _drain_gated(
        cfg, req_cat, req_nodes, req_rel, req_inc,
        count_headers=count_headers, chunk=chunk, max_cycles=max_cycles,
        allow_truncation=allow_truncation)

    # --- per-(inference, router) delivery: cycle the last request packet
    # destined to that PE router ejected. Routers a workload never
    # addresses fall back to the arrival cycle (nothing to wait for -
    # and they carry no results either).
    pdest = _packet_dest(request)
    et2 = req_et.reshape(k, npkt_req) if npkt_req else req_et.reshape(k, 0)
    delivery = np.broadcast_to(arr[:, None],
                               (k, cfg.num_routers)).astype(np.int64).copy()
    undelivered = bool((et2 < 0).any())
    live = pdest >= 0
    if live.any():
        rows = np.repeat(np.arange(k), int(live.sum()))
        cols = np.tile(pdest[live], k)
        np.maximum.at(delivery, (rows, cols),
                      et2[:, live].astype(np.int64).reshape(-1))

    # --- result network: per-PE release = that PE's delivery + compute
    # latency, monotone along k (a PE processes inferences in order).
    # Inferences whose requests were cut off never release their results.
    far = np.int64(2**31 - 2)
    rel = delivery[:, res_nodes.astype(np.int64)].T + lat[:, None]  # (P, K)
    if undelivered:
        miss = (et2 < 0).any(axis=1)        # (K,) inference lost requests
        rel[:, miss] = far
    rel = np.maximum.accumulate(np.minimum(rel, far), axis=1)
    res_cat = concat_inferences(result, k)
    res_len1 = np.asarray(result.length, np.int64)
    res_inc = np.broadcast_to(res_len1[:, None], (m_res, k))
    sched_res, res_it, res_et, res_ep, res_drained = _drain_gated(
        cfg, res_cat, res_nodes, rel, res_inc,
        count_headers=count_headers, chunk=chunk, max_cycles=max_cycles,
        allow_truncation=allow_truncation) if npkt_res else (
        None, np.zeros(0, np.int32), np.zeros(0, np.int32),
        np.zeros(1, np.int32), True)

    drained = req_drained and res_drained
    if check_conservation and drained:
        for name, tr_cat, ep in (("request", req_cat, req_ep),
                                 ("result", res_cat, res_ep)):
            if int(tr_cat.num_packets) <= 0:
                continue
            err = _conservation_error(
                np.asarray(tr_cat.length), np.asarray(tr_cat.meta),
                np.asarray(tr_cat.pkt), ep, int(tr_cat.num_packets))
            if err:
                raise RuntimeError(f"closed-loop {name}-phase conservation "
                                   f"violated: {err}")

    # --- per-inference completion: the cycle after the last result tail of
    # inference k ejected (request delivery for pure-distribution
    # workloads); -1 while any of its packets is still in flight.
    if npkt_res:
        ret2 = res_et.reshape(k, npkt_res).astype(np.int64)
        done_k = (ret2 >= 0).all(axis=1)
        completions = np.where(done_k, ret2.max(axis=1) + 1, -1)
    else:
        done_k = ((et2 >= 0).all(axis=1) if npkt_req
                  else np.ones(k, bool))
        completions = np.where(done_k, delivery.max(axis=1) + 1, -1)
    latencies = np.where(completions >= 0, completions - arr, -1)

    req_bt = res_bt = None
    if record_bt and drained:
        from .sim import simulate
        req_bt = simulate(cfg, request, count_headers=count_headers,
                          chunk=chunk, max_cycles=max_cycles,
                          check_conservation=check_conservation)
        if npkt_res:
            res_bt = simulate(cfg, result, count_headers=count_headers,
                              chunk=chunk, max_cycles=max_cycles,
                              check_conservation=check_conservation,
                              mc_nodes=res_nodes)

    return OnlineResult(
        arrivals=arr, completions=completions, latencies=latencies,
        truncated=int((completions < 0).sum()),
        request_drain_cycle=sched_req.drain_cycle,
        result_drain_cycle=(sched_res.drain_cycle if sched_res else
                            sched_req.drain_cycle),
        delivery=delivery, release=rel, compute_latency=lat,
        sched_request=sched_req, sched_result=sched_res,
        request=req_bt, result=res_bt,
        request_inj_time=req_it, request_eject_time=req_et,
        result_inj_time=res_it, result_eject_time=res_et)


# --- latency percentiles -------------------------------------------------


def percentile(values: Sequence[float], q: float) -> float:
    """The q-th percentile under linear interpolation - numpy's default
    ``np.percentile(values, q)`` semantics, pinned by tests against the
    numpy reference (ties, single samples, endpoints included)."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile must be in [0, 100], got {q!r}")
    v = np.sort(np.asarray(values, np.float64))
    if not v.size:
        raise ValueError("percentile of an empty sample")
    if v.size == 1:
        return float(v[0])
    pos = (q / 100.0) * (v.size - 1)
    lo = int(np.floor(pos))
    hi = min(lo + 1, v.size - 1)
    frac = pos - lo
    return float(v[lo] * (1.0 - frac) + v[hi] * frac)


def latency_percentiles(latencies: Sequence[int],
                        qs: Tuple[float, ...] = (50.0, 99.0)) -> dict:
    """Percentile summary of a per-inference latency ledger.

    Negative entries mark inferences still in flight at the cutoff
    (truncated runs): they are excluded from the percentiles but MUST be
    surfaced, so the summary always carries ``truncated`` alongside
    ``count`` - silently dropping them would bias every percentile low.
    Percentiles are ``None`` when nothing completed.
    """
    lat = np.asarray(latencies, np.int64)
    if lat.ndim != 1:
        raise ValueError("latencies must be 1-D")
    done = lat[lat >= 0]
    out = {"count": int(done.size), "truncated": int((lat < 0).sum())}
    for q in qs:
        key = f"p{q:g}"
        out[key] = percentile(done, q) if done.size else None
    if done.size:
        out["mean"] = float(done.mean())
        out["max"] = int(done.max())
    else:
        out["mean"] = out["max"] = None
    return out
