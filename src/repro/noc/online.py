"""Closed-loop (online) serving model: overlapped phases under load.

The offline simulator drains one inference's request (MC->PE) and result
(PE->MC) traffic as two independent one-shot phases and leaves the overlap
question open (the old DESIGN.md "Result phase" caveat). This module closes
the loop:

* **Per-PE gating.** A PE may start injecting inference k's results only
  after the request phase delivered *that PE's* last request packet of
  inference k, plus a per-PE compute latency - the compute/communication
  interaction the offline model elides.
* **Back-to-back inferences.** An arrival process (:class:`ArrivalProcess`,
  the offered-load axis) releases inference k's request flits at
  ``arrival[k]``; inference k+1's distribution overlaps inference k's
  results in the mesh.
* **Per-packet timestamps.** The gated drains run with the simulator's
  timestamp ledgers (``noc.sim`` ``timestamps=True``): each packet's
  NI-injection and ejection cycles are harvested into per-inference
  completion times and latency percentiles.

The gating contract (DESIGN.md "Closed-loop serving"):

* **Timing is schedule-determined.** Drain dynamics (routing, credits,
  arbitration) never read payload *values*, so the gated schedule's
  timing - and therefore every latency/throughput figure here - is
  identical across ordering transforms and precisions of one workload.
  One gated drain per offered-load point prices the whole transform axis.
* **BT is data-determined.** The per-phase BT the closed loop *reports*
  (``OnlineResult.request`` / ``.result``) is the canonical per-inference
  phase drain - bit-identical to the offline ``simulate`` phases at any
  compute latency, which is what keeps serving-row BT comparable with
  every offline figure in the repo. Request and result traffic ride
  disjoint virtual networks (the standard request/reply protocol-deadlock
  separation), so the per-phase recorders are well defined. The gated
  drains' own recorders stay available (``sched_request``/``sched_result``:
  they include inter-inference seam transitions and stall/resume
  interleavings) but are a different measurement, not the reported one.

Mechanically the gate is a thin wrapper over the fused step: a stream's
*effective length* at cycle c is the number of flits its release schedule
has unlocked, and the step's own ``ptr < length`` injection guard does the
rest - the router pipeline, recorders, and ledgers are the proven offline
step, byte for byte. With every gate open from cycle 0 the wrapped step is
the offline step (the oracle test pins this bit-for-bit).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .sim import (META_TAIL, SimResult, SimState, Traffic, Wire,
                  _conservation_error, _drain_timeout, _make_step, _mc_array,
                  _mesh_key, _result, fuse_traffic, make_state)
from .topology import NocConfig
from .traffic import concat_inferences

__all__ = ["ArrivalProcess", "OnlineResult", "simulate_online",
           "percentile", "latency_percentiles", "ARRIVAL_KINDS"]

# Release-cycle sentinel for gates that must never open (shed inferences,
# inferences whose upstream phase failed): far beyond any max_cycles.
FAR_RELEASE = np.int64(2**31 - 2)

ARRIVAL_KINDS = ("uniform", "poisson", "backtoback")


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Deterministic offered-load arrival process (cycles are the clock).

    kind: ``uniform`` spaces arrivals ``1000 / load`` cycles apart,
        ``poisson`` draws exponential gaps from a PCG64 stream seeded by
        ``seed`` (bit-reproducible: the same seed replays the same
        process), ``backtoback`` releases everything at cycle 0 - the
        saturation probe.
    load: offered load in inferences per 1000 cycles (ignored by
        ``backtoback``).
    """

    kind: str = "uniform"
    load: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(f"kind must be one of {ARRIVAL_KINDS}, "
                             f"got {self.kind!r}")
        if self.kind != "backtoback" and not self.load > 0:
            raise ValueError(f"offered load must be > 0, got {self.load!r}")

    def times(self, n: int) -> np.ndarray:
        """Arrival cycles of inferences ``0..n-1`` (non-decreasing int64;
        the first arrival is cycle 0)."""
        if n < 1:
            raise ValueError(f"need n >= 1 inferences, got {n}")
        if self.kind == "backtoback":
            return np.zeros(n, np.int64)
        mean_gap = 1000.0 / self.load
        if self.kind == "uniform":
            return np.floor(np.arange(n) * mean_gap).astype(np.int64)
        rng = np.random.Generator(np.random.PCG64(self.seed))
        gaps = rng.exponential(mean_gap, size=n - 1) if n > 1 else []
        return np.concatenate(
            [[0], np.floor(np.cumsum(gaps))]).astype(np.int64)


class _GatedWire(NamedTuple):
    """Fused wire plus its release schedule.

    inc:     (M, K) int32 - flits gate k unlocks on stream m
    release: (M, K) int32 - cycle gate k opens on stream m (non-decreasing
             along K; gates whose flits must stay locked forever use
             a sentinel far beyond max_cycles)
    """

    wire: jax.Array
    length: jax.Array
    inc: jax.Array
    release: jax.Array


def _make_online_step(mesh_key, count_headers: bool, faults=None):
    """The gated step: effective stream length = flits released by now."""
    base = _make_step(mesh_key, count_headers, track=True, timestamps=True,
                      faults=faults)

    def step(state, gwire: _GatedWire, mc_nodes):
        eff = jnp.sum(
            jnp.where(gwire.release <= state.cycle, gwire.inc, 0),
            axis=-1).astype(jnp.int32)
        return base(state, Wire(gwire.wire, eff), mc_nodes)

    return step


@functools.lru_cache(maxsize=None)
def _online_runner(mesh_key, count_headers: bool, chunk: int, faults=None):
    """Compiled ``chunk``-cycle gated driver, cached like ``_chunk_runner``
    (one executable per (state, wire, schedule) shape signature).
    ``faults`` is a hashable fault spec (``faults.StepFaults``) or None."""
    step = _make_online_step(mesh_key, count_headers, faults)

    def run(state, gwire: _GatedWire, mc_nodes):
        def body(s, _):
            return step(s, gwire, mc_nodes), ()
        out, _ = jax.lax.scan(body, state, None, length=chunk)
        return out, out.ejected

    return jax.jit(run, donate_argnums=0)


class _AdmissionController:
    """Chunk-boundary ingress admission control (the overload-shedding knob;
    DESIGN.md "Graceful degradation").

    The gated request drain calls :meth:`step` once per chunk dispatch.
    Arrivals inside the upcoming window ``[cycle, cycle + chunk)`` are
    decided then: an inference is admitted (its gates open at its arrival
    cycle) unless the number of admitted-but-incomplete inferences has
    reached ``threshold``, in which case it is shed - none of its flits
    ever inject. Queue depth is read from the ejection ledger as of the
    chunk boundary, so admission sees completions with up to one chunk of
    staleness: the chunk size is part of the admission semantics exactly
    as it is part of the gating semantics (releases quantize to chunk
    boundaries too).

    **Restart protocol.** The gated wire's effective length is a sum of
    released gate increments, so a gate that never opens mid-stream would
    make later gates unlock the wrong flit positions - shed flits must be
    *removed from the wire*, which a traced drain cannot do mid-run.
    Instead, the first :meth:`step` that sheds a NEW inference (one not in
    ``preshed``) sets ``restart_needed`` and the drain aborts before
    running another cycle; the caller replays the whole drain with the
    enlarged shed set filtered out up front. Because a shed inference
    never injected anything, the replay's dynamics are cycle-identical up
    to the aborted boundary - the protocol is deterministic and
    terminates after one replay per shedding boundary.
    """

    def __init__(self, arrivals: np.ndarray, threshold: int,
                 inc: np.ndarray, chunk: int, npkt_per_inf: int,
                 preshed: Optional[np.ndarray] = None):
        self.arr = np.asarray(arrivals, np.int64)
        self.k = int(self.arr.size)
        self.threshold = int(threshold)
        self.inc = np.asarray(inc, np.int64)            # (M, K)
        self.chunk = int(chunk)
        self.npkt = int(npkt_per_inf)
        self.decided = np.zeros(self.k, bool)
        self.admitted = np.zeros(self.k, bool)
        self.release = np.full(self.inc.shape, FAR_RELEASE, np.int64)
        self.restart_needed = False
        if preshed is not None:
            self.decided |= np.asarray(preshed, bool)

    @property
    def done(self) -> bool:
        return bool(self.decided.all())

    @property
    def shed(self) -> np.ndarray:
        return self.decided & ~self.admitted

    def step(self, cycle: int, eject_time: np.ndarray):
        """Decide arrivals before ``cycle + chunk``; returns the updated
        ``(release, admitted_flit_total)`` or None when nothing changed."""
        if self.restart_needed:
            return None
        todo = np.flatnonzero(~self.decided & (self.arr < cycle + self.chunk))
        if not todo.size:
            return None
        et2 = np.asarray(eject_time).reshape(self.k, self.npkt)
        outstanding = self.admitted & (et2 < 0).any(axis=1)
        for j in todo:
            self.decided[j] = True
            if int(outstanding.sum()) >= self.threshold:
                self.restart_needed = True               # shed: replay
                continue
            self.admitted[j] = True
            outstanding[j] = True
            self.release[:, j] = self.arr[j]
        return self.release, int(self.inc[:, self.admitted].sum())


def _drain_gated(cfg: NocConfig, traffic: Traffic, mc_nodes: np.ndarray,
                 release: np.ndarray, inc: np.ndarray, *,
                 count_headers: bool, chunk: int, max_cycles: int,
                 allow_truncation: bool, faults=None,
                 state: Optional[SimState] = None,
                 controller: Optional[_AdmissionController] = None):
    """Drain ``traffic`` under a release schedule; harvest the ledgers.

    Returns ``(sim_result, inj_time, eject_time, eject_pkt, drained,
    state)`` with the ledgers as host arrays over the real packet ids. The
    gated step's own ``drained_at`` is meaningless mid-gate (its completion
    test sees only released flits), so ``drain_cycle`` is rebuilt from the
    ejection ledger: the cycle after the last tail ejected.

    faults: a hashable ``faults.StepFaults`` spec threaded into the step
        (bit-flip schedule + protection checking); when set, protection
        code bits are stamped into the fused wire's sideband and the state
        carries the flip/bad packet ledgers.
    state: resume from a carried :class:`SimState` (the retransmission
        rounds of ``faults.drain_with_retries``): recorders and ledgers
        accumulate across rounds, and the drain target is offset by the
        carried ``ejected`` count. ``sim_result.injected`` still reports
        only this round's flits.
    controller: an :class:`_AdmissionController` consulted at every chunk
        boundary; the drain completes only once the controller has decided
        every arrival AND all admitted flits ejected.
    """
    m = int(traffic.length.shape[0])
    npkt = int(traffic.num_packets)
    if npkt <= 0:
        raise ValueError("gated drains need Traffic with num_packets set")
    if state is None:
        state = make_state(cfg, m, npkt=npkt, timestamps=True,
                           fault_ledgers=faults is not None)
        start_ej = 0
    else:
        start_ej = int(np.asarray(state.ejected))
    wire = fuse_traffic(traffic, track_pkt=True)
    if faults is not None and faults.protect != "none":
        from .faults import protect_wire
        wire = protect_wire(wire, faults.protect, cfg.lanes)
    gwire = _GatedWire(wire.wire, wire.length,
                       jnp.asarray(inc, jnp.int32),
                       jnp.asarray(release, jnp.int32))
    run = _online_runner(_mesh_key(cfg), count_headers, chunk, faults)
    nodes = jnp.asarray(mc_nodes, jnp.int32)
    total = int(np.sum(np.asarray(traffic.length)))
    drained = False
    while True:
        if controller is not None:
            upd = controller.step(int(state.cycle),
                                  np.asarray(state.eject_time)[:npkt])
            if upd is not None:
                new_rel, total = upd
                gwire = gwire._replace(
                    release=jnp.asarray(new_rel, jnp.int32))
            if controller.restart_needed:
                # Abort before the next chunk: the caller replays with the
                # newly shed inferences filtered out of the wire (see the
                # controller's restart protocol). Partial results are
                # discarded by the caller.
                break
        settled = (controller is None or controller.done)
        if total == 0 and settled:
            drained = True
            break
        if total:
            state, ej = run(state, gwire, nodes)
            if int(ej) - start_ej == total and settled:
                drained = True
                break
        else:
            # Nothing admitted yet but arrivals still pending: idle the
            # mesh one chunk so the controller's clock advances.
            state, ej = run(state, gwire, nodes)
        if int(state.cycle) >= max_cycles:
            break
    restarting = controller is not None and controller.restart_needed
    if not drained and not allow_truncation and not restarting:
        raise _drain_timeout(
            "closed-loop", int(state.cycle), int(state.ejected) - start_ej,
            total, np.asarray(state.count), np.asarray(state.inj_ptr),
            np.asarray(traffic.length),
            eject_time=np.asarray(state.eject_time), npkt=npkt)
    inj_t = np.asarray(state.inj_time)[:npkt]
    ej_t = np.asarray(state.eject_time)[:npkt]
    drain_cycle = int(ej_t.max()) + 1 if (ej_t >= 0).any() else 0
    res = _result(cfg, (np.asarray(state.link_bt),
                        np.asarray(state.link_flits),
                        np.asarray(state.inj_bt), state.ejected, state.cycle,
                        np.int32(drain_cycle)), total)
    return res, inj_t, ej_t, np.asarray(state.eject_pkt), drained, state


def _packet_dest(traffic: Traffic) -> np.ndarray:
    """Destination router per packet id of an unbatched Traffic (-1 for
    ids that never appear - there are none for packetizer-built traffic)."""
    npkt = int(traffic.num_packets)
    dest = np.asarray(traffic.dest)
    meta = np.asarray(traffic.meta)
    pkt = np.asarray(traffic.pkt)
    valid = (np.arange(dest.shape[1])[None, :]
             < np.asarray(traffic.length)[:, None])
    tails = valid & ((meta & META_TAIL) > 0)
    out = np.full(npkt, -1, np.int64)
    out[pkt[tails]] = dest[tails]
    return out


@dataclasses.dataclass
class OnlineResult:
    """One closed-loop run: per-inference timing plus per-phase BT.

    completions[k] is the cycle after inference k's last result tail
    ejected, or -1 while still in flight at the cutoff (truncated runs
    only); latencies[k] = completions[k] - arrivals[k] (or -1). The
    ``request``/``result`` SimResults are the canonical per-inference
    phase drains (the BT contract - ``None`` under ``record_bt=False``);
    ``sched_request``/``sched_result`` are the gated schedule drains whose
    timing every latency figure comes from.
    """

    arrivals: np.ndarray            # (K,) int64 arrival cycles
    completions: np.ndarray         # (K,) int64; -1 = in flight at cutoff
    latencies: np.ndarray           # (K,) int64; -1 = in flight at cutoff
    truncated: int                  # inferences still in flight at cutoff
    request_drain_cycle: int        # gated request-network drain
    result_drain_cycle: int         # gated result-network drain
    delivery: np.ndarray            # (K, NR) per-router request delivery
    release: np.ndarray             # (P, K) result-injection release cycles
    compute_latency: np.ndarray     # (P,) per-PE-stream compute cycles
    sched_request: SimResult
    sched_result: Optional[SimResult]
    request: Optional[SimResult]    # canonical request phase (BT contract)
    result: Optional[SimResult]
    request_inj_time: np.ndarray    # (K * NP_req,) per-packet ledgers
    request_eject_time: np.ndarray
    result_inj_time: np.ndarray
    result_eject_time: np.ndarray
    # --- fault-injection / graceful-degradation extensions (defaulted so
    # the fault-free construction sites stay unchanged) -------------------
    shed: Optional[np.ndarray] = None     # (K,) bool: refused admission
    failed: Optional[np.ndarray] = None   # (K,) bool: dropped/exhausted/
                                          # silently-corrupt packets
    deadline: Optional[int] = None        # per-inference latency SLO
    slo_attained: Optional[np.ndarray] = None  # (K,) bool when deadline set
    fault_ledger: Optional[dict] = None   # merged request+result ledger

    @property
    def completed(self) -> int:
        return int((self.completions >= 0).sum())

    @property
    def throughput(self) -> Optional[float]:
        """Completed inferences per 1000 cycles over the busy span."""
        done = self.completions[self.completions >= 0]
        if not done.size:
            return None
        span = int(done.max()) - int(self.arrivals.min())
        return float(done.size) * 1000.0 / max(span, 1)

    @property
    def num_shed(self) -> int:
        return int(self.shed.sum()) if self.shed is not None else 0

    @property
    def num_failed(self) -> int:
        return int(self.failed.sum()) if self.failed is not None else 0

    @property
    def slo_attainment(self) -> Optional[float]:
        """Fraction of OFFERED inferences that completed within the
        deadline (shed and failed inferences count against it - that is
        the point of reporting attainment under overload/faults)."""
        if self.slo_attained is None:
            return None
        return float(self.slo_attained.sum()) / max(self.slo_attained.size, 1)

    @property
    def goodput(self) -> Optional[float]:
        """SLO-attained inferences per 1000 cycles over the busy span
        (completed inferences when no deadline is set)."""
        ok = (self.slo_attained if self.slo_attained is not None
              else self.completions >= 0)
        done = self.completions[ok & (self.completions >= 0)]
        if not done.size:
            return None
        span = int(done.max()) - int(self.arrivals.min())
        return float(done.size) * 1000.0 / max(span, 1)


def simulate_online(cfg: NocConfig, request: Traffic, result: Traffic, *,
                    arrivals: Union[ArrivalProcess, Sequence[int]],
                    num_inferences: Optional[int] = None,
                    compute_latency: Union[int, Sequence[int]] = 0,
                    count_headers: bool = True, chunk: int = 2048,
                    max_cycles: int = 2_000_000,
                    check_conservation: bool = False,
                    allow_truncation: bool = False,
                    record_bt: bool = True,
                    faults=None,
                    deadline: Optional[int] = None,
                    admit_queue_depth: Optional[int] = None) -> OnlineResult:
    """Closed-loop drain of ``num_inferences`` back-to-back inferences.

    request / result: ONE inference's unbatched phase traffics (e.g.
        ``build_traffic(...)`` and ``build_result_traffic(...).variant(i)``)
        with ``num_packets`` metadata. The result traffic's streams follow
        the ``build_result_traffic`` convention: stream i injects at
        ``cfg.pe_nodes[i]`` (padding streams beyond the PE count must be
        empty).
    arrivals: an :class:`ArrivalProcess` (needs ``num_inferences``) or an
        explicit non-decreasing sequence of arrival cycles, one per
        inference.
    compute_latency: cycles between a PE receiving its last request packet
        of an inference and releasing that inference's results - a scalar,
        or one value per PE stream.
    allow_truncation: return partial results when ``max_cycles`` hits with
        inferences in flight (their completions/latencies are -1 and
        ``truncated`` counts them) instead of raising - the saturation
        probe's contract. Phase BT and conservation are only meaningful on
        fully drained runs, so ``record_bt``/``check_conservation`` apply
        as usual only when everything drained.
    record_bt: also run the canonical per-inference phase drains and attach
        them as ``request``/``result`` (the reported-BT contract). Skip in
        load sweeps that join BT from an offline sweep instead. Canonical
        phase drains are always CLEAN even under ``faults`` - fault-path
        BT lives in the ``sched_request``/``sched_result`` recorders,
        which see corrupted wires and retransmitted flits.
    faults: a :class:`repro.noc.faults.FaultModel`. Both phase drains run
        through ``drain_with_retries`` (bit-flip injection, protection
        checking, bounded ACK/NACK retransmission, unreachable-packet
        drops). Inferences with dropped or retry-exhausted request packets
        never release results; silently corrupted deliveries complete but
        are marked ``failed``.
    deadline: per-inference latency SLO in cycles; sets
        ``slo_attained[k] = completed within deadline and not failed``.
    admit_queue_depth: overload-shedding threshold - arrivals are refused
        admission (``shed``) while that many admitted inferences are still
        incomplete at the chunk boundary deciding them.
    """
    if isinstance(arrivals, ArrivalProcess):
        if num_inferences is None:
            raise ValueError("ArrivalProcess arrivals need num_inferences")
        arr = arrivals.times(num_inferences)
    else:
        arr = np.asarray(arrivals, np.int64)
        if arr.ndim != 1 or not arr.size:
            raise ValueError("arrivals must be a non-empty 1-D sequence")
        if num_inferences is not None and num_inferences != arr.size:
            raise ValueError(f"num_inferences={num_inferences} disagrees "
                             f"with {arr.size} explicit arrivals")
    if (np.diff(arr) < 0).any() or arr[0] < 0:
        raise ValueError("arrival cycles must be non-negative and "
                         "non-decreasing")
    k = int(arr.size)
    if deadline is not None and not deadline > 0:
        raise ValueError(f"deadline must be a positive cycle count, "
                         f"got {deadline!r}")
    if admit_queue_depth is not None and not admit_queue_depth >= 1:
        raise ValueError(f"admit_queue_depth must be >= 1, "
                         f"got {admit_queue_depth!r}")

    m_req = int(request.length.shape[0])
    req_nodes = np.asarray(_mc_array(cfg, request, m_req, batched=False))
    m_res = int(result.length.shape[0])
    pes = np.asarray(cfg.pe_nodes, np.int64)
    if m_res < pes.size:
        raise ValueError(f"result traffic has {m_res} streams, config has "
                         f"{pes.size} PEs")
    if m_res > pes.size and np.asarray(result.length)[pes.size:].any():
        raise ValueError("result streams beyond the PE count must be empty "
                         "padding")
    res_nodes = np.concatenate(
        [pes, np.zeros(m_res - pes.size, np.int64)]).astype(np.int32)
    lat = np.broadcast_to(
        np.asarray(compute_latency, np.int64), (m_res,)).copy()
    if (lat < 0).any():
        raise ValueError("compute_latency must be >= 0")

    npkt_req = int(request.num_packets)
    npkt_res = int(result.num_packets)

    # --- request network: every inference's distribution traffic, gated
    # by the arrival process (all MC streams of inference k open together).
    req_cat = concat_inferences(request, k)
    req_len1 = np.asarray(request.length, np.int64)
    req_rel = np.broadcast_to(arr[None, :], (m_req, k))
    req_inc = np.broadcast_to(req_len1[:, None], (m_req, k))
    if faults is not None:
        from .faults import (STATUS_DROPPED, STATUS_RETRY_EXHAUSTED,
                             drain_with_retries)
    ctrl = None
    fd_req = None
    preshed = np.zeros(k, bool)
    while True:
        req_cat_f, req_inc_f, req_rel_f = req_cat, req_inc, req_rel
        if admit_queue_depth is not None:
            ctrl = _AdmissionController(arr, admit_queue_depth, req_inc,
                                        chunk, npkt_req, preshed=preshed)
            req_rel_f = ctrl.release
            if preshed.any():
                # Shed flits must not sit in the wire (gate increments are
                # positional): filter them out and zero their gates.
                from .traffic import filter_packets
                keep_pkt = np.repeat(~preshed, npkt_req)
                req_cat_f = filter_packets(req_cat, keep_pkt)
                req_inc_f = np.where(preshed[None, :], 0, req_inc)
        if faults is not None:
            fd_req = drain_with_retries(
                cfg, req_cat_f, faults, mc_nodes=req_nodes,
                release=req_rel_f, inc=req_inc_f,
                count_headers=count_headers, chunk=chunk,
                max_cycles=max_cycles, allow_truncation=allow_truncation,
                controller=ctrl)
            sched_req, req_it, req_et = (fd_req.sim, fd_req.inj_time,
                                         fd_req.eject_time)
            req_ep, req_drained = fd_req.eject_counts, fd_req.drained
        else:
            sched_req, req_it, req_et, req_ep, req_drained, _ = _drain_gated(
                cfg, req_cat_f, req_nodes, req_rel_f, req_inc_f,
                count_headers=count_headers, chunk=chunk,
                max_cycles=max_cycles, allow_truncation=allow_truncation,
                controller=ctrl)
        if ctrl is None or not ctrl.restart_needed:
            break
        preshed = ctrl.shed.copy()
    shed_k = ctrl.shed.copy() if ctrl is not None else np.zeros(k, bool)

    # --- per-(inference, router) delivery: cycle the last request packet
    # destined to that PE router ejected. Routers a workload never
    # addresses fall back to the arrival cycle (nothing to wait for -
    # and they carry no results either).
    pdest = _packet_dest(request)
    et2 = req_et.reshape(k, npkt_req) if npkt_req else req_et.reshape(k, 0)
    delivery = np.broadcast_to(arr[:, None],
                               (k, cfg.num_routers)).astype(np.int64).copy()
    live = pdest >= 0
    if live.any():
        rows = np.repeat(np.arange(k), int(live.sum()))
        cols = np.tile(pdest[live], k)
        np.maximum.at(delivery, (rows, cols),
                      et2[:, live].astype(np.int64).reshape(-1))

    # --- result network: per-PE release = that PE's delivery + compute
    # latency, monotone along k (a PE processes inferences in order -
    # inferences that never release occupy no slot in that order).
    # Inferences whose requests were cut off, shed, dropped, or
    # retry-exhausted never release their results.
    rel = delivery[:, res_nodes.astype(np.int64)].T + lat[:, None]  # (P, K)
    blocked = ((et2 < 0).any(axis=1) if npkt_req
               else np.zeros(k, bool))      # lost/unsent request packets
    failed_req = np.zeros(k, bool)
    if fd_req is not None:
        st2 = fd_req.status.reshape(k, npkt_req)
        detected = ((st2 == STATUS_DROPPED)
                    | (st2 == STATUS_RETRY_EXHAUSTED)).any(axis=1)
        failed_req = (detected | fd_req.corrupted.reshape(
            k, npkt_req).any(axis=1)) & ~shed_k
        blocked |= detected     # the PE never assembled the full request
    rel = np.minimum(rel, FAR_RELEASE)
    if blocked.any():
        rel[:, blocked] = FAR_RELEASE
    open_idx = np.flatnonzero(~blocked)
    if open_idx.size:
        rel[:, open_idx] = np.maximum.accumulate(rel[:, open_idx], axis=1)
    res_cat = concat_inferences(result, k)
    res_len1 = np.asarray(result.length, np.int64)
    res_inc = np.broadcast_to(res_len1[:, None], (m_res, k))
    fd_res = None
    if npkt_res:
        res_cat_f, res_inc_f = res_cat, res_inc
        if blocked.any():
            # Result flits of blocked inferences never release: keep them
            # out of the wire (and the drain target) entirely.
            from .traffic import filter_packets
            res_cat_f = filter_packets(res_cat, np.repeat(~blocked, npkt_res))
            res_inc_f = np.where(blocked[None, :], 0, res_inc)
        if faults is not None:
            fd_res = drain_with_retries(
                cfg, res_cat_f, faults, mc_nodes=res_nodes, release=rel,
                inc=res_inc_f, count_headers=count_headers, chunk=chunk,
                max_cycles=max_cycles, allow_truncation=allow_truncation)
            sched_res, res_it, res_et = (fd_res.sim, fd_res.inj_time,
                                         fd_res.eject_time)
            res_ep, res_drained = fd_res.eject_counts, fd_res.drained
        else:
            sched_res, res_it, res_et, res_ep, res_drained, _ = _drain_gated(
                cfg, res_cat_f, res_nodes, rel, res_inc_f,
                count_headers=count_headers, chunk=chunk,
                max_cycles=max_cycles, allow_truncation=allow_truncation)
    else:
        sched_res, res_it, res_et, res_ep, res_drained = (
            None, np.zeros(0, np.int32), np.zeros(0, np.int32),
            np.zeros(1, np.int32), True)

    failed_k = failed_req.copy()
    if fd_res is not None:
        rst2 = fd_res.status.reshape(k, npkt_res)
        failed_k |= (((rst2 == STATUS_DROPPED)
                      | (rst2 == STATUS_RETRY_EXHAUSTED)).any(axis=1)
                     | fd_res.corrupted.reshape(k, npkt_res).any(axis=1))
        failed_k &= ~shed_k

    drained = req_drained and res_drained
    if check_conservation and drained:
        if faults is not None:
            for name, fd in (("request", fd_req), ("result", fd_res)):
                if fd is not None and not fd.ledger.get("conservation_ok"):
                    raise RuntimeError(
                        f"closed-loop {name}-phase fault ledger violated "
                        f"conservation: {fd.ledger}")
        elif ctrl is not None:
            # Shed inferences must eject exactly nothing; admitted ones
            # exactly once per packet.
            exp = np.repeat(ctrl.admitted.astype(req_ep.dtype), npkt_req)
            if not np.array_equal(req_ep[:k * npkt_req], exp):
                raise RuntimeError(
                    "closed-loop request-phase conservation violated under "
                    "admission control: ejection counts disagree with the "
                    "admitted set")
            if npkt_res:
                exp_r = np.repeat(
                    (ctrl.admitted & ~blocked).astype(res_ep.dtype),
                    npkt_res)
                if not np.array_equal(res_ep[:k * npkt_res], exp_r):
                    raise RuntimeError(
                        "closed-loop result-phase conservation violated "
                        "under admission control: ejection counts disagree "
                        "with the released set")
        else:
            for name, tr_cat, ep in (("request", req_cat, req_ep),
                                     ("result", res_cat, res_ep)):
                if int(tr_cat.num_packets) <= 0:
                    continue
                err = _conservation_error(
                    np.asarray(tr_cat.length), np.asarray(tr_cat.meta),
                    np.asarray(tr_cat.pkt), ep, int(tr_cat.num_packets))
                if err:
                    raise RuntimeError(f"closed-loop {name}-phase "
                                       f"conservation violated: {err}")

    # --- per-inference completion: the cycle after the last result tail of
    # inference k ejected (request delivery for pure-distribution
    # workloads); -1 while any of its packets is still in flight.
    if npkt_res:
        ret2 = res_et.reshape(k, npkt_res).astype(np.int64)
        done_k = (ret2 >= 0).all(axis=1)
        completions = np.where(done_k, ret2.max(axis=1) + 1, -1)
    else:
        done_k = ((et2 >= 0).all(axis=1) if npkt_req
                  else np.ones(k, bool))
        completions = np.where(done_k, delivery.max(axis=1) + 1, -1)
    latencies = np.where(completions >= 0, completions - arr, -1)

    slo = None
    if deadline is not None:
        slo = (completions >= 0) & (latencies <= deadline) & ~failed_k
    ledger = None
    if faults is not None:
        ledger = {"request": fd_req.ledger}
        if fd_res is not None:
            ledger["result"] = fd_res.ledger

    req_bt = res_bt = None
    if record_bt and drained:
        from .sim import simulate
        req_bt = simulate(cfg, request, count_headers=count_headers,
                          chunk=chunk, max_cycles=max_cycles,
                          check_conservation=check_conservation)
        if npkt_res:
            res_bt = simulate(cfg, result, count_headers=count_headers,
                              chunk=chunk, max_cycles=max_cycles,
                              check_conservation=check_conservation,
                              mc_nodes=res_nodes)

    degradation = ctrl is not None or faults is not None
    return OnlineResult(
        arrivals=arr, completions=completions, latencies=latencies,
        truncated=int(((completions < 0) & ~shed_k & ~failed_k).sum()),
        request_drain_cycle=sched_req.drain_cycle,
        result_drain_cycle=(sched_res.drain_cycle if sched_res else
                            sched_req.drain_cycle),
        delivery=delivery, release=rel, compute_latency=lat,
        sched_request=sched_req, sched_result=sched_res,
        request=req_bt, result=res_bt,
        request_inj_time=req_it, request_eject_time=req_et,
        result_inj_time=res_it, result_eject_time=res_et,
        shed=shed_k if degradation else None,
        failed=failed_k if degradation else None,
        deadline=deadline, slo_attained=slo, fault_ledger=ledger)


# --- latency percentiles -------------------------------------------------


def percentile(values: Sequence[float], q: float) -> float:
    """The q-th percentile under linear interpolation - numpy's default
    ``np.percentile(values, q)`` semantics, pinned by tests against the
    numpy reference (ties, single samples, endpoints included)."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile must be in [0, 100], got {q!r}")
    v = np.sort(np.asarray(values, np.float64))
    if not v.size:
        raise ValueError("percentile of an empty sample")
    if v.size == 1:
        return float(v[0])
    pos = (q / 100.0) * (v.size - 1)
    lo = int(np.floor(pos))
    hi = min(lo + 1, v.size - 1)
    frac = pos - lo
    return float(v[lo] * (1.0 - frac) + v[hi] * frac)


def latency_percentiles(latencies: Sequence[int],
                        qs: Tuple[float, ...] = (50.0, 99.0)) -> dict:
    """Percentile summary of a per-inference latency ledger.

    Negative entries mark inferences still in flight at the cutoff
    (truncated runs): they are excluded from the percentiles but MUST be
    surfaced, so the summary always carries ``truncated`` alongside
    ``count`` - silently dropping them would bias every percentile low.
    Percentiles are ``None`` when nothing completed.
    """
    lat = np.asarray(latencies, np.int64)
    if lat.ndim != 1:
        raise ValueError("latencies must be 1-D")
    done = lat[lat >= 0]
    out = {"count": int(done.size), "truncated": int((lat < 0).sum())}
    for q in qs:
        key = f"p{q:g}"
        out[key] = percentile(done, q) if done.size else None
    if done.size:
        out["mean"] = float(done.mean())
        out["max"] = int(done.max())
    else:
        out["mean"] = out["max"] = None
    return out
