"""Autotuned drain scheduling: measure candidate chunk/compaction settings
on a short pinned drain and persist the winner per shape class.

``simulate_batch`` has two pure scheduling knobs - the compiled chunk length
and the lane-compaction trigger ``compact_ratio`` - that trade dispatch
round-trips against wasted cycles on retired lanes. The right point depends
on the shape class (mesh size x stream count decide both the step cost and
how spread-out the per-variant drain cycles are), so instead of hand-pinned
constants the sweep can consult a measured table.

The candidate set follows the ``launch/hillclimb.py`` idiom: a small dict of
*named* variants, each encoding one scheduling hypothesis, run against the
same pinned drain. Every candidate is bit-identity-pinned against the first
(``total_bt``/``drain_cycle`` must match exactly - these knobs may only move
wall clock), so a tuning run doubles as a scheduling-invariance test.

Winners persist as JSON (:data:`DEFAULT_PATH`) keyed by
:func:`shape_class`; ``SweepGrid(tune_path=...)`` makes ``run_sweep`` apply
them per mesh. Run directly::

    PYTHONPATH=src python -m repro.noc.tune [mesh ...]
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional, Sequence

from .topology import NocConfig, mesh_by_name

__all__ = ["DrainSchedule", "CANDIDATES", "DEFAULT_PATH", "shape_class",
           "autotune_drain", "load_tuned", "save_tuned", "schedule_for"]

DEFAULT_PATH = "experiments/tune/drain.json"


@dataclasses.dataclass(frozen=True)
class DrainSchedule:
    """One named scheduling candidate: pure wall-clock knobs, no effect on
    any simulated quantity."""
    name: str
    chunk: int
    compact_ratio: float


# (name -> schedule) - names match the tuning-log hypotheses:
# H1 fine:    shorter chunks read drain bookkeeping sooner, so early-drained
#             lanes stop burning steps; wins when drain cycles are spread.
# H2 pinned:  the hand-pinned sweep constants (control).
# H3 coarse:  longer chunks amortize dispatch/readback round-trips and skip
#             most compactions; wins when lanes drain close together.
CANDIDATES: Dict[str, DrainSchedule] = {
    "fine": DrainSchedule("fine", chunk=512, compact_ratio=0.5),
    "pinned": DrainSchedule("pinned", chunk=2048, compact_ratio=0.5),
    "coarse": DrainSchedule("coarse", chunk=8192, compact_ratio=0.25),
}


def shape_class(cfg: NocConfig) -> str:
    """Shape-class key for the tuned table - one compiled simulator (and
    one batched drain) per mesh geometry, matching the sweep's grouping."""
    return f"{cfg.rows}x{cfg.cols}_mc{cfg.num_mcs}"


def autotune_drain(cfg: NocConfig, traffic, *,
                   candidates: Optional[Dict[str, DrainSchedule]] = None,
                   backend: str = "auto", max_cycles: int = 2_000_000,
                   repeats: int = 2) -> dict:
    """Time every candidate schedule on one pinned batched drain.

    ``traffic`` must carry a leading variants axis (a short
    ``build_traffic_batch`` drain is enough - the schedule only depends on
    step cost and drain spread, not on total volume). Each candidate runs
    ``repeats`` times after a warm-up pass that also pins bit-identity
    against the first candidate; the best wall time wins.

    Returns ``{"shape_class", "timings": {name: seconds}, "winner",
    "chunk", "compact_ratio"}`` - the exact record :func:`save_tuned`
    persists.
    """
    from .sim import simulate_batch

    cands = dict(candidates if candidates is not None else CANDIDATES)
    if not cands:
        raise ValueError("need at least one candidate schedule")
    timings: Dict[str, float] = {}
    pin = None
    for name, sched in cands.items():
        run = lambda: simulate_batch(  # noqa: E731
            cfg, traffic, chunk=sched.chunk,
            compact_ratio=sched.compact_ratio, backend=backend,
            max_cycles=max_cycles)
        res = run()                     # warm-up; compiles this chunk size
        got = [(r.total_bt, r.drain_cycle) for r in res]
        if pin is None:
            pin = got
        elif got != pin:
            raise RuntimeError(
                f"candidate {name!r} changed simulated results: {got} "
                f"vs {pin} - drain scheduling must be bit-identical")
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        timings[name] = best
    winner = min(timings, key=timings.get)
    return {"shape_class": shape_class(cfg),
            "timings": {k: round(v, 4) for k, v in timings.items()},
            "winner": winner,
            "chunk": cands[winner].chunk,
            "compact_ratio": cands[winner].compact_ratio}


def load_tuned(path: str = DEFAULT_PATH) -> Dict[str, dict]:
    """Tuned table (shape class -> record); empty when no file yet."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def save_tuned(record: dict, path: str = DEFAULT_PATH) -> Dict[str, dict]:
    """Merge one :func:`autotune_drain` record into the persisted table."""
    table = load_tuned(path)
    table[record["shape_class"]] = {
        k: record[k] for k in ("winner", "chunk", "compact_ratio", "timings")}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
        f.write("\n")
    return table


def schedule_for(cfg: NocConfig,
                 table: Dict[str, dict]) -> Optional[DrainSchedule]:
    """The persisted winner for ``cfg``'s shape class, or None."""
    rec = table.get(shape_class(cfg))
    if rec is None:
        return None
    return DrainSchedule(rec["winner"], int(rec["chunk"]),
                         float(rec["compact_ratio"]))


def _pinned_drain(cfg: NocConfig, max_packets: int):
    """Short deterministic drain: LeNet O0/O1/O2 float32 variants."""
    from benchmarks.fig12 import lenet_layers
    from repro.core.wire import by_name
    from .traffic import build_traffic_batch

    layers = lenet_layers(glyph_seed=7, trained=True)
    variants = [(by_name(n, tiebreak="pattern"), None)
                for n in ("O0", "O1", "O2")]
    return build_traffic_batch(layers, cfg, variants,
                               max_packets_per_layer=max_packets)


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("meshes", nargs="*", default=["4x4_mc2", "8x8_mc4"],
                    help="PAPER_NOCS names / RxC_mcN specs to tune")
    ap.add_argument("--out", default=DEFAULT_PATH)
    ap.add_argument("--max-packets", type=int, default=8,
                    help="per-layer packet budget of the pinned drain")
    ap.add_argument("--backend", default="auto")
    args = ap.parse_args(argv)

    for name in args.meshes:
        cfg = mesh_by_name(name)
        rec = autotune_drain(cfg, _pinned_drain(cfg, args.max_packets),
                             backend=args.backend)
        save_tuned(rec, args.out)
        times = " ".join(f"{k}={v}s" for k, v in rec["timings"].items())
        print(f"[ok] {rec['shape_class']}: winner={rec['winner']} "
              f"(chunk={rec['chunk']} ratio={rec['compact_ratio']}) {times}",
              flush=True)


if __name__ == "__main__":
    main()
