"""NoC substrate: topology, cycle-level simulator, DNN traffic, power model."""
from .topology import NocConfig, PAPER_NOCS, xy_route, neighbor_table
from .sim import Traffic, SimResult, simulate, make_state
from .traffic import (LayerTraffic, build_traffic, conv_layer_traffic,
                      linear_layer_traffic)
from . import power

__all__ = [
    "NocConfig", "PAPER_NOCS", "xy_route", "neighbor_table",
    "Traffic", "SimResult", "simulate", "make_state",
    "LayerTraffic", "build_traffic", "conv_layer_traffic",
    "linear_layer_traffic", "power",
]
