"""NoC substrate: topology, cycle-level simulator, DNN traffic (request
and result phases), sweep engine, power model."""
from .topology import (NocConfig, PAPER_NOCS, PLACEMENTS, AFFINITIES,
                       xy_route, neighbor_table, make_noc, mc_placement,
                       mesh_by_name, affinity_mc_table, packet_mean_hops,
                       alive_link_mask, fault_route_table)
from .sim import (Traffic, Wire, SimResult, DrainTimeout, simulate,
                  simulate_batch, make_state, fuse_traffic, pack_sideband)
from .traffic import (COMPRESSIONS, LayerTraffic, build_traffic,
                      build_traffic_batch, build_traffic_streamed,
                      build_result_traffic, compression_overhead,
                      layer_results, conv_layer_traffic,
                      linear_layer_traffic, filter_packets)
from .sweep import (SweepGrid, SweepReport, run_sweep, run_serving,
                    recovery_overhead_bits)
from .online import (ArrivalProcess, OnlineResult, simulate_online,
                     latency_percentiles, percentile)
from .faults import (FaultModel, FaultDrain, StepFaults, protect_wire,
                     drain_with_retries, simulate_faulty,
                     STATUS_DELIVERED, STATUS_DROPPED,
                     STATUS_RETRY_EXHAUSTED, STATUS_UNSENT)
from . import power

__all__ = [
    "NocConfig", "PAPER_NOCS", "PLACEMENTS", "AFFINITIES", "xy_route",
    "neighbor_table", "make_noc", "mc_placement", "mesh_by_name",
    "affinity_mc_table", "packet_mean_hops", "alive_link_mask",
    "fault_route_table",
    "Traffic", "Wire", "SimResult", "DrainTimeout", "simulate",
    "simulate_batch", "make_state", "fuse_traffic", "pack_sideband",
    "COMPRESSIONS", "LayerTraffic", "build_traffic", "build_traffic_batch",
    "build_traffic_streamed", "build_result_traffic",
    "compression_overhead", "layer_results",
    "conv_layer_traffic", "linear_layer_traffic", "filter_packets",
    "SweepGrid", "SweepReport", "run_sweep", "run_serving",
    "recovery_overhead_bits",
    "ArrivalProcess", "OnlineResult", "simulate_online",
    "latency_percentiles", "percentile",
    "FaultModel", "FaultDrain", "StepFaults", "protect_wire",
    "drain_with_retries", "simulate_faulty",
    "STATUS_DELIVERED", "STATUS_DROPPED", "STATUS_RETRY_EXHAUSTED",
    "STATUS_UNSENT",
    "power",
]
