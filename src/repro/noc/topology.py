"""2D-mesh NoC topology: coordinates, X-Y routing, MC placement.

Matches the paper's evaluated configurations (Sec. V-B): a 4x4 mesh with
2 memory controllers, and 8x8 meshes with 4 or 8 MCs. Routers use
dimension-ordered X-Y routing (X first, then Y), which is deadlock-free on
a mesh with credit-based flow control.

Port numbering (inputs and outputs symmetric):
    0=N  1=E  2=S  3=W  4=Local (input side: injection from the MC/PE NI;
                                 output side: ejection to the PE)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["NocConfig", "PORT_N", "PORT_E", "PORT_S", "PORT_W", "PORT_LOCAL",
           "NUM_PORTS", "OPPOSITE", "xy_route", "neighbor_table", "PAPER_NOCS",
           "PLACEMENTS", "AFFINITIES", "mc_placement", "make_noc",
           "mesh_by_name", "mean_hop_counts", "xy_link_loads",
           "affinity_mc_table", "packet_mean_hops", "alive_link_mask",
           "fault_route_table"]

PORT_N, PORT_E, PORT_S, PORT_W, PORT_LOCAL = 0, 1, 2, 3, 4
NUM_PORTS = 5
# The flit leaving out-port p of a router enters in-port OPPOSITE[p] of the
# neighbor: N<->S, E<->W.
OPPOSITE = np.array([PORT_S, PORT_W, PORT_N, PORT_E, PORT_LOCAL])


@dataclasses.dataclass(frozen=True)
class NocConfig:
    """Static NoC parameters (paper defaults: 4 VCs x 4-flit buffers)."""

    rows: int
    cols: int
    mc_nodes: Tuple[int, ...]      # router ids hosting memory controllers
    num_vcs: int = 4
    vc_depth: int = 4
    lanes: int = 16                # values per flit (512b/f32, 128b/fx8)

    @property
    def num_routers(self) -> int:
        return self.rows * self.cols

    @property
    def num_mcs(self) -> int:
        return len(self.mc_nodes)

    @property
    def pe_nodes(self) -> Tuple[int, ...]:
        return tuple(r for r in range(self.num_routers) if r not in self.mc_nodes)

    @property
    def num_inter_router_links(self) -> int:
        """Bidirectional inter-router links: 2*R*C - R - C each direction pair.

        The paper counts 112 for an 8x8 mesh: 2*8*7 = 112 bidirectional.
        """
        return self.rows * (self.cols - 1) + self.cols * (self.rows - 1)

    def coords(self, node: int) -> Tuple[int, int]:
        return divmod(node, self.cols)

    def node(self, r: int, c: int) -> int:
        return r * self.cols + c


def xy_route(cfg: NocConfig):
    """Precompute the X-Y routing table: out_port[router, dest] -> port id."""
    nr = cfg.num_routers
    table = np.zeros((nr, nr), dtype=np.int32)
    for cur in range(nr):
        r, c = divmod(cur, cfg.cols)
        for dst in range(nr):
            dr, dc = divmod(dst, cfg.cols)
            if dc > c:
                table[cur, dst] = PORT_E
            elif dc < c:
                table[cur, dst] = PORT_W
            elif dr > r:
                table[cur, dst] = PORT_S
            elif dr < r:
                table[cur, dst] = PORT_N
            else:
                table[cur, dst] = PORT_LOCAL
    return jnp.asarray(table)


def neighbor_table(cfg: NocConfig):
    """neighbor[router, out_port] -> downstream router id, -1 at mesh edge."""
    nr = cfg.num_routers
    nb = -np.ones((nr, NUM_PORTS), dtype=np.int32)
    for cur in range(nr):
        r, c = divmod(cur, cfg.cols)
        if r > 0:
            nb[cur, PORT_N] = cfg.node(r - 1, c)
        if c < cfg.cols - 1:
            nb[cur, PORT_E] = cfg.node(r, c + 1)
        if r < cfg.rows - 1:
            nb[cur, PORT_S] = cfg.node(r + 1, c)
        if c > 0:
            nb[cur, PORT_W] = cfg.node(r, c - 1)
    return jnp.asarray(nb)


def _edge_spread(rows: int, cols: int, n: int) -> Tuple[int, ...]:
    """Spread n MCs across the mesh boundary, evenly spaced.

    The paper does not pin MC coordinates; edge placement next to the
    off-chip interface is the standard choice (Fig. 6 places ordering units
    between DRAM and the NoC boundary).
    """
    border = []
    # top row L->R, right col T->B, bottom row R->L, left col B->T
    border += [(0, c) for c in range(cols)]
    border += [(r, cols - 1) for r in range(1, rows)]
    border += [(rows - 1, c) for c in range(cols - 2, -1, -1)]
    border += [(r, 0) for r in range(rows - 2, 0, -1)]
    # single-row/column meshes revisit the same coordinates going back
    border = list(dict.fromkeys(border))
    step = len(border) / n
    picks = [border[int(i * step)] for i in range(n)]
    return tuple(r * cols + c for r, c in picks)


def _corner_spread(rows: int, cols: int, n: int) -> Tuple[int, ...]:
    """Corners first (diagonal-opposite pairs), then evenly along the rest
    of the boundary.

    n=2 gives the two opposite corners, which on square meshes coincides
    with the evenly-spaced edge spread - the symmetry the placement parity
    tests pin (identical mc_nodes => identical sweep rows).
    """
    corners = [(0, 0), (rows - 1, cols - 1), (0, cols - 1), (rows - 1, 0)]
    corners = list(dict.fromkeys(corners))
    picks = corners[:n]
    need = n - len(picks)
    if need > 0:
        border = []
        border += [(0, c) for c in range(cols)]
        border += [(r, cols - 1) for r in range(1, rows)]
        border += [(rows - 1, c) for c in range(cols - 2, -1, -1)]
        border += [(r, 0) for r in range(rows - 2, 0, -1)]
        rest = [b for b in dict.fromkeys(border) if b not in set(picks)]
        step = len(rest) / need
        picks += [rest[int(i * step)] for i in range(need)]
    return tuple(r * cols + c for r, c in picks)


def _interleave_spread(rows: int, cols: int, n: int) -> Tuple[int, ...]:
    """MCs interleaved among the PEs through the whole mesh (row-major,
    evenly spaced) - interior placements the paper never measured, for the
    MC-placement sensitivity axis."""
    nr = rows * cols
    return tuple(int(i * nr / n) for i in range(n))


PLACEMENTS = ("edge", "corner", "interleaved")
_PLACEMENT_FNS = {
    "edge": _edge_spread,
    "corner": _corner_spread,
    "interleaved": _interleave_spread,
}


def mc_placement(rows: int, cols: int, num_mcs: int,
                 strategy: str = "edge") -> Tuple[int, ...]:
    """Router ids hosting the memory controllers under a placement strategy.

    ``edge``: evenly spaced along the mesh boundary (the paper's layout,
    next to the off-chip interface). ``corner``: corners first, then evenly
    along the remaining boundary. ``interleaved``: evenly through the whole
    row-major node list (interior MCs). All strategies are deterministic,
    return distinct nodes, and leave at least one PE router.
    """
    if strategy not in _PLACEMENT_FNS:
        raise KeyError(f"unknown MC placement {strategy!r}; "
                       f"supported: {sorted(_PLACEMENT_FNS)}")
    if num_mcs >= rows * cols:
        raise ValueError(f"{num_mcs} MCs on a {rows}x{cols} mesh leave no "
                         "PE routers to receive traffic")
    boundary = rows * cols - max(rows - 2, 0) * max(cols - 2, 0)
    if num_mcs < 1 or (strategy != "interleaved" and num_mcs > boundary):
        raise ValueError(f"cannot place {num_mcs} MCs on a "
                         f"{rows}x{cols} mesh boundary ({boundary} routers)")
    return _PLACEMENT_FNS[strategy](rows, cols, num_mcs)


def mean_hop_counts(cfg: NocConfig) -> np.ndarray:
    """Per-MC mean Manhattan (X-Y) hop count to the config's PE routers.

    A cheap congestion proxy for the drain scheduler: with packets dealt
    round-robin over PEs, the expected inter-router hops of one injected
    flit equal the mean |dr| + |dc| from its MC to the PE set. Boundary MC
    placements sit far from the PE centroid and saturate boundary links;
    interleaved MCs sit inside it. Purely geometric - no traffic needed.
    """
    pes = np.asarray(cfg.pe_nodes, np.int64)
    pr, pc = pes // cfg.cols, pes % cfg.cols
    out = np.zeros(cfg.num_mcs)
    for i, mc in enumerate(cfg.mc_nodes):
        r, c = divmod(mc, cfg.cols)
        out[i] = (np.abs(pr - r) + np.abs(pc - c)).mean() if pes.size else 0.0
    return out


# Packet->MC affinity strategies for the sweep engine's fourth ordering
# knob: "roundrobin" is the paper's dealing (packet g rides MC g % M),
# "nearest" assigns each PE's packets to the hop-minimizing MC.
AFFINITIES = ("roundrobin", "nearest")


def affinity_mc_table(cfg: NocConfig) -> np.ndarray:
    """Per-PE serving-MC choice minimizing the X-Y hop count: ``table[i]``
    is the MC *stream index* (position in ``cfg.mc_nodes``) that serves
    every packet destined for ``cfg.pe_nodes[i]``.

    Each PE picks the MC with the fewest Manhattan hops; ties break toward
    the MC with the fewest PEs assigned so far (greedy over PEs in node
    order), then toward the lower stream index - fully deterministic. The
    result is the period-``num_pes`` packet->MC table the packetizer
    consumes (packet g is destined for PE ``g % num_pes``, so its serving
    MC is ``table[g % num_pes]``); :func:`xy_link_loads` scores the
    resulting per-MC stream lengths statically for the drain scheduler.
    """
    pes = np.asarray(cfg.pe_nodes, np.int64)
    mcs = np.asarray(cfg.mc_nodes, np.int64)
    if not mcs.size:
        raise ValueError("config has no memory controllers")
    pr, pc = pes // cfg.cols, pes % cfg.cols
    mr, mc = mcs // cfg.cols, mcs % cfg.cols
    hops = (np.abs(pr[:, None] - mr[None, :])
            + np.abs(pc[:, None] - mc[None, :]))        # (num_pes, M)
    table = np.zeros(len(pes), np.int64)
    load = np.zeros(len(mcs), np.int64)
    for i in range(len(pes)):
        best = np.flatnonzero(hops[i] == hops[i].min())
        table[i] = best[np.argmin(load[best])]          # argmin: first tie
        load[table[i]] += 1
    return table


def packet_mean_hops(cfg: NocConfig, num_packets: int,
                     mc_table: Optional[np.ndarray] = None) -> float:
    """Exact mean MC<->PE Manhattan hop count over the first ``num_packets``
    packets of the round-robin PE deal.

    Packet g computes at PE ``g % num_pes``; its serving MC is
    ``mc_table[g % len(mc_table)]`` (any periodic table - the affinity
    tables from :func:`affinity_mc_table` have period ``num_pes``) or
    ``g % num_mcs`` (round-robin, the default). Both the request (MC->PE)
    and result (PE->MC) phases traverse this distance, so the affinity
    knob's hop-count objective is scored by exactly this number.
    """
    if num_packets <= 0:
        return 0.0
    pes = np.asarray(cfg.pe_nodes, np.int64)
    mcs = np.asarray(cfg.mc_nodes, np.int64)
    g = np.arange(num_packets, dtype=np.int64)
    pe = pes[g % len(pes)]
    if mc_table is not None:
        tbl = np.asarray(mc_table, np.int64)
        mc = mcs[tbl[g % len(tbl)]]
    else:
        mc = mcs[g % len(mcs)]
    hops = (np.abs(pe // cfg.cols - mc // cfg.cols)
            + np.abs(pe % cfg.cols - mc % cfg.cols))
    return float(hops.mean())


def xy_link_loads(cfg: NocConfig, lengths) -> np.ndarray:
    """Expected flits per directed inter-router link, (NR, 4) by out-port.

    Assumes each MC's ``lengths[i]`` flits spread uniformly over the PE
    set (the packetizer's PE round-robin is uniform up to one packet) and
    walks every (MC, PE) X-Y path. The hottest link is a congestion lower
    bound on the drain - exactly what separates boundary MC placements
    (whose few escape links carry everything) from interleaved ones whose
    injection bounds are identical. O(M * num_pes * diameter) host work.
    """
    loads = np.zeros((cfg.num_routers, 4))
    pes = cfg.pe_nodes
    if not pes:
        return loads
    for i, mc in enumerate(cfg.mc_nodes):
        if i >= len(lengths):
            break
        w = float(lengths[i]) / len(pes)
        r0, c0 = divmod(mc, cfg.cols)
        for pe in pes:
            r1, c1 = divmod(pe, cfg.cols)
            for c in range(c0, c1):                     # X first: east
                loads[r0 * cfg.cols + c, PORT_E] += w
            for c in range(c0, c1, -1):                 # or west
                loads[r0 * cfg.cols + c, PORT_W] += w
            for r in range(r0, r1):                     # then Y: south
                loads[r * cfg.cols + c1, PORT_S] += w
            for r in range(r0, r1, -1):                 # or north
                loads[r * cfg.cols + c1, PORT_N] += w
    return loads


def alive_link_mask(cfg: NocConfig, dead_links: Tuple[Tuple[int, int], ...] = (),
                    dead_routers: Tuple[int, ...] = ()) -> np.ndarray:
    """``(NR, 4)`` bool: which inter-router out-directions survive the hard
    faults.

    ``dead_links`` entries are ``(router, out_port)`` pairs naming a
    physical channel; the channel is bidirectional, so the opposite
    direction dies with it. Dead routers kill all four of their channels
    (both directions) — a flit can neither enter nor traverse them.
    Directions off the mesh edge are dead by construction.
    """
    nr = cfg.num_routers
    nb = np.asarray(neighbor_table(cfg))
    alive = nb[:, :4] >= 0
    for router, port in dead_links:
        if not (0 <= router < nr and 0 <= port < 4):
            raise ValueError(f"dead link ({router}, {port}) out of range for "
                             f"a {cfg.rows}x{cfg.cols} mesh")
        other = int(nb[router, port])
        if other < 0:
            raise ValueError(f"dead link ({router}, {port}) points off the "
                             "mesh edge - no physical channel there")
        alive[router, port] = False
        alive[other, int(OPPOSITE[port])] = False
    for router in dead_routers:
        if not 0 <= router < nr:
            raise ValueError(f"dead router {router} out of range")
        alive[router, :] = False
        for port in range(4):
            other = int(nb[router, port])
            if other >= 0:
                alive[other, int(OPPOSITE[port])] = False
    return alive


def fault_route_table(cfg: NocConfig,
                      dead_links: Tuple[Tuple[int, int], ...] = (),
                      dead_routers: Tuple[int, ...] = ()):
    """Fault-adaptive routing table and reachability matrix.

    Returns ``(table, reachable)`` where ``table[router, dest]`` is the
    out-port (int32, shape (NR, NR)) and ``reachable[src, dest]`` says a
    packet injected at ``src`` can reach ``dest`` over alive channels.

    The table starts from the exact X-Y table and is repaired **only**
    where the X-Y path is broken: a router whose entire X-Y path to the
    destination is alive keeps its X-Y port (with no hard faults the
    result equals :func:`xy_route` entry for entry — the zero-fault
    bit-identity pin). Broken-but-reachable entries detour along a
    BFS-shortest alive path, always stepping to a neighbor strictly
    closer (in BFS distance) to the destination — loop-free by
    construction, with a deterministic port preference (X-Y port first,
    then N/E/S/W). Detours abandon dimension order, so the X-Y deadlock
    argument no longer covers them; the drain watchdog
    (:class:`repro.noc.sim.DrainTimeout`) is the backstop.

    Entries for unreachable (src, dest) pairs keep their X-Y port and are
    meaningless; callers must pre-filter such packets via ``reachable``
    (``repro.noc.faults`` reports them as ``dropped``).
    """
    nr = cfg.num_routers
    alive = alive_link_mask(cfg, dead_links, dead_routers)
    nb = np.asarray(neighbor_table(cfg))
    dead_r = np.zeros(nr, bool)
    dead_r[list(dead_routers)] = True
    table = np.array(xy_route(cfg), dtype=np.int32)
    reachable = np.zeros((nr, nr), dtype=bool)
    unreach = nr + 1
    rows = np.arange(nr) // cfg.cols
    cols = np.arange(nr) % cfg.cols
    for d in range(nr):
        if dead_r[d]:
            continue                                 # no one reaches a dead router
        # BFS distance to d over alive channels (killed symmetrically, so a
        # reverse search from d equals forward reachability to d).
        dist = np.full(nr, unreach, np.int32)
        dist[d] = 0
        frontier = [d]
        while frontier:
            nxt = []
            for cur in frontier:
                for port in range(4):
                    n = int(nb[cur, port])
                    if n >= 0 and alive[cur, port] and dist[n] == unreach:
                        dist[n] = dist[cur] + 1
                        nxt.append(n)
            frontier = nxt
        reachable[:, d] = dist < unreach
        # X-Y-intact routers, in Manhattan-distance order: each X-Y hop lands
        # strictly closer, so "good" propagates from the destination outward.
        good = np.zeros(nr, bool)
        good[d] = True
        manhattan = np.abs(rows - rows[d]) + np.abs(cols - cols[d])
        for r in np.argsort(manhattan, kind="stable"):
            if r == d:
                continue
            port = int(table[r, d])
            n = int(nb[r, port])
            good[r] = n >= 0 and alive[r, port] and good[n]
        # Repair broken-but-reachable entries with a BFS-descending detour.
        for r in range(nr):
            if r == d or good[r] or dist[r] == unreach:
                continue
            prefs = [int(table[r, d])]
            prefs += [p for p in range(4) if p not in prefs]
            for port in prefs:
                n = int(nb[r, port])
                if n >= 0 and alive[r, port] and dist[n] == dist[r] - 1:
                    table[r, d] = port
                    break
    return table, reachable


# The paper's three evaluated NoC configurations (Sec. V-B).
PAPER_NOCS = {
    "4x4_mc2": NocConfig(4, 4, _edge_spread(4, 4, 2)),
    "8x8_mc4": NocConfig(8, 8, _edge_spread(8, 8, 4)),
    "8x8_mc8": NocConfig(8, 8, _edge_spread(8, 8, 8)),
}


def make_noc(rows: int, cols: int, num_mcs: int, placement: str = "edge",
             **kw) -> NocConfig:
    """Any mesh size under any MC placement strategy.

    The sweep engine uses this to go beyond the paper's three PAPER_NOCS
    (e.g. the 2x2/MC1 CI smoke mesh, 16x16 scaling studies, and the
    MC-placement sensitivity axis); ``placement`` picks one of
    :data:`PLACEMENTS` (default: the paper's boundary spread).
    """
    return NocConfig(rows, cols, mc_placement(rows, cols, num_mcs, placement),
                     **kw)


_MESH_NAME = re.compile(r"^(\d+)x(\d+)_mc(\d+)$")


def mesh_by_name(name: str) -> NocConfig:
    """Resolve a ``RxC_mcN`` mesh name; PAPER_NOCS names resolve exactly."""
    if name in PAPER_NOCS:
        return PAPER_NOCS[name]
    m = _MESH_NAME.match(name)
    if not m:
        raise KeyError(
            f"unknown mesh {name!r}: expected one of {sorted(PAPER_NOCS)} "
            "or a 'RxC_mcN' spec")
    rows, cols, mcs = map(int, m.groups())
    return make_noc(rows, cols, mcs)
