"""Cycle-level NoC simulator in pure JAX (lax.scan over cycles).

Faithful to the paper's evaluation platform (NocDAS-like, Sec. V-B): a 2D
mesh with X-Y dimension-ordered routing, 4 virtual channels per input port
with 4-flit-deep FIFOs, credit-based flow control (conservative one-cycle
credits), round-robin switch allocation per output port, one flit per link
per cycle. Memory controllers inject packetized DNN traffic at their local
ports; flits eject at the destination PE. Bit transitions are recorded on
every link exactly as the paper's Fig. 8 recorder does: the previous word a
link carried is XORed with the current one and the popcount accumulates.

Simplifications (documented in DESIGN.md):
  * static VC assignment - a packet keeps its VC index end-to-end
    ("straight-through" mapping). Link-level interleaving between packets on
    different VCs/ports - the phenomenon the paper stresses - is preserved;
    only the VC-reallocation stage of an IQ router is elided.
  * single-cycle routers (route + arbitrate + traverse in one cycle).
  * result traffic (PE->MC) is not modeled; the paper's figures measure the
    distribution traffic (inputs/weights), which dominates volume.

Everything is fixed-shape and jitted. ``Traffic`` is a *traced argument* of
the compiled cycle chunk (not closed over), so every ordering/precision
variant of the same traffic shape reuses one compiled executable; the
carried ``SimState`` is donated between chunks. :func:`simulate_batch` vmaps
the drain loop over a leading variants axis, which is how the sweep engine
(``repro.noc.sweep``) runs O0/O1/O2 x precision cells of one shape class in
a single compiled program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bits import popcount
from .topology import (NocConfig, NUM_PORTS, OPPOSITE, PORT_LOCAL,
                       neighbor_table, xy_route)

__all__ = ["Traffic", "SimState", "SimResult", "simulate", "simulate_batch",
           "make_state"]

# Flit meta bitfield
META_PAYLOAD = 1
META_TAIL = 2


class Traffic(NamedTuple):
    """Per-MC injection streams, padded to a common length T.

    words:  (M, T, L) uint32 - flit payloads as they appear on the wire
    dest:   (M, T) int32     - destination router id
    meta:   (M, T) int32     - META_* bitfield
    vc:     (M, T) int32     - static VC assignment (round-robin per packet)
    pkt:    (M, T) int32     - packet id (checked by ``check_conservation``)
    length: (M,) int32       - real stream length per MC

    A *batched* Traffic (as built by ``build_traffic_batch`` and consumed by
    :func:`simulate_batch`) carries one extra leading variants axis B on
    every field.
    """

    words: jax.Array
    dest: jax.Array
    meta: jax.Array
    vc: jax.Array
    pkt: jax.Array
    length: jax.Array


class SimState(NamedTuple):
    # FIFO contents; router axis padded by one phantom row absorbing
    # masked-out scatters.
    words: jax.Array   # (NR+1, P, V, D, L) uint32
    dest: jax.Array    # (NR+1, P, V, D) int32
    meta: jax.Array    # (NR+1, P, V, D) int32
    pkt: jax.Array     # (NR+1, P, V, D) int32
    head: jax.Array    # (NR+1, P, V) int32
    count: jax.Array   # (NR+1, P, V) int32
    rr: jax.Array      # (NR, P) int32 round-robin pointer per output port
    link_last: jax.Array  # (NR, P, L) uint32 last word per output link
    link_bt: jax.Array    # (NR, P) int32 accumulated transitions
    link_flits: jax.Array # (NR, P) int32 flits traversed
    inj_ptr: jax.Array    # (M,) int32
    inj_last: jax.Array   # (M, L) uint32 NI link state
    inj_bt: jax.Array     # (M,) int32
    ejected: jax.Array    # () int32 flits delivered
    cycle: jax.Array      # () int32
    eject_pkt: jax.Array  # (NP+1,) int32 tail ejections per pkt id (last row
                          # is a dump slot; NP=0 when conservation tracking
                          # is off)
    drained_at: jax.Array # () int32 first cycle with everything ejected, -1
                          # while the network still holds flits


@dataclasses.dataclass
class SimResult:
    cycles: int
    ejected: int
    injected: int
    link_bt: np.ndarray      # (NR, P) per-output-link transitions
    link_flits: np.ndarray
    inj_bt: np.ndarray       # (M,) NI-link transitions
    total_bt: int            # inter-router + ejection + NI links
    inter_router_bt: int
    # Exact cycle the last flit ejected. ``cycles`` is the chunk-quantized
    # driver-loop count (kept for seed compatibility); throughput metrics
    # should use ``drain_cycle``.
    drain_cycle: Optional[int] = None

    @property
    def bt_per_flit(self) -> float:
        return self.total_bt / max(int(self.link_flits.sum()), 1)


def make_state(cfg: NocConfig, num_mcs: int, npkt: int = 0) -> SimState:
    """Zeroed simulator state. ``npkt``: number of packet ids to track for
    the conservation check (0 disables tracking at ~no cost)."""
    nr, p, v, d, l = cfg.num_routers, NUM_PORTS, cfg.num_vcs, cfg.vc_depth, cfg.lanes
    return SimState(
        words=jnp.zeros((nr + 1, p, v, d, l), jnp.uint32),
        dest=jnp.zeros((nr + 1, p, v, d), jnp.int32),
        meta=jnp.zeros((nr + 1, p, v, d), jnp.int32),
        pkt=jnp.zeros((nr + 1, p, v, d), jnp.int32),
        head=jnp.zeros((nr + 1, p, v), jnp.int32),
        count=jnp.zeros((nr + 1, p, v), jnp.int32),
        rr=jnp.zeros((nr, p), jnp.int32),
        link_last=jnp.zeros((nr, p, l), jnp.uint32),
        link_bt=jnp.zeros((nr, p), jnp.int32),
        link_flits=jnp.zeros((nr, p), jnp.int32),
        inj_ptr=jnp.zeros((num_mcs,), jnp.int32),
        inj_last=jnp.zeros((num_mcs, l), jnp.uint32),
        inj_bt=jnp.zeros((num_mcs,), jnp.int32),
        ejected=jnp.zeros((), jnp.int32),
        cycle=jnp.zeros((), jnp.int32),
        eject_pkt=jnp.zeros((npkt + 1,), jnp.int32),
        drained_at=jnp.full((), -1, jnp.int32),
    )


def _front(state: SimState, nr: int):
    """Gather the front flit of every FIFO -> (NR, P, V, ...)."""
    idx = state.head[:nr, :, :, None]
    fw = jnp.take_along_axis(state.words[:nr], idx[..., None], axis=3)[:, :, :, 0]
    fd = jnp.take_along_axis(state.dest[:nr], idx, axis=3)[:, :, :, 0]
    fm = jnp.take_along_axis(state.meta[:nr], idx, axis=3)[:, :, :, 0]
    fp = jnp.take_along_axis(state.pkt[:nr], idx, axis=3)[:, :, :, 0]
    return fw, fd, fm, fp


def _mesh_key(cfg: NocConfig):
    """The static parameters the compiled step actually depends on.

    MC placement is deliberately excluded: ``mc_nodes`` enters the step as
    a traced argument, so NoC configs differing only in MC count/placement
    (e.g. 8x8/MC4 vs 8x8/MC8, with MC streams padded to a common count)
    share one executable.
    """
    return (cfg.rows, cfg.cols, cfg.num_vcs, cfg.vc_depth, cfg.lanes)


def _make_step(mesh_key, count_headers: bool):
    """One router cycle as a pure function of (state, traffic, mc_nodes).

    Unlike the seed implementation this does NOT close over the traffic
    tensors: they are traced arguments, so one compiled step serves every
    traffic value of the same shape (all orderings/precisions of a sweep
    shape class, and every MC placement of a mesh size).
    """
    rows, cols, num_vcs, vc_depth, lanes = mesh_key
    cfg = NocConfig(rows, cols, (), num_vcs=num_vcs, vc_depth=vc_depth,
                    lanes=lanes)    # mc-free view: routing/geometry only
    nr, p, v, d, l = cfg.num_routers, NUM_PORTS, cfg.num_vcs, cfg.vc_depth, cfg.lanes
    nslots = p * v
    route = xy_route(cfg)                      # (NR, NR)
    nb = neighbor_table(cfg)                   # (NR, P)
    opp = jnp.asarray(OPPOSITE)

    def step(state: SimState, traffic: Traffic, mc_nodes: jax.Array):
        m = traffic.length.shape[0]
        t_cap = traffic.words.shape[1]
        valid = state.count[:nr] > 0                       # (NR, P, V)
        fw, fd, fm, fp = _front(state, nr)

        # --- route computation (X-Y, deterministic) ---
        rid = jnp.arange(nr)[:, None, None]
        out_port = route[rid, fd]                          # (NR, P, V)

        # --- credit check: downstream FIFO (same VC) has space ---
        down = nb[rid, out_port]                            # (NR, P, V)
        down_ip = opp[out_port]
        vcs = jnp.arange(v)[None, None, :]
        down_cnt = state.count[jnp.where(down < 0, nr, down), down_ip, vcs]
        is_eject = out_port == PORT_LOCAL
        space = jnp.where(is_eject, True, (down >= 0) & (down_cnt < d))
        request = valid & space                             # (NR, P, V)

        # --- switch allocation: round-robin per (router, out_port) ---
        # req_po[r, o, slot]: slot = p*V + v requests output o
        slot_req = request.reshape(nr, nslots)
        slot_out = out_port.reshape(nr, nslots)
        outs = jnp.arange(NUM_PORTS)[None, :, None]
        req_po = slot_req[:, None, :] & (slot_out[:, None, :] == outs)
        rot_idx = (jnp.arange(nslots)[None, None, :] + state.rr[:, :, None]) % nslots
        rot = jnp.take_along_axis(req_po, rot_idx, axis=2)
        has = jnp.any(rot, axis=2)                          # (NR, P_out)
        first = jnp.argmax(rot, axis=2)
        winner = (first + state.rr) % nslots                # (NR, P_out)
        rr_new = jnp.where(has, (winner + 1) % nslots, state.rr)

        # --- pops ---
        onehot = (jnp.arange(nslots)[None, None, :] == winner[:, :, None]) & has[:, :, None]
        pop = jnp.any(onehot, axis=1).reshape(nr, p, v)     # (NR, P, V)
        head_new = jnp.where(pop, (state.head[:nr] + 1) % d, state.head[:nr])
        count_new = state.count[:nr] - pop.astype(jnp.int32)
        head2 = state.head.at[:nr].set(head_new)
        count2 = state.count.at[:nr].set(count_new)

        # --- gather moved flits per (router, out_port) ---
        win_p = winner // v
        win_v = winner % v
        r2 = jnp.arange(nr)[:, None]
        mv_word = fw[r2, win_p, win_v]                      # (NR, P_out, L)
        mv_dest = fd[r2, win_p, win_v]
        mv_meta = fm[r2, win_p, win_v]
        mv_pkt = fp[r2, win_p, win_v]

        # --- link BT recording (the Fig. 8 recorder) ---
        tog = popcount(state.link_last ^ mv_word).sum(-1).astype(jnp.int32)
        if count_headers:
            counted = has
        else:
            counted = has & ((mv_meta & META_PAYLOAD) > 0)
        link_bt = state.link_bt + jnp.where(counted, tog, 0)
        link_flits = state.link_flits + has.astype(jnp.int32)
        link_last = jnp.where(has[:, :, None], mv_word, state.link_last)

        # --- pushes into downstream FIFOs ---
        o_ids = jnp.arange(NUM_PORTS)[None, :]
        push_ok = has & (o_ids != PORT_LOCAL)
        down_r = nb[jnp.arange(nr)[:, None], o_ids]         # (NR, P_out)
        tgt_r = jnp.where(push_ok & (down_r >= 0), down_r, nr)  # phantom row
        tgt_p = opp[o_ids] * jnp.ones((nr, 1), jnp.int32)
        tgt_v = win_v
        slot = (head2[tgt_r, tgt_p, tgt_v] + count2[tgt_r, tgt_p, tgt_v]) % d

        fr, fo = tgt_r.reshape(-1), tgt_p.reshape(-1)
        fv, fs = tgt_v.reshape(-1), slot.reshape(-1)
        words3 = state.words.at[fr, fo, fv, fs].set(mv_word.reshape(-1, l))
        dest3 = state.dest.at[fr, fo, fv, fs].set(mv_dest.reshape(-1))
        meta3 = state.meta.at[fr, fo, fv, fs].set(mv_meta.reshape(-1))
        pkt3 = state.pkt.at[fr, fo, fv, fs].set(mv_pkt.reshape(-1))
        count3 = count2.at[fr, fo, fv].add(push_ok.reshape(-1).astype(jnp.int32))

        ejected = state.ejected + jnp.sum(has & (o_ids == PORT_LOCAL))

        # --- conservation ledger: tail flits ejecting at their PE ---
        npcap = state.eject_pkt.shape[0] - 1
        ej_tail = has & (o_ids == PORT_LOCAL) & ((mv_meta & META_TAIL) > 0)
        ledger_idx = jnp.where(ej_tail, jnp.minimum(mv_pkt, npcap), npcap)
        eject_pkt = state.eject_pkt.at[ledger_idx.reshape(-1)].add(
            ej_tail.reshape(-1).astype(jnp.int32))

        # --- injection: one flit per MC per cycle into the local in-port ---
        ptr = state.inj_ptr
        active = ptr < traffic.length
        safe_ptr = jnp.minimum(ptr, t_cap - 1)
        mrange = jnp.arange(m)
        iw = traffic.words[mrange, safe_ptr]                # (M, L)
        idst = traffic.dest[mrange, safe_ptr]
        imeta = traffic.meta[mrange, safe_ptr]
        ivc = traffic.vc[mrange, safe_ptr]
        ipkt = traffic.pkt[mrange, safe_ptr]
        mc_cnt = count3[mc_nodes, PORT_LOCAL, ivc]
        can = active & (mc_cnt < d)
        tgt_mr = jnp.where(can, mc_nodes, nr)
        islot = (head2[tgt_mr, PORT_LOCAL, ivc] + count3[tgt_mr, PORT_LOCAL, ivc]) % d
        words4 = words3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(iw)
        dest4 = dest3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(idst)
        meta4 = meta3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(imeta)
        pkt4 = pkt3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(ipkt)
        count4 = count3.at[tgt_mr, PORT_LOCAL, ivc].add(can.astype(jnp.int32))
        ptr_new = ptr + can.astype(jnp.int32)

        # NI-link BT (MC -> router); the ordering unit sits right before it.
        itog = popcount(state.inj_last ^ iw).sum(-1).astype(jnp.int32)
        if count_headers:
            icounted = can
        else:
            icounted = can & ((imeta & META_PAYLOAD) > 0)
        inj_bt = state.inj_bt + jnp.where(icounted, itog, 0)
        inj_last = jnp.where(can[:, None], iw, state.inj_last)

        total = jnp.sum(traffic.length)
        drained_at = jnp.where((state.drained_at < 0) & (ejected >= total),
                               state.cycle + 1, state.drained_at)

        return SimState(words4, dest4, meta4, pkt4, head2, count4, rr_new,
                        link_last, link_bt, link_flits, ptr_new, inj_last,
                        inj_bt, ejected, state.cycle + 1, eject_pkt,
                        drained_at)

    return step


@functools.lru_cache(maxsize=None)
def _chunk_runner(mesh_key, count_headers: bool, chunk: int, batched: bool):
    """Compiled ``chunk``-cycle driver for one (mesh size, recorder) pair.

    Returned once per static key and cached; jax.jit then caches one
    executable per (state, traffic, mc_nodes) shape signature, so
    re-simulating a new traffic value of a known shape costs zero retraces
    (the seed driver re-traced on every Traffic). The carried state is
    donated chunk-to-chunk.
    """
    step = _make_step(mesh_key, count_headers)

    def run(state: SimState, traffic: Traffic,
            mc_nodes: jax.Array) -> SimState:
        def body(s, _):
            return step(s, traffic, mc_nodes), ()
        out, _ = jax.lax.scan(body, state, None, length=chunk)
        return out

    if batched:
        run = jax.vmap(run, in_axes=(0, 0, None))
    return jax.jit(run, donate_argnums=0)


@functools.lru_cache(maxsize=None)
def _sharded_chunk_runner(mesh_key, count_headers: bool, chunk: int,
                          dev_mesh):
    """``_chunk_runner(batched=True)`` with the variants axis split across
    the devices of ``dev_mesh`` via shard_map.

    Variant lanes are fully independent (the vmapped drain exchanges
    nothing between them), so each device runs the plain local vmap over
    its slice - results are bit-identical to the single-device runner by
    construction, and the only cross-device traffic is the host readback
    of the drain bookkeeping between chunks.
    """
    from jax.experimental.shard_map import shard_map

    step = _make_step(mesh_key, count_headers)

    def run(state: SimState, traffic: Traffic,
            mc_nodes: jax.Array) -> SimState:
        def body(s, _):
            return step(s, traffic, mc_nodes), ()
        out, _ = jax.lax.scan(body, state, None, length=chunk)
        return out

    run = jax.vmap(run, in_axes=(0, 0, None))
    spec_b = jax.sharding.PartitionSpec("variants")
    run = shard_map(run, mesh=dev_mesh,
                    in_specs=(spec_b, spec_b, jax.sharding.PartitionSpec()),
                    out_specs=spec_b, check_rep=False)
    return jax.jit(run, donate_argnums=0)


def _conservation_error(traffic_row, eject_pkt: np.ndarray,
                        npkt: int) -> Optional[str]:
    """Check every injected pkt id ejected exactly once; None when clean."""
    length = np.asarray(traffic_row.length)
    meta = np.asarray(traffic_row.meta)
    pkt = np.asarray(traffic_row.pkt)
    valid = np.arange(meta.shape[1])[None, :] < length[:, None]
    tails = valid & ((meta & META_TAIL) > 0)
    injected = np.bincount(pkt[tails].reshape(-1), minlength=npkt)[:npkt]
    ejected = eject_pkt[:npkt]
    bad_inj = np.flatnonzero(injected > 1)
    if bad_inj.size:
        return (f"packet ids injected more than once: {bad_inj[:8].tolist()}"
                f" (counts {injected[bad_inj[:8]].tolist()})")
    present = injected > 0
    bad = np.flatnonzero(ejected[present] != 1)
    if bad.size:
        ids = np.flatnonzero(present)[bad]
        return (f"packet ids not ejected exactly once: {ids[:8].tolist()}"
                f" (eject counts {ejected[ids[:8]].tolist()})")
    stray = np.flatnonzero(~present & (ejected != 0))
    if stray.size:
        return f"ejections for never-injected packet ids: {stray[:8].tolist()}"
    return None


def _npkt(traffic: Traffic) -> int:
    pkt = np.asarray(traffic.pkt)
    return int(pkt.max()) + 1 if pkt.size else 0


def _mc_array(cfg: NocConfig, traffic: Traffic, m: int,
              batched: bool) -> jax.Array:
    """Validate the traffic's MC-stream count against ``cfg`` and return the
    per-stream injection node ids, padded to ``m``.

    Traffic may carry more streams than the config has MCs (the sweep
    engine pads MC counts within a mesh-size group so every placement
    shares one executable); padding streams must be empty, and their node
    ids are irrelevant because an empty stream never injects.
    """
    if m < cfg.num_mcs:
        raise ValueError(
            f"traffic has {m} MC streams, config has {cfg.num_mcs}")
    length = np.asarray(traffic.length)
    pad = length[..., cfg.num_mcs:] if batched else length[cfg.num_mcs:]
    if m > cfg.num_mcs and np.any(pad != 0):
        raise ValueError(
            f"traffic has {m} MC streams for a {cfg.num_mcs}-MC config and "
            "the extra streams are not empty padding")
    nodes = tuple(cfg.mc_nodes) + (0,) * (m - cfg.num_mcs)
    return jnp.asarray(nodes, jnp.int32)


def _result(cfg: NocConfig, state_leaves, total: int) -> SimResult:
    (link_bt, link_flits, inj_bt, ejected, cycle, drained_at) = state_leaves
    inter = int(link_bt[:, :PORT_LOCAL].sum())
    total_bt = int(link_bt.sum() + inj_bt.sum())
    drain = int(drained_at)
    return SimResult(
        cycles=int(cycle), ejected=int(ejected), injected=total,
        link_bt=link_bt, link_flits=link_flits, inj_bt=inj_bt,
        total_bt=total_bt, inter_router_bt=inter,
        drain_cycle=drain if drain >= 0 else int(cycle))


def simulate(cfg: NocConfig, traffic: Traffic, *, count_headers: bool = True,
             max_cycles: int = 2_000_000, chunk: int = 4096,
             check_conservation: bool = False) -> SimResult:
    """Run the NoC until all traffic drains; returns per-link BT counts.

    check_conservation: debug path - track tail ejections per packet id and
        raise if any injected packet id does not eject exactly once.
    """
    m = int(traffic.length.shape[0])
    mc_nodes = _mc_array(cfg, traffic, m, batched=False)
    npkt = _npkt(traffic) if check_conservation else 0
    state = make_state(cfg, m, npkt=npkt)
    run_chunk = _chunk_runner(_mesh_key(cfg), count_headers, chunk, False)

    total = int(np.sum(np.asarray(traffic.length)))
    while total:    # empty traffic: nothing to drain (and T may be 0)
        state = run_chunk(state, traffic, mc_nodes)
        drained = (int(state.ejected) == total)
        if drained or int(state.cycle) >= max_cycles:
            break
    if int(state.ejected) != total:
        raise RuntimeError(
            f"NoC did not drain: {int(state.ejected)}/{total} flits ejected "
            f"after {int(state.cycle)} cycles")
    if check_conservation:
        err = _conservation_error(traffic, np.asarray(state.eject_pkt), npkt)
        if err:
            raise RuntimeError(f"packet conservation violated: {err}")
    return _result(cfg, (np.asarray(state.link_bt), np.asarray(state.link_flits),
                         np.asarray(state.inj_bt), state.ejected, state.cycle,
                         state.drained_at), total)


def simulate_batch(cfg: NocConfig, traffic: Traffic, *,
                   count_headers: bool = True, max_cycles: int = 2_000_000,
                   chunk: int = 4096, check_conservation: bool = False,
                   devices=None) -> List[SimResult]:
    """Drain B traffic variants (leading axis) in one vmapped program.

    All variants must share shapes - which O0/O1/O2 x precision variants of
    one sweep shape class do by construction (ordering permutes words within
    packets and never changes the flit geometry). The drain loop steps every
    variant until the slowest one empties; already-drained variants idle at
    zero cost to correctness (no flits move, BT accumulators freeze) and
    their exact drain time is read from ``drain_cycle``.

    devices: shard the variants axis across these devices (shard_map over a
        1-D device mesh; the batch is padded with empty traffic rows up to
        a device multiple). Per-variant results are bit-identical to the
        single-device drain - variant lanes never communicate. ``None`` or
        a single device falls back to the plain vmapped runner.
    """
    if traffic.length.ndim != 2:
        raise ValueError("simulate_batch wants a leading variants axis; "
                         "use simulate() for a single Traffic")
    b, m = traffic.length.shape
    mc_nodes = _mc_array(cfg, traffic, m, batched=True)
    npkt = _npkt(traffic) if check_conservation else 0
    base = make_state(cfg, m, npkt=npkt)
    devs = list(devices) if devices is not None else []
    if len(devs) > 1:
        # Lazy import: repro.dist pulls in repro.models, which imports this
        # package back for its layer_traffic helpers.
        from repro.dist.sharding import batch_shardings
        bp = -(-b // len(devs)) * len(devs)
        if bp != b:
            traffic = Traffic(*(
                jnp.concatenate(
                    [x, jnp.zeros((bp - b,) + x.shape[1:], x.dtype)])
                for x in traffic))
        state = jax.tree.map(lambda x: jnp.stack([x] * bp), base)
        dev_mesh = jax.sharding.Mesh(np.asarray(devs), ("variants",))
        state = jax.device_put(
            state, batch_shardings(dev_mesh, state, "variants"))
        traffic = jax.device_put(
            traffic, batch_shardings(dev_mesh, traffic, "variants"))
        run_chunk = _sharded_chunk_runner(_mesh_key(cfg), count_headers,
                                          chunk, dev_mesh)
    else:
        state = jax.tree.map(lambda x: jnp.stack([x] * b), base)
        run_chunk = _chunk_runner(_mesh_key(cfg), count_headers, chunk, True)

    totals = np.asarray(traffic.length).sum(axis=1)
    ejected = np.asarray(state.ejected)
    while totals.sum():   # empty traffic: nothing to drain (and T may be 0)
        state = run_chunk(state, traffic, mc_nodes)
        ejected = np.asarray(state.ejected)
        if np.all(ejected == totals) or int(np.asarray(state.cycle).max()) >= max_cycles:
            break
    if not np.all(ejected == totals):
        lag = np.flatnonzero(ejected != totals)
        raise RuntimeError(
            f"NoC did not drain for variants {lag.tolist()}: "
            f"{ejected[lag].tolist()}/{totals[lag].tolist()} flits ejected "
            f"after {int(np.asarray(state.cycle).max())} cycles")

    link_bt = np.asarray(state.link_bt)
    link_flits = np.asarray(state.link_flits)
    inj_bt = np.asarray(state.inj_bt)
    cycles = np.asarray(state.cycle)
    drained_at = np.asarray(state.drained_at)
    eject_pkt = np.asarray(state.eject_pkt)
    host_traffic = ([np.asarray(x) for x in traffic]
                    if check_conservation else None)
    out = []
    for i in range(b):
        if check_conservation:
            row = Traffic(*(x[i] for x in host_traffic))
            err = _conservation_error(row, eject_pkt[i], npkt)
            if err:
                raise RuntimeError(
                    f"packet conservation violated (variant {i}): {err}")
        out.append(_result(cfg, (link_bt[i], link_flits[i], inj_bt[i],
                                 ejected[i], cycles[i], drained_at[i]),
                           int(totals[i])))
    return out
