"""Cycle-level NoC simulator in pure JAX (lax.scan over cycles).

Faithful to the paper's evaluation platform (NocDAS-like, Sec. V-B): a 2D
mesh with X-Y dimension-ordered routing, 4 virtual channels per input port
with 4-flit-deep FIFOs, credit-based flow control (conservative one-cycle
credits), round-robin switch allocation per output port, one flit per link
per cycle. Memory controllers inject packetized DNN traffic at their local
ports; flits eject at the destination PE. Bit transitions are recorded on
every link exactly as the paper's Fig. 8 recorder does: the previous word a
link carried is XORed with the current one and the popcount accumulates.

Simplifications (documented in DESIGN.md):
  * static VC assignment - a packet keeps its VC index end-to-end
    ("straight-through" mapping). Link-level interleaving between packets on
    different VCs/ports - the phenomenon the paper stresses - is preserved;
    only the VC-reallocation stage of an IQ router is elided.
  * single-cycle routers (route + arbitrate + traverse in one cycle).
  * result traffic (PE->MC) is modeled as an *independent second drain*
    (the paper's figures measure only the MC->PE distribution traffic):
    ``repro.noc.traffic.build_result_traffic`` packetizes per-PE result
    streams and this same simulator drains them - the injection-node
    argument (``mc_nodes``) names the flit *sources*, which for the result
    phase are the PE routers. See DESIGN.md "Result phase".

Fused-state hot loop (see DESIGN.md "Fused router step"): the per-flit
sideband (dest | META | VC) is packed into one uint32 word and stacked with
the payload lanes, so the per-cycle FIFO traffic is one sideband gather,
one winner-flit gather, and one combined push+inject scatter; X-Y routing,
port opposition, neighbor lookup, and the credit/count bookkeeping are
closed-form coordinate arithmetic and *static-index* gathers (XLA:CPU
lowers dynamic table gathers and scatters to scalar loops - they were most
of the cycle time); the BT recorder runs through
``jax.lax.population_count`` (the SWAR form in ``repro.core.bits`` stays
the oracle); and the per-packet conservation ledger exists only under
``check_conservation=True``. The pre-overhaul step survives verbatim in
``repro.noc._reference`` and the parity tests pin this step bit-for-bit
against it.

Everything is fixed-shape and jitted. Traffic enters the compiled cycle
chunk as a *traced argument* (not closed over), so every ordering/precision
variant of the same traffic shape reuses one compiled executable; the
carried ``SimState`` is donated between chunks. :func:`simulate_batch`
vmaps the drain over a leading variants axis, pipelines chunk dispatch
ahead of the host-side drain bookkeeping, and retires drained variants by
compacting the live lanes into a narrower batch (exact per-variant
``drain_cycle`` either way).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bits import popcount_hw
from .topology import (NocConfig, NUM_PORTS, OPPOSITE, PORT_E, PORT_LOCAL,
                       PORT_N, PORT_S, PORT_W)

__all__ = ["Traffic", "Wire", "SimState", "SimResult", "DrainTimeout",
           "simulate", "simulate_batch", "make_state", "fuse_traffic",
           "pack_sideband", "BACKENDS"]

# Flit meta bitfield
META_PAYLOAD = 1
META_TAIL = 2

# Packed sideband word layout (one uint32 lane stacked after the payload):
#   bits 0..8    destination router id (up to 512 routers; 16x16 = 256)
#   bits 9..10   META bitfield (META_PAYLOAD | META_TAIL)
#   bits 11..15  static VC index (up to 32 VCs)
SIDE_DEST_BITS = 9
SIDE_META_SHIFT = 9
SIDE_VC_SHIFT = 11
_DEST_MASK = (1 << SIDE_DEST_BITS) - 1
_META_MASK = 3
MAX_ROUTERS = 1 << SIDE_DEST_BITS
MAX_VCS = 1 << (16 - SIDE_VC_SHIFT)


class Traffic(NamedTuple):
    """Per-source injection streams, padded to a common length T.

    M is the stream count: one stream per MC for the request phase
    (``build_traffic*``), one per PE for the result phase
    (``build_result_traffic``); the ``mc_nodes`` argument of the simulate
    entry points names each stream's injection router.

    words:  (M, T, L) uint32 - flit payloads as they appear on the wire
    dest:   (M, T) int32     - destination router id
    meta:   (M, T) int32     - META_* bitfield
    vc:     (M, T) int32     - static VC assignment (round-robin per packet)
    pkt:    (M, T) int32     - packet id (checked by ``check_conservation``)
    length: (M,) int32       - real stream length per source
    num_packets: int         - packet-id count, carried as metadata by the
        packetizer so the conservation path never has to pull the full
        ``pkt`` tensor to the host just to size its ledger. ``-1`` means
        unknown (hand-built Traffic) and falls back to ``pkt.max()``.

    A *batched* Traffic (as built by ``build_traffic_batch`` and consumed by
    :func:`simulate_batch`) carries one extra leading variants axis B on
    every array field; ``num_packets`` stays a single int (the skeleton is
    shared across variants).
    """

    words: jax.Array
    dest: jax.Array
    meta: jax.Array
    vc: jax.Array
    pkt: jax.Array
    length: jax.Array
    num_packets: int = -1

    def variant(self, i) -> "Traffic":
        """One variant row of a batched Traffic (metadata preserved)."""
        return self._replace(
            words=self.words[i], dest=self.dest[i], meta=self.meta[i],
            vc=self.vc[i], pkt=self.pkt[i], length=self.length[i])


class Wire(NamedTuple):
    """Fused wire-format traffic: the simulator's traced input.

    wire: (M, T, LF) uint32 - payload lanes, then the packed sideband lane,
        then (only when the conservation ledger is on) a packet-id lane.
    length: (M,) int32
    """

    wire: jax.Array
    length: jax.Array


def pack_sideband(dest: jax.Array, meta: jax.Array, vc: jax.Array) -> jax.Array:
    """Pack (dest, META, VC) into the one-word sideband layout.

    Fields must fit their bitfields (dest < 512, meta < 4, vc < 32) or
    they bleed into each other; :func:`simulate` / :func:`simulate_batch`
    validate traffic against the config before packing.
    """
    return (dest.astype(jnp.uint32)
            | (meta.astype(jnp.uint32) << SIDE_META_SHIFT)
            | (vc.astype(jnp.uint32) << SIDE_VC_SHIFT))


def fuse_traffic(traffic: Traffic, track_pkt: bool = False) -> Wire:
    """Stack payload lanes with the packed sideband (and optional pkt lane).

    One device-side copy per simulate call; every per-cycle injection read
    then costs a single gather instead of five.
    """
    side = pack_sideband(traffic.dest, traffic.meta, traffic.vc)
    parts = [traffic.words, side[..., None]]
    if track_pkt:
        parts.append(traffic.pkt.astype(jnp.uint32)[..., None])
    return Wire(jnp.concatenate(parts, axis=-1), traffic.length)


class SimState(NamedTuple):
    # Fused FIFO contents: payload lanes | sideband | optional pkt lane.
    # Router axis padded by one phantom row absorbing masked-out scatters.
    fifo: jax.Array    # (NR+1, P, V, D, LF) uint32
    head: jax.Array    # (NR+1, P, V) int32
    count: jax.Array   # (NR+1, P, V) int32
    rr: jax.Array      # (NR, P) int32 round-robin pointer per output port
    link_last: jax.Array  # (NR, P, L) uint32 last word per output link
    link_bt: jax.Array    # (NR, P) int32 accumulated transitions
    link_flits: jax.Array # (NR, P) int32 flits traversed
    inj_ptr: jax.Array    # (M,) int32
    inj_last: jax.Array   # (M, L) uint32 NI link state
    inj_bt: jax.Array     # (M,) int32
    ejected: jax.Array    # () int32 flits delivered
    cycle: jax.Array      # () int32
    # Conservation ledger: tail ejections per pkt id (last row is a dump
    # slot). ``None`` - the field does not exist - unless the drain runs
    # with check_conservation; production drains pay nothing for it.
    eject_pkt: Optional[jax.Array]   # (NP+1,) int32 or None
    drained_at: jax.Array # () int32 first cycle with everything ejected, -1
                          # while the network still holds flits
    # Per-packet timestamp ledgers (the closed-loop serving model's
    # latency source, see repro.noc.online): cycle the header flit left the
    # NI and cycle the tail flit ejected at its destination, indexed by
    # packet id with a dump slot last. ``None`` - the fields do not exist -
    # unless the drain runs with ``timestamps=True``; like the conservation
    # ledger, production drains pay nothing for them.
    inj_time: Optional[jax.Array] = None     # (NP+1,) int32 or None
    eject_time: Optional[jax.Array] = None   # (NP+1,) int32 or None
    # Fault-injection ledgers (repro.noc.faults): per-packet counts of
    # ground-truth bit-flip events (``flip_pkt``, independent of any
    # protection scheme) and of protection-detected corrupt flits observed
    # at ejection (``bad_pkt``). ``None`` unless the drain runs with a
    # fault spec; the fault-free step never materializes them.
    flip_pkt: Optional[jax.Array] = None     # (NP+1,) int32 or None
    bad_pkt: Optional[jax.Array] = None      # (NP+1,) int32 or None


@dataclasses.dataclass
class SimResult:
    cycles: int
    ejected: int
    injected: int
    link_bt: np.ndarray      # (NR, P) per-output-link transitions
    link_flits: np.ndarray
    inj_bt: np.ndarray       # (M,) NI-link transitions
    total_bt: int            # inter-router + ejection + NI links
    inter_router_bt: int
    # Exact cycle the last flit ejected. ``cycles`` is the chunk-quantized
    # driver-loop count (kept for seed compatibility); throughput metrics
    # should use ``drain_cycle``.
    drain_cycle: Optional[int] = None

    @property
    def bt_per_flit(self) -> float:
        return self.total_bt / max(int(self.link_flits.sum()), 1)


_TIME_UNSET = np.int32(2**31 - 1)   # inj_time sentinel: "never injected"


class DrainTimeout(RuntimeError):
    """A drain hit ``max_cycles`` with flits still in the network.

    The watchdog replacement for spinning forever (or failing with a bare
    count): carries a diagnostic snapshot so a routing bug, dead link, or
    undersized ``max_cycles`` is attributable from the exception alone.

    Attributes:
        cycle, ejected, total: where the drain stood when it gave up.
        occupancy: list of ``(router, port, flits)`` for every non-empty
            input-FIFO block (flits summed over the VCs), busiest first.
        pending: list of ``(stream, flits_not_yet_injected)`` per source
            stream with uninjected traffic.
        undelivered: packet ids with no tail ejection recorded, when the
            drain ran with the packet ledger armed; ``None`` otherwise.
    """

    def __init__(self, message: str, *, cycle: int, ejected: int, total: int,
                 occupancy=None, pending=None, undelivered=None):
        super().__init__(message)
        self.cycle = cycle
        self.ejected = ejected
        self.total = total
        self.occupancy = occupancy or []
        self.pending = pending or []
        self.undelivered = undelivered


def _drain_timeout(context: str, cycle: int, ejected: int, total: int,
                   count: np.ndarray, inj_ptr: np.ndarray,
                   lengths: np.ndarray,
                   eject_time: Optional[np.ndarray] = None,
                   npkt: int = 0) -> DrainTimeout:
    """Build the watchdog diagnostic from one lane's final state leaves."""
    nr = count.shape[0] - 1                      # drop the phantom row
    occ = count[:nr].sum(axis=-1)                # (NR, P) flits over VCs
    rp = np.argwhere(occ > 0)
    order = np.argsort(-occ[occ > 0], kind="stable")
    occupancy = [(int(r), int(p_), int(occ[r, p_]))
                 for r, p_ in rp[order]]
    pending = [(int(i), int(lengths[i] - inj_ptr[i]))
               for i in np.flatnonzero(inj_ptr < lengths)]
    undelivered = None
    if eject_time is not None and npkt > 0:
        undelivered = np.flatnonzero(eject_time[:npkt] < 0).tolist()
    parts = [f"{context} did not drain: {ejected}/{total} flits ejected "
             f"after {cycle} cycles"]
    if pending:
        parts.append(f"{sum(n for _, n in pending)} flits uninjected across "
                     f"{len(pending)} streams")
    if occupancy:
        parts.append("occupied FIFOs (router, port, flits): "
                     f"{occupancy[:8]}" + (" ..." if len(occupancy) > 8 else ""))
    if undelivered is not None:
        parts.append(f"{len(undelivered)} undelivered packet ids: "
                     f"{undelivered[:16]}"
                     + (" ..." if len(undelivered) > 16 else ""))
    return DrainTimeout("; ".join(parts), cycle=cycle, ejected=ejected,
                        total=total, occupancy=occupancy, pending=pending,
                        undelivered=undelivered)


def make_state(cfg: NocConfig, num_mcs: int, npkt: int = 0,
               timestamps: bool = False,
               fault_ledgers: bool = False) -> SimState:
    """Zeroed simulator state. ``npkt``: number of packet ids to track for
    the conservation check (0 omits the ledger and its pkt lane entirely).
    ``timestamps`` adds the per-packet injection/ejection cycle ledgers
    (requires ``npkt > 0`` - the ledgers are indexed by packet id).
    ``fault_ledgers`` adds the per-packet flip/detection counters the
    fault-injection step writes (requires ``timestamps``)."""
    if timestamps and npkt <= 0:
        raise ValueError("timestamps=True needs npkt > 0 (the ledgers are "
                         "indexed by packet id)")
    if fault_ledgers and not timestamps:
        raise ValueError("fault_ledgers=True requires timestamps=True (the "
                         "fault step needs the timing ledgers for retries)")
    nr, p, v, d, l = cfg.num_routers, NUM_PORTS, cfg.num_vcs, cfg.vc_depth, cfg.lanes
    if nr > MAX_ROUTERS:
        raise ValueError(f"{nr} routers exceed the {SIDE_DEST_BITS}-bit "
                         f"sideband dest field ({MAX_ROUTERS} max)")
    if cfg.num_vcs > MAX_VCS:
        raise ValueError(f"{cfg.num_vcs} VCs exceed the sideband VC field "
                         f"({MAX_VCS} max)")
    track = npkt > 0
    lf = l + 1 + (1 if track else 0)
    return SimState(
        fifo=jnp.zeros((nr + 1, p, v, d, lf), jnp.uint32),
        head=jnp.zeros((nr + 1, p, v), jnp.int32),
        count=jnp.zeros((nr + 1, p, v), jnp.int32),
        rr=jnp.zeros((nr, p), jnp.int32),
        link_last=jnp.zeros((nr, p, l), jnp.uint32),
        link_bt=jnp.zeros((nr, p), jnp.int32),
        link_flits=jnp.zeros((nr, p), jnp.int32),
        inj_ptr=jnp.zeros((num_mcs,), jnp.int32),
        inj_last=jnp.zeros((num_mcs, l), jnp.uint32),
        inj_bt=jnp.zeros((num_mcs,), jnp.int32),
        ejected=jnp.zeros((), jnp.int32),
        cycle=jnp.zeros((), jnp.int32),
        eject_pkt=jnp.zeros((npkt + 1,), jnp.int32) if track else None,
        drained_at=jnp.full((), -1, jnp.int32),
        inj_time=(jnp.full((npkt + 1,), _TIME_UNSET, jnp.int32)
                  if timestamps else None),
        eject_time=(jnp.full((npkt + 1,), -1, jnp.int32)
                    if timestamps else None),
        flip_pkt=(jnp.zeros((npkt + 1,), jnp.int32)
                  if fault_ledgers else None),
        bad_pkt=(jnp.zeros((npkt + 1,), jnp.int32)
                 if fault_ledgers else None),
    )


def _mesh_key(cfg: NocConfig):
    """The static parameters the compiled step actually depends on.

    MC placement is deliberately excluded: ``mc_nodes`` enters the step as
    a traced argument, so NoC configs differing only in MC count/placement
    (e.g. 8x8/MC4 vs 8x8/MC8, with MC streams padded to a common count)
    share one executable.
    """
    return (cfg.rows, cfg.cols, cfg.num_vcs, cfg.vc_depth, cfg.lanes)


def _mix32(x: jax.Array) -> jax.Array:
    """SplitMix32 finalizer: a cheap counter-based uniform uint32 hash.

    The fault schedule is a pure function of (seed, cycle, link id) through
    this hash - no RNG state threads through the scan, so replaying a seed
    reproduces the exact flip schedule (pinned by the replay tests), and a
    lower soft-error rate's flip set is a subset of a higher one's (same
    hash, smaller threshold).
    """
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _make_step(mesh_key, count_headers: bool, track: bool,
               timestamps: bool = False, faults=None):
    """One router cycle as a pure function of (state, wire, mc_nodes).

    ``faults`` (a hashable spec with ``rate``/``seed``/``protect``/
    ``dead_links``/``dead_routers`` fields, see
    :class:`repro.noc.faults.StepFaults`; requires ``track`` and
    ``timestamps``) compiles the fault-injection hooks into the step:
    hard faults swap the closed-form X-Y route for the detour table from
    :func:`repro.noc.topology.fault_route_table`; transient faults XOR a
    seeded single-bit flip into the payload lanes of a traversing flit
    *before* the BT recorder and the downstream write, so the recorded
    wire toggles are the corrupted wire's; protection codes carried in
    sideband bits 16+ are re-derived at ejection and mismatches land in
    the ``bad_pkt`` ledger (ground-truth flip events land in ``flip_pkt``
    regardless of protection). All hooks are trace-time conditionals:
    with ``faults=None`` the emitted computation is byte-identical to
    before the fault subsystem existed.

    ``timestamps`` (requires ``track``) additionally records each packet's
    header-flit NI-injection cycle and tail-flit ejection cycle into the
    ``inj_time``/``eject_time`` ledgers - the closed-loop serving model's
    latency source (``repro.noc.online``). Off by default; the untracked
    production step is byte-identical with the flag off.

    Bit-identical to the pre-overhaul step (``repro.noc._reference``,
    pinned by tests/test_noc_step.py) with the hot-path structure changed:

    * one front gather of the packed sideband word per FIFO (the payload is
      gathered only for the <= NR*P switch winners, not every FIFO slot);
    * X-Y routing, port opposition, and downstream-router lookup are
      coordinate arithmetic / compile-time constants - XLA:CPU lowers table
      gathers to scalar loops, and these were half the cycle time;
    * the credit check gathers every neighbor's input-FIFO counts with a
      *static* index array (the four downstream blocks per router are fixed
      by the mesh) and selects by out_port elementwise;
    * round-robin arbitration picks winners by a masked min over the
      rotation distance instead of gathering a rotated request matrix;
    * pushes and injections write one combined scatter (their FIFO targets
      are provably disjoint: pushes never write local in-ports), and the
      FIFO-count increments are reconstructed receiver-side from another
      static-index gather instead of a second scatter.
    """
    if timestamps and not track:
        raise ValueError("timestamps=True requires track=True (the ledgers "
                         "are indexed by the tracked pkt lane)")
    rows, cols, num_vcs, vc_depth, lanes = mesh_key
    cfg = NocConfig(rows, cols, (), num_vcs=num_vcs, vc_depth=vc_depth,
                    lanes=lanes)    # mc-free view: routing/geometry only
    nr, p, v, d, l = cfg.num_routers, NUM_PORTS, cfg.num_vcs, cfg.vc_depth, cfg.lanes
    lf = l + 1 + (1 if track else 0)
    nslots = p * v

    # Compile-time routing constants (replacing the route/neighbor tables).
    coords = np.arange(nr)
    rrow_np, rcol_np = coords // cols, coords % cols
    rrow = jnp.asarray(rrow_np[:, None, None], jnp.int32)   # (NR, 1, 1)
    rcol = jnp.asarray(rcol_np[:, None, None], jnp.int32)
    # Downstream router per (router, out_port); phantom row nr at mesh edges
    # (the direction a port faces is static, only *whether* a flit goes
    # there is dynamic).
    delta_np = np.array([-cols, 1, cols, -1, 0])
    down_np = coords[:, None] + delta_np[None, :]
    dir_ok = np.stack([rrow_np > 0, rcol_np < cols - 1, rrow_np < rows - 1,
                       rcol_np > 0, np.zeros(nr, bool)], axis=1)
    down_o = jnp.asarray(np.where(dir_ok, down_np, nr), jnp.int32)  # (NR, P)
    opp4 = OPPOSITE[:4]                  # in/out opposition, non-local ports
    # Credit blocks: for output direction o of router r, the downstream
    # input FIFO block (nbr(r,o), opp(o)); phantom block (always count 0)
    # where the direction leaves the mesh.
    nb_blk = jnp.asarray(
        np.where(dir_ok[:, :4], down_np[:, :4] * NUM_PORTS + opp4[None, :],
                 nr * NUM_PORTS), jnp.int32)                        # (NR, 4)
    # Receiver-centric incoming map: in-port ip of router r receives the
    # winner of output opp(ip) at neighbor nbr(r, ip), when it exists.
    src_ok = jnp.asarray(dir_ok[:, :4])                             # (NR, 4)
    src_po = jnp.asarray(
        np.where(dir_ok[:, :4], down_np[:, :4], 0) * NUM_PORTS
        + opp4[None, :], jnp.int32)                                 # (NR, 4)
    rcv_base = jnp.asarray(
        coords[:, None] * NUM_PORTS + np.arange(4)[None, :], jnp.int32)
    phantom_row = nr * NUM_PORTS * num_vcs * vc_depth

    # --- fault-injection trace-time constants (None: no fault code at all)
    flips_on = False
    protect_bits = 0
    if faults is not None:
        if not (track and timestamps):
            raise ValueError("fault injection requires track=True and "
                             "timestamps=True (per-packet ledgers)")
        from repro.core.wire import PROTECTION_BITS, protection_syndrome_masks
        from .topology import fault_route_table
        route_np, _ = fault_route_table(cfg, tuple(faults.dead_links),
                                        tuple(faults.dead_routers))
        froute = jnp.asarray(route_np.reshape(-1), jnp.int32)     # (NR*NR,)
        flips_on = float(faults.rate) > 0.0
        if flips_on:
            flip_thresh = jnp.uint32(
                min(int(round(float(faults.rate) * 2.0**32)), 2**32 - 1))
            flip_seed = np.uint32(np.uint64(int(faults.seed)) & np.uint64(0xFFFFFFFF))
        protect_bits = PROTECTION_BITS[faults.protect]
        if protect_bits:
            syn = jnp.asarray(protection_syndrome_masks(faults.protect, l),
                              jnp.uint32)                         # (pb, L)

    def step(state: SimState, wire: Wire, mc_nodes: jax.Array):
        m = wire.length.shape[0]
        t_cap = wire.wire.shape[1]
        flip_pkt, bad_pkt = state.flip_pkt, state.bad_pkt
        head_r = state.head[:nr]                           # (NR, P, V)
        count_r = state.count[:nr]
        valid = count_r > 0

        # Row-flat FIFO views: gathers/scatters move whole LF-word rows
        # (one contiguous memcpy per flit) instead of per-element loops.
        fifo_rows = state.fifo.reshape((nr + 1) * p * v * d, lf)

        # --- front sideband: one word per FIFO ---
        side_col = fifo_rows[:, l]                         # strided slice
        front_row = (jnp.arange(nr * p * v, dtype=jnp.int32) * d
                     + head_r.reshape(-1))
        fside = jnp.take(side_col, front_row,
                         mode="clip").astype(jnp.int32)
        fside = fside.reshape(nr, p, v)
        fd = fside & _DEST_MASK                            # (NR, P, V)

        # --- route computation (X-Y, closed form) ---
        if faults is None:
            dr, dc = fd // cols, fd % cols
            out_port = jnp.where(
                dc > rcol, PORT_E, jnp.where(
                    dc < rcol, PORT_W, jnp.where(
                        dr > rrow, PORT_S, jnp.where(
                            dr < rrow, PORT_N, PORT_LOCAL)))).astype(jnp.int32)
        else:
            # Detour table: X-Y where intact, BFS-descending around dead
            # links (equal to X-Y entry-for-entry when no hard faults).
            # Garbage dests of empty FIFOs are masked by ``valid`` below.
            r_ids = jnp.arange(nr, dtype=jnp.int32)[:, None, None]
            out_port = jnp.take(froute, r_ids * nr + jnp.minimum(fd, nr - 1),
                                mode="clip")

        # --- credit check: downstream FIFO (same VC) has space ---
        # One static-index gather of every neighbor's input-FIFO counts
        # (the four possible downstream blocks per router are fixed by the
        # mesh), then an elementwise select by the flit's out_port. X-Y
        # routing never points off-mesh for a real flit, and ejection needs
        # no credit.
        is_eject = out_port == PORT_LOCAL
        count_blocks = state.count.reshape((nr + 1) * p, v)
        ok = (jnp.take(count_blocks, nb_blk.reshape(-1), axis=0,
                       mode="clip").reshape(nr, 4, v) < d)  # (NR, dir, V)
        space = jnp.where(
            out_port == PORT_N, ok[:, None, PORT_N, :], jnp.where(
                out_port == PORT_E, ok[:, None, PORT_E, :], jnp.where(
                    out_port == PORT_S, ok[:, None, PORT_S, :],
                    ok[:, None, PORT_W, :])))
        request = valid & (is_eject | space)               # (NR, P, V)

        # --- switch allocation: round-robin per (router, out_port) ---
        # winner = requesting slot with the smallest rotation distance from
        # the rr pointer (exactly the rotated-argmax of the old step).
        slot_req = request.reshape(nr, nslots)
        slot_out = out_port.reshape(nr, nslots)
        outs = jnp.arange(NUM_PORTS)[None, :, None]
        req_po = slot_req[:, None, :] & (slot_out[:, None, :] == outs)
        slots = jnp.arange(nslots, dtype=jnp.int32)[None, None, :]
        rel = slots - state.rr[:, :, None]
        rel = jnp.where(rel < 0, rel + nslots, rel)        # mod w/o division
        min_rel = jnp.where(req_po, rel, nslots).min(axis=2)  # (NR, P_out)
        has = min_rel < nslots
        winner = state.rr + min_rel
        winner = jnp.where(winner >= nslots, winner - nslots, winner)
        rr_new = winner + 1
        rr_new = jnp.where(rr_new >= nslots, rr_new - nslots, rr_new)
        rr_new = jnp.where(has, rr_new, state.rr)

        # --- pops ---
        pop = ((slots == winner[:, :, None]) & has[:, :, None]).any(axis=1)
        pop = pop.reshape(nr, p, v)                         # (NR, P, V)
        head_new = jnp.where(pop, (head_r + 1) % d, head_r)
        count_new = count_r - pop.astype(jnp.int32)
        head2 = state.head.at[:nr].set(head_new)
        count2 = state.count.at[:nr].set(count_new)

        # --- gather the winners' flits only: (NR, P_out, LF) ---
        win_p = winner // v
        win_v = winner % v
        r2 = jnp.arange(nr, dtype=jnp.int32)[:, None]
        win_pv = (r2 * p + win_p) * v + win_v              # (NR, P_out)
        win_head = jnp.take(state.head.reshape(-1), win_pv.reshape(-1),
                            mode="clip")
        win_row = win_pv.reshape(-1) * d + win_head
        mv = jnp.take(fifo_rows, win_row, axis=0,
                      mode="clip").reshape(nr, p, lf)
        if flips_on:
            # Transient per-link soft error: hash (seed, cycle, link id)
            # into a uniform word; a hit XORs one payload bit of the flit
            # traversing that link this cycle. Applied *before* the BT
            # recorder and the downstream FIFO write: the recorded wire
            # toggles and the delivered data are the corrupted ones.
            # Sideband and pkt lanes are never flipped (control/ledger
            # integrity is out of scope; DESIGN.md "Fault model").
            cyc_u = state.cycle.astype(jnp.uint32)
            lid = jnp.arange(nr * p, dtype=jnp.uint32).reshape(nr, p)
            h = _mix32(_mix32(lid + flip_seed)
                       ^ (cyc_u * jnp.uint32(0x9E3779B9)))
            hit = has & (h < flip_thresh)                       # (NR, P)
            bitpos = _mix32(h ^ jnp.uint32(0x632BE5AB)) % jnp.uint32(32 * l)
            hit_lane = (bitpos // 32).astype(jnp.int32)
            hit_word = jnp.uint32(1) << (bitpos % 32)
            lanes_ax = jnp.arange(l, dtype=jnp.int32)[None, None, :]
            fmask = jnp.where(
                (lanes_ax == hit_lane[..., None]) & hit[..., None],
                hit_word[..., None], jnp.uint32(0))
            mv = jnp.concatenate([mv[..., :l] ^ fmask, mv[..., l:]], axis=-1)
        mv_side = mv[..., l].astype(jnp.int32)
        mv_meta = (mv_side >> SIDE_META_SHIFT) & _META_MASK

        # --- link BT recording (the Fig. 8 recorder) ---
        tog = popcount_hw(state.link_last ^ mv[..., :l]).sum(-1)
        if count_headers:
            counted = has
        else:
            counted = has & ((mv_meta & META_PAYLOAD) > 0)
        link_bt = state.link_bt + jnp.where(counted, tog, 0)
        link_flits = state.link_flits + has.astype(jnp.int32)
        link_last = jnp.where(has[:, :, None], mv[..., :l], state.link_last)

        # --- pushes, receiver-side ---
        # In-port ip of router r receives the winner of output opp(ip) at
        # neighbor nbr(r, ip) - a *static* mapping, so the incoming flit,
        # its VC, and the write slot all come from static-index gathers and
        # local elementwise math; no dynamic sender->receiver indexing.
        o_ids = jnp.arange(NUM_PORTS)[None, :]
        inc_ok = (jnp.take(has.reshape(-1), src_po.reshape(-1), mode="clip")
                  .reshape(nr, 4) & src_ok)
        inc_vc = jnp.take(win_v.reshape(-1), src_po.reshape(-1),
                          mode="clip").reshape(nr, 4)
        inc_w = jnp.take(mv.reshape(nr * p, lf), src_po.reshape(-1),
                         axis=0, mode="clip")               # (NR*4, LF)
        # Write slot per (router, in-port): (head + count) of the incoming
        # VC's FIFO, selected elementwise over the V axis.
        wc4 = (head2[:nr, :4, :] + count2[:nr, :4, :]) % d   # (NR, 4, V)
        wslot = wc4[..., 0]
        for vi in range(1, v):      # static V-way select, no gather
            wslot = jnp.where(inc_vc == vi, wc4[..., vi], wslot)
        ejected = state.ejected + jnp.sum(has & (o_ids == PORT_LOCAL))

        # --- conservation ledger: tail flits ejecting at their PE ---
        if track:
            mv_pkt = mv[..., l + 1].astype(jnp.int32)
            npcap = state.eject_pkt.shape[0] - 1
            ej_tail = has & (o_ids == PORT_LOCAL) & ((mv_meta & META_TAIL) > 0)
            ledger_idx = jnp.where(ej_tail, jnp.minimum(mv_pkt, npcap), npcap)
            eject_pkt = state.eject_pkt.at[ledger_idx.reshape(-1)].add(
                ej_tail.reshape(-1).astype(jnp.int32))
            if timestamps:
                # A packet's tail ejects exactly once, so max() against the
                # -1 init records the cycle; non-tail rows write -1 into the
                # dump slot (a no-op under max).
                eject_time = state.eject_time.at[ledger_idx.reshape(-1)].max(
                    jnp.where(ej_tail, state.cycle, -1).reshape(-1))
            if flips_on:
                # Ground-truth corruption ledger: every flip event marks
                # the victim packet, whatever the protection scheme.
                f_idx = jnp.where(hit, jnp.minimum(mv_pkt, npcap), npcap)
                flip_pkt = flip_pkt.at[f_idx.reshape(-1)].add(
                    hit.reshape(-1).astype(jnp.int32))
            if protect_bits:
                # MC/PE-side detection at ejection: re-derive the code over
                # the (possibly corrupted) payload and compare with the
                # carried sideband bits. Linearity makes the mismatch a
                # function of the flip mask alone, never the payload - so
                # detection (and hence retransmission timing) is
                # schedule-determined, like the gating contract.
                ej_any = has & (o_ids == PORT_LOCAL)
                carried = (mv_side >> 16) & ((1 << protect_bits) - 1)
                code = jnp.zeros((nr, p), jnp.int32)
                for j in range(protect_bits):
                    pj = (popcount_hw(mv[..., :l] & syn[j]).sum(-1)
                          & 1).astype(jnp.int32)
                    code = code | (pj << j)
                mism = ej_any & (code != carried)
                b_idx = jnp.where(mism, jnp.minimum(mv_pkt, npcap), npcap)
                bad_pkt = bad_pkt.at[b_idx.reshape(-1)].add(
                    mism.reshape(-1).astype(jnp.int32))
        else:
            eject_pkt = None

        # --- injection: one flit per MC per cycle into the local in-port ---
        ptr = state.inj_ptr
        active = ptr < wire.length
        safe_ptr = jnp.minimum(ptr, t_cap - 1)
        mrange = jnp.arange(m)
        iw = wire.wire[mrange, safe_ptr]                    # (M, LF)
        iside = iw[..., l].astype(jnp.int32)
        imeta = (iside >> SIDE_META_SHIFT) & _META_MASK
        if protect_bits:
            # Protection codes ride sideband bits 16+: mask them out of the
            # VC extraction (fault-free sidebands carry nothing up there, so
            # the unmasked shift below is the same value).
            ivc = (iside >> SIDE_VC_SHIFT) & (MAX_VCS - 1)
        else:
            ivc = iside >> SIDE_VC_SHIFT
        # Pushes never touch local in-ports, so the local-port counts in
        # ``count2`` are already post-push values: injection composes with
        # the push scatter below without an intermediate count array.
        head2_flat = head2.reshape(-1)
        count2_flat = count2.reshape(-1)
        mc_pv = (mc_nodes * p + PORT_LOCAL) * v + ivc
        mc_cnt = jnp.take(count2_flat, mc_pv, mode="clip")
        can = active & (mc_cnt < d)
        if flips_on:
            # NI-link soft error: the flit entering the mesh this cycle is
            # a flit-hop too. Same hash family, link ids offset past the
            # router links. Applied before the combined scatter and the NI
            # BT recorder below.
            ilid = jnp.arange(m, dtype=jnp.uint32) + jnp.uint32(nr * p)
            ih = _mix32(_mix32(ilid + flip_seed)
                        ^ (cyc_u * jnp.uint32(0x9E3779B9)))
            ihit = can & (ih < flip_thresh)                     # (M,)
            ibitpos = (_mix32(ih ^ jnp.uint32(0x632BE5AB))
                       % jnp.uint32(32 * l))
            ihit_lane = (ibitpos // 32).astype(jnp.int32)
            ihit_word = jnp.uint32(1) << (ibitpos % 32)
            ilanes = jnp.arange(l, dtype=jnp.int32)[None, :]
            imask = jnp.where((ilanes == ihit_lane[:, None]) & ihit[:, None],
                              ihit_word[:, None], jnp.uint32(0))
            iw = jnp.concatenate([iw[..., :l] ^ imask, iw[..., l:]], axis=-1)
        inj_pv = jnp.where(can, mc_pv, (nr * p + PORT_LOCAL) * v + ivc)
        islot = (jnp.take(head2_flat, inj_pv, mode="clip")
                 + jnp.take(count2_flat, inj_pv, mode="clip")) % d

        # --- one combined push+inject scatter (disjoint FIFO targets) ---
        rcv_row = jnp.where(inc_ok, (rcv_base * v + inc_vc) * d + wslot,
                            phantom_row)
        cat_row = jnp.concatenate([rcv_row.reshape(-1), inj_pv * d + islot])
        cat_w = jnp.concatenate([inc_w, iw])
        fifo_new = fifo_rows.at[cat_row].set(
            cat_w, mode="promise_in_bounds").reshape(state.fifo.shape)
        # Count increments ride the same receiver-side masks: a one-hot VC
        # add instead of scattering increment rows (XLA:CPU scatters cost
        # ~5x a same-size gather).
        vcs4 = jnp.arange(v, dtype=jnp.int32)[None, None, :]
        count_inc = ((vcs4 == inc_vc[..., None])
                     & inc_ok[..., None]).astype(jnp.int32)  # (NR, 4, V)
        count_new = count2.at[:nr, :4, :].add(count_inc).reshape(-1).at[
            inj_pv].add(can.astype(jnp.int32),
                        mode="promise_in_bounds").reshape(count2.shape)
        ptr_new = ptr + can.astype(jnp.int32)

        # NI-link BT (MC -> router); the ordering unit sits right before it.
        itog = popcount_hw(state.inj_last ^ iw[..., :l]).sum(-1)
        if count_headers:
            icounted = can
        else:
            icounted = can & ((imeta & META_PAYLOAD) > 0)
        inj_bt = state.inj_bt + jnp.where(icounted, itog, 0)
        inj_last = jnp.where(can[:, None], iw[..., :l], state.inj_last)

        if timestamps:
            # Header flit leaving the NI stamps the packet's injection
            # cycle: min() against the UNSET init records the first (only)
            # header injection; everything else dumps into the last slot.
            ipkt = iw[..., l + 1].astype(jnp.int32)
            npcap2 = state.inj_time.shape[0] - 1
            inj_hdr = can & ((imeta & META_PAYLOAD) == 0)
            t_idx = jnp.where(inj_hdr, jnp.minimum(ipkt, npcap2), npcap2)
            inj_time = state.inj_time.at[t_idx].min(
                jnp.where(inj_hdr, state.cycle, _TIME_UNSET))
            if flips_on:
                fi_idx = jnp.where(ihit, jnp.minimum(ipkt, npcap2), npcap2)
                flip_pkt = flip_pkt.at[fi_idx].add(ihit.astype(jnp.int32))
        else:
            inj_time, eject_time = state.inj_time, state.eject_time

        total = jnp.sum(wire.length)
        drained_at = jnp.where((state.drained_at < 0) & (ejected >= total),
                               state.cycle + 1, state.drained_at)

        return SimState(fifo_new, head2, count_new, rr_new, link_last,
                        link_bt, link_flits, ptr_new, inj_last, inj_bt,
                        ejected, state.cycle + 1, eject_pkt, drained_at,
                        inj_time, eject_time, flip_pkt, bad_pkt)

    return step


BACKENDS = ("auto", "fused", "pallas")


def _resolve_backend(backend: str, track: bool) -> str:
    """Resolve the ``backend=`` knob to a concrete step implementation.

    ``auto`` follows the kernels/ops.py selector contract: the Pallas
    router-step kernel compiles through Mosaic on TPU and would only
    *interpret* on CPU, so auto picks ``pallas`` exactly when a TPU backs
    the default device and the proven fused step otherwise. The
    conservation ledger is a debug path the kernel does not carry, so an
    ``auto`` drain with the ledger armed resolves to the fused step (both
    are pinned bit-identical, making the substitution unobservable) - but
    an *explicit* ``backend="pallas"`` with ``check_conservation=True``
    is a contradiction and raises instead of being silently overridden.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend == "pallas" and track:
        raise ValueError(
            "backend='pallas' cannot honor check_conservation=True: the "
            "Pallas router kernel does not carry the packet-ledger lane. "
            "Use backend='auto' (resolves tracked drains to the "
            "bit-identical fused step) or drop check_conservation.")
    if backend == "auto":
        from repro.kernels.ops import on_tpu
        backend = "pallas" if on_tpu() else "fused"
    if track:
        backend = "fused"
    return backend


def _make_step_pallas(mesh_key, count_headers: bool, track: bool):
    """The per-cycle step routed through the Pallas router kernel.

    Same (state, wire, mc_nodes) signature and bit-identical results as
    :func:`_make_step` (pinned by tests/test_kernel_parity.py): the kernel
    body copies the fused step's arithmetic op for op. Only the injection
    row gather stays outside - the (M, T, LF) wire tensor cannot live in
    VMEM at DarkNet scale, and a one-row-per-stream dynamic slice is
    exactly what XLA already does well.
    """
    from repro.kernels.ops import on_tpu
    from repro.kernels.router_step import router_step_pallas

    if track:
        raise ValueError("the Pallas step does not carry the conservation "
                         "ledger; tracked drains use the fused step")
    rows, cols, num_vcs, vc_depth, lanes = mesh_key
    lf = lanes + 1
    interp = not on_tpu()

    def step(state: SimState, wire: Wire, mc_nodes: jax.Array):
        m = wire.length.shape[0]
        t_cap = wire.wire.shape[1]
        ptr = state.inj_ptr
        active = (ptr < wire.length).astype(jnp.int32)
        safe_ptr = jnp.minimum(ptr, t_cap - 1)
        iw = wire.wire[jnp.arange(m), safe_ptr]
        total = jnp.sum(wire.length).astype(jnp.int32)[None]
        leaves = (state.fifo.reshape(-1, lf), state.head, state.count,
                  state.rr, state.link_last, state.link_bt,
                  state.link_flits, state.inj_ptr, state.inj_last,
                  state.inj_bt, state.ejected[None], state.cycle[None],
                  state.drained_at[None])
        (fifo, head, count, rr, link_last, link_bt, link_flits, inj_ptr,
         inj_last, inj_bt, ejected, cycle, drained) = router_step_pallas(
            mesh_key, count_headers, lf, leaves, iw, active,
            mc_nodes.astype(jnp.int32), total, interpret=interp)
        return SimState(fifo.reshape(state.fifo.shape), head, count, rr,
                        link_last, link_bt, link_flits, inj_ptr, inj_last,
                        inj_bt, ejected[0], cycle[0], None, drained[0])

    return step


def _step_for(mesh_key, count_headers: bool, track: bool, backend: str):
    if backend == "pallas":
        return _make_step_pallas(mesh_key, count_headers, track)
    return _make_step(mesh_key, count_headers, track)


@functools.lru_cache(maxsize=None)
def _chunk_runner(mesh_key, count_headers: bool, chunk: int, batched: bool,
                  track: bool, backend: str = "fused"):
    """Compiled ``chunk``-cycle driver for one (mesh size, recorder) pair.

    Returned once per static key and cached; jax.jit then caches one
    executable per (state, wire, mc_nodes) shape signature, so re-simulating
    a new traffic value of a known shape costs zero retraces. The carried
    state is donated chunk-to-chunk; the returned ``ejected`` snapshot is a
    separate small output so the pipelined driver can dispatch chunk k+1
    and only then read chunk k's drain bookkeeping.
    """
    step = _step_for(mesh_key, count_headers, track, backend)

    def run(state: SimState, wire: Wire, mc_nodes: jax.Array):
        def body(s, _):
            return step(s, wire, mc_nodes), ()
        out, _ = jax.lax.scan(body, state, None, length=chunk)
        return out, out.ejected

    if batched:
        run = jax.vmap(run, in_axes=(0, 0, 0))
    return jax.jit(run, donate_argnums=0)


@functools.lru_cache(maxsize=None)
def _sharded_chunk_runner(mesh_key, count_headers: bool, chunk: int,
                          dev_mesh, track: bool, backend: str = "fused"):
    """``_chunk_runner(batched=True)`` with the variants axis split across
    the devices of ``dev_mesh`` via shard_map.

    Variant lanes are fully independent (the vmapped drain exchanges
    nothing between them), so each device runs the plain local vmap over
    its slice - results are bit-identical to the single-device runner by
    construction, and the only cross-device traffic is the host readback
    of the drain bookkeeping between chunks.
    """
    from jax.experimental.shard_map import shard_map

    step = _step_for(mesh_key, count_headers, track, backend)

    def run(state: SimState, wire: Wire, mc_nodes: jax.Array):
        def body(s, _):
            return step(s, wire, mc_nodes), ()
        out, _ = jax.lax.scan(body, state, None, length=chunk)
        return out, out.ejected

    run = jax.vmap(run, in_axes=(0, 0, 0))
    spec_b = jax.sharding.PartitionSpec(dev_mesh.axis_names[0])
    run = shard_map(run, mesh=dev_mesh,
                    in_specs=(spec_b, spec_b, spec_b),
                    out_specs=(spec_b, spec_b), check_rep=False)
    return jax.jit(run, donate_argnums=0)


def _conservation_error(length: np.ndarray, meta: np.ndarray,
                        pkt: np.ndarray, eject_pkt: np.ndarray,
                        npkt: int) -> Optional[str]:
    """Check every injected pkt id ejected exactly once; None when clean."""
    valid = np.arange(meta.shape[1])[None, :] < length[:, None]
    tails = valid & ((meta & META_TAIL) > 0)
    injected = np.bincount(pkt[tails].reshape(-1), minlength=npkt)[:npkt]
    ejected = eject_pkt[:npkt]
    bad_inj = np.flatnonzero(injected > 1)
    if bad_inj.size:
        return (f"packet ids injected more than once: {bad_inj[:8].tolist()}"
                f" (counts {injected[bad_inj[:8]].tolist()})")
    present = injected > 0
    bad = np.flatnonzero(ejected[present] != 1)
    if bad.size:
        ids = np.flatnonzero(present)[bad]
        return (f"packet ids not ejected exactly once: {ids[:8].tolist()}"
                f" (eject counts {ejected[ids[:8]].tolist()})")
    stray = np.flatnonzero(~present & (ejected != 0))
    if stray.size:
        return f"ejections for never-injected packet ids: {stray[:8].tolist()}"
    return None


def _validate_fields(cfg: NocConfig, traffic: Traffic) -> None:
    """Range-check the fields that feed packed sidebands and
    promise-in-bounds scatters.

    The packetizer satisfies these by construction; hand-built Traffic
    (or traffic packetized for a different config) must fail loudly here
    rather than corrupt the fused scatter. One device-side reduction per
    drain call - no host pull of the full tensors.
    """
    if not traffic.dest.size:
        return
    dmax = int(jnp.max(traffic.dest))
    if dmax >= cfg.num_routers:
        raise ValueError(f"traffic dest {dmax} out of range for a "
                         f"{cfg.num_routers}-router config")
    vmax = int(jnp.max(traffic.vc))
    if vmax >= cfg.num_vcs:
        raise ValueError(f"traffic vc {vmax} out of range for a "
                         f"{cfg.num_vcs}-VC config")


def _npkt(traffic: Traffic) -> int:
    n = int(traffic.num_packets)
    if n >= 0:
        return n
    # Hand-built Traffic without metadata: legacy full host pull.
    pkt = np.asarray(traffic.pkt)
    return int(pkt.max()) + 1 if pkt.size else 0


def _mc_array(cfg: NocConfig, traffic: Traffic, m: int,
              batched: bool) -> jax.Array:
    """Validate the traffic's MC-stream count against ``cfg`` and return the
    per-stream injection node ids, padded to ``m``.

    Traffic may carry more streams than the config has MCs (the sweep
    engine pads MC counts within a mesh-size group so every placement
    shares one executable); padding streams must be empty, and their node
    ids are irrelevant because an empty stream never injects.
    """
    if m < cfg.num_mcs:
        raise ValueError(
            f"traffic has {m} MC streams, config has {cfg.num_mcs}")
    length = np.asarray(traffic.length)
    pad = length[..., cfg.num_mcs:] if batched else length[cfg.num_mcs:]
    if m > cfg.num_mcs and np.any(pad != 0):
        raise ValueError(
            f"traffic has {m} MC streams for a {cfg.num_mcs}-MC config and "
            "the extra streams are not empty padding")
    nodes = tuple(cfg.mc_nodes) + (0,) * (m - cfg.num_mcs)
    return jnp.asarray(nodes, jnp.int32)


def _result(cfg: NocConfig, state_leaves, total: int) -> SimResult:
    (link_bt, link_flits, inj_bt, ejected, cycle, drained_at) = state_leaves
    inter = int(link_bt[:, :PORT_LOCAL].sum())
    total_bt = int(link_bt.sum() + inj_bt.sum())
    drain = int(drained_at)
    return SimResult(
        cycles=int(cycle), ejected=int(ejected), injected=total,
        link_bt=link_bt, link_flits=link_flits, inj_bt=inj_bt,
        total_bt=total_bt, inter_router_bt=inter,
        drain_cycle=drain if drain >= 0 else int(cycle))


def simulate(cfg: NocConfig, traffic: Traffic, *, count_headers: bool = True,
             max_cycles: int = 2_000_000, chunk: int = 4096,
             check_conservation: bool = False, mc_nodes=None,
             backend: str = "auto") -> SimResult:
    """Run the NoC until all traffic drains; returns per-link BT counts.

    check_conservation: debug path - track tail ejections per packet id and
        raise if any injected packet id does not eject exactly once. Only
        then does the state carry the ledger (and the FIFOs a pkt lane).
    mc_nodes: optional per-stream injection-node ids (one per traffic
        stream). ``None`` injects at ``cfg.mc_nodes`` - the request phase.
        The result phase passes ``cfg.pe_nodes``: streams then inject at
        the PEs and eject at the MCs their ``dest`` fields name.
    backend: step implementation - ``"fused"`` (the pure-jnp fused step),
        ``"pallas"`` (the ``kernels/router_step.py`` kernel: Mosaic on
        TPU, interpret-mode on CPU), or ``"auto"`` (pallas on TPU, fused
        otherwise; see :func:`_resolve_backend`). All backends are pinned
        bit-identical.
    """
    m = int(traffic.length.shape[0])
    if mc_nodes is None:
        mc_nodes = _mc_array(cfg, traffic, m, batched=False)
    else:
        mc_nodes = np.asarray(mc_nodes, np.int32)
        if mc_nodes.shape != (m,):
            raise ValueError(f"mc_nodes must have shape ({m},), "
                             f"got {mc_nodes.shape}")
        if mc_nodes.size and (mc_nodes.min() < 0
                              or mc_nodes.max() >= cfg.num_routers):
            raise ValueError("mc_nodes out of range for a "
                             f"{cfg.num_routers}-router config")
        mc_nodes = jnp.asarray(mc_nodes)
    _validate_fields(cfg, traffic)
    npkt = _npkt(traffic) if check_conservation else 0
    track = npkt > 0
    state = make_state(cfg, m, npkt=npkt)
    wire = fuse_traffic(traffic, track)
    run_chunk = _chunk_runner(_mesh_key(cfg), count_headers, chunk, False,
                              track, _resolve_backend(backend, track))

    total = int(np.sum(np.asarray(traffic.length)))
    while total:    # empty traffic: nothing to drain (and T may be 0)
        state, ej = run_chunk(state, wire, mc_nodes)
        drained = (int(ej) == total)
        if drained or int(state.cycle) >= max_cycles:
            break
    if int(state.ejected) != total:
        # With the packet ledger armed, name the undelivered ids: a tail
        # ejection count of zero maps to the builder's -1 sentinel.
        undeliv = (np.where(np.asarray(state.eject_pkt)[:npkt] > 0, 0, -1)
                   if track else None)
        raise _drain_timeout(
            "NoC", int(state.cycle), int(state.ejected), total,
            np.asarray(state.count), np.asarray(state.inj_ptr),
            np.asarray(traffic.length), eject_time=undeliv, npkt=npkt)
    if check_conservation and track:
        err = _conservation_error(
            np.asarray(traffic.length), np.asarray(traffic.meta),
            np.asarray(traffic.pkt), np.asarray(state.eject_pkt), npkt)
        if err:
            raise RuntimeError(f"packet conservation violated: {err}")
    return _result(cfg, (np.asarray(state.link_bt), np.asarray(state.link_flits),
                         np.asarray(state.inj_bt), state.ejected, state.cycle,
                         state.drained_at), total)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def simulate_batch(cfg: NocConfig, traffic: Traffic, *,
                   count_headers: bool = True, max_cycles: int = 2_000_000,
                   chunk: int = 4096, check_conservation: bool = False,
                   devices=None, mc_nodes=None, retire: bool = True,
                   backend: str = "auto",
                   compact_ratio: float = 0.5) -> List[SimResult]:
    """Drain B traffic variants (leading axis) in one vmapped program.

    All variants must share shapes - which O0/O1/O2 x precision variants of
    one sweep shape class do by construction (ordering permutes words within
    packets and never changes the flit geometry). The drain is pipelined
    (chunk k+1 dispatches before chunk k's drain bookkeeping is read back)
    and *drain-aware*: once a variant's exact ``drain_cycle`` is recorded,
    its lane can retire, and when at least half the lanes have retired the
    survivors are compacted into a narrower batch (results spliced back by
    lane index, bit-identical to the uncompacted drain - retired lanes were
    frozen anyway).

    devices: shard the variants axis across these devices (shard_map over a
        1-D device mesh; the batch is padded with empty traffic rows up to
        a device multiple). Per-variant results are bit-identical to the
        single-device drain - variant lanes never communicate. ``None`` or
        a single device falls back to the plain vmapped runner. A 1-D
        ``jax.sharding.Mesh`` is accepted directly, so a multi-host mesh
        built elsewhere (``dist.sharding`` specs) is a config change: the
        batch placement still goes through ``batch_shardings``.
    mc_nodes: optional (B, M) per-variant injection-node ids - this is how
        the sweep engine batches *different MC placements* of one mesh size
        into a single drain, and how the result phase injects its per-PE
        streams (pass each lane's ``cfg.pe_nodes``, zero-padded; the
        ``dest`` fields then name the MCs). ``None`` broadcasts
        ``cfg.mc_nodes`` and requires streams beyond ``cfg.num_mcs`` to be
        empty padding.
    retire: disable lane retirement/compaction (debug / parity testing);
        every lane then steps until the slowest variant drains.
    backend: step implementation (``"auto"``/``"fused"``/``"pallas"``,
        see :func:`simulate`); applies to the sharded runner too.
    compact_ratio: compaction trigger - survivors are compacted into a
        narrower batch once ``live <= ratio * rows``. The default 0.5
        keeps the pow2-halving schedule; ``noc.tune`` measures
        alternatives per shape class. 0.0 disables compaction (lanes
        still retire their bookkeeping). Pure scheduling: results are
        bit-identical across ratios.
    """
    if not 0.0 <= compact_ratio <= 1.0:
        raise ValueError(f"compact_ratio must be in [0, 1], "
                         f"got {compact_ratio!r}")
    if traffic.length.ndim != 2:
        raise ValueError("simulate_batch wants a leading variants axis; "
                         "use simulate() for a single Traffic")
    b, m = traffic.length.shape
    if mc_nodes is None:
        default_nodes = np.asarray(_mc_array(cfg, traffic, m, batched=True))
        mc = np.broadcast_to(default_nodes, (b, m)).copy()
    else:
        mc = np.ascontiguousarray(np.asarray(mc_nodes, np.int32))
        if mc.shape != (b, m):
            raise ValueError(f"mc_nodes must be ({b}, {m}), got {mc.shape}")
        if mc.size and (mc.min() < 0 or mc.max() >= cfg.num_routers):
            raise ValueError("mc_nodes out of range for a "
                             f"{cfg.num_routers}-router config")
    _validate_fields(cfg, traffic)
    npkt = _npkt(traffic) if check_conservation else 0
    track = npkt > 0
    host_cons = ((np.asarray(traffic.length), np.asarray(traffic.meta),
                  np.asarray(traffic.pkt)) if track else None)
    totals = np.asarray(traffic.length).sum(axis=1).astype(np.int64)
    wire = fuse_traffic(traffic, track)
    bk = _resolve_backend(backend, track)

    if isinstance(devices, jax.sharding.Mesh):
        if len(devices.axis_names) != 1:
            raise ValueError("simulate_batch wants a 1-D device mesh, got "
                             f"axes {devices.axis_names}")
        dev_mesh, ndev = devices, int(devices.devices.size)
    elif devices is not None:
        devs = list(devices)
        ndev = len(devs)
        dev_mesh = (jax.sharding.Mesh(np.asarray(devs), ("variants",))
                    if ndev > 1 else None)
    else:
        dev_mesh, ndev = None, 0
    sharded = ndev > 1
    if sharded:
        # Lazy import: repro.dist pulls in repro.models, which imports this
        # package back for its layer_traffic helpers.
        from repro.dist.sharding import batch_shardings, compact_batch
        bp = -(-b // ndev) * ndev
        if bp != b:
            zpad = lambda x: jnp.concatenate(   # noqa: E731
                [x, jnp.zeros((bp - b,) + x.shape[1:], x.dtype)])
            wire = Wire(zpad(wire.wire), zpad(wire.length))
            mc = np.concatenate([mc, np.zeros((bp - b, m), np.int32)])
            totals = np.concatenate([totals, np.zeros(bp - b, np.int64)])
        axis = dev_mesh.axis_names[0]
        place = lambda tree: jax.device_put(  # noqa: E731
            tree, batch_shardings(dev_mesh, tree, axis))
        compact = lambda tree, idx: compact_batch(  # noqa: E731
            dev_mesh, tree, idx, axis)
        run_chunk = _sharded_chunk_runner(_mesh_key(cfg), count_headers,
                                          chunk, dev_mesh, track, bk)
        min_rows = ndev
    else:
        bp = b
        place = lambda tree: tree  # noqa: E731
        compact = lambda tree, idx: jax.tree.map(  # noqa: E731
            lambda x: x[idx], tree)
        run_chunk = _chunk_runner(_mesh_key(cfg), count_headers, chunk, True,
                                  track, bk)
        min_rows = 1

    # Broadcast the zeroed base state instead of stacking B host copies;
    # the first chunk call takes ownership of the buffer via donation.
    base = make_state(cfg, m, npkt=npkt)
    state = place(jax.tree.map(
        lambda x: jnp.broadcast_to(x, (bp,) + x.shape), base))
    wire = place(wire)
    mc_dev = place(jnp.asarray(mc, jnp.int32))

    harvested = {}      # lane id -> host bookkeeping leaves

    def harvest(st, pairs):
        leaves = [np.asarray(st.link_bt), np.asarray(st.link_flits),
                  np.asarray(st.inj_bt), np.asarray(st.ejected),
                  np.asarray(st.cycle), np.asarray(st.drained_at)]
        ep = np.asarray(st.eject_pkt) if track else None
        for lane, row in pairs:
            harvested[lane] = tuple(a[row] for a in leaves) + (
                (ep[row],) if track else (None,))

    if totals.sum() == 0:   # empty traffic: nothing to drain (and T may be 0)
        harvest(state, [(lane, lane) for lane in range(bp)])
    else:
        live = list(range(bp))          # lanes still draining
        prim = {lane: lane for lane in live}    # lane -> device row
        state, ej = run_chunk(state, wire, mc_dev)
        nch = 1
        while True:
            # Pipelined driver: dispatch chunk k+1, then read chunk k's
            # bookkeeping - the readback no longer leaves the device idle.
            state2, ej2 = run_chunk(state, wire, mc_dev)
            nch += 1
            e = np.asarray(ej)          # ejected after chunk nch-1
            done = [lane for lane in live if e[prim[lane]] >= totals[lane]]
            if len(done) == len(live):
                harvest(state2, [(lane, prim[lane]) for lane in live])
                break
            if (nch - 1) * chunk >= max_cycles:
                lag = sorted(set(live) - set(done))
                # Diagnose the first lagging lane in full (occupancy +
                # pending) from the freshest state; the message still
                # names every laggard. (``state`` was donated to the
                # in-flight chunk - read ``state2``/``ej2``.)
                row = prim[lag[0]]
                e2 = np.asarray(ej2)
                lens = np.asarray(traffic.length)
                raise _drain_timeout(
                    f"NoC variants {lag} "
                    f"({[int(e2[prim[x]]) for x in lag]}/"
                    f"{[int(totals[x]) for x in lag]} flits; "
                    f"diagnostic for variant {lag[0]})",
                    nch * chunk, int(e2[row]), int(totals[lag[0]]),
                    np.asarray(state2.count)[row],
                    np.asarray(state2.inj_ptr)[row], lens[lag[0]])
            if retire and done:
                # Retire drained lanes: their recorders froze at the exact
                # drain_cycle, so chunk k+1's rows hold their final state.
                harvest(state2, [(lane, prim[lane]) for lane in done])
                live = [lane for lane in live if lane not in set(done)]
                cur = int(ej2.shape[0])
                target = max(_next_pow2(len(live)), min_rows)
                if target % min_rows:
                    target = -(-target // min_rows) * min_rows
                if len(live) <= int(cur * compact_ratio) and target < cur:
                    keep = [prim[lane] for lane in live]
                    rows = keep + [keep[0]] * (target - len(keep))
                    idx = jnp.asarray(rows, jnp.int32)
                    state2 = compact(state2, idx)
                    wire = compact(wire, idx)
                    mc_dev = compact(mc_dev, idx)
                    ej2 = compact(ej2, idx)
                    prim = {lane: i for i, lane in enumerate(live)}
            state, ej = state2, ej2

    out = []
    for i in range(b):
        (link_bt, link_flits, inj_bt, ejected, cycle, drained_at,
         eject_pkt) = harvested[i]
        if check_conservation and track:
            length, meta, pkt = host_cons
            err = _conservation_error(length[i], meta[i], pkt[i],
                                      eject_pkt, npkt)
            if err:
                raise RuntimeError(
                    f"packet conservation violated (variant {i}): {err}")
        out.append(_result(cfg, (link_bt, link_flits, inj_bt, ejected,
                                 cycle, drained_at), int(totals[i])))
    return out
