"""Link-power and ordering-unit overhead model (paper Sec. V-C, Tab. II).

All constants are the paper's synthesis results (TSMC 90nm, 125 MHz, 1.0V)
and its two link-energy models: the authors' Innovus extraction (0.173
pJ/transition) and Banerjee et al.'s (0.532 pJ/transition). The functions
reproduce the paper's own worked example:

    0.173 pJ/bit * (128 bits / 2) * 112 links * 125 MHz = 155.008 mW
"""
from __future__ import annotations

import dataclasses

__all__ = ["HWConstants", "HW", "link_power_mw", "paper_example",
           "ordering_overhead_mw", "net_power_saving_mw"]


@dataclasses.dataclass(frozen=True)
class HWConstants:
    freq_hz: float = 125e6
    e_bit_ours_pj: float = 0.173        # per-transition energy, Innovus
    e_bit_banerjee_pj: float = 0.532    # Banerjee et al. [6]
    ordering_unit_mw: float = 2.213     # one unit (Tab. II)
    ordering_unit_kge: float = 12.91
    router_mw: float = 16.92
    router_kge: float = 125.54


HW = HWConstants()


def link_power_mw(toggles_per_cycle: float, *, num_links: int = 1,
                  e_bit_pj: float = HW.e_bit_ours_pj,
                  freq_hz: float = HW.freq_hz) -> float:
    """Average link power given mean toggling bits per link per cycle."""
    return e_bit_pj * 1e-12 * toggles_per_cycle * num_links * freq_hz * 1e3


def paper_example(e_bit_pj: float = HW.e_bit_ours_pj) -> float:
    """The paper's illustrative number: half of a 128-bit link toggling,
    112 inter-router links, 125 MHz -> 155.008 mW (ours) / 476.672 mW [6]."""
    return link_power_mw(128 / 2, num_links=112, e_bit_pj=e_bit_pj)


def ordering_overhead_mw(num_mcs: int, separated: bool = False) -> float:
    """Ordering units' power: one per MC; separated-ordering runs the unit
    twice per payload (paper Sec. V-C: 'double time consumption'), modeled
    as doubled dynamic power."""
    scale = 2.0 if separated else 1.0
    return HW.ordering_unit_mw * num_mcs * scale


def net_power_saving_mw(baseline_toggles_per_cycle: float,
                        bt_reduction_rate: float, num_links: int,
                        num_mcs: int, *, separated: bool = False,
                        e_bit_pj: float = HW.e_bit_ours_pj) -> dict:
    """End-to-end accounting: link power saved minus ordering-unit cost."""
    base = link_power_mw(baseline_toggles_per_cycle, num_links=num_links,
                         e_bit_pj=e_bit_pj)
    reduced = base * (1.0 - bt_reduction_rate)
    overhead = ordering_overhead_mw(num_mcs, separated)
    return {
        "baseline_link_mw": base,
        "ordered_link_mw": reduced,
        "ordering_units_mw": overhead,
        "net_saving_mw": base - reduced - overhead,
    }
