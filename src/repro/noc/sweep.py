"""Declarative NoC sweep engine: the paper's Figs. 12-13 axis, batched.

The paper's headline results come from sweeping full DNNs across NoC sizes,
MC counts, orderings, and precisions. A :class:`SweepGrid` declares that
cross product once; :func:`run_sweep` then exploits two structural facts to
make the sweep cheap:

* all ordering/precision/tiebreak variants of one (mesh, model) pair share
  identical traffic *shapes* (ordering permutes words within packets,
  quantization narrows them; neither changes flit geometry), so the
  packetization skeleton is built once per shape class
  (``build_traffic_batch``) and every variant drains in a single vmapped,
  compile-cached simulation (``simulate_batch``);
* meshes of equal size share the simulator executable across models, since
  the compiled step is keyed only on (config, traffic shape).

Each grid cell yields one row: raw BT totals, exact drain cycles, the
reduction against the cell's O0 baseline, and an *honest* reduction that
charges the O2 recovery index (``WireTransform.overhead_bits_per_value``,
paper Sec. IV-C1) against the win. ``out_path`` writes the rows plus grid
metadata as a JSON artifact.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import msr
from repro.core.wire import COMPRESSIONS, WireTransform, by_name
from repro.quant import quantize_fixed8
from .topology import (AFFINITIES, NocConfig, PLACEMENTS, affinity_mc_table,
                       mc_placement, mesh_by_name, packet_mean_hops,
                       xy_link_loads)
from .traffic import (DEFAULT_RESULT_WINDOW, LayerTraffic, assemble_traffic,
                      build_result_traffic, build_traffic_batch,
                      build_traffic_streamed_multi, compression_overhead,
                      ordered_payloads, pad_traffic_length, payload_shapes,
                      result_values, stream_lengths)
from .sim import SimResult, Traffic, simulate_batch

__all__ = ["SweepGrid", "SweepReport", "run_sweep", "run_serving",
           "recovery_overhead_bits", "drain_estimate"]

Mesh = Union[str, NocConfig]
LayersFn = Callable[[str], Sequence[LayerTraffic]]

_QUANTIZERS = {
    "float32": None,
    "fixed8": lambda t: quantize_fixed8(t).values,
}


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """One declarative sweep: mesh sizes x MC placements x packet->MC
    affinities x transforms x tiebreaks x precisions x models, with an
    optional PE->MC result phase.

    meshes: PAPER_NOCS names, ``RxC_mcN`` specs, or NocConfig instances.
    placements: MC placement strategies (``topology.PLACEMENTS``). The
        default ``"edge"`` keeps every mesh's resolved mc_nodes untouched
        (for named meshes that IS the evenly-spread boundary placement);
        other strategies re-place the same MC count via ``mc_placement``.
        Placements of one mesh size stay in one shape class and share the
        compiled simulator.
    affinity: packet->MC assignment strategies (``topology.AFFINITIES``) -
        the fourth ordering knob. ``"roundrobin"`` (the default) deals
        packet g to MC ``g % M`` exactly as the seed packetizer did, and
        its rows are bit-identical to a grid without the axis;
        ``"nearest"`` serves each PE from its hop-minimizing MC
        (``topology.affinity_mc_table``). Affinity lanes ride the same
        batched drain as placements (same flit volume, different per-MC
        stream split).
    transforms: WireTransform names (``repro.core.wire.by_name``); the
        ``baseline`` transform anchors the per-cell reduction percentages.
    compression: flit payload compression schemes (``core.wire.COMPRESSIONS``)
        - the fifth ordering knob, crossed with every other axis. ``"none"``
        (the default) is the seed packetizer and its rows are bit-identical
        to a grid without the axis; ``"msr"`` runs every packet payload
        through the MSR 8b->5b codec (``repro.core.msr``) after ordering,
        shrinking flit counts and shifting drain cycles, and charges the
        per-window escape metadata at half a transition per bit in
        ``compression_overhead_bits`` / ``adjusted_bt`` - exactly how the
        O2 recovery index is priced. MSR reads int8 payloads, so ``"msr"``
        requires ``precisions`` to be exactly ``("fixed8",)`` subsets.
    max_packets_per_layer: deterministic-stride neuron subsampling budget;
        ``None`` packetizes the *full* layers through the streamed
        chunked path (``build_traffic_streamed``) instead of the one-shot
        payload cache.
    stream_chunk_packets: packet-chunk size of the streamed path.
    result_phase: also model the PE->MC result traffic: each cell's result
        packets (``traffic.build_result_traffic``) drain in a second,
        independent batched simulation and the row gains
        ``result_bt``/``result_cycles``/``result_flits`` plus the honest
        single-stream accounting columns ``result_overhead_bits``/
        ``result_adjusted_bt``/``result_adjusted_reduction_pct`` (all
        ``None`` when the phase is off - the request-phase columns are
        untouched either way).
    result_window: result values per result packet
        (``traffic.DEFAULT_RESULT_WINDOW`` when ``None``).
    """

    meshes: Sequence[Mesh] = ("4x4_mc2",)
    placements: Sequence[str] = ("edge",)
    affinity: Sequence[str] = ("roundrobin",)
    transforms: Sequence[str] = ("O0", "O1", "O2")
    tiebreaks: Sequence[str] = ("pattern",)
    precisions: Sequence[str] = ("float32", "fixed8")
    models: Sequence[str] = ("lenet",)
    compression: Sequence[str] = ("none",)
    max_packets_per_layer: Optional[int] = 40
    stream_chunk_packets: int = 4096
    count_headers: bool = True
    chunk: int = 2048
    max_cycles: int = 2_000_000
    baseline: str = "O0"
    result_phase: bool = False
    result_window: Optional[int] = None
    # Simulator step implementation ("auto"/"fused"/"pallas"): "auto"
    # follows the kernels/ops.py selector - the Pallas router kernel on
    # TPU, the fused jnp step elsewhere. Forwarded to every
    # ``simulate_batch`` call the sweep makes; all backends are pinned
    # bit-identical, so this is purely a speed knob.
    backend: str = "auto"
    # Autotuned drain scheduling: path to a ``noc.tune`` winners table
    # (JSON, see ``repro.noc.tune``). When set, each mesh looks up its
    # shape class and the measured winner overrides ``chunk`` and the
    # compaction ratio for that drain; classes absent from the table fall
    # back to ``chunk``. Scheduling only - results stay bit-identical.
    tune_path: Optional[str] = None
    # Closed-loop serving axis (:func:`run_serving`): offered-load points
    # in inferences per 1000 cycles. Empty disables the suite; run_sweep
    # ignores these knobs entirely. Timing is transform-independent (drain
    # dynamics never read payload values - see repro.noc.online), so each
    # load point costs ONE gated drain per (mesh, placement, affinity,
    # model) combo and the whole transform axis joins by BT.
    offered_loads: Sequence[float] = ()
    serving_inferences: int = 8
    compute_latency: int = 0            # per-PE compute cycles
    arrival: str = "uniform"            # online.ARRIVAL_KINDS process
    arrival_seed: int = 0
    # Fault-injection serving axis (:mod:`repro.noc.faults`): soft-error
    # rates swept per offered-load point. Rate 0.0 with no dead links runs
    # the pinned fault-free step (bit-identical to a grid without the
    # axis); every other point drains through the fault-injecting step
    # with ``fault_protect`` flit protection and bounded retransmission.
    # Detection is payload-independent (linear codes), so serving timing
    # stays transform-independent even under faults - the load x rate
    # axis is still priced once per combo. ``deadline`` (cycles) turns on
    # per-inference SLO attainment; ``admit_queue_depth`` turns on
    # overload shedding (see ``online.simulate_online``).
    fault_rates: Sequence[float] = ()
    fault_protect: str = "crc8"
    fault_seed: int = 0
    fault_dead_links: Sequence = ()
    fault_max_retries: int = 3
    fault_ack_latency: int = 32
    deadline: Optional[int] = None
    admit_queue_depth: Optional[int] = None

    def __post_init__(self):
        from .sim import BACKENDS
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        unknown = set(self.precisions) - set(_QUANTIZERS)
        if unknown:
            raise ValueError(f"unknown precisions {sorted(unknown)}; "
                             f"supported: {sorted(_QUANTIZERS)}")
        unknown = set(self.placements) - set(PLACEMENTS)
        if unknown:
            raise ValueError(f"unknown placements {sorted(unknown)}; "
                             f"supported: {sorted(PLACEMENTS)}")
        if not self.placements:
            raise ValueError("need at least one MC placement")
        unknown = set(self.affinity) - set(AFFINITIES)
        if unknown:
            raise ValueError(f"unknown affinity {sorted(unknown)}; "
                             f"supported: {sorted(AFFINITIES)}")
        if not self.affinity:
            raise ValueError("need at least one packet->MC affinity")
        if self.baseline not in self.transforms:
            raise ValueError(
                f"baseline {self.baseline!r} not in transforms {self.transforms}")
        unknown = set(self.compression) - set(COMPRESSIONS)
        if unknown:
            raise ValueError(f"unknown compression {sorted(unknown)}; "
                             f"supported: {COMPRESSIONS}")
        if not self.compression:
            raise ValueError("need at least one compression scheme")
        if "msr" in self.compression:
            nonint = set(self.precisions) - {"fixed8"}
            if nonint:
                raise ValueError(
                    "compression 'msr' reads int8 payloads; drop precisions "
                    f"{sorted(nonint)} or sweep compression=('none',)")
        from .online import ARRIVAL_KINDS
        if self.arrival not in ARRIVAL_KINDS:
            raise ValueError(f"arrival must be one of {ARRIVAL_KINDS}, "
                             f"got {self.arrival!r}")
        if any(not load > 0 for load in self.offered_loads):
            raise ValueError("offered_loads must be > 0 "
                             f"(got {tuple(self.offered_loads)})")
        if self.serving_inferences < 1:
            raise ValueError("serving_inferences must be >= 1")
        if self.compute_latency < 0:
            raise ValueError("compute_latency must be >= 0")
        from repro.core.wire import PROTECTION_BITS
        if self.fault_protect not in PROTECTION_BITS:
            raise ValueError(f"fault_protect must be one of "
                             f"{sorted(PROTECTION_BITS)}, "
                             f"got {self.fault_protect!r}")
        if any(not 0.0 <= r <= 1.0 for r in self.fault_rates):
            raise ValueError("fault_rates must lie in [0, 1] "
                             f"(got {tuple(self.fault_rates)})")
        if self.fault_max_retries < 0:
            raise ValueError("fault_max_retries must be >= 0")
        if self.fault_ack_latency < 1:
            raise ValueError("fault_ack_latency must be >= 1")
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError("deadline must be > 0 cycles when set")
        if self.admit_queue_depth is not None and self.admit_queue_depth < 1:
            raise ValueError("admit_queue_depth must be >= 1 when set")

    def variant_axes(self):
        """The per-shape-class variant list, in batch order."""
        return [(prec, tb, tr) for prec in self.precisions
                for tb in self.tiebreaks for tr in self.transforms]


@dataclasses.dataclass
class SweepReport:
    rows: List[dict]
    stats: dict

    def row(self, **match) -> dict:
        hits = [r for r in self.rows
                if all(r[k] == v for k, v in match.items())]
        if len(hits) != 1:
            raise KeyError(f"{len(hits)} rows match {match}")
        return hits[0]


def recovery_overhead_bits(layers: Sequence[LayerTraffic],
                           transform: WireTransform,
                           max_packets_per_layer: Optional[int] = None,
                           paired: bool = True) -> int:
    """Total recovery-index bits a transform must transmit for ``layers``.

    Separated ordering (O2/O3) needs a minimal-bit-width index per (input,
    weight) pair to re-affiliate the streams (paper Sec. IV-C1); the
    ordering window is the packet payload, so the index addresses one of
    ``k`` in-packet positions. O0 and (on the paired request phase) O1
    report zero. ``paired=False`` charges the *single-stream* contract
    instead - element order itself must be restorable, so every
    non-identity reorder (O1 included) owes the index.
    """
    total = 0
    for layer in layers:
        n, k = int(layer.inputs.shape[0]), int(layer.inputs.shape[1])
        if max_packets_per_layer is not None and n > max_packets_per_layer:
            n = max_packets_per_layer
        window = transform.window if transform.window is not None else k
        total += n * k * transform.overhead_bits_per_value(min(window, k),
                                                           paired=paired)
    return total


def cached_ordered_payloads(cache: Dict[tuple, list], model: str,
                            layers: Sequence[LayerTraffic], lanes: int,
                            variants, axes,
                            max_packets_per_layer: Optional[int],
                            timings: Optional[Dict[str, float]] = None,
                            compression: str = "none") -> list:
    """Ordered payloads for ``variants``, cached per (model, lanes,
    transform, precision, compression).

    The transform value is the frozen ``WireTransform`` dataclass, so the
    key carries the ordering name, window, tiebreak, and beam/starts
    settings - distinct tiebreaks or precisions can never collide on an
    entry, while every sweep cell that shares a variant (all meshes, MC
    placements, and packet->MC affinities of one model) reuses one ordering
    pass. Returns the per-layer ``(B, n, F, L)`` stacks in variant order,
    bit-identical to an uncached :func:`repro.noc.traffic.ordered_payloads`
    call over the full variant list.

    ``timings`` (transform name -> seconds, accumulated in place) charges
    each cache *miss* to its ordering - the per-transform packetization
    breakdown the bench trajectory records, so an O3 chain regression is
    attributable against the cheap O0-O2 permutes.
    """
    stacks = []
    for (tr, q), (prec, _, _) in zip(variants, axes):
        key = (model, lanes, tr, prec, compression)
        if key not in cache:
            t0 = time.perf_counter()
            cache[key] = ordered_payloads(
                layers, lanes, [(tr, q)],
                max_packets_per_layer=max_packets_per_layer,
                compression=compression)
            if timings is not None:
                timings[tr.name] = (timings.get(tr.name, 0.0)
                                    + time.perf_counter() - t0)
        stacks.append(cache[key])
    return [np.concatenate([s[li] for s in stacks])
            for li in range(len(stacks[0]))]


def _resolve_mesh(mesh: Mesh) -> tuple:
    if isinstance(mesh, NocConfig):
        return (f"{mesh.rows}x{mesh.cols}_mc{mesh.num_mcs}", mesh)
    return (mesh, mesh_by_name(mesh))


def _place(cfg: NocConfig, placement: str) -> NocConfig:
    """Apply a placement strategy to a resolved mesh.

    ``edge`` keeps the resolved mc_nodes untouched - named meshes already
    use the evenly-spread boundary placement, and an explicit NocConfig's
    hand-picked nodes stay authoritative. Other strategies re-place the
    same MC count.
    """
    if placement == "edge":
        return cfg
    return dataclasses.replace(
        cfg, mc_nodes=mc_placement(cfg.rows, cfg.cols, cfg.num_mcs,
                                   placement))


def drain_estimate(cfg: NocConfig, lengths: np.ndarray) -> float:
    """Cheap lower-bound drain estimate for one (config, stream set) cell.

    The drain cannot beat the injection bound (each MC injects at most one
    flit per cycle, so the longest stream is a floor) nor the hottest-link
    bound (one flit per link per cycle, with per-link loads walked along
    every MC->PE X-Y path in :func:`repro.noc.topology.xy_link_loads`).
    The link bound is what separates boundary MC placements - whose few
    escape links carry everything - from interleaved ones with identical
    injection bounds; on the recorded 16x16 DarkNet run it ranks edge
    (~181k-cycle drain) far above interleaved (~82k). Used only to *order*
    lanes so device-sharded batches stay balanced and slow lanes retire
    last; it carries no correctness weight.
    """
    lengths = np.asarray(lengths, float)[:cfg.num_mcs]
    inj = float(lengths.max()) if lengths.size else 0.0
    link = float(xy_link_loads(cfg, lengths).max()) if lengths.size else 0.0
    return max(inj, link)


def _deal_order(ests: np.ndarray, ndev: int) -> np.ndarray:
    """Lane permutation dealing estimate-sorted lanes round-robin across
    ``ndev`` contiguous device shards; identity when there is nothing to
    balance (one device or uniform estimates)."""
    if ndev <= 1 or np.unique(ests).size <= 1:
        return np.arange(ests.size)
    order = np.argsort(-ests, kind="stable")
    return np.concatenate([order[i::ndev] for i in range(ndev)])


def _take_lanes(traffic: Traffic, idx: np.ndarray) -> Traffic:
    if np.array_equal(idx, np.arange(idx.size)):
        return traffic
    j = jnp.asarray(idx)
    return traffic._replace(
        words=traffic.words[j], dest=traffic.dest[j], meta=traffic.meta[j],
        vc=traffic.vc[j], pkt=traffic.pkt[j], length=traffic.length[j])


def _concat_lanes(parts: Sequence[Traffic]) -> Traffic:
    if len(parts) == 1:
        return parts[0]
    cat = lambda f: jnp.concatenate([getattr(p, f) for p in parts])  # noqa: E731
    # The conservation ledger is sized from num_packets and must cover every
    # lane: result-phase parts legitimately differ in packet count (the
    # (PE, MC) grouping depends on placement/affinity), so take the max -
    # unknown (-1) in any part poisons the metadata.
    counts = [int(p.num_packets) for p in parts]
    return Traffic(words=cat("words"), dest=cat("dest"), meta=cat("meta"),
                   vc=cat("vc"), pkt=cat("pkt"), length=cat("length"),
                   num_packets=-1 if min(counts) < 0 else max(counts))


def _resolve_devices(devices):
    """``"auto"`` -> every local device when there are >1, else None."""
    if isinstance(devices, str):
        if devices != "auto":
            raise ValueError(f"devices must be 'auto', None, or a device "
                             f"sequence, got {devices!r}")
        local = jax.local_devices()
        return local if len(local) > 1 else None
    return devices


def run_sweep(grid: SweepGrid, layers_for_model: LayersFn, *,
              out_path: Optional[str] = None,
              check_conservation: bool = False,
              devices="auto") -> SweepReport:
    """Execute every cell of ``grid``; one packetization per (mesh,
    placement, affinity, model) cell and ONE batched, drain-aware request
    simulation per (mesh, model): all placement x affinity combinations
    ride the same call as extra variant lanes (per-lane ``mc_nodes``),
    ordered by :func:`drain_estimate` so device shards stay balanced, and
    lanes retire as they drain instead of idle-stepping until the most
    congested placement finishes. With ``grid.result_phase`` the PE->MC
    result traffic of every cell drains in one further batched simulation
    per (mesh, model) - an independent second phase whose per-row stats
    merge into the request row (see DESIGN.md "Result phase").

    layers_for_model: model name -> LayerTraffic sequence (the sweep engine
        stays decoupled from how weights are trained or loaded).
    devices: forwarded to :func:`repro.noc.sim.simulate_batch` - the
        default ``"auto"`` shards the variants axis across all local
        devices on multi-device hosts and falls back to the single-device
        vmapped drain otherwise (per-variant results are bit-identical
        either way).
    """
    axes = grid.variant_axes()
    variants = [(by_name(tr, tiebreak=tb), _QUANTIZERS[prec])
                for prec, tb, tr in axes]
    devs = _resolve_devices(devices)
    streamed = grid.max_packets_per_layer is None
    rows: List[dict] = []
    classes = []
    pack_s = sim_s = res_pack_s = res_s = 0.0
    pack_by_tr: Dict[str, float] = {}   # ordering seconds per transform
    stepped_cycles = 0          # request cycle-steps across all variants
    result_cycles = 0           # result-phase cycle-steps
    # Result values depend only on (model, variants) - computed once and
    # reused across every mesh/placement/affinity cell.
    rvalue_cache: Dict[str, list] = {}
    layer_cache: Dict[str, Sequence[LayerTraffic]] = {}
    # Ordered payload words are mesh-independent (the transform sees only
    # packet payloads and the flit width), so every mesh/MC-count cell of a
    # model reuses one ordering pass; entries are keyed per (model, lanes,
    # transform, precision) - see :func:`cached_ordered_payloads` - so
    # grids whose variant lists overlap (and the result phase below) share
    # orderings at variant granularity, not just whole-list granularity.
    # The streamed path deliberately skips this cache - holding every
    # layer's full payload tensor is exactly what it exists to avoid - but
    # still orders once per (mesh, model): one streamed pass feeds every
    # placement x affinity assembler (``build_traffic_streamed_multi``).
    ordered_cache: Dict[tuple, list] = {}
    payload_cache: Dict[tuple, list] = {}
    shape_cache: Dict[tuple, list] = {}
    # Autotuned drain schedule per shape class (``noc.tune`` winners);
    # meshes missing from the table keep the grid's pinned constants.
    if grid.tune_path:
        from .tune import load_tuned, schedule_for
        tuned = load_tuned(grid.tune_path)
        drain_sched = lambda cfg: (  # noqa: E731
            (s.chunk, s.compact_ratio)
            if (s := schedule_for(cfg, tuned)) else (grid.chunk, 0.5))
    else:
        drain_sched = lambda cfg: (grid.chunk, 0.5)  # noqa: E731
    # MC placements of one mesh size share a compiled simulator when their
    # traffic shapes match; pad every member of a size group to the group's
    # max MC-stream count and max stream length. Placement never changes
    # the MC count, so the placement axis rides inside each size group.
    resolved = [_resolve_mesh(m) for m in grid.meshes]
    size_groups: Dict[tuple, List[NocConfig]] = {}
    for _, cfg in resolved:
        key = (cfg.rows, cfg.cols, cfg.num_vcs, cfg.vc_depth, cfg.lanes)
        size_groups.setdefault(key, []).append(cfg)

    # Escape-metadata bits per (model, precision, lanes, compression) and
    # result-stream outlier counts per (model, precision): analytic,
    # value-only quantities shared by every mesh/placement/affinity cell.
    comp_cache: Dict[tuple, int] = {}
    routlier_cache: Dict[tuple, int] = {}
    nv = len(variants)
    ndev = len(devs) if devs else 1
    for mesh_name, base_cfg in resolved:
        # Compression joins as an extra shape class per (mesh, model): MSR
        # shrinks per-packet flit counts, so none/msr cells can never share
        # a packetization skeleton or a compiled drain lane.
        for model, comp in [(m, c) for m in grid.models
                            for c in grid.compression]:
            if model not in layer_cache:
                layer_cache[model] = layers_for_model(model)
            layers = layer_cache[model]

            t0 = time.perf_counter()
            pkey = (model, base_cfg.lanes, comp)
            if pkey not in shape_cache:
                if streamed:
                    # One single-packet geometry probe per model; the
                    # payloads themselves never materialize whole.
                    shape_cache[pkey] = payload_shapes(
                        layers, base_cfg.lanes, variants,
                        max_packets_per_layer=grid.max_packets_per_layer,
                        compression=comp)
                else:
                    # The one-shot path reads the geometry off the
                    # payload arrays it needs anyway - probing all
                    # variants again would double the transform work.
                    payload_cache[pkey] = cached_ordered_payloads(
                        ordered_cache, model, layers, base_cfg.lanes,
                        variants, axes,
                        max_packets_per_layer=grid.max_packets_per_layer,
                        timings=pack_by_tr, compression=comp)
                    shape_cache[pkey] = [(w.shape[1], w.shape[2])
                                         for w in payload_cache[pkey]]
            group = size_groups[(base_cfg.rows, base_cfg.cols,
                                 base_cfg.num_vcs, base_cfg.vc_depth,
                                 base_cfg.lanes)]
            shapes = shape_cache[pkey]
            npackets = sum(n for n, _ in shapes)
            mc_pad = max(c.num_mcs for c in group)

            # Every (MC placement x packet->MC affinity) combination of
            # this (mesh, model) drains in ONE batched call: combos share
            # the traffic shapes (padded below) and differ only in their
            # per-lane mc_nodes / per-MC stream split, so the drain
            # scheduler can retire fast lanes while congested ones keep
            # stepping.
            placed = [(pl, aff, _place(base_cfg, pl))
                      for pl in grid.placements for aff in grid.affinity]
            tables = [affinity_mc_table(cfg) if aff == "nearest" else None
                      for _, aff, cfg in placed]
            lens = [stream_lengths(shapes, cfg.num_mcs, tbl)
                    for (_, _, cfg), tbl in zip(placed, tables)]
            # Affinity skews the per-MC split, so the common stream length
            # covers every placement x affinity combo of every member of
            # the size group - same-size meshes keep sharing one compiled
            # drain under the new axis. The base config's combos are
            # already in `lens`; only other group members recompute.
            t_pad = max(
                [int(ln.max()) for ln in lens]
                + [int(stream_lengths(
                    shapes, gcfg.num_mcs,
                    affinity_mc_table(gcfg) if aff == "nearest" else None
                   ).max())
                   for c in group if c is not base_cfg
                   for pl in grid.placements
                   for aff in grid.affinity
                   for gcfg in (_place(c, pl),)])
            if streamed:
                # ONE ordering pass for every placement x affinity combo:
                # the transform output is mesh-independent, so each chunk
                # is ordered once and scattered into all combo layouts.
                combo_traffics = build_traffic_streamed_multi(
                    layers, [cfg for _, _, cfg in placed], variants,
                    chunk_packets=grid.stream_chunk_packets,
                    num_streams=mc_pad, shapes=shapes, mc_tables=tables,
                    compression=comp)
            else:
                combo_traffics = [
                    assemble_traffic(payload_cache[pkey], cfg,
                                     num_streams=mc_pad, num_variants=nv,
                                     mc_table=tbl)
                    for (_, _, cfg), tbl in zip(placed, tables)]
            parts = [pad_traffic_length(t, t_pad) for t in combo_traffics]
            del combo_traffics
            traffic = _concat_lanes(parts)
            del parts
            mc_rows = np.stack(
                [np.asarray(tuple(cfg.mc_nodes) + (0,) * (mc_pad - cfg.num_mcs),
                            np.int32)
                 for _, _, cfg in placed for _ in range(nv)])
            # Drain-aware lane order: deal estimate-sorted lanes across the
            # device shards so no device ends up with only congested lanes.
            ests = np.asarray([drain_estimate(cfg, ln)
                               for (_, _, cfg), ln in zip(placed, lens)
                               for _ in range(nv)])
            order = _deal_order(ests, ndev)
            inv = np.empty_like(order)
            inv[order] = np.arange(order.size)
            t1 = time.perf_counter()
            d_chunk, d_ratio = drain_sched(placed[0][2])
            res_perm: List[SimResult] = simulate_batch(
                placed[0][2], _take_lanes(traffic, order),
                mc_nodes=mc_rows[order],
                count_headers=grid.count_headers,
                chunk=d_chunk, max_cycles=grid.max_cycles,
                check_conservation=check_conservation, devices=devs,
                backend=grid.backend, compact_ratio=d_ratio)
            results = [res_perm[inv[i]] for i in range(len(order))]
            t2 = time.perf_counter()

            # Result phase: one independent PE->MC drain per (mesh, model)
            # covering every combo's lanes. Streams inject at the PEs
            # (per-lane mc_nodes = pe_nodes) and eject at the MCs. Stream
            # *counts* are padded across the size group; the stream-length
            # axis is padded only across this cell's combos (other group
            # members' result lengths aren't known without building their
            # traffic), so result drains compile once per (mesh, model)
            # rather than once per size group.
            rres: Optional[List[SimResult]] = None
            t2b = t2
            if grid.result_phase:
                if model not in rvalue_cache:
                    rvalue_cache[model] = result_values(
                        layers, variants,
                        max_packets_per_layer=grid.max_packets_per_layer)
                pe_pad = max(c.num_routers - c.num_mcs for c in group)
                rparts = []
                for (_, _, cfg), tbl in zip(placed, tables):
                    rparts.append(build_result_traffic(
                        layers, cfg, variants,
                        max_packets_per_layer=grid.max_packets_per_layer,
                        mc_table=tbl, result_window=grid.result_window,
                        num_streams=pe_pad, values=rvalue_cache[model],
                        compression=comp))
                rnpkts = [int(p.num_packets) for p in rparts]
                rt_pad = max(int(p.words.shape[-2]) for p in rparts)
                # Injection-bound estimate per combo (the longest PE
                # stream floors the drain), dealt across device shards
                # like the request lanes so no shard holds only the
                # congested combos.
                rests = np.asarray([int(np.asarray(p.length).max())
                                    if p.length.size else 0
                                    for p in rparts for _ in range(nv)])
                rtraffic = _concat_lanes(
                    [pad_traffic_length(p, rt_pad) for p in rparts])
                del rparts
                pe_rows = np.stack(
                    [np.asarray(tuple(cfg.pe_nodes)
                                + (0,) * (pe_pad - len(cfg.pe_nodes)),
                                np.int32)
                     for _, _, cfg in placed for _ in range(nv)])
                rorder = _deal_order(rests, ndev)
                rinv = np.empty_like(rorder)
                rinv[rorder] = np.arange(rorder.size)
                t2b = time.perf_counter()
                rres_perm = simulate_batch(
                    placed[0][2], _take_lanes(rtraffic, rorder),
                    mc_nodes=pe_rows[rorder],
                    count_headers=grid.count_headers,
                    chunk=d_chunk, max_cycles=grid.max_cycles,
                    check_conservation=check_conservation, devices=devs,
                    backend=grid.backend, compact_ratio=d_ratio)
                rres = [rres_perm[rinv[i]] for i in range(len(rorder))]
            t3 = time.perf_counter()

            pack_s += t1 - t0
            sim_s += t2 - t1
            res_pack_s += t2b - t2
            res_s += t3 - t2b
            class_cycles = sum(r.cycles for r in results)
            stepped_cycles += class_cycles
            entry = {
                "mesh": mesh_name, "placements": list(grid.placements),
                "affinity": list(grid.affinity),
                "model": model, "compression": comp,
                "variants": len(results),
                "packetize_s": round(t1 - t0, 4),
                "simulate_s": round(t2 - t1, 4),
                "cycles_per_sec": round(class_cycles / (t2 - t1), 1)
                if t2 > t1 else None,
            }
            if rres is not None:
                rc = sum(r.cycles for r in rres)
                result_cycles += rc
                entry["result_packetize_s"] = round(t2b - t2, 4)
                entry["result_simulate_s"] = round(t3 - t2b, 4)
                entry["result_cycles_per_sec"] = (
                    round(rc / (t3 - t2b), 1) if t3 > t2b else None)
            classes.append(entry)

            for pi, (placement, aff, cfg) in enumerate(placed):
                cell = results[pi * nv:(pi + 1) * nv]
                rcell = rres[pi * nv:(pi + 1) * nv] if rres else [None] * nv
                mean_hops = packet_mean_hops(cfg, npackets, tables[pi])
                base_bt = {}
                base_rbt = {}
                for (prec, tb, tr), res, rr in zip(axes, cell, rcell):
                    if tr == grid.baseline:
                        base_bt[(prec, tb)] = res.total_bt
                        base_rbt[(prec, tb)] = rr.total_bt if rr else None
                rw = (grid.result_window if grid.result_window is not None
                      else DEFAULT_RESULT_WINDOW)
                for (prec, tb, tr), (transform, _), res, rr in zip(
                        axes, variants, cell, rcell):
                    overhead = recovery_overhead_bits(
                        layers, transform,
                        max_packets_per_layer=grid.max_packets_per_layer)
                    # MSR escape metadata (per-window outlier count + per-
                    # outlier position and top bits). Whether a value is an
                    # outlier is a property of the value, not of its
                    # position, so the bit budget is transform-independent:
                    # one analytic pass per (model, precision, lanes).
                    ckey = (model, prec, base_cfg.lanes, comp)
                    if ckey not in comp_cache:
                        comp_cache[ckey] = compression_overhead(
                            layers, _QUANTIZERS[prec], base_cfg.lanes, comp,
                            max_packets_per_layer=grid.max_packets_per_layer)
                    comp_overhead = comp_cache[ckey]
                    # Charge each recovery-index bit half a transition (the
                    # toggle expectation of an uninformative bit stream): the
                    # index rides the same links as the payload, so an honest
                    # reduction figure must pay for it (paper Sec. IV-C1).
                    # MSR escape bits get the identical price - same links,
                    # same uninformative-stream toggle expectation.
                    adjusted_bt = res.total_bt + overhead // 2 + comp_overhead // 2
                    base = base_bt[(prec, tb)]
                    if rr:
                        # The result phase is a *single* stream: any
                        # non-identity reorder (O1 included) owes a window
                        # index per value to restore element order. One
                        # result value per request packet.
                        roverhead = npackets * transform.overhead_bits_per_value(
                            min(rw, npackets), paired=False)
                        rcomp = 0
                        if comp == "msr":
                            rokey = (model, prec)
                            if rokey not in routlier_cache:
                                vi = axes.index((prec, tb, tr))
                                routlier_cache[rokey] = int(sum(
                                    int(msr.outlier_mask(lay[vi]).sum())
                                    for lay in rvalue_cache[model]))
                            # Result packets pad to lane-rounded slots, so
                            # the escape window is the padded slot count.
                            rslots = -(-rw // base_cfg.lanes) * base_cfg.lanes
                            rcomp = msr.msr_stream_overhead_bits(
                                rslots, rnpkts[pi], routlier_cache[rokey])
                        radj = rr.total_bt + roverhead // 2 + rcomp // 2
                        rbase = base_rbt[(prec, tb)]
                    rows.append({
                        "mesh": mesh_name, "placement": placement,
                        "affinity": aff, "model": model, "precision": prec,
                        "transform": tr, "tiebreak": tb,
                        "compression": comp,
                        "total_bt": res.total_bt,
                        "adjusted_bt": adjusted_bt,
                        "overhead_bits": overhead,
                        "compression_overhead_bits": comp_overhead,
                        "cycles": res.drain_cycle,
                        "flits": res.injected,
                        "bt_per_flit": res.bt_per_flit,
                        "mean_hops": mean_hops,
                        "reduction_pct": (1 - res.total_bt / base) * 100,
                        "adjusted_reduction_pct": (1 - adjusted_bt / base) * 100,
                        "result_bt": rr.total_bt if rr else None,
                        "result_cycles": rr.drain_cycle if rr else None,
                        "result_flits": rr.injected if rr else None,
                        "result_overhead_bits": roverhead if rr else None,
                        "result_compression_overhead_bits":
                            rcomp if rr else None,
                        "result_adjusted_bt": radj if rr else None,
                        "result_adjusted_reduction_pct": (
                            (1 - radj / rbase) * 100 if rr else None),
                    })

    wall = pack_s + sim_s + res_pack_s + res_s
    stats = {
        "cells": len(rows),
        "shape_classes": classes,
        "packetize_s": round(pack_s, 4),
        # Ordering seconds attributed per transform (one-shot payload-cache
        # misses only; assembler/simulated time excluded) - lets an O3
        # chain regression show up against the cheap O0-O2 permutes.
        "packetize_by_transform": {k: round(v, 4)
                                   for k, v in sorted(pack_by_tr.items())},
        "simulate_s": round(sim_s, 4),
        "wall_s": round(wall, 4),
        "stepped_cycles": stepped_cycles,
        "cycles_per_sec": round(stepped_cycles / sim_s, 1) if sim_s else None,
        "streamed": streamed,
        "devices": len(devs) if devs else 1,
        "result_phase": grid.result_phase,
    }
    if grid.result_phase:
        stats["result_packetize_s"] = round(res_pack_s, 4)
        stats["result_simulate_s"] = round(res_s, 4)
        stats["result_cycles"] = result_cycles
        stats["result_cycles_per_sec"] = (
            round(result_cycles / res_s, 1) if res_s else None)
    report = SweepReport(rows=rows, stats=stats)
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump({"grid": _grid_json(grid), "rows": rows,
                       "stats": stats}, f, indent=1)
    return report


def _grid_json(grid: SweepGrid) -> dict:
    out = dataclasses.asdict(grid)
    out["meshes"] = [_resolve_mesh(m)[0] for m in grid.meshes]
    for key in ("placements", "affinity", "transforms", "tiebreaks",
                "precisions", "models", "compression", "offered_loads"):
        out[key] = list(out[key])
    return out


def run_serving(grid: SweepGrid, layers_for_model: LayersFn, *,
                out_path: Optional[str] = None,
                check_conservation: bool = False,
                devices="auto") -> SweepReport:
    """The closed-loop ``serving`` suite: the BT sweep joined with an
    offered-load latency sweep.

    Runs :func:`run_sweep` (result phase forced on - serving is
    bidirectional by definition) for the per-transform BT rows, then one
    gated closed-loop drain (:func:`repro.noc.online.simulate_online`) per
    (mesh, placement, affinity, model) combo and offered-load point, plus
    a back-to-back saturation probe per combo. Timing is
    transform-independent (drain dynamics never read payload values), so
    the load axis is priced once per combo and the latency/BT frontier is
    the cross product: a transform moves a combo's BT coordinate, a load
    point its latency coordinate.

    The returned report carries the BT rows unchanged; ``stats["serving"]``
    adds ``points`` (one entry per combo x load x fault rate: p50/p99/mean
    latency, measured throughput, completed/truncated counts, gated drain
    cycles; with the degradation axes on, also fault_rate/slo_attainment/
    goodput/shed/failed),
    ``combos`` (per-combo ``saturation_tput``, ``latency_monotone`` - p50
    non-decreasing along the sorted load axis at the lowest fault rate,
    restricted to load points where the admission controller shed
    nothing (shedding caps queueing, so p50 plateaus by design) - and
    the per-transform BT join ``transforms[tr] = {request_bt, result_bt,
    adjusted_bt, ...}`` at the grid's first precision/tiebreak), and the
    serving wall-clock.
    """
    from .online import ArrivalProcess, latency_percentiles, simulate_online

    if not grid.offered_loads:
        raise ValueError("run_serving needs grid.offered_loads (offered "
                         "load points in inferences per 1000 cycles)")
    if grid.max_packets_per_layer is None:
        raise ValueError("run_serving uses the one-shot packetizer; set "
                         "max_packets_per_layer")
    if set(grid.compression) != {"none"}:
        raise ValueError(
            "run_serving prices drain timing once per combo on the O0 "
            "baseline packetization; the compression axis changes flit "
            "geometry per scheme, so serving grids must keep "
            "compression=('none',) (BT-only compression rows come from "
            "run_sweep)")
    base = (grid if grid.result_phase
            else dataclasses.replace(grid, result_phase=True))
    report = run_sweep(base, layers_for_model,
                       check_conservation=check_conservation,
                       devices=devices)

    t0 = time.perf_counter()
    o0 = [(by_name(grid.baseline), _QUANTIZERS[grid.precisions[0]])]
    prec0, tb0 = grid.precisions[0], grid.tiebreaks[0]
    loads = sorted(grid.offered_loads)
    frates = sorted(set(grid.fault_rates))
    fault_axis = bool(frates)
    if not frates:
        frates = [0.0]
    dead = tuple(tuple(int(x) for x in d) for d in grid.fault_dead_links)
    degradation = (fault_axis or bool(dead) or grid.deadline is not None
                   or grid.admit_queue_depth is not None)

    def _fault_model(rate: float):
        # Rate 0 with no dead links is the pinned clean path: faults=None
        # keeps the drain bit-identical to a grid without the fault axis.
        if rate == 0.0 and not dead:
            return None
        from .faults import FaultModel
        return FaultModel(rate=rate, seed=grid.fault_seed,
                          protect=grid.fault_protect, dead_links=dead,
                          max_retries=grid.fault_max_retries,
                          ack_latency=grid.fault_ack_latency)
    points: List[dict] = []
    combos: List[dict] = []
    layer_cache: Dict[str, Sequence[LayerTraffic]] = {}
    for mesh_name, base_cfg in [_resolve_mesh(m) for m in grid.meshes]:
        for model in grid.models:
            if model not in layer_cache:
                layer_cache[model] = layers_for_model(model)
            layers = layer_cache[model]
            for pl in grid.placements:
                for aff in grid.affinity:
                    cfg = _place(base_cfg, pl)
                    tbl = (affinity_mc_table(cfg) if aff == "nearest"
                           else None)
                    req = build_traffic_batch(
                        layers, cfg, o0,
                        max_packets_per_layer=grid.max_packets_per_layer,
                        mc_table=tbl).variant(0)
                    res = build_result_traffic(
                        layers, cfg, o0,
                        max_packets_per_layer=grid.max_packets_per_layer,
                        mc_table=tbl,
                        result_window=grid.result_window).variant(0)
                    combo_key = {"mesh": mesh_name, "placement": pl,
                                 "affinity": aff, "model": model}
                    combo_p50 = []
                    slo_by_load: Dict[float, List] = {}
                    for load in loads:
                        for rate in frates:
                            onl = simulate_online(
                                cfg, req, res,
                                arrivals=ArrivalProcess(grid.arrival, load,
                                                        grid.arrival_seed),
                                num_inferences=grid.serving_inferences,
                                compute_latency=grid.compute_latency,
                                count_headers=grid.count_headers,
                                chunk=grid.chunk,
                                max_cycles=grid.max_cycles,
                                check_conservation=check_conservation,
                                record_bt=False,
                                faults=_fault_model(rate),
                                deadline=grid.deadline,
                                admit_queue_depth=grid.admit_queue_depth)
                            lp = latency_percentiles(onl.latencies)
                            # p50 is only guaranteed non-decreasing in
                            # offered load while every inference is
                            # admitted: once the admission controller
                            # sheds, queueing is capped and p50 plateaus
                            # by design, so those points are excluded
                            # from the monotonicity verdict.
                            if rate == frates[0] and not onl.num_shed:
                                combo_p50.append(lp["p50"])
                            point = {
                                **combo_key, "offered_load": load,
                                "throughput": onl.throughput,
                                "p50_latency": lp["p50"],
                                "p99_latency": lp["p99"],
                                "mean_latency": lp["mean"],
                                "completed": lp["count"],
                                "truncated": lp["truncated"],
                                "request_drain_cycle":
                                    onl.request_drain_cycle,
                                "result_drain_cycle":
                                    onl.result_drain_cycle,
                            }
                            if degradation:
                                point.update({
                                    "fault_rate": rate,
                                    "deadline": grid.deadline,
                                    "slo_attainment": onl.slo_attainment,
                                    "goodput": onl.goodput,
                                    "shed": onl.num_shed,
                                    "failed": onl.num_failed,
                                })
                                slo_by_load.setdefault(load, []).append(
                                    onl.slo_attainment)
                            points.append(point)
                    sat = simulate_online(
                        cfg, req, res,
                        arrivals=ArrivalProcess("backtoback"),
                        num_inferences=grid.serving_inferences,
                        compute_latency=grid.compute_latency,
                        count_headers=grid.count_headers,
                        chunk=grid.chunk, max_cycles=grid.max_cycles,
                        check_conservation=check_conservation,
                        record_bt=False)
                    transforms = {}
                    for tr in grid.transforms:
                        row = report.row(**combo_key, transform=tr,
                                         precision=prec0, tiebreak=tb0)
                        transforms[tr] = {
                            "request_bt": row["total_bt"],
                            "request_adjusted_bt": row["adjusted_bt"],
                            "result_bt": row["result_bt"],
                            "result_adjusted_bt": row["result_adjusted_bt"],
                            "adjusted_reduction_pct":
                                row["adjusted_reduction_pct"],
                        }
                    combo = {
                        **combo_key,
                        "saturation_tput": sat.throughput,
                        "latency_monotone": all(
                            b >= a for a, b in zip(combo_p50, combo_p50[1:])
                            if a is not None and b is not None),
                        "transforms": transforms,
                    }
                    if fault_axis and grid.deadline is not None:
                        # SLO attainment non-increasing along the sorted
                        # fault-rate axis at every load (flip schedules are
                        # nested in rate, so this holds by construction).
                        combo["slo_monotone_in_fault"] = all(
                            a >= b
                            for curve in slo_by_load.values()
                            for a, b in zip(curve, curve[1:])
                            if a is not None and b is not None)
                    combos.append(combo)
    report.stats["serving"] = {
        "offered_loads": loads,
        "inferences": grid.serving_inferences,
        "compute_latency": grid.compute_latency,
        "arrival": grid.arrival,
        "arrival_seed": grid.arrival_seed,
        "precision": prec0, "tiebreak": tb0,
        "conservation_checked": bool(check_conservation),
        "fault_rates": frates if fault_axis else [],
        "fault_protect": grid.fault_protect if degradation else None,
        "fault_dead_links": [list(d) for d in dead],
        "deadline": grid.deadline,
        "admit_queue_depth": grid.admit_queue_depth,
        "points": points,
        "combos": combos,
        "serving_s": round(time.perf_counter() - t0, 4),
    }
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump({"grid": _grid_json(grid), "rows": report.rows,
                       "stats": report.stats}, f, indent=1)
    return report
