"""DNN layer traffic -> packetized flit streams for the NoC simulator.

Models the paper's NOC-DNA dataflow (Fig. 7): memory controllers fetch
(input, weight) operand streams from off-chip memory, run them through the
ordering unit (a WireTransform), packetize into flits - inputs in the left
half-flit, weights in the right (Fig. 2) - and inject toward the PE assigned
to each neuron computation.

A *packet* carries the operands for one neuron (one output position x output
channel for conv; one output unit for linear): K (input, weight) pairs plus
one header flit. The ordering window is the packet payload, matching the
paper's ordering-unit-per-MC placement (it sees one packet at a time).

Packetization is fully vectorized (the seed's per-neuron Python loop lives
on only as the equivalence oracle in ``repro.noc._reference``): the MC/PE/VC
round-robin assignments are closed-form functions of the global packet id,
header words and META bitfields are synthesized as arrays, the ordering
transform is applied via one ``vmap`` per layer, and per-MC streams are
written with one scatter per layer. ``build_traffic_batch`` additionally
shares all of that skeleton work across ordering/precision variants, which
only differ in payload words.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wire import WireTransform
from .topology import NocConfig
from .sim import Traffic, META_PAYLOAD, META_TAIL

__all__ = ["LayerTraffic", "build_traffic", "build_traffic_batch",
           "ordered_payloads", "assemble_traffic", "stream_lengths",
           "pad_traffic_length", "conv_layer_traffic",
           "linear_layer_traffic"]

# One sweep variant: an ordering transform plus an optional value->wire-dtype
# quantizer (None transmits raw float32 words).
Variant = Tuple[WireTransform, Optional[Callable[[jax.Array], jax.Array]]]


@dataclasses.dataclass
class LayerTraffic:
    """(input, weight) operand pairs for every neuron of one layer.

    inputs:  (num_neurons, k) - receptive-field values per neuron
    weights: (num_neurons, k) - the matching kernel values
    """

    inputs: jax.Array
    weights: jax.Array

    def __post_init__(self):
        if self.inputs.shape != self.weights.shape:
            raise ValueError("inputs/weights must be (num_neurons, k) alike")


def conv_layer_traffic(x: jax.Array, w: jax.Array) -> LayerTraffic:
    """im2col a conv layer: x (H, W, Cin), w (kh, kw, Cin, Cout), VALID conv.

    Neuron = (output position, output channel); k = kh*kw*Cin.
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x[None].astype(jnp.float32), (kh, kw), (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    oh, ow, k = patches.shape
    patches = patches.reshape(oh * ow, k).astype(x.dtype)
    wcol = w.reshape(k, cout).T                      # (Cout, k)
    # neuron ordering: all positions of channel 0, then channel 1, ...
    inputs = jnp.tile(patches, (cout, 1))
    weights = jnp.repeat(wcol, oh * ow, axis=0)
    return LayerTraffic(inputs, weights)


def linear_layer_traffic(x: jax.Array, w: jax.Array) -> LayerTraffic:
    """x (k,), w (out, k): one packet per output unit."""
    out, k = w.shape
    inputs = jnp.broadcast_to(x[None, :], (out, k))
    return LayerTraffic(inputs, w)


def _subsample(layer: LayerTraffic,
               max_packets: Optional[int]) -> Tuple[jax.Array, jax.Array]:
    """Deterministic-stride neuron subsampling (BT rates are per-flit, so
    subsampling is unbiased); identical to the seed packetizer's."""
    inp, wgt = layer.inputs, layer.weights
    n = int(inp.shape[0])
    if max_packets is not None and n > max_packets:
        stride = n // max_packets
        idx = jnp.arange(0, stride * max_packets, stride)
        inp, wgt = inp[idx], wgt[idx]
    return inp, wgt


@functools.lru_cache(maxsize=None)
def _packet_fn(transform: WireTransform, lanes: int):
    """Vmapped packet transform, memoized per (transform, lanes).

    WireTransforms are frozen dataclasses, so they key the cache. The vmap
    is deliberately left un-jitted: its primitives (argsort, gathers,
    bitcasts) hit JAX's per-primitive executable cache, which the rest of
    the stack shares, whereas a whole-program jit would recompile per
    (transform, layer shape) combination - measurably slower for the one
    pass per model a sweep performs."""

    def one_packet(i, w):
        return transform.apply(i, w, lanes).words

    return jax.vmap(one_packet)


def _payload_words(inp: jax.Array, wgt: jax.Array, transform: WireTransform,
                   quantizer, lanes: int) -> np.ndarray:
    """Ordered payload flits for every neuron of one layer: (n, F, L) u32.

    One vmap over neurons applies the WireTransform packet-by-packet (the
    ordering window is the packet payload)."""
    if quantizer is not None:
        inp, wgt = quantizer(inp), quantizer(wgt)
    words = _packet_fn(transform, lanes)(inp, wgt)
    return np.asarray(words.astype(jnp.uint32))


def ordered_payloads(
    layers: Sequence[LayerTraffic],
    lanes: int,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
) -> List[np.ndarray]:
    """Ordered payload words per layer, stacked over variants: (B, n, F, L).

    This is the mesh-independent half of packetization (the transform sees
    only packet payloads and the flit width); the sweep engine computes it
    once per model and re-assembles it for every mesh / MC-count cell via
    :func:`assemble_traffic`.
    """
    if not variants:
        raise ValueError("need at least one (transform, quantizer) variant")
    out: List[np.ndarray] = []
    for layer in layers:
        inp, wgt = _subsample(layer, max_packets_per_layer)
        per_variant = [_payload_words(inp, wgt, tr, q, lanes)
                       for tr, q in variants]
        shapes = {w.shape for w in per_variant}
        if len(shapes) != 1:
            raise ValueError(
                f"variants disagree on flit geometry: {sorted(shapes)}")
        out.append(np.stack(per_variant))
    return out


def stream_lengths(layer_shapes: Sequence[Tuple[int, int]],
                   m: int) -> np.ndarray:
    """Per-MC flit counts for layers of ``(n_packets, payload_flits)``.

    Closed-form: packets round-robin over the ``m`` MCs, each contributing
    its payload plus one header flit. Lets the sweep engine size stream
    padding without materializing any traffic.
    """
    lengths = np.zeros(m, np.int64)
    g0 = 0
    for n, fpay in layer_shapes:
        gids = g0 + np.arange(n, dtype=np.int64)
        lengths += np.bincount(gids % m, minlength=m) * (fpay + 1)
        g0 += n
    return lengths


def pad_traffic_length(traffic: Traffic, t: int) -> Traffic:
    """Pad the per-MC stream axis T with empty flits.

    Padding beyond ``length`` is never injected, so this only changes array
    shapes - the sweep engine uses it (with MC-stream padding) to give every
    MC placement of one mesh size identical traffic shapes, and therefore
    one shared compiled simulator.
    """
    cur = int(traffic.words.shape[-2])
    if t <= cur:
        return traffic
    extra = t - cur

    def pad_last(a):
        widths = [(0, 0)] * (a.ndim - 1) + [(0, extra)]
        return jnp.asarray(np.pad(np.asarray(a), widths))

    words = np.pad(np.asarray(traffic.words),
                   [(0, 0)] * (traffic.words.ndim - 2) + [(0, extra), (0, 0)])
    return Traffic(words=jnp.asarray(words), dest=pad_last(traffic.dest),
                   meta=pad_last(traffic.meta), vc=pad_last(traffic.vc),
                   pkt=pad_last(traffic.pkt), length=traffic.length)


def assemble_traffic(layer_words: Sequence[np.ndarray],
                     cfg: NocConfig,
                     num_streams: Optional[int] = None,
                     num_variants: Optional[int] = None) -> Traffic:
    """Scatter per-layer (B, n, F, L) payloads into batched per-MC streams.

    All variants share the packetization skeleton (headers, META bitfields,
    MC/PE/VC round-robin, per-MC scatter layout): an ordering transform only
    permutes values within a packet and a quantizer only narrows them, so
    the flit geometry - and therefore dest/meta/vc/pkt/length - is variant-
    independent. Only the payload words differ per variant. The result
    feeds :func:`repro.noc.sim.simulate_batch` directly.

    num_streams: pad the MC-stream axis to this count with empty streams
        (packets still round-robin over the config's real MCs). The sweep
        engine pads every placement of one mesh size to a common count so
        they share a single compiled simulator.
    num_variants: the variants-axis size when ``layer_words`` is empty (it
        is otherwise read off the payload arrays).
    """
    m, lanes = cfg.num_mcs, cfg.lanes
    if num_streams is not None and num_streams < m:
        raise ValueError(f"cannot pad {m} MC streams down to {num_streams}")
    nv = layer_words[0].shape[0] if layer_words else (num_variants or 1)
    pes = np.asarray(cfg.pe_nodes, np.int64)
    for words_v in layer_words:
        if words_v.shape[3] != lanes:
            raise ValueError(f"payloads built for {words_v.shape[3]} lanes, "
                             f"config has {lanes}")

    # Closed-form round-robin skeleton. With global packet id g
    # (consecutive across layers), the seed loop's bookkeeping collapses to
    #   mc(g)   = g % M                 (packet round-robin over MCs)
    #   dest(g) = pes[g % num_pes]      (pe_rr increments once per packet)
    #   vc(g)   = (g // M) % V          (vc_rr[mc] counts packets at mc, and
    #                                    the mc assignment is a perfect RR)
    # and a packet's flit offset inside its MC stream is the running flit
    # count of earlier packets at that MC.
    per_layer = []
    lengths = np.zeros(m, np.int64)
    g0 = 0
    for words_v in layer_words:
        n, fpay = words_v.shape[1], words_v.shape[2]
        f = fpay + 1                                    # + header flit
        gids = g0 + np.arange(n, dtype=np.int64)
        mcs = gids % m
        per_layer.append((gids, mcs, f))
        lengths += np.bincount(mcs, minlength=m) * f
        g0 += n

    t = int(lengths.max()) if len(lengths) else 0
    words_arr = np.zeros((nv, m, t, lanes), np.uint32)
    dest_arr = np.zeros((m, t), np.int32)
    meta_arr = np.zeros((m, t), np.int32)
    vc_arr = np.zeros((m, t), np.int32)
    pkt_arr = np.zeros((m, t), np.int32)

    mc_base = np.zeros(m, np.int64)                     # flits written per MC
    for (gids, mcs, f), words_v in zip(per_layer, layer_words):
        n, fpay = words_v.shape[1], words_v.shape[2]
        if n == 0:
            continue
        start = gids[0]
        dest = pes[gids % len(pes)].astype(np.int32)
        vc = ((gids // m) % cfg.num_vcs).astype(np.int32)
        # Rank of each packet among this layer's packets at its MC: packets
        # at one MC are g0+j0, g0+j0+M, ... so rank = (j - j0) // M.
        j = gids - start
        j0 = (mcs - start) % m
        rank = (j - j0) // m
        flit0 = mc_base[mcs] + rank * f                 # (n,) stream offset
        cols = (flit0[:, None] + np.arange(f)[None, :]).reshape(-1)
        rows = np.repeat(mcs, f)

        # Header synthesis: word 0 = dest, 1 = packet id, 2 = payload flits.
        hdr = np.zeros((n, lanes), np.uint32)
        hdr[:, 0] = dest.astype(np.uint32)
        hdr[:, 1] = (gids & 0xFFFFFFFF).astype(np.uint32)
        hdr[:, 2] = fpay
        full = np.empty((nv, n, f, lanes), np.uint32)
        full[:, :, 0, :] = hdr[None]
        full[:, :, 1:, :] = words_v

        # META bitfield: header 0, payload flits PAYLOAD, last flit |= TAIL.
        md = np.full((f,), META_PAYLOAD, np.int32)
        md[0] = 0
        md[-1] |= META_TAIL

        words_arr[:, rows, cols] = full.reshape(nv, n * f, lanes)
        dest_arr[rows, cols] = np.repeat(dest, f)
        meta_arr[rows, cols] = np.broadcast_to(md, (n, f)).reshape(-1)
        vc_arr[rows, cols] = np.repeat(vc, f)
        pkt_arr[rows, cols] = np.repeat(gids.astype(np.int32), f)
        mc_base += np.bincount(mcs, minlength=m) * f

    if num_streams is not None and num_streams > m:
        extra = num_streams - m
        words_arr = np.concatenate(
            [words_arr, np.zeros((nv, extra, t, lanes), np.uint32)], axis=1)
        pad2 = ((0, extra), (0, 0))
        dest_arr = np.pad(dest_arr, pad2)
        meta_arr = np.pad(meta_arr, pad2)
        vc_arr = np.pad(vc_arr, pad2)
        pkt_arr = np.pad(pkt_arr, pad2)
        lengths = np.pad(lengths, (0, extra))

    def tile(a):
        return jnp.asarray(np.broadcast_to(a, (nv,) + a.shape))

    return Traffic(
        words=jnp.asarray(words_arr), dest=tile(dest_arr), meta=tile(meta_arr),
        vc=tile(vc_arr), pkt=tile(pkt_arr),
        length=tile(lengths.astype(np.int32)))


def build_traffic_batch(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
) -> Traffic:
    """Packetize ``layers`` once per (transform, quantizer) variant into a
    batched Traffic with a leading variants axis (see
    :func:`ordered_payloads` / :func:`assemble_traffic`)."""
    payloads = ordered_payloads(layers, cfg.lanes, variants,
                                max_packets_per_layer=max_packets_per_layer)
    return assemble_traffic(payloads, cfg, num_variants=len(variants))


def build_traffic(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    transform: WireTransform,
    *,
    quantizer=None,
    max_packets_per_layer: Optional[int] = None,
) -> Traffic:
    """Packetize layers under a WireTransform into per-MC injection streams.

    quantizer: optional value -> wire-dtype map (e.g. fixed-8 quantization);
        default transmits raw float32 words.
    max_packets_per_layer: subsample neurons (deterministic stride) to bound
        simulation time; BT rates are per-flit so subsampling is unbiased.

    Bit-identical to the seed loop implementation (pinned by the equivalence
    regression test against ``repro.noc._reference``).
    """
    batch = build_traffic_batch(layers, cfg, [(transform, quantizer)],
                                max_packets_per_layer=max_packets_per_layer)
    return Traffic(*(field[0] for field in batch))
