"""DNN layer traffic -> packetized flit streams for the NoC simulator.

Models the paper's NOC-DNA dataflow (Fig. 7): memory controllers fetch
(input, weight) operand streams from off-chip memory, run them through the
ordering unit (a WireTransform), packetize into flits - inputs in the left
half-flit, weights in the right (Fig. 2) - and inject toward the PE assigned
to each neuron computation.

A *packet* carries the operands for one neuron (one output position x output
channel for conv; one output unit for linear): K (input, weight) pairs plus
one header flit. The ordering window is the packet payload, matching the
paper's ordering-unit-per-MC placement (it sees one packet at a time).

Packetization is fully vectorized (the seed's per-neuron Python loop lives
on only as the equivalence oracle in ``repro.noc._reference``): the MC/PE/VC
round-robin assignments are closed-form functions of the global packet id,
header words and META bitfields are synthesized as arrays, the ordering
transform is applied via one ``vmap`` per layer, and per-MC streams are
written with one scatter per layer. ``build_traffic_batch`` additionally
shares all of that skeleton work across ordering/precision variants, which
only differ in payload words.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wire import WireTransform
from .topology import NocConfig
from .sim import Traffic, META_PAYLOAD, META_TAIL

__all__ = ["LayerTraffic", "build_traffic", "build_traffic_batch",
           "build_traffic_streamed", "ordered_payloads",
           "ordered_payloads_streamed", "payload_shapes", "assemble_traffic",
           "TrafficAssembler", "stream_lengths", "pad_traffic_length",
           "stack_traffics", "conv_layer_traffic", "linear_layer_traffic"]

# One sweep variant: an ordering transform plus an optional value->wire-dtype
# quantizer (None transmits raw float32 words).
Variant = Tuple[WireTransform, Optional[Callable[[jax.Array], jax.Array]]]


@dataclasses.dataclass
class LayerTraffic:
    """(input, weight) operand pairs for every neuron of one layer.

    inputs:  (num_neurons, k) - receptive-field values per neuron
    weights: (num_neurons, k) - the matching kernel values
    """

    inputs: jax.Array
    weights: jax.Array

    def __post_init__(self):
        if self.inputs.shape != self.weights.shape:
            raise ValueError("inputs/weights must be (num_neurons, k) alike")


def conv_layer_traffic(x: jax.Array, w: jax.Array) -> LayerTraffic:
    """im2col a conv layer: x (H, W, Cin), w (kh, kw, Cin, Cout), VALID conv.

    Neuron = (output position, output channel); k = kh*kw*Cin.
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x[None].astype(jnp.float32), (kh, kw), (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    oh, ow, k = patches.shape
    patches = patches.reshape(oh * ow, k).astype(x.dtype)
    wcol = w.reshape(k, cout).T                      # (Cout, k)
    # neuron ordering: all positions of channel 0, then channel 1, ...
    inputs = jnp.tile(patches, (cout, 1))
    weights = jnp.repeat(wcol, oh * ow, axis=0)
    return LayerTraffic(inputs, weights)


def linear_layer_traffic(x: jax.Array, w: jax.Array) -> LayerTraffic:
    """x (k,), w (out, k): one packet per output unit."""
    out, k = w.shape
    inputs = jnp.broadcast_to(x[None, :], (out, k))
    return LayerTraffic(inputs, w)


def _subsample(layer: LayerTraffic,
               max_packets: Optional[int]) -> Tuple[jax.Array, jax.Array]:
    """Deterministic-stride neuron subsampling (BT rates are per-flit, so
    subsampling is unbiased); identical to the seed packetizer's."""
    inp, wgt = layer.inputs, layer.weights
    n = int(inp.shape[0])
    if max_packets is not None and n > max_packets:
        stride = n // max_packets
        idx = jnp.arange(0, stride * max_packets, stride)
        inp, wgt = inp[idx], wgt[idx]
    return inp, wgt


@functools.lru_cache(maxsize=None)
def _packet_fn(transform: WireTransform, lanes: int):
    """Vmapped packet transform, memoized per (transform, lanes).

    WireTransforms are frozen dataclasses, so they key the cache. The vmap
    is deliberately left un-jitted: its primitives (argsort, gathers,
    bitcasts) hit JAX's per-primitive executable cache, which the rest of
    the stack shares, whereas a whole-program jit would recompile per
    (transform, layer shape) combination - measurably slower for the one
    pass per model a sweep performs."""

    def one_packet(i, w):
        return transform.apply(i, w, lanes).words

    return jax.vmap(one_packet)


def _payload_words(inp: jax.Array, wgt: jax.Array, transform: WireTransform,
                   quantizer, lanes: int) -> np.ndarray:
    """Ordered payload flits for every neuron of one layer: (n, F, L) u32.

    One vmap over neurons applies the WireTransform packet-by-packet (the
    ordering window is the packet payload)."""
    if quantizer is not None:
        inp, wgt = quantizer(inp), quantizer(wgt)
    words = _packet_fn(transform, lanes)(inp, wgt)
    return np.asarray(words.astype(jnp.uint32))


def ordered_payloads(
    layers: Sequence[LayerTraffic],
    lanes: int,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
) -> List[np.ndarray]:
    """Ordered payload words per layer, stacked over variants: (B, n, F, L).

    This is the mesh-independent half of packetization (the transform sees
    only packet payloads and the flit width); the sweep engine computes it
    once per model and re-assembles it for every mesh / MC-count cell via
    :func:`assemble_traffic`.
    """
    if not variants:
        raise ValueError("need at least one (transform, quantizer) variant")
    out: List[np.ndarray] = []
    for layer in layers:
        inp, wgt = _subsample(layer, max_packets_per_layer)
        if inp.shape[0] == 0:
            # Probe the geometry instead of transforming nothing - a
            # quantizer's scale reduction has no identity on empty operands.
            (_, fpay), = payload_shapes([layer], lanes, variants)
            out.append(np.zeros((len(variants), 0, fpay, lanes), np.uint32))
            continue
        per_variant = [_payload_words(inp, wgt, tr, q, lanes)
                       for tr, q in variants]
        shapes = {w.shape for w in per_variant}
        if len(shapes) != 1:
            raise ValueError(
                f"variants disagree on flit geometry: {sorted(shapes)}")
        out.append(np.stack(per_variant))
    return out


@functools.lru_cache(maxsize=None)
def _packet_chunk_fn(transform: WireTransform, lanes: int):
    """Jitted wrapper of :func:`_packet_fn` for the streamed path.

    One whole-program compile per (transform, lanes, chunk shape) that every
    chunk of every layer with that operand width reuses - the streamed
    packetizer pads its ragged final chunk up to the fixed chunk size
    precisely so this executable is hit on every call. Wrapping the shared
    vmap keeps the one-shot and streamed paths on a single transform kernel.
    """
    fn = _packet_fn(transform, lanes)
    return jax.jit(lambda i, w: fn(i, w).astype(jnp.uint32))


def payload_shapes(
    layers: Sequence[LayerTraffic],
    lanes: int,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
) -> List[Tuple[int, int]]:
    """Per-layer ``(n_packets, payload_flits)`` without materializing any
    payloads: the flit geometry is probed on a single packet per variant.

    Lets the streamed path (and the sweep engine's stream-length padding)
    size everything up front at O(1) cost per layer.
    """
    if not variants:
        raise ValueError("need at least one (transform, quantizer) variant")
    out: List[Tuple[int, int]] = []
    for layer in layers:
        inp, wgt = _subsample(layer, max_packets_per_layer)
        # Geometry depends only on the operand width k, so a zero-packet
        # layer is probed with a dummy packet (matching the one-shot path,
        # which emits a (B, 0, F, L) array for it).
        i1, w1 = (inp[:1], wgt[:1]) if inp.shape[0] else (
            jnp.zeros((1,) + inp.shape[1:], inp.dtype),
            jnp.zeros((1,) + wgt.shape[1:], wgt.dtype))
        shapes = set()
        for tr, q in variants:
            i0, w0 = (i1, w1) if q is None else (q(i1), q(w1))
            shapes.add(tuple(tr.apply(i0[0], w0[0], lanes).words.shape))
        if len(shapes) != 1:
            raise ValueError(
                f"variants disagree on flit geometry: {sorted(shapes)}")
        (fpay, _), = shapes
        out.append((int(inp.shape[0]), int(fpay)))
    return out


def ordered_payloads_streamed(
    layers: Sequence[LayerTraffic],
    lanes: int,
    variants: Sequence[Variant],
    *,
    chunk_packets: int = 4096,
    max_packets_per_layer: Optional[int] = None,
):
    """Generator form of :func:`ordered_payloads` with bounded working set.

    Yields ``(layer_index, start_packet, words)`` where ``words`` is the
    ``(B, c, F, L)`` uint32 payload block for packets
    ``[start, start + c)`` of that layer, ``c <= chunk_packets``.
    Concatenating a layer's chunks is bit-identical to the one-shot path:
    quantizers are applied to the *whole* layer first (a fixed-point scale
    must not depend on the chunking), and the transform - per-packet by
    construction - runs through one jit-cached vmap whose ragged final
    chunk is zero-padded to the fixed chunk shape and sliced back.
    """
    if not variants:
        raise ValueError("need at least one (transform, quantizer) variant")
    if chunk_packets < 1:
        raise ValueError(f"chunk_packets must be >= 1, got {chunk_packets}")
    for li, layer in enumerate(layers):
        inp, wgt = _subsample(layer, max_packets_per_layer)
        n = int(inp.shape[0])
        if n == 0:          # nothing to order or scatter for an empty layer
            continue
        ops = [(inp, wgt) if q is None else (q(inp), q(wgt))
               for _, q in variants]
        for start in range(0, n, chunk_packets):
            c = min(chunk_packets, n - start)
            per_variant = []
            for (tr, _), (qi, qw) in zip(variants, ops):
                ci, cw = qi[start:start + c], qw[start:start + c]
                if c < chunk_packets:
                    pad = ((0, chunk_packets - c), (0, 0))
                    ci, cw = jnp.pad(ci, pad), jnp.pad(cw, pad)
                words = _packet_chunk_fn(tr, lanes)(ci, cw)
                per_variant.append(np.asarray(words)[:c])
            shapes = {w.shape for w in per_variant}
            if len(shapes) != 1:
                raise ValueError(
                    f"variants disagree on flit geometry: {sorted(shapes)}")
            yield li, start, np.stack(per_variant)


def stream_lengths(layer_shapes: Sequence[Tuple[int, int]],
                   m: int) -> np.ndarray:
    """Per-MC flit counts for layers of ``(n_packets, payload_flits)``.

    Closed-form: packets round-robin over the ``m`` MCs, each contributing
    its payload plus one header flit. Lets the sweep engine size stream
    padding without materializing any traffic.
    """
    lengths = np.zeros(m, np.int64)
    g0 = 0
    for n, fpay in layer_shapes:
        gids = g0 + np.arange(n, dtype=np.int64)
        lengths += np.bincount(gids % m, minlength=m) * (fpay + 1)
        g0 += n
    return lengths


def pad_traffic_length(traffic: Traffic, t: int) -> Traffic:
    """Pad the per-MC stream axis T with empty flits.

    Padding beyond ``length`` is never injected, so this only changes array
    shapes - the sweep engine uses it (with MC-stream padding) to give every
    MC placement of one mesh size identical traffic shapes, and therefore
    one shared compiled simulator.
    """
    cur = int(traffic.words.shape[-2])
    if t <= cur:
        return traffic
    extra = t - cur

    def pad_last(a):
        widths = [(0, 0)] * (a.ndim - 1) + [(0, extra)]
        return jnp.asarray(np.pad(np.asarray(a), widths))

    words = np.pad(np.asarray(traffic.words),
                   [(0, 0)] * (traffic.words.ndim - 2) + [(0, extra), (0, 0)])
    return traffic._replace(
        words=jnp.asarray(words), dest=pad_last(traffic.dest),
        meta=pad_last(traffic.meta), vc=pad_last(traffic.vc),
        pkt=pad_last(traffic.pkt))


def stack_traffics(traffics: Sequence[Traffic]) -> Traffic:
    """Stack single (unbatched) Traffics into one batched Traffic.

    The lanes may carry different real lengths (each keeps its ``length``
    row - this is how heterogeneous-drain batches for the retirement
    scheduler are built); their stream axes are padded to the longest T
    first. ``num_packets`` becomes the max, which is what the conservation
    ledger needs to cover every lane.
    """
    if not traffics:
        raise ValueError("need at least one Traffic to stack")
    t = max(int(tr.words.shape[-2]) for tr in traffics)
    traffics = [pad_traffic_length(tr, t) for tr in traffics]
    return Traffic(
        words=jnp.stack([tr.words for tr in traffics]),
        dest=jnp.stack([tr.dest for tr in traffics]),
        meta=jnp.stack([tr.meta for tr in traffics]),
        vc=jnp.stack([tr.vc for tr in traffics]),
        pkt=jnp.stack([tr.pkt for tr in traffics]),
        length=jnp.stack([tr.length for tr in traffics]),
        num_packets=max(int(tr.num_packets) for tr in traffics))


class TrafficAssembler:
    """Incremental per-MC stream writer - the scatter half of packetization,
    shared verbatim by the one-shot (:func:`assemble_traffic`) and streamed
    (:func:`build_traffic_streamed`) paths, so the two are bit-identical by
    construction.

    Closed-form round-robin skeleton. With global packet id g (consecutive
    across layers), the seed loop's bookkeeping collapses to
        mc(g)   = g % M                 (packet round-robin over MCs)
        dest(g) = pes[g % num_pes]      (pe_rr increments once per packet)
        vc(g)   = (g // M) % V          (vc_rr[mc] counts packets at mc, and
                                         the mc assignment is a perfect RR)
    and a packet's flit offset inside its MC stream is the running flit
    count of earlier packets at that MC. Every quantity is elementwise in
    g, so a layer may arrive in any number of packet chunks: each chunk
    scatters into its final location independently.
    """

    def __init__(self, layer_shapes: Sequence[Tuple[int, int]],
                 cfg: NocConfig, num_streams: Optional[int] = None,
                 num_variants: int = 1):
        m, lanes = cfg.num_mcs, cfg.lanes
        if num_streams is not None and num_streams < m:
            raise ValueError(
                f"cannot pad {m} MC streams down to {num_streams}")
        self.cfg = cfg
        self.nv = num_variants
        self.num_streams = num_streams
        self.shapes = [(int(n), int(f)) for n, f in layer_shapes]
        self.pes = np.asarray(cfg.pe_nodes, np.int64)
        # Per-layer global packet offset and per-MC flit base at layer start.
        ns = [n for n, _ in self.shapes]
        self.layer_g0 = np.concatenate(
            [[0], np.cumsum(ns)]).astype(np.int64)
        self.layer_base = [np.zeros(m, np.int64)]
        lengths = np.zeros(m, np.int64)
        for (n, fpay), g0 in zip(self.shapes, self.layer_g0):
            gids = g0 + np.arange(n, dtype=np.int64)
            lengths = lengths + np.bincount(gids % m, minlength=m) * (fpay + 1)
            self.layer_base.append(lengths.copy())
        self.lengths = lengths
        t = int(lengths.max()) if m else 0
        self.words = np.zeros((self.nv, m, t, lanes), np.uint32)
        self.dest = np.zeros((m, t), np.int32)
        self.meta = np.zeros((m, t), np.int32)
        self.vc = np.zeros((m, t), np.int32)
        self.pkt = np.zeros((m, t), np.int32)

    def add_chunk(self, layer: int, start: int, words: np.ndarray) -> None:
        """Scatter payload ``words`` (B, c, F, L) for packets
        ``[start, start + c)`` of ``layer`` into the per-MC streams."""
        cfg, m, lanes = self.cfg, self.cfg.num_mcs, self.cfg.lanes
        n_l, fpay = self.shapes[layer]
        if words.shape[0] != self.nv:
            raise ValueError(f"payload chunk has {words.shape[0]} variants, "
                             f"assembler was sized for {self.nv}")
        if words.shape[2] != fpay or words.shape[3] != lanes:
            raise ValueError(
                f"payload chunk {words.shape[2:]} does not match layer "
                f"{layer} geometry ({fpay}, {lanes})")
        c = words.shape[1]
        if start < 0 or start + c > n_l:
            raise ValueError(f"chunk [{start}, {start + c}) out of range for "
                             f"layer {layer} with {n_l} packets")
        if c == 0:
            return
        f = fpay + 1                                    # + header flit
        g0 = self.layer_g0[layer]
        gids = g0 + start + np.arange(c, dtype=np.int64)
        mcs = gids % m
        dest = self.pes[gids % len(self.pes)].astype(np.int32)
        vc = ((gids // m) % cfg.num_vcs).astype(np.int32)
        # Rank of each packet among this layer's packets at its MC: packets
        # at one MC are g0+j0, g0+j0+M, ... so rank = (j - j0) // M.
        j = gids - g0
        j0 = (mcs - g0) % m
        rank = (j - j0) // m
        flit0 = self.layer_base[layer][mcs] + rank * f  # (c,) stream offset
        cols = (flit0[:, None] + np.arange(f)[None, :]).reshape(-1)
        rows = np.repeat(mcs, f)

        # Header synthesis: word 0 = dest, 1 = packet id, 2 = payload flits.
        hdr = np.zeros((c, lanes), np.uint32)
        hdr[:, 0] = dest.astype(np.uint32)
        hdr[:, 1] = (gids & 0xFFFFFFFF).astype(np.uint32)
        hdr[:, 2] = fpay
        full = np.empty((self.nv, c, f, lanes), np.uint32)
        full[:, :, 0, :] = hdr[None]
        full[:, :, 1:, :] = words

        # META bitfield: header 0, payload flits PAYLOAD, last flit |= TAIL.
        md = np.full((f,), META_PAYLOAD, np.int32)
        md[0] = 0
        md[-1] |= META_TAIL

        self.words[:, rows, cols] = full.reshape(self.nv, c * f, lanes)
        self.dest[rows, cols] = np.repeat(dest, f)
        self.meta[rows, cols] = np.broadcast_to(md, (c, f)).reshape(-1)
        self.vc[rows, cols] = np.repeat(vc, f)
        self.pkt[rows, cols] = np.repeat(gids.astype(np.int32), f)

    def finish(self) -> Traffic:
        """Batched Traffic over everything scattered so far (empty padding
        streams appended per ``num_streams``)."""
        m, lanes, t = self.cfg.num_mcs, self.cfg.lanes, self.words.shape[2]
        words_arr, lengths = self.words, self.lengths
        dest_arr, meta_arr = self.dest, self.meta
        vc_arr, pkt_arr = self.vc, self.pkt
        if self.num_streams is not None and self.num_streams > m:
            extra = self.num_streams - m
            words_arr = np.concatenate(
                [words_arr, np.zeros((self.nv, extra, t, lanes), np.uint32)],
                axis=1)
            pad2 = ((0, extra), (0, 0))
            dest_arr = np.pad(dest_arr, pad2)
            meta_arr = np.pad(meta_arr, pad2)
            vc_arr = np.pad(vc_arr, pad2)
            pkt_arr = np.pad(pkt_arr, pad2)
            lengths = np.pad(lengths, (0, extra))

        def tile(a):
            return jnp.asarray(np.broadcast_to(a, (self.nv,) + a.shape))

        return Traffic(
            words=jnp.asarray(words_arr), dest=tile(dest_arr),
            meta=tile(meta_arr), vc=tile(vc_arr), pkt=tile(pkt_arr),
            length=tile(lengths.astype(np.int32)),
            num_packets=int(self.layer_g0[-1]))


def assemble_traffic(layer_words: Sequence[np.ndarray],
                     cfg: NocConfig,
                     num_streams: Optional[int] = None,
                     num_variants: Optional[int] = None) -> Traffic:
    """Scatter per-layer (B, n, F, L) payloads into batched per-MC streams.

    All variants share the packetization skeleton (headers, META bitfields,
    MC/PE/VC round-robin, per-MC scatter layout): an ordering transform only
    permutes values within a packet and a quantizer only narrows them, so
    the flit geometry - and therefore dest/meta/vc/pkt/length - is variant-
    independent. Only the payload words differ per variant. The result
    feeds :func:`repro.noc.sim.simulate_batch` directly.

    num_streams: pad the MC-stream axis to this count with empty streams
        (packets still round-robin over the config's real MCs). The sweep
        engine pads every placement of one mesh size to a common count so
        they share a single compiled simulator.
    num_variants: the variants-axis size when ``layer_words`` is empty (it
        is otherwise read off the payload arrays).
    """
    nv = layer_words[0].shape[0] if layer_words else (num_variants or 1)
    for words_v in layer_words:
        if words_v.shape[3] != cfg.lanes:
            raise ValueError(f"payloads built for {words_v.shape[3]} lanes, "
                             f"config has {cfg.lanes}")
    asm = TrafficAssembler([(w.shape[1], w.shape[2]) for w in layer_words],
                           cfg, num_streams=num_streams, num_variants=nv)
    for li, words_v in enumerate(layer_words):
        asm.add_chunk(li, 0, words_v)
    return asm.finish()


def build_traffic_streamed(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    variants: Sequence[Variant],
    *,
    chunk_packets: int = 4096,
    num_streams: Optional[int] = None,
    max_packets_per_layer: Optional[int] = None,
    shapes: Optional[Sequence[Tuple[int, int]]] = None,
) -> Traffic:
    """Packetize full (DarkNet-scale) layers in fixed-size packet chunks.

    Bit-identical to ``build_traffic_batch`` (property-tested in
    tests/test_noc_stream.py) with a bounded packetization working set: the
    one-shot path materializes every layer's (B, n, F, L) payload tensor
    *plus* the transform's whole-layer intermediates before assembling,
    while this path holds one (B, chunk_packets, F, L) block at a time and
    scatters it straight into its final stream location. The dense output
    Traffic is the same either way - it is the input to the simulator.

    shapes: precomputed :func:`payload_shapes` result for these layers /
        variants (the sweep engine already has it for padding); probed here
        when omitted.
    """
    if shapes is None:
        shapes = payload_shapes(layers, cfg.lanes, variants,
                                max_packets_per_layer=max_packets_per_layer)
    asm = TrafficAssembler(shapes, cfg, num_streams=num_streams,
                           num_variants=len(variants))
    for li, start, words in ordered_payloads_streamed(
            layers, cfg.lanes, variants, chunk_packets=chunk_packets,
            max_packets_per_layer=max_packets_per_layer):
        asm.add_chunk(li, start, words)
    return asm.finish()


def build_traffic_batch(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
) -> Traffic:
    """Packetize ``layers`` once per (transform, quantizer) variant into a
    batched Traffic with a leading variants axis (see
    :func:`ordered_payloads` / :func:`assemble_traffic`)."""
    payloads = ordered_payloads(layers, cfg.lanes, variants,
                                max_packets_per_layer=max_packets_per_layer)
    return assemble_traffic(payloads, cfg, num_variants=len(variants))


def build_traffic(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    transform: WireTransform,
    *,
    quantizer=None,
    max_packets_per_layer: Optional[int] = None,
) -> Traffic:
    """Packetize layers under a WireTransform into per-MC injection streams.

    quantizer: optional value -> wire-dtype map (e.g. fixed-8 quantization);
        default transmits raw float32 words.
    max_packets_per_layer: subsample neurons (deterministic stride) to bound
        simulation time; BT rates are per-flit so subsampling is unbiased.

    Bit-identical to the seed loop implementation (pinned by the equivalence
    regression test against ``repro.noc._reference``).
    """
    batch = build_traffic_batch(layers, cfg, [(transform, quantizer)],
                                max_packets_per_layer=max_packets_per_layer)
    return batch.variant(0)
