"""DNN layer traffic -> packetized flit streams for the NoC simulator.

Models the paper's NOC-DNA dataflow (Fig. 7): memory controllers fetch
(input, weight) operand streams from off-chip memory, run them through the
ordering unit (a WireTransform), packetize into flits - inputs in the left
half-flit, weights in the right (Fig. 2) - and inject toward the PE assigned
to each neuron computation.

A *packet* carries the operands for one neuron (one output position x output
channel for conv; one output unit for linear): K (input, weight) pairs plus
one header flit. The ordering window is the packet payload, matching the
paper's ordering-unit-per-MC placement (it sees one packet at a time).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wire import WireTransform
from repro.core.flits import pack_paired
from .topology import NocConfig
from .sim import Traffic, META_PAYLOAD, META_TAIL

__all__ = ["LayerTraffic", "build_traffic", "conv_layer_traffic",
           "linear_layer_traffic"]


@dataclasses.dataclass
class LayerTraffic:
    """(input, weight) operand pairs for every neuron of one layer.

    inputs:  (num_neurons, k) - receptive-field values per neuron
    weights: (num_neurons, k) - the matching kernel values
    """

    inputs: jax.Array
    weights: jax.Array

    def __post_init__(self):
        if self.inputs.shape != self.weights.shape:
            raise ValueError("inputs/weights must be (num_neurons, k) alike")


def conv_layer_traffic(x: jax.Array, w: jax.Array) -> LayerTraffic:
    """im2col a conv layer: x (H, W, Cin), w (kh, kw, Cin, Cout), VALID conv.

    Neuron = (output position, output channel); k = kh*kw*Cin.
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x[None].astype(jnp.float32), (kh, kw), (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    oh, ow, k = patches.shape
    patches = patches.reshape(oh * ow, k).astype(x.dtype)
    wcol = w.reshape(k, cout).T                      # (Cout, k)
    # neuron ordering: all positions of channel 0, then channel 1, ...
    inputs = jnp.tile(patches, (cout, 1))
    weights = jnp.repeat(wcol, oh * ow, axis=0)
    return LayerTraffic(inputs, weights)


def linear_layer_traffic(x: jax.Array, w: jax.Array) -> LayerTraffic:
    """x (k,), w (out, k): one packet per output unit."""
    out, k = w.shape
    inputs = jnp.broadcast_to(x[None, :], (out, k))
    return LayerTraffic(inputs, w)


def _header_word(dest: int, pkt_id: int, n_payload: int, lanes: int) -> np.ndarray:
    h = np.zeros((lanes,), np.uint32)
    h[0], h[1], h[2] = dest, pkt_id & 0xFFFFFFFF, n_payload
    return h


def build_traffic(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    transform: WireTransform,
    *,
    quantizer=None,
    max_packets_per_layer: Optional[int] = None,
) -> Traffic:
    """Packetize layers under a WireTransform into per-MC injection streams.

    quantizer: optional value -> wire-dtype map (e.g. fixed-8 quantization);
        default transmits raw float32 words.
    max_packets_per_layer: subsample neurons (deterministic stride) to bound
        simulation time; BT rates are per-flit so subsampling is unbiased.
    """
    m = cfg.num_mcs
    pes = np.asarray(cfg.pe_nodes, np.int32)
    streams: List[List[np.ndarray]] = [[] for _ in range(m)]     # words
    meta: List[List[np.ndarray]] = [[] for _ in range(m)]        # (dest, meta, vc, pkt)
    vc_rr = [0] * m
    pkt_id = 0
    pe_rr = 0

    for layer in layers:
        inp, wgt = layer.inputs, layer.weights
        n = int(inp.shape[0])
        if max_packets_per_layer is not None and n > max_packets_per_layer:
            stride = n // max_packets_per_layer
            idx = jnp.arange(0, stride * max_packets_per_layer, stride)
            inp, wgt = inp[idx], wgt[idx]
            n = int(inp.shape[0])
        if quantizer is not None:
            inp, wgt = quantizer(inp), quantizer(wgt)
        # Apply the ordering transform per packet, vectorized over neurons.
        def one_packet(i, w):
            stream = transform.apply(i, w, cfg.lanes)
            return stream.words
        words = jax.vmap(one_packet)(inp, wgt)      # (n, F, L)
        words = np.asarray(words.astype(jnp.uint32))
        n_flits = words.shape[1]
        for j in range(n):
            mc = (pkt_id % m)
            dest = int(pes[pe_rr % len(pes)])
            pe_rr += 1
            header = _header_word(dest, pkt_id, n_flits, cfg.lanes)
            pkt_words = np.concatenate([header[None], words[j]], axis=0)
            f = pkt_words.shape[0]
            md = np.full((f,), META_PAYLOAD, np.int32)
            md[0] = 0
            md[-1] |= META_TAIL
            vc = vc_rr[mc] % cfg.num_vcs
            vc_rr[mc] += 1
            streams[mc].append(pkt_words)
            meta[mc].append(np.stack([
                np.full((f,), dest, np.int32),
                md,
                np.full((f,), vc, np.int32),
                np.full((f,), pkt_id, np.int32)], axis=1))
            pkt_id += 1

    lengths = np.array([sum(len(x) for x in s) for s in streams], np.int32)
    t = int(lengths.max()) if len(lengths) else 0
    l = cfg.lanes
    words_arr = np.zeros((m, t, l), np.uint32)
    dest_arr = np.zeros((m, t), np.int32)
    meta_arr = np.zeros((m, t), np.int32)
    vc_arr = np.zeros((m, t), np.int32)
    pkt_arr = np.zeros((m, t), np.int32)
    for mc in range(m):
        if not streams[mc]:
            continue
        w = np.concatenate(streams[mc], axis=0)
        md = np.concatenate(meta[mc], axis=0)
        words_arr[mc, :w.shape[0]] = w
        dest_arr[mc, :w.shape[0]] = md[:, 0]
        meta_arr[mc, :w.shape[0]] = md[:, 1]
        vc_arr[mc, :w.shape[0]] = md[:, 2]
        pkt_arr[mc, :w.shape[0]] = md[:, 3]
    return Traffic(
        words=jnp.asarray(words_arr), dest=jnp.asarray(dest_arr),
        meta=jnp.asarray(meta_arr), vc=jnp.asarray(vc_arr),
        pkt=jnp.asarray(pkt_arr), length=jnp.asarray(lengths))
