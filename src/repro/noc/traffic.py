"""DNN layer traffic -> packetized flit streams for the NoC simulator.

Models the paper's NOC-DNA dataflow (Fig. 7): memory controllers fetch
(input, weight) operand streams from off-chip memory, run them through the
ordering unit (a WireTransform), packetize into flits - inputs in the left
half-flit, weights in the right (Fig. 2) - and inject toward the PE assigned
to each neuron computation.

A *packet* carries the operands for one neuron (one output position x output
channel for conv; one output unit for linear): K (input, weight) pairs plus
one header flit. The ordering window is the packet payload, matching the
paper's ordering-unit-per-MC placement (it sees one packet at a time).

Packetization is fully vectorized (the seed's per-neuron Python loop lives
on only as the equivalence oracle in ``repro.noc._reference``): the MC/PE/VC
assignments are closed-form functions of the global packet id (round-robin,
or a periodic packet->MC affinity table - see ``_McSchedule``), header
words and META bitfields are synthesized as arrays, the ordering transform
is applied via one ``vmap`` per layer, and per-MC streams are written with
one scatter per layer. ``build_traffic_batch`` additionally shares all of
that skeleton work across ordering/precision variants, which only differ
in payload words.

The return direction is modeled too: :func:`build_result_traffic`
packetizes the PE->MC *result phase* (one MAC value per request packet,
grouped into per-(PE, MC) result windows and ordered by the same
WireTransforms via ``apply_single``); see DESIGN.md "Result phase".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import msr
from repro.core.wire import (COMPRESSIONS, WireTransform,
                             compression_overhead_bits)
from .topology import NocConfig
from .sim import Traffic, META_PAYLOAD, META_TAIL

__all__ = ["LayerTraffic", "build_traffic", "build_traffic_batch",
           "build_traffic_streamed", "build_traffic_streamed_multi",
           "build_result_traffic", "layer_results",
           "result_values", "ordered_payloads", "ordered_payloads_streamed",
           "payload_shapes", "assemble_traffic", "TrafficAssembler",
           "stream_lengths", "pad_traffic_length", "stack_traffics",
           "concat_inferences", "filter_packets", "conv_layer_traffic",
           "linear_layer_traffic", "DEFAULT_RESULT_WINDOW",
           "COMPRESSIONS", "compression_overhead"]

# One sweep variant: an ordering transform plus an optional value->wire-dtype
# quantizer (None transmits raw float32 words).
Variant = Tuple[WireTransform, Optional[Callable[[jax.Array], jax.Array]]]


@dataclasses.dataclass
class LayerTraffic:
    """(input, weight) operand pairs for every neuron of one layer.

    inputs:  (num_neurons, k) - receptive-field values per neuron
    weights: (num_neurons, k) - the matching kernel values
    """

    inputs: jax.Array
    weights: jax.Array

    def __post_init__(self):
        if self.inputs.shape != self.weights.shape:
            raise ValueError("inputs/weights must be (num_neurons, k) alike")


def conv_layer_traffic(x: jax.Array, w: jax.Array) -> LayerTraffic:
    """im2col a conv layer: x (H, W, Cin), w (kh, kw, Cin, Cout), VALID conv.

    Neuron = (output position, output channel); k = kh*kw*Cin.
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x[None].astype(jnp.float32), (kh, kw), (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    oh, ow, k = patches.shape
    patches = patches.reshape(oh * ow, k).astype(x.dtype)
    wcol = w.reshape(k, cout).T                      # (Cout, k)
    # neuron ordering: all positions of channel 0, then channel 1, ...
    inputs = jnp.tile(patches, (cout, 1))
    weights = jnp.repeat(wcol, oh * ow, axis=0)
    return LayerTraffic(inputs, weights)


def linear_layer_traffic(x: jax.Array, w: jax.Array) -> LayerTraffic:
    """x (k,), w (out, k): one packet per output unit."""
    out, k = w.shape
    inputs = jnp.broadcast_to(x[None, :], (out, k))
    return LayerTraffic(inputs, w)


def _subsample(layer: LayerTraffic,
               max_packets: Optional[int]) -> Tuple[jax.Array, jax.Array]:
    """Deterministic-stride neuron subsampling (BT rates are per-flit, so
    subsampling is unbiased); identical to the seed packetizer's."""
    inp, wgt = layer.inputs, layer.weights
    n = int(inp.shape[0])
    if max_packets is not None and n > max_packets:
        stride = n // max_packets
        idx = jnp.arange(0, stride * max_packets, stride)
        inp, wgt = inp[idx], wgt[idx]
    return inp, wgt


def _check_compression(compression: str) -> None:
    if compression not in COMPRESSIONS:
        raise ValueError(f"unknown compression {compression!r}; "
                         f"supported: {COMPRESSIONS}")


def _packet_words(transform: WireTransform, i: jax.Array, w: jax.Array,
                  lanes: int, compression: str) -> jax.Array:
    """One packet's payload words under (transform, compression).

    ``none`` is the transform's own packer (``apply``, bit-identical to the
    pre-compression path). ``msr`` reuses the exact same value ordering
    (``WireTransform.order``) but packs the 8b values as dense 5-bit MSR
    codes - fewer flits per packet, data-independent geometry (escape
    metadata is charged analytically, never materialized on the lanes)."""
    if compression == "none":
        return transform.apply(i, w, lanes).words
    oi, ow = transform.order(i, w, lanes)
    return msr.msr_pack_paired(oi, ow, lanes).words


@functools.lru_cache(maxsize=None)
def _packet_fn(transform: WireTransform, lanes: int,
               compression: str = "none"):
    """Vmapped packet transform, memoized per (transform, lanes,
    compression).

    WireTransforms are frozen dataclasses, so they key the cache. The vmap
    is deliberately left un-jitted: its primitives (argsort, gathers,
    bitcasts) hit JAX's per-primitive executable cache, which the rest of
    the stack shares, whereas a whole-program jit would recompile per
    (transform, layer shape) combination - measurably slower for the one
    pass per model a sweep performs."""

    def one_packet(i, w):
        return _packet_words(transform, i, w, lanes, compression)

    return jax.vmap(one_packet)


def _payload_words(inp: jax.Array, wgt: jax.Array, transform: WireTransform,
                   quantizer, lanes: int,
                   compression: str = "none") -> np.ndarray:
    """Ordered payload flits for every neuron of one layer: (n, F, L) u32.

    One vmap over neurons applies the WireTransform packet-by-packet (the
    ordering window is the packet payload)."""
    if quantizer is not None:
        inp, wgt = quantizer(inp), quantizer(wgt)
    words = _packet_fn(transform, lanes, compression)(inp, wgt)
    return np.asarray(words.astype(jnp.uint32))


def ordered_payloads(
    layers: Sequence[LayerTraffic],
    lanes: int,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
    compression: str = "none",
) -> List[np.ndarray]:
    """Ordered payload words per layer, stacked over variants: (B, n, F, L).

    This is the mesh-independent half of packetization (the transform sees
    only packet payloads and the flit width); the sweep engine computes it
    once per model and re-assembles it for every mesh / MC-count cell via
    :func:`assemble_traffic`. ``compression="msr"`` packs each ordered
    packet through the MSR codec instead of the raw 8b packer - fewer
    payload flits per packet, same data-independent geometry contract.
    """
    if not variants:
        raise ValueError("need at least one (transform, quantizer) variant")
    _check_compression(compression)
    out: List[np.ndarray] = []
    for layer in layers:
        inp, wgt = _subsample(layer, max_packets_per_layer)
        if inp.shape[0] == 0:
            # Probe the geometry instead of transforming nothing - a
            # quantizer's scale reduction has no identity on empty operands.
            (_, fpay), = payload_shapes([layer], lanes, variants,
                                        compression=compression)
            out.append(np.zeros((len(variants), 0, fpay, lanes), np.uint32))
            continue
        per_variant = [_payload_words(inp, wgt, tr, q, lanes, compression)
                       for tr, q in variants]
        shapes = {w.shape for w in per_variant}
        if len(shapes) != 1:
            raise ValueError(
                f"variants disagree on flit geometry: {sorted(shapes)}")
        out.append(np.stack(per_variant))
    return out


@functools.lru_cache(maxsize=None)
def _packet_chunk_fn(transform: WireTransform, lanes: int,
                     compression: str = "none"):
    """Jitted wrapper of :func:`_packet_fn` for the streamed path.

    One whole-program compile per (transform, lanes, compression, chunk
    shape) that every chunk of every layer with that operand width reuses -
    the streamed packetizer pads its ragged final chunk up to the fixed
    chunk size precisely so this executable is hit on every call. Wrapping
    the shared vmap keeps the one-shot and streamed paths on a single
    transform kernel.
    """
    fn = _packet_fn(transform, lanes, compression)
    return jax.jit(lambda i, w: fn(i, w).astype(jnp.uint32))


def payload_shapes(
    layers: Sequence[LayerTraffic],
    lanes: int,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
    compression: str = "none",
) -> List[Tuple[int, int]]:
    """Per-layer ``(n_packets, payload_flits)`` without materializing any
    payloads: the flit geometry is probed on a single packet per variant.

    Lets the streamed path (and the sweep engine's stream-length padding)
    size everything up front at O(1) cost per layer. The probe holds under
    compression because MSR flit geometry is a pure function of the operand
    width (fixed 5-bit codes; escapes ride the sideband).
    """
    if not variants:
        raise ValueError("need at least one (transform, quantizer) variant")
    _check_compression(compression)
    out: List[Tuple[int, int]] = []
    for layer in layers:
        inp, wgt = _subsample(layer, max_packets_per_layer)
        # Geometry depends only on the operand width k, so a zero-packet
        # layer is probed with a dummy packet (matching the one-shot path,
        # which emits a (B, 0, F, L) array for it).
        i1, w1 = (inp[:1], wgt[:1]) if inp.shape[0] else (
            jnp.zeros((1,) + inp.shape[1:], inp.dtype),
            jnp.zeros((1,) + wgt.shape[1:], wgt.dtype))
        shapes = set()
        for tr, q in variants:
            i0, w0 = (i1, w1) if q is None else (q(i1), q(w1))
            shapes.add(tuple(_packet_words(tr, i0[0], w0[0], lanes,
                                           compression).shape))
        if len(shapes) != 1:
            raise ValueError(
                f"variants disagree on flit geometry: {sorted(shapes)}")
        (fpay, _), = shapes
        out.append((int(inp.shape[0]), int(fpay)))
    return out


def ordered_payloads_streamed(
    layers: Sequence[LayerTraffic],
    lanes: int,
    variants: Sequence[Variant],
    *,
    chunk_packets: int = 4096,
    max_packets_per_layer: Optional[int] = None,
    compression: str = "none",
):
    """Generator form of :func:`ordered_payloads` with bounded working set.

    Yields ``(layer_index, start_packet, words)`` where ``words`` is the
    ``(B, c, F, L)`` uint32 payload block for packets
    ``[start, start + c)`` of that layer, ``c <= chunk_packets``.
    Concatenating a layer's chunks is bit-identical to the one-shot path:
    quantizers are applied to the *whole* layer first (a fixed-point scale
    must not depend on the chunking), and the transform - per-packet by
    construction - runs through one jit-cached vmap whose ragged final
    chunk is zero-padded to the fixed chunk shape and sliced back.
    """
    if not variants:
        raise ValueError("need at least one (transform, quantizer) variant")
    if chunk_packets < 1:
        raise ValueError(f"chunk_packets must be >= 1, got {chunk_packets}")
    _check_compression(compression)
    for li, layer in enumerate(layers):
        inp, wgt = _subsample(layer, max_packets_per_layer)
        n = int(inp.shape[0])
        if n == 0:          # nothing to order or scatter for an empty layer
            continue
        ops = [(inp, wgt) if q is None else (q(inp), q(wgt))
               for _, q in variants]
        for start in range(0, n, chunk_packets):
            c = min(chunk_packets, n - start)
            per_variant = []
            for (tr, _), (qi, qw) in zip(variants, ops):
                ci, cw = qi[start:start + c], qw[start:start + c]
                if c < chunk_packets:
                    pad = ((0, chunk_packets - c), (0, 0))
                    ci, cw = jnp.pad(ci, pad), jnp.pad(cw, pad)
                words = _packet_chunk_fn(tr, lanes, compression)(ci, cw)
                per_variant.append(np.asarray(words)[:c])
            shapes = {w.shape for w in per_variant}
            if len(shapes) != 1:
                raise ValueError(
                    f"variants disagree on flit geometry: {sorted(shapes)}")
            yield li, start, np.stack(per_variant)


class _McSchedule:
    """Closed-form packet->MC schedule, elementwise in the global packet id.

    ``mc_table=None`` is the seed round-robin (``mc(g) = g % M``); an
    explicit table is Q-periodic: ``mc(g) = table[g % Q]``. The affinity
    path uses ``Q = num_pes`` (``topology.affinity_mc_table``), so a
    packet's serving MC follows its destination PE. Every quantity the
    assembler needs - the serving MC, and the number of earlier packets at
    that MC (which fixes the VC and the stream offset) - stays an
    elementwise function of ``g``, preserving chunk-decomposability.
    """

    def __init__(self, m: int, mc_table=None):
        if mc_table is None:
            tbl = np.arange(m, dtype=np.int64)
        else:
            tbl = np.asarray(mc_table, np.int64)
            if tbl.ndim != 1 or not tbl.size:
                raise ValueError("mc_table must be a non-empty 1-D array")
            if tbl.min() < 0 or tbl.max() >= m:
                raise ValueError(
                    f"mc_table entries must be MC stream indices in [0, {m})")
        self.m, self.q, self.tbl = m, len(tbl), tbl
        self.cnt = np.bincount(tbl, minlength=m).astype(np.int64)
        onehot = np.zeros((len(tbl) + 1, m), np.int64)
        onehot[np.arange(1, len(tbl) + 1), tbl] = 1
        self.cum = np.cumsum(onehot, axis=0)                 # (Q+1, M)

    def mc(self, g):
        """Serving-MC stream index of packet(s) ``g``."""
        return self.tbl[g % self.q]

    def before(self, g):
        """``#{g' < g : mc(g') == mc(g)}`` - earlier packets at g's MC."""
        mc = self.tbl[g % self.q]
        return (g // self.q) * self.cnt[mc] + self.cum[g % self.q, mc]

    def counts_before(self, g: int) -> np.ndarray:
        """Per-MC packet counts over ``[0, g)`` - an ``(M,)`` vector."""
        return (g // self.q) * self.cnt + self.cum[g % self.q]


def stream_lengths(layer_shapes: Sequence[Tuple[int, int]],
                   m: int, mc_table=None) -> np.ndarray:
    """Per-MC flit counts for layers of ``(n_packets, payload_flits)``.

    Closed-form: packets are dealt over the ``m`` MCs - round-robin by
    default, or by a periodic affinity ``mc_table`` (see
    :func:`repro.noc.topology.affinity_mc_table`) - each contributing its
    payload plus one header flit. Lets the sweep engine size stream
    padding without materializing any traffic.
    """
    sched = _McSchedule(m, mc_table)
    lengths = np.zeros(m, np.int64)
    g0 = 0
    for n, fpay in layer_shapes:
        counts = sched.counts_before(g0 + n) - sched.counts_before(g0)
        lengths += counts * (fpay + 1)
        g0 += n
    return lengths


def pad_traffic_length(traffic: Traffic, t: int) -> Traffic:
    """Pad the per-MC stream axis T with empty flits.

    Padding beyond ``length`` is never injected, so this only changes array
    shapes - the sweep engine uses it (with MC-stream padding) to give every
    MC placement of one mesh size identical traffic shapes, and therefore
    one shared compiled simulator.
    """
    cur = int(traffic.words.shape[-2])
    if t <= cur:
        return traffic
    extra = t - cur

    def pad_last(a):
        widths = [(0, 0)] * (a.ndim - 1) + [(0, extra)]
        return jnp.asarray(np.pad(np.asarray(a), widths))

    words = np.pad(np.asarray(traffic.words),
                   [(0, 0)] * (traffic.words.ndim - 2) + [(0, extra), (0, 0)])
    return traffic._replace(
        words=jnp.asarray(words), dest=pad_last(traffic.dest),
        meta=pad_last(traffic.meta), vc=pad_last(traffic.vc),
        pkt=pad_last(traffic.pkt))


def concat_inferences(traffic: Traffic, n: int) -> Traffic:
    """Replicate a single-inference Traffic ``n`` times back-to-back.

    Inference k's flits immediately follow inference k-1's within every
    stream (the injector walks streams contiguously), and packet ids are
    offset by ``k * num_packets`` so the per-inference conservation and
    timestamp ledgers stay disjoint - the closed-loop serving model
    (``repro.noc.online``) gates each inference's slice with its own
    release cycle. Unbatched Traffic with known ``num_packets`` only; the
    word values are replicated verbatim, so per-stream NI sequences are the
    single-inference sequences repeated (seam transitions between
    consecutive inferences included).
    """
    if traffic.length.ndim != 1:
        raise ValueError("concat_inferences wants an unbatched Traffic "
                         "(use .variant(i) on a batched one)")
    npkt = int(traffic.num_packets)
    if npkt < 0:
        raise ValueError("concat_inferences needs num_packets metadata "
                         "(hand-built Traffic must set it)")
    if n < 1:
        raise ValueError(f"need n >= 1 inferences, got {n}")
    if n == 1:
        return traffic
    lengths = np.asarray(traffic.length, np.int64)
    m = lengths.shape[0]
    lanes = traffic.words.shape[-1]
    t2 = int(lengths.max()) * n if m else 0
    words = np.asarray(traffic.words)
    dest = np.asarray(traffic.dest)
    meta = np.asarray(traffic.meta)
    vc = np.asarray(traffic.vc)
    pkt = np.asarray(traffic.pkt)
    w2 = np.zeros((m, t2, lanes), np.uint32)
    d2 = np.zeros((m, t2), np.int32)
    me2 = np.zeros((m, t2), np.int32)
    v2 = np.zeros((m, t2), np.int32)
    p2 = np.zeros((m, t2), np.int32)
    for mi in range(m):
        ln = int(lengths[mi])
        if not ln:
            continue
        w2[mi, :n * ln] = np.tile(words[mi, :ln], (n, 1))
        d2[mi, :n * ln] = np.tile(dest[mi, :ln], n)
        me2[mi, :n * ln] = np.tile(meta[mi, :ln], n)
        v2[mi, :n * ln] = np.tile(vc[mi, :ln], n)
        p2[mi, :n * ln] = (np.tile(pkt[mi, :ln], n)
                           + np.repeat(np.arange(n, dtype=np.int64), ln)
                           * npkt)
    return Traffic(
        words=jnp.asarray(w2), dest=jnp.asarray(d2), meta=jnp.asarray(me2),
        vc=jnp.asarray(v2), pkt=jnp.asarray(p2),
        length=jnp.asarray((lengths * n).astype(np.int32)),
        num_packets=npkt * n)


def filter_packets(traffic: Traffic, keep_ids) -> Traffic:
    """Keep only the flits of the given packet ids, compacting each stream.

    ``keep_ids``: packet ids to retain (array-like of ints, or a boolean
    mask of length ``num_packets``). Flits of other packets are removed
    and each stream's survivors slide forward in original order (the NI
    walks streams contiguously); ``length`` shrinks accordingly, the tail
    is zeroed padding, and ``num_packets`` is preserved - surviving
    packets keep their ids/ledger slots. The fault-injection layer uses
    this twice: to drop packets to unreachable destinations before
    injection, and to build retransmission traffic from the original
    (clean) flits of detected-corrupt packets. Unbatched Traffic only.
    """
    if traffic.length.ndim != 1:
        raise ValueError("filter_packets wants an unbatched Traffic "
                         "(use .variant(i) on a batched one)")
    npkt = int(traffic.num_packets)
    if npkt < 0:
        raise ValueError("filter_packets needs num_packets metadata "
                         "(hand-built Traffic must set it)")
    keep_ids = np.asarray(keep_ids)
    if keep_ids.dtype == bool:
        keep_pkt = keep_ids
        if keep_pkt.shape != (npkt,):
            raise ValueError(f"boolean keep mask must have shape ({npkt},), "
                             f"got {keep_pkt.shape}")
    else:
        keep_pkt = np.zeros(npkt, bool)
        keep_pkt[keep_ids.astype(np.int64)] = True
    lengths = np.asarray(traffic.length, np.int64)
    m, t = np.asarray(traffic.meta).shape
    valid = np.arange(t)[None, :] < lengths[:, None]
    pkt = np.asarray(traffic.pkt)
    keep = valid & keep_pkt[np.clip(pkt, 0, npkt - 1)]
    # Stable compaction: kept flits first, original order preserved.
    order = np.argsort(~keep, axis=1, kind="stable")
    rows = np.arange(m)[:, None]
    new_len = keep.sum(axis=1).astype(np.int32)
    live = np.arange(t)[None, :] < new_len[:, None]

    def take(a, fill=0):
        out = np.asarray(a)[rows, order]
        return np.where(live, out, fill)

    words = np.asarray(traffic.words)[rows, order]      # (M, T, L) rows move
    words = np.where(live[..., None], words, 0).astype(np.uint32)
    return Traffic(
        words=jnp.asarray(words),
        dest=jnp.asarray(take(traffic.dest).astype(np.int32)),
        meta=jnp.asarray(take(traffic.meta).astype(np.int32)),
        vc=jnp.asarray(take(traffic.vc).astype(np.int32)),
        pkt=jnp.asarray(take(traffic.pkt).astype(np.int32)),
        length=jnp.asarray(new_len),
        num_packets=npkt)


def stack_traffics(traffics: Sequence[Traffic]) -> Traffic:
    """Stack single (unbatched) Traffics into one batched Traffic.

    The lanes may carry different real lengths (each keeps its ``length``
    row - this is how heterogeneous-drain batches for the retirement
    scheduler are built); their stream axes are padded to the longest T
    first. ``num_packets`` becomes the max, which is what the conservation
    ledger needs to cover every lane.
    """
    if not traffics:
        raise ValueError("need at least one Traffic to stack")
    t = max(int(tr.words.shape[-2]) for tr in traffics)
    traffics = [pad_traffic_length(tr, t) for tr in traffics]
    return Traffic(
        words=jnp.stack([tr.words for tr in traffics]),
        dest=jnp.stack([tr.dest for tr in traffics]),
        meta=jnp.stack([tr.meta for tr in traffics]),
        vc=jnp.stack([tr.vc for tr in traffics]),
        pkt=jnp.stack([tr.pkt for tr in traffics]),
        length=jnp.stack([tr.length for tr in traffics]),
        num_packets=max(int(tr.num_packets) for tr in traffics))


class TrafficAssembler:
    """Incremental per-MC stream writer - the scatter half of packetization,
    shared verbatim by the one-shot (:func:`assemble_traffic`) and streamed
    (:func:`build_traffic_streamed`) paths, so the two are bit-identical by
    construction.

    Closed-form skeleton. With global packet id g (consecutive across
    layers), the seed loop's bookkeeping collapses to
        mc(g)   = g % M                 (packet round-robin over MCs, or an
                                         affinity ``mc_table`` lookup)
        dest(g) = pes[g % num_pes]      (pe_rr increments once per packet)
        vc(g)   = before(g) % V         (vc_rr[mc] counts packets at mc;
                                         = (g // M) % V for round-robin)
    and a packet's flit offset inside its MC stream is the running flit
    count of earlier packets at that MC. Every quantity is elementwise in
    g, so a layer may arrive in any number of packet chunks: each chunk
    scatters into its final location independently.
    """

    def __init__(self, layer_shapes: Sequence[Tuple[int, int]],
                 cfg: NocConfig, num_streams: Optional[int] = None,
                 num_variants: int = 1, mc_table=None):
        m, lanes = cfg.num_mcs, cfg.lanes
        if num_streams is not None and num_streams < m:
            raise ValueError(
                f"cannot pad {m} MC streams down to {num_streams}")
        self.cfg = cfg
        self.nv = num_variants
        self.num_streams = num_streams
        self.shapes = [(int(n), int(f)) for n, f in layer_shapes]
        self.pes = np.asarray(cfg.pe_nodes, np.int64)
        self.sched = _McSchedule(m, mc_table)
        # Per-layer global packet offset, per-MC flit base and per-MC packet
        # count at layer start.
        ns = [n for n, _ in self.shapes]
        self.layer_g0 = np.concatenate(
            [[0], np.cumsum(ns)]).astype(np.int64)
        self.layer_cb = [self.sched.counts_before(int(g0))
                         for g0 in self.layer_g0]
        self.layer_base = [np.zeros(m, np.int64)]
        lengths = np.zeros(m, np.int64)
        for (n, fpay), cb0, cb1 in zip(self.shapes, self.layer_cb,
                                       self.layer_cb[1:]):
            lengths = lengths + (cb1 - cb0) * (fpay + 1)
            self.layer_base.append(lengths.copy())
        self.lengths = lengths
        t = int(lengths.max()) if m else 0
        self.words = np.zeros((self.nv, m, t, lanes), np.uint32)
        self.dest = np.zeros((m, t), np.int32)
        self.meta = np.zeros((m, t), np.int32)
        self.vc = np.zeros((m, t), np.int32)
        self.pkt = np.zeros((m, t), np.int32)

    def add_chunk(self, layer: int, start: int, words: np.ndarray) -> None:
        """Scatter payload ``words`` (B, c, F, L) for packets
        ``[start, start + c)`` of ``layer`` into the per-MC streams."""
        cfg, lanes = self.cfg, self.cfg.lanes
        n_l, fpay = self.shapes[layer]
        if words.shape[0] != self.nv:
            raise ValueError(f"payload chunk has {words.shape[0]} variants, "
                             f"assembler was sized for {self.nv}")
        if words.shape[2] != fpay or words.shape[3] != lanes:
            raise ValueError(
                f"payload chunk {words.shape[2:]} does not match layer "
                f"{layer} geometry ({fpay}, {lanes})")
        c = words.shape[1]
        if start < 0 or start + c > n_l:
            raise ValueError(f"chunk [{start}, {start + c}) out of range for "
                             f"layer {layer} with {n_l} packets")
        if c == 0:
            return
        f = fpay + 1                                    # + header flit
        g0 = self.layer_g0[layer]
        gids = g0 + start + np.arange(c, dtype=np.int64)
        mcs = self.sched.mc(gids)
        dest = self.pes[gids % len(self.pes)].astype(np.int32)
        before = self.sched.before(gids)
        vc = (before % cfg.num_vcs).astype(np.int32)
        # Rank of each packet among this layer's packets at its MC: earlier
        # packets at the MC minus the count at layer start.
        rank = before - self.layer_cb[layer][mcs]
        flit0 = self.layer_base[layer][mcs] + rank * f  # (c,) stream offset
        cols = (flit0[:, None] + np.arange(f)[None, :]).reshape(-1)
        rows = np.repeat(mcs, f)

        # Header synthesis: word 0 = dest, 1 = packet id, 2 = payload flits.
        hdr = np.zeros((c, lanes), np.uint32)
        hdr[:, 0] = dest.astype(np.uint32)
        hdr[:, 1] = (gids & 0xFFFFFFFF).astype(np.uint32)
        hdr[:, 2] = fpay
        full = np.empty((self.nv, c, f, lanes), np.uint32)
        full[:, :, 0, :] = hdr[None]
        full[:, :, 1:, :] = words

        # META bitfield: header 0, payload flits PAYLOAD, last flit |= TAIL.
        md = np.full((f,), META_PAYLOAD, np.int32)
        md[0] = 0
        md[-1] |= META_TAIL

        self.words[:, rows, cols] = full.reshape(self.nv, c * f, lanes)
        self.dest[rows, cols] = np.repeat(dest, f)
        self.meta[rows, cols] = np.broadcast_to(md, (c, f)).reshape(-1)
        self.vc[rows, cols] = np.repeat(vc, f)
        self.pkt[rows, cols] = np.repeat(gids.astype(np.int32), f)

    def finish(self) -> Traffic:
        """Batched Traffic over everything scattered so far (empty padding
        streams appended per ``num_streams``)."""
        m, lanes, t = self.cfg.num_mcs, self.cfg.lanes, self.words.shape[2]
        words_arr, lengths = self.words, self.lengths
        dest_arr, meta_arr = self.dest, self.meta
        vc_arr, pkt_arr = self.vc, self.pkt
        if self.num_streams is not None and self.num_streams > m:
            extra = self.num_streams - m
            words_arr = np.concatenate(
                [words_arr, np.zeros((self.nv, extra, t, lanes), np.uint32)],
                axis=1)
            pad2 = ((0, extra), (0, 0))
            dest_arr = np.pad(dest_arr, pad2)
            meta_arr = np.pad(meta_arr, pad2)
            vc_arr = np.pad(vc_arr, pad2)
            pkt_arr = np.pad(pkt_arr, pad2)
            lengths = np.pad(lengths, (0, extra))

        def tile(a):
            return jnp.asarray(np.broadcast_to(a, (self.nv,) + a.shape))

        return Traffic(
            words=jnp.asarray(words_arr), dest=tile(dest_arr),
            meta=tile(meta_arr), vc=tile(vc_arr), pkt=tile(pkt_arr),
            length=tile(lengths.astype(np.int32)),
            num_packets=int(self.layer_g0[-1]))


def assemble_traffic(layer_words: Sequence[np.ndarray],
                     cfg: NocConfig,
                     num_streams: Optional[int] = None,
                     num_variants: Optional[int] = None,
                     mc_table=None) -> Traffic:
    """Scatter per-layer (B, n, F, L) payloads into batched per-MC streams.

    All variants share the packetization skeleton (headers, META bitfields,
    MC/PE/VC round-robin, per-MC scatter layout): an ordering transform only
    permutes values within a packet and a quantizer only narrows them, so
    the flit geometry - and therefore dest/meta/vc/pkt/length - is variant-
    independent. Only the payload words differ per variant. The result
    feeds :func:`repro.noc.sim.simulate_batch` directly.

    num_streams: pad the MC-stream axis to this count with empty streams
        (packets still round-robin over the config's real MCs). The sweep
        engine pads every placement of one mesh size to a common count so
        they share a single compiled simulator.
    num_variants: the variants-axis size when ``layer_words`` is empty (it
        is otherwise read off the payload arrays).
    mc_table: optional periodic packet->MC assignment (the affinity knob;
        see :func:`repro.noc.topology.affinity_mc_table`). ``None`` keeps
        the seed round-robin deal.
    """
    nv = layer_words[0].shape[0] if layer_words else (num_variants or 1)
    for words_v in layer_words:
        if words_v.shape[3] != cfg.lanes:
            raise ValueError(f"payloads built for {words_v.shape[3]} lanes, "
                             f"config has {cfg.lanes}")
    asm = TrafficAssembler([(w.shape[1], w.shape[2]) for w in layer_words],
                           cfg, num_streams=num_streams, num_variants=nv,
                           mc_table=mc_table)
    for li, words_v in enumerate(layer_words):
        asm.add_chunk(li, 0, words_v)
    return asm.finish()


def build_traffic_streamed(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    variants: Sequence[Variant],
    *,
    chunk_packets: int = 4096,
    num_streams: Optional[int] = None,
    max_packets_per_layer: Optional[int] = None,
    shapes: Optional[Sequence[Tuple[int, int]]] = None,
    mc_table=None,
    compression: str = "none",
) -> Traffic:
    """Packetize full (DarkNet-scale) layers in fixed-size packet chunks.

    Bit-identical to ``build_traffic_batch`` (property-tested in
    tests/test_noc_stream.py) with a bounded packetization working set: the
    one-shot path materializes every layer's (B, n, F, L) payload tensor
    *plus* the transform's whole-layer intermediates before assembling,
    while this path holds one (B, chunk_packets, F, L) block at a time and
    scatters it straight into its final stream location. The dense output
    Traffic is the same either way - it is the input to the simulator.

    shapes: precomputed :func:`payload_shapes` result for these layers /
        variants (the sweep engine already has it for padding); probed here
        when omitted.
    mc_table: optional periodic packet->MC affinity assignment (every
        skeleton quantity stays elementwise in the global packet id, so
        the streamed path supports affinity unchanged).
    """
    return build_traffic_streamed_multi(
        layers, [cfg], variants, chunk_packets=chunk_packets,
        num_streams=num_streams, max_packets_per_layer=max_packets_per_layer,
        shapes=shapes, mc_tables=[mc_table], compression=compression)[0]


def build_traffic_streamed_multi(
    layers: Sequence[LayerTraffic],
    cfgs: Sequence[NocConfig],
    variants: Sequence[Variant],
    *,
    chunk_packets: int = 4096,
    num_streams: Optional[int] = None,
    max_packets_per_layer: Optional[int] = None,
    shapes: Optional[Sequence[Tuple[int, int]]] = None,
    mc_tables: Optional[Sequence] = None,
    compression: str = "none",
) -> List[Traffic]:
    """Streamed packetization for SEVERAL (config, mc_table) combos at once.

    Ordering dominates streamed packetization and is mesh-independent (the
    transform sees only packet payloads and the flit width), so one
    :func:`ordered_payloads_streamed` pass feeds every combo's assembler -
    each chunk is ordered once and scattered into all N stream layouts,
    instead of the N full re-ordering passes N separate
    :func:`build_traffic_streamed` calls would pay. All configs must share
    the flit lane width (they are placement/affinity variants of one mesh
    size in the sweep engine). Element i of the result is bit-identical to
    ``build_traffic_streamed(layers, cfgs[i], ..., mc_table=mc_tables[i])``.
    """
    if not cfgs:
        raise ValueError("need at least one config")
    if len({c.lanes for c in cfgs}) != 1:
        raise ValueError("streamed combos must share the flit lane width")
    if mc_tables is None:
        mc_tables = [None] * len(cfgs)
    if len(mc_tables) != len(cfgs):
        raise ValueError("mc_tables must match cfgs")
    if shapes is None:
        shapes = payload_shapes(layers, cfgs[0].lanes, variants,
                                max_packets_per_layer=max_packets_per_layer,
                                compression=compression)
    asms = [TrafficAssembler(shapes, cfg, num_streams=num_streams,
                             num_variants=len(variants), mc_table=tbl)
            for cfg, tbl in zip(cfgs, mc_tables)]
    for li, start, words in ordered_payloads_streamed(
            layers, cfgs[0].lanes, variants, chunk_packets=chunk_packets,
            max_packets_per_layer=max_packets_per_layer,
            compression=compression):
        for asm in asms:
            asm.add_chunk(li, start, words)
    return [asm.finish() for asm in asms]


def build_traffic_batch(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
    mc_table=None,
    compression: str = "none",
) -> Traffic:
    """Packetize ``layers`` once per (transform, quantizer) variant into a
    batched Traffic with a leading variants axis (see
    :func:`ordered_payloads` / :func:`assemble_traffic`)."""
    payloads = ordered_payloads(layers, cfg.lanes, variants,
                                max_packets_per_layer=max_packets_per_layer,
                                compression=compression)
    return assemble_traffic(payloads, cfg, num_variants=len(variants),
                            mc_table=mc_table)


def build_traffic(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    transform: WireTransform,
    *,
    quantizer=None,
    max_packets_per_layer: Optional[int] = None,
    compression: str = "none",
) -> Traffic:
    """Packetize layers under a WireTransform into per-MC injection streams.

    quantizer: optional value -> wire-dtype map (e.g. fixed-8 quantization);
        default transmits raw float32 words.
    max_packets_per_layer: subsample neurons (deterministic stride) to bound
        simulation time; BT rates are per-flit so subsampling is unbiased.
    compression: ``"none"`` (the default, bit-identical to the seed loop
        implementation, pinned against ``repro.noc._reference``) or
        ``"msr"`` (8b->5b MSR payload codes; needs an 8-bit quantizer).
    """
    batch = build_traffic_batch(layers, cfg, [(transform, quantizer)],
                                max_packets_per_layer=max_packets_per_layer,
                                compression=compression)
    return batch.variant(0)


def compression_overhead(layers: Sequence[LayerTraffic], quantizer,
                         lanes: int, compression: str, *,
                         max_packets_per_layer: Optional[int] = None) -> int:
    """Total escape/metadata bits the request phase owes under
    ``compression`` - 0 for ``"none"``.

    Each packet transmits two independent half-flit windows (inputs left,
    weights right), each padded to the lane-rounded slot count
    ``ceil(k / (lanes/2)) * (lanes/2)``; MSR charges a per-window outlier
    count plus a (position, top-bits) record per outlier
    (:func:`repro.core.wire.compression_overhead_bits`). Outlier status is
    per-value and every WireTransform only permutes values within the
    window, so the charge is identical across the whole transform axis -
    an honest adjusted-BT comparison adds it at half a transition per bit,
    exactly like the O2 recovery index.
    """
    _check_compression(compression)
    if compression == "none":
        return 0
    half = lanes // 2
    total = 0
    for layer in layers:
        inp, wgt = _subsample(layer, max_packets_per_layer)
        if inp.shape[0] == 0:
            continue
        if quantizer is not None:
            inp, wgt = quantizer(inp), quantizer(wgt)
        k = int(inp.shape[1])
        window = -(-k // half) * half
        total += compression_overhead_bits(compression, np.asarray(inp),
                                           window)
        total += compression_overhead_bits(compression, np.asarray(wgt),
                                           window)
    return total


# --- result phase: PE -> MC ejection traffic -------------------------------

# Result values per result packet (the result ordering window). Four payload
# flits at the paper's 16-lane links: long enough that ordering has material
# freedom, short enough that a PE never waits long to flush toward memory.
DEFAULT_RESULT_WINDOW = 64


def layer_results(layer: LayerTraffic,
                  max_packets: Optional[int] = None) -> jax.Array:
    """Per-neuron result values of one layer: the MAC of each packet's
    operand pairs, ``result(g) = sum_k inputs[g, k] * weights[g, k]``.

    This is the single value PE ``dest(g)`` ejects back toward memory for
    request packet ``g`` - the model-geometry-derived payload of the result
    phase. ``max_packets`` applies the same deterministic-stride neuron
    subsampling as the request packetizer so the two phases stay aligned
    on the same global packet ids.
    """
    inp, wgt = _subsample(layer, max_packets)
    return jnp.sum(inp.astype(jnp.float32) * wgt.astype(jnp.float32), axis=1)


def result_values(
    layers: Sequence[LayerTraffic],
    variants: Sequence[Variant],
    max_packets_per_layer: Optional[int] = None,
) -> List[List[jax.Array]]:
    """Per-layer, per-variant result value arrays - the ``values`` input of
    :func:`build_result_traffic`, computed once and reused across every
    mesh/placement/affinity cell of a sweep."""
    out: List[List[jax.Array]] = []
    for layer in layers:
        res = layer_results(layer, max_packets_per_layer)
        out.append([res if q is None else q(res) for _, q in variants])
    return out


@functools.lru_cache(maxsize=None)
def _result_packet_fn(transform: WireTransform, lanes: int,
                      compression: str = "none"):
    """Vmapped single-stream packet transform for result payloads,
    memoized per (transform, lanes, compression) exactly like
    :func:`_packet_fn`."""

    def one_packet(vals):
        if compression == "none":
            return transform.apply_single(vals, lanes).words
        return msr.msr_pack(transform.order_single(vals, lanes), lanes).words

    return jax.vmap(one_packet)


def build_result_traffic(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    variants: Sequence[Variant],
    *,
    max_packets_per_layer: Optional[int] = None,
    mc_table=None,
    result_window: Optional[int] = None,
    num_streams: Optional[int] = None,
    values: Optional[Sequence[Sequence[jax.Array]]] = None,
    compression: str = "none",
) -> Traffic:
    """Packetize the result phase: per-PE injection streams of PE->MC
    result packets, as a batched Traffic (leading variants axis).

    values: optional precomputed per-layer result values, one per-variant
        list of ``(n,)`` arrays per layer (:func:`layer_results` plus each
        variant's quantizer). Result values depend only on the layers and
        variants - never on the mesh, placement, or affinity - so the
        sweep engine computes them once per model and reuses them across
        every (placement, affinity) combo.

    The request phase computes neuron ``g`` at PE ``pes[g % num_pes]`` with
    operands served by MC ``mc(g)`` (round-robin, or the affinity
    ``mc_table``). The result phase returns each neuron's single MAC value
    (:func:`layer_results`) along the opposite path: stream ``i`` injects
    at ``cfg.pe_nodes[i]`` and every packet's destination is the *serving
    MC* of the neurons it carries, so request and result traffic traverse
    the same MC<->PE pairs in opposite directions.

    A result packet groups up to ``result_window`` consecutive results of
    one (PE, MC) pair within one layer (layer boundaries flush partial
    windows - results of a layer return before the next layer's). The
    window is the ordering window: each variant's transform orders the
    packet's result values via ``WireTransform.apply_single`` (O0 keeps
    arrival order, O1/O2 sort by popcount) and its quantizer narrows them,
    mirroring the request-side contract. All variants share the skeleton
    (dest/meta/vc/pkt/length); only payload words differ.

    num_streams: pad the PE-stream axis to this count with empty streams
        (the sweep engine gives every placement of one mesh size a common
        stream count so result drains share one executable).

    Feeds :func:`repro.noc.sim.simulate_batch` with
    ``mc_nodes=cfg.pe_nodes`` (padded with zeros for padding streams) -
    the injection-node argument names the *sources*, which for this phase
    are the PEs. Packet conservation (``check_conservation=True``) works
    unchanged: result packets number ``0..num_packets-1`` and every one
    must eject exactly once at its MC.
    """
    if not variants:
        raise ValueError("need at least one (transform, quantizer) variant")
    _check_compression(compression)
    m, lanes, nv = cfg.num_mcs, cfg.lanes, len(variants)
    pes = np.asarray(cfg.pe_nodes, np.int64)
    p = len(pes)
    if num_streams is not None and num_streams < p:
        raise ValueError(f"cannot pad {p} PE streams down to {num_streams}")
    w = DEFAULT_RESULT_WINDOW if result_window is None else int(result_window)
    if w < 1:
        raise ValueError(f"result_window must be >= 1, got {w}")
    sched = _McSchedule(m, mc_table)
    mcs_nodes = np.asarray(cfg.mc_nodes, np.int64)
    # Payload flits per full window; under MSR compression the window's
    # 5-bit codes pack into ceil(5 * slots / 8) bytes of 8-bit lanes.
    fw = (-(-w // lanes) if compression == "none"
          else msr.compressed_payload_flits(w, lanes))

    # Like the request-phase TrafficAssembler, assembly is scatters, not a
    # per-packet loop: each layer contributes one flat (stream row, flit
    # col, value) scatter, with per-stream running flit/packet counters
    # carrying the state between layers.
    stream_len = np.zeros(p, np.int64)        # flits written per stream
    stream_pkts = np.zeros(p, np.int64)       # packets per stream (-> VC)
    scatters = []                             # per-layer scatter payloads
    pkt_id = 0
    g0 = 0
    for li, layer in enumerate(layers):
        n = int(_subsample(layer, max_packets_per_layer)[0].shape[0])
        if n == 0:
            continue
        if values is not None:
            vals = values[li]
        else:
            res = layer_results(layer, max_packets_per_layer)
            vals = [res if q is None else q(res) for _, q in variants]

        gids = g0 + np.arange(n, dtype=np.int64)
        g0 += n
        src = (gids % p).astype(np.int64)            # PE stream index
        mcidx = sched.mc(gids)
        key = src * m + mcidx
        order = np.argsort(key, kind="stable")       # group-major, g-order
        ksort = key[order]
        uniq, start, counts = np.unique(ksort, return_index=True,
                                        return_counts=True)
        grp = np.repeat(np.arange(len(uniq)), counts)
        rank = np.arange(n) - np.repeat(start, counts)
        pkts_per_grp = -(-counts // w)
        pkt_base = np.concatenate([[0], np.cumsum(pkts_per_grp)])
        row = pkt_base[grp] + rank // w              # packet row per neuron
        col = rank % w
        npkt = int(pkt_base[-1])

        # One uniform-window transform vmap per variant; padding zeros end
        # up in the tail flits under every transform (popcount 0 sorts last
        # for O1/O2; the O3 deal confines the chained non-zeros to the
        # first ceil(z / lanes) * lanes slots), so slicing each packet to
        # its real flit count is exact. Under MSR the kept flits cover the
        # lane-rounded slot count's code bytes, which by the same argument
        # hold every non-zero code; dropped bytes pack only zero codes.
        mats = []
        for v in vals:
            mat = np.zeros((npkt, w), np.asarray(v).dtype)
            mat[row, col] = np.asarray(v)[order]
            mats.append(mat)
        words_v = [np.asarray(_result_packet_fn(tr, lanes, compression)(
            jnp.asarray(mat)).astype(jnp.uint32))
            for (tr, _), mat in zip(variants, mats)]
        shapes = {wv.shape for wv in words_v}
        if shapes != {(npkt, fw, lanes)}:
            raise ValueError(
                f"variants disagree on result flit geometry: {sorted(shapes)}")
        words_v = np.stack(words_v)                  # (nv, npkt, fw, L)

        # Per-packet skeleton, in (pe, mc, window) order = stream order.
        pk_grp = np.repeat(np.arange(len(uniq)), pkts_per_grp)
        pk_src = uniq[pk_grp] // m
        pk_mc = uniq[pk_grp] % m
        pk_idx = np.arange(npkt) - pkt_base[pk_grp]  # window index in group
        pk_c = np.minimum(counts[pk_grp] - pk_idx * w, w)
        pk_fpay = (np.asarray((-(-pk_c // lanes)) if compression == "none"
                              else msr.compressed_payload_flits(pk_c, lanes))
                   ).astype(np.int64)
        f_tot = pk_fpay + 1                          # + header flit
        dest_pk = mcs_nodes[pk_mc].astype(np.int32)
        ids_pk = (pkt_id + np.arange(npkt)).astype(np.int64)

        # Packets sorted by src, so each stream's packets of this layer
        # are one contiguous run: within-stream rank gives the VC, the
        # exclusive flit cumsum (rebased per run) the stream offset.
        s_counts = np.bincount(pk_src, minlength=p)
        s_first = np.concatenate([[0], np.cumsum(s_counts)])[:-1]
        within = np.arange(npkt) - np.repeat(s_first, s_counts)
        vc_pk = ((stream_pkts[pk_src] + within) % cfg.num_vcs).astype(np.int32)
        fcum = np.cumsum(f_tot) - f_tot              # exclusive, pk order
        run0 = fcum[np.minimum(s_first, max(npkt - 1, 0))]
        flit0 = stream_len[pk_src] + fcum - np.repeat(run0, s_counts)

        # Flat flit axis: j = flit index within its packet (0 = header).
        total_f = int(f_tot.sum())
        fl_pk = np.repeat(np.arange(npkt), f_tot)
        pk_f0 = np.concatenate([[0], np.cumsum(f_tot)])[:-1]
        j = np.arange(total_f) - np.repeat(pk_f0, f_tot)
        hdr = j == 0
        md = np.where(hdr, 0, META_PAYLOAD).astype(np.int32)
        md[j == f_tot[fl_pk] - 1] |= META_TAIL
        flit_words = words_v[:, fl_pk, np.maximum(j - 1, 0)]  # (nv, F, L)
        hdr_words = np.zeros((npkt, lanes), np.uint32)
        hdr_words[:, 0] = dest_pk.astype(np.uint32)
        hdr_words[:, 1] = (ids_pk & 0xFFFFFFFF).astype(np.uint32)
        hdr_words[:, 2] = pk_fpay
        flit_words[:, hdr] = hdr_words

        scatters.append((pk_src[fl_pk], flit0[fl_pk] + j, flit_words,
                         dest_pk[fl_pk], md, vc_pk[fl_pk],
                         ids_pk[fl_pk].astype(np.int32)))
        stream_len += np.bincount(pk_src, weights=f_tot,
                                  minlength=p).astype(np.int64)
        stream_pkts += s_counts
        pkt_id += npkt

    t = int(stream_len.max()) if p and stream_len.size else 0
    ns = num_streams if num_streams is not None else p
    words_arr = np.zeros((nv, ns, t, lanes), np.uint32)
    dest_arr = np.zeros((ns, t), np.int32)
    meta_arr = np.zeros((ns, t), np.int32)
    vc_arr = np.zeros((ns, t), np.int32)
    pkt_arr = np.zeros((ns, t), np.int32)
    for rows, cols, flit_words, dest_f, md, vc_f, pkt_f in scatters:
        words_arr[:, rows, cols] = flit_words
        dest_arr[rows, cols] = dest_f
        meta_arr[rows, cols] = md
        vc_arr[rows, cols] = vc_f
        pkt_arr[rows, cols] = pkt_f

    def tile(a):
        return jnp.asarray(np.broadcast_to(a, (nv,) + a.shape))

    lengths = np.pad(stream_len, (0, ns - p))
    return Traffic(
        words=jnp.asarray(words_arr), dest=tile(dest_arr),
        meta=tile(meta_arr), vc=tile(vc_arr), pkt=tile(pkt_arr),
        length=tile(lengths.astype(np.int32)), num_packets=pkt_id)
