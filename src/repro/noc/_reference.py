"""Frozen pre-overhaul packetizer and simulators — the oracles.

This module preserves, verbatim, two retired implementations:

* the seed per-neuron packetization loop and the closure-captured simulator
  driver (``build_traffic_reference`` / ``simulate_reference``) that
  ``repro.noc.traffic`` / ``repro.noc.sim`` originally shipped with;
* the PR-3 *unfused* router step and drivers (``simulate_unfused`` /
  ``simulate_batch_unfused``): traffic-as-traced-argument and compile
  cached like production, but with the split words/dest/meta/pkt FIFO
  state, the route/neighbor-table gathers, the SWAR BT recorder, and the
  always-on conservation ledger that the fused step replaced.

They exist so the equivalence regression tests can pin the vectorized
packetizer and the fused-state simulator bit-for-bit against fixed
implementations (``tests/test_noc_sweep.py`` / ``tests/test_noc_step.py``),
and so ``benchmarks.run`` can measure speedups against both generations
and record them in ``BENCH_noc.json``.

Do not "improve" this file: its value is that it does not change. The
production implementations live in ``traffic.py`` / ``sim.py``. The legacy
state layout is defined locally (the production ``SimState`` moved on to
the fused layout).
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bits import popcount
from repro.core.wire import WireTransform
from .topology import NocConfig, NUM_PORTS, OPPOSITE, PORT_LOCAL, \
    neighbor_table, xy_route
from .sim import Traffic, SimResult, META_PAYLOAD, META_TAIL, _result
from .traffic import LayerTraffic

__all__ = ["build_traffic_reference", "simulate_reference",
           "simulate_unfused", "simulate_batch_unfused"]


# --- the pre-overhaul (split-field) simulator state, frozen ---

class SimState(NamedTuple):
    # FIFO contents; router axis padded by one phantom row absorbing
    # masked-out scatters.
    words: jax.Array   # (NR+1, P, V, D, L) uint32
    dest: jax.Array    # (NR+1, P, V, D) int32
    meta: jax.Array    # (NR+1, P, V, D) int32
    pkt: jax.Array     # (NR+1, P, V, D) int32
    head: jax.Array    # (NR+1, P, V) int32
    count: jax.Array   # (NR+1, P, V) int32
    rr: jax.Array      # (NR, P) int32
    link_last: jax.Array  # (NR, P, L) uint32
    link_bt: jax.Array    # (NR, P) int32
    link_flits: jax.Array # (NR, P) int32
    inj_ptr: jax.Array    # (M,) int32
    inj_last: jax.Array   # (M, L) uint32
    inj_bt: jax.Array     # (M,) int32
    ejected: jax.Array    # () int32
    cycle: jax.Array      # () int32
    eject_pkt: jax.Array  # (NP+1,) int32
    drained_at: jax.Array # () int32


def make_state(cfg: NocConfig, num_mcs: int, npkt: int = 0) -> SimState:
    nr, p, v, d, l = cfg.num_routers, NUM_PORTS, cfg.num_vcs, cfg.vc_depth, cfg.lanes
    return SimState(
        words=jnp.zeros((nr + 1, p, v, d, l), jnp.uint32),
        dest=jnp.zeros((nr + 1, p, v, d), jnp.int32),
        meta=jnp.zeros((nr + 1, p, v, d), jnp.int32),
        pkt=jnp.zeros((nr + 1, p, v, d), jnp.int32),
        head=jnp.zeros((nr + 1, p, v), jnp.int32),
        count=jnp.zeros((nr + 1, p, v), jnp.int32),
        rr=jnp.zeros((nr, p), jnp.int32),
        link_last=jnp.zeros((nr, p, l), jnp.uint32),
        link_bt=jnp.zeros((nr, p), jnp.int32),
        link_flits=jnp.zeros((nr, p), jnp.int32),
        inj_ptr=jnp.zeros((num_mcs,), jnp.int32),
        inj_last=jnp.zeros((num_mcs, l), jnp.uint32),
        inj_bt=jnp.zeros((num_mcs,), jnp.int32),
        ejected=jnp.zeros((), jnp.int32),
        cycle=jnp.zeros((), jnp.int32),
        eject_pkt=jnp.zeros((npkt + 1,), jnp.int32),
        drained_at=jnp.full((), -1, jnp.int32),
    )


def _front(state: SimState, nr: int):
    """Gather the front flit of every FIFO -> (NR, P, V, ...)."""
    idx = state.head[:nr, :, :, None]
    fw = jnp.take_along_axis(state.words[:nr], idx[..., None], axis=3)[:, :, :, 0]
    fd = jnp.take_along_axis(state.dest[:nr], idx, axis=3)[:, :, :, 0]
    fm = jnp.take_along_axis(state.meta[:nr], idx, axis=3)[:, :, :, 0]
    fp = jnp.take_along_axis(state.pkt[:nr], idx, axis=3)[:, :, :, 0]
    return fw, fd, fm, fp


def _header_word(dest: int, pkt_id: int, n_payload: int, lanes: int) -> np.ndarray:
    h = np.zeros((lanes,), np.uint32)
    h[0], h[1], h[2] = dest, pkt_id & 0xFFFFFFFF, n_payload
    return h


def build_traffic_reference(
    layers: Sequence[LayerTraffic],
    cfg: NocConfig,
    transform: WireTransform,
    *,
    quantizer=None,
    max_packets_per_layer: Optional[int] = None,
) -> Traffic:
    """The seed's per-neuron packetization loop (numpy appends, per-packet
    ``vc_rr``/``pe_rr`` bookkeeping). Semantics frozen; see module docstring."""
    m = cfg.num_mcs
    pes = np.asarray(cfg.pe_nodes, np.int32)
    streams: List[List[np.ndarray]] = [[] for _ in range(m)]     # words
    meta: List[List[np.ndarray]] = [[] for _ in range(m)]        # (dest, meta, vc, pkt)
    vc_rr = [0] * m
    pkt_id = 0
    pe_rr = 0

    for layer in layers:
        inp, wgt = layer.inputs, layer.weights
        n = int(inp.shape[0])
        if max_packets_per_layer is not None and n > max_packets_per_layer:
            stride = n // max_packets_per_layer
            idx = jnp.arange(0, stride * max_packets_per_layer, stride)
            inp, wgt = inp[idx], wgt[idx]
            n = int(inp.shape[0])
        if quantizer is not None:
            inp, wgt = quantizer(inp), quantizer(wgt)
        # Apply the ordering transform per packet, vectorized over neurons.
        def one_packet(i, w):
            stream = transform.apply(i, w, cfg.lanes)
            return stream.words
        words = jax.vmap(one_packet)(inp, wgt)      # (n, F, L)
        words = np.asarray(words.astype(jnp.uint32))
        n_flits = words.shape[1]
        for j in range(n):
            mc = (pkt_id % m)
            dest = int(pes[pe_rr % len(pes)])
            pe_rr += 1
            header = _header_word(dest, pkt_id, n_flits, cfg.lanes)
            pkt_words = np.concatenate([header[None], words[j]], axis=0)
            f = pkt_words.shape[0]
            md = np.full((f,), META_PAYLOAD, np.int32)
            md[0] = 0
            md[-1] |= META_TAIL
            vc = vc_rr[mc] % cfg.num_vcs
            vc_rr[mc] += 1
            streams[mc].append(pkt_words)
            meta[mc].append(np.stack([
                np.full((f,), dest, np.int32),
                md,
                np.full((f,), vc, np.int32),
                np.full((f,), pkt_id, np.int32)], axis=1))
            pkt_id += 1

    lengths = np.array([sum(len(x) for x in s) for s in streams], np.int32)
    t = int(lengths.max()) if len(lengths) else 0
    l = cfg.lanes
    words_arr = np.zeros((m, t, l), np.uint32)
    dest_arr = np.zeros((m, t), np.int32)
    meta_arr = np.zeros((m, t), np.int32)
    vc_arr = np.zeros((m, t), np.int32)
    pkt_arr = np.zeros((m, t), np.int32)
    for mc in range(m):
        if not streams[mc]:
            continue
        w = np.concatenate(streams[mc], axis=0)
        md = np.concatenate(meta[mc], axis=0)
        words_arr[mc, :w.shape[0]] = w
        dest_arr[mc, :w.shape[0]] = md[:, 0]
        meta_arr[mc, :w.shape[0]] = md[:, 1]
        vc_arr[mc, :w.shape[0]] = md[:, 2]
        pkt_arr[mc, :w.shape[0]] = md[:, 3]
    return Traffic(
        words=jnp.asarray(words_arr), dest=jnp.asarray(dest_arr),
        meta=jnp.asarray(meta_arr), vc=jnp.asarray(vc_arr),
        pkt=jnp.asarray(pkt_arr), length=jnp.asarray(lengths),
        num_packets=pkt_id)


def _make_step_reference(cfg: NocConfig, traffic: Traffic, count_headers: bool):
    """The seed's step factory: closes over ``traffic``, so every Traffic
    value retraces and recompiles the whole cycle scan."""
    from repro.core.bits import popcount

    nr, p, v, d, l = cfg.num_routers, NUM_PORTS, cfg.num_vcs, cfg.vc_depth, cfg.lanes
    m = traffic.length.shape[0]
    nslots = p * v
    route = xy_route(cfg)                      # (NR, NR)
    nb = neighbor_table(cfg)                   # (NR, P)
    opp = jnp.asarray(OPPOSITE)
    mc_nodes = jnp.asarray(cfg.mc_nodes, jnp.int32)
    t_cap = traffic.words.shape[1]

    def step(state: SimState, _):
        valid = state.count[:nr] > 0                       # (NR, P, V)
        fw, fd, fm, fp = _front(state, nr)

        rid = jnp.arange(nr)[:, None, None]
        out_port = route[rid, fd]                          # (NR, P, V)

        down = nb[rid, out_port]                            # (NR, P, V)
        down_ip = opp[out_port]
        vcs = jnp.arange(v)[None, None, :]
        down_cnt = state.count[jnp.where(down < 0, nr, down), down_ip, vcs]
        is_eject = out_port == PORT_LOCAL
        space = jnp.where(is_eject, True, (down >= 0) & (down_cnt < d))
        request = valid & space                             # (NR, P, V)

        slot_req = request.reshape(nr, nslots)
        slot_out = out_port.reshape(nr, nslots)
        outs = jnp.arange(NUM_PORTS)[None, :, None]
        req_po = slot_req[:, None, :] & (slot_out[:, None, :] == outs)
        rot_idx = (jnp.arange(nslots)[None, None, :] + state.rr[:, :, None]) % nslots
        rot = jnp.take_along_axis(req_po, rot_idx, axis=2)
        has = jnp.any(rot, axis=2)                          # (NR, P_out)
        first = jnp.argmax(rot, axis=2)
        winner = (first + state.rr) % nslots                # (NR, P_out)
        rr_new = jnp.where(has, (winner + 1) % nslots, state.rr)

        onehot = (jnp.arange(nslots)[None, None, :] == winner[:, :, None]) & has[:, :, None]
        pop = jnp.any(onehot, axis=1).reshape(nr, p, v)     # (NR, P, V)
        head_new = jnp.where(pop, (state.head[:nr] + 1) % d, state.head[:nr])
        count_new = state.count[:nr] - pop.astype(jnp.int32)
        head2 = state.head.at[:nr].set(head_new)
        count2 = state.count.at[:nr].set(count_new)

        win_p = winner // v
        win_v = winner % v
        r2 = jnp.arange(nr)[:, None]
        mv_word = fw[r2, win_p, win_v]                      # (NR, P_out, L)
        mv_dest = fd[r2, win_p, win_v]
        mv_meta = fm[r2, win_p, win_v]
        mv_pkt = fp[r2, win_p, win_v]

        tog = popcount(state.link_last ^ mv_word).sum(-1).astype(jnp.int32)
        if count_headers:
            counted = has
        else:
            counted = has & ((mv_meta & META_PAYLOAD) > 0)
        link_bt = state.link_bt + jnp.where(counted, tog, 0)
        link_flits = state.link_flits + has.astype(jnp.int32)
        link_last = jnp.where(has[:, :, None], mv_word, state.link_last)

        o_ids = jnp.arange(NUM_PORTS)[None, :]
        push_ok = has & (o_ids != PORT_LOCAL)
        down_r = nb[jnp.arange(nr)[:, None], o_ids]         # (NR, P_out)
        tgt_r = jnp.where(push_ok & (down_r >= 0), down_r, nr)  # phantom row
        tgt_p = opp[o_ids] * jnp.ones((nr, 1), jnp.int32)
        tgt_v = win_v
        slot = (head2[tgt_r, tgt_p, tgt_v] + count2[tgt_r, tgt_p, tgt_v]) % d

        fr, fo = tgt_r.reshape(-1), tgt_p.reshape(-1)
        fv, fs = tgt_v.reshape(-1), slot.reshape(-1)
        words3 = state.words.at[fr, fo, fv, fs].set(mv_word.reshape(-1, l))
        dest3 = state.dest.at[fr, fo, fv, fs].set(mv_dest.reshape(-1))
        meta3 = state.meta.at[fr, fo, fv, fs].set(mv_meta.reshape(-1))
        pkt3 = state.pkt.at[fr, fo, fv, fs].set(mv_pkt.reshape(-1))
        count3 = count2.at[fr, fo, fv].add(push_ok.reshape(-1).astype(jnp.int32))

        ejected = state.ejected + jnp.sum(has & (o_ids == PORT_LOCAL))

        ptr = state.inj_ptr
        active = ptr < traffic.length
        safe_ptr = jnp.minimum(ptr, t_cap - 1)
        mrange = jnp.arange(m)
        iw = traffic.words[mrange, safe_ptr]                # (M, L)
        idst = traffic.dest[mrange, safe_ptr]
        imeta = traffic.meta[mrange, safe_ptr]
        ivc = traffic.vc[mrange, safe_ptr]
        ipkt = traffic.pkt[mrange, safe_ptr]
        mc_cnt = count3[mc_nodes, PORT_LOCAL, ivc]
        can = active & (mc_cnt < d)
        tgt_mr = jnp.where(can, mc_nodes, nr)
        islot = (head2[tgt_mr, PORT_LOCAL, ivc] + count3[tgt_mr, PORT_LOCAL, ivc]) % d
        words4 = words3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(iw)
        dest4 = dest3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(idst)
        meta4 = meta3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(imeta)
        pkt4 = pkt3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(ipkt)
        count4 = count3.at[tgt_mr, PORT_LOCAL, ivc].add(can.astype(jnp.int32))
        ptr_new = ptr + can.astype(jnp.int32)

        itog = popcount(state.inj_last ^ iw).sum(-1).astype(jnp.int32)
        if count_headers:
            icounted = can
        else:
            icounted = can & ((imeta & META_PAYLOAD) > 0)
        inj_bt = state.inj_bt + jnp.where(icounted, itog, 0)
        inj_last = jnp.where(can[:, None], iw, state.inj_last)

        new = state._replace(
            words=words4, dest=dest4, meta=meta4, pkt=pkt4, head=head2,
            count=count4, rr=rr_new, link_last=link_last, link_bt=link_bt,
            link_flits=link_flits, inj_ptr=ptr_new, inj_last=inj_last,
            inj_bt=inj_bt, ejected=ejected, cycle=state.cycle + 1)
        return new, ()

    return step


def simulate_reference(cfg: NocConfig, traffic: Traffic, *,
                       count_headers: bool = True, max_cycles: int = 2_000_000,
                       chunk: int = 4096) -> SimResult:
    """The seed driver: a fresh jit (and therefore a fresh trace + compile)
    for every traffic tensor, because ``traffic`` is closed over."""
    m = int(traffic.length.shape[0])
    if m != cfg.num_mcs:
        raise ValueError(f"traffic has {m} MC streams, config has {cfg.num_mcs}")
    state = make_state(cfg, m)
    step = _make_step_reference(cfg, traffic, count_headers)

    @jax.jit
    def run_chunk(s):
        s, _ = jax.lax.scan(step, s, None, length=chunk)
        return s

    total = int(np.sum(np.asarray(traffic.length)))
    while True:
        state = run_chunk(state)
        drained = (int(state.ejected) == total)
        if drained or int(state.cycle) >= max_cycles:
            break
    if int(state.ejected) != total:
        raise RuntimeError(
            f"NoC did not drain: {int(state.ejected)}/{total} flits ejected "
            f"after {int(state.cycle)} cycles")

    link_bt = np.asarray(state.link_bt)
    link_flits = np.asarray(state.link_flits)
    inj_bt = np.asarray(state.inj_bt)
    inter = int(link_bt[:, :PORT_LOCAL].sum())
    total_bt = int(link_bt.sum() + inj_bt.sum())
    return SimResult(
        cycles=int(state.cycle), ejected=int(state.ejected), injected=total,
        link_bt=link_bt, link_flits=link_flits, inj_bt=inj_bt,
        total_bt=total_bt, inter_router_bt=inter)


# --- the PR-3 unfused router step and drivers, frozen at PR 4 ---
#
# This is the production step as it stood before the fused-state overhaul:
# four sideband gathers + four scatters per cycle (words/dest/meta/pkt),
# route/neighbor/opposite table gathers, the SWAR popcount recorder, and an
# unconditional per-packet ejection ledger. The fused step is pinned
# bit-for-bit (total_bt / link_bt / drain_cycle) against it.

def _make_step_unfused(cfg: NocConfig, count_headers: bool):
    nr, p, v, d, l = cfg.num_routers, NUM_PORTS, cfg.num_vcs, cfg.vc_depth, cfg.lanes
    nslots = p * v
    route = xy_route(cfg)                      # (NR, NR)
    nb = neighbor_table(cfg)                   # (NR, P)
    opp = jnp.asarray(OPPOSITE)

    def step(state: SimState, traffic: Traffic, mc_nodes: jax.Array):
        m = traffic.length.shape[0]
        t_cap = traffic.words.shape[1]
        valid = state.count[:nr] > 0                       # (NR, P, V)
        fw, fd, fm, fp = _front(state, nr)

        rid = jnp.arange(nr)[:, None, None]
        out_port = route[rid, fd]                          # (NR, P, V)

        down = nb[rid, out_port]                            # (NR, P, V)
        down_ip = opp[out_port]
        vcs = jnp.arange(v)[None, None, :]
        down_cnt = state.count[jnp.where(down < 0, nr, down), down_ip, vcs]
        is_eject = out_port == PORT_LOCAL
        space = jnp.where(is_eject, True, (down >= 0) & (down_cnt < d))
        request = valid & space                             # (NR, P, V)

        slot_req = request.reshape(nr, nslots)
        slot_out = out_port.reshape(nr, nslots)
        outs = jnp.arange(NUM_PORTS)[None, :, None]
        req_po = slot_req[:, None, :] & (slot_out[:, None, :] == outs)
        rot_idx = (jnp.arange(nslots)[None, None, :] + state.rr[:, :, None]) % nslots
        rot = jnp.take_along_axis(req_po, rot_idx, axis=2)
        has = jnp.any(rot, axis=2)                          # (NR, P_out)
        first = jnp.argmax(rot, axis=2)
        winner = (first + state.rr) % nslots                # (NR, P_out)
        rr_new = jnp.where(has, (winner + 1) % nslots, state.rr)

        onehot = (jnp.arange(nslots)[None, None, :] == winner[:, :, None]) & has[:, :, None]
        pop = jnp.any(onehot, axis=1).reshape(nr, p, v)     # (NR, P, V)
        head_new = jnp.where(pop, (state.head[:nr] + 1) % d, state.head[:nr])
        count_new = state.count[:nr] - pop.astype(jnp.int32)
        head2 = state.head.at[:nr].set(head_new)
        count2 = state.count.at[:nr].set(count_new)

        win_p = winner // v
        win_v = winner % v
        r2 = jnp.arange(nr)[:, None]
        mv_word = fw[r2, win_p, win_v]                      # (NR, P_out, L)
        mv_dest = fd[r2, win_p, win_v]
        mv_meta = fm[r2, win_p, win_v]
        mv_pkt = fp[r2, win_p, win_v]

        tog = popcount(state.link_last ^ mv_word).sum(-1).astype(jnp.int32)
        if count_headers:
            counted = has
        else:
            counted = has & ((mv_meta & META_PAYLOAD) > 0)
        link_bt = state.link_bt + jnp.where(counted, tog, 0)
        link_flits = state.link_flits + has.astype(jnp.int32)
        link_last = jnp.where(has[:, :, None], mv_word, state.link_last)

        o_ids = jnp.arange(NUM_PORTS)[None, :]
        push_ok = has & (o_ids != PORT_LOCAL)
        down_r = nb[jnp.arange(nr)[:, None], o_ids]         # (NR, P_out)
        tgt_r = jnp.where(push_ok & (down_r >= 0), down_r, nr)  # phantom row
        tgt_p = opp[o_ids] * jnp.ones((nr, 1), jnp.int32)
        tgt_v = win_v
        slot = (head2[tgt_r, tgt_p, tgt_v] + count2[tgt_r, tgt_p, tgt_v]) % d

        fr, fo = tgt_r.reshape(-1), tgt_p.reshape(-1)
        fv, fs = tgt_v.reshape(-1), slot.reshape(-1)
        words3 = state.words.at[fr, fo, fv, fs].set(mv_word.reshape(-1, l))
        dest3 = state.dest.at[fr, fo, fv, fs].set(mv_dest.reshape(-1))
        meta3 = state.meta.at[fr, fo, fv, fs].set(mv_meta.reshape(-1))
        pkt3 = state.pkt.at[fr, fo, fv, fs].set(mv_pkt.reshape(-1))
        count3 = count2.at[fr, fo, fv].add(push_ok.reshape(-1).astype(jnp.int32))

        ejected = state.ejected + jnp.sum(has & (o_ids == PORT_LOCAL))

        npcap = state.eject_pkt.shape[0] - 1
        ej_tail = has & (o_ids == PORT_LOCAL) & ((mv_meta & META_TAIL) > 0)
        ledger_idx = jnp.where(ej_tail, jnp.minimum(mv_pkt, npcap), npcap)
        eject_pkt = state.eject_pkt.at[ledger_idx.reshape(-1)].add(
            ej_tail.reshape(-1).astype(jnp.int32))

        ptr = state.inj_ptr
        active = ptr < traffic.length
        safe_ptr = jnp.minimum(ptr, t_cap - 1)
        mrange = jnp.arange(m)
        iw = traffic.words[mrange, safe_ptr]                # (M, L)
        idst = traffic.dest[mrange, safe_ptr]
        imeta = traffic.meta[mrange, safe_ptr]
        ivc = traffic.vc[mrange, safe_ptr]
        ipkt = traffic.pkt[mrange, safe_ptr]
        mc_cnt = count3[mc_nodes, PORT_LOCAL, ivc]
        can = active & (mc_cnt < d)
        tgt_mr = jnp.where(can, mc_nodes, nr)
        islot = (head2[tgt_mr, PORT_LOCAL, ivc] + count3[tgt_mr, PORT_LOCAL, ivc]) % d
        words4 = words3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(iw)
        dest4 = dest3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(idst)
        meta4 = meta3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(imeta)
        pkt4 = pkt3.at[tgt_mr, PORT_LOCAL, ivc, islot].set(ipkt)
        count4 = count3.at[tgt_mr, PORT_LOCAL, ivc].add(can.astype(jnp.int32))
        ptr_new = ptr + can.astype(jnp.int32)

        itog = popcount(state.inj_last ^ iw).sum(-1).astype(jnp.int32)
        if count_headers:
            icounted = can
        else:
            icounted = can & ((imeta & META_PAYLOAD) > 0)
        inj_bt = state.inj_bt + jnp.where(icounted, itog, 0)
        inj_last = jnp.where(can[:, None], iw, state.inj_last)

        total = jnp.sum(traffic.length)
        drained_at = jnp.where((state.drained_at < 0) & (ejected >= total),
                               state.cycle + 1, state.drained_at)

        return SimState(words4, dest4, meta4, pkt4, head2, count4, rr_new,
                        link_last, link_bt, link_flits, ptr_new, inj_last,
                        inj_bt, ejected, state.cycle + 1, eject_pkt,
                        drained_at)

    return step


@functools.lru_cache(maxsize=None)
def _unfused_chunk_runner(mesh_key, count_headers: bool, chunk: int,
                          batched: bool):
    rows, cols, num_vcs, vc_depth, lanes = mesh_key
    cfg = NocConfig(rows, cols, (), num_vcs=num_vcs, vc_depth=vc_depth,
                    lanes=lanes)
    step = _make_step_unfused(cfg, count_headers)

    def run(state: SimState, traffic: Traffic,
            mc_nodes: jax.Array) -> SimState:
        def body(s, _):
            return step(s, traffic, mc_nodes), ()
        out, _ = jax.lax.scan(body, state, None, length=chunk)
        return out

    if batched:
        # Traffic.num_packets is scalar metadata shared by every lane;
        # broadcast it instead of mapping it.
        tr_axes = Traffic(words=0, dest=0, meta=0, vc=0, pkt=0, length=0,
                          num_packets=None)
        run = jax.vmap(run, in_axes=(0, tr_axes, None))
    return jax.jit(run, donate_argnums=0)


def _mesh_key_unfused(cfg: NocConfig):
    return (cfg.rows, cfg.cols, cfg.num_vcs, cfg.vc_depth, cfg.lanes)


def _mc_array_unfused(cfg: NocConfig, traffic: Traffic, m: int) -> jax.Array:
    if m < cfg.num_mcs:
        raise ValueError(
            f"traffic has {m} MC streams, config has {cfg.num_mcs}")
    nodes = tuple(cfg.mc_nodes) + (0,) * (m - cfg.num_mcs)
    return jnp.asarray(nodes, jnp.int32)


def simulate_unfused(cfg: NocConfig, traffic: Traffic, *,
                     count_headers: bool = True, max_cycles: int = 2_000_000,
                     chunk: int = 4096) -> SimResult:
    """The PR-3 drain loop: serial chunks, readback-synchronized."""
    m = int(traffic.length.shape[0])
    mc_nodes = _mc_array_unfused(cfg, traffic, m)
    state = make_state(cfg, m)
    run_chunk = _unfused_chunk_runner(_mesh_key_unfused(cfg), count_headers,
                                      chunk, False)
    total = int(np.sum(np.asarray(traffic.length)))
    while total:
        state = run_chunk(state, traffic, mc_nodes)
        if int(state.ejected) == total or int(state.cycle) >= max_cycles:
            break
    if int(state.ejected) != total:
        raise RuntimeError(
            f"NoC did not drain: {int(state.ejected)}/{total} flits ejected "
            f"after {int(state.cycle)} cycles")
    return _result(cfg, (np.asarray(state.link_bt),
                         np.asarray(state.link_flits),
                         np.asarray(state.inj_bt), state.ejected, state.cycle,
                         state.drained_at), total)


def simulate_batch_unfused(cfg: NocConfig, traffic: Traffic, *,
                           count_headers: bool = True,
                           max_cycles: int = 2_000_000,
                           chunk: int = 4096) -> List[SimResult]:
    """The PR-3 batched drain: vmapped lanes, no retirement, no pipeline."""
    if traffic.length.ndim != 2:
        raise ValueError("simulate_batch_unfused wants a variants axis")
    b, m = traffic.length.shape
    mc_nodes = _mc_array_unfused(cfg, traffic, m)
    base = make_state(cfg, m)
    state = jax.tree.map(lambda x: jnp.stack([x] * b), base)
    run_chunk = _unfused_chunk_runner(_mesh_key_unfused(cfg), count_headers,
                                      chunk, True)
    totals = np.asarray(traffic.length).sum(axis=1)
    ejected = np.asarray(state.ejected)
    while totals.sum():
        state = run_chunk(state, traffic, mc_nodes)
        ejected = np.asarray(state.ejected)
        if np.all(ejected == totals) or \
                int(np.asarray(state.cycle).max()) >= max_cycles:
            break
    if not np.all(ejected == totals):
        lag = np.flatnonzero(ejected != totals)
        raise RuntimeError(
            f"NoC did not drain for variants {lag.tolist()}: "
            f"{ejected[lag].tolist()}/{totals[lag].tolist()} flits ejected "
            f"after {int(np.asarray(state.cycle).max())} cycles")
    link_bt = np.asarray(state.link_bt)
    link_flits = np.asarray(state.link_flits)
    inj_bt = np.asarray(state.inj_bt)
    cycles = np.asarray(state.cycle)
    drained_at = np.asarray(state.drained_at)
    return [_result(cfg, (link_bt[i], link_flits[i], inj_bt[i], ejected[i],
                          cycles[i], drained_at[i]), int(totals[i]))
            for i in range(b)]
