"""Fault injection for the NoC: soft errors, dead links, protection, retry.

The fault model (DESIGN.md "Fault model & protection") has two axes:

* **Transient faults** - a seeded per-link soft-error process flips one
  payload bit of a traversing flit with probability ``rate`` per flit-hop.
  Flips are XORed into the payload lanes *inside the router step*, before
  the BT recorders, so every downstream wire sees (and every BT figure
  prices) the corrupted signal. The flip schedule is a pure counter hash of
  ``(seed, cycle, link)``: replays are bit-exact, and a lower rate's flip
  set is a subset of a higher rate's (same hash, smaller threshold), which
  is what makes SLO degradation monotone in ``rate`` by construction.
  Sideband and packet-ledger lanes are never flipped - control corruption
  is out of scope; the model is payload corruption on data wires.
* **Permanent (hard) faults** - ``dead_links`` / ``dead_routers`` are
  masked out of the routing tables before the run
  (:func:`repro.noc.topology.fault_route_table`): the detour table routes
  around them where a path exists, and packets whose destination became
  unreachable are dropped *pre-injection* with ``STATUS_DROPPED`` - never
  silently lost; the conservation ledger accounts for them.

Protection (``protect = none | parity | crc8``) stamps each flit's code
into sideband bits 16.. at fuse time (:func:`protect_wire`) and re-derives
it at ejection; both codes are linear with zero init, so detection is a
function of the flip mask alone, never of the payload value - the gating
contract ("timing is schedule-determined") survives fault injection, and
one fault drain still prices every ordering transform's timing. Detected
corrupt packets are retransmitted (:func:`drain_with_retries`) under a
bounded retry budget with exponential ACK backoff; what remains corrupt
after the budget is ``STATUS_RETRY_EXHAUSTED``. Undetected flips deliver
silently - ``FaultDrain.corrupted`` and the ledger's ``silent_corrupt``
count them from the ground-truth flip ledger (an upper bound: double
flips on one bit cancel on the wire but still count as events).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.bits import popcount_hw
from repro.core.wire import (PROTECTION_BITS, protection_overhead_bits,
                             protection_syndrome_masks)
from .online import FAR_RELEASE, _drain_gated
from .sim import META_TAIL, SimResult, Traffic, Wire, _mc_array
from .topology import NocConfig, fault_route_table
from .traffic import filter_packets

__all__ = [
    "FaultModel", "StepFaults", "FaultDrain",
    "STATUS_DELIVERED", "STATUS_DROPPED", "STATUS_RETRY_EXHAUSTED",
    "STATUS_UNSENT",
    "protect_wire", "drain_with_retries", "simulate_faulty",
]

# Per-packet terminal status after a fault drain. The four values
# partition every packet id: the conservation identity
# ``delivered + dropped + retry_exhausted + unsent == injected_packets``
# is asserted into the ledger, not assumed.
STATUS_DELIVERED = 0        # tail ejected, last transmission clean/undetected
STATUS_DROPPED = 1          # destination unreachable under hard faults
STATUS_RETRY_EXHAUSTED = 2  # still detected-corrupt after the retry budget
STATUS_UNSENT = 3           # never delivered: gated off (shed), truncated,
                            # or its retry never completed


class StepFaults(NamedTuple):
    """Hashable static fault spec threaded into ``_make_step`` (and the
    ``lru_cache`` runner keys): one compiled step per distinct spec."""

    rate: float
    seed: int
    protect: str
    dead_links: Tuple[Tuple[int, int], ...]
    dead_routers: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One fault-injection scenario (deterministic given ``seed``).

    rate: per-flit-hop single-bit soft-error probability (NI links and
        router output links alike).
    protect: flit protection scheme (``repro.core.wire.PROTECTION_BITS``);
        its sideband bits are charged via ``protection_overhead_bits`` on
        *transmitted* flits, retries included.
    dead_links: ``(router, port)`` output links that are permanently dead
        (both directions of the physical channel die together).
    dead_routers: routers whose every channel is dead.
    max_retries: retransmission budget per packet beyond the first send.
    ack_latency: cycles from tail ejection to the NACK reaching the
        source NI (round 1 re-release = eject + ack_latency).
    backoff: multiplicative ACK-latency backoff per retry round.
    """

    rate: float = 0.0
    seed: int = 0
    protect: str = "none"
    dead_links: Tuple[Tuple[int, int], ...] = ()
    dead_routers: Tuple[int, ...] = ()
    max_retries: int = 3
    ack_latency: int = 32
    backoff: int = 2

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate!r}")
        if self.protect not in PROTECTION_BITS:
            raise ValueError(f"unknown protection scheme {self.protect!r}; "
                             f"supported: {sorted(PROTECTION_BITS)}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.ack_latency < 0:
            raise ValueError("ack_latency must be >= 0")
        if self.backoff < 1:
            raise ValueError("backoff must be >= 1")
        object.__setattr__(self, "dead_links",
                           tuple((int(r), int(p)) for r, p in self.dead_links))
        object.__setattr__(self, "dead_routers",
                           tuple(int(r) for r in self.dead_routers))

    @property
    def is_null(self) -> bool:
        """True when the model injects nothing and protects nothing - the
        scenario the bit-identity pin runs under."""
        return (self.rate == 0.0 and self.protect == "none"
                and not self.dead_links and not self.dead_routers)

    @property
    def has_hard_faults(self) -> bool:
        return bool(self.dead_links or self.dead_routers)

    def static(self) -> StepFaults:
        return StepFaults(float(self.rate), int(self.seed), self.protect,
                          self.dead_links, self.dead_routers)

    def overhead_bits(self, num_flits: int) -> int:
        return protection_overhead_bits(self.protect, num_flits)


def protect_wire(wire: Wire, protect: str, lanes: int) -> Wire:
    """Stamp each flit's protection code into sideband bits ``16..``.

    The code is computed over the payload lanes with the same syndrome
    masks the step's ejection check uses, so a clean flit always verifies.
    Protection bits ride the sideband word, which the BT recorders exclude
    by construction - their wire cost is charged analytically instead
    (``overhead_bits_per_value`` convention, like the O2 recovery index).
    """
    pbits = PROTECTION_BITS[protect]
    if not pbits:
        return wire
    masks = jnp.asarray(protection_syndrome_masks(protect, lanes), jnp.uint32)
    pay = wire.wire[..., :lanes]
    code = jnp.zeros(pay.shape[:-1], jnp.uint32)
    for j in range(pbits):
        pj = (popcount_hw(pay & masks[j]).sum(-1) & 1).astype(jnp.uint32)
        code = code | (pj << j)
    side = wire.wire[..., lanes] | (code << 16)
    rest = wire.wire[..., lanes + 1:]
    return Wire(jnp.concatenate([pay, side[..., None], rest], axis=-1),
                wire.length)


@dataclasses.dataclass
class FaultDrain:
    """One fault drain: cumulative recorders plus per-packet outcomes.

    ``sim`` accumulates link/NI BT over *every* transmission round - the
    honest wire cost of retransmission. ``status`` is the terminal
    per-packet outcome (``STATUS_*``); ``corrupted`` marks silent
    corruption among delivered packets (ground-truth flips the protection
    scheme never saw). ``ledger`` carries the conservation identity and
    the per-round breakdown the CI gate asserts.
    """

    sim: SimResult
    inj_time: np.ndarray        # (NP,) first-injection cycles
    eject_time: np.ndarray      # (NP,) last tail-ejection cycle, -1 never
    eject_counts: np.ndarray    # (NP+1,) tail ejections per packet id
    status: np.ndarray          # (NP,) int32 STATUS_*
    corrupted: np.ndarray       # (NP,) bool silent corruption
    retries: np.ndarray         # (NP,) int32 retransmissions used
    rounds: list                # per-round dict breakdown
    ledger: dict
    drained: bool


def _packet_endpoints(traffic: Traffic, mc_nodes: np.ndarray,
                      npkt: int) -> Tuple[np.ndarray, np.ndarray]:
    """(source_router, dest_router) per packet id (-1 for absent ids)."""
    meta = np.asarray(traffic.meta)
    pkt = np.asarray(traffic.pkt)
    dest = np.asarray(traffic.dest)
    valid = (np.arange(meta.shape[1])[None, :]
             < np.asarray(traffic.length)[:, None])
    tails = valid & ((meta & META_TAIL) > 0)
    rows, _ = np.nonzero(tails)
    psrc = np.full(npkt, -1, np.int64)
    pdst = np.full(npkt, -1, np.int64)
    ids = pkt[tails]
    psrc[ids] = np.asarray(mc_nodes, np.int64)[rows]
    pdst[ids] = dest[tails]
    return psrc, pdst


def _gate_counts(traffic: Traffic, keepf: np.ndarray,
                 inc: np.ndarray) -> np.ndarray:
    """Kept-flit count per (stream, gate) after a flit keep-mask: gate k
    still unlocks exactly its own surviving flits once ``filter_packets``
    compacts the stream (compaction is order-preserving)."""
    inc = np.asarray(inc, np.int64)
    m, k = inc.shape
    cum = np.cumsum(inc, axis=1)
    pos = np.arange(keepf.shape[1])
    out = np.zeros((m, k), np.int64)
    for i in range(m):
        gates = np.searchsorted(cum[i], pos, side="right")
        np.add.at(out[i], np.clip(gates[keepf[i]], 0, k - 1), 1)
    return out


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def _per_packet_gates(traffic: Traffic, release_per_pkt: np.ndarray,
                      npkt: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-packet gates for a retry round: gate j of stream m unlocks that
    stream's j-th retried packet at its NACK-derived release cycle,
    monotone along the stream (the NI retransmit queue is in-order). Gate
    counts are padded to a power of two so the compiled gated runner is
    reused across retry rounds of similar size."""
    meta = np.asarray(traffic.meta)
    pkt = np.asarray(traffic.pkt)
    length = np.asarray(traffic.length)
    m, t = meta.shape
    valid = np.arange(t)[None, :] < length[:, None]
    tails = valid & ((meta & META_TAIL) > 0)
    kmax = int(tails.sum(axis=1).max()) if m else 0
    kpad = _next_pow2(max(kmax, 1))
    inc = np.zeros((m, kpad), np.int64)
    rel = np.full((m, kpad), int(FAR_RELEASE), np.int64)
    for i in range(m):
        tpos = np.flatnonzero(tails[i])
        if not tpos.size:
            continue
        counts = np.diff(np.concatenate([[-1], tpos]))
        ids = pkt[i, tpos]
        inc[i, :ids.size] = counts
        rel[i, :ids.size] = release_per_pkt[ids]
    rel = np.maximum.accumulate(np.minimum(rel, int(FAR_RELEASE)), axis=1)
    return inc, rel


def drain_with_retries(cfg: NocConfig, traffic: Traffic, model: FaultModel, *,
                       mc_nodes: Union[np.ndarray, Sequence[int]],
                       release: Optional[np.ndarray] = None,
                       inc: Optional[np.ndarray] = None,
                       count_headers: bool = True, chunk: int = 2048,
                       max_cycles: int = 2_000_000,
                       allow_truncation: bool = False,
                       controller=None) -> FaultDrain:
    """Drain ``traffic`` under ``model`` with bounded retransmission.

    Round 0 sends everything the hard-fault reachability precheck admits
    (unreachable packets are ``STATUS_DROPPED`` up front, their flits
    removed from the wire and their gate budgets shrunk accordingly).
    After each round, packets whose protection check flagged a corrupt
    flit at ejection are rebuilt *from the clean source data* and
    re-released at ``eject + ack_latency * backoff**round`` through the
    same gated simulator, carrying the ``SimState`` forward so BT
    recorders, cycle count, and ledgers accumulate across rounds -
    retransmitted flits toggle real wires and the reported BT says so.
    ``max_cycles`` is a whole-drain budget across all rounds.

    release / inc: optional ``(M, K)`` gate schedule for round 0 (the
        closed-loop serving path); default is one gate per stream opening
        at cycle 0 (the offline drain).
    controller: optional admission controller, consulted during round 0
        only (retries of admitted packets are never shed).
    """
    npkt = int(traffic.num_packets)
    if npkt <= 0:
        raise ValueError("fault drains need Traffic with num_packets set")
    if np.asarray(traffic.words).ndim != 3:
        raise ValueError("fault drains take unbatched Traffic")
    m = int(traffic.length.shape[0])
    mc_nodes = np.asarray(mc_nodes, np.int64)
    spec = model.static()

    status = np.full(npkt, STATUS_UNSENT, np.int32)
    base = traffic
    if release is None:
        rel0 = np.zeros((m, 1), np.int64)
        inc0 = np.asarray(traffic.length, np.int64)[:, None]
    else:
        rel0 = np.asarray(release, np.int64)
        inc0 = np.asarray(inc, np.int64)

    # --- hard-fault reachability precheck: drop before injecting.
    dropped = np.zeros(npkt, bool)
    if model.has_hard_faults:
        _, reachable = fault_route_table(cfg, spec.dead_links,
                                         spec.dead_routers)
        psrc, pdst = _packet_endpoints(traffic, mc_nodes, npkt)
        present = psrc >= 0
        dead_r = np.zeros(cfg.num_routers, bool)
        if spec.dead_routers:
            dead_r[list(spec.dead_routers)] = True
        dropped = present & (~reachable[np.clip(psrc, 0, None),
                                        np.clip(pdst, 0, None)]
                             | dead_r[np.clip(psrc, 0, None)]
                             | dead_r[np.clip(pdst, 0, None)])
        if dropped.any():
            status[dropped] = STATUS_DROPPED
            meta = np.asarray(traffic.meta)
            pkt = np.asarray(traffic.pkt)
            valid = (np.arange(meta.shape[1])[None, :]
                     < np.asarray(traffic.length)[:, None])
            keepf = valid & ~dropped[np.clip(pkt, 0, npkt - 1)]
            inc0 = _gate_counts(traffic, keepf, inc0)
            base = filter_packets(traffic, ~dropped)

    # --- never-release prefilter: gates pinned at FAR_RELEASE (inferences
    # whose upstream phase failed) hold their flits forever, so those
    # packets must not count toward the drain target. They stay
    # STATUS_UNSENT. Skipped under a controller, whose gates all START at
    # the far sentinel and open as arrivals are admitted.
    if controller is None and (np.asarray(rel0) >= int(FAR_RELEASE)).any():
        inc_arr = np.asarray(inc0, np.int64)
        cum = np.cumsum(inc_arr, axis=1)
        ngates = inc_arr.shape[1]
        pos = np.arange(np.asarray(base.meta).shape[1])
        valid = pos[None, :] < np.asarray(base.length)[:, None]
        openg = np.asarray(rel0) < int(FAR_RELEASE)
        keepf = np.zeros_like(valid)
        for i in range(m):
            gates = np.searchsorted(cum[i], pos, side="right")
            keepf[i] = valid[i] & openg[i][np.clip(gates, 0, ngates - 1)]
        if not keepf[valid].all():
            keep_pkt = np.zeros(npkt, bool)
            keep_pkt[np.unique(np.asarray(base.pkt)[keepf])] = True
            inc0 = _gate_counts(base, keepf, inc_arr)
            base = filter_packets(base, keep_pkt)

    sent = np.zeros(npkt, bool)
    pkt0 = np.asarray(base.pkt)
    valid0 = (np.arange(pkt0.shape[1])[None, :]
              < np.asarray(base.length)[:, None])
    sent[np.unique(pkt0[valid0])] = True

    cur, cur_rel, cur_inc = base, rel0, inc0
    state = None
    prev_flip = np.zeros(npkt, np.int64)
    prev_bad = np.zeros(npkt, np.int64)
    prev_ep = np.zeros(npkt, np.int64)
    final_bad = np.zeros(npkt, np.int64)   # detections in the final round
    last_dep = np.zeros(npkt, np.int64)    # ejections in the final round
    final_flip = np.zeros(npkt, np.int64)
    retries = np.zeros(npkt, np.int32)
    tx_mask = sent.copy()                  # packets transmitted this round
    total_tx_flits = 0
    rounds = []
    drained = True
    res = inj_t = ej_t = ep_full = None

    for rnd in range(model.max_retries + 1):
        total_tx_flits += int(np.asarray(cur.length).sum())
        res, inj_t, ej_t, ep_full, rnd_drained, state = _drain_gated(
            cfg, cur, mc_nodes, cur_rel, cur_inc,
            count_headers=count_headers, chunk=chunk, max_cycles=max_cycles,
            allow_truncation=allow_truncation, faults=spec, state=state,
            controller=controller if rnd == 0 else None)
        drained = drained and rnd_drained
        flip_now = np.asarray(state.flip_pkt)[:npkt].astype(np.int64)
        bad_now = np.asarray(state.bad_pkt)[:npkt].astype(np.int64)
        ep_now = ep_full[:npkt].astype(np.int64)
        dflip = flip_now - prev_flip
        dbad = bad_now - prev_bad
        dep = ep_now - prev_ep
        prev_flip, prev_bad, prev_ep = flip_now, bad_now, ep_now
        tx = np.flatnonzero(tx_mask)
        final_bad[tx] = dbad[tx]
        final_flip[tx] = dflip[tx]
        last_dep[tx] = dep[tx]
        bad_ids = tx[dbad[tx] > 0]
        rounds.append({
            "round": rnd,
            "packets": int(tx.size),
            "flits": int(np.asarray(cur.length).sum()),
            "flip_events": int(dflip.sum()),
            "detected_bad_flits": int(dbad.sum()),
            "corrupt_packets": int(bad_ids.size),
            "drain_cycle": res.drain_cycle,
        })
        if (controller is not None
                and getattr(controller, "restart_needed", False)):
            # Admission restart protocol: the caller replays the whole
            # fault drain with the enlarged shed set; everything below is
            # discarded.
            drained = False
            break
        if not rnd_drained or not bad_ids.size or rnd == model.max_retries:
            break
        retries[bad_ids] += 1
        cur = filter_packets(base, bad_ids)
        delay = model.ack_latency * model.backoff ** rnd
        per_pkt_rel = np.full(npkt, int(FAR_RELEASE), np.int64)
        per_pkt_rel[bad_ids] = ej_t[bad_ids].astype(np.int64) + delay
        cur_inc, cur_rel = _per_packet_gates(cur, per_pkt_rel, npkt)
        tx_mask = np.zeros(npkt, bool)
        tx_mask[bad_ids] = True
        # Re-arm the injection pointers for the round's fresh wire; every
        # other leaf (recorders, ledgers, link state, cycle) carries over.
        state = state._replace(inj_ptr=jnp.zeros_like(state.inj_ptr))

    delivered = sent & (last_dep > 0) & (final_bad == 0)
    exhausted = sent & (last_dep > 0) & (final_bad > 0)
    status[delivered] = STATUS_DELIVERED
    status[exhausted] = STATUS_RETRY_EXHAUSTED
    corrupted = delivered & (final_flip > 0)

    counts = {
        "delivered": int((status == STATUS_DELIVERED).sum()),
        "dropped": int((status == STATUS_DROPPED).sum()),
        "retry_exhausted": int((status == STATUS_RETRY_EXHAUSTED).sum()),
        "unsent": int((status == STATUS_UNSENT).sum()),
    }
    ledger = {
        "injected_packets": npkt,
        **counts,
        "conservation_ok": sum(counts.values()) == npkt,
        "silent_corrupt": int(corrupted.sum()),
        "flip_events": int(prev_flip.sum()),
        "detected_bad_flits": int(prev_bad.sum()),
        "tail_ejections": int(prev_ep.sum()),
        "retried_packets": int((retries > 0).sum()),
        "total_retries": int(retries.sum()),
        "transmission_rounds": len(rounds),
        "transmitted_flits": total_tx_flits,
        "protection_overhead_bits":
            protection_overhead_bits(model.protect, total_tx_flits),
        "drained": drained,
    }
    sim = dataclasses.replace(res, injected=total_tx_flits)
    return FaultDrain(sim=sim, inj_time=inj_t, eject_time=ej_t,
                      eject_counts=ep_full, status=status,
                      corrupted=corrupted, retries=retries, rounds=rounds,
                      ledger=ledger, drained=drained)


def simulate_faulty(cfg: NocConfig, traffic: Traffic, model: FaultModel, *,
                    mc_nodes: Optional[Sequence[int]] = None,
                    count_headers: bool = True, chunk: int = 2048,
                    max_cycles: int = 2_000_000,
                    allow_truncation: bool = False) -> FaultDrain:
    """Offline fault drain: every gate open at cycle 0 (the fault-aware
    counterpart of :func:`repro.noc.sim.simulate`, one retry loop around
    the same gated step). ``model.is_null`` reproduces ``simulate``'s
    BT/drain figures exactly - the bit-identity pin in the test suite."""
    m = int(traffic.length.shape[0])
    nodes = np.asarray(_mc_array(cfg, traffic, m, batched=False))
    return drain_with_retries(
        cfg, traffic, model, mc_nodes=nodes, count_headers=count_headers,
        chunk=chunk, max_cycles=max_cycles, allow_truncation=allow_truncation)
