"""Deterministic, shard-addressable synthetic data pipelines.

Every batch is a pure function of (stream seed, step, shard index) via
threefry counters - the property the fault-tolerance story relies on: any
host can regenerate any other host's shard after an elastic re-mesh or a
straggler eviction, with no data-service round trip (DESIGN.md Sec. 6).

Token streams are Zipf-distributed (text-like marginal statistics matter
for the paper's BT analyses - uniform random tokens would understate bit
correlations). Glyph images give LeNet/DarkNet a trainable task without any
offline dataset: procedural digit-like strokes with noise and affine jitter.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStream", "glyph_batch", "GLYPHS"]


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Zipf-ish LM token stream with next-token targets."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch(self, step: int, shard: int = 0, num_shards: int = 1):
        """Returns (tokens, targets, mask) for one shard of one step.

        The global batch is a pure function of (seed, step); a shard is a
        row slice of it. This makes shard assignments independent of the
        shard COUNT - the invariant elastic re-meshing needs (a job that
        shrinks from 32 to 16 data shards re-covers the identical global
        batch), and any host can regenerate any other host's shard.
        """
        if self.global_batch % num_shards:
            raise ValueError("global_batch must divide by num_shards")
        b = self.global_batch // num_shards
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        # inverse-CDF Zipf over the vocab (vectorized, no rejection)
        u = jax.random.uniform(key, (self.global_batch, self.seq_len + 1),
                               minval=1e-6)
        ranks = jnp.exp(jnp.log(u) * (-1.0 / (self.zipf_a - 1.0)))
        toks = jnp.clip(ranks.astype(jnp.int32) - 1, 0, self.vocab - 1)
        toks = toks[shard * b:(shard + 1) * b]
        tokens, targets = toks[:, :-1], toks[:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        return tokens, targets, mask


# 7-segment-style glyph templates for the 10 classes (rows of 5x3 cells).
_SEGS = {
    0: "111101101101111", 1: "010010010010010", 2: "111001111100111",
    3: "111001111001111", 4: "101101111001001", 5: "111100111001111",
    6: "111100111101111", 7: "111001001001001", 8: "111101111101111",
    9: "111101111001111",
}
GLYPHS = np.stack([
    np.array([int(c) for c in _SEGS[d]], np.float32).reshape(5, 3)
    for d in range(10)])


def glyph_batch(key: jax.Array, batch: int, hw: int = 32, channels: int = 1):
    """Procedural digit-like images: upsampled glyph + jitter + noise.

    Returns (images (B, hw, hw, channels) float32 in [0, 1], labels (B,)).
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    labels = jax.random.randint(k1, (batch,), 0, 10)
    glyphs = jnp.asarray(GLYPHS)[labels]                     # (B, 5, 3)
    up = hw // 8
    img = jax.image.resize(glyphs, (batch, 5 * up, 3 * up), "nearest")
    ph = hw - 5 * up
    pw = hw - 3 * up
    img = jnp.pad(img, ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2)))
    # jitter: random shift by up to +-2 px via roll
    sh = jax.random.randint(k2, (batch, 2), -2, 3)
    def roll_one(im, s):
        return jnp.roll(im, (s[0], s[1]), axis=(0, 1))
    img = jax.vmap(roll_one)(img, sh)
    img = img * jax.random.uniform(k3, (batch, 1, 1), minval=0.7, maxval=1.0)
    img = img + 0.15 * jax.random.normal(k4, img.shape)
    img = jnp.clip(img, 0.0, 1.0)[..., None]
    if channels > 1:
        img = jnp.repeat(img, channels, axis=-1)
    return img.astype(jnp.float32), labels
