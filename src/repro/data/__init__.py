from .pipeline import TokenStream, glyph_batch, GLYPHS

__all__ = ["TokenStream", "glyph_batch", "GLYPHS"]
