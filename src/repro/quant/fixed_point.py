"""Fixed-point-8 quantization (the paper's fixed-8 data format).

The paper transmits 8-bit fixed-point weights/activations over 128-bit links
(16 values per flit). We use symmetric per-tensor fixed-point: a power-of-two
scale (true fixed point, not affine int8), which is both what "fixed-point"
means in the NoC literature and what keeps dequantization a pure shift.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["FixedPointParams", "quantize_fixed8", "dequantize_fixed8"]


class FixedPointParams(NamedTuple):
    values: jax.Array    # int8 payload (two's complement on the wire)
    frac_bits: jax.Array # scalar int32: number of fractional bits


def quantize_fixed8(x: jax.Array) -> FixedPointParams:
    """Quantize float data to Q(7-f).f fixed point, f chosen per tensor.

    f = 7 - ceil(log2(max|x|)) clamped to [0, 7]: the largest power-of-two
    scale under which the tensor does not overflow int8.
    """
    amax = jnp.max(jnp.abs(x))
    amax = jnp.maximum(amax, jnp.float32(1e-12))
    int_bits = jnp.ceil(jnp.log2(amax)).astype(jnp.int32)
    frac_bits = jnp.clip(7 - int_bits, 0, 7)
    scale = jnp.exp2(frac_bits.astype(jnp.float32))
    q = jnp.clip(jnp.round(x * scale), -128, 127).astype(jnp.int8)
    return FixedPointParams(q, frac_bits)


def dequantize_fixed8(p: FixedPointParams) -> jax.Array:
    scale = jnp.exp2(-p.frac_bits.astype(jnp.float32))
    return p.values.astype(jnp.float32) * scale
