from .fixed_point import quantize_fixed8, dequantize_fixed8, FixedPointParams

__all__ = ["quantize_fixed8", "dequantize_fixed8", "FixedPointParams"]
