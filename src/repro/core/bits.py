"""Bit-level primitives: unsigned views, SWAR popcount, transition counts.

These are the scalar building blocks of the paper's technique: every ordering
decision is keyed on the '1'-bit count (popcount) of a transmitted value, and
every evaluation metric is a count of 0<->1 transitions between consecutive
flits on a link.

TPU note: there is no hardware popcount instruction on the VPU, so we use the
classic SWAR (SIMD-within-a-register) bit-twiddling reduction - exactly the
circuit the paper's ordering unit implements in RTL (Fig. 14). The same code
path is used by the pure-jnp reference and (in vector form) by the Pallas
kernel in ``repro.kernels.popcount``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "unsigned_view",
    "popcount",
    "popcount_hw",
    "popcount32",
    "popcount8",
    "bit_width",
    "bits_of",
    "transitions",
]

_UNSIGNED = {
    jnp.dtype(jnp.float32): jnp.uint32,
    jnp.dtype(jnp.int32): jnp.uint32,
    jnp.dtype(jnp.uint32): jnp.uint32,
    jnp.dtype(jnp.bfloat16): jnp.uint16,
    jnp.dtype(jnp.float16): jnp.uint16,
    jnp.dtype(jnp.int16): jnp.uint16,
    jnp.dtype(jnp.uint16): jnp.uint16,
    jnp.dtype(jnp.int8): jnp.uint8,
    jnp.dtype(jnp.uint8): jnp.uint8,
}


def bit_width(dtype) -> int:
    """Number of bits in one element of ``dtype``."""
    return jnp.dtype(dtype).itemsize * 8


def unsigned_view(values: jax.Array) -> jax.Array:
    """Reinterpret ``values`` as the same-width unsigned integer type.

    This is a bitcast (no numeric conversion): the float32 ``-0.0`` maps to
    ``0x80000000``, matching what travels on the physical link.
    """
    dt = jnp.dtype(values.dtype)
    if dt not in _UNSIGNED:
        raise TypeError(f"no unsigned view for dtype {dt}")
    target = _UNSIGNED[dt]
    if dt == jnp.dtype(target):
        return values
    return jax.lax.bitcast_convert_type(values, target)


def popcount32(x: jax.Array) -> jax.Array:
    """SWAR popcount of a uint32 array. Returns uint32 counts in [0, 32]."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


def popcount8(x: jax.Array) -> jax.Array:
    """SWAR popcount of a uint8 array. Returns uint8 counts in [0, 8]."""
    x = x.astype(jnp.uint8)
    x = x - ((x >> 1) & jnp.uint8(0x55))
    x = (x & jnp.uint8(0x33)) + ((x >> 2) & jnp.uint8(0x33))
    return (x + (x >> 4)) & jnp.uint8(0x0F)


def popcount(values: jax.Array) -> jax.Array:
    """'1'-bit count of each element, via its unsigned bit pattern.

    Works for any dtype with an unsigned view (float32, bf16, int8, ...).
    Returns an int32 array of the same shape.
    """
    u = unsigned_view(values)
    nbits = bit_width(u.dtype)
    if nbits == 8:
        return popcount8(u).astype(jnp.int32)
    # Promote 16-bit lanes to 32-bit; popcount32 handles both.
    return popcount32(u.astype(jnp.uint32)).astype(jnp.int32)


def popcount_hw(values: jax.Array) -> jax.Array:
    """'1'-bit count via :func:`jax.lax.population_count`.

    Numerically identical to :func:`popcount` (the SWAR form above remains
    the oracle, mirroring the paper's RTL circuit); this variant lowers to
    the backend's native popcount when one exists and to an XLA-chosen
    bit-twiddling expansion otherwise, which is what the NoC simulator's
    per-cycle BT recorder wants on its hot path. Returns int32 counts.
    """
    u = unsigned_view(values)
    return jax.lax.population_count(u).astype(jnp.int32)


def bits_of(values: jax.Array) -> jax.Array:
    """Expand each element into its bits, MSB first.

    Returns a uint8 array of shape ``values.shape + (nbits,)``. Used for the
    bit-position distribution analyses (paper Figs. 10-11).
    """
    u = unsigned_view(values)
    nbits = bit_width(u.dtype)
    u32 = u.astype(jnp.uint32)
    shifts = jnp.arange(nbits - 1, -1, -1, dtype=jnp.uint32)
    return ((u32[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.uint8)


def transitions(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-element count of toggling bits between ``a`` and ``b``.

    A bit transition is a 0->1 or 1->0 change on one wire between two
    consecutive flits (paper Sec. III-A); XOR marks toggling wires and the
    popcount tallies them.
    """
    ua, ub = unsigned_view(a), unsigned_view(b)
    return popcount(ua ^ ub)
