"""Flit packing: turning value streams into link flits.

A flit is the atomic unit that crosses one NoC link in one cycle. The paper
uses two link widths (Sec. V-B): a 512-bit link carrying 16 float-32 values
and a 128-bit link carrying 16 fixed-8 values; the no-NoC study (Tab. I) uses
8-value flits. We represent a flit stream as a 2D array of unsigned words,
``(num_flits, lanes)``, one row per flit - the bit pattern of row ``i`` is what
the wires hold on cycle ``i``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bits import unsigned_view, bit_width

__all__ = ["FlitStream", "pack", "pack_paired", "unpack", "num_flits"]


class FlitStream(NamedTuple):
    """A stream of flits.

    words: ``(num_flits, lanes)`` unsigned array - the raw link payload.
    lanes: number of values per flit.
    value_bits: bit width of one value (32 for float-32, 8 for fixed-8).
    """

    words: jax.Array
    lanes: int
    value_bits: int

    @property
    def flit_bits(self) -> int:
        return self.lanes * self.value_bits


def num_flits(n_values: int, lanes: int) -> int:
    return -(-n_values // lanes)


def pack(values: jax.Array, lanes: int) -> FlitStream:
    """Pack a flat value stream into ``lanes``-wide flits, zero-padded.

    Zero padding matches the paper (Sec. V-A: "Zeros are padded when the
    weight's kernel size doesn't exactly match the flit size").
    """
    u = unsigned_view(values.reshape(-1))
    n = u.shape[0]
    nf = num_flits(n, lanes)
    pad = nf * lanes - n
    u = jnp.pad(u, (0, pad))
    return FlitStream(u.reshape(nf, lanes), lanes, bit_width(u.dtype))


def pack_paired(inputs: jax.Array, weights: jax.Array, lanes: int) -> FlitStream:
    """Pack (input, weight) pairs: inputs in the left half-flit, weights right.

    This is the paper's Fig. 2 layout: each flit carries ``lanes//2`` inputs
    followed by ``lanes//2`` weights, so the weight half of consecutive flits
    toggles against itself and the input half against itself.
    """
    if lanes % 2:
        raise ValueError("paired packing needs an even lane count")
    half = lanes // 2
    ui = unsigned_view(inputs.reshape(-1))
    uw = unsigned_view(weights.reshape(-1))
    if ui.shape != uw.shape:
        raise ValueError("inputs and weights must have the same element count")
    if ui.dtype != uw.dtype:
        raise ValueError("inputs and weights must share a dtype")
    n = ui.shape[0]
    nf = num_flits(n, half)
    pad = nf * half - n
    ui = jnp.pad(ui, (0, pad)).reshape(nf, half)
    uw = jnp.pad(uw, (0, pad)).reshape(nf, half)
    words = jnp.concatenate([ui, uw], axis=1)
    return FlitStream(words, lanes, bit_width(words.dtype))


def unpack(stream: FlitStream, n_values: int, dtype) -> jax.Array:
    """Invert :func:`pack` - recover the first ``n_values`` values."""
    flat = stream.words.reshape(-1)[:n_values]
    target = jnp.dtype(dtype)
    if flat.dtype == target:
        return flat
    return jax.lax.bitcast_convert_type(flat, target)
