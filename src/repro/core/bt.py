"""Bit-transition metrics and the paper's expected-BT model (Sec. III).

Two families of function live here:

* **Measured BT** - exact transition counts on a concrete flit stream
  (what the paper's "BT recording" hardware of Fig. 8 tallies).
* **Expected BT** - the analytical i.i.d.-bit model of Eqs. (1)-(3), which is
  what the ordering strategy provably optimizes. ``pairing_objective`` is the
  F = sum(x_i * y_i) of Eq. (4) that descending ordering maximizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .bits import popcount, transitions, bits_of
from .flits import FlitStream

__all__ = [
    "bt_between",
    "bt_stream",
    "bt_per_flit",
    "bt_per_position",
    "ones_prob_per_position",
    "expected_bt_pair",
    "expected_bt_stream",
    "pairing_objective",
    "reduction_rate",
]


# ---------------------------------------------------------------------------
# Measured BT
# ---------------------------------------------------------------------------

def bt_between(flit_a: jax.Array, flit_b: jax.Array) -> jax.Array:
    """Total bit transitions when ``flit_b`` follows ``flit_a`` on the link."""
    return jnp.sum(transitions(flit_a, flit_b))


def bt_stream(stream: FlitStream) -> jax.Array:
    """Total BTs over a stream of consecutive flits (sum over row pairs)."""
    w = stream.words
    return jnp.sum(transitions(w[:-1], w[1:]))


def bt_per_flit(stream: FlitStream) -> jax.Array:
    """Average BTs per flit boundary - the paper's Tab. I metric."""
    w = stream.words
    n_pairs = max(w.shape[0] - 1, 1)
    return bt_stream(stream) / n_pairs


def bt_per_position(stream: FlitStream) -> jax.Array:
    """Probability of a transition at each bit position within a value.

    Paper Figs. 10-11 (bottom): x-axis is the bit position inside one value
    (sign / exponent / mantissa for float-32), y-axis the transition
    probability, averaged over lanes and flit boundaries.
    """
    bits = bits_of(stream.words)              # (nf, lanes, nbits)
    tog = bits[:-1] ^ bits[1:]
    return jnp.mean(tog.astype(jnp.float32), axis=(0, 1))


def ones_prob_per_position(stream: FlitStream) -> jax.Array:
    """Probability of a '1' at each bit position (paper Figs. 10-11 top)."""
    bits = bits_of(stream.words)
    return jnp.mean(bits.astype(jnp.float32), axis=(0, 1))


# ---------------------------------------------------------------------------
# Expected BT (Eqs. 1-3) under the i.i.d.-bit-position model
# ---------------------------------------------------------------------------

def expected_bt_pair(x: jax.Array, y: jax.Array, value_bits: int) -> jax.Array:
    """Eq. (2), generalized from 32 to ``value_bits`` = b:

        E = b * P(t) = x + y - 2xy/b          (b = 32 -> x + y - xy/16)

    ``x``/``y`` are '1'-bit counts of the two values sharing a lane.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    return x + y - 2.0 * x * y / value_bits


def expected_bt_stream(stream: FlitStream) -> jax.Array:
    """Eq. (3) summed over every consecutive flit pair of the stream."""
    c = popcount(stream.words)                # (nf, lanes)
    e = expected_bt_pair(c[:-1], c[1:], stream.value_bits)
    return jnp.sum(e)


def pairing_objective(x_counts: jax.Array, y_counts: jax.Array) -> jax.Array:
    """F = sum_i x_i * y_i (Eq. 4). Ordering maximizes this; BT ~ const - 2F/b."""
    return jnp.sum(x_counts.astype(jnp.float32) * y_counts.astype(jnp.float32))


def reduction_rate(baseline: jax.Array, optimized: jax.Array) -> jax.Array:
    """BT reduction rate = 1 - optimized/baseline (paper's headline metric)."""
    return 1.0 - optimized / baseline
