"""The paper's contribution: '1'-bit-count-based data transmission ordering.

Three orderings are provided:

* :func:`descending_order` - sort a value stream by popcount, descending.
  Packed row-major into flits this realizes the paper's Fig. 9 layout; the
  ``fill='interleave'`` variant deals the sorted stream round-robin across
  flits, which realizes the exact ``x1 >= y1 >= x2 >= y2 ...`` interleave the
  Sec. III-B proof shows is globally optimal for a flit pair.
* :func:`affiliated_order` (O1) - weights sorted by their own popcount,
  inputs carried along so (input, weight) pairs stay matched. Zero recovery
  cost: convolution / linear contractions are order-invariant (Fig. 5).
* :func:`separated_order` (O2) - weights and inputs each sorted by their own
  popcount. Larger BT win, at the cost of a minimal-bit-width permutation
  index for recovery.
* :func:`min_hamming_order` / :func:`separated_min_hamming_order` (O3) and
  :func:`affiliated_min_hamming_order` (O3a) - chain values by greedy
  multi-start nearest-neighbor *Hamming distance* with beam lookahead
  (``repro.kernels.min_hamming``), then deal the chain column-major across
  the window's flits so chain neighbors share a wire lane on consecutive
  flits. Popcount sorting is a proxy for the consecutive-flit Hamming
  objective; O3 optimizes it directly.

All orderings accept a ``window`` size: the ordering unit at a memory
controller only holds a packet's worth of data, so sorting happens inside
consecutive windows of the stream (window = the packet payload). ``window =
None`` sorts the full stream, which matches the no-NoC study of Sec. V-A.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .bits import popcount

__all__ = [
    "Ordered",
    "PairedOrdered",
    "descending_perm",
    "descending_order",
    "affiliated_order",
    "separated_order",
    "min_hamming_perm",
    "min_hamming_order",
    "affiliated_min_hamming_order",
    "separated_min_hamming_order",
    "inverse_permutation",
    "apply_permutation",
    "index_overhead_bits",
]


class Ordered(NamedTuple):
    values: jax.Array     # reordered stream, same multiset as the input
    perm: jax.Array       # values = input[perm]


class PairedOrdered(NamedTuple):
    inputs: jax.Array
    weights: jax.Array
    input_perm: jax.Array
    weight_perm: jax.Array


def _windowed(n: int, window: Optional[int]) -> tuple[int, int]:
    """Resolve (num_windows, window) for a length-n stream."""
    if window is None or window >= n:
        return 1, n
    if n % window:
        raise ValueError(
            f"stream length {n} is not a multiple of window {window}; "
            "pad the stream before ordering (the packetizer does this)")
    return n // window, window


def pad_to_window(values: jax.Array, window: Optional[int]) -> jax.Array:
    """Zero-pad a flat stream to the next packet (window) boundary.

    This is what the memory-controller packetizer does before the ordering
    unit sees the data (paper Sec. V-A pads kernels to flit boundaries).
    """
    flat = values.reshape(-1)
    if window is None:
        return flat
    pad = (-flat.shape[0]) % window
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


def descending_perm(values: jax.Array, window: Optional[int] = None,
                    tiebreak: str = "stable") -> jax.Array:
    """Permutation that sorts ``values`` by '1'-bit count, descending.

    tiebreak='stable' keeps original order among equal counts (the paper's
    proof only constrains the count order, and this is the cheapest
    hardware). tiebreak='pattern' additionally orders equal-count values by
    their bit pattern - the paper does not specify its tie-break, and
    pattern-clustering reproduces its float-32 reduction band (EXPERIMENTS.md
    SSTab.I); it costs one wider comparator in the ordering unit.

    Windowed if requested. Returns flat indices into the (zero-padded)
    stream; the stream is padded to a window multiple first, so the
    permutation length may exceed the input length.
    """
    from .bits import unsigned_view

    flat = pad_to_window(values, window)
    n = flat.shape[0]
    nw, w = _windowed(n, window)
    counts = popcount(flat).reshape(nw, w)
    if tiebreak == "pattern":
        u = unsigned_view(flat).reshape(nw, w)
        # secondary key first: pattern descending (~u ascending), then a
        # stable primary sort on the count.
        p1 = jnp.argsort(~u, axis=1)
        c1 = jnp.take_along_axis(counts, p1, axis=1)
        p2 = jnp.argsort(-c1, axis=1)
        perm = jnp.take_along_axis(p1, p2, axis=1)
    elif tiebreak == "stable":
        # argsort ascending on the negated count = descending on the count;
        # jnp.argsort is stable, preserving original order among ties.
        perm = jnp.argsort(-counts, axis=1)
    else:
        raise ValueError(f"unknown tiebreak {tiebreak!r}")
    offset = (jnp.arange(nw, dtype=perm.dtype) * w)[:, None]
    return (perm + offset).reshape(-1)


def apply_permutation(values: jax.Array, perm: jax.Array) -> jax.Array:
    return values.reshape(-1)[perm]


def inverse_permutation(perm: jax.Array) -> jax.Array:
    """inv with inv[perm] = arange; used to de-order separated streams."""
    n = perm.shape[0]
    inv = jnp.zeros((n,), dtype=perm.dtype)
    return inv.at[perm].set(jnp.arange(n, dtype=perm.dtype))


def descending_order(
    values: jax.Array,
    window: Optional[int] = None,
    fill: str = "rowmajor",
    lanes: Optional[int] = None,
    tiebreak: str = "stable",
) -> Ordered:
    """Sort a stream by popcount descending (the paper's core transform).

    fill='rowmajor': flit k gets sorted values [k*lanes, (k+1)*lanes) -
        the paper's Fig. 9 layout (each row descending, stream descending).
    fill='interleave': the sorted stream is dealt round-robin across the
        window's flits, realizing x1>=y1>=x2>=y2... between every consecutive
        flit pair (the Sec. III-B optimal interleave). Requires ``lanes``.
    """
    flat = pad_to_window(values, window)
    perm = descending_perm(flat, window, tiebreak)
    if fill == "rowmajor":
        return Ordered(flat[perm], perm)
    if fill != "interleave":
        raise ValueError(f"unknown fill {fill!r}")
    if lanes is None:
        raise ValueError("fill='interleave' needs the flit lane count")
    n = flat.shape[0]
    nw, w = _windowed(n, window)
    if w % lanes:
        raise ValueError("window must be a multiple of lanes for interleave")
    flits_per_window = w // lanes
    # Deal the sorted window column-major: lane j of flit f receives sorted
    # element j*flits_per_window + f, so each lane of consecutive flits holds
    # popcount-adjacent values (x1 >= y1 >= x2 >= y2 ... per lane pair).
    dealt = perm.reshape(nw, lanes, flits_per_window).transpose(0, 2, 1)
    dealt = dealt.reshape(-1)
    return Ordered(flat[dealt], dealt)


def affiliated_order(
    inputs: jax.Array,
    weights: jax.Array,
    window: Optional[int] = None,
    tiebreak: str = "stable",
) -> PairedOrdered:
    """O1: order (input, weight) pairs by the *weight's* popcount.

    The pairing survives, so a convolution/linear layer consuming the stream
    produces bit-identical partial sums with no de-ordering (paper Fig. 5).
    """
    if weights.size != inputs.size:
        raise ValueError("affiliated ordering needs paired streams of equal length")
    wflat = pad_to_window(weights, window)
    iflat = pad_to_window(inputs, window)
    perm = descending_perm(wflat, window, tiebreak)
    return PairedOrdered(iflat[perm], wflat[perm], perm, perm)


def separated_order(
    inputs: jax.Array,
    weights: jax.Array,
    window: Optional[int] = None,
    tiebreak: str = "stable",
) -> PairedOrdered:
    """O2: order inputs and weights independently, each by its own popcount.

    Both flit halves see reduced BT; the consumer needs the permutations (or
    their composition) to re-pair - see :func:`index_overhead_bits`.
    """
    wflat = pad_to_window(weights, window)
    iflat = pad_to_window(inputs, window)
    wperm = descending_perm(wflat, window, tiebreak)
    iperm = descending_perm(iflat, window, tiebreak)
    return PairedOrdered(iflat[iperm], wflat[wperm], iperm, wperm)


# --- O3: minimum-Hamming-distance chaining --------------------------------

DEFAULT_BEAM = 2
DEFAULT_STARTS = 8


def min_hamming_perm(values: jax.Array, window: Optional[int] = None,
                     beam: int = DEFAULT_BEAM,
                     starts: int = DEFAULT_STARTS) -> jax.Array:
    """Chain permutation minimizing consecutive Hamming distance per window.

    The windowed analog of :func:`descending_perm` for O3: each window of
    the (zero-padded) stream is chained by the multi-start greedy
    beam-lookahead kernel (``repro.kernels.min_hamming``); exact-zero
    values always land at the window tail in original order, and the chain
    never costs more than that zeros-to-tail identity order. Returns flat
    indices into the padded stream; this is the *logical* chain order - the
    flit-deal layout is applied by :func:`min_hamming_order`.
    """
    from repro.kernels.min_hamming import min_hamming_chain

    flat = pad_to_window(values, window)
    n = flat.shape[0]
    nw, w = _windowed(n, window)
    res = min_hamming_chain(flat.reshape(nw, w), beam=beam, starts=starts)
    offset = (jnp.arange(nw, dtype=res.perm.dtype) * w)[:, None]
    return (res.perm + offset).reshape(-1)


def _deal_chain(perm: jax.Array, z: jax.Array, lanes: int) -> jax.Array:
    """Deal per-window chain perms (nw, Wp) column-major over the flits.

    Chained (non-zero) value ``i`` goes to flit ``i % F`` lane ``i // F``
    with ``F = ceil(z / lanes)`` - consecutive chain elements share a wire
    lane on consecutive flits, which converts the chain objective into the
    wire's actual per-lane toggle cost. Restricting the deal to the first
    ``F`` flits (not the window's full flit count) keeps every flit beyond
    ``ceil(z / lanes)`` all-zero, preserving the result-phase packet-slicing
    contract for partially filled windows. Padding zeros fill the free slots
    in ascending order. Wp must be a multiple of ``lanes``.
    """
    wp = perm.shape[1]
    idx = jnp.arange(wp, dtype=jnp.int32)

    def one(p, zr):
        fr = jnp.maximum(-(-zr // lanes), 1)
        nzslot = (idx % fr) * lanes + idx // fr
        used = jnp.zeros((wp,), jnp.bool_).at[
            jnp.where(idx < zr, nzslot, wp)].set(True, mode="drop")
        free = jnp.argsort(used).astype(jnp.int32)     # unused slots, ascending
        slot = jnp.where(idx < zr, nzslot, free[jnp.maximum(idx - zr, 0)])
        return jnp.zeros((wp,), p.dtype).at[slot].set(p)

    return jax.vmap(one)(perm, z)


def min_hamming_order(
    values: jax.Array,
    window: Optional[int] = None,
    lanes: Optional[int] = None,
    beam: int = DEFAULT_BEAM,
    starts: int = DEFAULT_STARTS,
) -> Ordered:
    """O3 single-stream ordering: chain each window by Hamming distance and
    deal the chain across the window's flits (see :func:`_deal_chain`).

    Each window is zero-padded up to a ``lanes`` multiple before chaining,
    so the returned values/perm cover ``ceil(w / lanes) * lanes`` slots per
    window (``pack`` then pads nothing). The perm indexes the flit-padded
    stream; invert with :func:`inverse_permutation` and slice windows back
    to ``w`` to recover the input bit-exactly.
    """
    from repro.kernels.min_hamming import min_hamming_chain

    if lanes is None:
        raise ValueError("min-Hamming ordering needs the flit lane count")
    flat = pad_to_window(values, window)
    n = flat.shape[0]
    nw, w = _windowed(n, window)
    wp = -(-w // lanes) * lanes
    padded = jnp.pad(flat.reshape(nw, w), ((0, 0), (0, wp - w)))
    res = min_hamming_chain(padded, beam=beam, starts=starts)
    dealt = _deal_chain(res.perm, res.nonzeros, lanes)
    offset = (jnp.arange(nw, dtype=dealt.dtype) * wp)[:, None]
    perm = (dealt + offset).reshape(-1)
    return Ordered(padded.reshape(-1)[perm], perm)


def affiliated_min_hamming_order(
    inputs: jax.Array,
    weights: jax.Array,
    window: Optional[int] = None,
    lanes: Optional[int] = None,
    beam: int = DEFAULT_BEAM,
    starts: int = DEFAULT_STARTS,
) -> PairedOrdered:
    """O3a: chain (input, weight) pairs by their *combined* Hamming distance.

    One permutation moves both streams, so pairing survives with zero
    recovery cost (like O1). The distance summed over both planes is
    exactly the per-lane-pair wire cost of the paired flit layout (input in
    the left half-flit, weight in the right). ``lanes`` is the per-half
    lane count the deal targets (``flit lanes // 2`` for paired packing).
    """
    from repro.kernels.min_hamming import min_hamming_chain

    if lanes is None:
        raise ValueError("min-Hamming ordering needs the flit lane count")
    if weights.size != inputs.size:
        raise ValueError(
            "affiliated ordering needs paired streams of equal length")
    wflat = pad_to_window(weights, window)
    iflat = pad_to_window(inputs, window)
    n = wflat.shape[0]
    nw, w = _windowed(n, window)
    wp = -(-w // lanes) * lanes
    pad = ((0, 0), (0, wp - w))
    wpad = jnp.pad(wflat.reshape(nw, w), pad)
    ipad = jnp.pad(iflat.reshape(nw, w), pad)
    res = min_hamming_chain((ipad, wpad), beam=beam, starts=starts)
    dealt = _deal_chain(res.perm, res.nonzeros, lanes)
    offset = (jnp.arange(nw, dtype=dealt.dtype) * wp)[:, None]
    perm = (dealt + offset).reshape(-1)
    return PairedOrdered(ipad.reshape(-1)[perm], wpad.reshape(-1)[perm],
                         perm, perm)


def separated_min_hamming_order(
    inputs: jax.Array,
    weights: jax.Array,
    window: Optional[int] = None,
    lanes: Optional[int] = None,
    beam: int = DEFAULT_BEAM,
    starts: int = DEFAULT_STARTS,
) -> PairedOrdered:
    """O3: chain inputs and weights independently, each by its own Hamming
    distance - the larger win, needing the O2-style recovery index."""
    oi = min_hamming_order(inputs, window=window, lanes=lanes, beam=beam,
                           starts=starts)
    ow = min_hamming_order(weights, window=window, lanes=lanes, beam=beam,
                           starts=starts)
    return PairedOrdered(oi.values, ow.values, oi.perm, ow.perm)


def index_overhead_bits(window: int) -> int:
    """Bits per value of the separated-ordering recovery index.

    A minimal-bit-width index addressing positions inside one ordering window
    (paper Sec. IV-C1: "just a minimal-bit-width index is required").
    """
    return max(1, (window - 1).bit_length())
