"""Core of the paper's contribution: bit-transition math + transmission ordering.

Public API:
    bits      - popcount / unsigned views / per-element transitions
    flits     - packing value streams into link flits
    bt        - measured + expected bit-transition metrics (Eqs. 1-3)
    ordering  - descending / affiliated (O1) / separated (O2) orderings
    wire      - composable WireTransform API used by the NoC and dist layers
    msr       - MSR 8b->5b flit compression codec (the compression knob)
"""
from . import bits, flits, bt, msr, ordering, wire
from .bits import popcount, popcount_hw, transitions
from .flits import FlitStream, pack, pack_paired, unpack
from .bt import (
    bt_stream, bt_per_flit, bt_between, expected_bt_pair, expected_bt_stream,
    pairing_objective, reduction_rate, bt_per_position, ones_prob_per_position,
)
from .ordering import (
    descending_order, affiliated_order, separated_order, descending_perm,
    inverse_permutation, apply_permutation, index_overhead_bits,
    Ordered, PairedOrdered,
)
from .wire import WireTransform, by_name as wire_transform, measure as measure_stream
from .msr import (
    MsrCompressed, compress as msr_compress, decompress as msr_decompress,
    msr_overhead_bits, msr_pack, msr_pack_paired,
)

__all__ = [
    "bits", "flits", "bt", "msr", "ordering", "wire",
    "MsrCompressed", "msr_compress", "msr_decompress",
    "msr_overhead_bits", "msr_pack", "msr_pack_paired",
    "popcount", "popcount_hw", "transitions",
    "FlitStream", "pack", "pack_paired", "unpack",
    "bt_stream", "bt_per_flit", "bt_between", "expected_bt_pair",
    "expected_bt_stream", "pairing_objective", "reduction_rate",
    "bt_per_position", "ones_prob_per_position",
    "descending_order", "affiliated_order", "separated_order",
    "descending_perm", "inverse_permutation", "apply_permutation",
    "index_overhead_bits", "Ordered", "PairedOrdered",
    "WireTransform", "wire_transform", "measure_stream",
]
