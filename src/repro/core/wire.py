"""WireTransform: the composable link-payload transform API.

A WireTransform is what an ordering unit at a memory controller (paper
Fig. 6) -- or, in the beyond-paper extension, at a TPU ICI boundary -- applies
to a value stream before it hits the wires. It captures the three paper
configurations:

    O0 (baseline)  -> IdentityTransform
    O1 (affiliated)-> AffiliatedTransform   (keyed on the weight stream)
    O2 (separated) -> SeparatedTransform

plus the interleaved optimal variant used in the beyond-paper study and the
beyond-paper min-Hamming orderings:

    O3  (separated min-Hamming)  -> MinHammingTransform
    O3a (affiliated min-Hamming) -> MinHammingAffiliatedTransform

All transforms are pure functions of the payload (jit-safe) and report their
recovery overhead so benchmarks can charge it honestly.
``overhead_bits_per_value(window, paired=...)`` distinguishes the paired
request phase (order-invariant contraction: only *re-pairing* needs bits,
so O1/O3a are free) from single-stream phases (results, lone weight
streams: any non-identity reorder needs a recovery index to restore
element order).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import numpy as np

from . import msr, ordering
from .flits import FlitStream, pack, pack_paired
from . import bt as bt_mod

__all__ = [
    "WireTransform",
    "IdentityTransform",
    "DescendingTransform",
    "AffiliatedTransform",
    "SeparatedTransform",
    "MinHammingTransform",
    "MinHammingAffiliatedTransform",
    "TRANSFORMS",
    "by_name",
    "measure",
    "PROTECTION_BITS",
    "protection_overhead_bits",
    "protection_syndrome_masks",
    "crc8_reference",
    "COMPRESSIONS",
    "compression_overhead_bits",
]


@dataclasses.dataclass(frozen=True)
class WireTransform:
    """Base: pack a paired (inputs, weights) stream into flits untouched."""

    name: str = "O0"
    window: Optional[int] = None
    tiebreak: str = "stable"   # "pattern" clusters equal-count values

    def overhead_bits_per_value(self, window: int, paired: bool = True) -> int:
        """Recovery bits the receiver needs per transmitted value.

        ``paired=True`` is the request phase: (input, weight) pairs feed an
        order-invariant contraction, so only *re-pairing* information is
        chargeable. ``paired=False`` is a single stream (result phase, lone
        weight stream) whose element order itself must be restored, so any
        non-identity reorder costs a window-index per value.
        """
        return 0

    def order(self, inputs: jax.Array, weights: jax.Array, lanes: int):
        """The transform's value reordering alone: ``(inputs, weights) ->
        (ordered_inputs, ordered_weights)``, before any flit packing.

        ``apply`` is always ``pack_paired(*order(...))``; the compression
        knob (``repro.noc.traffic``) swaps the packer for the MSR codec's
        while reusing the exact same ordering, so ordered values have one
        definition per transform regardless of the wire encoding."""
        return inputs, weights

    def order_single(self, values: jax.Array, lanes: int) -> jax.Array:
        """Single-stream ordering alone (``apply_single`` = ``pack`` of it)."""
        return values

    def apply(self, inputs: jax.Array, weights: jax.Array, lanes: int) -> FlitStream:
        oi, ow = self.order(inputs, weights, lanes)
        return pack_paired(oi, ow, lanes)

    def apply_single(self, values: jax.Array, lanes: int) -> FlitStream:
        return pack(self.order_single(values, lanes), lanes)


class IdentityTransform(WireTransform):
    pass


@dataclasses.dataclass(frozen=True)
class DescendingTransform(WireTransform):
    """Single-stream popcount-descending ordering (no pairing semantics).

    ``fill='interleave'`` gives the provably optimal per-lane interleave;
    ``fill='rowmajor'`` is the paper's Fig. 9 layout.
    """

    name: str = "desc"
    fill: str = "rowmajor"

    def overhead_bits_per_value(self, window: int, paired: bool = True) -> int:
        # Halves are sorted independently (paired) and element order is not
        # preserved (single): a recovery index is owed either way.
        return ordering.index_overhead_bits(window)

    def order_single(self, values: jax.Array, lanes: int) -> jax.Array:
        return ordering.descending_order(
            values, window=self.window, fill=self.fill,
            lanes=lanes if self.fill == "interleave" else None,
            tiebreak=self.tiebreak).values

    def order(self, inputs: jax.Array, weights: jax.Array, lanes: int):
        # Without pairing semantics, order each half independently.
        oi = ordering.descending_order(
            inputs, window=self.window, fill=self.fill,
            lanes=(lanes // 2) if self.fill == "interleave" else None,
            tiebreak=self.tiebreak)
        ow = ordering.descending_order(
            weights, window=self.window, fill=self.fill,
            lanes=(lanes // 2) if self.fill == "interleave" else None,
            tiebreak=self.tiebreak)
        return oi.values, ow.values


@dataclasses.dataclass(frozen=True)
class AffiliatedTransform(WireTransform):
    """O1: order pairs by weight popcount; pairing intact, zero recovery cost."""

    name: str = "O1"

    def overhead_bits_per_value(self, window: int, paired: bool = True) -> int:
        # Pairs travel together, so re-pairing is free -- but a *single*
        # popcount-sorted stream still owes the index that restores order.
        return 0 if paired else ordering.index_overhead_bits(window)

    def order(self, inputs: jax.Array, weights: jax.Array, lanes: int):
        po = ordering.affiliated_order(inputs, weights, window=self.window,
                                       tiebreak=self.tiebreak)
        return po.inputs, po.weights

    def order_single(self, values: jax.Array, lanes: int) -> jax.Array:
        # A lone weight stream under O1 is just descending ordering.
        return ordering.descending_order(values, window=self.window,
                                         tiebreak=self.tiebreak).values


@dataclasses.dataclass(frozen=True)
class SeparatedTransform(WireTransform):
    """O2: order each stream by its own popcount; index needed to re-pair."""

    name: str = "O2"

    def overhead_bits_per_value(self, window: int, paired: bool = True) -> int:
        return ordering.index_overhead_bits(window)

    def order(self, inputs: jax.Array, weights: jax.Array, lanes: int):
        po = ordering.separated_order(inputs, weights, window=self.window,
                                      tiebreak=self.tiebreak)
        return po.inputs, po.weights

    def order_single(self, values: jax.Array, lanes: int) -> jax.Array:
        return ordering.descending_order(values, window=self.window,
                                         tiebreak=self.tiebreak).values


@dataclasses.dataclass(frozen=True)
class MinHammingTransform(WireTransform):
    """O3: chain each stream by consecutive Hamming distance (separated).

    Popcount sorting (O1/O2) is a proxy for the wire objective; O3 minimizes
    consecutive-flit Hamming distance directly via multi-start greedy
    nearest-neighbor chaining with beam lookahead, then deals each chain
    column-major so chain neighbors occupy the same lane on consecutive
    flits. Streams are chained independently, so re-pairing needs an
    O2-style index -- and so does a single stream's order recovery.
    """

    name: str = "O3"
    beam: int = ordering.DEFAULT_BEAM
    starts: int = ordering.DEFAULT_STARTS

    def overhead_bits_per_value(self, window: int, paired: bool = True) -> int:
        return ordering.index_overhead_bits(window)

    def order(self, inputs: jax.Array, weights: jax.Array, lanes: int):
        po = ordering.separated_min_hamming_order(
            inputs, weights, window=self.window, lanes=lanes // 2,
            beam=self.beam, starts=self.starts)
        return po.inputs, po.weights

    def order_single(self, values: jax.Array, lanes: int) -> jax.Array:
        return ordering.min_hamming_order(
            values, window=self.window, lanes=lanes,
            beam=self.beam, starts=self.starts).values


@dataclasses.dataclass(frozen=True)
class MinHammingAffiliatedTransform(MinHammingTransform):
    """O3a: one min-Hamming chain over the *combined* pair distance.

    The summed two-plane distance is exactly the paired flit's per-lane-pair
    toggle cost, and one shared permutation keeps pairs matched -- zero
    recovery cost on the request phase, like O1.
    """

    name: str = "O3a"

    def overhead_bits_per_value(self, window: int, paired: bool = True) -> int:
        return 0 if paired else ordering.index_overhead_bits(window)

    def order(self, inputs: jax.Array, weights: jax.Array, lanes: int):
        po = ordering.affiliated_min_hamming_order(
            inputs, weights, window=self.window, lanes=lanes // 2,
            beam=self.beam, starts=self.starts)
        return po.inputs, po.weights


TRANSFORMS = {
    "O0": IdentityTransform,
    "O1": AffiliatedTransform,
    "O2": SeparatedTransform,
    "O3": MinHammingTransform,
    "O3a": MinHammingAffiliatedTransform,
    "desc": DescendingTransform,
}


def by_name(name: str, window: Optional[int] = None, **kw) -> WireTransform:
    return TRANSFORMS[name](name=name, window=window, **kw)


# --------------------------------------------------------------------------
# Flit protection codes (the fault-injection wire axis; see DESIGN.md
# "Fault model & protection"). Charged per transmitted flit like the O2
# recovery index: these bits ride the sideband, not the payload lanes, so
# they never perturb the recorded payload BT - the cost is accounted
# analytically via protection_overhead_bits.

PROTECTION_BITS = {"none": 0, "parity": 1, "crc8": 8}


def protection_overhead_bits(protect: str, num_flits: int) -> int:
    """Total protection bits owed for ``num_flits`` transmitted flits.

    Retransmitted flits carry the code again, so callers charge the
    *transmitted* flit count (injections including retries), not the
    logical payload size.
    """
    return PROTECTION_BITS[protect] * int(num_flits)


# MSR payload compression (see repro.core.msr and DESIGN.md "MSR
# compression"). The 5-bit codes ride the payload lanes (real flit-count
# reduction); the per-window escape records - outlier count + (position,
# top bits) per outlier - ride the sideband like the recovery index and the
# protection codes, charged analytically at half a transition per bit.

COMPRESSIONS = ("none", "msr")


def compression_overhead_bits(compression: str, values, window: int) -> int:
    """Escape/metadata bits a compression scheme owes for transmitting
    ``values`` in ``window``-slot windows (a 2-D operand matrix charges one
    window per row; a flat stream is split into ``ceil(n/window)``).

    ``none`` owes nothing. ``msr`` owes the per-window escape records
    (:func:`repro.core.msr.escape_bits`); outlier status is per-value, so
    the charge is invariant under every WireTransform's within-window
    permutation - compression overhead is a (model, precision) property,
    never an ordering property.
    """
    if compression == "none":
        return 0
    if compression != "msr":
        raise KeyError(f"unknown compression scheme {compression!r}; "
                       f"supported: {COMPRESSIONS}")
    return msr.escape_bits(values, window)


def crc8_reference(data: bytes) -> int:
    """Bitwise CRC-8 (poly 0x07, init 0, MSB-first, no xor-out).

    With init 0 the map is linear over GF(2): ``crc(a ^ b) = crc(a) ^
    crc(b)``, which is what lets :func:`protection_syndrome_masks` reduce
    the per-flit code to a handful of masked popcount parities.
    """
    crc = 0
    for byte in data:
        crc ^= byte
        for _ in range(8):
            crc = ((crc << 1) ^ 0x07) & 0xFF if crc & 0x80 else (crc << 1) & 0xFF
    return crc


@functools.lru_cache(maxsize=None)
def protection_syndrome_masks(protect: str, lanes: int) -> np.ndarray:
    """``(code_bits, lanes)`` uint32 masks: code bit ``j`` of a flit payload
    equals ``popcount(payload & masks[j]) & 1`` summed over the lanes.

    The flit message is the payload words in lane order, little-endian
    bytes, LSB-first bits. Because both codes are linear with zero init,
    the code of any message is the XOR of the codes of its set bits -
    precomputing the per-bit syndromes folds CRC8 into 8 masked parities
    the simulator can evaluate with the ``popcount_hw`` it already has.
    Parity is the single all-ones mask (detects odd-weight corruption
    only; the benchmark reports what slips through).
    """
    pbits = PROTECTION_BITS[protect]
    masks = np.zeros((pbits, lanes), dtype=np.uint32)
    if protect == "parity":
        masks[0, :] = 0xFFFFFFFF
    elif protect == "crc8":
        nbytes = lanes * 4
        for pos in range(lanes * 32):
            msg = bytearray(nbytes)
            msg[pos // 8] = 1 << (pos % 8)
            syndrome = crc8_reference(bytes(msg))
            for j in range(8):
                if syndrome >> j & 1:
                    masks[j, pos // 32] |= np.uint32(1 << (pos % 32))
    elif protect != "none":
        raise KeyError(f"unknown protection scheme {protect!r}; "
                       f"supported: {sorted(PROTECTION_BITS)}")
    return masks


def measure(stream: FlitStream) -> dict:
    """BT metrics of one flit stream (the Fig. 8 recorder)."""
    return {
        "total_bt": float(bt_mod.bt_stream(stream)),
        "bt_per_flit": float(bt_mod.bt_per_flit(stream)),
        "expected_bt": float(bt_mod.expected_bt_stream(stream)),
        "num_flits": int(stream.words.shape[0]),
        "flit_bits": stream.flit_bits,
    }
