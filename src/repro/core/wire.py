"""WireTransform: the composable link-payload transform API.

A WireTransform is what an ordering unit at a memory controller (paper
Fig. 6) -- or, in the beyond-paper extension, at a TPU ICI boundary -- applies
to a value stream before it hits the wires. It captures the three paper
configurations:

    O0 (baseline)  -> IdentityTransform
    O1 (affiliated)-> AffiliatedTransform   (keyed on the weight stream)
    O2 (separated) -> SeparatedTransform

plus the interleaved optimal variant used in the beyond-paper study. All
transforms are pure functions of the payload (jit-safe) and report their
recovery overhead so benchmarks can charge it honestly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from . import ordering
from .flits import FlitStream, pack, pack_paired
from . import bt as bt_mod

__all__ = [
    "WireTransform",
    "IdentityTransform",
    "DescendingTransform",
    "AffiliatedTransform",
    "SeparatedTransform",
    "TRANSFORMS",
    "by_name",
    "measure",
]


@dataclasses.dataclass(frozen=True)
class WireTransform:
    """Base: pack a paired (inputs, weights) stream into flits untouched."""

    name: str = "O0"
    window: Optional[int] = None
    tiebreak: str = "stable"   # "pattern" clusters equal-count values

    def overhead_bits_per_value(self, window: int) -> int:
        return 0

    def apply(self, inputs: jax.Array, weights: jax.Array, lanes: int) -> FlitStream:
        return pack_paired(inputs, weights, lanes)

    def apply_single(self, values: jax.Array, lanes: int) -> FlitStream:
        return pack(values, lanes)


class IdentityTransform(WireTransform):
    pass


@dataclasses.dataclass(frozen=True)
class DescendingTransform(WireTransform):
    """Single-stream popcount-descending ordering (no pairing semantics).

    ``fill='interleave'`` gives the provably optimal per-lane interleave;
    ``fill='rowmajor'`` is the paper's Fig. 9 layout.
    """

    name: str = "desc"
    fill: str = "rowmajor"

    def apply_single(self, values: jax.Array, lanes: int) -> FlitStream:
        ordered = ordering.descending_order(
            values, window=self.window, fill=self.fill,
            lanes=lanes if self.fill == "interleave" else None,
            tiebreak=self.tiebreak)
        return pack(ordered.values, lanes)

    def apply(self, inputs: jax.Array, weights: jax.Array, lanes: int) -> FlitStream:
        # Without pairing semantics, order each half independently.
        oi = ordering.descending_order(
            inputs, window=self.window, fill=self.fill,
            lanes=(lanes // 2) if self.fill == "interleave" else None,
            tiebreak=self.tiebreak)
        ow = ordering.descending_order(
            weights, window=self.window, fill=self.fill,
            lanes=(lanes // 2) if self.fill == "interleave" else None,
            tiebreak=self.tiebreak)
        return pack_paired(oi.values, ow.values, lanes)


@dataclasses.dataclass(frozen=True)
class AffiliatedTransform(WireTransform):
    """O1: order pairs by weight popcount; pairing intact, zero recovery cost."""

    name: str = "O1"

    def apply(self, inputs: jax.Array, weights: jax.Array, lanes: int) -> FlitStream:
        po = ordering.affiliated_order(inputs, weights, window=self.window,
                                       tiebreak=self.tiebreak)
        return pack_paired(po.inputs, po.weights, lanes)

    def apply_single(self, values: jax.Array, lanes: int) -> FlitStream:
        # A lone weight stream under O1 is just descending ordering.
        ordered = ordering.descending_order(values, window=self.window,
                                            tiebreak=self.tiebreak)
        return pack(ordered.values, lanes)


@dataclasses.dataclass(frozen=True)
class SeparatedTransform(WireTransform):
    """O2: order each stream by its own popcount; index needed to re-pair."""

    name: str = "O2"

    def overhead_bits_per_value(self, window: int) -> int:
        return ordering.index_overhead_bits(window)

    def apply(self, inputs: jax.Array, weights: jax.Array, lanes: int) -> FlitStream:
        po = ordering.separated_order(inputs, weights, window=self.window,
                                      tiebreak=self.tiebreak)
        return pack_paired(po.inputs, po.weights, lanes)

    def apply_single(self, values: jax.Array, lanes: int) -> FlitStream:
        ordered = ordering.descending_order(values, window=self.window,
                                            tiebreak=self.tiebreak)
        return pack(ordered.values, lanes)


TRANSFORMS = {
    "O0": IdentityTransform,
    "O1": AffiliatedTransform,
    "O2": SeparatedTransform,
    "desc": DescendingTransform,
}


def by_name(name: str, window: Optional[int] = None, **kw) -> WireTransform:
    return TRANSFORMS[name](name=name, window=window, **kw)


def measure(stream: FlitStream) -> dict:
    """BT metrics of one flit stream (the Fig. 8 recorder)."""
    return {
        "total_bt": float(bt_mod.bt_stream(stream)),
        "bt_per_flit": float(bt_mod.bt_per_flit(stream)),
        "expected_bt": float(bt_mod.expected_bt_stream(stream)),
        "num_flits": int(stream.words.shape[0]),
        "flit_bits": stream.flit_bits,
    }
