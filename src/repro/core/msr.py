"""MSR (Most-Significant-Run) flit compression: 8b -> 5b payload codes.

Trained int8 tensors are dominated by near-zero values whose top bits are
sign-extension copies: whenever the ``MSR_RUN = 4`` most significant bits of
a byte form a run of the sign bit, the value fits in ``CODE_BITS = 5``
two's-complement bits and the wire only needs its low five. The codec splits
a value stream into fixed windows and produces

* a dense 5-bit *code* per value (the low five bits - always, so the flit
  geometry stays data-independent, which is what lets the packetizer keep
  one skeleton per layer and the streamed path stay bit-identical to the
  one-shot path), and
* per-window *escape metadata* for the rare outliers whose MSR is shorter
  than the threshold: an outlier count plus, per outlier, its window
  position and its ``ESCAPE_BITS = 3`` explicit top bits.

The payload lanes therefore always shrink 8b -> 5b (fewer flits on every
link, for every input), while the escape records ride the sideband and are
charged analytically at half a transition per bit - exactly like the O2
recovery index (``ordering.index_overhead_bits``) and the flit-protection
codes (``wire.protection_overhead_bits``); see DESIGN.md "MSR compression".

``decompress(compress(x)) == x`` is bit-exact for every int8/uint8 input;
the numpy reference implementation pins the jitted kernels
(tests/test_msr.py).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .flits import FlitStream, num_flits

__all__ = [
    "MSR_RUN", "CODE_BITS", "ESCAPE_BITS", "MsrCompressed",
    "compress", "decompress", "compress_reference", "decompress_reference",
    "outlier_mask", "msr_overhead_bits", "msr_stream_overhead_bits",
    "escape_bits", "compressed_bytes", "compressed_payload_flits",
    "compressed_paired_payload_flits", "msr_pack", "msr_pack_paired",
    "msr_pack_reference", "msr_pack_paired_reference",
    "unpack_codes_reference",
]

MSR_RUN = 4                       # MSB run length that makes a value an inlier
CODE_BITS = 9 - MSR_RUN           # 1 sign bit + (8 - MSR_RUN) value bits = 5
ESCAPE_BITS = 8 - CODE_BITS       # explicit top bits per outlier record = 3
_SIGN_BIT = 1 << (CODE_BITS - 1)          # 0x10: sign bit of a 5-bit code
_CODE_MASK = (1 << CODE_BITS) - 1         # 0x1F
_TOP_ONES = (1 << ESCAPE_BITS) - 1        # 0b111
_EXT_MASK = _TOP_ONES << CODE_BITS        # 0xE0: sign-extension of the top 3


class MsrCompressed(NamedTuple):
    """One compressed stream, split into fixed ordering windows.

    codes:   ``(num_windows, window)`` uint8 - the 5-bit code per value
             (low ``CODE_BITS`` bits; the dense wire payload).
    outlier: ``(num_windows, window)`` bool - True where the top
             ``MSR_RUN`` bits are NOT a sign run (escape record needed).
    top:     ``(num_windows, window)`` uint8 - the outlier's explicit top
             ``ESCAPE_BITS`` bits (0 at inlier slots).
    window / count / shape / dtype: window size, real value count before
             padding, and the original array shape/dtype for decompress.
    """

    codes: jax.Array
    outlier: jax.Array
    top: jax.Array
    window: int
    count: int
    shape: Tuple[int, ...]
    dtype: str

    def overhead_bits(self) -> int:
        """Escape-metadata bits this stream owes (count field per window
        plus a (position, top-bits) record per outlier)."""
        return msr_stream_overhead_bits(self.window, self.codes.shape[0],
                                        int(np.asarray(self.outlier).sum()))


# --- byte views ------------------------------------------------------------

def _to_bytes_jnp(values) -> jax.Array:
    v = jnp.asarray(values).reshape(-1)
    if v.dtype == jnp.uint8:
        return v
    if v.dtype == jnp.int8:
        return jax.lax.bitcast_convert_type(v, jnp.uint8)
    raise TypeError(f"MSR codec wants int8/uint8 values, got {v.dtype}")


def _to_bytes_np(values) -> np.ndarray:
    a = np.asarray(values)
    if a.dtype == np.uint8:
        return a.reshape(-1)
    if a.dtype == np.int8:
        return a.reshape(-1).view(np.uint8)
    raise TypeError(f"MSR codec wants int8/uint8 values, got {a.dtype}")


def _from_bytes(flat: np.ndarray, dtype: str, shape: Tuple[int, ...],
                xp) -> np.ndarray:
    if np.dtype(dtype) == np.int8:
        flat = (flat.view(np.int8) if xp is np
                else jax.lax.bitcast_convert_type(flat, jnp.int8))
    return flat.reshape(shape)


# --- codec -----------------------------------------------------------------

@jax.jit
def _compress_kernel(u: jax.Array):
    top = (u >> CODE_BITS).astype(jnp.uint8)
    predicted = jnp.where((u & _SIGN_BIT) != 0,
                          jnp.uint8(_TOP_ONES), jnp.uint8(0))
    outlier = top != predicted
    codes = (u & _CODE_MASK).astype(jnp.uint8)
    return codes, outlier, jnp.where(outlier, top, jnp.uint8(0))


@jax.jit
def _decompress_kernel(codes, outlier, top):
    ext = jnp.where((codes & _SIGN_BIT) != 0,
                    jnp.uint8(_EXT_MASK), jnp.uint8(0))
    escaped = ((top << CODE_BITS) | codes).astype(jnp.uint8)
    return jnp.where(outlier, escaped, codes | ext).astype(jnp.uint8)


def _windowed(u, window: int, xp):
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    n = int(u.shape[0])
    nw = -(-n // window)
    pad = nw * window - n
    u = xp.pad(u, (0, pad))
    return u.reshape(nw, window), n


def compress(values, window: int) -> MsrCompressed:
    """MSR-compress ``values`` (int8/uint8, any shape) in fixed windows.

    The last window is zero-padded (a zero byte is always an inlier).
    Jitted; :func:`compress_reference` is the pinned numpy oracle.
    """
    a = jnp.asarray(values)
    u, count = _windowed(_to_bytes_jnp(a), window, jnp)
    codes, outlier, top = _compress_kernel(u)
    return MsrCompressed(codes, outlier, top, window, count,
                         tuple(a.shape), str(a.dtype))


def decompress(comp: MsrCompressed) -> jax.Array:
    """Bit-exact inverse of :func:`compress`."""
    flat = _decompress_kernel(comp.codes, comp.outlier,
                              comp.top).reshape(-1)[:comp.count]
    return _from_bytes(flat, comp.dtype, comp.shape, jnp)


def compress_reference(values, window: int) -> MsrCompressed:
    """Pure-numpy reference codec (the oracle the jitted kernel must match
    bit for bit)."""
    a = np.asarray(values)
    u, count = _windowed(_to_bytes_np(a), window, np)
    top = (u >> CODE_BITS).astype(np.uint8)
    predicted = np.where(u & _SIGN_BIT, _TOP_ONES, 0).astype(np.uint8)
    outlier = top != predicted
    codes = (u & _CODE_MASK).astype(np.uint8)
    return MsrCompressed(codes, outlier, np.where(outlier, top, 0).astype(np.uint8),
                         window, count, tuple(a.shape), str(a.dtype))


def decompress_reference(comp: MsrCompressed) -> np.ndarray:
    codes = np.asarray(comp.codes, np.uint8)
    outlier = np.asarray(comp.outlier, bool)
    top = np.asarray(comp.top, np.uint8)
    ext = np.where(codes & _SIGN_BIT, _EXT_MASK, 0).astype(np.uint8)
    escaped = ((top.astype(np.uint16) << CODE_BITS) | codes).astype(np.uint8)
    flat = np.where(outlier, escaped, codes | ext).reshape(-1)[:comp.count]
    return _from_bytes(flat, comp.dtype, comp.shape, np)


def outlier_mask(values) -> np.ndarray:
    """Boolean mask (same shape) of values needing an escape record -
    equivalently ``(v < -16) | (v > 15)`` on the int8 view. Numpy; outlier
    status is per-value, so this is independent of windowing, ordering, or
    packet grouping."""
    a = np.asarray(values)
    u = _to_bytes_np(a)
    top = u >> CODE_BITS
    predicted = np.where(u & _SIGN_BIT, _TOP_ONES, 0)
    return (top != predicted).reshape(a.shape)


# --- escape-metadata accounting --------------------------------------------

def _count_field_bits(window: int) -> int:
    # The per-window outlier counter addresses 0..window inclusive.
    return max(1, int(window).bit_length())


def _pos_field_bits(window: int) -> int:
    # Same contract as ordering.index_overhead_bits: one of `window` slots.
    return max(1, int(window - 1).bit_length())


def msr_stream_overhead_bits(window: int, num_windows, num_outliers) -> int:
    """Escape bits for ``num_windows`` windows of ``window`` transmitted
    slots holding ``num_outliers`` outliers in total: a count field per
    window plus a (position, top-bits) record per outlier."""
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    return (int(num_windows) * _count_field_bits(window)
            + int(num_outliers) * (_pos_field_bits(window) + ESCAPE_BITS))


def msr_overhead_bits(window: int, num_outliers) -> int:
    """Escape bits one window of ``window`` slots owes for ``num_outliers``
    outliers."""
    return msr_stream_overhead_bits(window, 1, num_outliers)


def escape_bits(values, window: int) -> int:
    """Total escape bits for ``values`` transmitted in ``window``-slot
    windows: a 2-D ``(num_windows, k<=window)`` operand matrix (one row per
    packet, rows zero-padded on the wire to ``window`` slots - padding
    zeros are inliers), or a flat stream split into ``ceil(n/window)``
    windows."""
    a = np.asarray(values)
    if a.ndim == 2:
        if a.shape[1] > window:
            raise ValueError(f"operand rows of {a.shape[1]} values do not "
                             f"fit a {window}-slot window")
        nwin = int(a.shape[0])
    else:
        nwin = -(-a.size // window) if a.size else 0
    n_out = int(outlier_mask(a).sum())
    return msr_stream_overhead_bits(window, nwin, n_out)


# --- compressed flit geometry ----------------------------------------------

def compressed_bytes(n_slots: int) -> int:
    """Bytes of the dense 5-bit code stream for ``n_slots`` values."""
    return -(-CODE_BITS * int(n_slots) // 8)


def compressed_payload_flits(n_values, lanes: int):
    """Payload flits of an MSR-compressed single stream of ``n_values``
    values: values are lane-padded exactly like :func:`flits.pack` (the
    transform may deal real values anywhere in the lane-rounded slots), the
    5-bit codes are densely packed into bytes, and the bytes are
    lane-padded into 8-bit flit lanes. Never exceeds the uncompressed
    ``ceil(n/lanes)``. Scalar in, int out; array in, int64 array out."""
    n = np.asarray(n_values, np.int64)
    slots = -(-n // lanes) * lanes
    nbytes = -(-(CODE_BITS * slots) // 8)
    nf = -(-nbytes // lanes)
    return int(nf) if np.ndim(n_values) == 0 else nf


def compressed_paired_payload_flits(n_values, lanes: int):
    """Payload flits of an MSR-compressed paired stream of ``n_values``
    (input, weight) pairs - each half-flit stream compressed independently
    (mirrors :func:`flits.pack_paired`)."""
    if lanes % 2:
        raise ValueError("paired packing needs an even lane count")
    half = lanes // 2
    n = np.asarray(n_values, np.int64)
    slots = -(-n // half) * half
    nbytes = -(-(CODE_BITS * slots) // 8)
    nf = -(-nbytes // half)
    return int(nf) if np.ndim(n_values) == 0 else nf


# --- dense 5-bit packing + flit streams ------------------------------------

def _pack_codes(codes: jax.Array) -> jax.Array:
    """Densely bit-pack 5-bit codes into bytes, LSB-first (code ``i``
    occupies bit positions ``[5i, 5i+5)`` of the little-endian bitstream).
    Traceable; shapes are static per call site."""
    n = int(codes.shape[0])
    shifts = jnp.arange(CODE_BITS, dtype=jnp.uint8)
    bits = (codes[:, None] >> shifts) & jnp.uint8(1)
    flat = bits.reshape(-1)
    pad = (-CODE_BITS * n) % 8
    flat = jnp.pad(flat, (0, pad))
    weights = jnp.uint32(1) << jnp.arange(8, dtype=jnp.uint32)
    return (flat.reshape(-1, 8).astype(jnp.uint32)
            * weights).sum(axis=1).astype(jnp.uint8)


def _pack_codes_reference(codes: np.ndarray) -> np.ndarray:
    codes = np.asarray(codes, np.uint8).reshape(-1)
    bits = np.unpackbits(codes[:, None], axis=1,
                         bitorder="little")[:, :CODE_BITS]
    return np.packbits(bits.reshape(-1), bitorder="little")


def unpack_codes_reference(data, n: int) -> np.ndarray:
    """Recover ``n`` 5-bit codes from a dense byte stream (numpy)."""
    bits = np.unpackbits(np.asarray(data, np.uint8),
                         bitorder="little")[:CODE_BITS * n]
    if n == 0:
        return np.zeros(0, np.uint8)
    return np.packbits(bits.reshape(n, CODE_BITS), axis=1,
                       bitorder="little")[:, 0]


def msr_pack(values, lanes: int) -> FlitStream:
    """Compress a flat value stream and pack the 5-bit codes into flits.

    Mirrors :func:`repro.core.flits.pack`: values zero-padded to a lane
    multiple (a zero's code is zero), dense code bytes zero-padded to a
    lane multiple, one byte per 8-bit lane. Escape metadata never rides the
    payload - it is charged analytically via
    :func:`msr_stream_overhead_bits`, so the flit geometry is a pure
    function of the value count."""
    u = _to_bytes_jnp(values)
    n = int(u.shape[0])
    slots = num_flits(n, lanes) * lanes
    u = jnp.pad(u, (0, slots - n))
    data = _pack_codes(u & _CODE_MASK)
    nf = -(-int(data.shape[0]) // lanes)
    data = jnp.pad(data, (0, nf * lanes - int(data.shape[0])))
    return FlitStream(data.reshape(nf, lanes), lanes, 8)


def msr_pack_paired(inputs, weights, lanes: int) -> FlitStream:
    """Paired-stream :func:`msr_pack`: inputs compressed into the left
    half-flit, weights into the right (the Fig. 2 layout, each half's code
    stream packed independently so it toggles only against itself)."""
    if lanes % 2:
        raise ValueError("paired packing needs an even lane count")
    half = lanes // 2
    ui, uw = _to_bytes_jnp(inputs), _to_bytes_jnp(weights)
    if ui.shape != uw.shape:
        raise ValueError("inputs and weights must have the same element count")
    n = int(ui.shape[0])
    pad = num_flits(n, half) * half - n
    di = _pack_codes(jnp.pad(ui, (0, pad)) & _CODE_MASK)
    dw = _pack_codes(jnp.pad(uw, (0, pad)) & _CODE_MASK)
    nf = -(-int(di.shape[0]) // half)
    di = jnp.pad(di, (0, nf * half - int(di.shape[0]))).reshape(nf, half)
    dw = jnp.pad(dw, (0, nf * half - int(dw.shape[0]))).reshape(nf, half)
    return FlitStream(jnp.concatenate([di, dw], axis=1), lanes, 8)


def msr_pack_reference(values, lanes: int) -> np.ndarray:
    """Numpy reference of :func:`msr_pack` - returns the words array."""
    u = _to_bytes_np(values)
    n = u.shape[0]
    slots = num_flits(n, lanes) * lanes
    u = np.pad(u, (0, slots - n))
    data = _pack_codes_reference(u & _CODE_MASK)
    nf = -(-data.shape[0] // lanes)
    data = np.pad(data, (0, nf * lanes - data.shape[0]))
    return data.reshape(nf, lanes)


def msr_pack_paired_reference(inputs, weights, lanes: int) -> np.ndarray:
    """Numpy reference of :func:`msr_pack_paired` - returns the words."""
    if lanes % 2:
        raise ValueError("paired packing needs an even lane count")
    half = lanes // 2
    ui, uw = _to_bytes_np(inputs), _to_bytes_np(weights)
    if ui.shape != uw.shape:
        raise ValueError("inputs and weights must have the same element count")
    n = ui.shape[0]
    pad = num_flits(n, half) * half - n
    di = _pack_codes_reference(np.pad(ui, (0, pad)) & _CODE_MASK)
    dw = _pack_codes_reference(np.pad(uw, (0, pad)) & _CODE_MASK)
    nf = -(-di.shape[0] // half)
    di = np.pad(di, (0, nf * half - di.shape[0])).reshape(nf, half)
    dw = np.pad(dw, (0, nf * half - dw.shape[0])).reshape(nf, half)
    return np.concatenate([di, dw], axis=1)
