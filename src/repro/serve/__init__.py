from .engine import AdmissionController, Engine, GenerationConfig

__all__ = ["AdmissionController", "Engine", "GenerationConfig"]
