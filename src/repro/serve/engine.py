"""Batched serving engine: prefill + jitted decode loop.

Minimal-but-real: fixed-size batch slots, greedy or temperature sampling,
EOS handling, KV cache threaded functionally. The decode step is the same
function the dry-run lowers at (arch x decode shape), so served FLOPs match
the roofline analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GenerationConfig", "Engine", "AdmissionController"]


@dataclasses.dataclass
class AdmissionController:
    """Deadline / queue-depth admission control for a serving loop.

    The graceful-degradation policy shared with the cycle-accurate NoC
    serving model (``repro.noc.online.simulate_online``): a request
    offered while ``max_queue_depth`` admitted requests are still
    outstanding is *shed* (rejected at admission, never started), and an
    admitted request whose completion latency exceeds ``deadline`` time
    units - or that completes ``failed`` - misses its SLO. This object is
    pure bookkeeping: the caller drives time (cycles, seconds - any
    monotone clock) through ``offer``/``complete`` and reads ``stats``.

    Goodput counts only SLO-attained completions, per 1000 time units of
    busy span (first offer to last completion), matching
    ``OnlineResult.goodput`` so engine-level and NoC-level numbers are
    directly comparable.
    """
    max_queue_depth: Optional[int] = None
    deadline: Optional[float] = None
    offered: int = 0
    shed: int = 0
    failed: int = 0
    slo_attained: int = 0
    completed: int = 0
    _outstanding: dict = dataclasses.field(default_factory=dict)
    _t_first: Optional[float] = None
    _t_last: Optional[float] = None

    def __post_init__(self):
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1 when set")
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError("deadline must be > 0 when set")

    @property
    def queue_depth(self) -> int:
        return len(self._outstanding)

    def offer(self, req_id, now: float) -> bool:
        """Offer a request at time ``now``; True iff admitted."""
        self.offered += 1
        if self._t_first is None or now < self._t_first:
            self._t_first = now
        if (self.max_queue_depth is not None
                and len(self._outstanding) >= self.max_queue_depth):
            self.shed += 1
            return False
        if req_id in self._outstanding:
            raise ValueError(f"request {req_id!r} already outstanding")
        self._outstanding[req_id] = now
        return True

    def complete(self, req_id, now: float, failed: bool = False) -> bool:
        """Mark an admitted request finished; True iff it made its SLO."""
        start = self._outstanding.pop(req_id)
        self.completed += 1
        self._t_last = now if self._t_last is None else max(self._t_last, now)
        if failed:
            self.failed += 1
            return False
        ok = self.deadline is None or (now - start) <= self.deadline
        self.slo_attained += int(ok)
        return ok

    def stats(self) -> dict:
        span = (None if self._t_first is None or self._t_last is None
                else max(self._t_last - self._t_first, 1.0))
        return {
            "offered": self.offered,
            "admitted": self.offered - self.shed,
            "shed": self.shed,
            "completed": self.completed,
            "failed": self.failed,
            "outstanding": len(self._outstanding),
            "slo_attained": self.slo_attained,
            "slo_attainment": (self.slo_attained / self.offered
                               if self.offered else None),
            "goodput": (1000.0 * self.slo_attained / span
                        if span else None),
        }


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 64
    temperature: float = 0.0         # 0 = greedy
    eos_id: int = -1                 # -1 = never stop early
    sync_every: int = 8              # decode steps between host done-checks


class Engine:
    def __init__(self, model, params, context: int):
        self.model = model
        self.params = params
        self.context = context
        self._decode = jax.jit(model.decode_step)

    def generate(self, prompts: jax.Array, gen: GenerationConfig,
                 key: Optional[jax.Array] = None):
        """prompts (B, S) int32 -> (B, L) int32, L <= max_new_tokens.

        The decode loop only syncs with the host every ``gen.sync_every``
        steps: a per-step ``bool(jnp.all(done))`` blocks on every decode
        dispatch and serializes the whole pipeline. Finished rows keep
        emitting ``eos_id`` (the ``jnp.where`` below), so the exact output
        of a per-step early-exit loop is reconstructible from the tokens
        alone: trim to the first step at which every row's running output
        contains ``eos_id``. The result is bit-identical to the per-step
        loop; early exit still happens, within ``sync_every`` steps of
        batch completion.
        """
        b, s = prompts.shape
        logits, cache = self.model.prefill(self.params, prompts, self.context)
        if key is None:
            key = jax.random.PRNGKey(0)
        sync = max(1, gen.sync_every)
        out = []
        tok = self._sample(logits, gen, key)
        done = jnp.zeros((b,), bool)
        for i in range(gen.max_new_tokens):
            out.append(tok)
            done = done | (tok == gen.eos_id)
            if i == gen.max_new_tokens - 1:
                break
            if i % sync == sync - 1 and bool(jnp.all(done)):
                break
            pos = jnp.full((b,), s + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, gen, key)
            tok = jnp.where(done, gen.eos_id, tok)
        toks = jnp.stack(out, axis=1)
        all_done = np.logical_or.accumulate(
            np.asarray(toks) == gen.eos_id, axis=1).all(axis=0)
        if all_done.any():
            toks = toks[:, :int(all_done.argmax()) + 1]
        return toks

    @staticmethod
    def _sample(logits, gen: GenerationConfig, key):
        if gen.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / gen.temperature, axis=-1
                                      ).astype(jnp.int32)
