"""Batched serving engine: prefill + jitted decode loop.

Minimal-but-real: fixed-size batch slots, greedy or temperature sampling,
EOS handling, KV cache threaded functionally. The decode step is the same
function the dry-run lowers at (arch x decode shape), so served FLOPs match
the roofline analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GenerationConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 64
    temperature: float = 0.0         # 0 = greedy
    eos_id: int = -1                 # -1 = never stop early
    sync_every: int = 8              # decode steps between host done-checks


class Engine:
    def __init__(self, model, params, context: int):
        self.model = model
        self.params = params
        self.context = context
        self._decode = jax.jit(model.decode_step)

    def generate(self, prompts: jax.Array, gen: GenerationConfig,
                 key: Optional[jax.Array] = None):
        """prompts (B, S) int32 -> (B, L) int32, L <= max_new_tokens.

        The decode loop only syncs with the host every ``gen.sync_every``
        steps: a per-step ``bool(jnp.all(done))`` blocks on every decode
        dispatch and serializes the whole pipeline. Finished rows keep
        emitting ``eos_id`` (the ``jnp.where`` below), so the exact output
        of a per-step early-exit loop is reconstructible from the tokens
        alone: trim to the first step at which every row's running output
        contains ``eos_id``. The result is bit-identical to the per-step
        loop; early exit still happens, within ``sync_every`` steps of
        batch completion.
        """
        b, s = prompts.shape
        logits, cache = self.model.prefill(self.params, prompts, self.context)
        if key is None:
            key = jax.random.PRNGKey(0)
        sync = max(1, gen.sync_every)
        out = []
        tok = self._sample(logits, gen, key)
        done = jnp.zeros((b,), bool)
        for i in range(gen.max_new_tokens):
            out.append(tok)
            done = done | (tok == gen.eos_id)
            if i == gen.max_new_tokens - 1:
                break
            if i % sync == sync - 1 and bool(jnp.all(done)):
                break
            pos = jnp.full((b,), s + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, gen, key)
            tok = jnp.where(done, gen.eos_id, tok)
        toks = jnp.stack(out, axis=1)
        all_done = np.logical_or.accumulate(
            np.asarray(toks) == gen.eos_id, axis=1).all(axis=0)
        if all_done.any():
            toks = toks[:, :int(all_done.argmax()) + 1]
        return toks

    @staticmethod
    def _sample(logits, gen: GenerationConfig, key):
        if gen.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / gen.temperature, axis=-1
                                      ).astype(jnp.int32)
