"""End-to-end training driver.

Selects any --arch (full or reduced config), builds the sharded train step,
restores the newest intact checkpoint if present (fault-tolerant restart),
and trains on the deterministic token pipeline with gradient-wire BT
telemetry from the paper's technique.

Examples (CPU container):
    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 200 --seq 256 --batch 8 --ckpt /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
        --steps 50
On a real pod the same driver runs with --mesh pod (16x16) or --mesh
multipod (2x16x16); mesh selection is the only difference.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.data import TokenStream
from repro.dist.sharding import spec_shardings
from repro.models.spec import init_params
from repro.optim import AdamW, wsd, cosine
from repro.train import TrainState, make_train_step, init_state, checkpoint
from repro.launch.mesh import make_production_mesh, make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config of the same family")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=["wsd", "cosine"], default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"], default="host")
    ap.add_argument("--wire-telemetry", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get(args.arch)
    model = arch.build_reduced() if args.reduced else arch.build()
    cfg = model.cfg
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    # minicpm is the WSD arch per assignment; default the rest to cosine.
    sched_name = args.schedule or ("wsd" if "minicpm" in args.arch else "cosine")
    sched = (wsd if sched_name == "wsd" else cosine)(args.lr, args.steps)
    opt = AdamW(sched, state_dtype=arch.optimizer_state)

    specs = model.specs()
    shardings = spec_shardings(specs, arch.rules, mesh)
    with mesh:
        params = jax.jit(lambda k: init_params(specs, k),
                         out_shardings=shardings)(jax.random.PRNGKey(args.seed))
        state = init_state(params, opt)

        start = 0
        if args.ckpt:
            got = checkpoint.restore(args.ckpt, state)
            if got is not None:
                start, state = got
                print(f"restored checkpoint at step {start}")

        stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch, seed=args.seed)

        if arch.kind == "encdec":
            def loss_fn(p, batch):
                toks, tgt, mask = batch
                frames = jax.nn.one_hot(toks % cfg.d_model, cfg.d_model,
                                        dtype=jnp.bfloat16)
                return model.loss(p, frames, toks, tgt, mask)
        elif getattr(cfg, "vlm_prefix", 0):
            def loss_fn(p, batch):
                toks, tgt, mask = batch
                pe = jnp.zeros((toks.shape[0], cfg.vlm_prefix, cfg.d_model),
                               jnp.bfloat16)
                return model.loss(p, toks, tgt, mask, pe)
        else:
            def loss_fn(p, batch):
                toks, tgt, mask = batch
                return model.loss(p, toks, tgt, mask)

        step_fn = jax.jit(make_train_step(
            loss_fn, opt, microbatches=args.microbatches,
            wire_telemetry=args.wire_telemetry))

        t0 = time.time()
        for i in range(start, args.steps):
            state, metrics = step_fn(state, stream.batch(i))
            if i % 10 == 0 or i == args.steps - 1:
                msg = (f"step {i:5d} loss {float(metrics['loss']):.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f} "
                       f"lr {float(metrics['lr']):.2e} "
                       f"{(time.time() - t0) / max(i - start + 1, 1):.2f}s/step")
                if args.wire_telemetry:
                    w = metrics["wire"]
                    msg += (f" | wire-BT O1 {float(w['reduction_o1'])*100:+.1f}%"
                            f" O2 {float(w['reduction_o2'])*100:+.1f}%")
                print(msg, flush=True)
            if args.ckpt and (i + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt, i + 1, state)
        if args.ckpt:
            checkpoint.save(args.ckpt, args.steps, state)
        print("done")


if __name__ == "__main__":
    main()
