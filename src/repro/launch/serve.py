"""Batched serving driver: prefill a prompt batch, decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
        --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models.spec import init_params
from repro.serve import Engine, GenerationConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get(args.arch)
    model = arch.build_reduced() if args.reduced else arch.build()
    cfg = model.cfg
    if arch.kind == "encdec":
        raise SystemExit("use the transcription example for enc-dec archs")

    params = init_params(model.specs(), jax.random.PRNGKey(args.seed))
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    if getattr(cfg, "vlm_prefix", 0):
        raise SystemExit("use the VLM example for vision archs")

    engine = Engine(model, params, context=args.context)
    t0 = time.time()
    out = engine.generate(prompts, GenerationConfig(
        max_new_tokens=args.max_new, temperature=args.temperature))
    dt = time.time() - t0
    toks = out.size
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
