"""Batched serving driver: prefill a prompt batch, decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
        --batch 4 --prompt-len 32 --max-new 16

With ``--offered-load`` the driver switches from one batched call to an
arrival-driven serving loop: requests arrive per the same
:class:`repro.noc.online.ArrivalProcess` the NoC closed-loop simulator
uses (one "cycle" = one millisecond, so the load unit is requests per
second), each is generated on arrival, and the run reports p50/p99/mean
request latency plus measured throughput:

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
        --offered-load 4 --num-requests 16 --arrival poisson
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models.spec import init_params
from repro.serve import Engine, GenerationConfig


def serve_offered_load(engine: Engine, prompts: jax.Array,
                       gen: GenerationConfig, *, load: float,
                       arrival: str = "uniform", seed: int = 0,
                       pace: bool = True):
    """Arrival-driven serving loop: request ``k`` (row ``k`` of
    ``prompts``) arrives at its :class:`~repro.noc.online.ArrivalProcess`
    time (milliseconds; ``load`` is requests/second) and is generated on
    arrival. Returns ``(outputs, stats)`` where stats carries the p50/p99
    latency summary (:func:`repro.noc.online.latency_percentiles`, in ms)
    and the measured throughput in requests/second.

    ``pace=False`` skips the wall-clock sleeps and instead replays the
    arrival schedule analytically (start = max(arrival, previous finish)),
    which keeps tests fast and deterministic in shape.
    """
    from repro.noc.online import ArrivalProcess, latency_percentiles

    n = int(prompts.shape[0])
    arrivals = ArrivalProcess(arrival, load, seed).times(n)
    outputs = []
    latencies = []
    t0 = time.perf_counter()
    clock = 0.0                      # analytic clock (ms) when not pacing
    for k in range(n):
        arr_ms = float(arrivals[k])
        if pace:
            lag = arr_ms / 1000.0 - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
        tic = time.perf_counter()
        out = engine.generate(prompts[k:k + 1], gen,
                              key=jax.random.PRNGKey(seed + k))
        jax.block_until_ready(out)
        outputs.append(out)
        service_ms = (time.perf_counter() - tic) * 1000.0
        if pace:
            end_ms = (time.perf_counter() - t0) * 1000.0
        else:
            clock = max(clock, arr_ms) + service_ms
            end_ms = clock
        latencies.append(int(round(end_ms - arr_ms)))
    stats = latency_percentiles(np.asarray(latencies, np.int64))
    span_ms = max(1e-9, (time.perf_counter() - t0) * 1000.0)
    stats["throughput_rps"] = n * 1000.0 / span_ms
    stats["offered_load"] = load
    stats["arrival"] = arrival
    return outputs, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode steps between host done-checks")
    ap.add_argument("--offered-load", type=float, default=None,
                    help="requests/second; enables the arrival-driven loop")
    ap.add_argument("--num-requests", type=int, default=8,
                    help="requests in the arrival-driven loop")
    ap.add_argument("--arrival", default="uniform",
                    choices=("uniform", "poisson", "backtoback"))
    ap.add_argument("--arrival-seed", type=int, default=0)
    args = ap.parse_args()

    arch = get(args.arch)
    model = arch.build_reduced() if args.reduced else arch.build()
    cfg = model.cfg
    if arch.kind == "encdec":
        raise SystemExit("use the transcription example for enc-dec archs")

    params = init_params(model.specs(), jax.random.PRNGKey(args.seed))
    if getattr(cfg, "vlm_prefix", 0):
        raise SystemExit("use the VLM example for vision archs")

    engine = Engine(model, params, context=args.context)
    gen = GenerationConfig(max_new_tokens=args.max_new,
                           temperature=args.temperature,
                           sync_every=args.sync_every)

    if args.offered_load is not None:
        prompts = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1),
            (args.num_requests, args.prompt_len), 0, cfg.vocab)
        # warm the compile cache so the first arrival isn't charged for it
        jax.block_until_ready(engine.generate(prompts[:1], gen))
        outs, stats = serve_offered_load(
            engine, prompts, gen, load=args.offered_load,
            arrival=args.arrival, seed=args.arrival_seed)
        print(f"served {len(outs)} requests at offered load "
              f"{args.offered_load}/s ({args.arrival}): "
              f"p50={stats['p50']}ms p99={stats['p99']}ms "
              f"mean={stats['mean']:.1f}ms "
              f"tput={stats['throughput_rps']:.2f} req/s")
        return

    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = engine.generate(prompts, gen)
    dt = time.time() - t0
    toks = out.size
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
