import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory/cost/collective analyses for the roofline study.

MUST be imported before any other jax-touching module in the process (the
device count locks on first jax init), hence the XLA_FLAGS lines above
everything else.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm-2b \
        --shape train_4k [--multipod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get, cache_shardings
from repro.dist.sharding import spec_shardings
from repro.models.spec import abstract_params, param_bytes, param_count
from repro.optim import AdamW, wsd
from repro.launch.mesh import make_production_mesh

# ---------------------------------------------------------------------------
# collective-traffic extraction from the partitioned HLO
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TYPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[dtype]


_WHILE_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"[su]32\[\] constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """-> (comps: name -> [op lines], order preserved)."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and "{" in line and "(" in line:
            name = line.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
            cur = name
            comps[cur] = []
        elif line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _loop_multipliers(comps) -> dict:
    """Trip-count multiplier per computation, resolving nested while loops.

    XLA prints a scan's while body once; costs and collective traffic inside
    it occur trip_count times per step. The trip count is the bound constant
    in the loop condition computation.
    """
    edges = []   # (parent_comp, body_comp, trip)
    for parent, lines in comps.items():
        for line in lines:
            if " while(" not in line:
                continue
            mb = _WHILE_RE.search(line)
            mc = _COND_RE.search(line)
            trip = 1
            if mc and mc.group(1) in comps:
                consts = [int(x) for x in
                          _CONST_RE.findall("\n".join(comps[mc.group(1)]))]
                if consts:
                    trip = max(consts)
            if mb:
                edges.append((parent, mb.group(1), max(trip, 1)))
    mult = {name: 1 for name in comps}
    # propagate: a body's multiplier = parent's multiplier * trip
    for _ in range(8):  # few nesting levels; fixed-point quickly
        changed = False
        for parent, body, trip in edges:
            new = mult.get(parent, 1) * trip
            if mult.get(body, 1) != new:
                mult[body] = new
                changed = True
        if not changed:
            break
    return mult


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic from the partitioned module.

    For each collective op the largest typed shape on the line is the
    traffic proxy (covers reduce-scatter's big operand and all-gather's big
    result); ops inside while bodies are multiplied by the loop trip count
    (XLA prints scan bodies once - see EXPERIMENTS.md SSMethodology).
    """
    comps = _split_computations(hlo_text)
    mult = _loop_multipliers(comps)
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        m = mult.get(name, 1)
        for line in lines:
            s = line.strip()
            for kind in _COLLECTIVES:
                # match the op use, not tuple types or metadata mentions
                if f" {kind}(" in s or f"{kind}-start(" in s:
                    sizes = [_shape_bytes(t, d) for t, d in _TYPE_RE.findall(s)]
                    if sizes:
                        out[kind] += max(sizes) * m
                        counts[kind] += m
                    break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# cell construction: the exact jitted function per (arch, shape)
# ---------------------------------------------------------------------------

def _train_setup(arch, mesh):
    model = arch.build()
    specs = model.specs()
    params_abs = abstract_params(specs)
    params_shard = spec_shardings(specs, arch.rules, mesh)
    opt = AdamW(wsd(3e-4, 10000, warmup=500), state_dtype=arch.optimizer_state)
    opt_abs = jax.eval_shape(opt.init, params_abs)

    def opt_shard_like(_abs, pshard_tree):
        # step -> replicated; m/v trees mirror params. Q8 moments inherit
        # the parameter's PartitionSpec verbatim on the leading axes (their
        # block layout preserves them by construction - see optim.adamw.Q8);
        # the block-count axis reuses the param's last-dim axis only if it
        # still divides.
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.optim.adamw import Q8

        def _axsize(ax):
            if ax is None:
                return 1
            if isinstance(ax, tuple):
                n = 1
                for a in ax:
                    n *= mesh.shape[a]
                return n
            return mesh.shape[ax]

        def for_moment(subtree_abs, ps):
            def one(leaf_abs, psh):
                if isinstance(leaf_abs, Q8):
                    parts = list(psh.spec) if psh.spec else []
                    rank = leaf_abs.q.ndim
                    parts = (parts + [None] * rank)[:rank - 1]
                    last_ax = parts[-1] if parts else None
                    nb = leaf_abs.q.shape[-2]
                    nb_ax = last_ax if (last_ax and nb % _axsize(last_ax) == 0) \
                        else None
                    qspec = P(*(parts[:-1] + [nb_ax, None])) if parts else P(nb_ax, None)
                    sspec = P(*(parts[:-1] + [nb_ax])) if parts else P(nb_ax)
                    return Q8(NamedSharding(mesh, qspec),
                              NamedSharding(mesh, sspec))
                return psh
            return jax.tree.map(one, subtree_abs, ps,
                                is_leaf=lambda x: isinstance(x, Q8))

        return type(_abs)(NamedSharding(mesh, P()),
                          for_moment(_abs.m, pshard_tree),
                          for_moment(_abs.v, pshard_tree))

    opt_shard = opt_shard_like(opt_abs, params_shard)
    return model, params_abs, params_shard, opt, opt_abs, opt_shard


def build_cell(arch_name, shape_name: str, mesh):
    """Returns (fn, example_args, in_shardings) ready for jit().lower().

    ``arch_name`` may be an --arch id or an ArchDef (e.g. one carrying
    config overrides for a perf-iteration run)."""
    arch = get(arch_name) if isinstance(arch_name, str) else arch_name
    cell = SHAPES[shape_name]
    ins = arch.input_specs(shape_name)
    in_shard = arch.input_shardings(ins, mesh)

    if cell.mode == "train":
        model, p_abs, p_shard, opt, o_abs, o_shard = _train_setup(arch, mesh)

        if arch.kind == "encdec":
            def loss_fn(params, batch):
                return model.loss(params, batch["frames"], batch["tokens"],
                                  batch["targets"], batch["mask"])
        elif getattr(arch.config, "vlm_prefix", 0):
            def loss_fn(params, batch):
                return model.loss(params, batch["tokens"], batch["targets"],
                                  batch["mask"], batch["patch_embeds"])
        else:
            def loss_fn(params, batch):
                return model.loss(params, batch["tokens"], batch["targets"],
                                  batch["mask"])

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, loss

        args = (p_abs, o_abs, ins)
        shardings = (p_shard, o_shard, in_shard)
        return train_step, args, shardings

    model = arch.build()
    specs = model.specs()
    p_abs = abstract_params(specs)
    p_shard = arch.param_shardings(mesh)

    if cell.mode == "prefill":
        if arch.kind == "encdec":
            def prefill(params, batch):
                memory = model.encode(params, batch["frames"])
                logits = model.decode_train(params, batch["tokens"], memory)
                return logits[:, -1], memory
        elif getattr(arch.config, "vlm_prefix", 0):
            def prefill(params, batch):
                return model.prefill(params, batch["tokens"], cell.seq_len,
                                     batch["patch_embeds"])
        else:
            def prefill(params, batch):
                return model.prefill(params, batch["tokens"], cell.seq_len)
        return prefill, (p_abs, ins), (p_shard, in_shard)

    # decode: cache is an input; build its abstract pytree via eval_shape
    b = cell.global_batch
    ctx = cell.seq_len
    if arch.kind == "encdec":
        mem_abs = jax.ShapeDtypeStruct((b, ctx, arch.config.d_model),
                                       jnp.bfloat16)
        dec_ctx = max(ctx // 4, 8)
        cache_abs = jax.eval_shape(
            lambda m, p: model.init_cache(b, dec_ctx, m, p), mem_abs, p_abs)
    else:
        cache_abs = jax.eval_shape(lambda: model.init_cache(b, arch.config.cache_len(ctx)))
    cache_shard = cache_shardings(cache_abs, mesh)

    def decode(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    args = (p_abs, ins["token"], cache_abs, ins["pos"])
    shardings = (p_shard, in_shard["token"], cache_shard, in_shard["pos"])
    return decode, args, shardings


# ---------------------------------------------------------------------------
# the dry run itself
# ---------------------------------------------------------------------------

def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, kv_chunk: int = None, moe_groups: int = None,
             moe_shard: tuple = None, rules_override: dict = None,
             tag: str = "") -> dict:
    arch = get(arch_name)
    ok, reason = arch.supports(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "status": "skip", "reason": reason}
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{arch_name}__{shape_name}__{mesh_name}{tag}.json")
    if not ok:
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    import dataclasses as _dc
    cfg_over = {}
    if kv_chunk is not None:
        cfg_over["kv_chunk"] = kv_chunk
    if moe_groups is not None and hasattr(arch.config, "moe_groups"):
        cfg_over["moe_groups"] = moe_groups
    if moe_shard is not None and hasattr(arch.config, "moe_shard"):
        cfg_over["moe_shard"] = tuple(moe_shard)
    if os.environ.get("REPRO_TP_BF16"):
        cfg_over["tp_bf16_boundary"] = True
    if cfg_over:
        arch = _dc.replace(arch, config=_dc.replace(arch.config, **cfg_over))
    if rules_override:
        arch = _dc.replace(arch, rules={**arch.rules, **rules_override})

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args, shardings = build_cell(arch, shape_name, mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):   # jax returns [dict] on CPU
                cost = cost[0] if cost else None
            hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        model = arch.build()
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "devices": int(mesh.devices.size),
            "params": param_count(model.specs()),
            "param_bytes_global": param_bytes(model.specs()),
            "flops_per_device": cost.get("flops", -1.0) if cost else -1.0,
            "bytes_per_device": cost.get("bytes accessed", -1.0) if cost else -1.0,
            "collective_bytes_per_device": coll,
            "memory_analysis": _mem_dict(mem),
            "hlo_bytes": len(hlo),
        })
    except Exception as e:  # noqa: BLE001 - a failed cell is a recorded bug
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-4000:]})
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv-chunk", type=int, default=None)
    ap.add_argument("--moe-groups", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multipod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_fail = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, multi_pod=mp, out_dir=args.out,
                       kv_chunk=args.kv_chunk, moe_groups=args.moe_groups,
                       tag=args.tag)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" lower {rec['lower_s']}s compile {rec['compile_s']}s "
                     f"flops/dev {rec['flops_per_device']:.3g} "
                     f"coll/dev {rec['collective_bytes_per_device']['total']:.3g}B")
        elif status == "fail":
            n_fail += 1
            extra = " " + rec["error"][:160]
        print(f"[{status:4s}] {a} x {s} x "
              f"{'2x16x16' if mp else '16x16'}{extra}", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
