import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Perf-iteration driver: re-lowers the three hillclimb cells under named
configuration variants and records tagged dry-run artifacts that
benchmarks.roofline and EXPERIMENTS.md SSPerf consume.

Each variant encodes one hypothesis from the iteration log; run:
    PYTHONPATH=src python -m repro.launch.hillclimb [variant ...]
"""
import sys

from repro.launch.dryrun import run_cell

# (arch, shape, tag, overrides) - tags match EXPERIMENTS.md SSPerf iterations
VARIANTS = {
    # -- phi3-medium-14b x prefill_32k (worst roofline fraction) ----------
    # H1: head_dim-TP makes qk^T contract over a sharded axis -> the fp32
    #     (B,KV,S,S,G) score tensor is all-reduced every layer. Replicating
    #     attention (TP only in mlp/vocab) removes it.
    "phi3_it1": ("phi3-medium-14b", "prefill_32k", "_it1_nohd",
                 dict(rules_override={"head_dim": None})),
    # H2: blockwise (flash-style) attention removes the S^2 materialization
    #     -> memory term and the 710 GB temp footprint collapse.
    "phi3_it2": ("phi3-medium-14b", "prefill_32k", "_it2_chunk",
                 dict(rules_override={"head_dim": None}, kv_chunk=2048)),
    # H3: batch over both axes (pure DP for attention-heavy prefill);
    #     kv_chunk retained.
    "phi3_it3": ("phi3-medium-14b", "prefill_32k", "_it3_dp256",
                 dict(rules_override={"head_dim": None,
                                      "batch": ("data", "model"),
                                      "mlp": None, "heads": None,
                                      "kv_heads": None},
                      kv_chunk=2048)),

    # -- kimi-k2-1t-a32b x train_4k (most collective-bound) ---------------
    # H1: the flat (-1, 256) int8-moment layout forces a full resharding
    #     all-gather (6 x 1.375 TB in the baseline HLO). The last-axis block
    #     layout (optim.adamw.Q8) inherits the param sharding: those gathers
    #     should vanish. (Layout fix is now the default; this isolates it.)
    "kimi_it1": ("kimi-k2-1t-a32b", "train_4k", "_it1_q8layout", dict()),
    # H2: global top-k dispatch makes capacity buffers global -> expert
    #     compute replicates and xt all-gathers. Shard-local dispatch
    #     groups (= data axis) keep routing inside each shard.
    "kimi_it2": ("kimi-k2-1t-a32b", "train_4k", "_it2_groups",
                 dict(moe_groups=16)),
    # H3: EP over BOTH mesh axes (experts 384 = 256 x 1.5 -> only model) -
    #     instead push the FSDP axis onto the expert mlp dim to shrink the
    #     per-layer weight gathers.
    "kimi_it3": ("kimi-k2-1t-a32b", "train_4k", "_it3_mlpshard",
                 dict(moe_groups=16,
                      rules_override={"embed": None, "mlp": "data"})),

    # -- minicpm-2b x train_4k (representative dense DP cell) -------------
    # H1: the two per-layer TP all-reduces move fp32 activations; a 2.7B
    #     model on 256 chips doesn't need TP at all - batch over both axes
    #     (DP-256) leaves only FSDP weight gathers + grad reductions.
    "minicpm_it1": ("minicpm-2b", "train_4k", "_it1_dp256",
                    dict(rules_override={"batch": ("data", "model"),
                                         "mlp": None, "heads": None,
                                         "kv_heads": None,
                                         "vocab": "model", "embed": "data"})),
    # H2: keep TP but sequence-shard the residual stream so the partial-sum
    #     reduction happens on the (B, S/16, D) slice (Megatron-SP layout).
    "minicpm_it2": ("minicpm-2b", "train_4k", "_it2_seqshard",
                    dict(rules_override={"seq": "model"})),

    # H4 (kimi): propagation alone left the dispatch replicated (it2
    #     refuted); pin the capacity buffers with explicit sharding
    #     constraints on (group->data, expert->model).
    "kimi_it4": ("kimi-k2-1t-a32b", "train_4k", "_it4_moeshard",
                 dict(moe_groups=16, moe_shard=("data", "model"))),

    # H5 (kimi): it4 refuted - constraining the expert axis fights the
    #     einsum partitioner. Constrain only the group axis (mixtral's
    #     winning recipe).
    "kimi_it5": ("kimi-k2-1t-a32b", "train_4k", "_it5_groupshard",
                 dict(moe_groups=16, moe_shard=("data", None))),

    # H6 (kimi): constrain only the token-side tensors (xt, combine);
    #     leave the capacity buffers to the einsum partitioner.
    "kimi_it6": ("kimi-k2-1t-a32b", "train_4k", "_it6_tokonly",
                 dict(moe_groups=16, moe_shard=("data", "tokens-only"))),

    # -- bonus: mixtral inherits the kimi dispatch fixes -------------------
    "mixtral_groups": ("mixtral-8x7b", "train_4k", "_it1_groups",
                       dict(moe_groups=16)),
    "mixtral_it2": ("mixtral-8x7b", "train_4k", "_it2_moeshard",
                    dict(moe_groups=16, moe_shard=("data", None))),

    # H4 (phi3): shard attention over the sequence instead of heads/hd -
    #     flops split over both axes, k/v all-gathered per layer (64x fewer
    #     bytes than the score all-reduce), blockwise scores.
    "phi3_it4": ("phi3-medium-14b", "prefill_32k", "_it4_seqshard",
                 dict(rules_override={"head_dim": None, "seq": "model"},
                      kv_chunk=2048)),
}


def main():
    picks = sys.argv[1:] or list(VARIANTS)
    for name in picks:
        arch, shape, tag, ov = VARIANTS[name]
        rec = run_cell(arch, shape, multi_pod=False,
                       out_dir="experiments/dryrun", tag=tag, **ov)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" compile {rec['compile_s']}s"
                     f" coll/dev {rec['collective_bytes_per_device']['total']:.3g}B"
                     f" temps {rec['memory_analysis'].get('temp_size_in_bytes', 0)/1e9:.0f}GB")
        elif status == "fail":
            extra = " " + rec["error"][:200]
        print(f"[{status}] {name}: {arch} x {shape} {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
