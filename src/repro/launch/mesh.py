"""Production mesh construction.

Deliberately a function, not a module-level constant: importing this module
must never touch jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import,
and smoke tests must keep seeing 1 device).

Topology: TPU v5e pods of 256 chips arranged (data=16, model=16); the
multi-pod mesh adds a leading 'pod' axis over DCN, giving
(pod=2, data=16, model=16) = 512 chips. Batch shards over ('pod', 'data'),
tensor dims over 'model' (intra-pod ICI), so the only cross-pod collective
is the gradient all-reduce - the standard multi-pod layout.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has - used by examples/smoke tests."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
