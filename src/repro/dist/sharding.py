"""Logical-axis sharding rules: ParamSpec axes -> mesh PartitionSpecs.

Every tensor in this codebase names its dims with logical axes (see
``repro.models.spec``); a per-arch *rules* dict maps each logical axis to a
mesh axis (or None = replicate). ``logical_to_pspec`` applies the rules with
two safety valves that make full-size AND reduced configs shard with the
same rules:

* **divisibility fallback** - a dim that does not divide the mesh-axis size
  falls back to replication for that dim (14 heads on a 16-way model axis
  -> replicated; 48 heads -> sharded). No config ever fails to lower just
  because a reduced dim stopped dividing.
* **duplicate-axis guard** - one mesh axis is consumed at most once per
  tensor (left to right); a second logical axis mapped to the same mesh
  axis replicates instead of producing an invalid spec.

Multi-pod meshes add a leading ``pod`` axis over DCN. Rules keep saying
``"data"``; any ``"data"`` assignment transparently expands to
``("pod", "data")`` so the batch (and FSDP dims) span both axes and the
only cross-pod collective is the gradient all-reduce.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.spec import is_spec

__all__ = ["Rules", "DEFAULT_RULES", "logical_to_pspec", "spec_shardings",
           "batch_shardings", "compact_batch", "data_axis_size"]

# A rule maps one logical axis name to a mesh axis, a tuple of mesh axes, or
# None (replicate). Meshes only need .shape (name -> size) and .axis_names,
# so rule-level tests can run against stand-ins for pod-scale meshes.
Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

DEFAULT_RULES: Rules = {
    # data-parallel dims
    "batch": "data",
    "seq": None,
    # tensor-parallel dims: shard the "many units" axis over 'model'
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "experts": "model",
    "state": "model",
    # scan/stacking and small conv dims stay replicated
    "layers": None,
    "conv_in": None,
    "conv_out": "model",
}


def _expand(rule, axis_names) -> Tuple[str, ...]:
    """Normalize a rule value to a tuple of mesh axes, with pod expansion."""
    if rule is None:
        return ()
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    if "pod" in axis_names and "data" in axes and "pod" not in axes:
        out = []
        for a in axes:
            out.extend(("pod", "data") if a == "data" else (a,))
        axes = tuple(out)
    return axes


def logical_to_pspec(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                     rules: Rules, mesh) -> P:
    """PartitionSpec for one tensor, honoring fallback + duplicate guard.

    ``mesh`` only needs ``.shape`` (mapping axis name -> size) and
    ``.axis_names``; both jax.sharding.Mesh and test stand-ins qualify.
    """
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} vs shape {shape} rank mismatch")
    names = tuple(mesh.axis_names)
    used: set = set()
    entries = []
    for logical, dim in zip(axes, shape):
        cand = _expand(rules.get(logical) if logical else None, names)
        ok = (cand
              and all(a in names for a in cand)
              and not (set(cand) & used))
        if ok:
            size = 1
            for a in cand:
                size *= int(mesh.shape[a])
            ok = dim % size == 0
        if ok:
            used.update(cand)
            entries.append(cand[0] if len(cand) == 1 else cand)
        else:
            entries.append(None)
    return P(*entries)


def spec_shardings(specs, rules: Rules, mesh):
    """NamedSharding pytree for a ParamSpec pytree (same structure)."""
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, logical_to_pspec(s.axes, s.shape, rules, mesh)),
        specs, is_leaf=is_spec)


def batch_shardings(mesh, tree, axis: str = "data"):
    """NamedSharding pytree splitting every leaf's *leading* dim over
    ``axis`` - the array-tree sibling of :func:`spec_shardings` for batched
    data that has no ParamSpec (e.g. the NoC sweep engine's variant-stacked
    ``Traffic``/``SimState``, whose variants axis shards across devices).

    The divisibility fallback applies per leaf: a leading dim that does not
    divide the mesh-axis size (or a scalar leaf) replicates instead of
    failing to lower.
    """
    size = int(mesh.shape[axis])

    def one(x):
        shape = getattr(x, "shape", ())
        spec = P(axis) if (shape and shape[0] % size == 0) else P()
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, tree)


def compact_batch(mesh, tree, idx, axis: str = "data"):
    """Regroup a device-sharded batch: gather rows ``idx`` of every leaf's
    leading dim, then re-place the narrower tree across ``axis`` with
    :func:`batch_shardings`.

    This is the sharded half of the NoC drain scheduler's variant
    retirement: when lanes of a sharded ``simulate_batch`` finish early,
    the survivors (plus any clone-padding rows repeated in ``idx`` to keep
    the batch a device multiple) are compacted into a smaller batch without
    a host round-trip for the bulk state - the gather runs on device and
    only the re-placement moves shards. Works for any leaf whose leading
    dim is the batch axis; the usual divisibility fallback applies to the
    re-placement.
    """
    idx = jnp.asarray(idx)
    out = jax.tree.map(lambda x: x[idx], tree)
    return jax.device_put(out, batch_shardings(mesh, out, axis))


def data_axis_size(mesh) -> int:
    """Total data-parallel degree: the 'data' axis, times 'pod' if present."""
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= int(mesh.shape[a])
    return n
