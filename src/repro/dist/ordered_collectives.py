"""Transmission ordering for gradient-collective payloads (beyond-paper).

The paper orders (input, weight) pairs at a memory controller because the
MAC consuming them is order-invariant (Fig. 5). Data-parallel training has
the same structure on its gradient wires: the update consuming a
(gradient, weight) pair is order-invariant, the reduction is elementwise,
and the weights are **replicated** across DP peers - so every peer can
compute the *same* weight-keyed permutation locally and no index ever
travels. That is O1 on the gradient wire. O2 (each stream sorted by its own
popcount) bounds the win at the cost of a per-window index
(:func:`repro.core.ordering.index_overhead_bits`).

Two layers live here:

* :func:`order_gradient_bucket` / :func:`restore_gradient_bucket` - the
  payload transform itself. Built on
  :func:`repro.core.ordering.affiliated_order`; exact inverse (a
  permutation of bit patterns, so restore is bit-identical).
* :func:`gradient_wire_report` - BT telemetry over a 16-lane bf16 phit,
  reusing :mod:`repro.core.bt` and the O0/O1/O2 transforms of
  :mod:`repro.core.wire` on the paired (gradient, weight) stream. Jit-safe:
  the train loop embeds it in the compiled step when --wire-telemetry is on.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bt as bt_mod
from repro.core.ordering import (affiliated_order, index_overhead_bits,
                                 inverse_permutation, pad_to_window)
from repro.core.wire import (AffiliatedTransform, IdentityTransform,
                             SeparatedTransform)

__all__ = ["GradientBucket", "order_gradient_bucket",
           "restore_gradient_bucket", "gradient_wire_report"]


class GradientBucket(NamedTuple):
    """One ordered all-reduce bucket.

    values: the (zero-padded) gradient stream in wire order.
    perm:   wire order as indices into the padded natural-order stream.
            Derived from the replicated weights, so every DP peer holds the
            same perm without communicating it.
    """

    values: jax.Array
    perm: jax.Array


def order_gradient_bucket(grads: jax.Array, weights: jax.Array,
                          window: Optional[int] = 256,
                          tiebreak: str = "stable") -> GradientBucket:
    """O1-order one flat gradient bucket by its weights' '1'-bit counts.

    Both streams are zero-padded to the next window (packet) boundary; the
    returned values may therefore be longer than the input. The (grad,
    weight) pairing survives the sort, which is what makes the elementwise
    all-reduce and the weight update order-invariant.
    """
    po = affiliated_order(grads, weights, window=window, tiebreak=tiebreak)
    return GradientBucket(po.inputs, po.input_perm)


def restore_gradient_bucket(bucket: GradientBucket, length: int) -> jax.Array:
    """Exact inverse of :func:`order_gradient_bucket`.

    Returns the first ``length`` values in natural order, bit-identical to
    the stream that was ordered (a permutation never touches bit patterns).
    """
    inv = inverse_permutation(bucket.perm)
    return bucket.values[inv][:length]


def _flat_stream(tree, dtype) -> jax.Array:
    """Concatenate a pytree's leaves into one flat wire-format stream."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        raise ValueError("empty gradient tree")
    return jnp.concatenate([jnp.ravel(x).astype(dtype) for x in leaves])


def gradient_wire_report(grads, params, window: Optional[int] = 256,
                         lanes: int = 16,
                         wire_dtype=jnp.bfloat16) -> dict:
    """BT of the gradient wire under O0 / O1 / O2, on real gradient trees.

    The phit carries (gradient, weight) pairs - ``lanes//2`` gradients and
    their ``lanes//2`` weights per flit (paper Fig. 2 layout, bf16 wire
    format). Baseline streams natural order; O1 orders pairs by the weight's
    popcount (zero recovery cost: weights are replicated across DP peers and
    the consumer is order-invariant); O2 orders each half by its own
    popcount (needs ``o2_index_bits`` per value to re-pair).

    All values are jnp scalars so the report can live inside a jitted train
    step (``make_train_step(..., wire_telemetry=True)``).
    """
    g = pad_to_window(_flat_stream(grads, wire_dtype), window)
    w = pad_to_window(_flat_stream(params, wire_dtype), window)
    base = IdentityTransform().apply(g, w, lanes)
    o1 = AffiliatedTransform(window=window).apply(g, w, lanes)
    o2 = SeparatedTransform(window=window).apply(g, w, lanes)
    bt0 = bt_mod.bt_stream(base)
    bt1 = bt_mod.bt_stream(o1)
    bt2 = bt_mod.bt_stream(o2)
    eff_window = int(g.shape[0]) if window is None else window
    return {
        "bt_baseline": bt0,
        "bt_o1": bt1,
        "bt_o2": bt2,
        "bt_per_flit_baseline": bt_mod.bt_per_flit(base),
        "reduction_o1": bt_mod.reduction_rate(bt0, bt1),
        "reduction_o2": bt_mod.reduction_rate(bt0, bt2),
        "o2_index_bits": index_overhead_bits(eff_window),
    }
