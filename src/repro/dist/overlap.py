"""Gradient bucketing for collective/compute overlap.

At multi-pod scale the gradient all-reduce is the only cross-pod
collective; hiding it behind the backward pass requires (a) payloads cut
into buckets small enough that reducing bucket k overlaps computing bucket
k+1, and (b) an XLA configuration that actually schedules collectives
asynchronously. ``bucketed``/``unbucket`` do (a) as a pure pytree
transform (leaf order preserved, exact reassembly); ``xla_overlap_flags``
is (b), the flag set the launch scripts export.

Buckets are also the unit the ordering unit sees: each bucket is one
payload for :func:`repro.dist.ordered_collectives.order_gradient_bucket`.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

__all__ = ["bucketed", "unbucket", "xla_overlap_flags"]


def bucketed(tree, max_bytes: int) -> List[list]:
    """Partition a gradient tree's leaves into size-capped buckets.

    Greedy in leaf order (so ``unbucket`` is a plain concatenation): a leaf
    that would push the current bucket past ``max_bytes`` starts a new one.
    A single leaf larger than the cap gets a bucket of its own - it is
    never split, so restore stays a pure permutation of whole leaves.
    """
    if max_bytes <= 0:
        raise ValueError(f"max_bytes must be positive, got {max_bytes}")
    buckets: List[list] = []
    cur: list = []
    cur_bytes = 0
    for leaf in jax.tree.leaves(tree):
        nbytes = leaf.size * jnp.dtype(leaf.dtype).itemsize
        if cur and cur_bytes + nbytes > max_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(leaf)
        cur_bytes += nbytes
    if cur:
        buckets.append(cur)
    return buckets


def unbucket(buckets: List[list], tree):
    """Reassemble ``bucketed`` output into the original tree structure."""
    flat = [leaf for bucket in buckets for leaf in bucket]
    treedef = jax.tree.structure(tree)
    if len(flat) != treedef.num_leaves:
        raise ValueError(
            f"buckets hold {len(flat)} leaves, tree has {treedef.num_leaves}")
    return jax.tree.unflatten(treedef, flat)


def xla_overlap_flags() -> str:
    """XLA_FLAGS value enabling async collectives + latency-hiding scheduling.

    Exported by the launch scripts so the gradient all-reduce of bucket k
    overlaps the backward compute of bucket k+1 on TPU (and the CPU
    dry-run lowers with the same schedule).
    """
    return " ".join([
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
        "--xla_tpu_overlap_compute_collective_tc=true",
        "--xla_latency_hiding_scheduler_rerun=1",
    ])
