"""Distributed substrate: sharding rules, ordered collectives, static weight
layouts, and comm/compute overlap helpers.

This package extends the paper's transmission-ordering idea from NoC links
to the wires of a distributed training job:

* :mod:`repro.dist.sharding` - logical-axis -> mesh-axis rules with
  divisibility fallback (the GSPMD layer every launch script shards with).
* :mod:`repro.dist.ordered_collectives` - the paper's O1/O2 orderings
  applied to gradient all-reduce payloads (bucket transform + BT report).
* :mod:`repro.dist.static_reorder` - popcount-descending hidden-unit
  layouts that leave model outputs bit-identical (Fig. 5 order invariance,
  applied to stored weights instead of in-flight streams).
* :mod:`repro.dist.overlap` - gradient bucketing for collective/compute
  overlap plus the XLA flags that enable async collectives.
"""
from . import overlap, ordered_collectives, sharding, static_reorder
from .ordered_collectives import (GradientBucket, gradient_wire_report,
                                  order_gradient_bucket,
                                  restore_gradient_bucket)
from .overlap import bucketed, unbucket, xla_overlap_flags
from .sharding import (DEFAULT_RULES, Rules, batch_shardings, data_axis_size,
                       logical_to_pspec, spec_shardings)
from .static_reorder import (mlp_unit_permutation, reorder_lm_params,
                             reorder_mlp, stream_bt_report)

__all__ = [
    "sharding", "ordered_collectives", "static_reorder", "overlap",
    "Rules", "DEFAULT_RULES", "logical_to_pspec", "spec_shardings",
    "batch_shardings", "data_axis_size",
    "GradientBucket", "order_gradient_bucket", "restore_gradient_bucket",
    "gradient_wire_report",
    "mlp_unit_permutation", "reorder_mlp", "reorder_lm_params",
    "stream_bt_report",
    "bucketed", "unbucket", "xla_overlap_flags",
]
