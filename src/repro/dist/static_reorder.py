"""Static popcount-ordered weight layouts (the paper's Fig. 5, stored).

The paper reorders data in flight at a memory controller. Hidden units of
an MLP admit the same trick *statically*: permuting the up/gate projection
columns together with the down projection rows is a similarity transform of
the block - the model's outputs are bit-identical (the contraction consumes
(unit activation, unit row) pairs, which travel together, exactly the
affiliated-ordering invariance). Stored popcount-descending, every weight
stream leaving HBM is already in the paper's wire order, so the ordering
unit's sort stage becomes a no-op for weight traffic.

``reorder_lm_params`` rewrites every MLP (and MoE expert FFN) in an LM
parameter tree this way; ``stream_bt_report`` measures what the layout is
worth on the wire: BT per 16-lane phit of the unit-major weight stream,
before vs after.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bt as bt_mod
from repro.core.bits import popcount
from repro.core.flits import pack

__all__ = ["mlp_unit_permutation", "reorder_mlp", "reorder_lm_params",
           "stream_bt_report"]


def mlp_unit_permutation(w: jax.Array) -> jax.Array:
    """Popcount-descending permutation of the unit (last) axis of ``w``.

    ``w`` is ``(..., d, f)`` with hidden units as columns; the key for unit
    j is the total '1'-bit count of column j. Leading axes (e.g. the scan's
    stacked layers) get independent permutations. Stable among ties.
    """
    counts = jnp.sum(popcount(w), axis=-2)
    return jnp.argsort(-counts, axis=-1)


def _take_cols(w: jax.Array, perm: jax.Array) -> jax.Array:
    """w (..., d, f)[..., :, perm] with batched perm (..., f)."""
    return jnp.take_along_axis(w, jnp.expand_dims(perm, -2), axis=-1)


def _take_rows(w: jax.Array, perm: jax.Array) -> jax.Array:
    """w (..., f, d)[..., perm, :] with batched perm (..., f)."""
    return jnp.take_along_axis(w, jnp.expand_dims(perm, -1), axis=-2)


def reorder_mlp(p: dict):
    """Reorder one MLP param dict {"wu", "wd"[, "wg", ...]} -> (new, perm).

    The permutation key is the unit's total popcount across every matrix it
    appears in (wu/wg columns + wd rows) - that is the unit's full wire
    footprint. Keys other than wu/wg/wd (e.g. a MoE router) pass through
    untouched. Works on 2D mats and on scan-stacked (layers, ...) mats,
    where each layer (and each expert) gets its own permutation.
    """
    mats = [p["wu"]]
    if "wg" in p:
        mats.append(p["wg"])
    mats.append(jnp.swapaxes(p["wd"], -1, -2))
    perm = mlp_unit_permutation(jnp.concatenate(mats, axis=-2))
    new = dict(p)
    new["wu"] = _take_cols(p["wu"], perm)
    if "wg" in p:
        new["wg"] = _take_cols(p["wg"], perm)
    new["wd"] = _take_rows(p["wd"], perm)
    return new, perm


def _is_mlp_dict(v) -> bool:
    return isinstance(v, dict) and "wu" in v and "wd" in v


def reorder_lm_params(params):
    """Popcount-order every MLP / MoE-FFN in an LM parameter tree.

    Outputs of the reordered model are bit-identical to the original
    (tests/test_static_reorder.py pins this for gated and ungated MLPs).
    """
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if _is_mlp_dict(v):
                    out[k], _ = reorder_mlp(v)
                else:
                    out[k] = walk(v)
            return out
        return node
    return walk(params)


def _unit_major_stream(params, wire_dtype) -> jax.Array:
    """Flat unit-major stream of every MLP matrix in the tree.

    Each matrix is streamed one hidden unit at a time (wu/wg transposed to
    (..., f, d); wd is already unit-major) - the order a weight-stationary
    accelerator fetches an FFN, and the order the static layout optimizes.
    """
    chunks = []

    def walk(node):
        if isinstance(node, dict):
            for k in sorted(node):
                v = node[k]
                if _is_mlp_dict(v):
                    for name in ("wu", "wg", "wd"):
                        if name in v:
                            m = v[name] if name == "wd" else \
                                jnp.swapaxes(v[name], -1, -2)
                            chunks.append(jnp.ravel(m).astype(wire_dtype))
                else:
                    walk(v)

    walk(params)
    if not chunks:
        raise ValueError("no MLP blocks found in the parameter tree")
    return jnp.concatenate(chunks)


def stream_bt_report(before, after, lanes: int = 16,
                     wire_dtype=jnp.bfloat16) -> dict:
    """BT per flit of the unit-major MLP weight stream, before vs after.

    ``before``/``after`` are full LM parameter trees (e.g. ``params`` and
    ``reorder_lm_params(params)``); only the MLP matrices - the tensors the
    static layout touches - are streamed.
    """
    s0 = pack(_unit_major_stream(before, wire_dtype), lanes)
    s1 = pack(_unit_major_stream(after, wire_dtype), lanes)
    bt0 = bt_mod.bt_per_flit(s0)
    bt1 = bt_mod.bt_per_flit(s1)
    return {
        "bt_per_flit_before": bt0,
        "bt_per_flit_after": bt1,
        "reduction": bt_mod.reduction_rate(bt0, bt1),
    }
