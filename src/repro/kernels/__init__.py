"""Pallas TPU kernels for the paper's compute hot spots.

The ordering unit (paper Fig. 14) = popcount + sort; the BT recorder
(Fig. 8) = XOR + popcount + accumulate. Each kernel has a pure-jnp oracle
in ref.py and a shape-adapting public wrapper in ops.py.
"""
from .ops import (popcount, bt_boundaries, sort_windows_desc,
                  order_unit, on_tpu)
from . import ref

__all__ = ["popcount", "bt_boundaries", "sort_windows_desc",
           "order_unit", "on_tpu", "ref"]
