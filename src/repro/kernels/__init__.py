"""Pallas TPU kernels for the paper's compute hot spots.

The ordering unit (paper Fig. 14) = popcount + sort; the BT recorder
(Fig. 8) = XOR + popcount + accumulate. Each kernel has a pure-jnp oracle
in ref.py and a shape-adapting public wrapper in ops.py.
"""
from .ops import (popcount, bt_boundaries, sort_windows_desc,
                  order_unit, on_tpu)
from .min_hamming import (min_hamming_chain, min_hamming_chain_reference,
                          chain_cost)
from . import ref

__all__ = ["popcount", "bt_boundaries", "sort_windows_desc",
           "order_unit", "on_tpu", "ref",
           "min_hamming_chain", "min_hamming_chain_reference", "chain_cost"]
