"""Public jit'd wrappers around the Pallas kernels.

These adapt arbitrary shapes/dtypes to the kernels' (8k, 128k) tiling
contracts (padding + unsigned views), and select interpret mode
automatically: compiled Mosaic on TPU, interpret=True elsewhere (this
container is CPU-only, so tests exercise the kernel bodies in interpret
mode against the ref.py oracles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bits import unsigned_view, bit_width
from . import popcount as _pc
from . import bt_count as _bt
from . import bitonic_sort as _bs
from . import order_unit as _ou

__all__ = ["popcount", "bt_boundaries", "sort_windows_desc", "order_unit",
           "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _pad2d(x: jax.Array, row_mult: int, col_mult: int):
    m, n = x.shape
    pm = (-m) % row_mult
    pn = (-n) % col_mult
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, m, n


def popcount(values: jax.Array) -> jax.Array:
    """'1'-bit count per element via the Pallas kernel. Any shape/dtype."""
    u = unsigned_view(values)
    nbits = bit_width(u.dtype)
    flat = u.reshape(-1).astype(jnp.uint32)
    if nbits < 32:
        # Sub-32-bit values are zero-extended; SWAR popcount is unaffected.
        pass
    n = flat.shape[0]
    cols = 128
    rows = -(-n // cols)
    pad = rows * cols - n
    arr = jnp.pad(flat, (0, pad)).reshape(rows, cols)
    arr, m0, _ = _pad2d(arr, _pc.ROW_TILE, 128)
    out = _pc.popcount_words_pallas(arr, interpret=_interpret())
    return out[:m0].reshape(-1)[:n].reshape(values.shape)


def bt_boundaries(words: jax.Array) -> jax.Array:
    """Bit transitions at each boundary of a (F, L) flit stream -> (F-1,)."""
    u = unsigned_view(words).astype(jnp.uint32)
    prev_rows, cur_rows = u[:-1], u[1:]
    p = prev_rows.shape[0]
    if p == 0:
        return jnp.zeros((0,), jnp.int32)
    prev_p, p0, _ = _pad2d(prev_rows, _bt.ROW_TILE, 128)
    cur_p, _, _ = _pad2d(cur_rows, _bt.ROW_TILE, 128)
    out = _bt.bt_boundaries_pallas(prev_p, cur_p, interpret=_interpret())
    return out[:p0, 0]


def sort_windows_desc(keys: jax.Array, *payloads: jax.Array):
    """Descending key sort within each row of (R, W) arrays via bitonic net.

    W must be a power of two >= 128 (the framework's packet windows are).
    Payloads are carried through the same swaps (weights / paired inputs).
    """
    r, w = keys.shape
    kp, r0, _ = _pad2d(keys.astype(jnp.int32), _bs.ROW_TILE, w)
    # Pad keys with -1 so padding rows sort harmlessly (rows are independent,
    # so padded rows never mix with real data; -1 keeps them inert).
    pads = []
    for p in payloads:
        u = unsigned_view(p)
        pp, _, _ = _pad2d(u, _bs.ROW_TILE, w)
        pads.append(pp)
    outs = _bs.sort_windows_pallas(kp, *pads, interpret=_interpret())
    sk = outs[0][:r0]
    sp = []
    for orig, s in zip(payloads, outs[1:]):
        s = s[:r0]
        if s.dtype != orig.dtype:
            s = jax.lax.bitcast_convert_type(s, orig.dtype)
        sp.append(s)
    return (sk, *sp)


def order_unit(values: jax.Array):
    """Fused ordering unit: (R, W) values -> (descending-popcount-ordered
    values, window-local permutation). One HBM round-trip (Fig. 14 fused)."""
    u = unsigned_view(values)
    r, w = u.shape
    up, r0, _ = _pad2d(u.astype(jnp.uint32), _ou.ROW_TILE, w)
    out, perm = _ou.order_unit_pallas(up, interpret=_interpret())
    out = out[:r0]
    if out.dtype != values.dtype:
        out = jax.lax.bitcast_convert_type(out, values.dtype)
    return out, perm[:r0]
