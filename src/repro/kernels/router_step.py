"""Pallas kernel: one NoC router cycle over the whole mesh state.

This is the per-cycle hot loop of ``repro.noc.sim`` - sideband front
gather, X-Y route computation, credit check, masked-min round-robin
arbitration, the combined push+inject scatter, and the Fig. 8 BT
accumulate - as a single ``pl.pallas_call`` body. The surrounding scan,
the injection-row gather from the (M, T, LF) wire tensor (which cannot
live in VMEM at DarkNet scale), and the drain bookkeeping stay in
``noc.sim``; the kernel sees one cycle's state plus the M pre-gathered
injection rows and returns the next state.

Backend contract (``kernels/ops.py``): ``interpret=True`` on CPU so the
parity suite pins the kernel cycle-for-cycle against the fused step and
the frozen ``noc._reference`` step everywhere; on TPU the same body
compiles through Mosaic with the whole state resident in VMEM (an 8x8
mesh's fused FIFO tensor is ~350 KB, a 16x16's ~5.6 MB). The arithmetic
is copied from ``noc.sim._make_step`` op for op - same gathers, same
select chains, same scatter - so the two backends are bit-identical by
construction, and the parity tests keep them that way. The BT recorder
uses the SWAR popcount form (``kernels/popcount.py``) instead of
``lax.population_count``, which Mosaic does not lower; the two are
pinned equal by the kernel parity suite.

The conservation ledger (``check_conservation``) is a debug path and is
not carried here - ``noc.sim`` routes tracked drains through the fused
step regardless of the requested backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.noc.topology import (NocConfig, NUM_PORTS, PORT_E, PORT_LOCAL,
                                PORT_N, PORT_S, PORT_W)

__all__ = ["router_step_pallas", "make_router_step"]

# Sideband layout - must match noc.sim (imported there and checked by the
# parity suite; duplicated as literals here to keep the kernel module free
# of a circular import on noc.sim).
_SIDE_META_SHIFT = 9
_SIDE_VC_SHIFT = 11
_DEST_MASK = (1 << 9) - 1
_META_MASK = 3
_META_PAYLOAD = 1


def _popcount(x: jax.Array) -> jax.Array:
    """SWAR popcount (the popcount.py body) - Mosaic-lowerable, pinned
    bit-identical to ``lax.population_count`` by the parity suite."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _make_kernel(mesh_key, count_headers: bool, lf: int, m: int):
    """Build the kernel body for one (mesh size, recorder, stream count).

    All routing geometry is baked in as compile-time constants exactly as
    in ``noc.sim._make_step`` (same derivation, same names).
    """
    rows, cols, num_vcs, vc_depth, lanes = mesh_key
    cfg = NocConfig(rows, cols, (), num_vcs=num_vcs, vc_depth=vc_depth,
                    lanes=lanes)
    nr, p, v, d, l = (cfg.num_routers, NUM_PORTS, cfg.num_vcs, cfg.vc_depth,
                      cfg.lanes)
    nslots = p * v

    phantom_row = nr * NUM_PORTS * num_vcs * vc_depth

    def _geometry():
        """Routing constants, derived in-kernel from iota arithmetic.

        Pallas kernels cannot capture array constants, so the tables
        ``noc.sim._make_step`` bakes in as numpy arrays are recomputed
        here elementwise - same values (checked by the parity suite),
        zero inputs. Directions 0..3 are N, E, S, W; ``OPPOSITE`` for
        them is ``(dir + 2) % 4``.
        """
        cid3 = jax.lax.broadcasted_iota(jnp.int32, (nr, 1, 1), 0)
        rrow = cid3 // cols
        rcol = cid3 % cols
        c2 = jax.lax.broadcasted_iota(jnp.int32, (nr, 4), 0)
        dirid = jax.lax.broadcasted_iota(jnp.int32, (nr, 4), 1)
        rr2, cc2 = c2 // cols, c2 % cols
        delta = jnp.where(
            dirid == PORT_N, -cols, jnp.where(
                dirid == PORT_E, 1, jnp.where(
                    dirid == PORT_S, cols, -1)))
        down2 = c2 + delta
        dir_ok = jnp.where(
            dirid == PORT_N, rr2 > 0, jnp.where(
                dirid == PORT_E, cc2 < cols - 1, jnp.where(
                    dirid == PORT_S, rr2 < rows - 1, cc2 > 0)))
        opp = (dirid + 2) % 4
        nb_blk = jnp.where(dir_ok, down2 * NUM_PORTS + opp,
                           nr * NUM_PORTS)
        src_po = jnp.where(dir_ok, down2, 0) * NUM_PORTS + opp
        rcv_base = c2 * NUM_PORTS + dirid
        return rrow, rcol, nb_blk, dir_ok, src_po, rcv_base

    def kernel(fifo_ref, head_ref, count_ref, rr_ref, link_last_ref,
               link_bt_ref, link_flits_ref, inj_ptr_ref, inj_last_ref,
               inj_bt_ref, ejected_ref, cycle_ref, drained_ref,
               iw_ref, active_ref, mc_ref, total_ref,
               fifo_o, head_o, count_o, rr_o, link_last_o,
               link_bt_o, link_flits_o, inj_ptr_o, inj_last_o,
               inj_bt_o, ejected_o, cycle_o, drained_o):
        rrow, rcol, nb_blk, src_ok, src_po, rcv_base = _geometry()
        fifo_rows = fifo_ref[...]                   # ((NR+1)*P*V*D, LF)
        head_full = head_ref[...]                   # (NR+1, P, V)
        count_full = count_ref[...]
        rr = rr_ref[...]                            # (NR, P)
        head_r = head_full[:nr]
        count_r = count_full[:nr]
        valid = count_r > 0

        # --- front sideband: one word per FIFO ---
        side_col = fifo_rows[:, l]
        front_row = (jnp.arange(nr * p * v, dtype=jnp.int32) * d
                     + head_r.reshape(-1))
        fside = jnp.take(side_col, front_row, mode="clip").astype(jnp.int32)
        fside = fside.reshape(nr, p, v)
        fd = fside & _DEST_MASK

        # --- route computation (X-Y, closed form) ---
        dr, dc = fd // cols, fd % cols
        out_port = jnp.where(
            dc > rcol, PORT_E, jnp.where(
                dc < rcol, PORT_W, jnp.where(
                    dr > rrow, PORT_S, jnp.where(
                        dr < rrow, PORT_N, PORT_LOCAL)))).astype(jnp.int32)

        # --- credit check ---
        is_eject = out_port == PORT_LOCAL
        count_blocks = count_full.reshape((nr + 1) * p, v)
        ok = (jnp.take(count_blocks, nb_blk.reshape(-1), axis=0,
                       mode="clip").reshape(nr, 4, v) < d)
        space = jnp.where(
            out_port == PORT_N, ok[:, None, PORT_N, :], jnp.where(
                out_port == PORT_E, ok[:, None, PORT_E, :], jnp.where(
                    out_port == PORT_S, ok[:, None, PORT_S, :],
                    ok[:, None, PORT_W, :])))
        request = valid & (is_eject | space)

        # --- switch allocation: masked-min round-robin ---
        slot_req = request.reshape(nr, nslots)
        slot_out = out_port.reshape(nr, nslots)
        outs = jnp.arange(NUM_PORTS)[None, :, None]
        req_po = slot_req[:, None, :] & (slot_out[:, None, :] == outs)
        slots = jnp.arange(nslots, dtype=jnp.int32)[None, None, :]
        rel = slots - rr[:, :, None]
        rel = jnp.where(rel < 0, rel + nslots, rel)
        min_rel = jnp.where(req_po, rel, nslots).min(axis=2)
        has = min_rel < nslots
        winner = rr + min_rel
        winner = jnp.where(winner >= nslots, winner - nslots, winner)
        rr_new = winner + 1
        rr_new = jnp.where(rr_new >= nslots, rr_new - nslots, rr_new)
        rr_new = jnp.where(has, rr_new, rr)

        # --- pops ---
        pop = ((slots == winner[:, :, None]) & has[:, :, None]).any(axis=1)
        pop = pop.reshape(nr, p, v)
        head_new = jnp.where(pop, (head_r + 1) % d, head_r)
        count_new = count_r - pop.astype(jnp.int32)
        head2 = head_full.at[:nr].set(head_new)
        count2 = count_full.at[:nr].set(count_new)

        # --- gather the winners' flits only ---
        win_p = winner // v
        win_v = winner % v
        r2 = jnp.arange(nr, dtype=jnp.int32)[:, None]
        win_pv = (r2 * p + win_p) * v + win_v
        win_head = jnp.take(head_full.reshape(-1), win_pv.reshape(-1),
                            mode="clip")
        win_row = win_pv.reshape(-1) * d + win_head
        mv = jnp.take(fifo_rows, win_row, axis=0,
                      mode="clip").reshape(nr, p, lf)
        mv_side = mv[..., l].astype(jnp.int32)
        mv_meta = (mv_side >> _SIDE_META_SHIFT) & _META_MASK

        # --- link BT recording ---
        tog = _popcount(link_last_ref[...] ^ mv[..., :l]).sum(-1)
        if count_headers:
            counted = has
        else:
            counted = has & ((mv_meta & _META_PAYLOAD) > 0)
        link_bt_o[...] = link_bt_ref[...] + jnp.where(counted, tog, 0)
        link_flits_o[...] = link_flits_ref[...] + has.astype(jnp.int32)
        link_last_o[...] = jnp.where(has[:, :, None], mv[..., :l],
                                     link_last_ref[...])

        # --- pushes, receiver-side ---
        o_ids = jnp.arange(NUM_PORTS)[None, :]
        inc_ok = (jnp.take(has.reshape(-1), src_po.reshape(-1), mode="clip")
                  .reshape(nr, 4) & src_ok)
        inc_vc = jnp.take(win_v.reshape(-1), src_po.reshape(-1),
                          mode="clip").reshape(nr, 4)
        inc_w = jnp.take(mv.reshape(nr * p, lf), src_po.reshape(-1),
                         axis=0, mode="clip")
        wc4 = (head2[:nr, :4, :] + count2[:nr, :4, :]) % d
        wslot = wc4[..., 0]
        for vi in range(1, v):
            wslot = jnp.where(inc_vc == vi, wc4[..., vi], wslot)
        ejected = (ejected_ref[...][0]
                   + jnp.sum(has & (o_ids == PORT_LOCAL)).astype(jnp.int32))

        # --- injection ---
        iw = iw_ref[...]                            # (M, LF) pre-gathered
        active = active_ref[...] > 0                # (M,)
        iside = iw[..., l].astype(jnp.int32)
        imeta = (iside >> _SIDE_META_SHIFT) & _META_MASK
        ivc = iside >> _SIDE_VC_SHIFT
        head2_flat = head2.reshape(-1)
        count2_flat = count2.reshape(-1)
        mc_pv = (mc_ref[...] * p + PORT_LOCAL) * v + ivc
        mc_cnt = jnp.take(count2_flat, mc_pv, mode="clip")
        can = active & (mc_cnt < d)
        inj_pv = jnp.where(can, mc_pv, (nr * p + PORT_LOCAL) * v + ivc)
        islot = (jnp.take(head2_flat, inj_pv, mode="clip")
                 + jnp.take(count2_flat, inj_pv, mode="clip")) % d

        # --- one combined push+inject scatter ---
        rcv_row = jnp.where(inc_ok, (rcv_base * v + inc_vc) * d + wslot,
                            phantom_row)
        cat_row = jnp.concatenate([rcv_row.reshape(-1), inj_pv * d + islot])
        cat_w = jnp.concatenate([inc_w, iw])
        fifo_o[...] = fifo_rows.at[cat_row].set(cat_w,
                                                mode="promise_in_bounds")
        vcs4 = jnp.arange(v, dtype=jnp.int32)[None, None, :]
        count_inc = ((vcs4 == inc_vc[..., None])
                     & inc_ok[..., None]).astype(jnp.int32)
        count_o[...] = count2.at[:nr, :4, :].add(count_inc).reshape(-1).at[
            inj_pv].add(can.astype(jnp.int32),
                        mode="promise_in_bounds").reshape(count2.shape)
        head_o[...] = head2
        rr_o[...] = rr_new
        inj_ptr_o[...] = inj_ptr_ref[...] + can.astype(jnp.int32)

        # --- NI-link BT ---
        itog = _popcount(inj_last_ref[...] ^ iw[..., :l]).sum(-1)
        if count_headers:
            icounted = can
        else:
            icounted = can & ((imeta & _META_PAYLOAD) > 0)
        inj_bt_o[...] = inj_bt_ref[...] + jnp.where(icounted, itog, 0)
        inj_last_o[...] = jnp.where(can[:, None], iw[..., :l],
                                    inj_last_ref[...])

        cycle = cycle_ref[...][0]
        drained = drained_ref[...][0]
        drained_new = jnp.where(
            (drained < 0) & (ejected >= total_ref[...][0]),
            cycle + 1, drained)
        ejected_o[...] = ejected[None]
        cycle_o[...] = (cycle + 1)[None]
        drained_o[...] = drained_new[None]

    return kernel


@functools.lru_cache(maxsize=None)
def make_router_step(mesh_key, count_headers: bool, lf: int, m: int,
                     interpret: bool):
    """Pallas-callable for one router cycle; cached per static signature.

    The callable takes the 13 state leaves (fifo flattened to rows), the
    pre-gathered injection rows ``iw`` (M, LF), the ``active`` int32 mask
    (M,), the per-stream injection nodes (M,) and the scalar flit total
    (1,), and returns the 13 next-cycle leaves in the same layout.
    """
    rows, cols, num_vcs, vc_depth, lanes = mesh_key
    nr = rows * cols
    p, v, d, l = NUM_PORTS, num_vcs, vc_depth, lanes
    nrows = (nr + 1) * p * v * d
    kernel = _make_kernel(mesh_key, count_headers, lf, m)
    out_shape = [
        jax.ShapeDtypeStruct((nrows, lf), jnp.uint32),      # fifo rows
        jax.ShapeDtypeStruct((nr + 1, p, v), jnp.int32),    # head
        jax.ShapeDtypeStruct((nr + 1, p, v), jnp.int32),    # count
        jax.ShapeDtypeStruct((nr, p), jnp.int32),           # rr
        jax.ShapeDtypeStruct((nr, p, l), jnp.uint32),       # link_last
        jax.ShapeDtypeStruct((nr, p), jnp.int32),           # link_bt
        jax.ShapeDtypeStruct((nr, p), jnp.int32),           # link_flits
        jax.ShapeDtypeStruct((m,), jnp.int32),              # inj_ptr
        jax.ShapeDtypeStruct((m, l), jnp.uint32),           # inj_last
        jax.ShapeDtypeStruct((m,), jnp.int32),              # inj_bt
        jax.ShapeDtypeStruct((1,), jnp.int32),              # ejected
        jax.ShapeDtypeStruct((1,), jnp.int32),              # cycle
        jax.ShapeDtypeStruct((1,), jnp.int32),              # drained_at
    ]
    return pl.pallas_call(kernel, out_shape=out_shape, interpret=interpret)


def router_step_pallas(mesh_key, count_headers: bool, lf: int,
                       state_leaves, iw, active, mc_nodes, total,
                       *, interpret: bool = True):
    """One router cycle through the Pallas kernel (see module docstring).

    state_leaves: the 13 SimState leaves with fifo pre-flattened to
        ``((NR+1)*P*V*D, LF)`` rows and the three scalars shaped (1,).
    """
    m = int(iw.shape[0])
    call = make_router_step(mesh_key, count_headers, lf, m, interpret)
    return call(*state_leaves, iw, active, mc_nodes, total)
