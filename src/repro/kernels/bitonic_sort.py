"""Pallas TPU kernel: windowed descending bitonic key-value sort.

This is the ordering unit itself, adapted to TPU. The paper's RTL uses
bubble sort (Fig. 14) - a serial-hardware idiom with data-dependent swap
chains that has no efficient TPU mapping. A bitonic sorting network is the
TPU-native equivalent: O(log^2 W) compare-exchange stages, every stage a
branch-free vectorized select over static lane pairings, identical work for
every window, so it vectorizes across windows (rows) and pipelines through
VMEM. DESIGN.md records this as a hardware adaptation.

Each grid step sorts ROW_TILE windows of width W (a power of two): keys are
the '1'-bit counts, and one or two payload arrays (weights, and inputs for
affiliated ordering) ride along through the same swaps.

The compare-exchange at (stage k, substage j) pairs lane i with lane i^2^j;
we realize it with a static reshape (R, W) -> (R, G, 2, s) so both halves of
every pair sit in adjacent slices - no gathers, only selects on an iota-
derived direction mask, which Mosaic lowers to vregs ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sort_windows_pallas", "ROW_TILE"]

ROW_TILE = 8


def _compare_exchange(keys, payloads, k: int, j: int, w: int):
    """One bitonic compare-exchange substage over the last axis (width w)."""
    s = 1 << j
    g = w // (2 * s)
    r = keys.shape[0]

    def split(x):
        x = x.reshape(r, g, 2, s)
        return x[:, :, 0, :], x[:, :, 1, :]

    ka, kb = split(keys)
    # Descending overall: block b = i >> (k+1) sorts descending when even.
    # Group index of lane pair = g'; block index = g' >> (k - j).
    grp = jax.lax.broadcasted_iota(jnp.int32, (r, g, s), 1)
    desc = ((grp >> (k - j)) & 1) == 0
    swap = jnp.where(desc, ka < kb, ka > kb)

    def merge(a, b):
        lo = jnp.where(swap, b, a)
        hi = jnp.where(swap, a, b)
        return jnp.stack([lo, hi], axis=2).reshape(r, w)

    new_keys = merge(ka, kb)
    new_payloads = []
    for p in payloads:
        pa, pb = split(p)
        new_payloads.append(merge(pa, pb))
    return new_keys, tuple(new_payloads)


def _make_kernel(w: int, n_payloads: int):
    stages = w.bit_length() - 1  # log2(w)

    def kernel(*refs):
        keys = refs[0][...]
        payloads = tuple(refs[1 + i][...] for i in range(n_payloads))
        for k in range(stages):
            for j in range(k, -1, -1):
                keys, payloads = _compare_exchange(keys, payloads, k, j, w)
        refs[1 + n_payloads][...] = keys
        for i in range(n_payloads):
            refs[2 + n_payloads + i][...] = payloads[i]

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_windows_pallas(keys: jax.Array, *payloads: jax.Array,
                        interpret: bool = True):
    """Sort each row of (R, W) descending by key, payloads riding along.

    W must be a power of two >= 128 (lane-width multiple) and R a multiple
    of ROW_TILE; ops.py pads arbitrary streams to this contract. Keys are
    int32; payloads any 32-bit dtype. Bitonic networks are not stable -
    the ordering objective (Eq. 4) only depends on keys, so stability is
    irrelevant on the wire; the ref oracle is compared on keys and on the
    *multiset* of (key, payload) pairs.
    """
    r, w = keys.shape
    if w & (w - 1) or w < 128:
        raise ValueError(f"window must be a power of two >= 128, got {w}")
    if r % ROW_TILE:
        raise ValueError(f"rows must be a multiple of {ROW_TILE}, got {r}")
    for p in payloads:
        if p.shape != keys.shape:
            raise ValueError("payload shape must match keys")
    n_payloads = len(payloads)
    kernel = _make_kernel(w, n_payloads)
    grid = (r // ROW_TILE,)
    spec = pl.BlockSpec((ROW_TILE, w), lambda i: (i, 0))
    out_shapes = [jax.ShapeDtypeStruct((r, w), jnp.int32)] + [
        jax.ShapeDtypeStruct((r, w), p.dtype) for p in payloads
    ]
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * (1 + n_payloads),
        out_specs=[spec] * (1 + n_payloads),
        out_shape=out_shapes,
        interpret=interpret,
    )(keys.astype(jnp.int32), *payloads)
    return tuple(outs)
