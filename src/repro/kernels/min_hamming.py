"""Batched minimum-Hamming-distance chaining - the O3 ordering kernel.

O1/O2 sort by popcount, a *proxy* for the link-power objective: what the
wires actually pay is the Hamming distance between consecutive values on a
lane (operands Hamming distance optimization; see PAPERS.md). O3 optimizes
that objective directly: within each ordering window it chains values so
each value is followed by a near-nearest neighbor in Hamming space.

The chain is greedy nearest-neighbor with two quality refinements, both
pinned against a brute-force optimal-path oracle on exhaustive small
windows (tests/test_ordering_o3.py):

* **multi-start**: a greedy chain is only as good as its first element, and
  the best start is not always the max-popcount value (e.g. the window
  ``{1, 2, 3}`` is optimally chained ``1 -> 3 -> 2``). The kernel runs the
  chain from ``starts`` positions spread evenly over the descending-popcount
  ranks and keeps the cheapest; windows with at most ``starts`` distinct
  positions are covered exhaustively, which is what makes the oracle
  equality on <= 6-value windows hold by construction.
* **beam lookahead**: each step scores the ``beam`` nearest candidates by
  ``d(cur, c) + min_r d(c, r)`` (one step of lookahead) instead of by
  ``d(cur, c)`` alone, breaking ties toward the smaller immediate hop and
  then the smaller index (fully deterministic).

A third guard makes the chain *never worse than not reordering*: the
zeros-to-tail identity order is always evaluated as a candidate and wins
ties, so ``chain cost <= identity cost`` for every window.

Zero values (exact-zero payload, i.e. window padding) are excluded from the
chain and appended at the tail in original order: the result-phase
packetizer slices packets to their real flit count and relies on padding
zeros occupying the tail flits (the same contract O1/O2 satisfy because
popcount 0 sorts last).

Implementation notes: this is a pure-jnp batched kernel (vmappable over
windows, like the transform vmaps in ``repro.noc.traffic``), not a Pallas
body - the chain is a data-dependent ``lax.scan`` whose per-step work is a
masked-argmin over pairwise XOR-popcount distances computed on the fly
(never a (W, W) matrix, so streamed chunks stay memory-bounded). The greedy
selection is encoded in one int32 key per candidate,
``(d + lookahead) * K1 + d * K2 + index`` plus large penalties for visited /
zero-region entries, so lexicographic tie-breaking is a single argmin.
``min_hamming_chain_reference`` is the per-window numpy mirror (same
arithmetic, python loops) used as the equivalence oracle by the property
suite.

Dispatch layout (the fig12 packetizer hot path): the scan runs ONCE per
chain call with every window of the batch in flight - windows stacked on
the outer vmap axis, the multi-start fan vmapped inside - and the whole
stack dispatches through one jitted entry (``_chain_stack``), so ordering a
layer costs one executable launch regardless of its packet count. Beam
candidates are picked by ``beam`` iterated argmins over the selection key
instead of ``lax.top_k``: the key embeds the candidate index, so keys are
distinct and the two are bit-identical - but XLA:CPU lowers top-k to a full
sort that was ~95% of the per-step cost. ``chain_select_pallas`` is the
TPU-resident variant of that distance+select body (SWAR popcount keys +
the bitonic network from ``bitonic_sort``), parity-pinned in interpret
mode and slotted in on Mosaic where iterated argmins serialize badly.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.bits import popcount32, unsigned_view

__all__ = ["ChainResult", "min_hamming_chain", "min_hamming_chain_reference",
           "chain_cost", "chain_select_pallas", "DEFAULT_BEAM",
           "DEFAULT_STARTS"]

DEFAULT_BEAM = 2
DEFAULT_STARTS = 8

# Penalty encoding (int32): a visited candidate must lose to any zero-region
# one, and a zero-region candidate to any live one. Legit scores are bounded
# by (2*64) * K1 + 64 * K2 + W with K1 = 130*W, K2 = W, i.e. ~16705*W, so
# windows up to ~16k values fit under _ZONE with room to spare.
_VISITED = np.int32(1 << 30)
_ZONE = np.int32(1 << 28)
_INF = np.int32(1 << 20)
_MAX_WINDOW = 16000


class ChainResult(NamedTuple):
    perm: jax.Array   # (R, W) int32 - chained order, window-local indices
    cost: jax.Array   # (R,) int32 - sum of consecutive Hamming distances
    nonzeros: jax.Array  # (R,) int32 - chained (non-padding) values per window


def _as_planes(streams) -> Tuple[jax.Array, ...]:
    if isinstance(streams, (jax.Array, np.ndarray)):
        streams = (streams,)
    planes = tuple(unsigned_view(jnp.asarray(s)).astype(jnp.uint32)
                   for s in streams)
    if not planes:
        raise ValueError("need at least one value stream")
    if len({p.shape for p in planes}) != 1:
        raise ValueError("all streams must share a (R, W) shape")
    if planes[0].ndim != 2:
        raise ValueError(f"streams must be (R, W), got {planes[0].shape}")
    return planes


def _dist(a: jax.Array, b: jax.Array) -> jax.Array:
    """Summed XOR-popcount distance; ``a``/``b`` broadcast over a leading
    plane axis (affiliated chains sum the input- and weight-half toggles,
    which is exactly the per-lane-pair wire cost)."""
    return jnp.sum(popcount32(a ^ b), axis=0).astype(jnp.int32)


def _select_beam(key: jax.Array, beam: int) -> jax.Array:
    """Indices of the ``beam`` smallest entries of ``key``, ascending.

    Bit-identical to ``lax.top_k`` on the negated key: every selection key
    embeds the candidate index (``... + idx``), so keys are pairwise
    distinct and both formulations pick the same set in the same order
    (argmin's first-occurrence rule only matters on ties, which cannot
    occur). Iterated argmin+mask beats top_k because XLA:CPU lowers top-k
    to a full O(W log W) sort per scan step - ~95% of the chain's runtime
    at W=400 - while ``beam`` argmin passes are O(beam * W)."""
    masked = key
    cands = []
    for _ in range(beam):
        c = jnp.argmin(masked).astype(jnp.int32)
        cands.append(c)
        masked = masked.at[c].set(jnp.int32(np.iinfo(np.int32).max))
    return jnp.stack(cands)


def _greedy_from(q: jax.Array, z: jax.Array, start: jax.Array,
                 beam: int) -> Tuple[jax.Array, jax.Array]:
    """One greedy beam-lookahead chain over a partitioned (P, W) window."""
    w = q.shape[1]
    idx = jnp.arange(w, dtype=jnp.int32)
    zone = jnp.where(idx >= z, _ZONE, 0)
    k1, k2 = np.int32(130 * w), np.int32(w)

    visited0 = jnp.zeros((w,), jnp.bool_).at[start].set(True)
    order0 = jnp.zeros((w,), jnp.int32).at[0].set(start)

    def step(carry, i):
        visited, cur, cost, order = carry
        vis = jnp.where(visited, _VISITED, 0)
        dvec = _dist(q[:, cur][:, None], q)                      # (W,)
        cand = _select_beam(dvec * k2 + idx + vis + zone, beam)
        d_b = dvec[cand]                                         # (B,)
        d2 = _dist(q[:, cand][:, :, None], q[:, None, :])        # (B, W)
        lamask = (visited | (idx >= z))[None, :] | (idx[None, :] == cand[:, None])
        la = jnp.min(jnp.where(lamask, _INF, d2), axis=1)
        la = jnp.where(la >= _INF, 0, la)
        score = ((d_b + la) * k1 + d_b * k2 + cand
                 + vis[cand] + zone[cand])
        nxt = cand[jnp.argmin(score)]
        carry = (visited.at[nxt].set(True), nxt, cost + dvec[nxt],
                 order.at[i].set(nxt))
        return carry, None

    init = (visited0, start.astype(jnp.int32), jnp.int32(0), order0)
    (_, _, cost, order), _ = jax.lax.scan(step, init,
                                          jnp.arange(1, w, dtype=jnp.int32))
    return order, cost


def _chain_window(u: jax.Array, beam: int, starts: int):
    """Chain one (P, W) window: partition zeros to the tail, run ``starts``
    greedy chains, fall back to the partitioned identity when cheaper."""
    w = u.shape[1]
    idx = jnp.arange(w, dtype=jnp.int32)
    pops = jnp.sum(popcount32(u), axis=0).astype(jnp.int32)      # (W,)
    nz = pops > 0
    z = nz.sum().astype(jnp.int32)
    part = jnp.argsort(jnp.where(nz, 0, 1)).astype(jnp.int32)    # stable
    q = u[:, part]
    cid = (_dist(q[:, :-1], q[:, 1:]).sum().astype(jnp.int32)
           if w > 1 else jnp.int32(0))

    # Start positions: descending-popcount ranks 0, z/S, 2z/S, ... - all of
    # 0..z-1 when z <= starts (the exhaustive small-window regime).
    dperm = jnp.argsort(-pops[part]).astype(jnp.int32)           # stable
    ranks = (jnp.arange(starts, dtype=jnp.int32) * z) // starts
    start_pos = dperm[ranks]                                     # (S,)

    orders, costs = jax.vmap(_greedy_from, in_axes=(None, None, 0, None))(
        q, z, start_pos, beam)
    sbest = jnp.argmin(costs)
    use_greedy = costs[sbest] < cid
    chain = jnp.where(use_greedy, orders[sbest], idx)
    cost = jnp.minimum(costs[sbest], cid)
    return part[chain], cost, z


@functools.partial(jax.jit, static_argnames=("beam", "starts"))
def _chain_stack(u: jax.Array, beam: int, starts: int):
    """One compiled dispatch chaining every window of a (P, R, W) stack: the
    data-dependent scan executes once with the full (R, starts) batch in
    flight, so a layer's ordering cost is one executable launch rather than
    one per window (the fig12 packetizer regime)."""
    return jax.vmap(_chain_window, in_axes=(1, None, None))(u, beam, starts)


def min_hamming_chain(streams, *, beam: int = DEFAULT_BEAM,
                      starts: int = DEFAULT_STARTS) -> ChainResult:
    """Chain each window (row) of one or more (R, W) value streams.

    streams: a single (R, W) array, or a sequence of them sharing a shape
        (the affiliated variant chains (input, weight) pairs on the summed
        distance of both planes). Any unsigned-viewable dtype.
    beam: lookahead beam width (>= 1).
    starts: number of greedy start positions (>= 1); windows with at most
        this many chained values are searched from every start.

    Returns window-local permutations: ``values[r, perm[r]]`` is the chained
    sequence, padding zeros at the tail, cost minimal over the evaluated
    candidates and never above the zeros-to-tail identity order.
    """
    planes = _as_planes(streams)
    w = planes[0].shape[1]
    if beam < 1:
        raise ValueError(f"beam must be >= 1, got {beam}")
    if starts < 1:
        raise ValueError(f"starts must be >= 1, got {starts}")
    if w > _MAX_WINDOW:
        raise ValueError(
            f"window {w} exceeds the int32 score encoding bound "
            f"({_MAX_WINDOW}); chain smaller windows")
    u = jnp.stack(planes)                                        # (P, R, W)
    if w == 0:
        r = planes[0].shape[0]
        zeros = jnp.zeros((r, 0), jnp.int32)
        return ChainResult(zeros, jnp.zeros((r,), jnp.int32),
                           jnp.zeros((r,), jnp.int32))
    beam = min(beam, w)
    perm, cost, z = _chain_stack(u, beam, starts)
    return ChainResult(perm, cost, z)


# -- Pallas variant of the chain step's distance+select body ----------------
#
# One chain-scan step is: XOR the current value against the window, popcount
# the toggles, form the selection key ``dvec * k2 + idx + penalty`` and take
# the ``beam`` smallest keys. On CPU the iterated-argmin form above is
# optimal; on TPU the serial argmin chain under a scan lowers poorly, so
# this kernel fuses the SWAR popcount (popcount.py's body) with the full
# bitonic network from bitonic_sort.py carrying the lane index as payload -
# the caller slices the first ``beam`` columns of the returned order. Keys
# are negated going into the descending network, so the output is ascending
# in the original key, matching ``_select_beam`` / ``lax.top_k`` exactly
# (keys embed the index, hence are distinct and the order is total).

_SELECT_ROW_TILE = 8
# Padding-lane penalty: above any legitimate key (bounded by _VISITED +
# _ZONE + ~2^21, see the score-encoding note at the top) yet far enough
# from int32 max that negation cannot overflow.
_PAD_PENALTY = np.int32((1 << 30) + (1 << 29))


def _make_select_kernel(w: int, n_planes: int, k2: int):
    from repro.kernels.bitonic_sort import _compare_exchange

    stages = w.bit_length() - 1

    def kernel(*refs):
        dvec = jnp.zeros((_SELECT_ROW_TILE, w), jnp.int32)
        for p in range(n_planes):
            x = refs[p][...].astype(jnp.uint32)
            x = x - ((x >> 1) & jnp.uint32(0x55555555))
            x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
            x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
            dvec = dvec + ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
        pen = refs[n_planes][...]
        idx = jax.lax.broadcasted_iota(jnp.int32, dvec.shape, 1)
        keys = -(dvec * jnp.int32(k2) + idx + pen)
        order = idx
        for k in range(stages):
            for j in range(k, -1, -1):
                keys, (order,) = _compare_exchange(keys, (order,), k, j, w)
        refs[n_planes + 1][...] = dvec
        refs[n_planes + 2][...] = order

    return kernel


def chain_select_pallas(xors, penalty, *, k2: int | None = None,
                        interpret: bool = True):
    """Distance + beam-select body of one chain step, as a Pallas kernel.

    xors: a (R, W) uint32 array (or sequence of them for multi-plane
        affiliated chains) holding ``window ^ current`` toggle words.
    penalty: (R, W) int32 - the step's visited/zone penalties.
    k2: distance multiplier in the selection key (defaults to W, the
        chain's ``k2 = w`` encoding).

    Returns ``(dvec, order)``: per-lane summed popcount distances (R, W)
    int32, and lane indices sorted ascending by ``dvec * k2 + idx +
    penalty`` (R, W) int32 - slice ``order[:, :beam]`` for the beam
    candidates, bit-identical to ``_select_beam`` on the same key.
    """
    if isinstance(xors, (jax.Array, np.ndarray)):
        xors = (xors,)
    planes = tuple(jnp.asarray(x).astype(jnp.uint32) for x in xors)
    if len({p.shape for p in planes}) != 1 or planes[0].ndim != 2:
        raise ValueError("xor planes must share a (R, W) shape")
    r, w = planes[0].shape
    if penalty.shape != (r, w):
        raise ValueError(f"penalty must be {(r, w)}, got {penalty.shape}")
    if k2 is None:
        k2 = w
    wp = max(128, 1 << (w - 1).bit_length())
    rp = -(-r // _SELECT_ROW_TILE) * _SELECT_ROW_TILE
    planes = tuple(jnp.pad(p, ((0, rp - r), (0, wp - w))) for p in planes)
    pen = jnp.pad(penalty.astype(jnp.int32), ((0, rp - r), (0, wp - w)),
                  constant_values=_PAD_PENALTY)
    kernel = _make_select_kernel(wp, len(planes), int(k2))
    spec = pl.BlockSpec((_SELECT_ROW_TILE, wp), lambda i: (i, 0))
    dvec, order = pl.pallas_call(
        kernel,
        grid=(rp // _SELECT_ROW_TILE,),
        in_specs=[spec] * (len(planes) + 1),
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((rp, wp), jnp.int32),
                   jax.ShapeDtypeStruct((rp, wp), jnp.int32)],
        interpret=interpret,
    )(*planes, pen)
    # Padding lanes carry _PAD_PENALTY and sort behind every real lane, so
    # the first W order columns are exactly the real candidates.
    return dvec[:r, :w], order[:r, :w]


def chain_cost(streams, perm) -> jax.Array:
    """Sum of consecutive summed-plane Hamming distances of each window of
    ``streams`` reordered by ``perm`` - the objective O3 minimizes."""
    planes = _as_planes(streams)
    perm = jnp.asarray(perm)
    seq = jnp.stack([jnp.take_along_axis(p, perm, axis=1) for p in planes])
    if seq.shape[-1] < 2:
        return jnp.zeros((seq.shape[1],), jnp.int32)
    return jnp.sum(popcount32(seq[..., :-1] ^ seq[..., 1:]),
                   axis=(0, 2)).astype(jnp.int32)


def min_hamming_chain_reference(streams, *, beam: int = DEFAULT_BEAM,
                                starts: int = DEFAULT_STARTS):
    """Per-window numpy mirror of :func:`min_hamming_chain` - the oracle the
    property suite compares the batched kernel against bit for bit."""
    planes = [np.asarray(unsigned_view(jnp.asarray(s)), np.uint32)
              for s in ((streams,) if isinstance(streams, (jax.Array,
                                                           np.ndarray))
                        else streams)]
    r, w = planes[0].shape
    beam_w = min(max(beam, 1), max(w, 1))

    def popc(x):
        return bin(int(x)).count("1")

    def dist(i, j, q):
        return sum(popc(int(p[i]) ^ int(p[j])) for p in q)

    perms = np.zeros((r, w), np.int32)
    costs = np.zeros((r,), np.int32)
    zs = np.zeros((r,), np.int32)
    if w == 0:
        return perms, costs, zs
    for row in range(r):
        q0 = [p[row] for p in planes]
        pops = [sum(popc(int(p[i])) for p in q0) for i in range(w)]
        nzidx = [i for i in range(w) if pops[i] > 0]
        zidx = [i for i in range(w) if pops[i] == 0]
        part = nzidx + zidx
        q = [p[part] for p in q0]
        z = len(nzidx)
        cid = sum(dist(i, i + 1, q) for i in range(w - 1))

        dperm = sorted(range(w), key=lambda i: (-sum(
            popc(int(p[i])) for p in q), i))
        start_pos = [dperm[(s * z) // starts] for s in range(starts)]

        best_cost, best_order = None, None
        for start in start_pos:
            visited = [False] * w
            visited[start] = True
            order = [start]
            cur, cost = start, 0
            for _ in range(w - 1):
                def selkey(j):
                    d = dist(cur, j, q)
                    pen = (int(_VISITED) if visited[j] else 0) + \
                        (int(_ZONE) if j >= z else 0)
                    return d * w + j + pen
                cands = sorted(range(w), key=selkey)[:beam_w]

                def score(c):
                    d = dist(cur, c, q)
                    rest = [j for j in range(w)
                            if not visited[j] and j < z and j != c]
                    la = min((dist(c, j, q) for j in rest), default=0)
                    pen = (int(_VISITED) if visited[c] else 0) + \
                        (int(_ZONE) if c >= z else 0)
                    return (d + la) * (130 * w) + d * w + c + pen
                nxt = min(cands, key=score)
                visited[nxt] = True
                order.append(nxt)
                cost += dist(cur, nxt, q)
                cur = nxt
            if best_cost is None or cost < best_cost:
                best_cost, best_order = cost, order
        if best_cost is None or not (best_cost < cid):
            best_cost, best_order = cid, list(range(w))
        perms[row] = np.asarray(part, np.int32)[best_order] if w else []
        costs[row] = best_cost
        zs[row] = z
    return perms, costs, zs
