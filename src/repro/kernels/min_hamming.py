"""Batched minimum-Hamming-distance chaining - the O3 ordering kernel.

O1/O2 sort by popcount, a *proxy* for the link-power objective: what the
wires actually pay is the Hamming distance between consecutive values on a
lane (operands Hamming distance optimization; see PAPERS.md). O3 optimizes
that objective directly: within each ordering window it chains values so
each value is followed by a near-nearest neighbor in Hamming space.

The chain is greedy nearest-neighbor with two quality refinements, both
pinned against a brute-force optimal-path oracle on exhaustive small
windows (tests/test_ordering_o3.py):

* **multi-start**: a greedy chain is only as good as its first element, and
  the best start is not always the max-popcount value (e.g. the window
  ``{1, 2, 3}`` is optimally chained ``1 -> 3 -> 2``). The kernel runs the
  chain from ``starts`` positions spread evenly over the descending-popcount
  ranks and keeps the cheapest; windows with at most ``starts`` distinct
  positions are covered exhaustively, which is what makes the oracle
  equality on <= 6-value windows hold by construction.
* **beam lookahead**: each step scores the ``beam`` nearest candidates by
  ``d(cur, c) + min_r d(c, r)`` (one step of lookahead) instead of by
  ``d(cur, c)`` alone, breaking ties toward the smaller immediate hop and
  then the smaller index (fully deterministic).

A third guard makes the chain *never worse than not reordering*: the
zeros-to-tail identity order is always evaluated as a candidate and wins
ties, so ``chain cost <= identity cost`` for every window.

Zero values (exact-zero payload, i.e. window padding) are excluded from the
chain and appended at the tail in original order: the result-phase
packetizer slices packets to their real flit count and relies on padding
zeros occupying the tail flits (the same contract O1/O2 satisfy because
popcount 0 sorts last).

Implementation notes: this is a pure-jnp batched kernel (vmappable over
windows, like the transform vmaps in ``repro.noc.traffic``), not a Pallas
body - the chain is a data-dependent ``lax.scan`` whose per-step work is a
masked-argmin over pairwise XOR-popcount distances computed on the fly
(never a (W, W) matrix, so streamed chunks stay memory-bounded). The greedy
selection is encoded in one int32 key per candidate,
``(d + lookahead) * K1 + d * K2 + index`` plus large penalties for visited /
zero-region entries, so lexicographic tie-breaking is a single argmin.
``min_hamming_chain_reference`` is the per-window numpy mirror (same
arithmetic, python loops) used as the equivalence oracle by the property
suite.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bits import popcount32, unsigned_view

__all__ = ["ChainResult", "min_hamming_chain", "min_hamming_chain_reference",
           "chain_cost", "DEFAULT_BEAM", "DEFAULT_STARTS"]

DEFAULT_BEAM = 2
DEFAULT_STARTS = 8

# Penalty encoding (int32): a visited candidate must lose to any zero-region
# one, and a zero-region candidate to any live one. Legit scores are bounded
# by (2*64) * K1 + 64 * K2 + W with K1 = 130*W, K2 = W, i.e. ~16705*W, so
# windows up to ~16k values fit under _ZONE with room to spare.
_VISITED = np.int32(1 << 30)
_ZONE = np.int32(1 << 28)
_INF = np.int32(1 << 20)
_MAX_WINDOW = 16000


class ChainResult(NamedTuple):
    perm: jax.Array   # (R, W) int32 - chained order, window-local indices
    cost: jax.Array   # (R,) int32 - sum of consecutive Hamming distances
    nonzeros: jax.Array  # (R,) int32 - chained (non-padding) values per window


def _as_planes(streams) -> Tuple[jax.Array, ...]:
    if isinstance(streams, (jax.Array, np.ndarray)):
        streams = (streams,)
    planes = tuple(unsigned_view(jnp.asarray(s)).astype(jnp.uint32)
                   for s in streams)
    if not planes:
        raise ValueError("need at least one value stream")
    if len({p.shape for p in planes}) != 1:
        raise ValueError("all streams must share a (R, W) shape")
    if planes[0].ndim != 2:
        raise ValueError(f"streams must be (R, W), got {planes[0].shape}")
    return planes


def _dist(a: jax.Array, b: jax.Array) -> jax.Array:
    """Summed XOR-popcount distance; ``a``/``b`` broadcast over a leading
    plane axis (affiliated chains sum the input- and weight-half toggles,
    which is exactly the per-lane-pair wire cost)."""
    return jnp.sum(popcount32(a ^ b), axis=0).astype(jnp.int32)


def _greedy_from(q: jax.Array, z: jax.Array, start: jax.Array,
                 beam: int) -> Tuple[jax.Array, jax.Array]:
    """One greedy beam-lookahead chain over a partitioned (P, W) window."""
    w = q.shape[1]
    idx = jnp.arange(w, dtype=jnp.int32)
    zone = jnp.where(idx >= z, _ZONE, 0)
    k1, k2 = np.int32(130 * w), np.int32(w)

    visited0 = jnp.zeros((w,), jnp.bool_).at[start].set(True)
    order0 = jnp.zeros((w,), jnp.int32).at[0].set(start)

    def step(carry, i):
        visited, cur, cost, order = carry
        vis = jnp.where(visited, _VISITED, 0)
        dvec = _dist(q[:, cur][:, None], q)                      # (W,)
        _, cand = jax.lax.top_k(-(dvec * k2 + idx + vis + zone), beam)
        d_b = dvec[cand]                                         # (B,)
        d2 = _dist(q[:, cand][:, :, None], q[:, None, :])        # (B, W)
        lamask = (visited | (idx >= z))[None, :] | (idx[None, :] == cand[:, None])
        la = jnp.min(jnp.where(lamask, _INF, d2), axis=1)
        la = jnp.where(la >= _INF, 0, la)
        score = ((d_b + la) * k1 + d_b * k2 + cand
                 + vis[cand] + zone[cand])
        nxt = cand[jnp.argmin(score)]
        carry = (visited.at[nxt].set(True), nxt, cost + dvec[nxt],
                 order.at[i].set(nxt))
        return carry, None

    init = (visited0, start.astype(jnp.int32), jnp.int32(0), order0)
    (_, _, cost, order), _ = jax.lax.scan(step, init,
                                          jnp.arange(1, w, dtype=jnp.int32))
    return order, cost


def _chain_window(u: jax.Array, beam: int, starts: int):
    """Chain one (P, W) window: partition zeros to the tail, run ``starts``
    greedy chains, fall back to the partitioned identity when cheaper."""
    w = u.shape[1]
    idx = jnp.arange(w, dtype=jnp.int32)
    pops = jnp.sum(popcount32(u), axis=0).astype(jnp.int32)      # (W,)
    nz = pops > 0
    z = nz.sum().astype(jnp.int32)
    part = jnp.argsort(jnp.where(nz, 0, 1)).astype(jnp.int32)    # stable
    q = u[:, part]
    cid = (_dist(q[:, :-1], q[:, 1:]).sum().astype(jnp.int32)
           if w > 1 else jnp.int32(0))

    # Start positions: descending-popcount ranks 0, z/S, 2z/S, ... - all of
    # 0..z-1 when z <= starts (the exhaustive small-window regime).
    dperm = jnp.argsort(-pops[part]).astype(jnp.int32)           # stable
    ranks = (jnp.arange(starts, dtype=jnp.int32) * z) // starts
    start_pos = dperm[ranks]                                     # (S,)

    orders, costs = jax.vmap(_greedy_from, in_axes=(None, None, 0, None))(
        q, z, start_pos, beam)
    sbest = jnp.argmin(costs)
    use_greedy = costs[sbest] < cid
    chain = jnp.where(use_greedy, orders[sbest], idx)
    cost = jnp.minimum(costs[sbest], cid)
    return part[chain], cost, z


def min_hamming_chain(streams, *, beam: int = DEFAULT_BEAM,
                      starts: int = DEFAULT_STARTS) -> ChainResult:
    """Chain each window (row) of one or more (R, W) value streams.

    streams: a single (R, W) array, or a sequence of them sharing a shape
        (the affiliated variant chains (input, weight) pairs on the summed
        distance of both planes). Any unsigned-viewable dtype.
    beam: lookahead beam width (>= 1).
    starts: number of greedy start positions (>= 1); windows with at most
        this many chained values are searched from every start.

    Returns window-local permutations: ``values[r, perm[r]]`` is the chained
    sequence, padding zeros at the tail, cost minimal over the evaluated
    candidates and never above the zeros-to-tail identity order.
    """
    planes = _as_planes(streams)
    w = planes[0].shape[1]
    if beam < 1:
        raise ValueError(f"beam must be >= 1, got {beam}")
    if starts < 1:
        raise ValueError(f"starts must be >= 1, got {starts}")
    if w > _MAX_WINDOW:
        raise ValueError(
            f"window {w} exceeds the int32 score encoding bound "
            f"({_MAX_WINDOW}); chain smaller windows")
    u = jnp.stack(planes)                                        # (P, R, W)
    if w == 0:
        r = planes[0].shape[0]
        zeros = jnp.zeros((r, 0), jnp.int32)
        return ChainResult(zeros, jnp.zeros((r,), jnp.int32),
                           jnp.zeros((r,), jnp.int32))
    beam = min(beam, w)
    perm, cost, z = jax.vmap(_chain_window, in_axes=(1, None, None))(
        u, beam, starts)
    return ChainResult(perm, cost, z)


def chain_cost(streams, perm) -> jax.Array:
    """Sum of consecutive summed-plane Hamming distances of each window of
    ``streams`` reordered by ``perm`` - the objective O3 minimizes."""
    planes = _as_planes(streams)
    perm = jnp.asarray(perm)
    seq = jnp.stack([jnp.take_along_axis(p, perm, axis=1) for p in planes])
    if seq.shape[-1] < 2:
        return jnp.zeros((seq.shape[1],), jnp.int32)
    return jnp.sum(popcount32(seq[..., :-1] ^ seq[..., 1:]),
                   axis=(0, 2)).astype(jnp.int32)


def min_hamming_chain_reference(streams, *, beam: int = DEFAULT_BEAM,
                                starts: int = DEFAULT_STARTS):
    """Per-window numpy mirror of :func:`min_hamming_chain` - the oracle the
    property suite compares the batched kernel against bit for bit."""
    planes = [np.asarray(unsigned_view(jnp.asarray(s)), np.uint32)
              for s in ((streams,) if isinstance(streams, (jax.Array,
                                                           np.ndarray))
                        else streams)]
    r, w = planes[0].shape
    beam_w = min(max(beam, 1), max(w, 1))

    def popc(x):
        return bin(int(x)).count("1")

    def dist(i, j, q):
        return sum(popc(int(p[i]) ^ int(p[j])) for p in q)

    perms = np.zeros((r, w), np.int32)
    costs = np.zeros((r,), np.int32)
    zs = np.zeros((r,), np.int32)
    if w == 0:
        return perms, costs, zs
    for row in range(r):
        q0 = [p[row] for p in planes]
        pops = [sum(popc(int(p[i])) for p in q0) for i in range(w)]
        nzidx = [i for i in range(w) if pops[i] > 0]
        zidx = [i for i in range(w) if pops[i] == 0]
        part = nzidx + zidx
        q = [p[part] for p in q0]
        z = len(nzidx)
        cid = sum(dist(i, i + 1, q) for i in range(w - 1))

        dperm = sorted(range(w), key=lambda i: (-sum(
            popc(int(p[i])) for p in q), i))
        start_pos = [dperm[(s * z) // starts] for s in range(starts)]

        best_cost, best_order = None, None
        for start in start_pos:
            visited = [False] * w
            visited[start] = True
            order = [start]
            cur, cost = start, 0
            for _ in range(w - 1):
                def selkey(j):
                    d = dist(cur, j, q)
                    pen = (int(_VISITED) if visited[j] else 0) + \
                        (int(_ZONE) if j >= z else 0)
                    return d * w + j + pen
                cands = sorted(range(w), key=selkey)[:beam_w]

                def score(c):
                    d = dist(cur, c, q)
                    rest = [j for j in range(w)
                            if not visited[j] and j < z and j != c]
                    la = min((dist(c, j, q) for j in rest), default=0)
                    pen = (int(_VISITED) if visited[c] else 0) + \
                        (int(_ZONE) if c >= z else 0)
                    return (d + la) * (130 * w) + d * w + c + pen
                nxt = min(cands, key=score)
                visited[nxt] = True
                order.append(nxt)
                cost += dist(cur, nxt, q)
                cur = nxt
            if best_cost is None or cost < best_cost:
                best_cost, best_order = cost, order
        if best_cost is None or not (best_cost < cid):
            best_cost, best_order = cid, list(range(w))
        perms[row] = np.asarray(part, np.int32)[best_order] if w else []
        costs[row] = best_cost
        zs[row] = z
    return perms, costs, zs
