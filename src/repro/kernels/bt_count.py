"""Pallas TPU kernel: bit-transition counter between consecutive flits.

The hardware analogue is the paper's BT recorder (Fig. 8): hold the previous
flit, XOR it with the current one, popcount the toggles, accumulate. Here the
stream is materialized as a (F, L) word array, and the kernel receives two
row-aligned views - rows [0, F-1) and rows [1, F) - so that tile i of the
two inputs covers the flit pairs of tile i without overlapping block reads.

Per boundary the kernel reduces over the L lanes, emitting one int32 per
flit boundary. Arithmetic intensity is ~1 op/byte so the kernel is memory
bound; the (8, L) tiles stream through VMEM at full HBM bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bt_boundaries_pallas", "ROW_TILE"]

ROW_TILE = 8


def _bt_kernel(prev_ref, cur_ref, o_ref):
    x = prev_ref[...].astype(jnp.uint32) ^ cur_ref[...].astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    counts = ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
    o_ref[...] = jnp.sum(counts, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bt_boundaries_pallas(prev_rows: jax.Array, cur_rows: jax.Array,
                         *, interpret: bool = True) -> jax.Array:
    """Transitions per flit boundary.

    prev_rows = words[:-1], cur_rows = words[1:], both (P, L) uint32 with L a
    multiple of 128 and P a multiple of ROW_TILE (ops.py pads). Returns
    int32 (P, 1).
    """
    p, l = prev_rows.shape
    if l % 128 or p % ROW_TILE:
        raise ValueError(f"bt kernel needs (8k, 128k) shape, got {prev_rows.shape}")
    grid = (p // ROW_TILE,)
    return pl.pallas_call(
        _bt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, l), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, 1), jnp.int32),
        interpret=interpret,
    )(prev_rows, cur_rows)
