"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the semantic ground truth the kernels are tested
against (tests/test_kernels.py sweeps shapes and dtypes and asserts
exact equality - these are integer/bit ops, so no tolerance is needed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bits as _bits

__all__ = ["popcount_ref", "bt_boundaries_ref", "sort_windows_desc_ref",
           "order_unit_ref"]


def popcount_ref(x: jax.Array) -> jax.Array:
    """'1'-bit count per element (int32), any dtype with an unsigned view."""
    return _bits.popcount(x)


def bt_boundaries_ref(words: jax.Array) -> jax.Array:
    """Bit transitions at each flit boundary of a (F, L) word stream.

    Returns int32 (F-1,): entry i is the number of wires that toggle when
    flit i+1 follows flit i (the paper's Fig. 8 recorder).
    """
    tog = _bits.transitions(words[:-1], words[1:])
    return jnp.sum(tog, axis=-1).astype(jnp.int32)


def sort_windows_desc_ref(keys: jax.Array, *payloads: jax.Array):
    """Descending stable key sort within each row of (R, W) arrays.

    Returns (sorted_keys, *sorted_payloads). This is the ordering unit's
    semantics: each window (packet) independently sorted by '1'-bit count,
    descending, stable among ties.
    """
    order = jnp.argsort(-keys, axis=-1)  # stable
    sk = jnp.take_along_axis(keys, order, axis=-1)
    sp = tuple(jnp.take_along_axis(p, order, axis=-1) for p in payloads)
    return (sk, *sp)


def order_unit_ref(values: jax.Array):
    """Oracle for the fused ordering unit: descending stable popcount sort
    per row, returning (ordered values, permutation)."""
    keys = _bits.popcount(values)
    order = jnp.argsort(-keys, axis=-1)
    return jnp.take_along_axis(values, order, axis=-1), order
