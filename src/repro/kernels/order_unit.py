"""Pallas TPU kernel: the complete ordering unit in one fused pass.

Paper Fig. 14 shows the RTL pipeline: pop-count stage -> sort stage. The
separate kernels in this package mirror those stages; this kernel fuses
them: values stream HBM->VMEM once, SWAR popcount keys are computed in
registers, the bitonic network runs in VMEM, and only the ordered values
(plus the window-local permutation for separated-ordering recovery) return
to HBM. Saves one full HBM round-trip of the key tensor versus the
two-kernel pipeline - the dominant cost, since the ordering unit is purely
memory-bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitonic_sort import _compare_exchange, ROW_TILE

__all__ = ["order_unit_pallas"]


def _popcount32_vmem(x):
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _make_kernel(w: int):
    stages = w.bit_length() - 1

    def kernel(v_ref, out_ref, perm_ref):
        vals = v_ref[...]
        keys = _popcount32_vmem(vals)                  # fused pop-count stage
        idx = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
        payloads = (vals, idx)
        for k in range(stages):
            for j in range(k, -1, -1):
                keys, payloads = _compare_exchange(keys, payloads, k, j, w)
        out_ref[...] = payloads[0]
        perm_ref[...] = payloads[1]

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def order_unit_pallas(values: jax.Array, *, interpret: bool = True):
    """(R, W) uint32 windows -> (ordered values, window-local permutation).

    W a power of two >= 128, R a multiple of ROW_TILE. The permutation is
    what separated-ordering transmits as its recovery index.
    """
    r, w = values.shape
    if w & (w - 1) or w < 128:
        raise ValueError(f"window must be a power of two >= 128, got {w}")
    if r % ROW_TILE:
        raise ValueError(f"rows must be a multiple of {ROW_TILE}, got {r}")
    spec = pl.BlockSpec((ROW_TILE, w), lambda i: (i, 0))
    out, perm = pl.pallas_call(
        _make_kernel(w),
        grid=(r // ROW_TILE,),
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r, w), values.dtype),
                   jax.ShapeDtypeStruct((r, w), jnp.int32)],
        interpret=interpret,
    )(values)
    return out, perm
