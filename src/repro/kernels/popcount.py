"""Pallas TPU kernel: SWAR popcount over a 2D word array.

This is the TPU-native version of the paper's ordering-unit front half
(Fig. 14: "Pop-count" stage). The VPU has no popcount instruction, so the
kernel runs the SWAR reduction on 32-bit lanes - 4 shifts, 4 ands, 2 adds,
1 multiply per word, all elementwise, so the kernel is trivially memory
bound and tiles cleanly into VMEM.

Layout: input is (M, N) uint32 with N a multiple of 128 (lane width); the
grid walks row tiles of 8 sublanes, the natural (8, 128) TPU vreg tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["popcount_words_pallas", "ROW_TILE"]

ROW_TILE = 8  # sublanes per vreg


def _popcount_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    o_ref[...] = ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def popcount_words_pallas(words: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Popcount of each element of a (M, N) uint32 array -> int32 (M, N).

    N must be a multiple of 128 and M a multiple of ROW_TILE; the ops.py
    wrapper pads arbitrary shapes to this contract.
    """
    m, n = words.shape
    if n % 128 or m % ROW_TILE:
        raise ValueError(f"popcount kernel needs (8k, 128k) shape, got {words.shape}")
    grid = (m // ROW_TILE,)
    return pl.pallas_call(
        _popcount_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(words)
