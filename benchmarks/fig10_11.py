"""Paper Figs. 10-11: per-bit-position '1' probability and transition
probability, float-32 and fixed-8, random vs trained LeNet weights,
baseline vs descending-ordered.

The figure itself is a bar chart; the benchmark emits the underlying
arrays (written to experiments/fig10_11.json) plus the summary statistics
the paper narrates: the sign/exponent/mantissa structure for float-32 and
the ordered-vs-baseline transition-probability gap.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import (pack, bt_per_position, ones_prob_per_position,
                        descending_order)
from repro.quant import quantize_fixed8

from ._trained import get_trained, random_params

LANES = 8
OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _analyze(vals):
    base = pack(vals, LANES)
    ordered = pack(descending_order(vals).values, LANES)
    return {
        "ones_prob": ones_prob_per_position(base).tolist(),
        "bt_prob_baseline": bt_per_position(base).tolist(),
        "bt_prob_ordered": bt_per_position(ordered).tolist(),
    }


def run():
    model, trained, _ = get_trained("lenet")
    _, rand = random_params("lenet")
    out = {}
    for tag, params in (("random", rand), ("trained", trained)):
        stream = model.weight_stream(params)
        out[f"float32-{tag}"] = _analyze(stream)
        out[f"fixed8-{tag}"] = _analyze(quantize_fixed8(stream).values)
    return out


def summarize(out):
    rows = []
    for case, d in out.items():
        ones = jnp.asarray(d["ones_prob"])
        base = jnp.asarray(d["bt_prob_baseline"])
        ord_ = jnp.asarray(d["bt_prob_ordered"])
        if "float32" in case:
            regions = {"sign": slice(0, 1), "exponent": slice(1, 9),
                       "mantissa": slice(9, 32)}
        else:
            regions = {"all8": slice(0, 8)}
        for rname, sl in regions.items():
            rows.append({
                "case": case, "region": rname,
                "ones_prob": float(ones[sl].mean()),
                "bt_prob_baseline": float(base[sl].mean()),
                "bt_prob_ordered": float(ord_[sl].mean()),
            })
    return rows


def main(print_csv=True):
    t0 = time.perf_counter()
    out = run()
    us = (time.perf_counter() - t0) * 1e6
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fig10_11.json"), "w") as f:
        json.dump(out, f)
    rows = summarize(out)
    if print_csv:
        for r in rows:
            print(f"fig10_11/{r['case']}/{r['region']},{us / len(rows):.1f},"
                  f"p1={r['ones_prob']:.3f}"
                  f" ptrans_base={r['bt_prob_baseline']:.3f}"
                  f" ptrans_ord={r['bt_prob_ordered']:.3f}")
    return rows


if __name__ == "__main__":
    main()
