"""Paper Fig. 13: normalized BTs for different DNN models (LeNet vs the
DarkNet-like model, 64x64x3 input) on the default 4x4/MC2 NoC, O0/O1/O2.
Paper: up to 35.93% (LeNet) and 40.85% (DarkNet) reduction."""
from __future__ import annotations

import json
import os
import time

import jax

from repro.core.wire import by_name
from repro.noc import PAPER_NOCS, simulate, build_traffic
from repro.quant import quantize_fixed8
from repro.data import glyph_batch

from ._trained import get_trained

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run(max_packets=40, tiebreak="pattern"):
    cfg = PAPER_NOCS["4x4_mc2"]
    results = {}
    for net in ("lenet", "darknet"):
        model, params, _ = get_trained(net)
        hw = model.input_shape[0]
        ch = model.input_shape[-1]
        x, _ = glyph_batch(jax.random.PRNGKey(11), 1, hw=hw, channels=ch)
        layers = model.layer_traffic(params, x[0])
        for fmt in ("float32", "fixed8"):
            q = None if fmt == "float32" else (lambda t: quantize_fixed8(t).values)
            base = None
            for o in ("O0", "O1", "O2"):
                tr = build_traffic(layers, cfg, by_name(o, tiebreak=tiebreak),
                                   quantizer=q, max_packets_per_layer=max_packets)
                t0 = time.perf_counter()
                res = simulate(cfg, tr, chunk=2048)
                dt = time.perf_counter() - t0
                base = res.total_bt if o == "O0" else base
                results[f"{net}/{fmt}/{o}"] = {
                    "total_bt": res.total_bt,
                    "normalized": res.total_bt / base,
                    "reduction_pct": (1 - res.total_bt / base) * 100,
                    "sim_s": round(dt, 2),
                }
    return results


def main(print_csv=True):
    results = run()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fig13.json"), "w") as f:
        json.dump(results, f, indent=1)
    if print_csv:
        for key, r in results.items():
            print(f"fig13/{key},{r['sim_s'] * 1e6:.0f},"
                  f"normalized={r['normalized']:.3f}"
                  f" reduction={r['reduction_pct']:.2f}%")
    return results


if __name__ == "__main__":
    main()
