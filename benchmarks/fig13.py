"""Paper Fig. 13: normalized BTs for different DNN models (LeNet vs the
DarkNet-like model, 64x64x3 input) on the default 4x4/MC2 NoC, O0/O1/O2.
Paper: up to 35.93% (LeNet) and 40.85% (DarkNet) reduction.

Driven by the sweep engine: both models ride one SweepGrid (one
packetization + one batched simulation per model), and the reported
reductions include the honest O2 recovery-index charge next to the raw
link number. ``REPRO_BENCH_SMOKE=1`` shrinks to LeNet on a 2x2/MC1 mesh
with random-init weights.
"""
from __future__ import annotations

import json
import os

import jax

from repro.noc import SweepGrid, run_sweep
from repro.data import glyph_batch

from ._trained import get_trained, random_params

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _layers(name: str):
    if SMOKE:
        model, params = random_params(name)
    else:
        model, params, _ = get_trained(name)
    hw = model.input_shape[0]
    ch = model.input_shape[-1]
    x, _ = glyph_batch(jax.random.PRNGKey(11), 1, hw=hw, channels=ch)
    return model.layer_traffic(params, x[0])


def run(max_packets=40, tiebreak="pattern", affinity=("roundrobin",),
        result_phase=False, transforms=("O0", "O1", "O2")):
    """The Fig. 13 sweep; ``affinity``/``result_phase`` surface the PR-5
    axes, ``transforms`` the beyond-paper O3 lane (defaults keep the paper
    grid and the seed-stable key format)."""
    grid = SweepGrid(
        meshes=("2x2_mc1",) if SMOKE else ("4x4_mc2",),
        affinity=affinity,
        transforms=transforms, tiebreaks=(tiebreak,),
        precisions=("float32", "fixed8"),
        models=("lenet",) if SMOKE else ("lenet", "darknet"),
        max_packets_per_layer=min(max_packets, 4) if SMOKE else max_packets,
        result_phase=result_phase, chunk=2048)
    report = run_sweep(grid, _layers)
    results = {}
    for r in report.rows:
        base = report.row(model=r["model"], precision=r["precision"],
                          tiebreak=r["tiebreak"], affinity=r["affinity"],
                          transform=grid.baseline)["total_bt"]
        key = f"{r['model']}/{r['precision']}/{r['transform']}"
        if len(affinity) > 1:
            key += f"/{r['affinity']}"
        results[key] = {
            "total_bt": r["total_bt"],
            "normalized": r["total_bt"] / base,
            "reduction_pct": r["reduction_pct"],
            "adjusted_reduction_pct": r["adjusted_reduction_pct"],
        }
        if result_phase:
            results[key]["result_bt"] = r["result_bt"]
            results[key]["result_cycles"] = r["result_cycles"]
    return results, report.stats


def main(print_csv=True, transforms=("O0", "O1", "O2")):
    results, stats = run(transforms=transforms)
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fig13.json"), "w") as f:
        json.dump(results, f, indent=1)
    if print_csv:
        per_cell_us = stats["wall_s"] / max(stats["cells"], 1) * 1e6
        for key, r in results.items():
            print(f"fig13/{key},{per_cell_us:.0f},"
                  f"normalized={r['normalized']:.3f}"
                  f" reduction={r['reduction_pct']:.2f}%"
                  f" adj={r['adjusted_reduction_pct']:.2f}%")
    return {"results": results, "bench": stats}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description="Fig. 13 model sweep")
    ap.add_argument("--transforms", default="O0,O1,O2",
                    help="comma-separated WireTransform names "
                         "(e.g. O0,O1,O2,O3)")
    ns = ap.parse_args()
    main(transforms=tuple(ns.transforms.split(",")))
