"""Paper Tab. I: BT reduction without NoC.

Protocol (Sec. V-A): flits of 8 weights; LeNet weights, random-init and
trained; float-32 (256-bit flits) and fixed-8 (64-bit flits); kernels
zero-padded to flit boundaries; stream ordered descending by '1'-bit count.
The paper's packet granularity is not fully specified, so the bench also
reports a window sensitivity row (EXPERIMENTS.md discusses the bands).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import pack, bt_per_flit, descending_order, reduction_rate
from repro.core.ordering import pad_to_window
from repro.quant import quantize_fixed8

from ._trained import get_trained, random_params

LANES = 8
PAPER = {  # (baseline BT/flit, ordered, reduction %) from Tab. I
    "float32-random": (113.27, 90.18, 20.38),
    "fixed8-random": (31.01, 22.42, 27.70),
    "float32-trained": (112.80, 91.46, 18.92),
    "fixed8-trained": (30.55, 13.73, 55.71),
}


def _measure(stream, window, tiebreak):
    base = pack(pad_to_window(stream, window), LANES)
    t0 = time.perf_counter()
    ordered = descending_order(stream, window=window, tiebreak=tiebreak)
    jax.block_until_ready(ordered.values)
    us = (time.perf_counter() - t0) * 1e6
    opt = pack(ordered.values, LANES)
    b, o = float(bt_per_flit(base)), float(bt_per_flit(opt))
    return b, o, float(reduction_rate(jnp.asarray(b), jnp.asarray(o))) * 100, us


def run(window=None):
    model, trained, acc = get_trained("lenet")
    _, rand = random_params("lenet")
    rows = []
    for tag, params in (("random", rand), ("trained", trained)):
        stream = model.weight_stream(params)
        for fmt in ("float32", "fixed8"):
            vals = stream if fmt == "float32" else quantize_fixed8(stream).values
            key = f"{fmt}-{tag}"
            pb, po, pr = PAPER[key]
            for tiebreak in ("stable", "pattern"):
                b, o, red, us = _measure(vals, window, tiebreak)
                rows.append({
                    "case": key, "tiebreak": tiebreak,
                    "baseline_bt_per_flit": b, "ordered_bt_per_flit": o,
                    "reduction_pct": red, "paper_reduction_pct": pr, "us": us,
                })
    return {"rows": rows, "lenet_glyph_acc": acc}


def main(print_csv=True):
    out = run()
    lines = []
    for r in out["rows"]:
        lines.append(f"table1/{r['case']}/{r['tiebreak']},{r['us']:.1f},"
                     f"reduction={r['reduction_pct']:.2f}%"
                     f"(paper {r['paper_reduction_pct']}%)"
                     f" base={r['baseline_bt_per_flit']:.2f}"
                     f" ord={r['ordered_bt_per_flit']:.2f}")
    if print_csv:
        for ln in lines:
            print(ln)
    return out


if __name__ == "__main__":
    main()
