"""Beyond-paper: transmission ordering for gradient all-reduce payloads.

Trains the reduced xlstm config briefly so the gradients are *real* (not
synthetic noise), then measures bit transitions of the gradient wire as a
16-lane bf16 phit of (gradient, weight) pairs - the training-time analogue
of the paper's (input, weight) MAC stream, since the update consuming each
pair is order-invariant. Baseline (O0) streams natural order; O1 orders
pairs by the weight's popcount (zero communication overhead: weights are
replicated across DP peers, so every peer derives the same permutation
locally and no index travels); O2 sorts each half by its own popcount
(upper bound, needs a per-window index to re-pair).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.data import TokenStream
from repro.dist.ordered_collectives import gradient_wire_report
from repro.models.spec import init_params
from repro.optim import AdamW, cosine
from repro.train import make_train_step, init_state


def run(steps=12):
    arch = get("xlstm-125m")
    model = arch.build_reduced()
    cfg = model.cfg
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    stream = TokenStream(vocab=cfg.vocab, seq_len=64, global_batch=8)
    opt = AdamW(cosine(3e-3, steps, warmup=2))

    def loss_fn(p, b):
        toks, tgt, mask = b
        return model.loss(p, toks, tgt, mask)

    step = jax.jit(make_train_step(loss_fn, opt))
    st = init_state(params, opt)
    for i in range(steps):
        st, _ = step(st, stream.batch(i))

    # real gradients at the trained point
    grads = jax.grad(loss_fn)(st.params, stream.batch(steps))
    t0 = time.perf_counter()
    rep = jax.jit(lambda g, p: gradient_wire_report(g, p, window=4096,
                                                    lanes=16))(grads, st.params)
    rep = {k: float(v) for k, v in rep.items()}
    us = (time.perf_counter() - t0) * 1e6
    return rep, us


def main(print_csv=True):
    rep, us = run()
    if print_csv:
        print(f"ordered_collectives/gradient_allreduce,{us:.0f},"
              f"O1_weightkeyed={rep['reduction_o1']*100:.2f}%"
              f" O2_selfkeyed={rep['reduction_o2']*100:.2f}%"
              f" baseline_bt={rep['bt_baseline']:.3g}")
    if rep["reduction_o1"] <= 0:
        raise SystemExit(
            f"O1 must reduce BT on real gradients, got {rep['reduction_o1']:.4f}")
    return rep


if __name__ == "__main__":
    main()
