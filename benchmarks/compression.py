"""Ordering x compression co-design: does transmission ordering still pay
once the flit payloads are MSR-compressed?

MSR compression (``repro.core.msr``, the sweep's fifth knob) shrinks every
8-bit payload lane to a dense 5-bit code stream - fewer flits per packet,
fewer link toggles per flit, plus an analytically-charged escape sideband.
That directly attacks the same quantity the O1-O3 orderings attack (bit
transitions on the payload lanes), so the honest question for the paper's
contribution is whether ordering's *adjusted* win survives on compressed
traffic. This suite sweeps O0/O1/O2/O3 x {none, msr} on a mid-size LeNet
mesh (6x6/MC4) and the full, unsubsampled DarkNet traffic on 16x16/MC16
(streamed packetization), and records per-cell BT, drain cycles, flits,
and escape overhead, the msr/none ratios per transform, and two verdicts:

* ``ordering_pays_after_compression`` - the *best* non-baseline ordering
  still beats O0 on overhead-adjusted BT when both ride MSR-compressed
  lanes, on every workload (the co-design claim; suite fails if it flips).
  The per-transform gains are recorded too, because the split is the
  finding: O1's zero-overhead descending order keeps paying on 5-bit
  lanes, while the O3 min-Hamming chain - whose objective is wired to the
  8-bit flit layout - loses its edge once the dense 5-bit re-packing
  scrambles which code bits land adjacent on the wire, and its recovery
  index then drags the adjusted number below the O0 floor;
* ``compression_none_identical`` - the ``compression="none"`` rows agree
  field-by-field with a control grid that never names the axis (the
  default-off pin; suite fails if it drifts).

``REPRO_BENCH_SMOKE=1`` shrinks to random-init LeNet on 4x4/MC2 with a
4-packet budget - the CI gate for the codec-through-sweep path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax

from repro.data import glyph_batch
from repro.noc import SweepGrid, run_sweep

from ._trained import get_trained, random_params

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

TRANSFORMS = ("O0", "O1", "O2", "O3")


def _layers(name: str):
    if SMOKE:
        model, params = random_params(name)
    else:
        model, params, _ = get_trained(name)
    hw, ch = model.input_shape[0], model.input_shape[-1]
    x, _ = glyph_batch(jax.random.PRNGKey(11), 1, hw=hw, channels=ch)
    return model.layer_traffic(params, x[0])


def _workloads():
    """(label, model, grid) per workload; the control grid is the same
    sweep with the compression axis left at its default."""
    if SMOKE:
        return [("lenet_4x4", SweepGrid(
            meshes=("4x4_mc2",), transforms=TRANSFORMS,
            tiebreaks=("pattern",), precisions=("fixed8",),
            models=("lenet",), compression=("none", "msr"),
            max_packets_per_layer=4, chunk=128))]
    return [
        ("lenet_6x6", SweepGrid(
            meshes=("6x6_mc4",), transforms=TRANSFORMS,
            tiebreaks=("pattern",), precisions=("fixed8",),
            models=("lenet",), compression=("none", "msr"),
            max_packets_per_layer=40, chunk=2048)),
        ("darknet_full_16x16", SweepGrid(
            meshes=("16x16_mc16",), transforms=TRANSFORMS,
            tiebreaks=("pattern",), precisions=("fixed8",),
            models=("darknet",), compression=("none", "msr"),
            max_packets_per_layer=None,      # full traffic -> streamed
            stream_chunk_packets=4096, chunk=4096)),
    ]


def _check_none_identical(report, grid, layers_fn) -> bool:
    """Control arm: rerun the same grid without naming the compression
    axis; the axis-on none rows must agree field-by-field (minus the three
    axis columns, which the control predates)."""
    control = run_sweep(dataclasses.replace(grid, compression=("none",)),
                        layers_fn)
    none_rows = [r for r in report.rows if r["compression"] == "none"]
    assert len(none_rows) == len(control.rows), \
        f"control row count {len(control.rows)} != {len(none_rows)}"
    for got, want in zip(none_rows, control.rows):
        for key in want:
            assert got[key] == want[key], \
                f"compression=none drifted from the axis-default path at " \
                f"{want['transform']}/{key}: {got[key]} != {want[key]}"
    return True


def run() -> dict:
    results = {}
    workloads = {}
    wall = 0.0
    for label, grid in _workloads():
        layers = _layers(grid.models[0])
        layers_fn = lambda _n: layers        # noqa: E731 - one shared load

        t0 = time.perf_counter()
        report = run_sweep(grid, layers_fn)
        wl_wall = time.perf_counter() - t0
        wall += wl_wall

        mesh = grid.meshes[0]
        for r in report.rows:
            results[f"{label}/{mesh}/{r['transform']}/{r['compression']}"] = {
                "total_bt": r["total_bt"],
                "adjusted_bt": r["adjusted_bt"],
                "overhead_bits": r["overhead_bits"],
                "compression_overhead_bits": r["compression_overhead_bits"],
                "cycles": r["cycles"], "flits": r["flits"],
                "bt_per_flit": round(r["bt_per_flit"], 3),
            }

        # The co-design join: per transform, what did compression do to
        # BT, drain cycles, and flit volume?
        by_transform = {}
        for tr in grid.transforms:
            none = report.row(transform=tr, compression="none")
            msr = report.row(transform=tr, compression="msr")
            assert msr["flits"] <= none["flits"], \
                f"{label}/{tr}: MSR increased flit volume"
            by_transform[tr] = {
                "bt_ratio": round(msr["total_bt"] / none["total_bt"], 4),
                "adjusted_bt_ratio": round(
                    msr["adjusted_bt"] / none["adjusted_bt"], 4),
                "flit_ratio": round(msr["flits"] / none["flits"], 4),
                "cycle_delta_pct": round(
                    (1 - msr["cycles"] / none["cycles"]) * 100, 2),
                "escape_overhead_share_pct": round(
                    (msr["compression_overhead_bits"] // 2)
                    / msr["adjusted_bt"] * 100, 2),
            }

        # Ordering's honest win, with and without compression underneath:
        # per transform the overhead-adjusted gain over O0, and the best
        # ordering per compression mode (which ordering to co-design with
        # is exactly what moves between the two columns).
        gains = {}
        best = {}
        for comp in grid.compression:
            base = report.row(transform=grid.baseline, compression=comp)
            gains[comp] = {
                tr: round((1 - report.row(transform=tr, compression=comp)
                           ["adjusted_bt"] / base["adjusted_bt"]) * 100, 3)
                for tr in grid.transforms if tr != grid.baseline}
            best[comp] = max(gains[comp], key=gains[comp].get)
        pays = gains["msr"][best["msr"]] > 0
        assert pays, \
            f"{label}: no ordering's adjusted win survives MSR " \
            f"({gains['msr']}) - the co-design claim failed"

        none_identical = _check_none_identical(report, grid, layers_fn)

        workloads[label] = {
            "mesh": mesh, "model": grid.models[0],
            "streamed": report.stats["streamed"],
            "packets": int(sum(
                int(l.inputs.shape[0]) for l in layers)
                if grid.max_packets_per_layer is None else sum(
                    min(int(l.inputs.shape[0]), grid.max_packets_per_layer)
                    for l in layers)),
            "wall_s": round(wl_wall, 3),
            "by_transform": by_transform,
            "adjusted_gain_pct": gains,
            "best_ordering": best,
            "ordering_pays_after_compression": pays,
            "compression_none_identical": none_identical,
        }

    overall_pays = all(w["ordering_pays_after_compression"]
                       for w in workloads.values())
    overall_identical = all(w["compression_none_identical"]
                            for w in workloads.values())
    assert overall_pays and overall_identical
    bench = {
        "transforms": list(TRANSFORMS),
        "compression": ["none", "msr"],
        "wall_s": round(wall, 3),
        "workloads": workloads,
        "ordering_pays_after_compression": overall_pays,
        "compression_none_identical": overall_identical,
    }
    return {"results": results, "bench": bench}


def main(print_csv=True):
    out = run()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "compression.json"), "w") as f:
        json.dump(out["results"], f, indent=1)
    if print_csv:
        b = out["bench"]
        for key, r in out["results"].items():
            print(f"compression/{key},0,bt={r['total_bt']}"
                  f" adj={r['adjusted_bt']} cycles={r['cycles']}"
                  f" flits={r['flits']}"
                  f" escape_bits={r['compression_overhead_bits']}")
        for label, w in b["workloads"].items():
            gm = w["adjusted_gain_pct"]["msr"]
            print(f"compression/{label}/verdict,"
                  f"{w['wall_s'] * 1e6:.0f},"
                  f"best_none={w['best_ordering']['none']}"
                  f" best_msr={w['best_ordering']['msr']}"
                  f"({gm[w['best_ordering']['msr']]}%)"
                  f" pays={w['ordering_pays_after_compression']}"
                  f" none_identical={w['compression_none_identical']}")
    return out


if __name__ == "__main__":
    main()
