"""Paper Fig. 12: BTs across NoC sizes (4x4/MC2, 8x8/MC4, 8x8/MC8) under
O0/O1/O2 with full LeNet inference traffic, float-32 and fixed-8.

Traffic is deterministic-stride subsampled per layer to keep CPU simulation
time bounded; BT *rates* are per-flit quantities, so subsampling is
unbiased (the paper's absolute counts scale with traffic volume).
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.core.wire import by_name
from repro.noc import PAPER_NOCS, simulate, build_traffic
from repro.quant import quantize_fixed8
from repro.data import glyph_batch

from ._trained import get_trained

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
PAPER_BANDS = {
    # Sec. V-B1: reduction ranges across NoC sizes
    "float32": {"O1": (12.09, 18.58), "O2": (23.30, 32.01)},
    "fixed8": {"O1": (7.88, 17.75), "O2": (16.95, 35.93)},
}


def run(max_packets=40, tiebreak="pattern", count_headers=True):
    model, params, _ = get_trained("lenet")
    x, _ = glyph_batch(jax.random.PRNGKey(7), 1)
    layers = model.layer_traffic(params, x[0])
    results = {}
    for noc_name, cfg in PAPER_NOCS.items():
        for fmt in ("float32", "fixed8"):
            q = None if fmt == "float32" else (lambda t: quantize_fixed8(t).values)
            base_bt = None
            for o in ("O0", "O1", "O2"):
                tr = build_traffic(layers, cfg, by_name(o, tiebreak=tiebreak),
                                   quantizer=q, max_packets_per_layer=max_packets)
                t0 = time.perf_counter()
                res = simulate(cfg, tr, chunk=2048, count_headers=count_headers)
                dt = time.perf_counter() - t0
                key = f"{noc_name}/{fmt}/{o}"
                red = None
                if o == "O0":
                    base_bt = res.total_bt
                else:
                    red = (1 - res.total_bt / base_bt) * 100
                results[key] = {
                    "total_bt": res.total_bt, "cycles": res.cycles,
                    "flits": res.injected, "reduction_pct": red,
                    "sim_s": round(dt, 2),
                }
    return results


def main(print_csv=True):
    results = run()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fig12.json"), "w") as f:
        json.dump(results, f, indent=1)
    if print_csv:
        for key, r in results.items():
            red = "" if r["reduction_pct"] is None else \
                f" reduction={r['reduction_pct']:.2f}%"
            print(f"fig12/{key},{r['sim_s'] * 1e6:.0f},"
                  f"bt={r['total_bt']}{red} cycles={r['cycles']}")
    return results


if __name__ == "__main__":
    main()
