"""Paper Fig. 12: BTs across NoC sizes (4x4/MC2, 8x8/MC4, 8x8/MC8) under
O0/O1/O2 with full LeNet inference traffic, float-32 and fixed-8.

Driven by the declarative sweep engine (``repro.noc.sweep``): one
packetization and one vmapped, compile-cached simulation per mesh, instead
of the seed's per-cell build+retrace loop. Reduction percentages charge the
O2 recovery index via ``WireTransform.overhead_bits_per_value`` (paper
Sec. IV-C1): ``reduction_pct`` is the raw link number, ``adjusted_*`` is the
honest one.

Traffic is deterministic-stride subsampled per layer to keep CPU simulation
time bounded; BT *rates* are per-flit quantities, so subsampling is
unbiased (the paper's absolute counts scale with traffic volume).

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to a 2x2/MC1 mesh with random-init
weights - the CI regression gate for the sweep engine.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.noc import PAPER_NOCS, SweepGrid, run_sweep
from repro.data import glyph_batch

from ._trained import get_trained, random_params

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
PAPER_BANDS = {
    # Sec. V-B1: reduction ranges across NoC sizes
    "float32": {"O1": (12.09, 18.58), "O2": (23.30, 32.01)},
    "fixed8": {"O1": (7.88, 17.75), "O2": (16.95, 35.93)},
}

# The pinned equivalence configuration: `reference_compare` must reproduce
# the seed driver's BT totals bit-for-bit on exactly this setup - the full
# O0/O1/O2 x mesh-size sweep (both tiebreaks, both precisions) at a bounded
# packet budget, with the chunk sized to the measured drain cycles.
PINNED = {
    "meshes": tuple(PAPER_NOCS), "max_packets": 8,
    "tiebreaks": ("stable", "pattern"), "chunk": 128,
    "glyph_seed": 7, "param_seed": 1,
}


def lenet_layers(glyph_seed: int = 7, trained: bool = True):
    """One LeNet inference's operand traffic (the Fig. 12 workload)."""
    if trained:
        model, params, _ = get_trained("lenet")
    else:
        model, params = random_params("lenet")
    x, _ = glyph_batch(jax.random.PRNGKey(glyph_seed), 1)
    return model.layer_traffic(params, x[0])


def run(max_packets=40, tiebreak="pattern", count_headers=True, meshes=None,
        placements=("edge",), affinity=("roundrobin",), result_phase=False,
        transforms=("O0", "O1", "O2")):
    """The Fig. 12 sweep. ``placements``/``affinity`` widen the grid beyond
    the paper's axes (single-strategy runs keep the seed-stable key format);
    ``result_phase`` adds the PE->MC drain columns to every row;
    ``transforms`` widens the ordering axis (e.g. the beyond-paper ``O3``
    min-Hamming lane)."""
    if meshes is None:
        meshes = ("2x2_mc1",) if SMOKE else tuple(PAPER_NOCS)
    if SMOKE:
        max_packets = min(max_packets, 4)
    grid = SweepGrid(
        meshes=meshes, placements=placements, affinity=affinity,
        transforms=transforms,
        tiebreaks=(tiebreak,), precisions=("float32", "fixed8"),
        models=("lenet",), max_packets_per_layer=max_packets,
        count_headers=count_headers, result_phase=result_phase, chunk=2048)
    report = run_sweep(grid, lambda _name: lenet_layers(trained=not SMOKE))
    results = {}
    for r in report.rows:
        key = f"{r['mesh']}/{r['precision']}/{r['transform']}"
        if len(placements) > 1:     # single-placement keys stay seed-stable
            key = f"{r['mesh']}/{r['placement']}/{r['precision']}/{r['transform']}"
        if len(affinity) > 1:
            key += f"/{r['affinity']}"
        is_base = r["transform"] == grid.baseline
        results[key] = {
            "total_bt": r["total_bt"], "cycles": r["cycles"],
            "flits": r["flits"],
            "reduction_pct": None if is_base else r["reduction_pct"],
            "adjusted_reduction_pct":
                None if is_base else r["adjusted_reduction_pct"],
            "overhead_bits": r["overhead_bits"],
        }
        if result_phase:
            results[key]["result_bt"] = r["result_bt"]
            results[key]["result_cycles"] = r["result_cycles"]
    return results, report.stats


def reference_compare():
    """Pinned speedup + equivalence record for BENCH_noc.json.

    Runs the pre-refactor driver (``repro.noc._reference``: per-neuron
    packetization, one trace+compile per traffic tensor) and the sweep
    engine on the pinned configuration, asserts bit-identical BT totals,
    and reports the wall-clock ratio.
    """
    from repro.core.wire import by_name
    from repro.noc import mesh_by_name
    from repro.noc._reference import build_traffic_reference, simulate_reference
    from repro.quant import quantize_fixed8

    model, params = random_params("lenet", seed=PINNED["param_seed"])
    x, _ = glyph_batch(jax.random.PRNGKey(PINNED["glyph_seed"]), 1)
    layers = model.layer_traffic(params, x[0])
    meshes = ("2x2_mc1", "4x4_mc2") if SMOKE else PINNED["meshes"]
    precisions = ("fixed8",) if SMOKE else ("float32", "fixed8")
    tiebreaks = ("pattern",) if SMOKE else PINNED["tiebreaks"]
    orderings = ("O0", "O1", "O2")
    max_packets = 4 if SMOKE else PINNED["max_packets"]

    quant = {"float32": None, "fixed8": lambda t: quantize_fixed8(t).values}
    t0 = time.perf_counter()
    legacy_bt = {}
    for mesh in meshes:
        cfg = mesh_by_name(mesh)
        for fmt in precisions:
            for tb in tiebreaks:
                for o in orderings:
                    tr = build_traffic_reference(
                        layers, cfg, by_name(o, tiebreak=tb),
                        quantizer=quant[fmt],
                        max_packets_per_layer=max_packets)
                    res = simulate_reference(cfg, tr, chunk=PINNED["chunk"])
                    legacy_bt[f"{mesh}/{fmt}/{tb}/{o}"] = res.total_bt
    legacy_s = time.perf_counter() - t0

    grid = SweepGrid(
        meshes=meshes, transforms=orderings, tiebreaks=tiebreaks,
        precisions=precisions, models=("lenet",),
        max_packets_per_layer=max_packets, chunk=PINNED["chunk"])
    t0 = time.perf_counter()
    report = run_sweep(grid, lambda _name: layers)
    sweep_s = time.perf_counter() - t0

    sweep_bt = {f"{r['mesh']}/{r['precision']}/{r['tiebreak']}"
                f"/{r['transform']}": r["total_bt"]
                for r in report.rows}
    if sweep_bt != legacy_bt:
        raise RuntimeError(
            f"sweep engine diverged from the pre-refactor path on the pinned "
            f"config: {sweep_bt} != {legacy_bt}")
    return {
        "pinned": {**PINNED, "meshes": list(meshes),
                   "max_packets": max_packets,
                   "tiebreaks": list(tiebreaks),
                   "precisions": list(precisions)},
        "variants": len(legacy_bt),
        "legacy_s": round(legacy_s, 3),
        "sweep_s": round(sweep_s, 3),
        "speedup": round(legacy_s / sweep_s, 2),
        "bt_identical": True,
        "total_bt": sweep_bt,
    }


def placement_smoke():
    """CI gate for the MC-placement axis: a 4x4 fig12 sweep across all
    three placements. Pins the structural symmetry (edge and corner resolve
    to the same opposite-corner MC set on 4x4/MC2, so every cell matches
    exactly) and that interleaved placement changes link totals without
    changing flit volume."""
    results, stats = run(max_packets=4, meshes=("4x4_mc2",),
                         placements=("edge", "corner", "interleaved"))
    assert stats["cells"] == 18, stats
    for prec in ("float32", "fixed8"):
        for tr in ("O0", "O1", "O2"):
            edge = results[f"4x4_mc2/edge/{prec}/{tr}"]
            corner = results[f"4x4_mc2/corner/{prec}/{tr}"]
            inter = results[f"4x4_mc2/interleaved/{prec}/{tr}"]
            assert edge["total_bt"] == corner["total_bt"], (prec, tr)
            assert edge["cycles"] == corner["cycles"], (prec, tr)
            assert inter["flits"] == edge["flits"], (prec, tr)
    assert any(
        results[f"4x4_mc2/interleaved/{p}/{t}"]["total_bt"]
        != results[f"4x4_mc2/edge/{p}/{t}"]["total_bt"]
        for p in ("float32", "fixed8") for t in ("O0", "O1", "O2"))
    print(f"placement smoke ok: {stats['cells']} cells, "
          f"edge==corner pinned, interleaved diverges")


def o3_vs_o2(results):
    """Per (mesh, precision) O3-vs-O2 adjusted-reduction gaps, plus the
    acceptance verdict: O3 must beat O2's honest number on fixed8 and stay
    >= O2 on float32 on every mesh."""
    gaps = {}
    ok = True
    for key, r in results.items():
        if not key.endswith("/O3"):
            continue
        cell = key.rsplit("/", 1)[0]
        o2 = results[cell + "/O2"]
        d = r["adjusted_reduction_pct"] - o2["adjusted_reduction_pct"]
        gaps[cell] = {
            "o2_adjusted_reduction_pct": round(o2["adjusted_reduction_pct"], 3),
            "o3_adjusted_reduction_pct": round(r["adjusted_reduction_pct"], 3),
            "delta_pct": round(d, 3),
        }
        strict = cell.endswith("/fixed8")
        if (d <= 0) if strict else (d < 0):
            ok = False
    return {"cells": gaps, "o3_beats_o2": ok}


def o3_smoke():
    """CI gate for the O3 lane: the fig12 grid widened with O3/O3a must
    produce O3 rows that beat O2's adjusted reduction on fixed8 and stay
    >= O2 on float32."""
    results, stats = run(max_packets=4,
                         transforms=("O0", "O1", "O2", "O3", "O3a"))
    comp = o3_vs_o2(results)
    assert comp["cells"], "no O3 cells produced"
    assert comp["o3_beats_o2"], comp
    print(f"o3 smoke ok: {len(comp['cells'])} cells, all beat O2 adjusted")
    for key, g in comp["cells"].items():
        print(f"  {key}: O2 {g['o2_adjusted_reduction_pct']}% -> "
              f"O3 {g['o3_adjusted_reduction_pct']}% (+{g['delta_pct']})")
    return comp


def main(print_csv=True, transforms=("O0", "O1", "O2")):
    results, stats = run(transforms=transforms)
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fig12.json"), "w") as f:
        json.dump(results, f, indent=1)
    if print_csv:
        per_cell_us = stats["wall_s"] / max(stats["cells"], 1) * 1e6
        for key, r in results.items():
            red = "" if r["reduction_pct"] is None else \
                f" reduction={r['reduction_pct']:.2f}%" \
                f" adj={r['adjusted_reduction_pct']:.2f}%"
            print(f"fig12/{key},{per_cell_us:.0f},"
                  f"bt={r['total_bt']}{red} cycles={r['cycles']}")
    out = {"results": results, "bench": stats}
    if "O3" in transforms and "O2" in transforms:
        out["bench"]["o3_vs_o2"] = o3_vs_o2(results)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description="Fig. 12 BT sweep")
    ap.add_argument("--transforms", default="O0,O1,O2",
                    help="comma-separated WireTransform names "
                         "(e.g. O0,O1,O2,O3)")
    ns = ap.parse_args()
    main(transforms=tuple(ns.transforms.split(",")))
