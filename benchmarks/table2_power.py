"""Paper Tab. II + Sec. V-C: ordering-unit overhead vs router power, link
power under both energy models, and end-to-end net savings using the
measured fig13 reduction."""
from __future__ import annotations

import json
import os
import time

from repro.noc import power

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run():
    hw = power.HW
    rows = {
        "ordering_unit_mw": hw.ordering_unit_mw,
        "ordering_unit_kge": hw.ordering_unit_kge,
        "router_mw": hw.router_mw,
        "router_kge": hw.router_kge,
        "four_units_mw": hw.ordering_unit_mw * 4,
        "router64_mw": hw.router_mw * 64,
        "link_power_ours_mw": power.paper_example(),
        "link_power_banerjee_mw": power.paper_example(hw.e_bit_banerjee_pj),
    }
    # net accounting with the measured DarkNet fixed-8 O2 reduction if the
    # fig13 bench has run; fall back to the paper's 40.85%.
    red = 0.4085
    fig13 = os.path.join(OUT, "fig13.json")
    if os.path.exists(fig13):
        with open(fig13) as f:
            d = json.load(f)
        key = "darknet/fixed8/O2"
        if key in d:
            red = d[key]["reduction_pct"] / 100.0
    rows["measured_reduction"] = red
    rows["net"] = power.net_power_saving_mw(
        64, red, num_links=112, num_mcs=4, separated=True)
    return rows


def main(print_csv=True):
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    if print_csv:
        print(f"table2/ordering_unit,{us:.1f},"
              f"{rows['ordering_unit_mw']}mW/{rows['ordering_unit_kge']}kGE"
              f" vs router {rows['router_mw']}mW/{rows['router_kge']}kGE")
        print(f"table2/link_power,{us:.1f},"
              f"ours={rows['link_power_ours_mw']:.3f}mW"
              f" banerjee={rows['link_power_banerjee_mw']:.3f}mW")
        n = rows["net"]
        print(f"table2/net_saving,{us:.1f},"
              f"reduction={rows['measured_reduction']*100:.2f}%"
              f" link {n['baseline_link_mw']:.1f}->{n['ordered_link_mw']:.1f}mW"
              f" units {n['ordering_units_mw']:.2f}mW"
              f" net={n['net_saving_mw']:.1f}mW")
    return rows


if __name__ == "__main__":
    main()
