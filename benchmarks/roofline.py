"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms, in seconds per step, per chip (TPU v5e):

    compute    = HW_FLOPs / (chips * 197e12)
    memory     = HBM_bytes / (chips * 819e9)
    collective = collective_bytes_per_chip / 50e9

Methodology notes (full discussion in EXPERIMENTS.md):
  * collective bytes come from the compiled partitioned HLO with while-body
    traffic multiplied by loop trip counts (repro.launch.dryrun).
  * XLA-CPU cost_analysis does NOT scale loop bodies by trip count and its
    'bytes accessed' lacks fusion-aware cache modeling (calibrated 5x high
    on a bare dot), so the compute/memory terms use an analytic hardware
    model (the industry MFU convention, plus attention/recurrence terms),
    with the raw HLO numbers recorded alongside for reference.
  * MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); the ratio against
    hardware FLOPs shows remat/attention overhead; the roofline fraction =
    useful-compute time / dominant term time.
"""
from __future__ import annotations

import glob
import json
import os
import time

from repro.configs import ARCHS, SHAPES
from repro.models.spec import is_spec

import jax

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / chip (ICI)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


# ---------------------------------------------------------------------------
# analytic parameter/flop model
# ---------------------------------------------------------------------------

def _param_split(arch):
    """(matmul_params_active, matmul_params_total, embed_params)."""
    model = arch.build()
    specs = model.specs()
    leaves = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec)[0]
    active = total = embed = 0
    cfg = arch.config
    for path, s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        pstr = "/".join(str(getattr(p, "key", p)) for p in path)
        if "embed" == pstr or "pos_dec" in pstr or "vis_proj" in pstr:
            embed += n
            continue
        total += n
        if "experts" in (s.axes or ()):
            frac = cfg.top_k / cfg.n_experts
            active += int(n * frac)
        else:
            active += n
    # logits matmul: tied embeddings reuse the embed table as a matmul
    v = getattr(cfg, "padded_vocab", 0)
    d = cfg.d_model
    if getattr(cfg, "tied_embeddings", True) and v:
        active += v * d
        total += v * d
    return active, total, embed


def _attn_layers(cfg):
    if hasattr(cfg, "pattern"):
        n_attn = sum(1 for k in cfg.pattern) * 0  # recomputed below
        per = len(cfg.pattern)
        full_groups = cfg.n_layers // per
        counts = {}
        for k in cfg.pattern:
            counts[k] = counts.get(k, 0) + 1
        tail = cfg.pattern[:cfg.n_layers % per]
        for k in tail:
            counts[k] = counts.get(k, 0)  # ensure key
        n = {k: counts.get(k, 0) * full_groups for k in counts}
        for k in tail:
            n[k] = n.get(k, 0) + 1
        return n
    return {"attn": cfg.n_layers * 2}   # enc-dec: both stacks (+cross below)


def _cell_flops(arch, cell):
    """(hw_flops_global, model_flops_global) for one step."""
    cfg = arch.config
    b, s = cell.global_batch, cell.seq_len
    act, tot, _ = _param_split(arch)
    d_kv = cfg.hd * cfg.n_heads if hasattr(cfg, "pattern") else cfg.d_model
    layer_counts = _attn_layers(cfg)

    def attn_fwd(q_len, kv_len, causal=True):
        window = getattr(cfg, "window", 0)
        eff = min(kv_len, window) if window else kv_len
        frac = 0.5 if (causal and not window and q_len == kv_len) else 1.0
        return 4.0 * b * q_len * eff * d_kv * frac

    n_attn = layer_counts.get("attn", 0)
    extra = 0.0
    # mLSTM/sLSTM state updates: ~8 * B * S * H * hd^2-equivalent per layer
    for kind in ("mlstm", "slstm"):
        if kind in layer_counts and hasattr(cfg, "hd"):
            hd = cfg.d_model // cfg.n_heads
            per_tok = 8.0 * cfg.n_heads * hd * hd if kind == "mlstm" \
                else 8.0 * cfg.d_model
            extra += layer_counts[kind] * per_tok

    if cell.mode == "train":
        tokens = b * s
        fwd = 2.0 * act * tokens + n_attn * attn_fwd(s, s) + extra * tokens
        if arch.kind == "encdec":
            s_dec = max(s // 4, 8)
            tokens = b * (s + s_dec)
            fwd = (2.0 * act * tokens
                   + cfg.n_layers * (attn_fwd(s, s, False)
                                     + attn_fwd(s_dec, s_dec)
                                     + attn_fwd(s_dec, s, False)))
        hw = fwd * 4.0          # bwd = 2x fwd, remat re-fwd = 1x
        model = fwd * 3.0
    elif cell.mode == "prefill":
        tokens = b * s
        fwd = 2.0 * act * tokens + n_attn * attn_fwd(s, s) + extra * tokens
        if arch.kind == "encdec":
            s_dec = max(s // 4, 8)
            fwd = (2.0 * act * b * (s + s_dec)
                   + cfg.n_layers * (attn_fwd(s, s, False)
                                     + attn_fwd(s_dec, s_dec)
                                     + attn_fwd(s_dec, s, False)))
        hw = model = fwd
    else:  # decode: one token per sequence
        cache = cfg.cache_len(s) if hasattr(cfg, "cache_len") else s
        fwd = 2.0 * act * b + n_attn * attn_fwd(1, cache, causal=False) + extra * b
        if arch.kind == "encdec":
            fwd = 2.0 * act * b + cfg.n_layers * (
                attn_fwd(1, max(s // 4, 8), False) + attn_fwd(1, s, False))
        hw = model = fwd
    return hw, model


def _cell_bytes(arch, cell, devices, rec):
    """Analytic per-device HBM traffic per step (lower-bound model)."""
    cfg = arch.config
    b, s = cell.global_batch, cell.seq_len
    act, tot, embed = _param_split(arch)
    p_bytes = rec.get("param_bytes_global", tot * 2)
    p_dev = p_bytes / devices
    d = cfg.d_model
    layers = cfg.n_layers
    if cell.mode == "train":
        # params r/w + fp32-equivalent opt states r/w + grads r/w +
        # one activation checkpoint per layer r/w (remat recompute covers
        # the rest from those)
        opt_factor = 2.0 if arch.optimizer_state == "int8" else 8.0
        act_ckpt = layers * (b * s / devices) * d * 2 * 4
        return p_dev * 4 + p_dev * opt_factor + act_ckpt
    if cell.mode == "prefill":
        kv = 2 * layers * (b * s / devices) * cfg.n_kv * cfg.hd * 2 \
            if hasattr(cfg, "n_kv") else 0
        acts = layers * (b * s / devices) * d * 2 * 2
        return p_dev + kv + acts
    # decode: touch active params once + read/write the cache
    cache = cfg.cache_len(s) if hasattr(cfg, "cache_len") else s
    act_dev = act * 2 / min(devices, 256)   # active params, bf16, sharded
    kv_dev = 2 * layers * (b / max(devices // 16, 1)) * cache \
        * getattr(cfg, "n_kv", 1) * getattr(cfg, "hd", d) * 2 / 16
    # simpler: global cache bytes / devices
    kv_global = 2 * layers * b * cache * getattr(cfg, "n_kv", 1) \
        * getattr(cfg, "hd", d) * 2
    return act * 2 / devices + kv_global / devices


# ---------------------------------------------------------------------------
# table construction
# ---------------------------------------------------------------------------

def load_records(mesh="pod16x16", tag=""):
    recs = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}{tag}.json")):
        base = os.path.basename(path)
        if tag == "" and base.count("__") != 2:
            continue
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"])] = r
    return recs


def build_table(mesh="pod16x16", tag=""):
    recs = load_records(mesh, tag)
    rows = []
    for arch_name in ARCHS:
        arch = ARCHS[arch_name]
        for shape_name, cell in SHAPES.items():
            r = recs.get((arch_name, shape_name))
            if r is None:
                continue
            if r["status"] == "skip":
                rows.append({"arch": arch_name, "shape": shape_name,
                             "status": "skip", "reason": r["reason"]})
                continue
            if r["status"] != "ok":
                rows.append({"arch": arch_name, "shape": shape_name,
                             "status": "fail", "reason": r.get("error", "")})
                continue
            devices = r["devices"]
            hw_flops, model_flops = _cell_flops(arch, cell)
            compute_s = hw_flops / devices / PEAK_FLOPS
            mem_bytes = _cell_bytes(arch, cell, devices, r)
            memory_s = mem_bytes / HBM_BW
            coll_bytes = r["collective_bytes_per_device"]["total"]
            coll_s = coll_bytes / LINK_BW
            terms = {"compute": compute_s, "memory": memory_s,
                     "collective": coll_s}
            dominant = max(terms, key=terms.get)
            useful_s = model_flops / devices / PEAK_FLOPS
            fraction = useful_s / max(terms.values()) if max(terms.values()) else 0
            rows.append({
                "arch": arch_name, "shape": shape_name, "status": "ok",
                "devices": devices,
                "compute_s": compute_s, "memory_s": memory_s,
                "collective_s": coll_s, "dominant": dominant,
                "model_flops": model_flops, "hw_flops": hw_flops,
                "hlo_flops_raw_per_dev": r.get("flops_per_device"),
                "model_over_hw": model_flops / hw_flops,
                "roofline_fraction": fraction,
                "collective_breakdown": r["collective_bytes_per_device"],
            })
    return rows


def to_markdown(rows):
    md = ["| arch | shape | compute s | memory s | collective s | dominant "
          "| roofline frac | note |",
          "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            md.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | "
                      f"{r['status']}: {r.get('reason','')[:60]} |")
            continue
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | |")
    return "\n".join(md)


def main(print_csv=True, mesh="pod16x16", tag=""):
    t0 = time.perf_counter()
    rows = build_table(mesh, tag)
    us = (time.perf_counter() - t0) * 1e6
    if print_csv:
        for r in rows:
            if r["status"] != "ok":
                print(f"roofline/{r['arch']}/{r['shape']},0,{r['status']}")
                continue
            print(f"roofline/{r['arch']}/{r['shape']},{us/len(rows):.0f},"
                  f"dominant={r['dominant']}"
                  f" frac={r['roofline_fraction']:.3f}"
                  f" c={r['compute_s']:.2e}s m={r['memory_s']:.2e}s"
                  f" x={r['collective_s']:.2e}s")
    return rows


if __name__ == "__main__":
    main()
