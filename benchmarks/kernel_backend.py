"""Kernel-backend trajectory suite: the raw-speed layer's before/after.

Two measurements back the ``kernel_backend`` entry in BENCH_noc.json:

* ``fig12_packetize``: the fig12 ordering workload (full trained-LeNet
  operand layers, O0/O1/O2/O3 x float32/fixed8 at the fig12 packet budget)
  timed against the frozen pre-batching recording
  (:data:`PR6_FIG12_PACKETIZE_S` - ``suites.fig12.packetize_s`` before the
  batched O3 chain landed). Ordering caches are cleared first so the
  number is cold-equivalent even when fig12 ran earlier in the process.
* ``router_step``: cycles/sec of the pinned ``step_overhaul`` 8x8 chunk
  under the fused jnp step vs the Pallas router kernel. On CPU the Pallas
  path runs in interpret mode (a correctness artifact, expected slower -
  the recorded ``mode`` says which path was measured); on TPU it compiles
  via Mosaic.

Both halves pin bit-identity: the O3 payloads against the per-window
numpy oracle chain, and the Pallas drain against the fused drain on a
short pinned batch. ``--check-packetize-ceiling S`` runs only the
ordering measurement and exits nonzero above S seconds - the CI gate
against packetizer regressions (generous margin for CI jitter).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.wire import by_name
from repro.kernels.ops import on_tpu
from repro.noc import mesh_by_name
from repro.noc.sim import simulate_batch
from repro.noc.traffic import _packet_fn, build_traffic_batch, \
    ordered_payloads
from repro.quant import quantize_fixed8

from .fig12 import SMOKE, lenet_layers
from .step_overhaul import PIN, _fused_cps, _pinned_traffic

# suites.fig12.packetize_s recorded at PR 6 (per-window O3 chain dispatch,
# lax.top_k beam select) - the baseline the batched chain is gated against.
PR6_FIG12_PACKETIZE_S = 160.47


def _fig12_variants(transforms=("O0", "O1", "O2", "O3")):
    """The fig12 ordering workload's variant list (pattern tiebreak,
    float32 + fixed8) - what ``run_sweep`` orders once per model."""
    quant = {"float32": None, "fixed8": lambda t: quantize_fixed8(t).values}
    return [(by_name(tr, tiebreak="pattern"), quant[prec])
            for prec in ("float32", "fixed8") for tr in transforms]


def _clear_ordering_caches():
    """Cold-equivalent timing: drop the memoized packet transforms and
    every jitted executable (the batched chain scan included)."""
    import jax
    _packet_fn.cache_clear()
    jax.clear_caches()


def packetize_compare() -> dict:
    """Time the fig12 ordering workload cold and pin O3 == oracle."""
    from repro.kernels.min_hamming import (min_hamming_chain,
                                           min_hamming_chain_reference)

    layers = lenet_layers(trained=not SMOKE)
    lanes = mesh_by_name("4x4_mc2").lanes
    max_packets = 4 if SMOKE else 40
    _clear_ordering_caches()
    t0 = time.perf_counter()
    stacks = ordered_payloads(layers, lanes, _fig12_variants(),
                              max_packets_per_layer=max_packets)
    after_s = time.perf_counter() - t0
    total_words = int(sum(s.size for s in stacks))

    # Oracle pin: the batched beam-select chain must match the per-window
    # numpy reference on real operand windows (the full sweep-level parity
    # lives in tests/test_kernel_parity.py).
    u = np.abs(np.asarray(layers[0].inputs[:5, :12],
                          np.float32)).view(np.uint32)
    res = min_hamming_chain(u)
    want_perm, want_cost, _ = min_hamming_chain_reference(u)
    oracle_ok = (np.array_equal(np.asarray(res.perm), want_perm)
                 and np.array_equal(np.asarray(res.cost), want_cost))

    before_s = None if SMOKE else PR6_FIG12_PACKETIZE_S
    out = {
        "workload": {"model": "lenet", "transforms": ["O0", "O1", "O2", "O3"],
                     "precisions": ["float32", "fixed8"],
                     "tiebreak": "pattern", "max_packets": max_packets,
                     "lanes": lanes, "smoke": SMOKE},
        "before_s": before_s,
        "after_s": round(after_s, 3),
        "speedup": (round(before_s / after_s, 2) if before_s else None),
        "ordered_words": total_words,
        "oracle_identical": bool(oracle_ok),
    }
    if not oracle_ok:
        raise RuntimeError(f"batched O3 chain diverged from the numpy "
                           f"oracle: {out}")
    return out


def router_step_compare() -> dict:
    """Fused vs Pallas cycles/sec on the pinned 8x8 chunk, plus drain
    bit-identity of the two backends on a short pinned batch."""
    cfg, batch = _pinned_traffic()
    fused_cps, _ = _fused_cps(cfg, batch)
    pallas_cps, _ = _fused_cps(cfg, batch, backend="pallas")

    small = mesh_by_name("4x4_mc2")
    sl = lenet_layers(trained=not SMOKE)
    variants = [(by_name(o, tiebreak="pattern"), None)
                for o in ("O0", "O1", "O2")]
    bt = build_traffic_batch(sl, small, variants, max_packets_per_layer=8)
    rf = simulate_batch(small, bt, chunk=512, backend="fused")
    rp = simulate_batch(small, bt, chunk=512, backend="pallas")
    bt_ok = all(
        a.total_bt == b.total_bt and a.drain_cycle == b.drain_cycle
        and np.array_equal(a.link_bt, b.link_bt)
        for a, b in zip(rf, rp))
    return {
        "pinned": dict(PIN, variants=list(PIN["variants"])),
        "fused_cps": round(fused_cps, 1),
        "pallas_cps": round(pallas_cps, 1),
        "mode": "mosaic" if on_tpu() else "interpret",
        "bt_identical": bool(bt_ok),
    }


def main(print_csv: bool = True) -> dict:
    pk = packetize_compare()
    rs = router_step_compare()
    if not rs["bt_identical"]:
        raise RuntimeError(f"Pallas drain diverged from fused: {rs}")
    bench = {"fig12_packetize": pk, "router_step": rs,
             "bt_identical": bool(pk["oracle_identical"]
                                  and rs["bt_identical"])}
    if print_csv:
        spd = f" speedup={pk['speedup']}x" if pk["speedup"] else ""
        print(f"kernel_backend/fig12_packetize,"
              f"{pk['after_s'] * 1e6:.0f},"
              f"before={pk['before_s']} after={pk['after_s']}{spd}")
        print(f"kernel_backend/router_step,0,"
              f"fused={rs['fused_cps']} pallas={rs['pallas_cps']} "
              f"mode={rs['mode']} bt_identical={rs['bt_identical']}")
    return {"results": bench, "bench": bench}


def check_packetize_ceiling(ceiling_s: float) -> None:
    pk = packetize_compare()
    print(f"kernel_backend packetize check: {pk['after_s']:.1f}s "
          f"(ceiling {ceiling_s}s, oracle_identical={pk['oracle_identical']})")
    if pk["after_s"] > ceiling_s:
        raise SystemExit(f"fig12 packetize regression: {pk['after_s']:.1f}s "
                         f"> ceiling {ceiling_s}s")


if __name__ == "__main__":
    if len(sys.argv) == 1:
        main()
    elif sys.argv[1] == "--check-packetize-ceiling" and len(sys.argv) == 3:
        check_packetize_ceiling(float(sys.argv[2]))
    else:
        raise SystemExit(
            f"usage: {sys.argv[0]} [--check-packetize-ceiling SECONDS]")
