"""Beyond the paper's scale axis: the *full*, unsubsampled DarkNet traffic
on a 16x16 mesh (the paper tops out at 8x8) with the MC-placement and
packet->MC-affinity axes plus the PE->MC result phase, via streamed
packetization and the (optionally device-sharded) batched drain.

This is the sweep the engine existed to reach: every neuron of every
DarkNetLike layer (~100k packets, ~1.3M flits) is packetized in bounded
chunks (`build_traffic_streamed`), placement x affinity combinations share
one compiled simulator, the result phase drains each cell's PE->MC return
traffic in a second batched simulation, and - on multi-device hosts - the
variants axis shards across devices. The suite records wall-clock,
simulated cycles/sec, the sharded-vs-unsharded speedup, per-direction BT,
result-phase drain cycles, and the affinity hop/cycle deltas into
BENCH_noc.json (schema: docs/bench_schema.md). Affinity-off (roundrobin)
rows are compared against the previous recording in
experiments/darknet_full.json - they must stay bit-identical.

Continuing the paper's doubling pattern (4x4/MC2 -> 8x8/MC4 -> 8x8/MC8),
the 16x16 mesh carries 16 MCs so injection bandwidth scales with the mesh.

``REPRO_BENCH_SMOKE=1`` shrinks to full (still unsubsampled) LeNet traffic
on a 4x4/MC2 mesh with random-init weights - the CI gate for the streamed
full-traffic path.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.noc import SweepGrid, run_sweep
from repro.data import glyph_batch

from ._trained import get_trained, random_params

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _layers(name: str):
    if SMOKE:
        model, params = random_params(name)
    else:
        model, params, _ = get_trained(name)
    hw, ch = model.input_shape[0], model.input_shape[-1]
    x, _ = glyph_batch(jax.random.PRNGKey(11), 1, hw=hw, channels=ch)
    return model.layer_traffic(params, x[0])


def _grid() -> SweepGrid:
    return SweepGrid(
        meshes=("4x4_mc2",) if SMOKE else ("16x16_mc16",),
        placements=("edge", "interleaved"),
        affinity=("roundrobin", "nearest"),
        transforms=("O0", "O1") if SMOKE else ("O0", "O1", "O2"),
        tiebreaks=("pattern",),
        precisions=("fixed8",),
        models=("lenet",) if SMOKE else ("darknet",),
        max_packets_per_layer=None,          # full traffic -> streamed path
        result_phase=True,
        chunk=4096)


def _row_key(r, baseline_affinity="roundrobin"):
    """Seed-stable keys: roundrobin rows keep the PR-3/PR-4 key format so
    recordings stay comparable; other affinities append their own segment."""
    key = f"{r['mesh']}/{r['placement']}/{r['precision']}/{r['transform']}"
    if r["affinity"] != baseline_affinity:
        key += f"/{r['affinity']}"
    return key


def run() -> dict:
    grid = _grid()
    model = grid.models[0]
    ndev = jax.local_device_count()
    layers = _layers(model)
    layers_fn = lambda _name: layers         # noqa: E731 - one shared load

    t0 = time.perf_counter()
    report = run_sweep(grid, layers_fn)      # devices="auto": sharded if >1
    wall = time.perf_counter() - t0

    results = {}
    for r in report.rows:
        results[_row_key(r)] = {
            "total_bt": r["total_bt"], "cycles": r["cycles"],
            "flits": r["flits"],
            "reduction_pct":
                None if r["transform"] == grid.baseline
                else r["reduction_pct"],
            "adjusted_reduction_pct":
                None if r["transform"] == grid.baseline
                else r["adjusted_reduction_pct"],
            "result_bt": r["result_bt"],
            "result_cycles": r["result_cycles"],
            "result_flits": r["result_flits"],
        }

    # Affinity-off rows must be bit-identical to the previous recording
    # (the knob defaults off, so its introduction cannot move the needle).
    prior_path = os.path.join(OUT, "darknet_full.json")
    rr_identical = None
    if os.path.exists(prior_path):
        try:
            with open(prior_path) as f:
                prior = json.load(f)
            checks = [
                (results[k][f], prior[k][f])
                for k in prior if k in results and "/nearest" not in k
                for f in ("total_bt", "cycles", "flits")
                if f in prior[k]]
            rr_identical = (all(a == b for a, b in checks)
                            if checks else None)
        except (ValueError, KeyError, TypeError):
            rr_identical = None

    # Per-direction BT and the affinity hop/cycle deltas, per placement.
    affinity = {}
    for pl in grid.placements:
        rr = report.row(placement=pl, affinity="roundrobin",
                        transform=grid.baseline)
        near = report.row(placement=pl, affinity="nearest",
                          transform=grid.baseline)
        affinity[pl] = {
            "mean_hops_roundrobin": rr["mean_hops"],
            "mean_hops_nearest": near["mean_hops"],
            "hop_delta_pct": round(
                (1 - near["mean_hops"] / rr["mean_hops"]) * 100, 2)
            if rr["mean_hops"] else None,
            "cycles_roundrobin": rr["cycles"],
            "cycles_nearest": near["cycles"],
            "cycle_delta_pct": round(
                (1 - near["cycles"] / rr["cycles"]) * 100, 2)
            if rr["cycles"] else None,
            "request_bt_delta_pct": round(
                (1 - near["total_bt"] / rr["total_bt"]) * 100, 2)
            if rr["total_bt"] else None,
            "result_bt_delta_pct": round(
                (1 - near["result_bt"] / rr["result_bt"]) * 100, 2)
            if rr["result_bt"] else None,
        }

    base_row = report.row(placement=grid.placements[0], affinity="roundrobin",
                          transform=grid.baseline)
    bench = {
        "model": model, "mesh": grid.meshes[0],
        "placements": list(grid.placements),
        "affinity_axis": list(grid.affinity),
        "packets_full": int(sum(int(l.inputs.shape[0]) for l in layers)),
        "wall_s": round(wall, 3),
        "devices": ndev,
        **{k: report.stats[k] for k in
           ("cells", "packetize_s", "simulate_s", "stepped_cycles",
            "cycles_per_sec", "streamed", "result_packetize_s",
            "result_simulate_s", "result_cycles",
            "result_cycles_per_sec")},
        # per-shape-class engine throughput (one entry per mesh x model,
        # placement x affinity combos ride one drain-aware batched call)
        "shape_classes": report.stats["shape_classes"],
        # per-direction totals of the baseline roundrobin/edge cell
        "request_bt_baseline": base_row["total_bt"],
        "result_bt_baseline": base_row["result_bt"],
        "affinity_deltas": affinity,
        "roundrobin_bt_identical_to_prior": rr_identical,
    }

    # Sharded-vs-unsharded speedup: re-drain one placement's shape class
    # unsharded and compare the simulate wall. Only meaningful with >1
    # device; a 1-device host records the fallback.
    if ndev > 1:
        import dataclasses
        probe = dataclasses.replace(grid, placements=(grid.placements[0],),
                                    affinity=("roundrobin",),
                                    result_phase=False)
        sharded = run_sweep(probe, layers_fn)
        unsharded = run_sweep(probe, layers_fn, devices=None)
        assert [r["total_bt"] for r in sharded.rows] == \
            [r["total_bt"] for r in unsharded.rows], \
            "sharded drain diverged from unsharded"
        bench["sharded_simulate_s"] = sharded.stats["simulate_s"]
        bench["unsharded_simulate_s"] = unsharded.stats["simulate_s"]
        bench["shard_speedup"] = round(
            unsharded.stats["simulate_s"] / sharded.stats["simulate_s"], 2)
        bench["shard_bt_identical"] = True
    else:
        bench["shard_speedup"] = None

    return {"results": results, "bench": bench}


def main(print_csv=True):
    out = run()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "darknet_full.json"), "w") as f:
        json.dump(out["results"], f, indent=1)
    if print_csv:
        b = out["bench"]
        for key, r in out["results"].items():
            red = "" if r["reduction_pct"] is None else \
                f" reduction={r['reduction_pct']:.2f}%" \
                f" adj={r['adjusted_reduction_pct']:.2f}%"
            print(f"darknet_full/{key},0,bt={r['total_bt']}"
                  f" cycles={r['cycles']} flits={r['flits']}"
                  f" result_bt={r['result_bt']}"
                  f" result_cycles={r['result_cycles']}{red}")
        print(f"darknet_full/engine,{b['wall_s'] * 1e6:.0f},"
              f"cycles_per_sec={b['cycles_per_sec']}"
              f" devices={b['devices']} shard_speedup={b['shard_speedup']}"
              f" rr_identical={b['roundrobin_bt_identical_to_prior']}")
    return out


if __name__ == "__main__":
    main()
