"""Beyond the paper's scale axis: the *full*, unsubsampled DarkNet traffic
on a 16x16 mesh (the paper tops out at 8x8) with the MC-placement axis, via
streamed packetization and the (optionally device-sharded) batched drain.

This is the sweep the engine existed to reach: every neuron of every
DarkNetLike layer (~100k packets, ~1.3M flits) is packetized in bounded
chunks (`build_traffic_streamed`), placements share one compiled simulator,
and - on multi-device hosts - the variants axis shards across devices.
The suite records wall-clock, simulated cycles/sec, and the
sharded-vs-unsharded speedup into BENCH_noc.json.

Continuing the paper's doubling pattern (4x4/MC2 -> 8x8/MC4 -> 8x8/MC8),
the 16x16 mesh carries 16 MCs so injection bandwidth scales with the mesh.

``REPRO_BENCH_SMOKE=1`` shrinks to full (still unsubsampled) LeNet traffic
on a 4x4/MC2 mesh with random-init weights - the CI gate for the streamed
full-traffic path.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.data import glyph_batch
from repro.noc import SweepGrid, run_sweep

from ._trained import get_trained, random_params

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _layers(name: str):
    if SMOKE:
        model, params = random_params(name)
    else:
        model, params, _ = get_trained(name)
    hw, ch = model.input_shape[0], model.input_shape[-1]
    x, _ = glyph_batch(jax.random.PRNGKey(11), 1, hw=hw, channels=ch)
    return model.layer_traffic(params, x[0])


def _grid() -> SweepGrid:
    return SweepGrid(
        meshes=("4x4_mc2",) if SMOKE else ("16x16_mc16",),
        placements=("edge", "interleaved"),
        transforms=("O0", "O1") if SMOKE else ("O0", "O1", "O2"),
        tiebreaks=("pattern",),
        precisions=("fixed8",),
        models=("lenet",) if SMOKE else ("darknet",),
        max_packets_per_layer=None,          # full traffic -> streamed path
        chunk=4096)


def run() -> dict:
    grid = _grid()
    model = grid.models[0]
    ndev = jax.local_device_count()
    layers = _layers(model)
    layers_fn = lambda _name: layers         # noqa: E731 - one shared load

    t0 = time.perf_counter()
    report = run_sweep(grid, layers_fn)      # devices="auto": sharded if >1
    wall = time.perf_counter() - t0

    results = {}
    for r in report.rows:
        key = f"{r['mesh']}/{r['placement']}/{r['precision']}/{r['transform']}"
        results[key] = {
            "total_bt": r["total_bt"], "cycles": r["cycles"],
            "flits": r["flits"],
            "reduction_pct":
                None if r["transform"] == grid.baseline
                else r["reduction_pct"],
            "adjusted_reduction_pct":
                None if r["transform"] == grid.baseline
                else r["adjusted_reduction_pct"],
        }

    bench = {
        "model": model, "mesh": grid.meshes[0],
        "placements": list(grid.placements),
        "packets_full": int(sum(int(l.inputs.shape[0]) for l in layers)),
        "wall_s": round(wall, 3),
        "devices": ndev,
        **{k: report.stats[k] for k in
           ("cells", "packetize_s", "simulate_s", "stepped_cycles",
            "cycles_per_sec", "streamed")},
        # per-shape-class engine throughput (one entry per mesh x model,
        # placements ride one drain-aware batched call)
        "shape_classes": report.stats["shape_classes"],
    }

    # Sharded-vs-unsharded speedup: re-drain one placement's shape class
    # unsharded and compare the simulate wall. Only meaningful with >1
    # device; a 1-device host records the fallback.
    if ndev > 1:
        import dataclasses
        probe = dataclasses.replace(grid, placements=(grid.placements[0],))
        sharded = run_sweep(probe, layers_fn)
        unsharded = run_sweep(probe, layers_fn, devices=None)
        assert [r["total_bt"] for r in sharded.rows] == \
            [r["total_bt"] for r in unsharded.rows], \
            "sharded drain diverged from unsharded"
        bench["sharded_simulate_s"] = sharded.stats["simulate_s"]
        bench["unsharded_simulate_s"] = unsharded.stats["simulate_s"]
        bench["shard_speedup"] = round(
            unsharded.stats["simulate_s"] / sharded.stats["simulate_s"], 2)
        bench["shard_bt_identical"] = True
    else:
        bench["shard_speedup"] = None

    return {"results": results, "bench": bench}


def main(print_csv=True):
    out = run()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "darknet_full.json"), "w") as f:
        json.dump(out["results"], f, indent=1)
    if print_csv:
        b = out["bench"]
        for key, r in out["results"].items():
            red = "" if r["reduction_pct"] is None else \
                f" reduction={r['reduction_pct']:.2f}%" \
                f" adj={r['adjusted_reduction_pct']:.2f}%"
            print(f"darknet_full/{key},0,bt={r['total_bt']}"
                  f" cycles={r['cycles']} flits={r['flits']}{red}")
        print(f"darknet_full/engine,{b['wall_s'] * 1e6:.0f},"
              f"cycles_per_sec={b['cycles_per_sec']}"
              f" devices={b['devices']} shard_speedup={b['shard_speedup']}")
    return out


if __name__ == "__main__":
    main()
