"""Trained LeNet/DarkNet weights for the paper's experiments.

No offline dataset ships with this container, so 'trained weights' come
from training on the procedural glyph task (repro.data.glyph_batch) - what
matters for the paper's BT statistics is the post-training weight
distribution (concentrated, near-zero-heavy), not the dataset identity.
Weights are cached under experiments/weights/.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.data import glyph_batch
from repro.models import LeNet, DarkNetLike, init_params
from repro.optim import AdamW, cosine
from repro.train import make_train_step, init_state, checkpoint

CACHE = os.path.join(os.path.dirname(__file__), "..", "experiments", "weights")


def _train(model, channels: int, steps: int, seed: int = 0, batch: int = 64):
    params = init_params(model.specs(), jax.random.PRNGKey(seed))
    opt = AdamW(cosine(2e-3, steps, warmup=max(steps // 20, 5)),
                weight_decay=1e-4)

    def loss_fn(p, b):
        x, y = b
        return model.loss(p, x, y)

    step = jax.jit(make_train_step(loss_fn, opt))
    st = init_state(params, opt)
    hw = model.input_shape[0]
    for i in range(steps):
        st, m = step(st, glyph_batch(jax.random.PRNGKey(1000 + i), batch,
                                     hw=hw, channels=channels))
    x, y = glyph_batch(jax.random.PRNGKey(9999), 512, hw=hw, channels=channels)
    acc = float(jnp.mean(jnp.argmax(model.forward(st.params, x), -1) == y))
    return st.params, float(m["loss"]), acc


def get_trained(name: str, steps: int = 400):
    """name in {lenet, darknet}; returns (model, trained params, accuracy)."""
    model = LeNet() if name == "lenet" else DarkNetLike()
    channels = model.input_shape[-1]
    ckpt_dir = os.path.join(CACHE, name)
    ref = init_params(model.specs(), jax.random.PRNGKey(0))
    got = checkpoint.restore(ckpt_dir, {"params": ref, "acc": jnp.zeros(())})
    if got is not None:
        blob = got[1]
        return model, blob["params"], float(blob["acc"])
    params, loss, acc = _train(model, channels, steps)
    checkpoint.save(ckpt_dir, steps, {"params": params,
                                      "acc": jnp.asarray(acc)})
    return model, params, acc


def random_params(name: str, seed: int = 1):
    model = LeNet() if name == "lenet" else DarkNetLike()
    return model, init_params(model.specs(), jax.random.PRNGKey(seed))
