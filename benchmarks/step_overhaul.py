"""Before/after measurement of the fused-state router-step overhaul.

Two numbers back the overhaul's claims in BENCH_noc.json:

* ``pinned_8x8``: cycles-per-sec of a fixed 2048-cycle chunk (B=3 variant
  lanes, 8x8/MC4, LeNet-like synthetic traffic) stepped by the frozen
  PR-3 unfused runner (``repro.noc._reference``) and by the fused runner -
  an apples-to-apples per-cycle cost comparison with no drain logic, no
  retirement, and no sharding involved.
* ``bt_identical``: the same pinned chunk's BT accumulators and ejection
  counts must agree bit-for-bit between the two steps mid-flight (the full
  36-cell drain parity lives in tests/test_noc_step.py).

``main()`` (the ``step_overhaul`` suite in ``benchmarks.run``) returns both
plus the retirement parity flag; ``--check-floor N`` runs only the fused
measurement and exits nonzero below N cycles/sec - the CI perf-smoke gate
against step regressions (the floor carries generous margin for CI jitter).
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wire import by_name
from repro.noc import make_noc
from repro.noc.sim import (_chunk_runner, _mesh_key, fuse_traffic,
                           make_state, simulate_batch)
from repro.noc.traffic import LayerTraffic, build_traffic, \
    build_traffic_batch
from repro.noc import _reference as ref

# The pinned chunk: mesh, lanes-of-variants, synthetic operand geometry,
# and the fixed cycle count every measurement steps.
PIN = {"mesh": "8x8_mc4", "variants": ("O0", "O1", "O2"), "packets": 400,
       "k": 32, "seed": 11, "cycles": 2048, "chunk": 512}

# cycles_per_sec of the full-DarkNet 16x16/MC16 darknet_full suite recorded
# in BENCH_noc.json at PR 3 - the overhaul's end-to-end baseline.
PR3_DARKNET_CPS = 416.9


def _pinned_traffic():
    cfg = make_noc(*[int(x) for x in
                     PIN["mesh"].replace("x", " ").replace("_mc", " ").split()])
    key = jax.random.PRNGKey(PIN["seed"])
    layers = [LayerTraffic(
        jax.random.normal(key, (PIN["packets"], PIN["k"])),
        jax.random.normal(jax.random.fold_in(key, 1),
                          (PIN["packets"], PIN["k"])) * 0.3)]
    variants = [(by_name(o), None) for o in PIN["variants"]]
    return cfg, build_traffic_batch(layers, cfg, variants)


def _time_chunks(step_fn, n_chunks):
    """Best-of-3 wall time for ``n_chunks`` sequential chunk calls."""
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        step_fn(n_chunks)
        best = min(best, time.perf_counter() - t0)
    return best


def _fused_cps(cfg, batch, backend: str = "fused"):
    b, m = batch.length.shape
    wire = fuse_traffic(batch, False)
    mc = jnp.broadcast_to(jnp.asarray(cfg.mc_nodes, jnp.int32), (b, m))
    run = _chunk_runner(_mesh_key(cfg), True, PIN["chunk"], True, False,
                        backend)
    state0 = jax.tree.map(lambda x: jnp.broadcast_to(x, (b,) + x.shape),
                          make_state(cfg, m))
    state, ej = run(state0, wire, mc)       # compile + warm
    holder = {"state": state}

    def go(n):
        st = holder["state"]
        for _ in range(n):
            st, ej = run(st, wire, mc)
        jax.block_until_ready(ej)
        holder["state"] = st

    n_chunks = PIN["cycles"] // PIN["chunk"]
    wall = _time_chunks(go, n_chunks)
    return b * PIN["cycles"] / wall, holder["state"]


def _unfused_cps(cfg, batch):
    b, m = batch.length.shape
    mc = jnp.asarray(cfg.mc_nodes, jnp.int32)
    run = ref._unfused_chunk_runner(ref._mesh_key_unfused(cfg), True,
                                    PIN["chunk"], True)
    state0 = jax.tree.map(lambda x: jnp.stack([x] * b),
                          ref.make_state(cfg, m))
    state = run(state0, batch, mc)          # compile + warm
    holder = {"state": state}

    def go(n):
        st = holder["state"]
        for _ in range(n):
            st = run(st, batch, mc)
        jax.block_until_ready(st.ejected)
        holder["state"] = st

    n_chunks = PIN["cycles"] // PIN["chunk"]
    wall = _time_chunks(go, n_chunks)
    return b * PIN["cycles"] / wall, holder["state"]


def pinned_chunk_compare() -> dict:
    """Step the pinned chunk with both generations; verify mid-flight BT
    agreement and report the cycles-per-sec ratio."""
    cfg, batch = _pinned_traffic()
    after_cps, fused_state = _fused_cps(cfg, batch)
    before_cps, unfused_state = _unfused_cps(cfg, batch)
    # Both holders stepped 1 warm + 3x timed chunk groups from zeroed
    # state, so their accumulators are comparable mid-flight.
    bt_ok = (np.array_equal(np.asarray(fused_state.link_bt),
                            np.asarray(unfused_state.link_bt))
             and np.array_equal(np.asarray(fused_state.inj_bt),
                                np.asarray(unfused_state.inj_bt))
             and np.array_equal(np.asarray(fused_state.ejected),
                                np.asarray(unfused_state.ejected)))
    return {
        "pinned": dict(PIN, variants=list(PIN["variants"])),
        "before_cps": round(before_cps, 1),
        "after_cps": round(after_cps, 1),
        "step_speedup": round(after_cps / before_cps, 2),
        "bt_identical": bool(bt_ok),
    }


def retirement_parity() -> bool:
    """Exact drain_cycle parity of the retire/compact scheduler vs the
    plain batched drain on heterogeneous lanes (also unit-tested)."""
    cfg = make_noc(3, 3, 1, lanes=4)
    key = jax.random.PRNGKey(5)
    singles = []
    for i, n in enumerate((34, 3, 11, 0)):
        ki = jax.random.fold_in(key, i)
        layer = LayerTraffic(
            jax.random.normal(ki, (n, 5)),
            jax.random.normal(jax.random.fold_in(ki, 1), (n, 5)) * 0.4)
        singles.append(build_traffic([layer], cfg, by_name("O0")))
    from repro.noc.traffic import stack_traffics
    batch = stack_traffics(singles)
    fast = simulate_batch(cfg, batch, chunk=32, retire=True)
    plain = simulate_batch(cfg, batch, chunk=32, retire=False)
    return all(f.drain_cycle == p.drain_cycle and f.total_bt == p.total_bt
               for f, p in zip(fast, plain))


def main(print_csv: bool = True) -> dict:
    cmp_ = pinned_chunk_compare()
    drain_ok = retirement_parity()
    bench = {**cmp_, "retirement_drain_parity": bool(drain_ok)}
    if not cmp_["bt_identical"]:
        raise RuntimeError("fused step diverged from the PR-3 step on the "
                           f"pinned chunk: {cmp_}")
    if not drain_ok:
        raise RuntimeError("retirement scheduler broke drain_cycle parity")
    if print_csv:
        print(f"step_overhaul/pinned_8x8,0,before={cmp_['before_cps']} "
              f"after={cmp_['after_cps']} speedup={cmp_['step_speedup']}x "
              f"bt_identical={cmp_['bt_identical']} "
              f"drain_parity={drain_ok}")
    return {"results": {"pinned_8x8": cmp_}, "bench": bench}


def check_floor(floor: float) -> None:
    cfg, batch = _pinned_traffic()
    cps, _ = _fused_cps(cfg, batch)
    print(f"step_overhaul floor check: {cps:.1f} cycles/sec "
          f"(floor {floor})")
    if cps < floor:
        raise SystemExit(
            f"fused-step perf regression: {cps:.1f} < floor {floor}")


if __name__ == "__main__":
    if len(sys.argv) == 1:
        main()
    elif sys.argv[1] == "--check-floor" and len(sys.argv) == 3:
        check_floor(float(sys.argv[2]))
    else:
        raise SystemExit(f"usage: {sys.argv[0]} [--check-floor CPS]")
