"""Fault-injection suite: do the ordering wins survive faults?

The offline figures price O1/O2/O3 on a perfect mesh; this suite prices
them on a faulty one (``repro.noc.faults``): seeded per-link soft errors
XOR'd into the payload lanes mid-flight, flit protection (parity/CRC-8)
stamped into the sideband and charged analytically like the O2 recovery
index, and bounded ACK/NACK retransmission whose retried flits toggle real
wires. Per (fault rate x protection x transform) cell the suite records
total BT over *all* transmission rounds and the fully adjusted BT
(payload BT + recovery-index bits/2 + protection bits/2, both charged the
half-transition toggle expectation of an uninformative stream), plus the
ordered-vs-O0 adjusted reduction at that operating point - the number the
ISSUE asks for. Unlike the clean sweeps, BT under faults is re-simulated
per transform: flips corrupt payload values, so the wire cost is no
longer shared across orderings.

Hard assertions (the suite fails rather than record nonsense): the null
model is bit-identical to ``simulate`` (total_bt/link_bt/drain_cycle),
every drain's conservation ledger closes (delivered + dropped +
retry-exhausted + unsent == injected), the dead-link schedule still
delivers every packet via detour routing, and the dead-router schedule
reports its unreachable packets as dropped - never silently lost.

``REPRO_BENCH_SMOKE=1`` shrinks to random-init LeNet on 4x4/MC2 with two
nonzero rates - the CI fault-injection smoke gate.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.data import glyph_batch
from repro.core.wire import by_name
from repro.noc import (PAPER_NOCS, FaultModel, make_noc,
                       build_traffic_batch, recovery_overhead_bits,
                       simulate, simulate_faulty)
from repro.quant import quantize_fixed8

from ._trained import get_trained, random_params

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

RATES = (0.0, 1e-3, 4e-3) if SMOKE else (0.0, 5e-4, 2e-3, 8e-3)
PROTECTS = ("parity", "crc8")
TRANSFORMS = ("O0", "O1", "O2")
MAXP = 8 if SMOKE else 24
CHUNK = 256 if SMOKE else 1024
SEED = 5


def _layers(name: str):
    if SMOKE:
        model, params = random_params(name)
    else:
        model, params, _ = get_trained(name)
    hw, ch = model.input_shape[0], model.input_shape[-1]
    x, _ = glyph_batch(jax.random.PRNGKey(11), 1, hw=hw, channels=ch)
    return model.layer_traffic(params, x[0])


def _adjusted(fd, layers, tr) -> int:
    """Payload BT + recovery index + protection bits, half a transition
    per overhead bit (the sweep engine's accounting convention)."""
    rec = recovery_overhead_bits(layers, by_name(tr),
                                 max_packets_per_layer=MAXP)
    return fd.sim.total_bt + rec // 2 + fd.ledger["protection_overhead_bits"] // 2


def main() -> dict:
    layers = _layers("lenet")
    cfg = PAPER_NOCS["4x4_mc2"] if SMOKE else make_noc(6, 6, 4)
    mesh = "4x4_mc2" if SMOKE else "6x6_mc4"
    quant = lambda t: quantize_fixed8(t).values      # noqa: E731
    batch = build_traffic_batch(layers, cfg,
                                [(by_name(tr), quant) for tr in TRANSFORMS],
                                max_packets_per_layer=MAXP)
    traffics = {tr: batch.variant(i) for i, tr in enumerate(TRANSFORMS)}

    # --- pin: the null model is bit-identical to the plain simulator.
    clean = simulate(cfg, traffics["O0"], chunk=CHUNK)
    fd0 = simulate_faulty(cfg, traffics["O0"], FaultModel(), chunk=CHUNK)
    zero_fault_identical = bool(
        clean.total_bt == fd0.sim.total_bt
        and clean.drain_cycle == fd0.sim.drain_cycle
        and np.array_equal(np.asarray(clean.link_bt),
                           np.asarray(fd0.sim.link_bt)))
    print(f"faults/{mesh}/zero_fault,{fd0.sim.total_bt},"
          f"identical={zero_fault_identical}")
    if not zero_fault_identical:
        raise AssertionError(
            "null FaultModel drain is not bit-identical to simulate(): "
            f"bt {clean.total_bt} vs {fd0.sim.total_bt}, "
            f"cycles {clean.drain_cycle} vs {fd0.sim.drain_cycle}")

    # --- the rate x protection x transform matrix.
    entries = []
    for protect in PROTECTS:
        for rate in RATES:
            base_adj = None
            for tr in TRANSFORMS:
                model = FaultModel(rate=rate, protect=protect, seed=SEED)
                fd = simulate_faulty(cfg, traffics[tr], model, chunk=CHUNK)
                led = fd.ledger
                if not led["conservation_ok"]:
                    raise AssertionError(
                        f"conservation violated at rate={rate} "
                        f"protect={protect} transform={tr}: {led}")
                adj = _adjusted(fd, layers, tr)
                if tr == "O0":
                    base_adj = adj
                red = (1 - adj / base_adj) * 100
                entries.append({
                    "mesh": mesh, "transform": tr, "fault_rate": rate,
                    "protect": protect, "total_bt": fd.sim.total_bt,
                    "adjusted_bt": adj,
                    "adjusted_reduction_pct": round(red, 3),
                    "drain_cycle": fd.sim.drain_cycle,
                    "transmitted_flits": led["transmitted_flits"],
                    "protection_overhead_bits":
                        led["protection_overhead_bits"],
                    "delivered": led["delivered"],
                    "retry_exhausted": led["retry_exhausted"],
                    "retried_packets": led["retried_packets"],
                    "total_retries": led["total_retries"],
                    "transmission_rounds": led["transmission_rounds"],
                    "silent_corrupt": led["silent_corrupt"],
                    "flip_events": led["flip_events"],
                    "conservation_ok": led["conservation_ok"],
                })
                print(f"faults/{mesh}/{tr}/rate{rate:g}/{protect},"
                      f"{fd.sim.total_bt},adj={adj} red={red:.2f}% "
                      f"retries={led['total_retries']} "
                      f"exhausted={led['retry_exhausted']}")

    # --- hard faults: a dead mid-mesh link must detour-deliver everything;
    # a dead router must report its packets dropped, never lose them.
    dead_link = FaultModel(dead_links=((cfg.cols + 1, 0),), seed=SEED)
    fdl = simulate_faulty(cfg, traffics["O0"], dead_link, chunk=CHUNK)
    dead_router = FaultModel(dead_routers=(cfg.cols + 1,), seed=SEED)
    fdr = simulate_faulty(cfg, traffics["O0"], dead_router, chunk=CHUNK)
    for name, fd in (("dead_link", fdl), ("dead_router", fdr)):
        led = fd.ledger
        if not led["conservation_ok"]:
            raise AssertionError(f"{name} ledger does not close: {led}")
        print(f"faults/{mesh}/{name},{fd.sim.total_bt},"
              f"delivered={led['delivered']} dropped={led['dropped']}")
    if fdl.ledger["dropped"] != 0 or fdl.ledger["delivered"] == 0:
        raise AssertionError(
            f"dead link should detour-deliver everything: {fdl.ledger}")
    if fdr.ledger["delivered"] == 0:
        raise AssertionError(
            f"dead router killed all delivery: {fdr.ledger}")

    hard = {
        name: {k: fd.ledger[k] for k in
               ("injected_packets", "delivered", "dropped",
                "retry_exhausted", "unsent", "conservation_ok")}
        for name, fd in (("dead_link", fdl), ("dead_router", fdr))}
    bench = {
        "mesh": mesh, "rates": list(RATES), "protects": list(PROTECTS),
        "transforms": list(TRANSFORMS), "max_packets_per_layer": MAXP,
        "seed": SEED,
        "zero_fault_identical": zero_fault_identical,
        "entries": entries,
        "hard_faults": hard,
    }
    return {"results": entries, "bench": bench}


if __name__ == "__main__":
    main()
